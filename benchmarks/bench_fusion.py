#!/usr/bin/env python
"""Round compression benchmark — eager vs fused flight accounting.

Prices the per-batch proxy op stream on both rings with and without the
flight batcher (mpc/fusion.py) via TraceEngine probes of the one
engine-generic forward, and models the WAN delay of a selection phase
over it (serial and §4.4-scheduled makespan). Emits `BENCH_fusion.json`
— the perf trajectory baseline for the fused MPC path.

`--smoke` additionally EXECUTES a tiny fused phase through the wave
executor and enforces the acceptance gates (CI tier-1 runs this):
  * ExecConfig runs the FUSED stream by default (launch --eager opts out)
  * fused RING32 2PC rounds < eager rounds (>= 40% fewer) at same bytes
  * fused vs eager output shares bitwise identical
  * the fused phase ledger satisfies iosched.ledger_agrees
  * the analytic mirror matches the fused probe record-for-record
  * scale-carrying gate (ISSUE 5): the RING32 2PC stream's truncation
    events are >= 25% below the frozen PR 4 per-op-trunc baseline
    (costs.pr4_trunc_baseline) with strictly lower dealer trunc-pair
    offline bytes — `trunc_events` / `offline_nbytes` land in
    BENCH_fusion.json as the regression trajectory

`--protocol 3pc` (the CI 3PC smoke job) runs the 2PC gates above AND
executes both rings under the replicated-3PC backend, additionally
gating:
  * ZERO dealer/offline events in every 3PC ledger (the dealer is dead)
  * costs.proxy_exec_cost(protocol="3pc") mirrors record-for-record
  * fused 3PC rounds strictly below eager at identical bytes

`--protocol aby3trunc` runs the dealer-free gates above under the exact
trunc2 backend; `--protocol spdz2pc` (the CI malicious smoke job) gates
the malicious tier instead: MAC'd offline bytes present, the boundary
mac_key/mac_check records in the eager stream, and fused rounds still
strictly below eager. Every run also emits `malicious_overhead` — the
semi-honest -> malicious cost curve (rounds, online/offline bytes,
truncation events) of each hardened backend against its semi-honest
baseline (spdz2pc vs 2pc, aby3trunc vs 3pc), per ring and fusion mode.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402
import jax  # noqa: E402

from repro.configs.base import ArchConfig  # noqa: E402
from repro.core import iosched  # noqa: E402
from repro.core.proxy import ProxySpec  # noqa: E402
from repro.engine import cached_probe, cached_probe_info  # noqa: E402
from repro.mpc import costs, protocols  # noqa: E402
from repro.mpc.comm import PROFILES, WAN, NetProfile  # noqa: E402
from repro.mpc.ring import RING32, RING64  # noqa: E402

RINGS = {"ring64": RING64, "ring32": RING32}

# protocols with no trusted dealer: their ledgers must never carry an
# offline channel or dealer-op records
DEALER_FREE = ("3pc", "aby3trunc")

# each hardened backend and the semi-honest baseline its overhead curve
# is measured against
SEMI_HONEST_OF = {"spdz2pc": "2pc", "aby3trunc": "3pc"}


def probe_grid(cfg: ArchConfig, spec: ProxySpec, *, batch: int, seq: int,
               classes: int, n_batches: int,
               protocol: str = "2pc", net: NetProfile = WAN) -> dict:
    """{ring}_{eager|fused} -> per-batch ledger totals + modeled delay.
    The offline (dealer) channel is reported separately — it is the axis
    on which the 3pc backend's zero sits. `net` prices the net_* keys
    (the same profile the socket pacer emulates under --wire); the
    legacy wan_* keys stay pinned to WAN for trajectory comparability."""
    out = {}
    sched = iosched.SchedConfig()
    for rname, ring in RINGS.items():
        for mode, fused in (("eager", False), ("fused", True)):
            t0 = time.time()
            led = cached_probe(cfg, spec, batch=batch, seq=seq,
                               classes=classes, ring=ring,
                               protocol=protocol, fused=fused)
            out[f"{rname}_{mode}"] = {
                "rounds": led.rounds,
                "lat_rounds": led.lat_rounds,
                "bw_rounds": led.bw_rounds,
                "nbytes": led.nbytes,
                "offline_nbytes": led.offline_nbytes,
                "flights": len(led.records),
                "wan_serial_s": led.serial_time(WAN),
                "wan_makespan_s": iosched.makespan(led, n_batches, WAN,
                                                   sched),
                "net": net.name,
                "net_serial_s": led.serial_time(net),
                "net_makespan_s": iosched.makespan(led, n_batches, net,
                                                   sched),
                "probe_ms": (time.time() - t0) * 1e3,
            }
    for rname in RINGS:
        e, f = out[f"{rname}_eager"], out[f"{rname}_fused"]
        out[f"{rname}_round_reduction"] = 1.0 - f["rounds"] / e["rounds"]
    return out


def smoke_execute(protocol: str = "2pc") -> dict:
    """Run a tiny phase for real (eager + fused) and enforce the gates."""
    from benchmarks.common import tiny_exec_setup
    from repro.core.executor import ExecConfig, WaveExecutor

    # the flipped default is itself a gate: deployments run fused unless
    # they explicitly opt out (launch/select.py --eager)
    assert ExecConfig().fuse is True, "ExecConfig.fuse default must be True"

    seq, classes, pool_n, batch, wave = 8, 2, 24, 8, 2
    cfg, spec, pp = tiny_exec_setup(0, seq=seq, n_classes=classes)
    pool = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                             (pool_n, seq))
    key = jax.random.key(7)
    out = {}
    for rname, ring in RINGS.items():
        scores, reports = {}, {}
        for mode, fused in (("eager", False), ("fused", True)):
            ex = WaveExecutor(ExecConfig(wave=wave, batch=batch, ring=ring,
                                         fuse=fused, protocol=protocol))
            ent = ex.score_phase(key, pp, cfg, pool, spec)
            scores[mode], reports[mode] = np.asarray(ent.sh), ex.reports[-1]
        assert np.array_equal(scores["eager"], scores["fused"]), \
            f"{protocol}/{rname}: fusion changed output shares"
        for mode, rep in reports.items():
            assert rep.agrees(), \
                f"{protocol}/{rname}/{mode}: ledger_agrees failed"
        ana = costs.proxy_exec_cost(batch, seq, cfg.d_model, spec.n_heads,
                                    cfg.n_kv_heads, cfg.d_head, spec.mlp_dim,
                                    classes, spec.n_layers, ring=ring,
                                    protocol=protocol, fused=True)
        pb = reports["fused"].per_batch
        assert len(pb.records) == len(ana.records) and all(
            (g.rounds, g.nbytes, g.numel, g.flops, g.tag)
            == (w.rounds, w.nbytes, w.numel, w.flops, w.tag)
            for g, w in zip(pb.records, ana.records)), \
            f"{protocol}/{rname}: proxy_exec_cost(fused=True) mirror diverged"
        if protocol in DEALER_FREE:
            # the headline gate: the dealer is DEAD — no offline channel,
            # no dealer ops, anywhere in the executed phase ledger
            for mode, rep in reports.items():
                led = rep.ledger
                assert led.offline_nbytes == 0, \
                    f"{protocol}/{rname}/{mode}: offline bytes in a " \
                    f"dealer-free ledger"
                bad = [r.op for r in led.records
                       if r.tag == "offline" or r.op.startswith("offline")
                       or r.op.startswith("beaver")
                       or r.op.startswith("trunc_open")]
                assert not bad, \
                    f"{protocol}/{rname}/{mode}: dealer events {bad}"
        if protocol == "spdz2pc":
            # the malicious gates: MAC'd dealer randomness present, and
            # the boundary MAC check + key shipment on every ledger (op
            # names survive fusion only on the offline channel and the
            # eager stream — check each where it is visible)
            for mode, rep in reports.items():
                led = rep.ledger
                assert led.offline_nbytes > 0, \
                    f"spdz2pc/{rname}/{mode}: no MAC'd offline bytes"
                assert any(r.op.endswith("mac_key") for r in led.records), \
                    f"spdz2pc/{rname}/{mode}: no MAC-key shipment record"
            eager_ops = [r.op for r in reports["eager"].per_batch.records]
            assert "mac_check" in eager_ops, \
                f"spdz2pc/{rname}: no boundary mac_check in eager stream"
            assert "sacrifice" in eager_ops, \
                f"spdz2pc/{rname}: no triple-sacrifice flight"
        e = reports["eager"].per_batch
        red = 1.0 - pb.rounds / e.rounds
        assert pb.nbytes == e.nbytes, \
            f"{protocol}/{rname}: fusion changed bytes"
        assert pb.rounds < e.rounds, f"{protocol}/{rname}: no round reduction"
        if ring is RING32 and protocol == "2pc":
            assert red >= 0.40, \
                f"ring32 round reduction {red:.2%} below the 40% gate"
        # scale-carrying truncation events: every force is one bw trunc
        # flight in the EAGER stream (trunc_open / trunc_reshare); the
        # dealer pair bytes ride the offline channel in both modes
        trunc_events = sum(1 for r in e.records
                           if r.tag == "bw" and "trunc" in r.op)
        trunc_pair_bytes = sum(r.nbytes for r in pb.records
                               if r.tag == "offline" and "trunc" in r.op)
        base_events, base_bytes = costs.pr4_trunc_baseline(
            batch, seq, cfg.d_model, spec.n_heads, cfg.n_kv_heads,
            cfg.d_head, spec.mlp_dim, classes, spec.n_layers, ring=ring)
        trunc_red = 1.0 - trunc_events / base_events
        if ring is RING32 and protocol == "2pc":
            # the ISSUE 5 gate: cross-op deferred truncation must strip
            # >= 25% of the per-op trunc events AND the dealer's pair
            # bytes versus the frozen PR 4 stream
            assert trunc_red >= 0.25, \
                f"trunc events {trunc_events} vs PR4 {base_events}: " \
                f"{trunc_red:.2%} below the 25% gate"
            assert trunc_pair_bytes < base_bytes, \
                f"trunc-pair bytes {trunc_pair_bytes} not below PR4 " \
                f"baseline {base_bytes}"
        if (ring is RING64 and trunc_events
                and protocols.get(protocol).exact_trunc):
            # the ring-parameterized headroom cap (scale.cap: 3f fits in
            # 63 bits, so RING64 defers one more truncation than the
            # RING32 2f cap) — the new RING64 floor, for the backends
            # whose truncation is EXACT at any exponent (spdz2pc dealer
            # pairs, aby3trunc trunc2); probabilistic local-trunc
            # backends keep the 2f cap (ops._headroom_bits) and are not
            # gated here. pr4_trunc_baseline stays FROZEN at the PR 4
            # per-op stream, so the reduction key tracks the widening
            # gap rather than moving the goalpost
            assert trunc_events <= 16, \
                f"{protocol}/ring64: {trunc_events} trunc events above " \
                f"the 3f-headroom floor of 16"
        if protocol in DEALER_FREE:
            assert pb.offline_nbytes == 0, \
                f"{protocol}/{rname}: folded dealer-free probe carries " \
                f"offline bytes"
        out[rname] = {"eager_rounds": e.rounds, "fused_rounds": pb.rounds,
                      "round_reduction": red, "bitwise_identical": True,
                      "ledger_agrees": True, "mirror_exact": True,
                      # measured device-side makespan of the fused phase
                      # (per-wave dispatch/ready stamps, PhaseReport.device)
                      "device_makespan_s": reports["fused"].device_makespan_s,
                      "offline_nbytes": pb.offline_nbytes,
                      "trunc_events": trunc_events,
                      "trunc_events_pr4": base_events,
                      "trunc_event_reduction": trunc_red,
                      "trunc_pair_nbytes": trunc_pair_bytes,
                      "trunc_pair_nbytes_pr4": base_bytes}
    # the ring-cap dividend in one number: how many MORE trunc events
    # the 2f RING32 cap pays than the RING64 cap (1 on the exact-trunc
    # backends spdz2pc/aby3trunc — the only ones allowed the 3f
    # deferral; 0 on 3pc, whose probabilistic local trunc keeps 2f on
    # both rings; 17 on semi-honest 2pc, whose RING64 truncation is
    # recordless-local and never hits the wire at all)
    out["ring64_trunc_event_delta"] = (out["ring32"]["trunc_events"]
                                       - out["ring64"]["trunc_events"])
    return out


def mesh_smoke() -> dict:
    """Execute the RING32 2pc smoke phase on a REAL device mesh
    (forced host devices on CPU CI) and enforce the device-half gates:
      * mesh="host": party axis -> "pod" devices, wave axis -> "data"
        devices via NamedSharding device_put (GSPMD collectives at the
        opens); mesh="shardmap": wave lanes split across the data axis
        under jax.shard_map — BOTH must yield entropy scores bitwise
        identical to the single-device run and ledger_agrees
      * combine="interpret": the fused RING32 Beaver combines must
        demonstrably run through kernels/ops.secure_matmul (kernel-path
        dispatch counter > 0, ref-fallback counter == 0) instead of the
        silent jnp reference
      * device_makespan_s > 0 measured from the double-buffer loop's
        per-wave dispatch/ready stamps
    Geometry: 64 candidates / batch 8 / wave 4 -> 2 waves x 4 lanes, so
    the lane count divides the data axis on an 8-device mesh (pod 2 x
    data 4)."""
    from benchmarks.common import tiny_exec_setup
    from repro.core.executor import ExecConfig, WaveExecutor

    seq, classes, pool_n, batch, wave = 8, 2, 64, 8, 4
    cfg, spec, pp = tiny_exec_setup(0, seq=seq, n_classes=classes)
    pool = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                             (pool_n, seq))
    key = jax.random.key(7)
    n_dev = len(jax.devices())
    out = {"n_devices": n_dev}

    ex0 = WaveExecutor(ExecConfig(wave=wave, batch=batch, ring=RING32))
    ref = np.asarray(ex0.score_phase(key, pp, cfg, pool, spec).sh)
    rep0 = ex0.reports[-1]
    assert rep0.agrees(), "mesh: single-device reference ledger diverged"
    out["none"] = {"device_makespan_s": rep0.device_makespan_s,
                   "wall_s": rep0.wall_s}

    for mode in ("host", "shardmap"):
        ex = WaveExecutor(ExecConfig(wave=wave, batch=batch, ring=RING32,
                                     mesh=mode, combine="interpret"))
        ent = ex.score_phase(key, pp, cfg, pool, spec)
        rep = ex.reports[-1]
        dev = rep.device
        assert np.array_equal(ref, np.asarray(ent.sh)), \
            f"mesh={mode}: sharded execution changed entropy scores"
        assert rep.agrees(), f"mesh={mode}: ledger_agrees failed"
        assert dev.device_makespan_s > 0.0, \
            f"mesh={mode}: no measured device makespan"
        assert dev.combine_kernel > 0, \
            f"mesh={mode}: fused RING32 combines never hit the " \
            f"secure_matmul kernel (interpret mode)"
        assert dev.combine_ref == 0, \
            f"mesh={mode}: {dev.combine_ref} combines silently fell " \
            f"back to the jnp reference"
        if mode == "host" and n_dev >= 2 and n_dev % 2 == 0:
            assert dev.mesh_axes.get("pod") == 2, \
                f"host mesh did not map the 2pc party axis to a pod " \
                f"axis: {dev.mesh_axes}"
        out[mode] = {
            "bitwise_identical": True,
            "ledger_agrees": True,
            "n_devices": dev.n_devices,
            "mesh_axes": dev.mesh_axes,
            "device_makespan_s": dev.device_makespan_s,
            "wall_s": rep.wall_s,
            "combine_kernel": dev.combine_kernel,
            "combine_ref": dev.combine_ref,
            "combine_padded": dev.combine_padded,
            "devices_used": [w.devices_used for w in dev.waves],
        }
    return out


def wire_smoke(wire: str, net: str,
               wire_protocols=("2pc", "3pc")) -> dict:
    """Execute the smoke phase over a REAL transport (repro/net/) and
    enforce the real-wire acceptance gates, per protocol:
      * entropy shares bitwise identical to the ledger-only default path
        (coalesced + fused — the wire run forces the eager schedule, so
        this doubles as a schedule-invariance check)
      * transport-counted bytes == ledger nbytes (record-for-record via
        net.reconcile inside the executor, totals re-asserted here)
      * every party's received-payload digest matches the flight tape
    `wire_makespan_s` is MEASURED wall-clock between the parties' SYNC
    barrier and the last party finishing — under --wire socket the links
    are paced/delayed to emulate `net`, so the number sits next to the
    modeled makespan as an experiment vs its model."""
    from benchmarks.common import tiny_exec_setup
    from repro.core.executor import ExecConfig, WaveExecutor

    seq, classes, pool_n, batch, wave = 8, 2, 24, 8, 2
    cfg, spec, pp = tiny_exec_setup(0, seq=seq, n_classes=classes)
    pool = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                             (pool_n, seq))
    key = jax.random.key(7)
    profile = PROFILES[net]
    out = {"mode": wire, "net": net}
    for proto in wire_protocols:
        ex0 = WaveExecutor(ExecConfig(wave=wave, batch=batch,
                                      protocol=proto))
        ref = np.asarray(ex0.score_phase(key, pp, cfg, pool, spec).sh)
        ex = WaveExecutor(ExecConfig(wave=wave, batch=batch, protocol=proto,
                                     wire=wire, net=net))
        ent = ex.score_phase(key, pp, cfg, pool, spec)
        rep = ex.reports[-1]
        w = rep.wire
        assert w is not None, f"{proto}: wire run produced no WireReport"
        assert np.array_equal(ref, np.asarray(ent.sh)), \
            f"{proto}: wire execution changed entropy scores"
        assert w.bytes_match, \
            f"{proto}: wire bytes {w.wire_nbytes} != tape {w.tape_nbytes}"
        assert w.wire_nbytes == rep.ledger.nbytes, \
            f"{proto}: wire bytes {w.wire_nbytes} != ledger " \
            f"{rep.ledger.nbytes}"
        assert w.digests_ok, f"{proto}: received-payload digests diverged"
        out[proto] = {
            "wire_makespan_s": w.wire_makespan_s,
            "modeled_makespan_s": rep.makespan(profile),
            "nbytes": w.wire_nbytes,
            "flights": w.n_flights,
            "msgs": w.n_msgs,
            "frames": w.n_frames,
            "beats_seen": w.beats_seen,
            "suspects": w.suspects,
            "n_parties": w.n_parties,
            "bitwise_identical": True,
            "bytes_match": True,
            "digests_ok": True,
        }
    return out


def chaos_smoke(wire: str, net: str, seed: int,
                wire_protocols=("2pc", "3pc")) -> dict:
    """Execute the wire smoke under a seeded FaultPlan (net/faults.py)
    and enforce the chaos acceptance gates, per protocol:
      * the replay COMPLETES despite dropped frames, latency spikes, a
        connection reset, and (3pc) a party crash mid-phase
      * entropy scores stay bitwise identical to the fault-free path
      * goodput still reconciles byte-for-byte against the ledger —
        recovery traffic rides the separate RETRANS channel
      * `retries > 0` (losses actually recovered, not dodged) and, when
        the plan crashes a party, `respawns >= 1` / `recovery_time_s > 0`
      * determinism: the same seed over the same tape produces the
        identical fault placement (2pc runs twice and compares plans)
    """
    from benchmarks.common import tiny_exec_setup
    from repro.core.executor import ExecConfig, WaveExecutor

    seq, classes, pool_n, batch, wave = 8, 2, 24, 8, 2
    cfg, spec, pp = tiny_exec_setup(0, seq=seq, n_classes=classes)
    pool = np.random.default_rng(0).integers(0, cfg.vocab_size,
                                             (pool_n, seq))
    key = jax.random.key(7)
    out = {"mode": wire, "net": net, "seed": seed}
    for proto in wire_protocols:
        ex0 = WaveExecutor(ExecConfig(wave=wave, batch=batch,
                                      protocol=proto))
        ref = np.asarray(ex0.score_phase(key, pp, cfg, pool, spec).sh)
        n_runs = 2 if proto == "2pc" else 1    # 2pc doubles as the
        runs = []                              # determinism witness
        for _ in range(n_runs):
            ex = WaveExecutor(ExecConfig(wave=wave, batch=batch,
                                         protocol=proto, wire=wire,
                                         net=net, chaos_seed=seed))
            ent = ex.score_phase(key, pp, cfg, pool, spec)
            runs.append((np.asarray(ent.sh), ex.reports[-1].wire))
        got, w = runs[0]
        assert w is not None and w.faults_injected > 0, \
            f"{proto}: chaos run injected no faults"
        assert np.array_equal(ref, got), \
            f"{proto}: chaos execution changed entropy scores"
        assert w.bytes_match, \
            f"{proto}: goodput {w.wire_nbytes} != tape {w.tape_nbytes} " \
            f"under chaos"
        assert w.digests_ok, f"{proto}: payload digests diverged under chaos"
        assert w.retries > 0, f"{proto}: no retries — faults not exercised"
        plan = json.loads(w.fault_plan)
        if plan.get("crash"):
            assert w.respawns >= 1 or w.degraded, \
                f"{proto}: crashed party neither respawned nor degraded"
            assert w.recovery_time_s > 0, \
                f"{proto}: crash recovered in zero time?"
        for _, w2 in runs[1:]:
            assert w2.fault_plan == w.fault_plan, \
                f"{proto}: same seed produced a different fault placement"
        out[proto] = {
            "faults_injected": w.faults_injected,
            "fault_plan": plan,
            "retries": w.retries,
            "retrans_bytes": w.retrans_bytes,
            "ack_bytes": w.ack_bytes,
            "dup_frames": w.dup_frames,
            "reconnects": w.reconnects,
            "respawns": w.respawns,
            "recovery_time_s": w.recovery_time_s,
            "degraded": w.degraded,
            "dead_parties": w.dead_parties,
            "nbytes": w.wire_nbytes,
            "wire_makespan_s": w.wire_makespan_s,
            "bitwise_identical": True,
            "bytes_match": True,
            "digests_ok": True,
            "deterministic": True,
        }
    return out


def _trunc_events(led) -> int:
    """Protocol-level truncation events in an EAGER stream (trunc_open /
    trunc2 / trunc_reshare); fused streams fold bw op names into their
    flights, so the count is always taken from the eager probe — the
    events themselves are mode-invariant."""
    return sum(1 for r in led.records if r.tag == "bw" and "trunc" in r.op)


def malicious_overhead(cfg: ArchConfig, spec: ProxySpec, *, batch: int,
                       seq: int, classes: int) -> dict:
    """The semi-honest -> hardened cost curve: per-batch TraceEngine
    probes of each hardened backend against its baseline (spdz2pc vs
    2pc, aby3trunc vs 3pc) on both rings and both fusion modes —
    rounds, online bytes, offline (dealer) bytes, truncation events.
    This is what malicious security costs on the wire."""
    out = {}
    for mal, base in SEMI_HONEST_OF.items():
        for rname, ring in RINGS.items():
            leds = {}
            for proto in (mal, base):
                for mode, fused in (("eager", False), ("fused", True)):
                    # memoized: the 2pc/3pc baselines here are the SAME
                    # probes the probe_grid of a matching --protocol run
                    # already paid for (~1 s each)
                    leds[proto, mode] = cached_probe(
                        cfg, spec, batch=batch, seq=seq, classes=classes,
                        ring=ring, protocol=proto, fused=fused)
            te_m = _trunc_events(leds[mal, "eager"])
            te_b = _trunc_events(leds[base, "eager"])
            for mode in ("eager", "fused"):
                lm, lb = leds[mal, mode], leds[base, mode]
                out[f"{mal}_{rname}_{mode}"] = {
                    "baseline": base,
                    "rounds": lm.rounds,
                    "rounds_base": lb.rounds,
                    "rounds_overhead": lm.rounds - lb.rounds,
                    "online_nbytes": lm.nbytes,
                    "online_nbytes_base": lb.nbytes,
                    "online_overhead": lm.nbytes - lb.nbytes,
                    "offline_nbytes": lm.offline_nbytes,
                    "offline_nbytes_base": lb.offline_nbytes,
                    "trunc_events": te_m,
                    "trunc_events_base": te_b,
                }
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry + executed acceptance gates (CI)")
    ap.add_argument("--protocol",
                    choices=["2pc", "3pc", "spdz2pc", "aby3trunc"],
                    default="2pc",
                    help="secret-sharing backend to bench; any non-2pc "
                         "choice also re-runs the 2pc gates (the CI 3pc "
                         "and malicious smoke jobs)")
    ap.add_argument("--wire", choices=["none", "local", "socket"],
                    default="none",
                    help="execute the smoke phase over a real transport "
                         "(repro/net/): 'local' = one thread per party "
                         "over in-process queues, 'socket' = one process "
                         "per party over paced localhost TCP emulating "
                         "--net; measures wire_makespan_s and reconciles "
                         "transport bytes against the ledger "
                         "(requires --smoke)")
    ap.add_argument("--mesh", action="store_true",
                    help="execute the smoke phase on a real device mesh "
                         "(party -> pod, wave -> data; forced host "
                         "devices on CPU) in both host-GSPMD and "
                         "shard_map placements, gating bitwise scores, "
                         "ledger agreement, the secure_matmul kernel "
                         "combine path, and a measured device_makespan_s "
                         "(requires --smoke; run under XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8)")
    ap.add_argument("--net", choices=sorted(PROFILES), default="wan",
                    help="NetProfile for BOTH the delay model (net_* "
                         "probe keys) and the socket pacer")
    ap.add_argument("--chaos", action="store_true",
                    help="re-run the wire smoke under a seeded FaultPlan "
                         "(drops, spikes, a connection reset, a party "
                         "crash) and gate recovery: scores bitwise "
                         "identical, goodput reconciled, retries > 0; "
                         "lands in BENCH_fusion.json['chaos'] "
                         "(requires --smoke and --wire)")
    ap.add_argument("--chaos-seed", type=int, default=123,
                    help="FaultPlan seed (same seed + tape = identical "
                         "fault placement)")
    ap.add_argument("--csv", action="store_true",
                    help="emit benchmarks.run CSV rows instead of summary")
    ap.add_argument("--out", default="BENCH_fusion.json")
    args = ap.parse_args(argv)
    if args.wire != "none" and not args.smoke:
        ap.error("--wire requires --smoke (the paper-scale geometry is "
                 "probed analytically, never executed)")
    if args.chaos and args.wire == "none":
        ap.error("--chaos requires --wire local|socket (faults are "
                 "injected into a real transport)")
    if args.mesh:
        if not args.smoke:
            ap.error("--mesh requires --smoke (only the smoke geometry "
                     "is executed on the mesh)")
        # only effective before backend init — the CI job sets XLA_FLAGS
        # in the environment; this covers direct script invocations
        from repro.parallel import sharding as _sharding
        _sharding.force_host_devices(8)

    if args.smoke:
        cfg = ArchConfig(name="fusion-smoke", family="dense", n_layers=1,
                         d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                         d_ff=64, vocab_size=64)
        spec, batch, seq, classes, n_batches = ProxySpec(1, 2, 4), 8, 8, 2, 3
    else:
        # paper scale: BERT-base phase-2 proxy <3, 12, 16> over 42K docs
        cfg = ArchConfig(name="bert-base", family="dense", n_layers=3,
                         d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
                         d_ff=3072, vocab_size=30522)
        spec, batch, seq, classes = ProxySpec(3, 12, 16), 4, 512, 2
        n_batches = -(-42_000 // batch)

    result = {
        "geometry": {"arch": cfg.name, "proxy": dataclasses.asdict(spec),
                     "batch": batch, "seq": seq, "classes": classes,
                     "n_batches": n_batches, "protocol": args.protocol,
                     "net": args.net, "wire": args.wire},
        "probe": probe_grid(cfg, spec, batch=batch, seq=seq,
                            classes=classes, n_batches=n_batches,
                            protocol=args.protocol, net=PROFILES[args.net]),
        # the semi-honest -> malicious overhead curve always ships with
        # the benchmark: it is the trajectory the malicious smoke job
        # gates and the number the threat-model docs quote
        "malicious_overhead": malicious_overhead(cfg, spec, batch=batch,
                                                 seq=seq, classes=classes),
    }
    if args.smoke:
        # the 2pc gates always run (a hardened job must not regress
        # 2pc); any other --protocol adds its own gates on top
        result["smoke"] = smoke_execute("2pc")
        if args.protocol != "2pc":
            result[f"smoke_{args.protocol}"] = smoke_execute(args.protocol)
        if args.mesh:
            # device-mesh gates: wave/party axes physically sharded,
            # kernel-path combines, measured device_makespan_s
            result["mesh"] = mesh_smoke()
        if args.wire != "none":
            # real-wire gates: both party counts (2pc duplex pair, 3pc
            # ring) cross the transport; wire_makespan_s is measured
            result["wire"] = wire_smoke(args.wire, args.net)
        if args.chaos:
            result["chaos"] = chaos_smoke(args.wire, args.net,
                                          args.chaos_seed)
    ci = cached_probe_info()
    result["probe_cache"] = {"hits": ci.hits, "misses": ci.misses}

    for key, curve in result["malicious_overhead"].items():
        if curve["rounds_overhead"] < 0:
            print(f"FAIL: {key}: hardened backend claims FEWER rounds "
                  f"than its semi-honest baseline", file=sys.stderr)
            return 1
        if key.startswith("aby3trunc_ring64"):
            # exact trunc2 unlocks the 3f headroom deferral the
            # semi-honest 3pc baseline's probabilistic local trunc must
            # forgo (ops._headroom_bits) — hardening strictly REDUCES
            # truncation events here even as rounds stay above baseline
            if curve["trunc_events"] >= curve["trunc_events_base"]:
                print(f"FAIL: {key}: exact-trunc backend did not defer "
                      f"past its semi-honest baseline's 2f cap",
                      file=sys.stderr)
                return 1
    if args.protocol == "spdz2pc":
        off = sum(v["offline_nbytes"] for v in result["probe"].values()
                  if isinstance(v, dict))
        if off == 0:
            print("FAIL: spdz2pc probe carries no MAC'd offline bytes",
                  file=sys.stderr)
            return 1
        for rname in RINGS:
            curve = result["malicious_overhead"][f"spdz2pc_{rname}_eager"]
            if curve["rounds_overhead"] <= 0:
                print(f"FAIL: spdz2pc/{rname}: malicious hardening shows "
                      f"no round overhead (sacrifice/mac_check missing?)",
                      file=sys.stderr)
                return 1
            if curve["offline_nbytes"] <= curve["offline_nbytes_base"]:
                print(f"FAIL: spdz2pc/{rname}: MAC'd offline bytes not "
                      f"above the semi-honest dealer's", file=sys.stderr)
                return 1
        r32 = result["probe"]["ring32_round_reduction"]
        if r32 <= 0.0:
            print("FAIL: fused spdz2pc probe shows no round reduction",
                  file=sys.stderr)
            return 1
    elif args.protocol in DEALER_FREE:
        off = sum(v["offline_nbytes"] for v in result["probe"].values()
                  if isinstance(v, dict))
        if off != 0:
            print(f"FAIL: {args.protocol} probe carries {off} offline "
                  f"dealer bytes", file=sys.stderr)
            return 1
        r32 = result["probe"]["ring32_round_reduction"]
        if r32 <= 0.0:
            print(f"FAIL: fused {args.protocol} probe shows no round "
                  f"reduction", file=sys.stderr)
            return 1
    else:
        r32 = result["probe"]["ring32_round_reduction"]
        if r32 < 0.40:
            print(f"FAIL: fused RING32 probe reduces rounds by only "
                  f"{r32:.2%}", file=sys.stderr)
            return 1
    # merge-update: different CI jobs write different sections (--mesh
    # adds "mesh", --wire adds "wire"/"chaos", --protocol its smoke_*);
    # each run overwrites only the sections it recomputed, so the
    # checked-in artifact accumulates every job's trajectory instead of
    # the last job clobbering the others
    merged = {}
    try:
        with open(args.out) as f:
            merged = json.load(f)
    except (OSError, ValueError):
        pass
    merged.update(result)
    result = merged
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    for k, v in result["probe"].items():
        if args.csv:
            from benchmarks.common import emit
            if isinstance(v, dict):
                emit(f"fusion.{k}", v["probe_ms"] * 1e3,
                     {"rounds": v["rounds"], "nbytes": v["nbytes"],
                      "wan_makespan_s": round(v["wan_makespan_s"], 3)})
            else:
                emit(f"fusion.{k}", 0.0, {"reduction": round(v, 4)})
        elif isinstance(v, dict):
            print(f"{k}: rounds={v['rounds']} bytes={v['nbytes']} "
                  f"wan_makespan={v['wan_makespan_s']:.1f}s")
        else:
            print(f"{k}: {v:.2%}")
    if "mesh" in result and not args.csv:
        mv = result["mesh"]
        for mode in ("host", "shardmap"):
            m = mv[mode]
            print(f"mesh[{mode}] devices={m['n_devices']} "
                  f"axes={m['mesh_axes']} "
                  f"device_makespan={m['device_makespan_s']:.3f}s "
                  f"kernel_combines={m['combine_kernel']} "
                  f"padded={m['combine_padded']}")
    if "wire" in result and not args.csv:
        for proto in ("2pc", "3pc"):
            wv = result["wire"][proto]
            print(f"wire[{result['wire']['mode']}/{result['wire']['net']}] "
                  f"{proto}: measured={wv['wire_makespan_s']:.3f}s "
                  f"modeled={wv['modeled_makespan_s']:.3f}s "
                  f"bytes={wv['nbytes']} flights={wv['flights']}")
    if "chaos" in result and not args.csv:
        for proto in ("2pc", "3pc"):
            cv = result["chaos"][proto]
            print(f"chaos[{result['chaos']['mode']}] {proto}: "
                  f"faults={cv['faults_injected']} retries={cv['retries']} "
                  f"retrans={cv['retrans_bytes']}B "
                  f"respawns={cv['respawns']} "
                  f"recovery={cv['recovery_time_s']:.3f}s "
                  f"degraded={cv['degraded']}")
    if not args.csv:
        print(f"wrote {args.out}")
    return 0


def run() -> None:
    """benchmarks.run harness entry: smoke geometry, CSV rows, and the
    executed acceptance gates (raises on regression)."""
    if main(["--smoke", "--csv"]) != 0:
        raise RuntimeError("fused RING32 round reduction below the gate")


if __name__ == "__main__":
    raise SystemExit(main())
