"""Table 1/8: selection efficacy — Ours vs Random vs Oracle.

CPU-scale instantiation of the paper's protocol: tiny encoder target,
synthetic imbalanced unlabeled pool, 20% budget. Asserts the paper's
ordering (Ours > Random, Ours ~ Oracle) averaged over seeds.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.configs.paper_targets import TINY_TARGET
from repro.core import target as tgt
from repro.core.proxy import ProxySpec
from repro.core.selection import SelectionConfig, run_selection
from repro.data.tasks import make_classification_task

SEEDS = (0, 1)
POOL = 500
BUDGET = 0.25


def _one_seed(seed: int) -> dict:
    task = make_classification_task(seed, n_pool=POOL, n_test=300, seq=12,
                                    vocab=256, n_classes=4)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=256, n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4,
                              d_head=16, d_ff=128)
    key = jax.random.key(seed)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)
    sel = SelectionConfig(phases=[ProxySpec(1, 2, 2, 0.5),
                                  ProxySpec(2, 4, 8, 1.0)],
                          budget_frac=BUDGET, boot_frac=0.06,
                          exvivo_steps=120, invivo_steps=50,
                          finetune_steps=60)
    res = run_selection(key, params0, cfg, task.pool_tokens, sel,
                        n_classes=task.n_classes,
                        boot_labels_fn=lambda i: task.pool_labels[i])
    n_sel = len(res.selected)
    rng = np.random.default_rng(seed)
    rand_idx = rng.choice(POOL, size=n_sel, replace=False)
    # oracle: entropy under the FULL finetuned target (gold selection)
    mg, _ = tgt.finetune(jax.random.fold_in(key, 3), params0, cfg,
                         jnp.asarray(task.pool_tokens[res.boot_idx]),
                         jnp.asarray(task.pool_labels[res.boot_idx]),
                         steps=100)
    ent = np.asarray(tgt.prediction_entropy(mg, cfg,
                                            jnp.asarray(task.pool_tokens)))
    oracle_idx = np.argsort(ent)[-n_sel:]

    accs = {}
    for name, idx in (("ours", res.selected), ("random", rand_idx),
                      ("oracle", oracle_idx)):
        p, _ = tgt.finetune(jax.random.fold_in(key, 11), params0, cfg,
                            jnp.asarray(task.pool_tokens[idx]),
                            jnp.asarray(task.pool_labels[idx]), steps=150)
        accs[name] = tgt.accuracy(p, cfg, jnp.asarray(task.test_tokens),
                                  task.test_labels)
    return accs


def run() -> dict:
    rows = []
    with timed() as t:
        for s in SEEDS:
            rows.append(_one_seed(s))
    mean = {k: float(np.mean([r[k] for r in rows])) for k in rows[0]}
    emit("table1.accuracy", t.us, {
        "ours": round(mean["ours"], 3), "random": round(mean["random"], 3),
        "oracle": round(mean["oracle"], 3),
        "ours_minus_random": round(mean["ours"] - mean["random"], 3),
        "oracle_minus_ours": round(mean["oracle"] - mean["ours"], 3),
        "seeds": len(SEEDS)})
    assert mean["ours"] > mean["random"] - 0.01, mean
    assert mean["oracle"] - mean["ours"] < 0.10, mean
    return mean
