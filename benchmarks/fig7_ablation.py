"""Figure 7: delay reduction per technique — P / PM / PMT / Ours.

  P   proxy (3-layer, exact nonlinearities), single phase, serial MPC
  PM  + MLP emulation of nonlinearities
  PMT + multi-phase (cheap phase-1 sieve filters 70%)
  Ours + IO scheduling (coalesce latency-bound ops, overlap comm/compute)

Two sections:
  MODELED   paper geometry (DistilBERT, 42K pool, WAN) via the analytic
            cost model — the headline hours.
  EXECUTED  the four (coalesce, overlap) schedule variants RUN through
            the wave executor (core/executor.py) on a CPU-scale pool.
            Each variant's realized flight ledger must agree with the
            iosched.makespan inputs to exact integer equality, all
            variants must produce bitwise-identical scores, and the
            measured per-batch op stream must match the analytic mirror
            (mpc/costs.proxy_exec_cost) record-for-record — that chain
            is what licenses trusting the modeled hours above.

Paper claims IO scheduling buys 1.3-1.4x (PMT -> Ours); MLPs buy orders
of magnitude (P -> PM).
"""
from __future__ import annotations

import numpy as np
import jax

from benchmarks.common import assert_mirror, emit, timed, tiny_exec_setup
from repro.core import executor as executor_mod, iosched
from repro.mpc import costs
from repro.mpc.comm import WAN

POOL, SEQ, BATCH, CLASSES = 42_000, 128, 8, 2

# executed section: CPU-scale geometry (the schedule, not the model size,
# is what's under test)
EXEC_POOL, EXEC_SEQ, EXEC_BATCH, EXEC_WAVE = 48, 8, 8, 4


def _modeled(t) -> dict:
    d, h = 768, 12
    dh = d // h
    serial = iosched.SchedConfig(coalesce=False, overlap=False)
    full = iosched.SchedConfig(coalesce=True, overlap=True)
    nb = -(-POOL // BATCH)
    g3 = costs.BlockGeom(BATCH, SEQ, d, h, dh, 0)
    g1 = costs.BlockGeom(BATCH, SEQ, d, 1, dh, 0)

    # P: proxy with exact softmax/LN (no FFN), single phase
    led_p = costs.merge(
        costs.matmul_cost(1, BATCH * SEQ, d, 3 * h * dh, "qkv"),
        costs.matmul_cost(BATCH * h, SEQ, dh, SEQ, "scores"),
        costs.softmax_cost(BATCH * h * SEQ, SEQ),
        costs.matmul_cost(BATCH * h, SEQ, SEQ, dh, "av"),
        costs.matmul_cost(1, BATCH * SEQ, h * dh, d, "out"),
        costs.layernorm_cost(BATCH * SEQ, d),
    )
    led_p = led_p.scaled(3)
    led_p.records.extend(costs.entropy_cost(BATCH, CLASSES).records)
    t_p = iosched.makespan(led_p, nb, WAN, serial)

    # PM: + MLP emulators
    led_pm = costs.proxy_model_cost(g3, 3, CLASSES, 16)
    t_pm = iosched.makespan(led_pm, nb, WAN, serial)

    # PMT: + multiphase (phase1 tiny proxy over full pool, phase2 30%)
    led_ph1 = costs.proxy_model_cost(g1, 1, CLASSES, 2)
    nb1 = nb
    nb2 = -(-int(0.3 * POOL) // BATCH)
    t_pmt = (iosched.makespan(led_ph1, nb1, WAN, serial)
             + iosched.makespan(led_pm, nb2, WAN, serial))

    # Ours: + IO scheduling
    t_ours = (iosched.makespan(led_ph1, nb1, WAN, full)
              + iosched.makespan(led_pm, nb2, WAN, full))

    for name, val in (("P", t_p), ("PM", t_pm), ("PMT", t_pmt),
                      ("ours", t_ours)):
        emit(f"fig7.{name}", t.us, {"hours": round(val / 3600, 1)})
    iosched_gain = t_pmt / t_ours
    emit("fig7.summary", t.us, {
        "mlp_gain": round(t_p / t_pm, 1),
        "multiphase_gain": round(t_pm / t_pmt, 2),
        "iosched_gain": round(iosched_gain, 2),
        "paper_iosched_gain": "1.3-1.4"})
    assert t_p > t_pm > t_pmt > t_ours
    assert 1.15 < iosched_gain < 2.5, iosched_gain
    return {"iosched_gain": iosched_gain, "mlp_gain": t_p / t_pm}


def _executed(t) -> dict:
    cfg, spec, pp = tiny_exec_setup(7, seq=EXEC_SEQ, n_classes=CLASSES)
    tokens = np.random.default_rng(7).integers(0, cfg.vocab_size,
                                               (EXEC_POOL, EXEC_SEQ))
    # runs all four variants through the REAL executor; raises if any
    # variant's flight ledger diverges from the makespan inputs or any
    # variant changes the scores
    reports = executor_mod.run_variants(jax.random.key(71), pp, cfg,
                                        tokens, spec, batch=EXEC_BATCH,
                                        wave=EXEC_WAVE)
    mk = {}
    for name, rep in reports.items():
        # exact integer agreement: ledger == makespan inputs == analytic
        assert_mirror(rep, cfg, spec, batch=EXEC_BATCH, seq=EXEC_SEQ,
                      n_classes=CLASSES)
        mk[name] = rep.makespan(WAN)
        emit(f"fig7.exec.{name}", t.us, {
            "lat_rounds": rep.ledger.lat_rounds,
            "bw_rounds": rep.ledger.bw_rounds,
            "mbytes": round(rep.ledger.nbytes / 1e6, 2),
            "makespan_wan_s": round(mk[name], 2),
            "wall_s": round(rep.wall_s, 2)})
    # schedule dominance, realized: coalescing strips exactly the
    # latency rounds the model says it strips
    assert mk["serial"] >= mk["+coalesce"] >= mk["ours"]
    assert mk["serial"] >= mk["+overlap"] >= mk["ours"]
    gain = mk["serial"] / mk["ours"]
    emit("fig7.exec.summary", t.us, {
        "exec_iosched_gain": round(gain, 2),
        "ledger_agrees": True})
    return {"exec_iosched_gain": gain}


def run() -> dict:
    with timed() as t:
        out = _modeled(t)
        out.update(_executed(t))
    return out
