"""Shared benchmark plumbing: timing + `name,us_per_call,derived` CSV."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: dict) -> None:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{d}", flush=True)


class timed:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        pass

    @property
    def us(self) -> float:
        return (time.time() - self.t0) * 1e6
