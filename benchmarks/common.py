"""Shared benchmark plumbing: timing + `name,us_per_call,derived` CSV,
plus the tiny executor-calibration harness fig7/table4 both drive."""
from __future__ import annotations

import time


def emit(name: str, us_per_call: float, derived: dict) -> None:
    d = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{d}", flush=True)


class timed:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        pass

    @property
    def us(self) -> float:
        return (time.time() - self.t0) * 1e6


# ---------------------------------------------------------------------------
# tiny executor-calibration harness (shared by fig7 / table4)
# ---------------------------------------------------------------------------

def tiny_exec_setup(seed: int, *, seq: int = 8, n_classes: int = 2):
    """CPU-scale (cfg, spec, pp) for driving the wave executor — the
    schedule, not the model size, is what these benchmarks exercise."""
    import dataclasses

    import jax

    from repro.configs.paper_targets import TINY_TARGET
    from repro.core import proxy as proxy_mod
    from repro.core.proxy import ProxySpec

    cfg = dataclasses.replace(TINY_TARGET, vocab_size=64, n_layers=1,
                              d_model=32, n_heads=2, n_kv_heads=2,
                              d_head=16, d_ff=64)
    spec = ProxySpec(1, 2, 4)
    pp = proxy_mod.random_proxy(jax.random.key(seed), cfg, spec,
                                seq_len=seq, n_classes=n_classes)
    return cfg, spec, pp


def assert_mirror(report, cfg, spec, *, batch: int, seq: int,
                  n_classes: int) -> None:
    """The executed per-batch op stream must equal the analytic mirror
    (mpc/costs.proxy_exec_cost) to exact integer equality, and the phase
    ledger must equal the makespan model's inputs. The mirror is
    parameterized by how the report says the stream was produced
    (ring / protocol backend / fused), so this holds for every
    ExecConfig combination."""
    from repro.mpc import costs

    assert report.agrees()
    pb = report.per_batch
    ana = costs.proxy_exec_cost(batch, seq, cfg.d_model, spec.n_heads,
                                cfg.n_kv_heads, cfg.d_head, spec.mlp_dim,
                                n_classes, spec.n_layers,
                                ring=report.ring, protocol=report.protocol,
                                fused=report.fused)
    assert (pb.rounds, pb.lat_rounds, pb.nbytes, pb.offline_nbytes,
            pb.flops) == \
        (ana.rounds, ana.lat_rounds, ana.nbytes, ana.offline_nbytes,
         ana.flops)
