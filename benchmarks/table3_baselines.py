"""Table 3 + Appendix 7.2: SelectFormer vs MPCFormer vs Bolt.

Accuracy side (CPU scale): MPCFormer = distill the target's logits into
the proxy on the (small, skewed) bootstrap set + 2Quad softmax — the
skew propagates and selection collapses toward the majority class.
Bolt = polynomial softmax approximation (no dimension reduction), better
than MPCFormer but below Ours. Delay side: from the calibrated cost
model (MPCFormer keeps full-dim nonlinearities + FFN + distillation),
PLUS a measured per-nonlinearity section: each baseline softmax is now
an MPCEngine strategy, so TraceEngine probes its real share-level op
stream at paper geometry and iosched prices the modeled MPC delay.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.configs.paper_targets import TINY_TARGET
from repro.core import iosched, proxy as proxy_mod, target as tgt
from repro.core.proxy import ProxySpec
from repro.core.selection import SelectionConfig, run_selection
from repro.data.tasks import make_classification_task
from repro.engine import (ClearEngine, TraceEngine, VARIANTS,
                          abstract_shares, proxy_entropy, proxy_logits)
from repro.mpc import costs
from repro.mpc.comm import WAN
from repro.mpc.ring import RING64

POOL = 500


def _distill_proxy(key, pp, cfg, spec, teacher_params, boot_tokens):
    """MPCFormer-style: match teacher logits on bootstrap (skewed!)."""
    teacher = tgt.classifier_logits(teacher_params, cfg, boot_tokens)
    m = jax.tree.map(jnp.zeros_like, pp)
    v = jax.tree.map(jnp.zeros_like, pp)

    def loss_fn(pp):
        logits = proxy_logits(ClearEngine(), pp, cfg, boot_tokens, spec,
                              frozenset({"quad_sm", "se"}))
        return jnp.mean((logits - teacher) ** 2)

    @jax.jit
    def step(pp, m, v, i):
        loss, g = jax.value_and_grad(loss_fn)(pp)
        m = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
        v = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, v, g)
        mh = jax.tree.map(lambda x: x / (1 - 0.9 ** (i + 1.0)), m)
        vh = jax.tree.map(lambda x: x / (1 - 0.999 ** (i + 1.0)), v)
        pp = jax.tree.map(lambda p, a, b: p - 5e-4 * a / (jnp.sqrt(b) + 1e-8),
                          pp, mh, vh)
        return pp, m, v, loss

    for i in range(80):
        pp, m, v, _ = step(pp, m, v, jnp.float32(i))
    return pp


def run() -> dict:
    task = make_classification_task(7, n_pool=POOL, n_test=300, seq=12,
                                    vocab=256, n_classes=4, imbalance=10.0)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=256, n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4,
                              d_head=16, d_ff=128)
    key = jax.random.key(7)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)
    accs: dict[str, float] = {}

    def finetune_eval(idx):
        p, _ = tgt.finetune(jax.random.fold_in(key, 11), params0, cfg,
                            jnp.asarray(task.pool_tokens[idx]),
                            jnp.asarray(task.pool_labels[idx]), steps=150)
        return tgt.accuracy(p, cfg, jnp.asarray(task.test_tokens),
                            task.test_labels)

    with timed() as t:
        # ----- Ours / Bolt: same pipeline, different softmax op ----------
        for name, variant in (("ours", frozenset({"sm", "ln", "se"})),
                              ("bolt", frozenset({"poly_sm", "se"}))):
            sel = SelectionConfig(phases=[ProxySpec(2, 4, 8, 1.0)],
                                  budget_frac=0.25, boot_frac=0.06,
                                  exvivo_steps=120, invivo_steps=50,
                                  finetune_steps=60, variant=variant,
                                  engine=ClearEngine())
            res = run_selection(key, params0, cfg, task.pool_tokens, sel,
                                n_classes=task.n_classes,
                                boot_labels_fn=lambda i: task.pool_labels[i])
            accs[name] = finetune_eval(res.selected)

        # ----- MPCFormer: distillation on skewed bootstrap ---------------
        rng = np.random.default_rng(7)
        boot_idx = np.sort(rng.choice(POOL, size=30, replace=False))
        boot = jnp.asarray(task.pool_tokens[boot_idx])
        mg, _ = tgt.finetune(jax.random.fold_in(key, 3), params0, cfg,
                             boot, jnp.asarray(task.pool_labels[boot_idx]),
                             steps=100, n_layers=2)
        spec = ProxySpec(2, 4, 8)
        stats = proxy_mod.collect_stats(mg, cfg, boot, spec)
        pp = proxy_mod.build_proxy(jax.random.fold_in(key, 5), mg, cfg,
                                   stats, spec, seq_len=12, n_classes=4,
                                   exvivo_steps=60)
        pp = _distill_proxy(jax.random.fold_in(key, 6), pp, cfg, spec, mg,
                            boot)
        ents = np.asarray(proxy_entropy(
            ClearEngine(), pp, cfg, jnp.asarray(task.pool_tokens), spec,
            frozenset({"quad_sm", "se"})))
        mf_idx = np.argsort(ents)[-int(0.25 * POOL):]
        accs["mpcformer"] = finetune_eval(mf_idx)

    # ----- delays at paper scale (BERT, SST2 42K) -------------------------
    g = costs.BlockGeom(8, 128, 768, 12, 64, 3072)
    serial = iosched.SchedConfig(coalesce=False, overlap=False)
    full = iosched.SchedConfig()
    nb = -(-42_000 // 8)
    mf_led = costs.mpcformer_block_cost(g).scaled(3)
    t_mf = iosched.makespan(mf_led, nb, WAN, serial) / 3600
    ours_led = costs.proxy_model_cost(g, 3, 2, 16)
    t_ours = (iosched.makespan(costs.proxy_model_cost(
        costs.BlockGeom(8, 128, 768, 1, 64, 0), 1, 2, 2), nb, WAN, full)
        + iosched.makespan(ours_led, -(-12_600 // 8), WAN, full)) / 3600

    # ----- measured per-nonlinearity MPC delay (TraceEngine probe) --------
    # Each baseline softmax is an MPCEngine strategy now, so its real
    # share-level op stream is measurable: probe ONE batch abstractly at
    # paper geometry (zero FLOPs, no weights materialized) and price the
    # full pool with the §4.4 schedule.
    nl_hours = _baseline_nonlinearity_delays()
    emit("table3.mpc_delay_per_nonlinearity", t.us,
         {k: round(v, 1) for k, v in nl_hours.items()})

    emit("table3.accuracy", t.us, {
        "ours": round(accs["ours"], 3), "bolt": round(accs["bolt"], 3),
        "mpcformer": round(accs["mpcformer"], 3)})
    emit("table3.delay", t.us, {
        "ours_h": round(t_ours, 1), "mpcformer_h": round(t_mf, 1),
        "speedup": round(t_mf / t_ours, 1), "paper_speedup": "7x"})
    assert accs["ours"] >= accs["mpcformer"] - 0.02, accs
    assert t_mf / t_ours > 3, (t_mf, t_ours)
    # MLP emulation must beat both executable baseline softmaxes
    assert nl_hours["ours_mlp_sm_h"] < nl_hours["mpcformer_2quad_h"]
    assert nl_hours["ours_mlp_sm_h"] < nl_hours["bolt_poly_h"]
    return {"accs": accs, "mf_delay_ratio": t_mf / t_ours,
            "nl_hours": nl_hours}


def _baseline_nonlinearity_delays(n_pool: int = 42_000) -> dict[str, float]:
    """Modeled WAN hours of one selection pass per softmax strategy,
    from TraceEngine probes of the executable op streams (BERT-ish
    geometry: d=768, 12 heads, seq 128, 3-layer proxy)."""
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=30522, d_model=768,
                              n_heads=12, n_kv_heads=12, d_head=64,
                              d_ff=3072, n_layers=3)
    spec = ProxySpec(3, 12, 16)
    batch, seq, classes = 8, 128, 2
    pp_sh = abstract_shares(cfg, spec, seq_len=seq, n_classes=classes)
    nb = -(-n_pool // batch)
    sched = iosched.SchedConfig()
    out = {}
    for name, vname in (("ours_mlp_sm", "full"),
                        ("mpcformer_2quad", "quad_sm"),
                        ("bolt_poly", "poly_sm")):
        per_batch = TraceEngine(RING64).probe(
            pp_sh, cfg, spec, (batch, seq, cfg.d_model),
            variant=VARIANTS[vname])
        out[name + "_h"] = iosched.makespan(per_batch, nb, WAN, sched) / 3600
    return out
