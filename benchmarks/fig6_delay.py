"""Figure 6: end-to-end selection delay, ours vs Oracle, per benchmark.

Pool sizes from the paper (SST2 42K ... YELP 188K; CIFAR 10K/6K), target
geometry DistilBERT/BERT/ViT, 20% budget, paper WAN profile. Delays come
from the calibrated analytic protocol costs scheduled by the paper's IO
scheduler (2-phase: <1 layer, 1 head, d=2> then <3 layers, full, d=16>).

Paper headline reproduced: DistilBERT/SST2 ~20 h vs Oracle ~3740 h
(~200x); our model should land in the same decade.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.core import iosched
from repro.mpc import costs
from repro.mpc.comm import WAN, POD_DCN

BENCHES = [
    # name, pool, target layers, d_model, heads, classes
    ("sst2_distilbert", 42_000, 6, 768, 12, 2),
    ("qnli_distilbert", 58_000, 6, 768, 12, 2),
    ("qqp_distilbert", 149_000, 6, 768, 12, 2),
    ("agnews_distilbert", 40_000, 6, 768, 12, 4),
    ("yelp_distilbert", 188_000, 6, 768, 12, 5),
    ("sst2_bert", 42_000, 12, 768, 12, 2),
    ("cifar10_vit_small", 10_000, 12, 384, 6, 10),
    ("cifar100_vit_base", 6_000, 12, 768, 12, 100),
]

SEQ = 512          # paper geometry: BERT-family default sequence length
BATCH = 4          # paper: max batch on their GPU


def pipeline_delay(n_pool: int, d_model: int, heads: int, classes: int,
                   net, sched) -> float:
    dh = d_model // heads
    keep1 = int(0.3 * n_pool)
    g1 = costs.BlockGeom(BATCH, SEQ, d_model, 1, dh, 0)
    g2 = costs.BlockGeom(BATCH, SEQ, d_model, heads, dh, 0)
    ph1 = costs.proxy_model_cost(g1, 1, classes, 2)
    ph2 = costs.proxy_model_cost(g2, 3, classes, 16)
    t1 = iosched.makespan(ph1, -(-n_pool // BATCH), net, sched)
    t2 = iosched.makespan(ph2, -(-keep1 // BATCH), net, sched)
    return t1 + t2


def oracle_delay(n_pool: int, layers: int, d_model: int, heads: int,
                 classes: int, net) -> float:
    g = costs.BlockGeom(BATCH, SEQ, d_model, heads, d_model // heads,
                        4 * d_model)
    led = costs.exact_model_cost(g, layers, classes)
    serial = iosched.SchedConfig(coalesce=False, overlap=False)
    return iosched.makespan(led, -(-n_pool // BATCH), net, serial)


def run() -> dict:
    sched = iosched.SchedConfig()
    out = {}
    with timed() as t:
        for name, pool, layers, d, h, c in BENCHES:
            ours = pipeline_delay(pool, d, h, c, WAN, sched)
            orc = oracle_delay(pool, layers, d, h, c, WAN)
            dcn = pipeline_delay(pool, d, h, c, POD_DCN, sched)
            out[name] = (ours / 3600, orc / 3600)
            emit(f"fig6.{name}", t.us, {
                "ours_h": round(ours / 3600, 1),
                "oracle_h": round(orc / 3600),
                "speedup": round(orc / ours),
                "pod_dcn_s": round(dcn, 1)})
    sst2 = out["sst2_distilbert"]
    emit("fig6.headline", t.us, {
        "sst2_ours_h": round(sst2[0], 1), "paper_ours_h": 20,
        "sst2_oracle_h": round(sst2[1]), "paper_oracle_h": 3740})
    # same decade as the paper's headline numbers
    assert 5 < sst2[0] < 60, sst2
    assert 1000 < sst2[1] < 12000, sst2
    return {"sst2_ours_h": sst2[0], "sst2_oracle_h": sst2[1]}
