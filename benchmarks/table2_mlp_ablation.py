"""Table 2: MLP-substitution ablation — Ours vs NoAttnSM / NoAttnLN /
NoApprox. The paper finds all variants within ~1-2% of each other (MLP
emulation costs little accuracy) while the comm saving differs hugely;
we assert both sides at CPU scale.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.configs.paper_targets import TINY_TARGET
from repro.core import target as tgt
from repro.core.proxy import ProxySpec
from repro.core.selection import SelectionConfig, run_selection
from repro.data.tasks import make_classification_task
from repro.engine import ClearEngine
from repro.mpc import costs

VARIANTS = {
    "ours": frozenset({"sm", "ln", "se"}),
    "NoAttnSM": frozenset({"ln", "se"}),
    "NoAttnLN": frozenset({"sm", "se"}),
    "NoApprox": frozenset({"se"}),
}


def run() -> dict:
    task = make_classification_task(5, n_pool=500, n_test=300, seq=12,
                                    vocab=256, n_classes=4)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=256, n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4,
                              d_head=16, d_ff=128)
    key = jax.random.key(5)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)
    accs = {}
    with timed() as t:
        for name, variant in VARIANTS.items():
            sel = SelectionConfig(phases=[ProxySpec(2, 4, 8, 1.0)],
                                  budget_frac=0.25, boot_frac=0.06,
                                  exvivo_steps=120, invivo_steps=50,
                                  finetune_steps=60, variant=variant,
                                  engine=ClearEngine())
            res = run_selection(key, params0, cfg, task.pool_tokens, sel,
                                n_classes=task.n_classes,
                                boot_labels_fn=lambda i: task.pool_labels[i])
            p, _ = tgt.finetune(jax.random.fold_in(key, 11), params0, cfg,
                                jnp.asarray(task.pool_tokens[res.selected]),
                                jnp.asarray(task.pool_labels[res.selected]),
                                steps=150)
            accs[name] = tgt.accuracy(p, cfg, jnp.asarray(task.test_tokens),
                                      task.test_labels)
            emit(f"table2.{name}", t.us, {
                "acc": round(accs[name], 3),
                "delta_vs_ours": round(accs[name] - accs["ours"], 3)})
    # accuracy side: every variant within a few points of Ours
    for name, a in accs.items():
        assert abs(a - accs["ours"]) < 0.08, (name, accs)
    # cost side: each MLP's comm saving at the paper's geometry (seq 512,
    # phase-1 hidden dim 2). MLP_sm: 42x reproduces the paper exactly;
    # MLP_ln: our CrypTen-cost model for NR-rsqrt is cheaper than their
    # measured implementation, so the LN saving is smaller here (module
    # ratio ~2x vs paper's 8.25x) — consistent with their observation
    # that LN emulation saves far less than softmax emulation.
    rows, seq = 4 * 12 * 512, 512
    sm_save = costs.softmax_cost(rows, seq).nbytes \
        / costs.mlp_cost(rows, seq, 2, seq).nbytes
    ln_rows = 4 * 512
    ln_save = costs.rsqrt_cost(ln_rows).nbytes \
        / costs.mlp_cost(ln_rows, 1, 2, 1).nbytes
    emit("table2.comm_savings", t.us, {
        "attn_sm_x": round(sm_save, 1), "attn_ln_x": round(ln_save, 1),
        "paper": "42x / 8.25x"})
    assert 30 < sm_save < 60
    return accs
