"""Figure 2: per-op MPC cost of one transformer block forward.

Paper setup: one layer, 12 heads, batch 5 (seq 128), CrypTen over WAN
(100 MB/s, 100 ms). Reports rounds / bytes / simulated time per op class
and asserts the paper's headline: softmax dominates communication.
"""
from __future__ import annotations

from benchmarks.common import emit, timed
from repro.mpc import costs
from repro.mpc.comm import WAN


def run() -> dict:
    g = costs.BlockGeom(batch=5, seq=128, d_model=768, heads=12,
                        d_head=64, d_ff=3072)
    with timed() as t:
        led = costs.exact_block_cost(g)
    groups: dict[str, dict] = {}
    for k, r in led.by_op().items():
        top = k.split(".")[0] + "." + (k.split(".")[1] if "." in k else "")
        grp = ("softmax" if "softmax" in k else
               "layernorm" if ".ln" in k or "layernorm" in k else
               "gelu" if "gelu" in k else
               "matmul" if any(s in k for s in
                               ("qkv", "scores", "av", "out", "fc")) else k)
        d = groups.setdefault(grp, {"rounds": 0, "mbytes": 0.0})
        d["rounds"] += r.rounds
        d["mbytes"] += r.nbytes / 1e6
    total_b = sum(d["mbytes"] for d in groups.values())
    total_r = sum(d["rounds"] for d in groups.values())
    sm_frac = groups["softmax"]["mbytes"] / total_b
    for grp, d in sorted(groups.items(), key=lambda kv: -kv[1]["mbytes"]):
        emit(f"fig2.{grp}", t.us, {
            "rounds": d["rounds"], "MB": round(d["mbytes"], 1),
            "wan_s": round(WAN.time(d["rounds"], d["mbytes"] * 1e6), 1)})
    emit("fig2.total", t.us, {
        "rounds": total_r, "MB": round(total_b, 1),
        "softmax_byte_frac": round(sm_frac, 3),
        "paper_claim": 0.819})
    assert sm_frac > 0.5, "softmax must dominate communication (Fig 2)"
    return {"softmax_frac": sm_frac, "rounds": total_r}
