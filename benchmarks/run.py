# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: every paper table/figure + the roofline report.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run fig2 fig6  # subset
"""
from __future__ import annotations

import sys
import traceback

SUITES = ["fig2", "fig5", "fig6", "fig7", "table1", "table2", "table3",
          "table4", "roofline", "fusion"]


def main() -> None:
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    chosen = args or SUITES
    print("name,us_per_call,derived")
    failures = []
    for name in chosen:
        mod_name = {
            "fig2": "benchmarks.fig2_op_costs",
            "fig5": "benchmarks.fig5_budget_sweep",
            "fig6": "benchmarks.fig6_delay",
            "fig7": "benchmarks.fig7_ablation",
            "table1": "benchmarks.table1_efficacy",
            "table2": "benchmarks.table2_mlp_ablation",
            "table3": "benchmarks.table3_baselines",
            "table4": "benchmarks.table4_multiphase",
            "roofline": "benchmarks.roofline",
            "fusion": "benchmarks.bench_fusion",
        }[name]
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run()
        except Exception as e:                           # noqa: BLE001
            failures.append((name, e))
            print(f"{name}.FAILED,0,error={type(e).__name__}: {e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
