"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

  compute    = HLO_FLOPs_per_device / peak_FLOPs        (197 TF/s bf16)
  memory     = HLO_bytes_per_device / HBM_bw            (819 GB/s)
  collective = wire_bytes_per_device / link_bw          (50 GB/s ICI)

cost_analysis() of the SPMD-partitioned module is per-device, so the
brief's global formulas reduce to the per-device forms above (global =
per-device x chips on both numerator and denominator).

MODEL_FLOPS: 6*N_active*D (train), 2*N_active*D (prefill),
2*N_active*B (decode step). The MODEL/HLO ratio flags remat/redundancy
waste (train remat recompute, causal-chunk overcount, MoE padding).

Writes experiments/roofline.md (the EXPERIMENTS.md table) + CSV lines.
"""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit, timed

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "dryrun")
OUT_MD = os.path.join(os.path.dirname(__file__), "..", "experiments",
                      "roofline.md")


def model_flops(cell: dict) -> float:
    """Ideal model FLOPs: 6*N_active*D train / 2*N_active*D prefill /
    2*N_active*B decode, + the attention S^2 term from the dry-run."""
    n = cell["active_params"]
    sh = cell["shape"]
    attn = cell.get("attn_model_flops", 0.0)
    if sh["kind"] == "train":
        return 6.0 * n * sh["global_batch"] * sh["seq_len"] + attn
    if sh["kind"] == "prefill":
        return 2.0 * n * sh["global_batch"] * sh["seq_len"] + attn
    return 2.0 * n * sh["global_batch"] + attn   # decode: one token


def memory_bytes(cell: dict) -> float:
    """Per-device HBM traffic estimate.

    XLA-CPU `bytes accessed` counts fusion-internal traffic and is not
    HBM-representative; instead: measured buffer streams from
    memory_analysis (arguments read + outputs written — params, optimizer
    state, KV caches) plus analytic activation traffic of
    KAPPA x d_model x n_layers x tokens_per_device x 2B (KAPPA ~= tensors
    touched per token-layer; 16 fwd-only, 24 with bwd + remat). Decode
    streams buffers only (1-token activations are noise).
    """
    mem = cell["memory"]
    base = (mem["argument_bytes"] or 0) + (mem["output_bytes"] or 0)
    sh = cell["shape"]
    if sh["kind"] == "decode":
        return base
    from repro.configs.base import load_arch
    nb = 32 if "2x16x16" in cell["mesh"] else 16
    b, s = sh["global_batch"], sh["seq_len"]
    tokens_dev = b * s / nb if b % nb == 0 else b * s
    cfg = load_arch(cell["arch"])
    kappa = 24.0 if sh["kind"] == "train" else 16.0
    act = kappa * cfg.d_model * cfg.n_layers * tokens_dev * 2.0
    return base + act


def analyze(cell: dict) -> dict:
    n_dev = cell["n_devices"]
    hlo_global = cell["cost"].get("flops_global") or \
        (cell["cost"]["flops"] or 0.0) * n_dev
    fl_dev = hlo_global / n_dev
    by = memory_bytes(cell)
    wire = cell["collectives"].get("wire_bytes_per_device_scaled",
                                   cell["collectives"]["wire_bytes_per_device"])
    t_c = fl_dev / PEAK_FLOPS
    t_m = by / HBM_BW
    t_x = wire / LINK_BW
    dom = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
    mf = model_flops(cell)
    return {
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_x,
        "dominant": dom,
        "model_flops": mf, "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_fraction": max(t_c, 1e-30)
        / max(t_c, t_m, t_x, 1e-30),
        "peak_gb": (cell["memory"]["peak_bytes"] or 0) / 2 ** 30,
    }


SUGGEST = {
    "compute": "reduce recompute (remat policy) / causal-block skipping",
    "memory": "fuse elementwise chains; widen arithmetic intensity via "
              "larger per-device batch or weight-stationary blocking",
    "collective": "re-shard to cut all-gathers (FSDP axis choice), overlap "
                  "collectives with compute, or compress the reduced grads",
}


def run() -> dict:
    cells = {}
    for p in sorted(glob.glob(os.path.join(DRYRUN, "*.json"))):
        with open(p) as f:
            c = json.load(f)
        if c.get("applicable") and "error" not in c:
            cells[c["cell"]] = c
    lines = ["| cell | compute s | memory s | collective s | dominant | "
             "MODEL/HLO | peak GB | note |",
             "|---|---|---|---|---|---|---|---|"]
    worst, most_coll = None, None
    out = {}
    with timed() as t:
        for name, c in cells.items():
            a = analyze(c)
            out[name] = a
            emit(f"roofline.{name}", t.us, {
                "compute_s": f"{a['compute_s']:.3e}",
                "memory_s": f"{a['memory_s']:.3e}",
                "collective_s": f"{a['collective_s']:.3e}",
                "dominant": a["dominant"],
                "useful_ratio": round(a["useful_ratio"], 3)})
            lines.append(
                f"| {name} | {a['compute_s']:.3e} | {a['memory_s']:.3e} | "
                f"{a['collective_s']:.3e} | {a['dominant']} | "
                f"{a['useful_ratio']:.2f} | {a['peak_gb']:.1f} | "
                f"{SUGGEST[a['dominant']]} |")
            if name.count("__") != 2:
                continue            # hillclimb variants: rows only, not picks
            frac = a["roofline_fraction"]
            if worst is None or frac < worst[1]:
                worst = (name, frac)
            cshare = a["collective_s"] / max(
                a["compute_s"] + a["memory_s"] + a["collective_s"], 1e-30)
            if most_coll is None or cshare > most_coll[1]:
                most_coll = (name, cshare)
    os.makedirs(os.path.dirname(OUT_MD), exist_ok=True)
    with open(OUT_MD, "w") as f:
        f.write("\n".join(lines) + "\n")
    emit("roofline.summary", t.us, {
        "cells": len(cells),
        "worst_fraction_cell": worst[0] if worst else "-",
        "worst_fraction": round(worst[1], 4) if worst else "-",
        "most_collective_cell": most_coll[0] if most_coll else "-",
    })
    return out
