#!/usr/bin/env python
"""Appraisal-service benchmark — sustained appraisals/hour vs sequential.

Enqueues N appraisal sessions (tiny target + synthetic classification
task, the Stage-2 smoke geometry) into `repro.serve.AppraisalServer`
and compares its modeled service makespan at a fixed WAN profile
against the N-sequential baseline: the same phases priced as
back-to-back `run_selection` calls (no cross-session overlap, every
phase executed, each phase paying its own pipeline-fill). The service
wins on two axes — fingerprint-identical phases are served from the
cross-session cache (request coalescing makes a concurrently-executing
twin wait rather than duplicate), and executed phases from different
sessions overlap comm against compute in the §4.4 stream model.

Every session is replayed standalone through `run_selection` and its
raw per-phase score shares (`SelectionResult.phase_scores`) compared
bitwise — the scheduler moves flights, never values.

`--smoke` enforces the acceptance gates (the CI smoke-serve job):
  * serve appraisals/hour STRICTLY above the N-sequential baseline
  * dealer_stall_s == 0 (offline material fully pipelined behind the
    sessions' clear-side work)
  * cross-session cache hits > 0 on the repeated session
  * every session's score shares bitwise identical to standalone
  * every per-session ledger satisfies iosched.ledger_agrees

Emits `BENCH_serve.json` — the service-throughput trajectory baseline.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)

import numpy as np  # noqa: E402


def build_spec(sid: str, task_seed: int, *, n_pool: int, protocol: str,
               ring, wave: int):
    """One synthetic appraisal session + the context to replay it
    standalone (the parity witness)."""
    import jax

    from repro.configs.paper_targets import TINY_TARGET
    from repro.core import target as tgt
    from repro.core.executor import ExecConfig
    from repro.core.proxy import ProxySpec
    from repro.core.selection import SelectionConfig
    from repro.data.tasks import make_classification_task
    from repro.engine import MPCEngine
    from repro.serve import SessionSpec

    task = make_classification_task(task_seed, n_pool=n_pool, n_test=32,
                                    seq=8, vocab=64, n_classes=2)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=task.vocab)
    key = jax.random.key(task_seed)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)
    sel = SelectionConfig(
        phases=[ProxySpec(1, 1, 2, 0.5), ProxySpec(1, 2, 4, 1.0)],
        budget_frac=0.25, boot_frac=0.1,
        engine=MPCEngine(ring=ring, protocol=protocol),
        exvivo_steps=4, invivo_steps=2, finetune_steps=2,
        score_batch=16, checkpoint_dir=None,
        executor=ExecConfig(wave=wave, ring=ring, protocol=protocol))
    spec = SessionSpec(sid=sid, key=key, target_params=params0,
                       arch_cfg=cfg, pool_tokens=task.pool_tokens,
                       sel=sel, n_classes=task.n_classes,
                       boot_labels_fn=lambda i: task.pool_labels[i])
    ctx = dict(key=key, params0=params0, cfg=cfg, task=task, sel=sel,
               seed=task_seed)
    return spec, ctx


def run_bench(*, n_sessions: int, n_pool: int, protocol: str,
              ring_bits: int, net: str, seed: int, wave: int) -> dict:
    from repro.core.selection import run_selection
    from repro.mpc.ring import RING32, RING64
    from repro.serve import AppraisalServer

    ring = RING32 if ring_bits == 32 else RING64

    # session 1 duplicates session 0's seed: the cross-session cache /
    # request-coalescing target (hits > 0 is a smoke gate)
    seeds = [seed if i == 1 and n_sessions > 1 else seed + i
             for i in range(n_sessions)]
    srv = AppraisalServer(dealer_seed=seed)
    sessions, ctxs = [], []
    for i, s in enumerate(seeds):
        spec, ctx = build_spec(f"s{i}", s, n_pool=n_pool,
                               protocol=protocol, ring=ring, wave=wave)
        sessions.append(srv.submit(spec))
        ctxs.append(ctx)
    t0 = time.time()
    rep = srv.run()
    serve_wall_s = time.time() - t0
    srv.close()

    # ---- N-sequential baseline + bitwise parity -------------------------
    # one standalone run_selection per UNIQUE seed; every session (cached
    # or executed) must match its seed's standalone scores bit for bit
    standalone: dict[int, object] = {}
    seq_wall_s = 0.0
    for ctx in ctxs:
        if ctx["seed"] in standalone:
            continue
        task, sel = ctx["task"], ctx["sel"]
        t0 = time.time()
        standalone[ctx["seed"]] = run_selection(
            ctx["key"], ctx["params0"], ctx["cfg"], task.pool_tokens,
            dataclasses.replace(sel), n_classes=task.n_classes,
            boot_labels_fn=lambda i: task.pool_labels[i])
        seq_wall_s += time.time() - t0
    parity = {}
    for sess, ctx in zip(sessions, ctxs):
        std = standalone[ctx["seed"]]
        parity[sess.sid] = bool(
            len(sess.result.phase_scores) == len(std.phase_scores)
            and all(np.array_equal(a, b) for a, b in
                    zip(sess.result.phase_scores, std.phase_scores))
            and sess.result.appraisal_entropy == std.appraisal_entropy
            and np.array_equal(sess.result.selected, std.selected))

    t = rep["throughput"]
    return {
        "config": {"n_sessions": n_sessions, "n_pool": n_pool,
                   "protocol": protocol, "ring": ring.name, "net": net,
                   "wave": wave, "seed": seed, "session_seeds": seeds},
        "throughput": t,
        "cache": rep["cache"],
        "dealer": rep["dealer"],
        "probe_cache": rep["probe_cache"],
        "ledger_agrees": rep["ledger_agrees"],
        "parity": parity,
        "wall": {"serve_s": serve_wall_s,
                 "sequential_unique_s": seq_wall_s},
        "sessions": rep["sessions"],
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny queue + acceptance gates (CI smoke-serve)")
    ap.add_argument("--sessions", type=int, default=3,
                    help="appraisal sessions to enqueue (session 1 "
                         "repeats session 0's seed)")
    ap.add_argument("--pool", type=int, default=96,
                    help="candidate pool size per session")
    ap.add_argument("--protocol",
                    choices=["2pc", "3pc", "spdz2pc", "aby3trunc"],
                    default="2pc", help="secret-sharing backend")
    ap.add_argument("--ring", type=int, choices=[64, 32], default=64,
                    help="MPC ring width")
    ap.add_argument("--net", default="wan",
                    help="NetProfile for the makespan model")
    ap.add_argument("--wave", type=int, default=2,
                    help="vmap lanes per flight")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serve.json")
    args = ap.parse_args(argv)

    result = run_bench(n_sessions=args.sessions, n_pool=args.pool,
                       protocol=args.protocol, ring_bits=args.ring,
                       net=args.net, seed=args.seed, wave=args.wave)
    t = result["throughput"]

    if args.smoke:
        gates = {
            "throughput_above_sequential":
                t["serve_appraisals_per_hour"]
                > t["sequential_appraisals_per_hour"],
            "dealer_stall_zero":
                result["dealer"]["dealer_stall_s"] == 0.0,
            "cache_hits_positive": result["cache"]["hits"] > 0,
            "bitwise_parity": all(result["parity"].values()),
            "ledger_agrees": bool(result["ledger_agrees"]),
        }
        result["gates"] = gates
        for name, ok in gates.items():
            print(f"  gate {name}: {'PASS' if ok else 'FAIL'}")
        if not all(gates.values()):
            with open(args.out, "w") as f:
                json.dump(result, f, indent=1, default=float)
            print(f"wrote {args.out} (FAILED)")
            return 1

    with open(args.out, "w") as f:
        json.dump(result, f, indent=1, default=float)
    print(f"[bench_serve] {t['n_sessions']} sessions "
          f"({t['n_phases_executed']}/{t['n_phases_total']} phases "
          f"executed): {t['serve_appraisals_per_hour']:.2f}/h served vs "
          f"{t['sequential_appraisals_per_hour']:.2f}/h sequential "
          f"({t['speedup']:.2f}x) at {result['config']['net']}; "
          f"cache {result['cache']['hits']} hits "
          f"(+{result['cache']['coalesced_waits']} coalesced waits); "
          f"dealer stall {result['dealer']['dealer_stall_s']:.3f}s; "
          f"parity {all(result['parity'].values())}")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
