"""Figure 5 / Tables 6-7: accuracy vs purchase budget, Ours vs Random.

Paper claim: at a 20-25% budget, Ours matches what Random needs 70-100%
of the pool to reach. CPU-scale instantiation: sweep budgets, compare the
budget Random needs to match Ours@25%.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timed
from repro.configs.paper_targets import TINY_TARGET
from repro.core import target as tgt
from repro.core.proxy import ProxySpec
from repro.core.selection import SelectionConfig, run_selection
from repro.data.tasks import make_classification_task

POOL = 500
BUDGETS = (0.15, 0.25, 0.4)
RANDOM_BUDGETS = (0.15, 0.25, 0.4, 0.7, 1.0)


def run() -> dict:
    task = make_classification_task(1, n_pool=POOL, n_test=300, seq=12,
                                    vocab=256, n_classes=4)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=256, n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4,
                              d_head=16, d_ff=128)
    key = jax.random.key(1)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)
    rng = np.random.default_rng(1)

    def finetune_eval(idx):
        p, _ = tgt.finetune(jax.random.fold_in(key, 13), params0, cfg,
                            jnp.asarray(task.pool_tokens[idx]),
                            jnp.asarray(task.pool_labels[idx]), steps=150)
        return tgt.accuracy(p, cfg, jnp.asarray(task.test_tokens),
                            task.test_labels)

    ours, rand = {}, {}
    with timed() as t:
        for b in BUDGETS:
            sel = SelectionConfig(phases=[ProxySpec(1, 2, 2, 0.6),
                                          ProxySpec(2, 4, 8, 1.0)],
                                  budget_frac=b, boot_frac=0.06,
                                  exvivo_steps=150, invivo_steps=100,
                                  finetune_steps=60)
            res = run_selection(key, params0, cfg, task.pool_tokens, sel,
                                n_classes=task.n_classes,
                                boot_labels_fn=lambda i: task.pool_labels[i])
            ours[b] = finetune_eval(res.selected)
        for b in RANDOM_BUDGETS:
            idx = rng.choice(POOL, size=int(b * POOL), replace=False)
            rand[b] = finetune_eval(idx)
        for b in BUDGETS:
            emit(f"fig5.budget_{int(b * 100)}", t.us, {
                "ours": round(ours[b], 3),
                "random": round(rand[b], 3),
                "gain": round(ours[b] - rand[b], 3)})
        # budget Random needs to match Ours@25%
        target = ours[0.25] - 0.005
        need = next((b for b in RANDOM_BUDGETS if rand[b] >= target), 1.0)
        emit("fig5.headline", t.us, {
            "ours_at_25": round(ours[0.25], 3),
            "random_needs_budget": need,
            "paper": "random needs 70-100% to match ours@20%"})
    assert ours[0.25] >= rand[0.25] - 0.01
    return {"ours": ours, "random": rand, "random_needs": need}
