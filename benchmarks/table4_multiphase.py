"""Table 4/5: phase-schedule sweep — 1/2/3-phase accuracy + delay.

CPU-scale accuracy for schedules 16 / (2,16) / (2,8,16) (paper's main
rows) + the modeled delay of each at paper scale. Paper: multi-phase
cuts delay 33-61% and holds or improves accuracy.

The paper-scale delays are analytic, but the pricing is calibrated: a
CPU-scale phase is RUN through the wave executor first and its measured
per-batch op stream must equal the analytic mirror exactly — the same
formulas then evaluate the paper geometry.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import assert_mirror, emit, timed, tiny_exec_setup
from repro.configs.paper_targets import TINY_TARGET
from repro.core import executor as executor_mod, iosched, target as tgt
from repro.core.proxy import ProxySpec
from repro.core.selection import SelectionConfig, run_selection
from repro.data.tasks import make_classification_task
from repro.mpc import costs
from repro.mpc.comm import WAN

SCHEDULES = {
    "1phase_d16": [ProxySpec(2, 4, 8, 1.0)],
    "2phase_d2_16": [ProxySpec(1, 2, 2, 0.6), ProxySpec(2, 4, 8, 1.0)],
    "3phase_d2_8_16": [ProxySpec(1, 2, 2, 0.7), ProxySpec(1, 4, 4, 0.6),
                       ProxySpec(2, 4, 8, 1.0)],
}


def modeled_delay(phases: list[ProxySpec], n_pool: int = 42_000) -> float:
    d, h, dh = 768, 12, 64
    sched = iosched.SchedConfig()
    remaining = n_pool
    total = 0.0
    budget = int(0.2 * n_pool)
    for i, ph in enumerate(phases):
        g = costs.BlockGeom(8, 128, d, min(ph.n_heads * 3, h), dh, 0)
        led = costs.proxy_model_cost(g, ph.n_layers, 2,
                                     {2: 2, 4: 8, 8: 16}.get(ph.mlp_dim,
                                                             ph.mlp_dim))
        total += iosched.makespan(led, -(-remaining // 8), WAN, sched)
        remaining = max(budget, int(remaining * ph.selectivity)) \
            if i < len(phases) - 1 else budget
    return total / 3600


def _exec_calibration(t) -> None:
    """Run one CPU-scale phase through the executor; its measured stream
    must match the analytic cost formulas to exact integer equality."""
    cfg, spec, pp = tiny_exec_setup(4)
    tokens = np.random.default_rng(4).integers(0, cfg.vocab_size, (32, 8))
    ex = executor_mod.WaveExecutor(executor_mod.ExecConfig(wave=4, batch=8))
    ex.score_phase(jax.random.key(41), pp, cfg, tokens, spec)
    rep = ex.reports[-1]
    assert_mirror(rep, cfg, spec, batch=8, seq=8, n_classes=2)
    emit("table4.exec_calibration", t.us,
         {"ledger_agrees": True, "rounds": rep.per_batch.rounds,
          "nbytes": rep.per_batch.nbytes})


def run() -> dict:
    task = make_classification_task(9, n_pool=500, n_test=300, seq=12,
                                    vocab=256, n_classes=4)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=256, n_layers=2,
                              d_model=64, n_heads=4, n_kv_heads=4,
                              d_head=16, d_ff=128)
    key = jax.random.key(9)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)
    out = {}
    with timed() as t:
        _exec_calibration(t)
        for name, phases in SCHEDULES.items():
            sel = SelectionConfig(phases=phases, budget_frac=0.25,
                                  boot_frac=0.06, exvivo_steps=150,
                                  invivo_steps=100, finetune_steps=60)
            res = run_selection(key, params0, cfg, task.pool_tokens, sel,
                                n_classes=task.n_classes,
                                boot_labels_fn=lambda i: task.pool_labels[i])
            p, _ = tgt.finetune(jax.random.fold_in(key, 11), params0, cfg,
                                jnp.asarray(task.pool_tokens[res.selected]),
                                jnp.asarray(task.pool_labels[res.selected]),
                                steps=150)
            acc = tgt.accuracy(p, cfg, jnp.asarray(task.test_tokens),
                               task.test_labels)
            delay = modeled_delay(phases)
            out[name] = (acc, delay)
            emit(f"table4.{name}", t.us, {"acc": round(acc, 3),
                                          "modeled_delay_h": round(delay, 1)})
    acc1, d1 = out["1phase_d16"]
    acc2, d2 = out["2phase_d2_16"]
    emit("table4.summary", t.us, {
        "delay_cut_2phase": round(1 - d2 / d1, 2),
        "paper_delay_cut": "0.33-0.61",
        "acc_delta_2phase": round(acc2 - acc1, 3)})
    assert d2 < d1, "multi-phase must cut delay"
    # paper Table 4 itself shows multi-phase accuracy swings of ~±1% at
    # their scale and up to -0.91 on DistilBERT/SST2; at CPU scale the
    # tiny phase-1 proxy is noisier — the BEST multi-phase schedule must
    # hold accuracy while cutting delay
    best_multi = max(out["2phase_d2_16"][0], out["3phase_d2_8_16"][0])
    assert best_multi > acc1 - 0.06, out
    return {k: v[0] for k, v in out.items()}
