"""Quickstart: train a smoke-scale model end to end with the full stack
(data pipeline, sharded train step, checkpoint/restart).

    PYTHONPATH=src python examples/quickstart.py [--arch mamba2_2_7b]
"""
import argparse
import shutil
import sys

sys.path.insert(0, "src")

from repro.launch.train import TrainConfig, train  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--steps", type=int, default=40)
    args = ap.parse_args()
    ckpt = "/tmp/repro_quickstart_ckpt"
    shutil.rmtree(ckpt, ignore_errors=True)
    out = train(TrainConfig(arch=args.arch, smoke=True, steps=args.steps,
                            batch=8, seq=64, ckpt_dir=ckpt, ckpt_every=20))
    print(f"[quickstart] loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}")
    assert out["final_loss"] < out["first_loss"], "loss must decrease"
    # restart from the checkpoint to prove resume works
    out2 = train(TrainConfig(arch=args.arch, smoke=True, steps=args.steps + 10,
                             batch=8, seq=64, ckpt_dir=ckpt, ckpt_every=20))
    assert out2["resumed_from"] > 0, "must resume from checkpoint"
    print(f"[quickstart] resumed at {out2['resumed_from']}, "
          f"final loss {out2['final_loss']:.3f}")


if __name__ == "__main__":
    main()
