"""Multi-phase vs single-phase selection (paper Table 4 protocol at CPU
scale): same final proxy, with/without the phase-1 cheap sieve, plus the
modeled delay difference.

    PYTHONPATH=src python examples/multiphase_ablation.py
"""
import sys

sys.path.insert(0, "src")

import dataclasses  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.paper_targets import TINY_TARGET  # noqa: E402
from repro.core import target as tgt  # noqa: E402
from repro.core.proxy import ProxySpec  # noqa: E402
from repro.core.selection import SelectionConfig, run_selection  # noqa: E402
from repro.data.tasks import make_classification_task  # noqa: E402
from repro.launch.select import paper_scale_delay  # noqa: E402


def main() -> None:
    task = make_classification_task(1, n_pool=600, n_test=300, seq=16,
                                    vocab=256, n_classes=4)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=task.vocab)
    key = jax.random.key(1)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)

    def run_with(phases, tag):
        sel = SelectionConfig(phases=phases, budget_frac=0.25,
                              boot_frac=0.05, exvivo_steps=120,
                              invivo_steps=60, finetune_steps=80)
        res = run_selection(key, params0, cfg, task.pool_tokens, sel,
                            n_classes=task.n_classes,
                            boot_labels_fn=lambda i: task.pool_labels[i])
        import jax.numpy as jnp
        p, _ = tgt.finetune(jax.random.fold_in(key, 9), params0, cfg,
                            jnp.asarray(task.pool_tokens[res.selected]),
                            jnp.asarray(task.pool_labels[res.selected]),
                            steps=150)
        acc = tgt.accuracy(p, cfg, jnp.asarray(task.test_tokens),
                           task.test_labels)
        print(f"[{tag}] acc={acc:.3f} selected={len(res.selected)}")
        return acc

    acc_sps = run_with([ProxySpec(2, 4, 8, 1.0)], "single-phase")
    acc_mps = run_with([ProxySpec(1, 2, 2, 0.4), ProxySpec(2, 4, 8, 1.0)],
                       "multi-phase")
    print(f"[ablation] multi-phase {acc_mps:.3f} vs single {acc_sps:.3f}")
    d = paper_scale_delay(42_000, 0.2)
    print(f"[ablation] modeled WAN delay ours "
          f"{d['wan']['ours_hours']:.1f}h (multi-phase pipeline)")


if __name__ == "__main__":
    main()
