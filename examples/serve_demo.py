"""Batched serving demo: continuous-batching server over a smoke model.

    PYTHONPATH=src python examples/serve_demo.py [--arch mamba2_2_7b]
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.launch.serve import ServeConfig, Server, Request  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--requests", type=int, default=6)
    args = ap.parse_args()
    srv = Server(ServeConfig(arch=args.arch, slots=3, max_new=8))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size,
                                    size=int(rng.integers(4, 12))))
            for i in range(args.requests)]
    out = srv.run(reqs)
    print(f"[serve] {out['requests']} requests -> {out['tokens']} tokens "
          f"@ {out['tok_per_s']:.1f} tok/s")
    assert out["requests"] == args.requests
    assert all(len(v) == 8 for v in out["outputs"].values())


if __name__ == "__main__":
    main()
