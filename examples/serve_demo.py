"""Batched serving demo: continuous-batching server over a smoke model.

    PYTHONPATH=src python examples/serve_demo.py [--arch mamba2_2_7b]

`--appraise` demos the other serving mode — the appraisal service: two
queued private-selection sessions (the second a duplicate of the first)
interleaved through repro.serve.AppraisalServer, with the duplicate's
phases served from the cross-session cache.

    PYTHONPATH=src python examples/serve_demo.py --appraise
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np  # noqa: E402

from repro.launch.serve import ServeConfig, Server, Request  # noqa: E402

# the SERVE/SELECT shared per-phase report shape (PhaseReport.as_dict)
PHASE_KEYS = {"n_batches", "n_waves", "protocol", "lat_rounds",
              "bw_rounds", "nbytes", "offline_nbytes", "makespan_wan_s",
              "wall_s", "device_makespan_s", "device", "wire"}


def appraise_demo() -> None:
    from repro.launch.serve import appraise

    rep = appraise(n_sessions=2, n_pool=48, out_path=None)
    t = rep["throughput"]
    print(f"[serve] 2 appraisals: {t['serve_appraisals_per_hour']:.1f}/h "
          f"served vs {t['sequential_appraisals_per_hour']:.1f}/h "
          f"sequential ({t['speedup']:.2f}x); "
          f"cache hits={rep['cache']['hits']}")
    # pinned output shape: per-phase dicts are exactly the SELECT shape,
    # the duplicate session was served from cache, ledgers reconcile
    assert len(rep["sessions"]) == 2
    for sess in rep["sessions"]:
        assert sess["ledger_agrees"] and sess["n_selected"] > 0
        for ph in sess["phases"]:
            assert set(ph) == PHASE_KEYS, sorted(set(ph) ^ PHASE_KEYS)
    assert rep["cache"]["hits"] + rep["cache"]["coalesced_waits"] > 0
    assert rep["ledger_agrees"] is True
    assert rep["dealer"]["dealer_stall_s"] == 0.0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--appraise", action="store_true",
                    help="demo the appraisal service instead of token "
                         "decoding")
    args = ap.parse_args()
    if args.appraise:
        appraise_demo()
        return
    srv = Server(ServeConfig(arch=args.arch, slots=3, max_new=8))
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size,
                                    size=int(rng.integers(4, 12))))
            for i in range(args.requests)]
    out = srv.run(reqs)
    print(f"[serve] {out['requests']} requests -> {out['tokens']} tokens "
          f"@ {out['tok_per_s']:.1f} tok/s")
    assert out["requests"] == args.requests
    assert all(len(v) == 8 for v in out["outputs"].values())


if __name__ == "__main__":
    main()
