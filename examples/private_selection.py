"""End-to-end SelectFormer workflow (the paper's pipeline): bootstrap ->
proxy generation (ex-vivo + in-vivo MLP training) -> multi-phase private
selection -> finetune on purchased data -> accuracy vs Random, plus the
paper-scale delay model (ours vs Oracle over MPC).

    PYTHONPATH=src python examples/private_selection.py [--mode mpc]

mode=mpc runs the share-level protocol (slower; proves the real MPC path
end to end). mode=clear runs the float path with identical control flow.
"""
import argparse
import sys

sys.path.insert(0, "src")


from repro.launch.select import run  # noqa: E402
from repro.mpc.ring import x64_scope  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", choices=["clear", "mpc"], default="clear")
    ap.add_argument("--pool", type=int, default=600)
    args = ap.parse_args()
    if args.mode == "mpc":
        with x64_scope():
            out = run(0, args.pool, 0.2, "mpc", finetune_steps=150)
    else:
        out = run(0, args.pool, 0.2, "clear", finetune_steps=150)
    print(f"[selection] ours={out['acc_ours']:.3f} "
          f"random={out['acc_random']:.3f} (+{out['gain']:.3f})")
    d = out["paper_scale_delay"]
    print(f"[selection] modeled delay @42K pool (paper WAN): "
          f"ours {d['wan']['ours_hours']:.1f}h vs oracle "
          f"{d['wan']['oracle_hours']:.0f}h -> {d['wan']['speedup']:.0f}x")
    print(f"[selection] same pipeline on 2-pod DCN: "
          f"{d['pod_dcn']['ours_hours'] * 3600:.1f}s "
          f"({d['pod_dcn']['speedup']:.0f}x vs oracle)")
    assert out["acc_ours"] >= out["acc_random"] - 0.02, \
        "selection should not be worse than random"


if __name__ == "__main__":
    main()
