"""qwen2.5-32b [dense] — GQA, QKV bias [hf:Qwen/Qwen2.5-32B; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6, norm_type="rmsnorm", act="swiglu",
)

SMOKE = ArchConfig(
    name="qwen2.5-32b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=192, vocab_size=256,
    qkv_bias=True, rope_theta=1e6, norm_type="rmsnorm", act="swiglu",
)
