"""Architecture config schema + shape registry.

Every assigned architecture is an ArchConfig instance in its own module
(src/repro/configs/<id>.py) exposing CONFIG (full) and SMOKE (reduced,
same family) — selected via --arch <id> in the launchers.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "hybrid", "ssm", "encdec", "vlm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rope_fraction: float = 1.0          # glm4 applies RoPE to half the dims
    norm_type: str = "rmsnorm"
    act: str = "swiglu"
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    d_expert: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    moe_groups: int = 1        # routing groups; launcher sets = #data shards
    # hybrid (recurrentgemma): repeating block pattern
    block_pattern: tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    window_size: int = 0                 # local attention window
    lru_width: int = 0
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    conv_width: int = 4
    # enc-dec
    n_enc_layers: int = 0
    n_cross_kv: int = 1500               # whisper encoder frames at decode
    # vlm
    n_prefix_tokens: int = 0             # image patch embeddings prepended
    # modality frontends are stubs: input_specs() provides embeddings
    frontend_stub: bool = False
    dtype: str = "bfloat16"
    # scan unrolling (1 = rolled while-loop; dryrun's cost pass sets it to
    # n_layers so HLO cost analysis counts every layer)
    scan_unroll: int = 1
    # cast f32 master params to bf16 BEFORE the layer scan so FSDP
    # all-gathers move bf16, not f32 (2x weight-gather traffic; §Perf)
    bf16_param_gather: bool = False
    # KV cache storage: "bf16" | "int8" (per-token-per-head symmetric
    # quant; halves decode HBM streaming — dense family, §Perf)
    kv_cache_dtype: str = "bf16"

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """May run long_500k (bounded state / local window)."""
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            d_in = self.ssm_expand * d
            nh = d_in // self.ssm_head_dim
            per = (d * (2 * d_in + 2 * self.ssm_state + nh)
                   + self.conv_width * (d_in + 2 * self.ssm_state)
                   + d_in * d + 2 * d_in + 3 * nh + d)
            return emb + self.n_layers * per
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        glu = self.act in ("swiglu", "geglu")
        if self.family == "moe":
            ffn = (d * self.n_experts
                   + self.n_experts * (d * self.d_expert * (3 if glu else 2)))
            if self.n_shared_experts:
                ffn += d * self.n_shared_experts * self.d_expert * (3 if glu else 2)
        else:
            ffn = d * self.d_ff * (3 if glu else 2)
        per = attn + ffn + 2 * d
        n_attn_layers = self.n_layers
        total = emb
        if self.family == "hybrid":
            pat = self.block_pattern or ("attn",)
            n_rec = sum(1 for b in self._layer_kinds() if b == "rec")
            n_att = self.n_layers - n_rec
            lru = self.lru_width
            rec_per = (2 * d * lru + self.conv_width * lru
                       + 2 * lru * lru + lru * d + 4 * lru) + ffn + 2 * d
            return emb + n_att * per + n_rec * rec_per
        if self.family == "encdec":
            cross = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
                + self.n_heads * self.d_head * d
            return (emb + self.n_enc_layers * per
                    + self.n_layers * (per + cross + d))
        return total + n_attn_layers * per

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        glu = self.act in ("swiglu", "geglu")
        full_ffn = self.n_experts * d * self.d_expert * (3 if glu else 2)
        act_ffn = self.moe_top_k * d * self.d_expert * (3 if glu else 2)
        return self.param_count() - self.n_layers * (full_ffn - act_ffn)

    def _layer_kinds(self) -> list[str]:
        if self.family == "hybrid" and self.block_pattern:
            pat = list(self.block_pattern)
            return [pat[i % len(pat)] for i in range(self.n_layers)]
        if self.family == "ssm":
            return ["ssd"] * self.n_layers
        return ["attn"] * self.n_layers


# ---------------------------------------------------------------------------
# shape registry (assigned input shapes)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "qwen2_0_5b", "qwen2_5_32b", "starcoder2_3b", "glm4_9b",
    "recurrentgemma_2b", "granite_moe_3b", "phi3_5_moe", "whisper_small",
    "mamba2_2_7b", "paligemma_3b",
]


def load_arch(arch_id: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.SMOKE if smoke else mod.CONFIG


def cell_is_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; else reason for the skip."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("full-attention arch: 500k dense KV decode skipped "
                       "(DESIGN.md §4)")
    return True, ""
