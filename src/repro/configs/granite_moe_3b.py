"""granite-moe-3b-a800m [moe] — 40 experts top-8, d_expert=512
[hf:ibm-granite/granite-3.0-3b-a800m-base; hf].

Note: the assignment line lists both "MoE 40e top-8" and "32 experts
top-8"; we follow the explicit field "MoE 40e top-8" (matches the HF
granite-3.0-3b-a800m card).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_head=64,
    d_ff=512, vocab_size=49155,
    rope_theta=1e4, norm_type="rmsnorm", act="swiglu",
    n_experts=40, moe_top_k=8, d_expert=512,
)

SMOKE = ArchConfig(
    name="granite-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=64, vocab_size=256,
    rope_theta=1e4, norm_type="rmsnorm", act="swiglu",
    n_experts=4, moe_top_k=2, d_expert=64,
    capacity_factor=4.0,      # dropless at smoke scale: exact decode tests
)
