"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab_size=32064,
    rope_theta=1e4, norm_type="layernorm", act="swiglu",
    n_experts=16, moe_top_k=2, d_expert=6400,
)

SMOKE = ArchConfig(
    name="phi3.5-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256,
    rope_theta=1e4, norm_type="layernorm", act="swiglu",
    n_experts=4, moe_top_k=2, d_expert=128,
    capacity_factor=4.0,      # dropless at smoke scale: exact decode tests
)
