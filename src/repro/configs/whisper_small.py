"""whisper-small [audio] — enc-dec backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings) [arXiv:2212.04356].

Backbone-only per the assignment: 12 encoder + 12 decoder layers,
d_model 768, 12 heads (MHA: kv=12), d_ff 3072, vocab 51865. Positional
scheme normalized to RoPE for zoo uniformity (DESIGN.md §8).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, n_enc_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_head=64, d_ff=3072, vocab_size=51865,
    rope_theta=1e4, norm_type="layernorm", act="gelu",
    frontend_stub=True,
)

SMOKE = ArchConfig(
    name="whisper-small-smoke", family="encdec",
    n_layers=2, n_enc_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_head=16, d_ff=128, vocab_size=256,
    rope_theta=1e4, norm_type="layernorm", act="gelu",
    frontend_stub=True,
)
