"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free
[arXiv:2405.21060]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=50280,
    norm_type="rmsnorm", act="silu",
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, conv_width=4,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab_size=256,
    norm_type="rmsnorm", act="silu",
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, conv_width=4,
    tie_embeddings=True,
)
