"""paligemma-3b [vlm] — SigLIP frontend STUB (precomputed patch
embeddings) + gemma-2b decoder with prefix-LM masking [arXiv:2407.07726].
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_head=256,
    d_ff=16384, vocab_size=257216,
    rope_theta=1e4, norm_type="rmsnorm", act="geglu",
    n_prefix_tokens=256, frontend_stub=True, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="paligemma-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
    d_ff=128, vocab_size=256,
    rope_theta=1e4, norm_type="rmsnorm", act="geglu",
    n_prefix_tokens=8, frontend_stub=True, tie_embeddings=True,
)
