"""starcoder2-3b [dense] — GQA, RoPE, LayerNorm + plain-GeLU MLP
[arXiv:2402.19173; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab_size=49152,
    qkv_bias=True, rope_theta=1e5, norm_type="layernorm", act="gelu",
)

SMOKE = ArchConfig(
    name="starcoder2-3b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=256, vocab_size=256,
    qkv_bias=True, rope_theta=1e5, norm_type="layernorm", act="gelu",
)
