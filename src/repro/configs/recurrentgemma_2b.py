"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 2:1 pattern
(rec, rec, attn), window 2048 [arXiv:2402.19427; hf]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, d_head=256,
    d_ff=7680, vocab_size=256000,
    rope_theta=1e4, norm_type="rmsnorm", act="geglu",
    block_pattern=("rec", "rec", "attn"), window_size=2048, lru_width=2560,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="recurrentgemma-2b-smoke", family="hybrid",
    n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
    d_ff=128, vocab_size=256,
    rope_theta=1e4, norm_type="rmsnorm", act="geglu",
    block_pattern=("rec", "rec", "attn"), window_size=16, lru_width=64,
    tie_embeddings=True,
)
