"""The paper's own target models (Section 5.1): BERT, DistilBERT, ViT.

Modeled in the same zoo as encoder-style dense transformers (bidir mask,
classification head via the selection core). ViT's patchify frontend is a
stub per the modality rule. These drive the paper-reproduction benchmarks
(selection efficacy + delay), not the assigned-arch dry-run grid.
"""
from repro.configs.base import ArchConfig

BERT = ArchConfig(
    name="bert-base", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab_size=30522,
    norm_type="layernorm", act="gelu", rope_theta=1e4,
)

DISTILBERT = ArchConfig(
    name="distilbert", family="dense",
    n_layers=6, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab_size=30522,
    norm_type="layernorm", act="gelu", rope_theta=1e4,
)

VIT_BASE = ArchConfig(
    name="vit-base", family="dense",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_head=64,
    d_ff=3072, vocab_size=1000,
    norm_type="layernorm", act="gelu", rope_theta=1e4,
)

VIT_SMALL = ArchConfig(
    name="vit-small", family="dense",
    n_layers=12, d_model=384, n_heads=6, n_kv_heads=6, d_head=64,
    d_ff=1536, vocab_size=1000,
    norm_type="layernorm", act="gelu", rope_theta=1e4,
)

# tiny geometry used by the CPU-scale efficacy experiments
TINY_TARGET = ArchConfig(
    name="tiny-target", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
    d_ff=256, vocab_size=512,
    norm_type="layernorm", act="gelu", rope_theta=1e4,
)
