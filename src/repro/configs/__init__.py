from repro.configs.base import (
    ArchConfig, ShapeSpec, SHAPES, ARCH_IDS, load_arch, cell_is_applicable,
)
