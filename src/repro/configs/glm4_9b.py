"""glm4-9b [dense] — partial RoPE (half dims), GQA [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b", family="dense",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_head=128,
    d_ff=13696, vocab_size=151552,
    qkv_bias=True, rope_theta=1e4, rope_fraction=0.5,
    norm_type="rmsnorm", act="swiglu",
)

SMOKE = ArchConfig(
    name="glm4-9b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=192, vocab_size=256,
    qkv_bias=True, rope_theta=1e4, rope_fraction=0.5,
    norm_type="rmsnorm", act="swiglu",
)
