"""Serving driver: batched prefill + decode with a continuous queue.

Smoke-scale on CPU (examples/serve_demo.py); same code shape as the pod
deployment. Structure: requests arrive with prompts, are batched to the
configured slot count, prefilled once, then decoded step-locked; finished
sequences free their slot for the next queued request (continuous
batching at slot granularity).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import load_arch, ARCH_IDS
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.parallel.sharding import ShardRules, rules_scope


@dataclasses.dataclass
class ServeConfig:
    arch: str = "qwen2_0_5b"
    smoke: bool = True
    slots: int = 4                 # concurrent sequences
    max_len: int = 128
    max_new: int = 16
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) tokens
    out: list[int] = dataclasses.field(default_factory=list)


class Server:
    def __init__(self, sc: ServeConfig):
        self.sc = sc
        self.cfg = load_arch(sc.arch, smoke=sc.smoke)
        self.mesh = make_host_mesh()
        self.rules = ShardRules(self.mesh)
        key = jax.random.key(sc.seed)
        with rules_scope(self.rules):
            self.params = T.init_params(key, self.cfg)
        self._decode = jax.jit(
            lambda p, c, b, pos: T.decode_step(p, self.cfg, c, b, pos))
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, self.cfg, b, max_len=sc.max_len))

    def run(self, requests: list[Request]) -> dict:
        sc = self.sc
        queue = list(requests)
        done: list[Request] = []
        t0 = time.time()
        tokens_out = 0
        while queue:
            active = queue[:sc.slots]
            queue = queue[sc.slots:]
            s = max(len(r.prompt) for r in active)
            toks = np.zeros((len(active), s), np.int32)
            for i, r in enumerate(active):
                toks[i, -len(r.prompt):] = r.prompt     # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            with rules_scope(self.rules):
                logits, cache = self._prefill(self.params, batch)
                step_tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                for r, t in zip(active, np.asarray(step_tok)[:, 0]):
                    r.out.append(int(t))
                for j in range(sc.max_new - 1):
                    pos = jnp.int32(s + j)
                    logits, cache = self._decode(self.params, cache,
                                                 {"tokens": step_tok}, pos)
                    step_tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                    for r, t in zip(active, np.asarray(step_tok)[:, 0]):
                        r.out.append(int(t))
                    tokens_out += len(active)
            done.extend(active)
        dt = time.time() - t0
        return {"requests": len(done), "tokens": tokens_out,
                "tok_per_s": tokens_out / max(dt, 1e-9),
                "outputs": {r.rid: r.out for r in done}}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2_0_5b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic requests to enqueue")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (continuous batching "
                         "granularity)")
    ap.add_argument("--max-len", type=int, default=128,
                    help="prefill/KV-cache length budget per slot")
    ap.add_argument("--max-new", type=int, default=8,
                    help="tokens decoded per request")
    ap.add_argument("--seed", type=int, default=0,
                    help="parameter-init and synthetic-prompt seed")
    ap.add_argument("--full", action="store_true",
                    help="full-size architecture (default is the smoke "
                         "geometry)")
    args = ap.parse_args()
    sc = ServeConfig(arch=args.arch, smoke=not args.full, slots=args.slots,
                     max_len=args.max_len, max_new=args.max_new,
                     seed=args.seed)
    srv = Server(sc)
    rng = np.random.default_rng(sc.seed)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size,
                                    size=rng.integers(4, 12)))
            for i in range(args.requests)]
    out = srv.run(reqs)
    print(f"[serve] {out['requests']} requests, {out['tokens']} tokens, "
          f"{out['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
