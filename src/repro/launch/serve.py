"""Serving driver: batched prefill + decode, plus the appraisal service.

Smoke-scale on CPU (examples/serve_demo.py); same code shape as the pod
deployment. Two serving modes share this driver:

  token decoding (default)   requests arrive with prompts, are batched
      to the configured slot count, prefilled once, then decoded
      step-locked; finished sequences free their slot for the next
      queued request (continuous batching at slot granularity).
  --appraise                 requests are (data-owner, model-owner)
      APPRAISAL sessions: the repro.serve.AppraisalServer decomposes
      each into its multiphase MPC schedule, continuously batches waves
      across sessions, pipelines the offline dealer, and serves
      fingerprint-identical phases from the cross-session cache. Writes
      SERVE_report.json whose per-phase dicts are PhaseReport.as_dict —
      the exact shape of SELECT_report's `executed` block.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import load_arch, ARCH_IDS
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.parallel.sharding import ShardRules, rules_scope


@dataclasses.dataclass
class ServeConfig:
    arch: str = "qwen2_0_5b"
    smoke: bool = True
    slots: int = 4                 # concurrent sequences
    max_len: int = 128
    max_new: int = 16
    seed: int = 0


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray             # (S,) tokens
    out: list[int] = dataclasses.field(default_factory=list)


class Server:
    def __init__(self, sc: ServeConfig):
        self.sc = sc
        self.cfg = load_arch(sc.arch, smoke=sc.smoke)
        self.mesh = make_host_mesh()
        self.rules = ShardRules(self.mesh)
        key = jax.random.key(sc.seed)
        with rules_scope(self.rules):
            self.params = T.init_params(key, self.cfg)
        self._decode = jax.jit(
            lambda p, c, b, pos: T.decode_step(p, self.cfg, c, b, pos))
        self._prefill = jax.jit(
            lambda p, b: T.prefill(p, self.cfg, b, max_len=sc.max_len))

    def run(self, requests: list[Request]) -> dict:
        sc = self.sc
        queue = list(requests)
        done: list[Request] = []
        t0 = time.time()
        tokens_out = 0
        while queue:
            active = queue[:sc.slots]
            queue = queue[sc.slots:]
            s = max(len(r.prompt) for r in active)
            toks = np.zeros((len(active), s), np.int32)
            for i, r in enumerate(active):
                toks[i, -len(r.prompt):] = r.prompt     # left-pad
            batch = {"tokens": jnp.asarray(toks)}
            with rules_scope(self.rules):
                logits, cache = self._prefill(self.params, batch)
                step_tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                for r, t in zip(active, np.asarray(step_tok)[:, 0]):
                    r.out.append(int(t))
                for j in range(sc.max_new - 1):
                    pos = jnp.int32(s + j)
                    logits, cache = self._decode(self.params, cache,
                                                 {"tokens": step_tok}, pos)
                    step_tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                    for r, t in zip(active, np.asarray(step_tok)[:, 0]):
                        r.out.append(int(t))
                    tokens_out += len(active)
            done.extend(active)
        dt = time.time() - t0
        return {"requests": len(done), "tokens": tokens_out,
                "tok_per_s": tokens_out / max(dt, 1e-9),
                "outputs": {r.rid: r.out for r in done}}


def appraise(n_sessions: int = 3, n_pool: int = 96, protocol: str = "2pc",
             ring_bits: int = 64, seed: int = 0, repeat_first: bool = True,
             out_path: str | None = "SERVE_report.json") -> dict:
    """Run an appraisal-service queue and emit SERVE_report.json.

    Builds `n_sessions` synthetic appraisal sessions (tiny target +
    synthetic task, the Stage-2 smoke geometry); with `repeat_first` the
    second session duplicates the first — the cross-session cache serves
    its phases without re-execution (hits > 0 is a CI gate)."""
    from repro.configs.paper_targets import TINY_TARGET
    from repro.core import target as tgt
    from repro.core.executor import ExecConfig
    from repro.core.proxy import ProxySpec
    from repro.core.selection import SelectionConfig
    from repro.data.tasks import make_classification_task
    from repro.engine import MPCEngine
    from repro.mpc.ring import RING32, RING64
    from repro.serve import AppraisalServer, SessionSpec

    ring = RING32 if ring_bits == 32 else RING64

    def spec(sid: str, task_seed: int) -> SessionSpec:
        task = make_classification_task(task_seed, n_pool=n_pool, n_test=32,
                                        seq=8, vocab=64, n_classes=2)
        cfg = dataclasses.replace(TINY_TARGET, vocab_size=task.vocab)
        key = jax.random.key(task_seed)
        params0 = tgt.init_classifier(key, cfg, task.n_classes)
        sel = SelectionConfig(
            phases=[ProxySpec(1, 1, 2, 0.5), ProxySpec(1, 2, 4, 1.0)],
            budget_frac=0.25, boot_frac=0.1,
            engine=MPCEngine(ring=ring, protocol=protocol),
            exvivo_steps=4, invivo_steps=2, finetune_steps=2,
            score_batch=16, checkpoint_dir=None,
            executor=ExecConfig(wave=2, ring=ring, protocol=protocol))
        return SessionSpec(sid=sid, key=key, target_params=params0,
                           arch_cfg=cfg, pool_tokens=task.pool_tokens,
                           sel=sel, n_classes=task.n_classes,
                           boot_labels_fn=lambda i: task.pool_labels[i])

    srv = AppraisalServer(dealer_seed=seed)
    for i in range(n_sessions):
        task_seed = seed if (repeat_first and i == 1) else seed + i
        srv.submit(spec(f"s{i}", task_seed))
    report = srv.run()
    srv.close()
    if out_path:
        import json
        with open(out_path, "w") as f:
            json.dump(report, f, indent=1, default=float)
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2_0_5b", choices=ARCH_IDS)
    ap.add_argument("--requests", type=int, default=8,
                    help="synthetic requests to enqueue")
    ap.add_argument("--slots", type=int, default=4,
                    help="concurrent decode slots (continuous batching "
                         "granularity)")
    ap.add_argument("--max-len", type=int, default=128,
                    help="prefill/KV-cache length budget per slot")
    ap.add_argument("--max-new", type=int, default=8,
                    help="tokens decoded per request")
    ap.add_argument("--seed", type=int, default=0,
                    help="parameter-init and synthetic-prompt seed")
    ap.add_argument("--full", action="store_true",
                    help="full-size architecture (default is the smoke "
                         "geometry)")
    ap.add_argument("--appraise", action="store_true",
                    help="serve APPRAISAL sessions through the "
                         "repro.serve AppraisalServer instead of token "
                         "decoding; writes SERVE_report.json")
    ap.add_argument("--sessions", type=int, default=3,
                    help="appraisal sessions to enqueue (--appraise)")
    ap.add_argument("--pool", type=int, default=96,
                    help="candidate pool size per session (--appraise)")
    ap.add_argument("--protocol",
                    choices=["2pc", "3pc", "spdz2pc", "aby3trunc"],
                    default="2pc", help="MPC backend (--appraise)")
    ap.add_argument("--ring", type=int, choices=[64, 32], default=64,
                    help="MPC ring width (--appraise)")
    ap.add_argument("--out", default="SERVE_report.json",
                    help="report path (--appraise)")
    args = ap.parse_args()
    if args.appraise:
        rep = appraise(n_sessions=args.sessions, n_pool=args.pool,
                       protocol=args.protocol, ring_bits=args.ring,
                       seed=args.seed, out_path=args.out)
        t = rep["throughput"]
        print(f"[serve] {t['n_sessions']} appraisals: "
              f"{t['serve_appraisals_per_hour']:.2f}/h served vs "
              f"{t['sequential_appraisals_per_hour']:.2f}/h sequential "
              f"({t['speedup']:.2f}x); cache {rep['cache']['hits']} hits/"
              f"{rep['cache']['misses']} misses; dealer stall "
              f"{rep['dealer']['dealer_stall_s']:.3f}s; "
              f"ledger_agrees={rep['ledger_agrees']} -> {args.out}")
        return
    sc = ServeConfig(arch=args.arch, smoke=not args.full, slots=args.slots,
                     max_len=args.max_len, max_new=args.max_new,
                     seed=args.seed)
    srv = Server(sc)
    rng = np.random.default_rng(sc.seed)
    reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size,
                                    size=rng.integers(4, 12)))
            for i in range(args.requests)]
    out = srv.run(reqs)
    print(f"[serve] {out['requests']} requests, {out['tokens']} tokens, "
          f"{out['tok_per_s']:.1f} tok/s")


if __name__ == "__main__":
    main()
