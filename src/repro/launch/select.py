"""SelectFormer workflow driver — the paper's end-to-end pipeline.

Stage 1 bootstrap -> proxy generation -> Stage 2 multi-phase MPC sieve ->
Stage 3 transaction + appraisal -> finetune target on purchased data ->
report test accuracy and the modeled selection delay (WAN profile at
paper scale; pod-DCN profile for the deployment projection).

CPU-scale by default (tiny target + synthetic imbalanced task); the same
driver, pointed at the pod mesh and a real corpus, is the deployment
entry point. Delay numbers come from the calibrated analytic cost model
(mpc/costs.py) scheduled by core/iosched.py — identical formulas to the
executable share-level path, evaluated at the paper's geometry.

--mode mpc runs Stage 2 through the wave executor (core/executor.py)
with an MPCEngine interpreting the unified proxy forward; --ring 32
switches the same code path onto the TPU-native RING32 ring and
--protocol {2pc,3pc,spdz2pc,aby3trunc} picks the secret-sharing backend
(2pc: additive + trusted-dealer Beaver triples, offline bytes reported
separately; 3pc: replicated 2-of-3, dealer-free — zero offline bytes;
spdz2pc: the malicious tier, MAC'd shares that abort on tamper;
aby3trunc: 3pc with ABY3's exact 2-round truncation).
--wave/--no-coalesce/--no-overlap select among Fig 7's four schedule
variants at runtime; openings/reshares are round-compressed into fused
flights by default (mpc/fusion.py) — --eager disables the batcher. The
output includes each phase's realized flight ledger plus its exact
agreement with the makespan model. Re-runs resume from phase
checkpoints (--no-resume disables).
"""
from __future__ import annotations

import argparse
import dataclasses
import getpass
import os
import tempfile
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.paper_targets import TINY_TARGET
from repro.core import target as tgt, iosched
from repro.core.executor import ExecConfig
from repro.core.proxy import ProxySpec
from repro.core.selection import SelectionConfig, run_selection
from repro.data.tasks import make_classification_task
from repro.engine import ClearEngine, MPCEngine
from repro.mpc import costs
from repro.mpc.comm import WAN, POD_DCN
from repro.mpc.ring import RING32, RING64


def paper_scale_delay(n_pool: int, budget_frac: float, *, seq: int = 128,
                      layers: int = 12, d_model: int = 768, heads: int = 12,
                      classes: int = 2, batch: int = 8) -> dict:
    """Selection delay at paper geometry (BERT-ish) under both nets."""
    g = costs.BlockGeom(batch=batch, seq=seq, d_model=d_model, heads=heads,
                        d_head=d_model // heads, d_ff=4 * d_model)
    budget = int(budget_frac * n_pool)
    phase1 = costs.selection_phase_cost(
        n_pool, int(0.3 * n_pool),
        costs.BlockGeom(batch, seq, d_model, 1, d_model // heads, 0),
        layers=1, classes=classes, mlp_hidden=2)
    phase2 = costs.selection_phase_cost(
        int(0.3 * n_pool), budget, g, layers=3, classes=classes,
        mlp_hidden=16)
    oracle = costs.oracle_selection_cost(n_pool, budget, g, layers=layers,
                                         classes=classes)
    per_batch1 = costs.selection_phase_cost(
        batch, batch,
        costs.BlockGeom(batch, seq, d_model, 1, d_model // heads, 0),
        1, classes, 2)
    out = {}
    for net_name, net in (("wan", WAN), ("pod_dcn", POD_DCN)):
        sched = iosched.SchedConfig()
        ours = (iosched.makespan(phase1.scaled(batch / n_pool),
                                 -(-n_pool // batch), net, sched)
                + iosched.makespan(phase2.scaled(batch / max(int(0.3 * n_pool), 1)),
                                   -(-int(0.3 * n_pool) // batch), net, sched))
        serial = iosched.SchedConfig(coalesce=False, overlap=False)
        orc = iosched.makespan(oracle.scaled(batch / n_pool),
                               -(-n_pool // batch), net, serial)
        out[net_name] = {"ours_hours": ours / 3600,
                         "oracle_hours": orc / 3600,
                         "speedup": orc / max(ours, 1e-9)}
    return out


def run(seed: int = 0, n_pool: int = 800, budget: float = 0.2,
        mode: str = "clear", finetune_steps: int = 250, *,
        wave: int = 8, coalesce: bool = True, overlap: bool = True,
        fuse: bool = True, score_batch: int = 64, ring_bits: int = 64,
        protocol: str = "2pc", resume: bool = True,
        wire: str = "none", net: str = "wan",
        chaos_seed: int | None = None, degraded: bool = False,
        mesh: str = "none", combine: str = "auto") -> dict:
    task = make_classification_task(seed, n_pool=n_pool, n_test=400,
                                    seq=16, vocab=256, n_classes=4)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=task.vocab)
    key = jax.random.key(seed)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)

    ring = RING32 if ring_bits == 32 else RING64
    engine = MPCEngine(ring=ring, protocol=protocol) if mode == "mpc" \
        else ClearEngine()
    ckpt_dir = os.path.join(tempfile.gettempdir(),
                            f"selectformer_phases_{getpass.getuser()}")
    sel = SelectionConfig(
        phases=[ProxySpec(1, 2, 2, 0.4), ProxySpec(2, 4, 8, 1.0)],
        budget_frac=budget, boot_frac=0.05, engine=engine,
        exvivo_steps=150, invivo_steps=80, finetune_steps=100,
        score_batch=score_batch,
        checkpoint_dir=ckpt_dir, resume=resume,
        executor=ExecConfig(wave=wave, coalesce=coalesce, overlap=overlap,
                            fuse=fuse, protocol=protocol,
                            wire=wire, net=net,
                            chaos_seed=chaos_seed, degraded=degraded,
                            mesh=mesh, combine=combine))
    t0 = time.time()
    res = run_selection(key, params0, cfg, task.pool_tokens, sel,
                        n_classes=task.n_classes,
                        boot_labels_fn=lambda i: task.pool_labels[i])
    sel_time = time.time() - t0

    # realized §4.4 schedule: per-phase flight ledgers, checked against
    # the analytic makespan's inputs (exact integer agreement)
    executed = None
    if mode == "mpc":
        # ledger_agrees: None until at least one phase actually executed
        # this run — a fully-resumed run must not assert a contract it
        # never checked
        executed = {"phases": [],
                    "ledger_agrees": True if res.exec_reports else None,
                    "resumed_phases": res.resumed_phases}
        for rep in res.exec_reports:
            executed["ledger_agrees"] &= rep.agrees()
            # the shared per-phase dict shape (PhaseReport.as_dict) —
            # SERVE_report.json emits the identical keys
            executed["phases"].append(rep.as_dict())

    def finetune_and_eval(idx, tag):
        p, _ = tgt.finetune(jax.random.fold_in(key, 7), params0, cfg,
                            jnp.asarray(task.pool_tokens[idx]),
                            jnp.asarray(task.pool_labels[idx]),
                            steps=finetune_steps)
        return tgt.accuracy(p, cfg, jnp.asarray(task.test_tokens),
                            task.test_labels)

    rng = np.random.default_rng(seed)
    rand_idx = rng.choice(n_pool, size=len(res.selected), replace=False)
    acc_ours = finetune_and_eval(res.selected, "ours")
    acc_rand = finetune_and_eval(rand_idx, "random")

    delays = paper_scale_delay(42_000, budget)
    return {"acc_ours": acc_ours, "acc_random": acc_rand,
            "gain": acc_ours - acc_rand,
            "appraisal_entropy": res.appraisal_entropy,
            "selection_wall_s": sel_time,
            "paper_scale_delay": delays,
            "executed": executed,
            "n_selected": int(len(res.selected))}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pool", type=int, default=800)
    ap.add_argument("--budget", type=float, default=0.2)
    ap.add_argument("--mode", choices=["clear", "mpc"], default="clear")
    ap.add_argument("--wave", type=int, default=8,
                    help="batches coalesced per MPC flight (mode=mpc)")
    ap.add_argument("--score-batch", type=int, default=64)
    ap.add_argument("--no-coalesce", action="store_true",
                    help="disable latency-flight coalescing (fig7 'serial')")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable comm/compute double buffering")
    ap.add_argument("--eager", action="store_true",
                    help="disable the flight batcher (fused round "
                         "compression is the default; mpc/fusion.py)")
    ap.add_argument("--ring", type=int, choices=[64, 32], default=64,
                    help="MPC ring: 64 (CrypTen oracle) or 32 (TPU)")
    ap.add_argument("--protocol",
                    choices=["2pc", "3pc", "spdz2pc", "aby3trunc"],
                    default="2pc",
                    help="secret-sharing backend: 2pc (additive + "
                         "trusted-dealer Beaver), 3pc (replicated "
                         "2-of-3, dealer-free), spdz2pc (malicious: "
                         "MAC'd shares, aborts on tamper) or aby3trunc "
                         "(3pc with exact ABY3 truncation)")
    ap.add_argument("--no-resume", action="store_true",
                    help="ignore existing phase checkpoints")
    ap.add_argument("--wire", choices=["none", "local", "socket"],
                    default="none",
                    help="execute MPC flights over a real transport "
                         "(repro/net/): 'local' = party threads over "
                         "in-process queues, 'socket' = party processes "
                         "over paced localhost TCP; each phase report "
                         "gains a measured wire_makespan_s (mode=mpc)")
    ap.add_argument("--net", choices=["wan", "pod_dcn", "ici"],
                    default="wan",
                    help="NetProfile the socket transport emulates "
                         "(pacing + injected latency)")
    ap.add_argument("--chaos-seed", type=int, default=None,
                    help="inject a deterministic FaultPlan derived from "
                         "each phase's tape with this seed (drops, "
                         "latency spikes, resets, one crash); requires "
                         "--wire local|socket. Scores stay bitwise "
                         "identical and goodput still reconciles.")
    ap.add_argument("--chaos-plan", type=str, default=None,
                    help="write each wired phase's injected FaultPlan "
                         "as JSON to this path (phase index appended) "
                         "for exact replay")
    ap.add_argument("--degraded", action="store_true",
                    help="with --chaos-seed on an honest-majority "
                         "protocol (3pc/aby3trunc): place the crash at "
                         "a phase boundary and complete 2-of-3 with "
                         "the survivors instead of respawning")
    ap.add_argument("--mesh", choices=["none", "host", "shardmap"],
                    default="none",
                    help="device mesh for the wave executor "
                         "(parallel/sharding.py): 'host' device_puts "
                         "each wave with party -> pod and wave -> data "
                         "over the local devices (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on "
                         "CPU); 'shardmap' splits wave lanes across the "
                         "data axis under jax.shard_map. Each phase "
                         "report gains a measured device_makespan_s "
                         "(mode=mpc)")
    ap.add_argument("--combine", choices=["auto", "pallas", "interpret",
                                          "ref"],
                    default="auto",
                    help="Beaver post-open combine for fused RING32 2pc "
                         "matmuls: the Pallas secure_matmul kernel "
                         "('auto' compiles on TPU, 'interpret' runs the "
                         "kernel body on CPU) or the jnp reference — "
                         "bitwise identical either way")
    args = ap.parse_args()
    out = run(args.seed, args.pool, args.budget, args.mode,
              wave=args.wave, coalesce=not args.no_coalesce,
              overlap=not args.no_overlap, fuse=not args.eager,
              score_batch=args.score_batch,
              ring_bits=args.ring, protocol=args.protocol,
              resume=not args.no_resume, wire=args.wire, net=args.net,
              chaos_seed=args.chaos_seed, degraded=args.degraded,
              mesh=args.mesh, combine=args.combine)
    if out["executed"] is not None:
        ex = out["executed"]
        ph = ex["phases"]
        if ex["resumed_phases"]:
            print(f"[select] resumed {ex['resumed_phases']} phase(s) from "
                  "checkpoints — MPC execution skipped for those "
                  "(re-run with --no-resume to execute everything)")
        if ph:
            print(f"[select] executed {len(ph)} MPC phases, ledger_agrees="
                  f"{ex['ledger_agrees']}; per-phase makespan(WAN) "
                  + ", ".join(f"{p['makespan_wan_s']:.1f}s" for p in ph))
        meshed = [p for p in ph if p.get("device")
                  and p["device"]["placement"] != "none"]
        if meshed:
            d0 = meshed[0]["device"]
            print(f"[select] device mesh ({d0['placement']}): "
                  f"{d0['n_devices']} devices {d0['mesh_axes']}; measured "
                  + ", ".join(f"{p['device_makespan_s']:.3f}s"
                              for p in meshed))
        wired = [p["wire"] for p in ph if p.get("wire")]
        if wired:
            print("[select] real wire (" + wired[0]["mode"] + "): measured "
                  + ", ".join(f"{w['wire_makespan_s']:.3f}s" for w in wired)
                  + f"; bytes reconciled={all(w['bytes_match'] for w in wired)}")
        chaotic = [w for w in wired if w.get("faults_injected")]
        if chaotic:
            print("[select] chaos: "
                  f"{sum(w['faults_injected'] for w in chaotic)} faults, "
                  f"{sum(w['retries'] for w in chaotic)} retries, "
                  f"{sum(w['respawns'] for w in chaotic)} respawns, "
                  f"{sum(w['retrans_bytes'] for w in chaotic)} retrans B, "
                  "recovery "
                  f"{sum(w['recovery_time_s'] for w in chaotic):.3f}s")
            if args.chaos_plan:
                for i, w in enumerate(chaotic):
                    if w.get("fault_plan"):
                        path = f"{args.chaos_plan}.phase{i}.json"
                        with open(path, "w") as f:
                            f.write(w["fault_plan"])
                        print(f"[select] chaos plan -> {path}")
    print(f"[select] ours={out['acc_ours']:.3f} random={out['acc_random']:.3f} "
          f"(+{out['gain']:.3f}); modeled WAN delay "
          f"{out['paper_scale_delay']['wan']['ours_hours']:.1f}h vs oracle "
          f"{out['paper_scale_delay']['wan']['oracle_hours']:.0f}h "
          f"({out['paper_scale_delay']['wan']['speedup']:.0f}x)")


if __name__ == "__main__":
    main()
