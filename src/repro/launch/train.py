"""Training driver: data pipeline + sharded train step + checkpoint/
restart + fault-tolerance hooks.

Runs at any scale: on the CPU container it trains smoke configs end to
end (examples/quickstart.py); on a pod it is the same code with the
production mesh. The loop structure is the deliverable: deterministic
resume (data state is (seed, step)), async checkpoints, heartbeat +
straggler-mitigated input pipeline, optional gradient compression.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, restore_checkpoint
from repro.configs.base import load_arch, ARCH_IDS
from repro.data.pipeline import DataPipeline
from repro.launch.mesh import make_host_mesh, data_shards
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.parallel.sharding import ShardRules, param_specs, rules_scope
from repro.runtime.ft import HeartbeatMonitor, StragglerMitigator


@dataclasses.dataclass
class TrainConfig:
    arch: str = "qwen2_0_5b"
    smoke: bool = True
    steps: int = 50
    batch: int = 8
    seq: int = 128
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    model_parallel: int = 1
    seed: int = 0
    log_every: int = 10
    remat: bool = True


def make_train_step(cfg, opt_cfg: AdamWConfig, remat: bool):
    def train_step(params, opt_state, batch):
        (loss, aux), grads = jax.value_and_grad(
            partial(T.train_loss, remat=remat), has_aux=True)(
                params, cfg, batch)
        params, opt_state, stats = adamw_update(params, grads, opt_state,
                                                opt_cfg)
        return params, opt_state, loss, stats["grad_norm"]
    return train_step


def train(tc: TrainConfig) -> dict:
    cfg = load_arch(tc.arch, smoke=tc.smoke)
    mesh = make_host_mesh(tc.model_parallel)
    rules = ShardRules(mesh)
    if cfg.family == "moe":
        g = data_shards(mesh)
        if (tc.batch * tc.seq) % g == 0:
            cfg = dataclasses.replace(cfg, moe_groups=g)
    opt_cfg = AdamWConfig(total_steps=tc.steps, warmup_steps=max(tc.steps // 10, 1))

    key = jax.random.key(tc.seed)
    with rules_scope(rules):
        params = T.init_params(key, cfg)
        opt_state = init_opt_state(params)
        p_shard = param_specs(params, rules)
        o_shard = param_specs(opt_state, rules)
        params = jax.device_put(params, p_shard)
        opt_state = jax.device_put(opt_state, o_shard)

        # ---- restore ----------------------------------------------------
        start_step = 0
        restored, step_found = restore_checkpoint(
            tc.ckpt_dir, {"params": params, "opt": opt_state})
        if step_found is not None:
            params = jax.device_put(restored["params"], p_shard)
            opt_state = jax.device_put(restored["opt"], o_shard)
            start_step = step_found
            print(f"[train] resumed from step {start_step}")

        step_fn = jax.jit(make_train_step(cfg, opt_cfg, tc.remat),
                          in_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1))

        pipe = DataPipeline(tc.seed, tc.batch, tc.seq, cfg.vocab_size,
                            start_step=start_step)
        ckpt = AsyncCheckpointer(tc.ckpt_dir)
        hb = HeartbeatMonitor(n_hosts=1, timeout_s=60)
        strag = StragglerMitigator()
        losses = []
        t0 = time.time()
        for step in range(start_step, tc.steps):
            batch_np = strag.run(lambda: next(pipe), backup=lambda: next(pipe))
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            params, opt_state, loss, gnorm = step_fn(params, opt_state, batch)
            hb.beat(0)
            losses.append(float(loss))
            if (step + 1) % tc.log_every == 0:
                print(f"[train] step {step + 1} loss {float(loss):.4f} "
                      f"gnorm {float(gnorm):.3f} "
                      f"({(step + 1 - start_step) / (time.time() - t0):.2f} it/s)")
            if (step + 1) % tc.ckpt_every == 0 or step + 1 == tc.steps:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
        ckpt.wait()
        pipe.close()
    return {"final_loss": losses[-1] if losses else None,
            "first_loss": losses[0] if losses else None,
            "healthy": hb.healthy(), "backups": strag.backups_fired,
            "resumed_from": start_step}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2_0_5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (pod scale) instead of smoke")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()
    out = train(TrainConfig(arch=args.arch, smoke=not args.full,
                            steps=args.steps, batch=args.batch, seq=args.seq,
                            ckpt_dir=args.ckpt_dir))
    print("[train] done:", out)


if __name__ == "__main__":
    main()
