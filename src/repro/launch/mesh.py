"""Production mesh builders (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    On the multi-pod mesh the "pod" axis serves double duty: MPC party
    axis for the selection workload (DESIGN.md §3), extra DP dim for
    plain training/serving.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices actually exist (smoke runs, examples)."""
    n = len(jax.devices())
    assert n % model_parallel == 0
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def data_shards(mesh) -> int:
    """Number of data-parallel shards (routing groups for MoE)."""
    n = mesh.shape.get("data", 1)
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
