# Launch layer: mesh.py / dryrun.py / train.py / serve.py / select.py.
# NOTE: dryrun.py must be started as its own process (python -m
# repro.launch.dryrun) — it sets XLA_FLAGS for 512 host devices before
# importing jax and must not be imported into a live session.
