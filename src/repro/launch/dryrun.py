"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST run as its own process: the first two lines pin 512 placeholder host
devices before jax initializes. Produces, per cell:
  memory_analysis  (proves the program fits per-device HBM)
  cost_analysis    (HLO FLOPs / bytes for the roofline)
  collective bytes (parsed from the partitioned HLO)
written to experiments/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch qwen2_0_5b --shape train_4k --mesh multi
  python -m repro.launch.dryrun --all          # every applicable cell
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"  # noqa: E402

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import re                # noqa: E402
import time              # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import (ARCH_IDS, SHAPES, ShapeSpec, load_arch,  # noqa: E402
                                cell_is_applicable)
from repro.launch.mesh import make_production_mesh, data_shards  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state  # noqa: E402
from repro.parallel.sharding import ShardRules, param_specs, rules_scope  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "experiments", "dryrun")

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
               "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def input_specs(cfg, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
    elif shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
    else:                                       # decode: one new token
        specs = {"tokens": jax.ShapeDtypeStruct((b, 1), i32)}
    if cfg.family == "vlm" and shape.kind != "decode":
        specs["patches"] = jax.ShapeDtypeStruct(
            (b, cfg.n_prefix_tokens, cfg.d_model), bf16)
    if cfg.family == "encdec" and shape.kind != "decode":
        specs["frames"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), bf16)
    return specs


def batch_sharding(specs, rules: ShardRules, n_batch_shards: int):
    def one(leaf):
        b = leaf.shape[0]
        axes = ["batch" if b % n_batch_shards == 0 and b >= n_batch_shards
                else None]
        axes += [None] * (leaf.ndim - 1)
        return rules.sharding(*axes)
    return jax.tree.map(one, specs)


def cache_sharding(cache_shapes, rules: ShardRules, n_batch_shards: int):
    from repro.parallel.sharding import axis_size, fit_spec
    msize = axis_size(rules, rules.resolve("model"))

    def one(path_tuple, leaf):
        name = "/".join(str(getattr(p, "key", p)) for p in path_tuple)
        bdim = leaf.shape[1]
        bax = "batch" if bdim % n_batch_shards == 0 and bdim >= n_batch_shards \
            else None
        if name.endswith(("k", "v")) and leaf.ndim == 5:      # (L,B,S,K,dh)
            # KV heads over model when divisible; else split-KV: shard the
            # SEQ dim (flash-decoding style) — required to fit 32k caches
            # when n_kv (2/8/12) doesn't divide the 16-way model axis
            if leaf.shape[3] % msize == 0:
                spec = [None, bax, None, "model", None]
            else:
                spec = [None, bax, "model", None, None]
        elif name.endswith(("ks", "vs")):                      # (L,B,S,K)
            if leaf.shape[3] % msize == 0:
                spec = [None, bax, None, "model"]
            else:
                spec = [None, bax, "model", None]
        elif "conv" in name:                                   # (L,B,W,C)
            spec = [None, bax, None, "model"]
        elif name.endswith("h"):                               # (L,B,lru)
            spec = [None, bax, "model"]
        elif "ssm" in name:                                    # (L,B,H,P,N)
            spec = [None, bax, "model", None, None]
        else:
            spec = [None] * leaf.ndim
        return NamedSharding(rules.mesh, fit_spec(rules, leaf.shape, spec))
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_cell(cfg, shape: ShapeSpec, rules: ShardRules):
    """Returns (step_fn, arg_shapes, in_shardings, donate)."""
    mesh = rules.mesh
    nb = 1
    for a in rules.batch_axes:
        nb *= mesh.shape[a]
    if cfg.family == "moe":
        tokens = shape.global_batch * max(
            shape.seq_len if shape.kind == "train" else 1, 1)
        groups = nb if tokens % nb == 0 else 1
        cfg = dataclasses.replace(cfg, moe_groups=groups)

    key = jax.random.key(0)
    p_shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), key)
    if shape.kind != "train":
        # serving weights are bf16 (no optimizer, no master copy)
        p_shapes = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 and len(s.shape) >= 2 else s, p_shapes)
    p_shard = param_specs(p_shapes, rules)
    specs = input_specs(cfg, shape)
    b_shard = batch_sharding(specs, rules, nb)

    if shape.kind == "train":
        opt_cfg = AdamWConfig()
        o_shapes = jax.eval_shape(lambda: init_opt_state(p_shapes))
        o_shard = param_specs(o_shapes, rules)

        def _fwd_params(params):
            if not cfg.bf16_param_gather:
                return params
            return jax.tree.map(
                lambda p: p.astype(jnp.bfloat16)
                if p.dtype == jnp.float32 and p.ndim >= 2 else p, params)

        def train_step(params, opt_state, batch):
            (loss, aux), grads = jax.value_and_grad(
                lambda p, c, b: T.train_loss(_fwd_params(p), c, b),
                has_aux=True)(params, cfg, batch)
            params, opt_state, stats = adamw_update(params, grads,
                                                    opt_state, opt_cfg)
            return params, opt_state, loss, stats["grad_norm"]

        return (train_step, (p_shapes, o_shapes, specs),
                (p_shard, o_shard, b_shard), (0, 1))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return T.prefill(params, cfg, batch, max_len=shape.seq_len)
        return prefill_step, (p_shapes, specs), (p_shard, b_shard), ()

    cache_shapes = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))
    c_shard = cache_sharding(cache_shapes, rules, nb)
    pos = jax.ShapeDtypeStruct((), jnp.int32)

    def serve_step(params, cache, batch, pos):
        return T.decode_step(params, cfg, cache, batch, pos)

    return (serve_step, (p_shapes, cache_shapes, specs, pos),
            (p_shard, c_shard, b_shard, NamedSharding(rules.mesh, P())), (1,))


# ---------------------------------------------------------------------------
# HLO analysis
# ---------------------------------------------------------------------------

def parse_collectives(hlo_text: str, body_mult: int = 1) -> dict:
    """Sum RESULT-shape bytes of every collective op in the partitioned
    HLO (operands are printed without types). Shapes are per-device; the
    ring model converts to wire bytes per device.

    HLO cost counting sees while bodies once; collectives inside non-ENTRY
    computations (the layer-scan bodies) are scaled by `body_mult` (the
    layer trip count). ENTRY-level collectives (embed/loss/optimizer)
    count once."""
    per_op: dict[str, float] = {}
    wire_per_dev = 0.0
    n_ops = 0
    shape_re = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
    mult = 1
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{") and not line.startswith(" "):
            mult = 1 if line.lstrip().startswith("ENTRY") else body_mult
        m = re.search(r"= (\([^)]*\)|[^ ]+) ([a-z-]+)\(", line)
        if not m:
            continue
        result_ty, op = m.group(1), m.group(2)
        base = op.removesuffix("-start")
        if base not in COLLECTIVES or op.endswith("-done"):
            continue
        nbytes = 0
        for dt, dims in shape_re.findall(result_ty):
            if dt not in DTYPE_BYTES:
                continue
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * DTYPE_BYTES[dt]
        gm = re.search(r"replica_groups=\{\{([0-9, ]+)\}", line)
        gsize = len(gm.group(1).split(",")) if gm else 1
        gm2 = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
        if gm2:
            gsize = int(gm2.group(2))
        n_ops += 1
        nbytes *= mult
        per_op[base] = per_op.get(base, 0.0) + nbytes
        # ring-model wire bytes per device (result-shape based)
        if base == "all-reduce":
            wire_per_dev += 2 * nbytes * (gsize - 1) / max(gsize, 1)
        elif base == "all-gather":
            wire_per_dev += nbytes * (gsize - 1) / max(gsize, 1)
        elif base in ("reduce-scatter", "all-to-all"):
            wire_per_dev += nbytes * (gsize - 1) / max(gsize, 1)
        else:                                   # collective-permute
            wire_per_dev += nbytes
    return {"per_op_bytes": per_op, "n_collectives": n_ops,
            "operand_bytes_total": sum(per_op.values()),
            "wire_bytes_per_device": wire_per_dev}


def inner_scan_flop_correction(cfg, shape: ShapeSpec) -> float:
    """Closed-form FLOPs executed by inner-scan bodies beyond HLO cost
    analysis's body-once counting (chunked attention / SSD chunk scans).

    The cost pass unrolls the LAYER scan, so per layer exactly one inner
    body is already counted; the correction adds the remaining
    (trips - 1) bodies. Train multiplies by 4 (fwd + remat recompute +
    ~2x grad). Exact for dot-product bodies (attention, SSD einsums).
    """
    import math
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return 0.0
    prefix = cfg.n_prefix_tokens if cfg.family == "vlm" else 0
    s_eff = s + prefix
    mult = 4.0 if shape.kind == "train" else 1.0
    total = 0.0
    h, dh = cfg.n_heads, cfg.d_head
    if cfg.family in ("dense", "moe", "vlm", "encdec") and s_eff > 2048:
        qc, kc = math.gcd(s_eff, 512), math.gcd(s_eff, 1024)
        nq, nk = s_eff // qc, s_eff // kc
        body = 4.0 * b * h * qc * kc * dh
        n_attn = cfg.n_layers
        if cfg.family == "encdec":
            n_attn += cfg.n_enc_layers + cfg.n_layers    # enc self + cross
        total += n_attn * (nq * nk - 1) * body * mult
    if cfg.family == "hybrid" and s_eff > 2048:
        qc = math.gcd(s_eff, 512)
        nq = s_eff // qc
        body = 4.0 * b * h * qc * (cfg.window_size + qc) * dh
        total += cfg._layer_kinds().count("attn") * (nq - 1) * body * mult
    if cfg.family == "ssm":
        q = min(128, s_eff)
        nc = s_eff // q
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        p_, n_ = cfg.ssm_head_dim, cfg.ssm_state
        body = b * (2.0 * q * q * n_ + nh * q * q + 2.0 * nh * q * q * p_
                    + 4.0 * q * nh * p_ * n_)
        total += cfg.n_layers * (nc - 1) * body * mult
    return total


def attn_model_flops(cfg, shape: ShapeSpec) -> float:
    """Ideal attention FLOPs for MODEL_FLOPS (6ND misses the S^2 term)."""
    b, s = shape.global_batch, shape.seq_len
    hdh = cfg.n_heads * cfg.d_head
    kinds = cfg._layer_kinds()
    n_attn = kinds.count("attn")
    if cfg.family == "encdec":
        n_attn = cfg.n_layers + cfg.n_enc_layers
    if n_attn == 0:
        return 0.0
    if shape.kind == "decode":
        kv = min(s, cfg.window_size) if cfg.family == "hybrid" else s
        return 4.0 * b * kv * hdh * n_attn
    kv_per_q = min(s, cfg.window_size) if cfg.family == "hybrid" else s * 0.5
    base = 4.0 * b * s * kv_per_q * hdh * n_attn
    return base * (3.0 if shape.kind == "train" else 1.0)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = RESULTS_DIR, variant: str = "") -> dict:
    cfg = load_arch(arch)
    if "bf16gather" in variant:
        cfg = dataclasses.replace(cfg, bf16_param_gather=True)
    if "int8kv" in variant:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    shape = SHAPES[shape_name]
    ok, why = cell_is_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}"
    if variant:
        cell += f"__{variant}"
    result: dict = {"cell": cell, "arch": arch, "shape": shape_name,
                    "mesh": mesh_name, "applicable": ok}
    if not ok:
        result["skip_reason"] = why
        _write(out_dir, cell, result)
        return result

    if "tp8" in variant:
        # elastic re-mesh: same 256/512 chips factorized (data=32, model=8)
        # so model divides 40 q-heads and 8 kv-heads evenly
        shape_ = (2, 32, 8) if multi_pod else (32, 8)
        axes_ = ("pod", "data", "model") if multi_pod else ("data", "model")
        mesh = jax.make_mesh(shape_, axes_)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    # ZeRO-3 only where there is optimizer state to shard; serving keeps
    # weights TP-sharded without per-layer regathers
    rules = ShardRules(mesh, fsdp=(shape.kind == "train"),
                       seq_axis="model" if "sp" in variant else None,
                       fsdp_layer_dim=("fsdpL" in variant))
    t0 = time.time()
    with rules_scope(rules):
        step_fn, arg_shapes, shardings, donate = build_cell(cfg, shape, rules)
        jitted = jax.jit(step_fn, in_shardings=shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        # ---- global cost pass: unrolled layer scan, lowered only --------
        # HLO cost analysis counts while bodies ONCE; unrolling the layer
        # scan (trip count 1) makes it count every layer. Inner chunk
        # scans are corrected in closed form (exact dot-product bodies).
        t1 = time.time()
        cfg_u = dataclasses.replace(cfg, scan_unroll=max(cfg.n_layers, 1))
        fn_u, args_u, shard_u, don_u = build_cell(cfg_u, shape, rules)
        lowered_u = jax.jit(fn_u, in_shardings=shard_u,
                            donate_argnums=don_u).lower(*args_u)
        cost_u = lowered_u.cost_analysis()
        t_cost = time.time() - t1

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    cost_l = lowered.cost_analysis()
    n_dev = mesh.devices.size

    corr = inner_scan_flop_correction(cfg, shape)
    flops_global = (cost_u.get("flops") or 0.0) + corr
    # trip-ratio R scales the fused per-device bytes for loop trips
    rolled_flops_global = max(cost_l.get("flops") or 1.0, 1.0)
    r_trip = max(flops_global / rolled_flops_global, 1.0)
    bytes_dev = (cost.get("bytes accessed") or 0.0) * r_trip
    trips = max(cfg.n_layers // max(len(cfg.block_pattern), 1)
                if cfg.family == "hybrid" else cfg.n_layers, 1)
    coll_scaled = parse_collectives(compiled.as_text(), body_mult=trips)
    coll_scaled["wire_bytes_per_device_scaled"] = \
        coll_scaled.pop("wire_bytes_per_device")
    coll_once = parse_collectives(compiled.as_text(), body_mult=1)
    coll_scaled["wire_bytes_per_device"] = coll_once["wire_bytes_per_device"]

    result.update({
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "cost_pass_s": round(t_cost, 1),
        "n_devices": n_dev,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "cost": {"flops": cost.get("flops"),
                 "bytes_accessed": cost.get("bytes_accessed") or
                 cost.get("bytes accessed"),
                 "flops_global": flops_global,
                 "flops_unrolled_lowered": cost_u.get("flops"),
                 "inner_scan_correction": corr,
                 "trip_ratio": r_trip,
                 "bytes_per_device_scaled": bytes_dev},
        "collectives": coll_scaled,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "attn_model_flops": attn_model_flops(cfg, shape),
        "shape": dataclasses.asdict(shape),
    })
    _write(out_dir, cell, result)
    return result


def _write(out_dir: str, cell: str, result: dict) -> None:
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, cell + ".json"), "w") as f:
        json.dump(result, f, indent=1)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="",
                    help="optimization variant suffix (e.g. bf16gather)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for a in ARCH_IDS:
            for s in SHAPES:
                for mp in ((False, True) if args.mesh == "both" else
                           ((args.mesh == "multi"),)):
                    cells.append((a, s, mp))
    else:
        assert args.arch and args.shape
        for mp in ((False, True) if args.mesh == "both" else
                   ((args.mesh == "multi"),)):
            cells.append((args.arch, args.shape, mp))

    for a, s, mp in cells:
        mesh_name = "pod2x16x16" if mp else "pod16x16"
        suffix = f"__{args.variant}" if args.variant else ""
        path = os.path.join(args.out, f"{a}__{s}__{mesh_name}{suffix}.json")
        if args.skip_existing and os.path.exists(path):
            with open(path) as f:
                if "error" not in json.load(f):
                    print(f"[skip] {a} {s} {mesh_name}")
                    continue
        print(f"[cell] {a} {s} {mesh_name} ...", flush=True)
        try:
            r = run_cell(a, s, mp, args.out, args.variant)
            status = "SKIP " + r.get("skip_reason", "") if not r["applicable"] \
                else (f"ok compile={r['compile_s']}s "
                      f"flops={r['cost']['flops']:.3g} "
                      f"peak={r['memory']['peak_bytes']}")
            print(f"       {status}", flush=True)
        except Exception as e:                                   # noqa: BLE001
            print(f"       FAIL {type(e).__name__}: {e}", flush=True)
            _write(args.out, f"{a}__{s}__{mesh_name}{suffix}",
                   {"cell": f"{a}__{s}__{mesh_name}{suffix}", "error": str(e)})


if __name__ == "__main__":
    main()
