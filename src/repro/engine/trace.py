"""TraceEngine — the zero-FLOP cost probe.

Runs the MPC op stream of the unified forward under `jax.eval_shape`:
the Python protocol executes (so every `comm.record` fires with real
static shapes) but no array math does.  This is the probe the wave
executor used to improvise inline; it also prices *paper-scale*
geometries without materializing a single weight — `abstract_shares`
builds a ShapeDtypeStruct proxy pytree, so a BERT-scale per-batch
Ledger costs microseconds (benchmarks/table3_baselines.py). Both the
ring and the protocol backend are probe parameters: the same abstract
run prices the 2PC dealer stream (offline channel included) or the
3PC resharing stream.
"""
import contextlib
import functools

import jax

from repro.engine.forward import proxy_entropy
from repro.engine.mpc import MPCEngine
from repro.mpc import comm, fusion, protocols
from repro.mpc.comm import Ledger
from repro.mpc.ring import RING64, RingSpec, x64_scope
from repro.mpc.sharing import Share


class TraceEngine:
    """Probe engine: prices the MPC op stream via `probe()`. It does
    not execute forwards itself — attempting to use it as a tensor
    engine fails loudly rather than pretending to hold data."""

    kind = "trace"

    def __init__(self, ring: RingSpec = RING64, variant=None,
                 protocol: str = "2pc"):
        self.ring = ring
        self.variant = variant
        self.protocol = protocol

    def fused(self, label):
        """No-op: the probe prices through MPCEngine, which batches for
        itself; TraceEngine used directly has no wire to compress."""
        return contextlib.nullcontext()

    def probe(self, pp_sh, cfg, spec, batch_shape, key=None,
              variant=None, fused: bool = False) -> Ledger:
        """Ledger of ONE batch (B, S, d) of the share-level forward.

        `pp_sh` may hold real share arrays or ShapeDtypeStructs — both
        flow through eval_shape untouched.  `fused=True` probes the
        round-compressed stream (the op trace runs under
        `fusion.flight_scope`, exactly as the executor runs it).
        """
        ring = self.ring
        proto = self.protocol
        n_parties = protocols.get(proto).n_parties
        variant = self.variant if variant is None else variant
        key = jax.random.key(0) if key is None else key

        def fwd(pp, sh, k):
            eng = MPCEngine(ring=ring, variant=variant,
                            protocol=proto).with_key(k)
            with fusion.flight_scope(enabled=fused):
                return proxy_entropy(eng, pp, cfg, Share(sh, ring, proto),
                                     spec, variant).sh

        ctx = x64_scope() if ring.bits >= 64 else contextlib.nullcontext()
        with ctx, comm.ledger_scope() as led:
            jax.eval_shape(fwd, pp_sh,
                           jax.ShapeDtypeStruct(
                               (n_parties,) + tuple(batch_shape),
                               ring.dtype), key)
        return led

    def embed(self, pp, x_in, cfg):
        raise TypeError(
            "TraceEngine measures cost streams abstractly — call "
            "TraceEngine.probe(pp_sh, cfg, spec, batch_shape) instead of "
            "running a forward through it; use ClearEngine/MPCEngine to "
            "execute")


@functools.lru_cache(maxsize=256)
def _cached_probe(cfg, spec, seq: int, classes: int, batch: int,
                  ring: RingSpec, protocol: str, fused: bool,
                  variant) -> Ledger:
    pp_sh = abstract_shares(cfg, spec, seq, classes, ring, protocol)
    return TraceEngine(ring, variant, protocol=protocol).probe(
        pp_sh, cfg, spec, (batch, seq, cfg.d_model), fused=fused)


def cached_probe(cfg, spec, *, batch: int, seq: int, classes: int,
                 ring: RingSpec, protocol: str = "2pc",
                 fused: bool = False, variant=None) -> Ledger:
    """Per-batch probe ledger, memoized on the full probe geometry
    (arch, proxy, batch/seq/classes, ring, protocol, fused, variant).

    A probe costs ~1 s of abstract tracing and the same geometry is
    re-probed per profile sweep / per executed phase — this cache turns
    repeats into microseconds. `ArchConfig`/`ProxySpec`/`RingSpec` are
    frozen (hashable) and the probe key is irrelevant under eval_shape,
    so the memo is sound. Returns a fresh shallow copy so callers may
    extend/mutate their ledger without corrupting the cache."""
    led = _cached_probe(cfg, spec, seq, classes, batch, ring, protocol,
                        fused, variant)
    out = Ledger()
    out.records.extend(led.records)
    return out


def cached_probe_info():
    """lru cache stats for the shared probe memo (hits/misses)."""
    return _cached_probe.cache_info()


def abstract_shares(cfg, spec, seq_len: int, n_classes: int,
                    ring: RingSpec = RING64, protocol: str = "2pc"):
    """ShapeDtypeStruct pytree shaped like `proxy.share_proxy`'s output
    (minus the embedding table, which the MPC forward never touches) —
    lets `TraceEngine.probe` price paper-scale proxies for free. The
    leading party-axis size comes from the protocol backend."""
    dh, w = cfg.d_head, spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    L, hid = spec.n_layers, spec.mlp_dim
    p = protocols.get(protocol).n_parties

    def sh(*shape):
        return Share(jax.ShapeDtypeStruct((p,) + shape, ring.dtype), ring,
                     protocol)

    def mlp(d_in, d_out):
        return {"w1": sh(d_in, hid), "b1": sh(hid),
                "w2": sh(hid, d_out), "b2": sh(d_out)}

    return {
        "cls_head": sh(cfg.d_model, n_classes),
        "attn": {
            "wq": sh(L, cfg.d_model, w * dh),
            "wk": sh(L, cfg.d_model, wk * dh),
            "wv": sh(L, cfg.d_model, wk * dh),
            "wo": sh(L, w * dh, cfg.d_model),
        },
        "ln_scale": sh(L, cfg.d_model),
        "ln_bias": sh(L, cfg.d_model),
        "mlp_sm": [mlp(seq_len, seq_len) for _ in range(L)],
        "mlp_ln": [mlp(1, 1) for _ in range(L)],
        "mlp_se": mlp(n_classes, 1),
    }
