"""Unified tensor-engine API: one proxy forward, many execution
substrates (clear floats / MPC shares / eval_shape cost tracing).

    from repro.engine import ClearEngine, MPCEngine, proxy_entropy
    ent = proxy_entropy(ClearEngine(), pp, cfg, tokens, spec)
    ent_sh = proxy_entropy(MPCEngine(ring).with_key(k), pp_sh, cfg,
                           x_shared, spec)

See engine/base.py for the protocol and README "Engine API" for how to
add a backend.
"""
from repro.engine.base import (FULL_VARIANT, VARIANTS, TensorEngine,
                               resolve_engine, resolve_variant)
from repro.engine.clear import ClearEngine
from repro.engine.forward import proxy_entropy, proxy_logits
from repro.engine.mpc import MPCEngine
from repro.engine.trace import (TraceEngine, abstract_shares, cached_probe,
                                cached_probe_info)

__all__ = ["FULL_VARIANT", "VARIANTS", "TensorEngine", "resolve_engine",
           "resolve_variant", "ClearEngine", "MPCEngine", "TraceEngine",
           "abstract_shares", "cached_probe", "cached_probe_info",
           "proxy_entropy", "proxy_logits"]
