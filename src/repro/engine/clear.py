"""ClearEngine — float (jnp) interpretation of the proxy forward.

The numerical reference and the training substrate: in-vivo finetuning
differentiates straight through it.  Nonlinearity strategies implement
the Table-2 ablations (exact softmax / rsqrt / entropy when the MLP
emulator is ablated) and the Table-3 baseline softmaxes (MPCFormer
2Quad, Bolt-style polynomial exp).
"""
import contextlib
import dataclasses
from typing import ClassVar

import jax
import jax.numpy as jnp

from repro.engine.forward import _mlp_at


def mlp_apply(p, x):
    """Clear 2-layer emulator MLP (Linear -> ReLU -> Linear).

    Canonical home of the clear apply path (core/approx re-exports it);
    the share-level twin lives in engine/mpc.mlp_apply_mpc.
    """
    h = jax.nn.relu(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def softmax_entropy(logits):
    """Exact fused softmax+entropy (the op MLP_se emulates)."""
    p = jax.nn.softmax(logits, axis=-1)
    return -jnp.sum(p * jnp.log(p + 1e-9), axis=-1, keepdims=True)


@dataclasses.dataclass(frozen=True)
class ClearEngine:
    """Stateless (hashable, jit-closure friendly) float engine."""

    variant: frozenset | None = None     # default nonlinearity policy
    kind: ClassVar[str] = "clear"

    # -- data entry ------------------------------------------------------
    def embed(self, pp, x_in, cfg):
        if jnp.issubdtype(jnp.asarray(x_in).dtype, jnp.floating):
            return x_in                  # pre-embedded activations
        x = jnp.take(pp["embed"], x_in, axis=0).astype(jnp.float32)
        return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)

    # -- round compression (no wire: nothing to fuse) --------------------
    def fused(self, label):
        return contextlib.nullcontext()

    # -- linear algebra --------------------------------------------------
    def add(self, x, y):
        return x + y

    def sub(self, x, y):
        return x - y

    def mul(self, x, y):
        return x * y

    def mul_public(self, x, v):
        return x * v

    def add_public(self, x, v):
        return x + v

    def matmul(self, x, y):
        return jnp.matmul(x, y)

    def mean(self, x, axis):
        return jnp.mean(x, axis=axis)

    # -- shape ops -------------------------------------------------------
    def shape(self, x):
        return tuple(x.shape)

    def reshape(self, x, shape):
        return jnp.reshape(x, shape)

    def broadcast(self, x, shape):
        return jnp.broadcast_to(x, shape)

    def moveaxis(self, x, src, dst):
        return jnp.moveaxis(x, src, dst)

    def swapaxes(self, x, a, b):
        return jnp.swapaxes(x, a, b)

    def index(self, x, i):
        return x[i]

    # -- nonlinearity strategies -----------------------------------------
    def mlp(self, p, x):
        return mlp_apply(p, x)

    def ln_inv(self, pp, li, var, variant):
        if "ln" in variant:
            return self.mlp(_mlp_at(pp["mlp_ln"], li), var)
        return jax.lax.rsqrt(var + 1e-5)

    def attn_probs(self, pp, li, scores, variant):
        """Rows (N, S) -> attention probabilities (N, S)."""
        if "sm" in variant:
            return self.mlp(_mlp_at(pp["mlp_sm"], li), scores)
        if "quad_sm" in variant:         # MPCFormer 2Quad
            e = (scores + 5.0) ** 2
            return e / jnp.maximum(e.sum(-1, keepdims=True), 1e-6)
        if "poly_sm" in variant:         # Bolt-style polynomial exp
            t = jnp.clip(scores - scores.max(-1, keepdims=True), -8, 0)
            e = 1 + t + t * t / 2 + t ** 3 / 6 + t ** 4 / 24
            e = jnp.maximum(e, 0.0)
            return e / jnp.maximum(e.sum(-1, keepdims=True), 1e-6)
        return jax.nn.softmax(scores, axis=-1)

    def entropy_head(self, pp, logits, variant):
        if "se" in variant:
            return self.mlp(pp["mlp_se"], logits)[:, 0]
        return softmax_entropy(logits)[:, 0]
