"""TensorEngine — one proxy forward, many execution substrates.

The paper's core claim is that the *same* proxy network runs both in the
clear (in-vivo training, efficacy numbers) and over MPC (the private
sieve).  This module makes that claim true BY CONSTRUCTION: the proxy
layer math exists exactly once (`engine/forward.py`), written against the
`TensorEngine` protocol below, and an engine only interprets the
primitive ops over its own tensor type:

  ClearEngine   jnp float arrays (engine/clear.py)
  MPCEngine     additive shares over a RingSpec, PRNG keys threaded
                internally (engine/mpc.py)
  TraceEngine   the jax.eval_shape cost probe — runs the MPC op stream
                abstractly so every comm.record fires with real shapes
                but zero FLOPs execute (engine/trace.py)

Adding a substrate (a ring, a sharing scheme, a cost-tracing variant)
never rewrites the forward: rings and protocol backends (additive-2PC
dealer / replicated-3PC, `mpc/protocols/`) are MPCEngine parameters,
and a genuinely new tensor type is a ~100-line engine implementation —
the dispatch-layer move MPC frameworks like CrypTen make with their
tensor stack.

Nonlinearity policy: the Table-2/Table-3 `variant` sets are engine-level
strategies.  A variant is a frozenset naming which nonlinearities use
MLP emulators ("sm", "ln", "se"); absent members fall back to the exact
op on BOTH substrates (secure softmax / NR-rsqrt / secure entropy over
MPC), and "quad_sm" / "poly_sm" select the MPCFormer-2Quad and
Bolt-polynomial softmax baselines.
"""
from typing import Any, Protocol, runtime_checkable

Tensor = Any          # opaque: jnp array (clear) or AShare (mpc/trace)

FULL_VARIANT = frozenset({"sm", "ln", "se"})

# Named variant sets: Table 2 ablations + Table 3 baseline nonlinearities.
VARIANTS = {
    "full": FULL_VARIANT,
    "no-sm": frozenset({"ln", "se"}),
    "no-ln": frozenset({"sm", "se"}),
    "no-se": frozenset({"sm", "ln"}),
    "quad_sm": frozenset({"quad_sm", "ln", "se"}),      # MPCFormer 2Quad
    "poly_sm": frozenset({"poly_sm", "ln", "se"}),      # Bolt polynomial
}


@runtime_checkable
class TensorEngine(Protocol):
    """The op vocabulary `engine/forward.py` is written against.

    Tensors are opaque; parameters arrive engine-native (float leaves
    for ClearEngine, AShare leaves from `proxy.share_proxy` for
    MPCEngine).  Engines that need per-op randomness (Beaver openings,
    dealer truncation) thread PRNG keys internally — callers never
    split keys.
    """

    kind: str                     # "clear" | "mpc" | "trace"

    # -- data entry ------------------------------------------------------
    def embed(self, pp, x_in, cfg) -> Tensor: ...

    # -- linear algebra --------------------------------------------------
    def add(self, x: Tensor, y: Tensor) -> Tensor: ...
    def sub(self, x: Tensor, y: Tensor) -> Tensor: ...
    def mul(self, x: Tensor, y: Tensor) -> Tensor: ...
    def mul_public(self, x: Tensor, v) -> Tensor: ...
    def add_public(self, x: Tensor, v) -> Tensor: ...
    def matmul(self, x: Tensor, y: Tensor) -> Tensor: ...
    def mean(self, x: Tensor, axis: int) -> Tensor: ...

    # -- round compression ----------------------------------------------
    def fused(self, label: str):
        """Context manager marking a group of independent ops whose
        openings may ride one wire flight (mpc/fusion.py). Substrates
        without a wire (clear/trace) treat it as a no-op, preserving the
        single-forward invariant."""
        ...

    # -- shape ops (local, free on every substrate) ----------------------
    def shape(self, x: Tensor) -> tuple: ...
    def reshape(self, x: Tensor, shape) -> Tensor: ...
    def broadcast(self, x: Tensor, shape) -> Tensor: ...
    def moveaxis(self, x: Tensor, src: int, dst: int) -> Tensor: ...
    def swapaxes(self, x: Tensor, a: int, b: int) -> Tensor: ...
    def index(self, x: Tensor, i: int) -> Tensor: ...

    # -- nonlinearity strategies (variant-dispatched) --------------------
    def mlp(self, p, x: Tensor) -> Tensor: ...
    def ln_inv(self, pp, li: int, var: Tensor, variant) -> Tensor: ...
    def attn_probs(self, pp, li: int, scores: Tensor, variant) -> Tensor: ...
    def entropy_head(self, pp, logits: Tensor, variant) -> Tensor: ...


def resolve_engine(engine, ring=None, protocol: str = "2pc") -> "TensorEngine":
    """Engine instance from an instance (pass-through) or a mode string
    ("clear" / "mpc" / "trace" — the legacy `SelectionConfig.mode`).
    `protocol` picks the secret-sharing backend for the MPC substrates
    ("2pc" additive+dealer / "3pc" replicated, dealer-free)."""
    if not isinstance(engine, str):
        return engine
    from repro.engine.clear import ClearEngine
    from repro.engine.mpc import MPCEngine
    from repro.engine.trace import TraceEngine
    from repro.mpc.ring import RING64
    ring = RING64 if ring is None else ring
    if engine == "clear":
        return ClearEngine()
    if engine == "mpc":
        return MPCEngine(ring=ring, protocol=protocol)
    if engine == "trace":
        return TraceEngine(ring=ring, protocol=protocol)
    raise ValueError(f"unknown engine {engine!r} "
                     "(expected 'clear', 'mpc', 'trace', or an instance)")


def resolve_variant(engine, variant) -> frozenset:
    """Per-call variant > engine default > full MLP emulation; strings
    name entries of VARIANTS."""
    if variant is None:
        variant = getattr(engine, "variant", None)
    if variant is None:
        return FULL_VARIANT
    if isinstance(variant, str):
        return VARIANTS[variant]
    return frozenset(variant)
