"""THE proxy forward (paper §4.2/§4.3) — written once, engine-generic.

Every execution substrate runs this exact function: the clear float
path (in-vivo training, efficacy numbers), the share-level MPC path
(the private sieve, driven by the wave executor), and the eval_shape
cost probe.  Clear/MPC parity is structural, not maintained by
discipline: there is no second copy of the layer math to drift.

The op order below is load-bearing for the accounting contract: the
MPC op stream it induces is mirrored record-for-record by
`mpc/costs.proxy_exec_cost`, and the wave executor's realized flight
ledger must reproduce that stream exactly (`iosched.ledger_agrees`).
Reorder ops here and the mirror test tells you immediately.  The
`eng.fused(label)` groups are part of that contract too: under a
`fusion.flight_scope` they bound the fused flights, and the analytic
mirror places its GroupBegin/GroupEnd markers at the same spots —
move a group here and `proxy_exec_cost(fused=True)` must move with it.
"""
import jax

from repro.engine.base import resolve_variant


def _mlp_at(mlps, li: int):
    """Per-layer MLP params: lists index directly; stacked trees slice."""
    if isinstance(mlps, (list, tuple)):
        return mlps[li]
    return jax.tree.map(lambda a: a[li], mlps)


def _proxy_layer(eng, x, pp, li, cfg, spec, variant):
    """One proxy block: MLP-LayerNorm -> pruned attention -> residual."""
    dh = cfg.d_head
    w = spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    g = w // wk
    b, s, d = eng.shape(x)
    # MLP-LayerNorm: numerator exact, reciprocal-sqrt emulated ("ln").
    # The stat openings (the variance Beaver open plus whatever forced
    # truncations the scale lattice fires — pow2 means fold for free)
    # form one fused flight under a flight_scope — `eng.fused` is a
    # no-op on wireless substrates, so clear/MPC parity is untouched.
    with eng.fused("ln_stats"):
        mu = eng.mean(x, axis=-1)
        xc = eng.sub(x, eng.broadcast(eng.reshape(mu, (b, s, 1)), (b, s, d)))
        var = eng.mean(eng.mul(xc, xc), axis=-1)
    inv = eng.ln_inv(pp, li, eng.reshape(var, (b * s, 1)), variant)
    h = eng.mul(xc, eng.broadcast(eng.reshape(inv, (b, s, 1)), (b, s, d)))
    gamma = eng.reshape(eng.index(pp["ln_scale"], li), (1, 1, d))
    h = eng.mul(h, eng.broadcast(gamma, (b, s, d)))
    beta = eng.reshape(eng.index(pp["ln_bias"], li), (1, 1, d))
    h = eng.add(h, eng.broadcast(beta, (b, s, d)))
    # pruned attention: per-projection matmuls, GQA head grouping. The
    # three projections consume the same input and nothing of each other
    # — the canonical independent group, one (eps, delta) flight for all
    # three; the shared input's forced truncation (ops.force memo) is
    # paid once and rides the same flight.
    ap = pp["attn"]
    h2 = eng.reshape(h, (b * s, d))
    with eng.fused("qkv"):
        q = eng.matmul(h2, eng.index(ap["wq"], li))
        k = eng.matmul(h2, eng.index(ap["wk"], li))
        v = eng.matmul(h2, eng.index(ap["wv"], li))
    if "bq" in ap:
        q = eng.add(q, eng.broadcast(eng.index(ap["bq"], li), (b * s, w * dh)))
        k = eng.add(k, eng.broadcast(eng.index(ap["bk"], li),
                                     (b * s, wk * dh)))
        v = eng.add(v, eng.broadcast(eng.index(ap["bv"], li),
                                     (b * s, wk * dh)))
    # scores per (batch, kv-head, group): fold heads into batch dims
    qT = eng.moveaxis(eng.reshape(q, (b, s, wk, g, dh)), 1, 3)  # b wk g s dh
    kT = eng.swapaxes(eng.moveaxis(eng.reshape(k, (b, s, wk, dh)), 2, 1),
                      -1, -2)                                    # b wk dh s
    kT = eng.broadcast(eng.reshape(kT, (b, wk, 1, dh, s)), (b, wk, g, dh, s))
    scores = eng.mul_public(eng.matmul(qT, kT), dh ** -0.5)      # b wk g s s
    probs = eng.attn_probs(pp, li, eng.reshape(scores, (b * wk * g * s, s)),
                           variant)
    probs = eng.reshape(probs, (b, wk, g, s, s))
    vT = eng.moveaxis(eng.reshape(v, (b, s, wk, dh)), 2, 1)      # b wk s dh
    vT = eng.broadcast(eng.reshape(vT, (b, wk, 1, s, dh)), (b, wk, g, s, dh))
    o = eng.matmul(probs, vT)                                    # b wk g s dh
    o2 = eng.reshape(eng.moveaxis(o, 3, 1), (b * s, w * dh))
    out = eng.matmul(o2, eng.index(ap["wo"], li))
    return eng.add(x, eng.reshape(out, (b, s, d)))


def proxy_logits(eng, pp, cfg, x_in, spec, variant=None):
    """Proxy classifier logits: embed -> l pruned blocks -> mean-pool."""
    variant = resolve_variant(eng, variant)
    x = eng.embed(pp, x_in, cfg)
    for li in range(spec.n_layers):
        x = _proxy_layer(eng, x, pp, li, cfg, spec, variant)
    pooled = eng.mean(x, axis=1)
    return eng.matmul(pooled, pp["cls_head"])


def proxy_entropy(eng, pp, cfg, x_in, spec, variant=None):
    """Per-example entropy score — the sieve's ranking signal.

    `x_in` is engine-native input: token ids for ClearEngine (it owns
    the embedding lookup), shared embedded activations (B, S, d) for
    MPCEngine (the data owner shares one-hot rows; the embedding matmul
    is folded into share generation, priced by costs.py).
    """
    variant = resolve_variant(eng, variant)
    logits = proxy_logits(eng, pp, cfg, x_in, spec, variant)
    return eng.entropy_head(pp, logits, variant)
