"""MPCEngine — share-level interpretation of the proxy forward.

Tensors are `Share`s over a `RingSpec` and a protocol backend
(`mpc/protocols/`): RING64 and RING32 share this one code path, and so
do the additive-2PC (trusted-dealer Beaver) and replicated-3PC
(dealer-free resharing) protocols — the ring decides the truncation
arithmetic, the backend decides the sharing scheme and what lands in
the cost Ledger. All six variant strategies run bitwise-reproducibly on
every (ring, protocol) combination: the op stream is fixed by
`engine/forward.py` and keys derive deterministically below.

Fixed-point scale flows through the forward as share metadata
(`Share.fb`, mpc/scale.py): products ride at 2f, pow2 rescales fold
free, and forced truncations fire only where the lattice demands —
the engine adds exactly one boundary rule of its own, `entropy_head`
returns CANONICAL-scale scores (QuickSelect and appraisal consume
them as public-contract fb == frac_bits).

PRNG keys are threaded internally: the engine is seeded once per
forward (`with_key`) and derives one key per keyed op site by folding
an op counter.  The op sequence is fixed by `engine/forward.py`, so the
derived key stream is deterministic — the wave executor's schedule
variants (vmapped wave vs per-lane serial) see identical keys and
therefore produce bitwise-identical shares.

Exact-op variant strategies (softmax / rsqrt / entropy when the MLP
emulator is ablated, plus the 2Quad and polynomial baseline softmaxes)
run the real CrypTen-style protocols from `mpc/nonlinear.py` — this is
what lets Table 3's baselines be *executed* over MPC, not only priced.
"""
import jax
import jax.numpy as jnp

from repro.engine.forward import _mlp_at
from repro.mpc import compare, fusion, nonlinear, protocols, ops as mops
from repro.mpc.ring import RING64, RingSpec
from repro.mpc.sharing import Share


def _ax(axis: int) -> int:
    """Value axis -> share-array axis (leading party axis)."""
    return axis + 1 if axis >= 0 else axis


def mlp_apply_mpc(p_sh: dict, x: Share, key) -> Share:
    """Share-level emulator MLP: weights are model-owner-private shares.

    Cost: 2 secure matmuls (1 round each) + ReLU over `hidden` elements
    only — the dimension reduction the paper's MPC savings come from.
    Canonical home of the share-level apply path; the clear twin lives
    in engine/clear.mlp_apply.
    """
    def _badd(h: Share, b: Share) -> Share:
        # build the broadcast from b (it carries b's exponent — h may
        # ride at 2f, and add() lifts the bias to match, exactly)
        bb = b.with_sh(jnp.broadcast_to(b.sh[:, None, :], h.sh.shape))
        return mops.add(h, bb)

    k1, k2, k3 = jax.random.split(key, 3)
    h = mops.matmul(x, p_sh["w1"], k1)
    h = _badd(h, p_sh["b1"])
    h = compare.relu(h, k2)
    out = mops.matmul(h, p_sh["w2"], k3)
    return _badd(out, p_sh["b2"])


class MPCEngine:
    kind = "mpc"

    def __init__(self, ring: RingSpec = RING64, variant=None, key=None,
                 combine_impl: str = "auto", protocol: str = "2pc"):
        self.ring = ring
        self.variant = variant
        self._key = key
        self._ctr = 0
        # protocol backend: "2pc" (additive + trusted dealer), "3pc"
        # (replicated 2-of-3, dealer-free), "spdz2pc" (malicious, MAC'd)
        # or "aby3trunc" (3pc + exact trunc2) — mpc/protocols/
        self.protocol = protocol
        self.backend = protocols.get(protocol)
        # Beaver post-open combine for 2-D RING32 2PC matmuls: the fused
        # Pallas secure_matmul kernel ("auto" = compiled on TPU, jnp
        # reference elsewhere; "interpret" exercises the kernel body on
        # CPU). Bitwise-identical wrapping int32 arithmetic either way.
        self.combine_impl = combine_impl

    def with_key(self, key) -> "MPCEngine":
        """Fresh engine seeded for one forward (keys derive from here)."""
        return MPCEngine(self.ring, self.variant, key=key,
                         combine_impl=self.combine_impl,
                         protocol=self.protocol)

    def fused(self, label: str):
        """Mark a group of independent ops: their openings/reshares ride
        one flight under an ambient `fusion.flight_scope` (no-op
        eagerly)."""
        return fusion.fused_group(label)

    def _k(self):
        if self._key is None:
            raise ValueError("MPCEngine needs a PRNG seed: call "
                             "engine.with_key(key) before the forward")
        k = jax.random.fold_in(self._key, self._ctr)
        self._ctr += 1
        return k

    # -- data entry ------------------------------------------------------
    def embed(self, pp, x_in, cfg):
        if not isinstance(x_in, Share):
            raise TypeError(
                "MPCEngine consumes shared embedded inputs (B, S, d): the "
                "data owner shares one-hot rows and the embedding matmul "
                "is folded into share generation (see mpc/sharing.share)")
        if x_in.proto != self.protocol:
            raise ValueError(
                f"engine protocol {self.protocol!r} but input shares are "
                f"{x_in.proto!r} — share the inputs with "
                f"share(..., proto={self.protocol!r})")
        return x_in

    # -- linear algebra --------------------------------------------------
    # add/sub get a key: exponent alignment is usually an exact lift,
    # but a pow2-folded operand above the 2f cap (layer>=2 mean vs the
    # 2f residual) must down-trunc EXACTLY — keyless local shifts wrap
    # too often at fb > 2f on the 32-bit ring
    def add(self, x, y):
        return mops.add(x, y, key=self._k())

    def sub(self, x, y):
        return mops.sub(x, y, key=self._k())

    def mul(self, x, y):
        return mops.mul(x, y, self._k())

    def mul_public(self, x, v):
        return mops.mul_public(x, v, key=self._k())

    def add_public(self, x, v):
        return mops.add_public(x, v)

    def matmul(self, x, y):
        combine = self.combine_impl \
            if self.ring.bits == 32 and self.protocol == "2pc" else None
        return mops.matmul(x, y, self._k(), combine_impl=combine)

    def mean(self, x, axis):
        return mops.mean(x, axis=axis, key=self._k())

    # -- shape ops (local on shares) -------------------------------------
    # Layout ops go through Share.derive: they are scale-preserving AND
    # remember their source, so a forced truncation on (say) a broadcast
    # inverse-std fires on the small pre-broadcast tensor and the free
    # layout replays — fewer dealer trunc-pair bytes for the same event.
    def shape(self, x):
        return x.shape

    def reshape(self, x, shape):
        return x.reshape(*shape)

    def broadcast(self, x, shape):
        # right-align the VALUE dims under the leading party axis: a
        # (P, n)-share broadcast to value shape (rows, n) must become
        # (P, 1, n) first, or the party axis would be matched against a
        # value dim (the attention-bias path hits exactly this)
        shape = tuple(shape)
        pad = len(shape) - x.ndim
        val_shape = x.shape

        def fn(sh):
            sh = sh.reshape((sh.shape[0],) + (1,) * pad + val_shape)
            return jnp.broadcast_to(sh, (sh.shape[0],) + shape)

        return x.derive(fn)

    def moveaxis(self, x, src, dst):
        return x.derive(lambda sh: jnp.moveaxis(sh, _ax(src), _ax(dst)))

    def swapaxes(self, x, a, b):
        return x.derive(lambda sh: jnp.swapaxes(sh, _ax(a), _ax(b)))

    def index(self, x, i):
        # no lineage: forcing a slice must not truncate the whole source
        return x.with_sh(x.sh[:, i])

    # -- nonlinearity strategies -----------------------------------------
    def mlp(self, p, x):
        return mlp_apply_mpc(p, x, self._k())

    def ln_inv(self, pp, li, var, variant):
        if "ln" in variant:
            return self.mlp(_mlp_at(pp["mlp_ln"], li), var)
        return nonlinear.rsqrt(mops.add_public(var, 1e-5), self._k())

    def attn_probs(self, pp, li, scores, variant):
        if "sm" in variant:
            return self.mlp(_mlp_at(pp["mlp_sm"], li), scores)
        if "quad_sm" in variant:
            return self._quad_softmax(scores)
        if "poly_sm" in variant:
            return self._poly_softmax(scores)
        return nonlinear.softmax(scores, self._k(), axis=-1)

    def entropy_head(self, pp, logits, variant):
        """Entropy scores, forced to CANONICAL scale: the forward's
        public boundary. Downstream consumers (QuickSelect ranking,
        appraisal means, decode-at-f callers) see fb == frac_bits."""
        b = logits.shape[0]
        if "se" in variant:
            out = self.mlp(pp["mlp_se"], logits).reshape(b)
        else:
            out = nonlinear.entropy_from_logits(logits, self._k())
        out = mops.force(out, self._k())
        # Malicious backends (spdz2pc) verify every partial opening of
        # the forward with ONE batched MAC check at this boundary — the
        # constant-size flight that makes the whole forward abort on
        # tamper. Semi-honest backends have no hook; nothing fires.
        check = getattr(self.backend, "mac_check_flight", None)
        if check is not None:
            check(self.ring)
        return out

    # -- Table-3 baseline softmaxes over shares --------------------------
    def _quad_softmax(self, scores):
        """MPCFormer 2Quad: (x+5)^2 / sum — square + NR reciprocal."""
        a = mops.add_public(scores, 5.0)
        e = mops.mul(a, a, self._k())
        s = mops.sum_(e, axis=-1, keepdims=True)
        # clamp mirroring the clear strategy's max(sum, 1e-6): keeps the
        # NR reciprocal away from a near-zero pole when every score in a
        # row sits near -5
        s = mops.add_public(s, 1e-6)
        r = nonlinear.reciprocal(s, self._k())
        rb = r.with_sh(jnp.broadcast_to(r.sh, e.sh.shape))
        return mops.mul(e, rb, self._k())

    def _poly_softmax(self, scores):
        """Bolt-style polynomial exp of clipped, max-shifted scores.

        clip(t, -8, 0) over shares: max(t,-8) = relu(t+8)-8, then
        min(u,0) = u - relu(u) — two comparisons per element, matching
        the baseline's real MPC cost profile. The comparisons are
        scale-invariant and their bits multiply at exponent 0, so the
        whole clip chain rides at the scores' carried exponent without
        a single truncation.
        """
        mx = compare.max_(scores, axis=-1, key=self._k())
        mb = mx.with_sh(jnp.broadcast_to(mx.sh, scores.sh.shape))
        # keyed subs: the max-shift may align carried exponents DOWN a
        # real truncation (keyless degrades to the local-shift path —
        # absent for MAC'd shares, wrap-prone on RING32)
        t = mops.sub(scores, mb, key=self._k())
        lo = mops.add_public(compare.relu(mops.add_public(t, 8.0), self._k()),
                             -8.0)
        t = mops.sub(lo, compare.relu(lo, self._k()), key=self._k())
        # Horner: e = 1 + t(1 + t(1/2 + t(1/6 + t/24))) — one fused
        # flight: every message is a mask component, the public parts of
        # the chained openings reconstruct locally (fusion.py legality).
        # Scale carrying does the cross-op trunc folding here: each
        # step's product emits at 2f and the next mul's headroom plan
        # forces exactly one trunc — the PendingShare choreography this
        # chain used to motivate is gone.
        with fusion.fused_group("horner"):
            acc = mops.add_public(mops.mul_public(t, 1.0 / 24.0,
                                                  key=self._k()), 1.0 / 6.0)
            acc = mops.add_public(mops.mul(t, acc, self._k()), 0.5)
            acc = mops.add_public(mops.mul(t, acc, self._k()), 1.0)
            e = mops.add_public(mops.mul(t, acc, self._k()), 1.0)
        e = compare.relu(e, self._k())
        s = mops.sum_(e, axis=-1, keepdims=True)
        r = nonlinear.reciprocal(s, self._k())
        rb = r.with_sh(jnp.broadcast_to(r.sh, e.sh.shape))
        return mops.mul(e, rb, self._k())
