"""PartyRuntime: execute a captured flight plan as real parties.

The MPC engine computes every party's share components in one process
(the simulation layout of mpc/sharing.py) while `comm.WireTape` captures
each online flight's actual point-to-point messages. This module closes
the loop: it compiles the tape into one flight plan PER PARTY and runs
one worker per party — threads over a `LocalTransport` (`mode="local"`,
deterministic) or spawned processes over `SocketTransport` meshes
(`mode="socket"`, paced + latency-injected localhost TCP) — so every
recorded flight becomes an actual framed exchange.

What executing the plan proves, per run:

  bytes    transport-counted GOODPUT bytes == the tape's (== the
           ledger's) `nbytes`, link by link — `reconcile()` and the
           post-run check both fail loudly on divergence. Chaos
           recovery traffic (retransmissions, ACKs) is counted on a
           separate RETRANS channel and never bends this match;
  content  each party chain-digests every payload it receives, in
           order (state = BLAKE2b(state || payload)); the final states
           must match what the tape says it should receive. The chain
           form makes the digest CHECKPOINTABLE — a crashed party
           resumes it from its flight cursor;
  time     `wire_makespan_s` is measured wall-clock between the SYNC
           start barrier and the last party finishing.

Fault tolerance (opt-in, `reliable=True` / `fault_plan=` / `recover=`):

  * `transport.ReliableTransport` gives every link sequenced,
    deduplicated, retransmitting delivery — dropped frames and
    connection resets (injected by `faults.ChaosTransport` or real)
    heal under the flight plan without changing its semantics.
  * every party commits a durable flight cursor (atomic write +
    COMMIT file, the `checkpoint/ckpt.py` discipline) after each
    flight, then cumulatively ACKs — peers prune their resend buffers
    only up to committed state, so anything a crashed party may need
    again is still buffered somewhere.
  * a supervisor watches socket-mode children: a dead process (or a
    live one whose cursor stops advancing past the heartbeat window —
    the `ft.HeartbeatMonitor` escalation path) is declared dead and
    respawned; the new incarnation restores its cursor (flight index,
    digest chain state, per-link sequence/goodput watermarks), skips
    the start barrier, reconnects the mesh and replays from its last
    committed flight. Re-sent flights dedup at the receivers;
    re-counted bytes land in goodput exactly once across incarnations.
  * degraded mode (`degraded=True`, 3-party tapes): a party dead at a
    phase boundary (nothing committed) is dropped instead of
    respawned — survivors rerun the tape filtered of the dead party's
    links, completing 2-of-3, and the report says so.

Liveness rides along: workers emit BEAT frames to party 0 every
`beat_every` flights and party 0 drains them into a
`runtime.ft.HeartbeatMonitor` (via `ft.TransportHeartbeat`), so the
fault-tolerance heartbeat path is exercised by the same wire as the
protocol traffic.

Deadlock-freedom: every party walks the SAME tape in the same flight
order, sends are non-blocking enqueues, and multi-sub-round flights
(comparisons, ABY3 trunc2) order their dependent messages via the
WireMsg `rnd` field — a party never blocks on a message whose sender
has not already been able to enqueue it.
"""
from __future__ import annotations

import copy
import dataclasses
import hashlib
import json
import multiprocessing
import os
import tempfile
import threading
import time
import zlib

from repro.net import faults as fx
from repro.net import transport as tp
from repro.runtime import ft

# flights between BEAT frames (and beat-queue drains on party 0)
DEFAULT_BEAT_EVERY = 8
# socket-mode exit code for an injected hard crash (os._exit)
CRASH_EXIT = 77
MAX_RESPAWNS = 2


# ---------------------------------------------------------------------------
# plan compilation — tape -> per-party send/recv schedule
# ---------------------------------------------------------------------------
# A plan is pickle-plain (lists/tuples/bytes/ints) because socket-mode
# children receive theirs through multiprocessing spawn args:
#   plan   = [flight, ...]
#   flight = [(sends, recvs), ...]      one entry per sub-round, in order
#   sends  = [(dst, payload_bytes), ...]
#   recvs  = [(src, expected_nbytes), ...]

def compile_plan(tape, party: int) -> list:
    plan = []
    for f in tape.flights:
        rounds = sorted({m.rnd for m in f.msgs}) or [0]
        subs = []
        for r in rounds:
            sends = [(m.dst, m.data) for m in f.msgs
                     if m.rnd == r and m.src == party]
            recvs = [(m.src, len(m.data)) for m in f.msgs
                     if m.rnd == r and m.dst == party]
            subs.append((sends, recvs))
        plan.append(subs)
    return plan


def _chain(state: bytes, payload: bytes) -> bytes:
    return hashlib.blake2b(state + payload, digest_size=16).digest()


def expected_digests(tape, n_parties: int) -> list[str]:
    """Per-party chained BLAKE2b over every payload the party receives,
    in the order the party loop receives them — the content half of the
    reconciliation contract. Chained (state = H(state || payload))
    rather than streamed so a party's digest state is a 16-byte value
    that checkpoints into the flight cursor and survives a crash."""
    states = [b"" for _ in range(n_parties)]
    for f in tape.flights:
        for r in sorted({m.rnd for m in f.msgs} or {0}):
            for m in f.msgs:
                if m.rnd == r:
                    states[m.dst] = _chain(states[m.dst], m.data)
    return [s.hex() for s in states]


def filter_tape(tape, dead: int):
    """The degraded 2-of-3 tape: every message to or from the dead
    party removed, flight structure (count, ops, sub-rounds) intact.
    Surviving parties replay THIS tape; byte/digest reconciliation
    holds against its totals."""
    t2 = copy.copy(tape)
    t2.flights = []
    for f in tape.flights:
        kept = tuple(m for m in f.msgs if dead not in (m.src, m.dst))
        t2.flights.append(dataclasses.replace(
            f, msgs=kept, nbytes=sum(len(m.data) for m in kept)))
    return t2


# ---------------------------------------------------------------------------
# durable flight cursor — the crash-recovery resume point
# ---------------------------------------------------------------------------

class FlightCursor:
    """Per-party durable replay position, `checkpoint/ckpt.py`
    discipline: the state file is written to a tmp name and atomically
    renamed, then the COMMIT marker (naming the flight) is renamed into
    place LAST — a crash between the two leaves the previous commit
    authoritative. The payload carries a crc32 so a torn write is
    detected and the newest intact older cursor wins."""

    KEEP = 3   # retained cursor generations

    def __init__(self, run_dir: str, party: int):
        self.dir = os.path.join(run_dir, f"party{party}")
        os.makedirs(self.dir, exist_ok=True)
        self._commit_path = os.path.join(self.dir, "COMMIT")

    def _cursor_path(self, flight: int) -> str:
        return os.path.join(self.dir, f"cursor-{flight:08d}.json")

    def commit(self, flight: int, digest_state: bytes,
               wire_state: dict | None) -> None:
        body = json.dumps({"flight": flight,
                           "digest_state": digest_state.hex(),
                           "wire": wire_state or {}},
                          sort_keys=True)
        payload = json.dumps({"crc": zlib.crc32(body.encode()),
                              "body": body})
        path = self._cursor_path(flight)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
        tmp = self._commit_path + ".tmp"
        with open(tmp, "w") as f:
            f.write(str(flight))
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._commit_path)
        self._prune(flight)

    def _prune(self, newest: int) -> None:
        for name in os.listdir(self.dir):
            if name.startswith("cursor-") and name.endswith(".json"):
                try:
                    n = int(name[7:15])
                except ValueError:
                    continue
                if n <= newest - self.KEEP:
                    try:
                        os.remove(os.path.join(self.dir, name))
                    except OSError:
                        pass

    def _read(self, flight: int) -> dict | None:
        try:
            with open(self._cursor_path(flight)) as f:
                raw = json.loads(f.read())
            if zlib.crc32(raw["body"].encode()) != raw["crc"]:
                return None                     # torn/corrupt write
            st = json.loads(raw["body"])
            st["digest_state"] = bytes.fromhex(st["digest_state"])
            return st
        except (OSError, ValueError, KeyError):
            return None

    def load(self) -> dict | None:
        """Newest committed state, falling back through retained older
        generations when the committed file is corrupt; None when
        nothing has ever committed."""
        try:
            with open(self._commit_path) as f:
                newest = int(f.read().strip())
        except (OSError, ValueError):
            return None
        for flight in range(newest, max(0, newest - self.KEEP), -1):
            st = self._read(flight)
            if st is not None:
                return st
        return None

    def committed_flight(self) -> int:
        st = self.load()
        return st["flight"] if st else 0

    def mtime(self) -> float | None:
        """COMMIT file mtime — the supervisor's liveness signal: a
        party whose cursor stops advancing is a heartbeat suspect."""
        try:
            return os.path.getmtime(self._commit_path)
        except OSError:
            return None


# ---------------------------------------------------------------------------
# the party loop (shared by thread and process workers)
# ---------------------------------------------------------------------------

def _sync_barrier(t, party: int, n: int, timeout: float,
                  active: list | None = None):
    """All-parties gate rooted at the lowest active party: workers
    report in, the root releases everyone. Used at the start (timing
    begins only after release, so connection setup never pollutes the
    makespan) and at the end (nobody tears its mesh down while a peer
    is still replaying — link death is LOUD now, so an early close
    would read as a fault)."""
    active = list(active) if active is not None else list(range(n))
    root = min(active)

    def _send(dst):
        try:
            t.send(party, dst, b"", kind=tp.SYNC)
        except tp.WireDown:
            # a link that died late (post-last-DATA reset) and nothing
            # recovered yet: heal it here, the barrier must hold
            t.reconnect(dst, timeout=min(timeout, 5.0))
            t.send(party, dst, b"", kind=tp.SYNC)

    def _recv(src):
        try:
            t.recv(party, src, kind=tp.SYNC, timeout=timeout)
        except tp.WireDown:
            t.reconnect(src, timeout=min(timeout, 5.0))
            t.recv(party, src, kind=tp.SYNC, timeout=timeout)

    if party == root:
        for p in active:
            if p != root:
                _recv(p)
        for p in active:
            if p != root:
                _send(p)
    else:
        _send(root)
        _recv(root)


def _beat(hb) -> None:
    # heartbeats are advisory: a down link must never kill the worker
    try:
        hb.emit()
        hb.drain()
    except tp.WireError:
        pass


def _party_loop(t, party: int, n: int, plan: list,
                beat_every: int, timeout: float,
                heartbeat_timeout_s: float, *,
                rt: tp.ReliableTransport | None = None,
                fault_plan: fx.FaultPlan | None = None,
                cursor: FlightCursor | None = None,
                resume: bool = False,
                hard_crash: bool = False,
                active: list | None = None) -> dict:
    act = list(active) if active is not None else list(range(n))
    hb = ft.TransportHeartbeat(
        t, party, n,
        monitor=(ft.HeartbeatMonitor(n, timeout_s=heartbeat_timeout_s)
                 if party == 0 else None),
        kind=tp.BEAT)
    state = b""
    start_flight = 0
    if resume and cursor is not None:
        st = cursor.load()
        if st is not None:
            start_flight = st["flight"]
            state = st["digest_state"]
            if rt is not None:
                rt.restore_for(party, st["wire"])
                # rebuild the resend window from the tape: a peer may
                # still be missing a pre-crash frame (e.g. one a reset
                # ate just before we died) and will ask for it by seq
                seqs: dict[int, int] = {}
                for j in range(start_flight):
                    for sends, _recvs in plan[j]:
                        for dst, data in sends:
                            s = seqs.get(dst, 0)
                            rt.rebuffer(party, dst, s, data)
                            seqs[dst] = s + 1
    if not resume:
        _sync_barrier(t, party, n, timeout, act)
    t0 = time.monotonic()
    for i in range(start_flight, len(plan)):
        if fault_plan is not None and fault_plan.crash == (party, i):
            if hard_crash:
                os._exit(CRASH_EXIT)     # a real death, not an exception
            raise fx.InjectedCrash(f"party {party} crashed at flight {i}")
        stall = fault_plan.slow.get(party) if fault_plan is not None else None
        if stall:
            time.sleep(stall)
        for sends, recvs in plan[i]:
            for dst, data in sends:
                t.send(party, dst, data)
            for src, want in recvs:
                data = t.recv(party, src, timeout=timeout)
                if len(data) != want:
                    raise tp.WireError(
                        f"party {party} flight {i}: expected {want} bytes "
                        f"from {src}, got {len(data)}")
                state = _chain(state, data)
        if cursor is not None:
            # durable BEFORE the cumulative ACK: peers prune their
            # resend buffers only past what we can never need again
            cursor.commit(i + 1, state,
                          rt.state_for(party) if rt is not None else None)
        if rt is not None:
            rt.ack(party)
        if beat_every and (i + 1) % beat_every == 0:
            _beat(hb)
    _beat(hb)
    t1 = time.monotonic()
    _sync_barrier(t, party, n, timeout, act)    # end gate: see docstring
    sent = {link: nb for link, nb in t.data_bytes.items()
            if link[0] == party}
    res = {"party": party, "t0": t0, "t1": t1,
           "elapsed_s": t1 - t0, "digest": state.hex(),
           "sent_bytes": sent, "resumed": resume,
           "beats_seen": hb.beats_seen,
           "suspects": hb.monitor.suspects() if hb.monitor else []}
    if rt is not None:
        res["wire_stats"] = {
            "retries": rt.retries, "dup_frames": rt.dup_frames,
            "gap_frames": rt.gap_frames, "reconnects": rt.reconnects,
            "recovery_s": rt.recovery_s,
            "retrans_bytes": sum(rt.retrans_bytes.values())
            if hasattr(rt.retrans_bytes, "values") else 0,
            "ack_bytes": rt.ack_bytes}
    return res


def _build_stack(base, fault_plan, reliable):
    """base -> [ChaosTransport] -> [ReliableTransport]; chaos sits
    UNDER reliability so recovery sees injected faults exactly like
    real ones."""
    t = base
    chaos = None
    if fault_plan is not None:
        t = chaos = fx.ChaosTransport(t, fault_plan)
    rt = None
    if reliable:
        t = rt = tp.ReliableTransport(t)
    return t, chaos, rt


def _party_main(party: int, n: int, ports: list, profile, plan: list,
                beat_every: int, timeout: float, heartbeat_timeout_s: float,
                q, fault_plan=None, reliable: bool = False,
                run_dir: str | None = None, resume: bool = False,
                absent: tuple = ()) -> None:
    """Socket-mode child entry point (module-level: spawn imports it by
    reference — `repro.net.runtime._party_main`)."""
    base = tp.SocketTransport(n, party, ports, profile,
                              connect_timeout=timeout, absent=absent)
    t, _chaos_t, rt = _build_stack(base, fault_plan, reliable)
    cursor = FlightCursor(run_dir, party) if run_dir else None
    try:
        res = _party_loop(t, party, n, plan, beat_every, timeout,
                          heartbeat_timeout_s, rt=rt,
                          fault_plan=fault_plan, cursor=cursor,
                          resume=resume, hard_crash=True,
                          active=[p for p in range(n) if p not in absent])
        res["n_frames"] = base.n_frames
        q.put(res)
    except BaseException as e:                     # surface to the parent
        q.put({"party": party, "error": f"{type(e).__name__}: {e}"})
        raise
    finally:
        t.close()


# ---------------------------------------------------------------------------
# reconciliation + report
# ---------------------------------------------------------------------------

def reconcile(ledger, tape) -> dict:
    """Record-for-record check that the captured flight plan IS the
    ledger's online cost model: same flight count, same op / rounds /
    nbytes per flight, message sizes summing to each flight's nbytes.
    Raises WireError on any divergence; returns a summary dict."""
    online = [r for r in ledger.records if r.tag != "offline"]
    if len(online) != len(tape.flights):
        raise tp.WireError(
            f"ledger has {len(online)} online records but the tape "
            f"captured {len(tape.flights)} flights")
    for i, (r, f) in enumerate(zip(online, tape.flights)):
        if (r.op, r.rounds, r.nbytes) != (f.op, f.rounds, f.nbytes):
            raise tp.WireError(
                f"flight {i} diverges: ledger ({r.op}, rounds={r.rounds}, "
                f"nbytes={r.nbytes}) vs tape ({f.op}, rounds={f.rounds}, "
                f"nbytes={f.nbytes})")
        msg_total = sum(len(m.data) for m in f.msgs)
        if msg_total != f.nbytes:
            raise tp.WireError(
                f"flight {i} ({f.op}): messages carry {msg_total} bytes, "
                f"record prices {f.nbytes}")
    return {"n_flights": len(online), "nbytes": tape.nbytes}


@dataclasses.dataclass
class WireReport:
    """Outcome of one real-wire execution of a tape."""
    mode: str                       # "local" | "socket"
    n_parties: int
    n_flights: int
    n_msgs: int
    tape_nbytes: int                # what the ledger/tape priced
    wire_nbytes: int                # GOODPUT the transport counted
    wire_makespan_s: float          # measured: barrier -> last party done
    per_party_s: list
    digests_ok: bool
    n_frames: int
    beats_seen: int = 0
    suspects: list = dataclasses.field(default_factory=list)
    # chaos / recovery accounting (the RETRANS channel — never part of
    # the goodput `bytes_match` contract)
    retries: int = 0                # timeout-triggered resend requests
    retrans_bytes: int = 0          # retransmitted DATA payload bytes
    ack_bytes: int = 0              # ACK control payload bytes
    dup_frames: int = 0             # retransmissions deduplicated
    reconnects: int = 0             # TCP link re-establishments
    respawns: int = 0               # party processes respawned
    recovery_time_s: float = 0.0    # death detection -> resumed replay
    faults_injected: int = 0
    degraded: bool = False          # 2-of-3 completion
    dead_parties: list = dataclasses.field(default_factory=list)
    fault_plan: str | None = None   # the injected FaultPlan, as JSON

    @property
    def bytes_match(self) -> bool:
        return self.wire_nbytes == self.tape_nbytes

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["bytes_match"] = self.bytes_match
        return d


class _DegradedRestart(Exception):
    """Internal supervisor signal: drop `dead` and rerun 2-of-3."""

    def __init__(self, dead: int):
        self.dead = dead
        super().__init__(f"party {dead} dead at phase boundary")


class PartyRuntime:
    """Run a `comm.WireTape` as real parties over a transport.

    mode="local"   one thread per party over a shared LocalTransport —
                   deterministic, unpaced; the correctness path.
    mode="socket"  one spawned process per party over a SocketTransport
                   mesh, paced/delayed by `profile` — the measurement
                   path.

    Fault tolerance knobs:
      reliable    wrap every party's transport in ReliableTransport
                  (sequencing + dedup + resend + reconnect).
      fault_plan  a `faults.FaultPlan` to inject (forces reliable).
      recover     respawn crashed parties and resume from their durable
                  flight cursor.
      degraded    3-party tapes only: a party dead at a phase boundary
                  (nothing committed) is dropped and survivors complete
                  2-of-3 over the filtered tape.
      run_dir     where flight cursors live (a fresh tempdir when
                  recovery is on and no directory is given).
    """

    def __init__(self, tape, mode: str = "local", profile=None,
                 beat_every: int = DEFAULT_BEAT_EVERY,
                 timeout_s: float = 60.0,
                 heartbeat_timeout_s: float = 30.0,
                 reliable: bool = False,
                 fault_plan: fx.FaultPlan | None = None,
                 recover: bool = False,
                 degraded: bool = False,
                 run_dir: str | None = None):
        if mode not in ("local", "socket"):
            raise ValueError(f"unknown wire mode {mode!r}")
        self.tape = tape
        self.mode = mode
        self.profile = profile
        self.beat_every = beat_every
        self.timeout_s = timeout_s
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.fault_plan = fault_plan
        self.reliable = reliable or fault_plan is not None
        self.recover = recover
        self.degraded = degraded
        if fault_plan is not None and fault_plan.crash is not None \
                and not (recover or degraded):
            raise ValueError(
                "a FaultPlan with a crash needs recover=True (respawn) "
                "or degraded=True (2-of-3)")
        if (recover or fault_plan is not None) and run_dir is None:
            run_dir = tempfile.mkdtemp(prefix="wire-cursor-")
        self.run_dir = run_dir

    def execute(self) -> WireReport:
        try:
            return self._execute(self.tape, active=None,
                                 fault_plan=self.fault_plan)
        except _DegradedRestart as d:
            # 2-of-3 completion: drop the dead party, replay the
            # filtered tape among survivors (fresh mesh, same faults
            # minus the crash)
            n = self.tape.n_parties
            survivors = [p for p in range(n) if p != d.dead]
            plan = (self.fault_plan.without_crash()
                    if self.fault_plan is not None else None)
            rep = self._execute(filter_tape(self.tape, d.dead),
                                active=survivors, fault_plan=plan)
            rep.degraded = True
            rep.dead_parties = [d.dead]
            return rep

    def _execute(self, tape, active: list | None,
                 fault_plan: fx.FaultPlan | None) -> WireReport:
        n = tape.n_parties
        act = active if active is not None else list(range(n))
        plans = [compile_plan(tape, p) for p in range(n)]
        want_digests = expected_digests(tape, n)
        if self.mode == "local":
            results, n_frames, stats = self._run_local(
                plans, n, act, fault_plan)
        else:
            results, n_frames, stats = self._run_socket(
                plans, n, act, fault_plan)
        results.sort(key=lambda r: r["party"])
        wire_nbytes = sum(nb for r in results
                          for nb in r["sent_bytes"].values())
        digests_ok = all(r["digest"] == want_digests[r["party"]]
                         for r in results)
        # CLOCK_MONOTONIC is boot-anchored on Linux, so t0/t1 are
        # comparable across the spawned party processes
        makespan = (max(r["t1"] for r in results)
                    - min(r["t0"] for r in results))
        report = WireReport(
            mode=self.mode, n_parties=n,
            n_flights=len(tape.flights),
            n_msgs=sum(len(f.msgs) for f in tape.flights),
            tape_nbytes=tape.nbytes, wire_nbytes=wire_nbytes,
            wire_makespan_s=makespan,
            per_party_s=[r["elapsed_s"] for r in results],
            digests_ok=digests_ok, n_frames=n_frames,
            beats_seen=sum(r["beats_seen"] for r in results),
            suspects=sorted({s for r in results for s in r["suspects"]}),
            faults_injected=fault_plan.n_faults if fault_plan else 0,
            fault_plan=fault_plan.to_json() if fault_plan else None,
            **stats)
        if not report.bytes_match:
            raise tp.WireError(
                f"wire counted {report.wire_nbytes} goodput bytes but "
                f"the tape priced {report.tape_nbytes}")
        if not digests_ok:
            raise tp.WireError(
                "received payload digests diverge from the tape — the "
                "wire did not carry the protocol's bytes")
        return report

    # -- backends -------------------------------------------------------
    def _run_local(self, plans: list, n: int, act: list, fault_plan):
        base = tp.LocalTransport(n)
        t, chaos, rt = _build_stack(base, fault_plan, self.reliable)
        cursors = {p: FlightCursor(self.run_dir, p) for p in act} \
            if self.run_dir else {}
        results: list = [None] * n
        errors: list = []
        crashes: list = []
        stats = {"respawns": 0, "recovery_time_s": 0.0}

        def work(p, resume=False):
            # a respawned incarnation keeps every link fault armed but
            # must not die twice
            fp = fault_plan.without_crash() if (resume and fault_plan) \
                else fault_plan
            try:
                results[p] = _party_loop(
                    t, p, n, plans[p], self.beat_every, self.timeout_s,
                    self.heartbeat_timeout_s, rt=rt, fault_plan=fp,
                    cursor=cursors.get(p), resume=resume, active=act)
            except fx.InjectedCrash:
                crashes.append((p, time.monotonic()))
            except BaseException as e:
                errors.append((p, e))

        threads = {p: threading.Thread(target=work, args=(p,), daemon=True)
                   for p in act}
        for th in threads.values():
            th.start()
        deadline = time.monotonic() + self.timeout_s * 2
        while any(th.is_alive() for th in threads.values()) or crashes:
            if crashes:
                p, t_dead = crashes.pop()
                committed = cursors[p].committed_flight() if cursors else 0
                if self.degraded and n == 3 and committed == 0:
                    raise _DegradedRestart(p)
                if not self.recover:
                    raise tp.WireError(
                        f"party {p} crashed and recovery is off")
                stats["respawns"] += 1
                stats["recovery_time_s"] += time.monotonic() - t_dead
                th = threading.Thread(target=work, args=(p, True),
                                      daemon=True)
                threads[p] = th
                th.start()
            if errors:
                break
            if time.monotonic() > deadline:
                raise tp.WireError("party threads never finished")
            time.sleep(0.01)
        for th in threads.values():
            th.join(timeout=self.timeout_s)
        if errors:
            p, e = errors[0]
            raise tp.WireError(f"party {p} failed: {e}") from e
        results = [r for r in results if r is not None]
        if len(results) != len(act):
            raise tp.WireError("a party thread never finished")
        if rt is not None:
            stats.update(retries=rt.retries, dup_frames=rt.dup_frames,
                         reconnects=rt.reconnects,
                         retrans_bytes=base.total_retrans_bytes,
                         ack_bytes=base.ack_bytes)
            stats["recovery_time_s"] += rt.recovery_s
        return results, base.n_frames, stats

    def _run_socket(self, plans: list, n: int, act: list, fault_plan):
        ports = tp.free_ports(n)
        ctx = multiprocessing.get_context("spawn")
        q = ctx.Queue()
        absent = tuple(p for p in range(n) if p not in act)
        cursors = {p: FlightCursor(self.run_dir, p) for p in act} \
            if self.run_dir else {}
        stats = {"respawns": 0, "recovery_time_s": 0.0}

        def spawn(p, resume):
            plan = fault_plan.without_crash() if (resume and fault_plan) \
                else fault_plan
            pr = ctx.Process(
                target=_party_main,
                args=(p, n, ports, self.profile, plans[p], self.beat_every,
                      self.timeout_s, self.heartbeat_timeout_s, q,
                      plan, self.reliable, self.run_dir, resume, absent),
                daemon=True)
            pr.start()
            return pr

        procs = {p: spawn(p, False) for p in act}
        respawn_count = {p: 0 for p in act}
        # the supervisor's liveness monitor: a party beats by advancing
        # its durable cursor; a stalled-but-alive party becomes a
        # suspect and is escalated to declared-dead exactly like a
        # crashed one
        monitor = ft.HeartbeatMonitor(n, timeout_s=self.heartbeat_timeout_s)
        last_mtime = dict.fromkeys(act)
        results = []
        try:
            deadline = time.monotonic() + self.timeout_s * 4
            while len(results) < len(act):
                try:
                    res = q.get(timeout=0.2)
                except Exception:
                    res = None
                if res is not None:
                    if "error" in res:
                        raise tp.WireError(
                            f"party {res['party']} failed: {res['error']}")
                    results.append(res)
                    monitor.beat(res["party"])
                    continue
                done = {r["party"] for r in results}
                for p in act:
                    if p in done:
                        monitor.beat(p)
                        continue
                    if cursors:
                        mt = cursors[p].mtime()
                        if mt is not None and mt != last_mtime[p]:
                            last_mtime[p] = mt
                            monitor.beat(p)
                    pr = procs[p]
                    dead = (not pr.is_alive()
                            and pr.exitcode not in (0, None))
                    stalled = pr.is_alive() and p in monitor.suspects()
                    if not dead and not stalled:
                        continue
                    t_dead = time.monotonic()
                    if stalled:
                        # HeartbeatMonitor suspect -> declared dead
                        pr.terminate()
                        pr.join(timeout=5.0)
                    committed = cursors[p].committed_flight() \
                        if cursors else 0
                    if self.degraded and n == 3 and committed == 0:
                        raise _DegradedRestart(p)
                    if not self.recover \
                            or respawn_count[p] >= MAX_RESPAWNS:
                        raise tp.WireError(
                            f"party {p} died (exit {pr.exitcode}, "
                            f"{respawn_count[p]} respawns) and cannot "
                            "be recovered")
                    respawn_count[p] += 1
                    stats["respawns"] += 1
                    procs[p] = spawn(p, True)
                    monitor.beat(p)
                    stats["recovery_time_s"] += time.monotonic() - t_dead
                if time.monotonic() > deadline:
                    raise tp.WireError(
                        "timed out waiting for party results (alive: "
                        f"{[procs[p].is_alive() for p in act]})")
        except _DegradedRestart:
            for pr in procs.values():
                if pr.is_alive():
                    pr.terminate()
            raise
        finally:
            for pr in procs.values():
                pr.join(timeout=5.0)
                if pr.is_alive():
                    pr.terminate()
        n_frames = sum(r.get("n_frames", 0) for r in results)
        for r in results:
            ws = r.get("wire_stats")
            if ws:
                for k in ("retries", "dup_frames", "reconnects",
                          "retrans_bytes", "ack_bytes"):
                    stats[k] = stats.get(k, 0) + ws[k]
                stats["recovery_time_s"] += ws["recovery_s"]
        return results, n_frames, stats
