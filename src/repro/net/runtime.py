"""PartyRuntime: execute a captured flight plan as real parties.

The MPC engine computes every party's share components in one process
(the simulation layout of mpc/sharing.py) while `comm.WireTape` captures
each online flight's actual point-to-point messages. This module closes
the loop: it compiles the tape into one flight plan PER PARTY and runs
one worker per party — threads over a `LocalTransport` (`mode="local"`,
deterministic) or spawned processes over `SocketTransport` meshes
(`mode="socket"`, paced + latency-injected localhost TCP) — so every
recorded flight becomes an actual framed exchange.

What executing the plan proves, per run:

  bytes    transport-counted DATA bytes == the tape's (== the ledger's)
           `nbytes`, link by link — `reconcile()` and the post-run check
           both fail loudly on divergence;
  content  each party digests every payload it receives, in order; the
           digests must match what the tape says it should receive
           (BLAKE2b over the concatenated payloads);
  time     `wire_makespan_s` is measured wall-clock between the SYNC
           start barrier and the last party finishing — on the socket
           backend under a `comm.NetProfile` pacer this is an emulated-
           network MEASUREMENT to put next to the modeled
           `wan_makespan_s` (the model charges rounds x RTT serially;
           simultaneous exchanges on a real duplex wire overlap, so the
           measurement may legitimately undercut the model).

Liveness rides along: workers emit BEAT frames to party 0 every
`beat_every` flights and party 0 drains them into a
`runtime.ft.HeartbeatMonitor` (via `ft.TransportHeartbeat`), so the
fault-tolerance heartbeat path is exercised by the same wire as the
protocol traffic.

Deadlock-freedom: every party walks the SAME tape in the same flight
order, sends are non-blocking enqueues, and multi-sub-round flights
(comparisons, ABY3 trunc2) order their dependent messages via the
WireMsg `rnd` field — a party never blocks on a message whose sender
has not already been able to enqueue it.
"""
from __future__ import annotations

import dataclasses
import hashlib
import multiprocessing
import threading
import time

from repro.net import transport as tp
from repro.runtime import ft

# flights between BEAT frames (and beat-queue drains on party 0)
DEFAULT_BEAT_EVERY = 8


# ---------------------------------------------------------------------------
# plan compilation — tape -> per-party send/recv schedule
# ---------------------------------------------------------------------------
# A plan is pickle-plain (lists/tuples/bytes/ints) because socket-mode
# children receive theirs through multiprocessing spawn args:
#   plan   = [flight, ...]
#   flight = [(sends, recvs), ...]      one entry per sub-round, in order
#   sends  = [(dst, payload_bytes), ...]
#   recvs  = [(src, expected_nbytes), ...]

def compile_plan(tape, party: int) -> list:
    plan = []
    for f in tape.flights:
        rounds = sorted({m.rnd for m in f.msgs}) or [0]
        subs = []
        for r in rounds:
            sends = [(m.dst, m.data) for m in f.msgs
                     if m.rnd == r and m.src == party]
            recvs = [(m.src, len(m.data)) for m in f.msgs
                     if m.rnd == r and m.dst == party]
            subs.append((sends, recvs))
        plan.append(subs)
    return plan


def expected_digests(tape, n_parties: int) -> list[str]:
    """Per-party BLAKE2b over every payload the party receives, in the
    order the party loop receives them — the content half of the
    reconciliation contract."""
    hs = [hashlib.blake2b(digest_size=16) for _ in range(n_parties)]
    for f in tape.flights:
        for r in sorted({m.rnd for m in f.msgs} or {0}):
            for m in f.msgs:
                if m.rnd == r:
                    hs[m.dst].update(m.data)
    return [h.hexdigest() for h in hs]


# ---------------------------------------------------------------------------
# the party loop (shared by thread and process workers)
# ---------------------------------------------------------------------------

def _sync_barrier(t: tp.Transport, party: int, n: int, timeout: float):
    """All-parties start gate: workers report to party 0, party 0
    releases everyone. Timing starts only after release, so connection
    setup and plan unpickling never pollute the makespan."""
    if party == 0:
        for p in range(1, n):
            t.recv(0, p, kind=tp.SYNC, timeout=timeout)
        for p in range(1, n):
            t.send(0, p, b"", kind=tp.SYNC)
    else:
        t.send(party, 0, b"", kind=tp.SYNC)
        t.recv(party, 0, kind=tp.SYNC, timeout=timeout)


def _party_loop(t: tp.Transport, party: int, n: int, plan: list,
                beat_every: int, timeout: float,
                heartbeat_timeout_s: float) -> dict:
    hb = ft.TransportHeartbeat(
        t, party, n,
        monitor=(ft.HeartbeatMonitor(n, timeout_s=heartbeat_timeout_s)
                 if party == 0 else None),
        kind=tp.BEAT)
    digest = hashlib.blake2b(digest_size=16)
    _sync_barrier(t, party, n, timeout)
    t0 = time.monotonic()
    for i, flight in enumerate(plan):
        for sends, recvs in flight:
            for dst, data in sends:
                t.send(party, dst, data)
            for src, want in recvs:
                data = t.recv(party, src, timeout=timeout)
                if len(data) != want:
                    raise tp.WireError(
                        f"party {party} flight {i}: expected {want} bytes "
                        f"from {src}, got {len(data)}")
                digest.update(data)
        if beat_every and (i + 1) % beat_every == 0:
            hb.emit()
            hb.drain()
    hb.emit()
    hb.drain()
    t1 = time.monotonic()
    sent = {link: nb for link, nb in t.data_bytes.items()
            if link[0] == party}
    return {"party": party, "t0": t0, "t1": t1,
            "elapsed_s": t1 - t0, "digest": digest.hexdigest(),
            "sent_bytes": sent,
            "beats_seen": hb.beats_seen,
            "suspects": hb.monitor.suspects() if hb.monitor else []}


def _party_main(party: int, n: int, ports: list, profile, plan: list,
                beat_every: int, timeout: float, heartbeat_timeout_s: float,
                q) -> None:
    """Socket-mode child entry point (module-level: spawn imports it by
    reference — `repro.net.runtime._party_main`)."""
    t = tp.SocketTransport(n, party, ports, profile,
                           connect_timeout=timeout)
    try:
        res = _party_loop(t, party, n, plan, beat_every, timeout,
                          heartbeat_timeout_s)
        res["n_frames"] = t.n_frames
        q.put(res)
    except BaseException as e:                     # surface to the parent
        q.put({"party": party, "error": f"{type(e).__name__}: {e}"})
        raise
    finally:
        t.close()


# ---------------------------------------------------------------------------
# reconciliation + report
# ---------------------------------------------------------------------------

def reconcile(ledger, tape) -> dict:
    """Record-for-record check that the captured flight plan IS the
    ledger's online cost model: same flight count, same op / rounds /
    nbytes per flight, message sizes summing to each flight's nbytes.
    Raises WireError on any divergence; returns a summary dict."""
    online = [r for r in ledger.records if r.tag != "offline"]
    if len(online) != len(tape.flights):
        raise tp.WireError(
            f"ledger has {len(online)} online records but the tape "
            f"captured {len(tape.flights)} flights")
    for i, (r, f) in enumerate(zip(online, tape.flights)):
        if (r.op, r.rounds, r.nbytes) != (f.op, f.rounds, f.nbytes):
            raise tp.WireError(
                f"flight {i} diverges: ledger ({r.op}, rounds={r.rounds}, "
                f"nbytes={r.nbytes}) vs tape ({f.op}, rounds={f.rounds}, "
                f"nbytes={f.nbytes})")
        msg_total = sum(len(m.data) for m in f.msgs)
        if msg_total != f.nbytes:
            raise tp.WireError(
                f"flight {i} ({f.op}): messages carry {msg_total} bytes, "
                f"record prices {f.nbytes}")
    return {"n_flights": len(online), "nbytes": tape.nbytes}


@dataclasses.dataclass
class WireReport:
    """Outcome of one real-wire execution of a tape."""
    mode: str                       # "local" | "socket"
    n_parties: int
    n_flights: int
    n_msgs: int
    tape_nbytes: int                # what the ledger/tape priced
    wire_nbytes: int                # what the transport counted
    wire_makespan_s: float          # measured: barrier -> last party done
    per_party_s: list
    digests_ok: bool
    n_frames: int
    beats_seen: int = 0
    suspects: list = dataclasses.field(default_factory=list)

    @property
    def bytes_match(self) -> bool:
        return self.wire_nbytes == self.tape_nbytes

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["bytes_match"] = self.bytes_match
        return d


class PartyRuntime:
    """Run a `comm.WireTape` as real parties over a transport.

    mode="local"   one thread per party over a shared LocalTransport —
                   deterministic, unpaced; the correctness path.
    mode="socket"  one spawned process per party over a SocketTransport
                   mesh, paced/delayed by `profile` — the measurement
                   path.
    """

    def __init__(self, tape, mode: str = "local", profile=None,
                 beat_every: int = DEFAULT_BEAT_EVERY,
                 timeout_s: float = 60.0,
                 heartbeat_timeout_s: float = 30.0):
        if mode not in ("local", "socket"):
            raise ValueError(f"unknown wire mode {mode!r}")
        self.tape = tape
        self.mode = mode
        self.profile = profile
        self.beat_every = beat_every
        self.timeout_s = timeout_s
        self.heartbeat_timeout_s = heartbeat_timeout_s

    def execute(self) -> WireReport:
        n = self.tape.n_parties
        plans = [compile_plan(self.tape, p) for p in range(n)]
        want_digests = expected_digests(self.tape, n)
        if self.mode == "local":
            results, n_frames = self._run_local(plans, n)
        else:
            results, n_frames = self._run_socket(plans, n)
        results.sort(key=lambda r: r["party"])
        wire_nbytes = sum(nb for r in results
                          for nb in r["sent_bytes"].values())
        digests_ok = all(r["digest"] == want_digests[r["party"]]
                         for r in results)
        # CLOCK_MONOTONIC is boot-anchored on Linux, so t0/t1 are
        # comparable across the spawned party processes
        makespan = (max(r["t1"] for r in results)
                    - min(r["t0"] for r in results))
        report = WireReport(
            mode=self.mode, n_parties=n,
            n_flights=len(self.tape.flights),
            n_msgs=sum(len(f.msgs) for f in self.tape.flights),
            tape_nbytes=self.tape.nbytes, wire_nbytes=wire_nbytes,
            wire_makespan_s=makespan,
            per_party_s=[r["elapsed_s"] for r in results],
            digests_ok=digests_ok, n_frames=n_frames,
            beats_seen=sum(r["beats_seen"] for r in results),
            suspects=sorted({s for r in results for s in r["suspects"]}))
        if not report.bytes_match:
            raise tp.WireError(
                f"wire counted {report.wire_nbytes} DATA bytes but the "
                f"tape priced {report.tape_nbytes}")
        if not digests_ok:
            raise tp.WireError(
                "received payload digests diverge from the tape — the "
                "wire did not carry the protocol's bytes")
        return report

    # -- backends -------------------------------------------------------
    def _run_local(self, plans: list, n: int):
        t = tp.LocalTransport(n)
        results: list = [None] * n
        errors: list = []

        def work(p):
            try:
                results[p] = _party_loop(t, p, n, plans[p], self.beat_every,
                                         self.timeout_s,
                                         self.heartbeat_timeout_s)
            except BaseException as e:
                errors.append((p, e))

        threads = [threading.Thread(target=work, args=(p,), daemon=True)
                   for p in range(n)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=self.timeout_s * 2)
        if errors:
            p, e = errors[0]
            raise tp.WireError(f"party {p} failed: {e}") from e
        if any(r is None for r in results):
            raise tp.WireError("a party thread never finished")
        return results, t.n_frames

    def _run_socket(self, plans: list, n: int):
        ports = tp.free_ports(n)
        ctx = multiprocessing.get_context("spawn")
        q = ctx.Queue()
        procs = [ctx.Process(
            target=_party_main,
            args=(p, n, ports, self.profile, plans[p], self.beat_every,
                  self.timeout_s, self.heartbeat_timeout_s, q),
            daemon=True) for p in range(n)]
        for pr in procs:
            pr.start()
        results = []
        try:
            deadline = time.monotonic() + self.timeout_s * 4
            while len(results) < n:
                try:
                    res = q.get(timeout=0.2)
                except Exception:
                    # a child that died without posting a result (bad
                    # entry-point import, OOM, kill) must fail the run
                    # NOW, not after the full protocol timeout
                    dead = [pr.exitcode for pr in procs
                            if not pr.is_alive() and pr.exitcode != 0]
                    if dead:
                        raise tp.WireError(
                            f"party process died with exit code(s) {dead} "
                            "before reporting a result")
                    if time.monotonic() > deadline:
                        raise tp.WireError(
                            "timed out waiting for party results "
                            f"(alive: {[pr.is_alive() for pr in procs]})")
                    continue
                if "error" in res:
                    raise tp.WireError(
                        f"party {res['party']} failed: {res['error']}")
                results.append(res)
        finally:
            for pr in procs:
                pr.join(timeout=5.0)
                if pr.is_alive():
                    pr.terminate()
        n_frames = sum(r.get("n_frames", 0) for r in results)
        return results, n_frames
