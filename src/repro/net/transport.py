"""Party-to-party transports — the real wire under the flight ledger.

Two backends behind one blocking point-to-point interface:

  LocalTransport   in-process queues. Deterministic, unpaced, test-grade:
                   what the fault-tolerance tests and the `--wire local`
                   smoke path drive.
  SocketTransport  localhost TCP, one full-duplex connection per party
                   pair, length-prefixed framed messages. Every directed
                   link has a token-bucket pacer (bandwidth) and the
                   receiver injects one-way latency from a
                   `comm.NetProfile`, so any modeled network can be
                   EMULATED on a real wire — the measured makespan of a
                   flight plan is then an experiment, not a formula.

Framing (SocketTransport): every message is one frame

    !B  kind        DATA (payload, counted) | BEAT (heartbeat) | SYNC
                    | ACK (reliability control)
    !d  depart_ts   sender monotonic clock AFTER pacing (Linux
                    CLOCK_MONOTONIC is boot-anchored, so it is
                    comparable across processes on one host)
    !I  seq         per-link monotonic sequence number (DATA frames sent
                    through ReliableTransport; UNSEQ otherwise)
    !I  length      payload bytes

followed by `length` payload bytes. The receiver thread delays delivery
until `depart_ts + one_way_latency`, which serializes subsequent frames
on the link exactly like propagation delay does.

Reliability (`ReliableTransport`): a wrapper that works identically over
both backends (and over `faults.ChaosTransport`). Every DATA frame gets
a per-link monotonic sequence number and sits in a bounded resend buffer
until the receiver's cumulative ACK covers it; the receiver deduplicates
(seq < expected), discards out-of-order frames past a gap (go-back-N),
and turns a recv timeout into an `ft.retry`-driven resend request with
exponential backoff. A link the base transport declares dead is
reconnected (TCP redial/re-accept) and the unACKed window retransmitted.

Byte accounting: `data_bytes` counts each DATA payload's FIRST
transmission only (goodput — the reconciliation target is the ledger's
`nbytes`, which prices share bytes once). Retransmissions of an
already-counted sequence number land in `retrans_bytes` and ACK payloads
in `ack_bytes` — a separate RETRANS channel, so chaos recovery never
bends the goodput ledger match. Frame headers and BEAT/SYNC frames are
excluded everywhere.

Link death is LOUD: once a link's sender or receiver thread dies, plain
`send`/`recv` on that link raise `WireDown("link down: ...")` instead of
silently blocking until a timeout; only `ReliableTransport` recovers.
"""
from __future__ import annotations

import collections
import queue
import socket
import struct
import threading
import time

from repro.runtime import ft

# frame kinds
DATA, BEAT, SYNC, ACK = 0, 1, 2, 3

_HEADER = struct.Struct("!BdII")     # kind, depart_ts, seq, length
_ACK_BODY = struct.Struct("!BIIB")   # kind, cum_committed, resend_from, want
# kinds under reliable delivery: protocol payloads AND barrier frames —
# a reset that eats a SYNC release would otherwise stall a party one
# flight behind its peers forever
RELIABLE_KINDS = (DATA, SYNC)
UNSEQ = 0xFFFFFFFF                   # header seq for unsequenced frames

# a paced sender never sleeps longer than this per chunk, so huge frames
# on a slow profile still make progress and ctrl-C stays responsive
_MAX_SLEEP_S = 0.25


class WireError(RuntimeError):
    """Transport-level failure (timeout, short read, protocol abuse)."""


class WireDown(WireError):
    """The link is known dead (peer closed, reset, crashed): raised
    immediately on send/recv instead of blocking until a timeout."""


class TokenBucket:
    """Per-link bandwidth pacer: `throttle(n)` blocks until n bytes of
    budget have accrued at `rate_Bps`. Burst capacity defaults to 64 KiB
    or 50 ms of line rate, whichever is larger."""

    def __init__(self, rate_Bps: float, burst: float | None = None, *,
                 clock=time.monotonic, sleep=time.sleep):
        self.rate = float(rate_Bps)
        self.burst = float(burst if burst is not None
                           else max(65536.0, self.rate * 0.05))
        self._tokens = self.burst
        self._t = clock()
        self._clock, self._sleep = clock, sleep

    def throttle(self, nbytes: int) -> float:
        """Consume nbytes of budget, sleeping until the deficit is paid
        off; returns seconds slept. Deficit-based so a frame LARGER than
        the burst capacity still paces correctly (it waits out its own
        line time) instead of waiting for a token level the cap can
        never reach."""
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._t) * self.rate)
        self._t = now
        self._tokens -= nbytes
        slept = 0.0
        while self._tokens < 0:
            wait = min(-self._tokens / self.rate, _MAX_SLEEP_S)
            self._sleep(wait)
            slept += wait
            now = self._clock()
            self._tokens += (now - self._t) * self.rate
            self._t = now
        return slept


class Transport:
    """Blocking point-to-point byte transport between n parties.

    send() is non-blocking (enqueue); recv() blocks until the next frame
    of the requested kind on the (src -> dst) link arrives. Per-link
    FIFO order is guaranteed within a kind. DATA payload bytes are
    counted by channel: first transmission of a sequence number (or any
    unsequenced frame) into `data_bytes` (goodput), re-transmissions
    into `retrans_bytes`, ACK payloads into `ack_bytes`.
    """

    n_parties: int

    def __init__(self, n_parties: int):
        self.n_parties = n_parties
        self.data_bytes: dict[tuple[int, int], int] = {}
        self.retrans_bytes: dict[tuple[int, int], int] = {}
        self.ack_bytes = 0
        self.n_frames = 0
        self.n_retrans_frames = 0
        self.n_ack_frames = 0
        # per-link goodput watermark: seqs below it have been counted
        self._tx_counted: dict[tuple[int, int], int] = {}
        self._lock = threading.Lock()

    def _count(self, src: int, dst: int, n: int, kind: int,
               seq: int | None = None) -> None:
        with self._lock:
            self.n_frames += 1
            if kind == ACK:
                self.ack_bytes += n
                self.n_ack_frames += 1
            elif kind == DATA:
                link = (src, dst)
                if seq is not None and seq < self._tx_counted.get(link, 0):
                    # retransmission of an already-counted frame — the
                    # RETRANS channel, never goodput
                    self.retrans_bytes[link] = \
                        self.retrans_bytes.get(link, 0) + n
                    self.n_retrans_frames += 1
                else:
                    self.data_bytes[link] = self.data_bytes.get(link, 0) + n
                    if seq is not None:
                        self._tx_counted[link] = seq + 1

    @property
    def total_data_bytes(self) -> int:
        with self._lock:
            return sum(self.data_bytes.values())

    @property
    def total_retrans_bytes(self) -> int:
        with self._lock:
            return sum(self.retrans_bytes.values())

    def restore_accounting(self, data_bytes: dict[tuple[int, int], int],
                           tx_counted: dict[tuple[int, int], int]) -> None:
        """Crash-recovery hook: seed the goodput counters and watermarks
        of a fresh (respawned-party) transport from a durable cursor so
        re-sent flights are counted exactly once across incarnations.
        Monotone (max-merge): a SHARED transport (local mode) already
        holds counts past the cursor — the watermark and goodput never
        rewind, so the crashed incarnation's replayed sends land in the
        RETRANS channel."""
        with self._lock:
            for k, v in data_bytes.items():
                self.data_bytes[k] = max(self.data_bytes.get(k, 0), v)
            for k, v in tx_counted.items():
                self._tx_counted[k] = max(self._tx_counted.get(k, 0), v)

    # -- interface ------------------------------------------------------
    def send(self, src: int, dst: int, data: bytes, kind: int = DATA,
             seq: int | None = None) -> None:
        raise NotImplementedError

    def recv_seq(self, dst: int, src: int, kind: int = DATA,
                 timeout: float | None = None) -> tuple[int | None, bytes]:
        raise NotImplementedError

    def recv(self, dst: int, src: int, kind: int = DATA,
             timeout: float | None = None) -> bytes:
        return self.recv_seq(dst, src, kind, timeout)[1]

    def try_recv(self, dst: int, src: int, kind: int = DATA) -> bytes | None:
        """Non-blocking recv: None when no frame is waiting."""
        try:
            return self.recv(dst, src, kind, timeout=0.0)
        except WireError:
            return None

    def link_down(self, peer: int) -> str | None:
        """Reason string when the link to `peer` is known dead."""
        return None

    def reconnect(self, peer: int, timeout: float = 10.0) -> None:
        """Re-establish a dead link (socket backend); no-op elsewhere."""

    def purge(self, src: int, dst: int, kind: int = DATA) -> int:
        """Drop undelivered in-flight frames on a link (fault injection
        uses this to model a reset's lost window); returns frames dropped."""
        return 0

    def close(self) -> None:
        pass


class LocalTransport(Transport):
    """In-process queue transport: deterministic and instantaneous.
    The test-grade backend — heartbeat/straggler tests and `--wire
    local` runs exchange the same frames as the socket backend, minus
    pacing. Queue items carry (seq, payload) so the reliability layer
    behaves identically over both backends."""

    def __init__(self, n_parties: int):
        super().__init__(n_parties)
        self._q: dict[tuple[int, int, int], queue.Queue] = {}
        self._qlock = threading.Lock()

    def _queue(self, src: int, dst: int, kind: int) -> queue.Queue:
        k = (src, dst, kind)
        with self._qlock:
            q = self._q.get(k)
            if q is None:
                q = self._q[k] = queue.Queue()
            return q

    def send(self, src: int, dst: int, data: bytes, kind: int = DATA,
             seq: int | None = None) -> None:
        self._count(src, dst, len(data), kind, seq)
        self._queue(src, dst, kind).put((seq, bytes(data)))

    def recv_seq(self, dst: int, src: int, kind: int = DATA,
                 timeout: float | None = None) -> tuple[int | None, bytes]:
        try:
            if timeout == 0.0:
                return self._queue(src, dst, kind).get_nowait()
            return self._queue(src, dst, kind).get(timeout=timeout)
        except queue.Empty:
            raise WireError(
                f"recv timeout: party {dst} waiting on {src} (kind {kind})")

    def purge(self, src: int, dst: int, kind: int = DATA) -> int:
        q = self._queue(src, dst, kind)
        n = 0
        while True:
            try:
                q.get_nowait()
                n += 1
            except queue.Empty:
                return n


def free_ports(n: int) -> list[int]:
    """n distinct free loopback TCP ports (bound simultaneously so they
    cannot collide with each other, then released for the parties)."""
    socks, ports = [], []
    try:
        for _ in range(n):
            s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            s.bind(("127.0.0.1", 0))
            socks.append(s)
            ports.append(s.getsockname()[1])
        return ports
    finally:
        for s in socks:
            s.close()


def _recvall(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(1 << 20, n - len(buf)))
        if not chunk:
            raise WireError("peer closed connection mid-frame")
        buf += chunk
    return bytes(buf)


class SocketTransport(Transport):
    """Localhost TCP transport for ONE party of a full mesh.

    Connection setup: party p listens on ports[p]; it accepts one
    connection from every higher-numbered party and dials every
    lower-numbered one (a 1-byte hello identifies the dialer), yielding
    one full-duplex socket per pair. Each directed outgoing link gets a
    sender thread (so protocol-level simultaneous exchanges can never
    head-of-line deadlock on TCP buffers) that applies token-bucket
    pacing per `profile.bandwidth_Bps`; each incoming socket gets a
    receiver thread that demultiplexes frames by kind and delays
    delivery to `depart_ts + profile.latency_s / 2` (one-way latency —
    the profile's `latency_s` is a round trip).

    A link whose sender or receiver thread dies (peer reset, peer crash)
    is flagged down: subsequent `send`/`recv` on it raise `WireDown`
    immediately. `reconnect(peer)` re-establishes the pair — the
    lower-numbered end re-listens on its original port, the higher end
    redials — and restarts the link threads; `ReliableTransport` then
    retransmits the lost window.
    """

    def __init__(self, n_parties: int, party: int, ports: list[int],
                 profile=None, *, connect_timeout: float = 20.0,
                 absent: tuple = ()):
        super().__init__(n_parties)
        self.party = party
        self.profile = profile
        self.one_way_s = (profile.latency_s / 2.0) if profile else 0.0
        self._ports = list(ports)
        self._absent = frozenset(absent)   # degraded mode: dead parties
        self._socks: dict[int, socket.socket] = {}
        self._inbox: dict[tuple[int, int], queue.Queue] = {
            (peer, kind): queue.Queue()
            for peer in range(n_parties) if peer != party
            for kind in (DATA, BEAT, SYNC, ACK)}
        self._outbox: dict[int, queue.Queue] = {}
        self._senders: list[threading.Thread] = []
        self._receivers: list[threading.Thread] = []
        self._closed = threading.Event()
        self._down: dict[int, str] = {}
        self._gen: dict[int, int] = {}        # link thread generation
        self._reconnect_lock = threading.Lock()
        self._repair_lock = threading.Lock()
        self._repairing: set[int] = set()
        self._connect(ports, connect_timeout)
        for peer, sock in list(self._socks.items()):
            self._spawn_link_threads(peer, sock)

    # -- mesh setup -----------------------------------------------------
    def _dial(self, peer: int, timeout: float) -> socket.socket:
        """Dial a peer's listening port, retrying while it boots (or
        reboots, on crash recovery) — ft.retry owns the backoff."""
        def attempt():
            s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            try:
                s.connect(("127.0.0.1", self._ports[peer]))
            except OSError:
                s.close()
                raise
            return s
        try:
            s = ft.retry(attempt, attempts=max(8, int(timeout / 0.02)),
                         backoff_s=0.02, max_backoff_s=0.25,
                         retriable=(OSError,), deadline_s=timeout)
        except OSError:
            raise WireError(
                f"party {self.party} could not reach party {peer} on "
                f"port {self._ports[peer]}")
        s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        s.sendall(struct.pack("!B", self.party))     # hello: who dials
        return s

    def _connect(self, ports: list[int], timeout: float) -> None:
        p = self.party
        higher = [x for x in range(p + 1, self.n_parties)
                  if x not in self._absent]
        listener = None
        try:
            if higher:                      # someone will dial us
                listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
                listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
                listener.bind(("127.0.0.1", ports[p]))
                listener.listen(self.n_parties)
                listener.settimeout(timeout)
            # dial every lower-numbered party (retry while it boots)
            for peer in range(p):
                if peer not in self._absent:
                    self._socks[peer] = self._dial(peer, timeout)
            # accept every higher-numbered party
            for _ in higher:
                try:
                    s, _addr = listener.accept()
                except socket.timeout:
                    raise WireError(f"party {p}: accept timed out")
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                (peer,) = struct.unpack("!B", _recvall(s, 1))
                self._socks[peer] = s
        finally:
            # the listener must die on EVERY exit path — a timed-out
            # accept or a failed dial used to leak it (and pin the port)
            if listener is not None:
                listener.close()

    def _spawn_link_threads(self, peer: int, sock: socket.socket) -> None:
        gen = self._gen.get(peer, 0) + 1
        self._gen[peer] = gen
        ob = self._outbox.setdefault(peer, queue.Queue())
        ts = threading.Thread(target=self._sender,
                              args=(peer, sock, ob, gen), daemon=True)
        tr = threading.Thread(target=self._receiver,
                              args=(peer, sock, gen), daemon=True)
        ts.start()
        tr.start()
        self._senders.append(ts)
        self._receivers.append(tr)

    # -- link health ----------------------------------------------------
    def _mark_down(self, peer: int, reason: str,
                   gen: int | None = None) -> None:
        if self._closed.is_set():
            return
        if gen is not None and self._gen.get(peer) != gen:
            # a stale link thread dying on the OLD socket after a
            # reconnect already replaced it — the new link is healthy;
            # re-marking it down here would tear it straight back down
            # (reconnect storm)
            return
        self._down.setdefault(peer, reason)
        # Self-healing: drive the reconnect from a background thread so
        # recovery never depends on WHICH op a party is blocked in. A
        # party stuck receiving on a healthy link would otherwise never
        # re-listen for a respawned peer that is trying to dial back in
        # (three-way deadlock: respawned party can't finish _connect,
        # survivors can't make progress without it).
        if peer in self._absent:
            return
        with self._repair_lock:
            if peer in self._repairing:
                return
            self._repairing.add(peer)
        threading.Thread(target=self._repair, args=(peer,),
                         daemon=True).start()

    def _repair(self, peer: int) -> None:
        try:
            while not self._closed.is_set() and \
                    self._down.get(peer) is not None:
                try:
                    self.reconnect(peer, timeout=2.0)
                except (WireError, OSError):
                    time.sleep(0.05)
        finally:
            with self._repair_lock:
                self._repairing.discard(peer)

    def link_down(self, peer: int) -> str | None:
        return self._down.get(peer)

    def inject_reset(self, peer: int) -> None:
        """Fault-injection hook: hard-close the socket to `peer` (both
        ends' link threads die — the remote sees a reset/EOF)."""
        sock = self._socks.get(peer)
        self._mark_down(peer, "injected connection reset")
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            sock.close()

    def reconnect(self, peer: int, timeout: float = 10.0) -> None:
        """Re-establish a down link. The lower-numbered end re-listens
        on its original port and accepts; the higher end redials — the
        same orientation as initial setup, so concurrent recovery from
        both ends converges. Raises WireError if the peer does not show
        up within `timeout`."""
        if peer in self._absent:
            raise WireError(f"party {peer} is absent (degraded mesh)")
        with self._reconnect_lock:
            if self._closed.is_set():
                raise WireError("transport closed")
            if self._down.get(peer) is None:
                return                       # already recovered
            old = self._socks.pop(peer, None)
            if old is not None:
                try:
                    old.close()
                except OSError:
                    pass
            if peer < self.party:
                sock = self._dial(peer, timeout)
            else:
                sock = self._accept_reconnect(peer, timeout)
            self._socks[peer] = sock
            self._down.pop(peer, None)
            self._spawn_link_threads(peer, sock)

    def _accept_reconnect(self, peer: int, timeout: float) -> socket.socket:
        listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        try:
            listener.bind(("127.0.0.1", self._ports[self.party]))
            listener.listen(self.n_parties)
            listener.settimeout(0.5)
            deadline = time.monotonic() + timeout
            while True:
                if time.monotonic() > deadline:
                    raise WireError(
                        f"party {self.party}: reconnect accept timed out "
                        f"waiting for {peer}")
                try:
                    s, _addr = listener.accept()
                except socket.timeout:
                    continue
                s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                (who,) = struct.unpack("!B", _recvall(s, 1))
                if who == peer:
                    return s
                if self._down.get(who) is not None:
                    # a different peer reconnecting through the same
                    # window: adopt its link too, keep waiting for ours
                    self._socks[who] = s
                    self._down.pop(who, None)
                    self._spawn_link_threads(who, s)
                else:
                    s.close()
        finally:
            listener.close()

    # -- link threads ---------------------------------------------------
    def _sender(self, peer: int, sock: socket.socket, ob: queue.Queue,
                gen: int):
        bucket = TokenBucket(self.profile.bandwidth_Bps) if self.profile \
            else None
        while not self._closed.is_set() and self._gen.get(peer) == gen:
            try:
                item = ob.get(timeout=0.2)
            except queue.Empty:
                continue
            if item is None:
                return
            kind, seq, data = item
            if bucket is not None and kind == DATA and data:
                bucket.throttle(len(data))
            frame = _HEADER.pack(kind, time.monotonic(),
                                 UNSEQ if seq is None else seq,
                                 len(data)) + data
            try:
                sock.sendall(frame)
            except OSError as e:
                self._mark_down(peer, f"send failed: {e}", gen)
                return

    def _receiver(self, peer: int, sock: socket.socket, gen: int):
        while not self._closed.is_set() and self._gen.get(peer) == gen:
            try:
                hdr = _recvall(sock, _HEADER.size)
                kind, depart, seq, length = _HEADER.unpack(hdr)
                data = _recvall(sock, length) if length else b""
            except (WireError, OSError) as e:
                self._mark_down(peer, f"recv failed: {e}", gen)
                return
            if self.one_way_s:
                # propagation delay: deliver no earlier than
                # departure + one-way latency (delays this link's later
                # frames too, exactly like a real pipe)
                dt = depart + self.one_way_s - time.monotonic()
                if dt > 0:
                    time.sleep(dt)
            self._inbox[peer, kind].put(
                (None if seq == UNSEQ else seq, data))

    # -- interface ------------------------------------------------------
    def send(self, src: int, dst: int, data: bytes, kind: int = DATA,
             seq: int | None = None) -> None:
        if src != self.party:
            raise WireError(f"party {self.party} cannot send as {src}")
        if dst in self._absent:
            raise WireDown(f"link down: {src}->{dst} (party {dst} absent)")
        reason = self._down.get(dst)
        if reason is not None:
            raise WireDown(f"link down: {src}->{dst} ({reason})")
        self._count(src, dst, len(data), kind, seq)
        self._outbox[dst].put((kind, seq, bytes(data)))

    def recv_seq(self, dst: int, src: int, kind: int = DATA,
                 timeout: float | None = None) -> tuple[int | None, bytes]:
        if dst != self.party:
            raise WireError(f"party {self.party} cannot recv as {dst}")
        q = self._inbox[src, kind]
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            # poll in short slices so a link death surfaces as WireDown
            # immediately instead of a silent block until the timeout
            try:
                if timeout == 0.0:
                    return q.get_nowait()
                slice_t = 0.1
                if deadline is not None:
                    slice_t = min(slice_t,
                                  max(0.001, deadline - time.monotonic()))
                return q.get(timeout=slice_t)
            except queue.Empty:
                reason = self._down.get(src)
                if reason is not None:
                    raise WireDown(f"link down: {src}->{dst} ({reason})")
                if timeout == 0.0 or (deadline is not None
                                      and time.monotonic() >= deadline):
                    raise WireError(
                        f"recv timeout: party {dst} waiting on {src} "
                        f"(kind {kind})")

    def close(self) -> None:
        # drain FIRST: senders exit on the None sentinel only after every
        # already-enqueued frame is on the wire — shutting the socket
        # before that silently drops the tail of the stream
        for ob in self._outbox.values():
            ob.put(None)
        for ts in self._senders:
            ts.join(timeout=10.0)
        self._closed.set()
        for s in self._socks.values():
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            s.close()


class _FrameLost(WireError):
    """Internal: a frame is missing (timeout or sequence gap) — the
    retry driver sends a resend request and backs off."""


class ReliableTransport:
    """Reliable-delivery wrapper over any Transport (Local, Socket, or a
    `faults.ChaosTransport` around either).

    Sender side: every DATA frame gets the link's next sequence number
    and is held in a bounded per-link resend buffer until the receiver's
    cumulative ACK covers it. Receiver side: in-order frames are
    delivered; duplicates (retransmissions of already-delivered seqs)
    are dropped; a gap (frame lost ahead of later arrivals) discards the
    out-of-order tail and triggers go-back-N retransmission.

    Loss recovery is receiver-driven: a recv that times out sends the
    peer an ACK frame with `want_resend` set (carrying the durable
    cumulative watermark + the resend-from seq) and retries under
    `ft.retry` with exponential backoff; peers service resend requests
    opportunistically whenever they touch the transport. A link the base
    reports down is reconnected and its unACKed window retransmitted.

    ACKs carry `rx_committed`, advanced by `ack()` — the party loop
    calls it at flight boundaries AFTER durably committing its cursor,
    so a crashed party can always re-fetch every flight past its last
    commit: peers prune their resend buffers only up to the committed
    watermark.
    """

    def __init__(self, base: Transport, *, window: int = 4096,
                 rto_s: float = 0.05, max_attempts: int = 16,
                 reconnect_timeout_s: float = 3.0,
                 sleep=time.sleep, clock=time.monotonic):
        self.base = base
        self.n_parties = base.n_parties
        self.window = window
        self.rto_s = rto_s
        self.max_attempts = max_attempts
        self.reconnect_timeout_s = reconnect_timeout_s
        self._sleep, self._clock = sleep, clock
        # all reliable state is keyed (src, dst, kind): DATA and SYNC
        # run independent sequence spaces on every directed link
        self._tx_next: dict[tuple, int] = collections.defaultdict(int)
        self._tx_buf: dict[tuple, collections.OrderedDict] = \
            collections.defaultdict(collections.OrderedDict)
        self._rx_next: dict[tuple, int] = collections.defaultdict(int)
        self._rx_committed: dict[tuple, int] = collections.defaultdict(int)
        self._slock = threading.Lock()
        # stats (the WireReport's chaos accounting)
        self.retries = 0             # timeout-triggered resend requests
        self.dup_frames = 0          # deduplicated retransmissions seen
        self.gap_frames = 0          # out-of-order frames discarded
        self.resends_honored = 0     # resend requests we served
        self.reconnects = 0
        self.recovery_s = 0.0        # time spent re-establishing links

    # -- counters proxy (the party loop reads these off the transport) --
    @property
    def data_bytes(self):
        return self.base.data_bytes

    @property
    def retrans_bytes(self):
        return self.base.retrans_bytes

    @property
    def ack_bytes(self):
        return self.base.ack_bytes

    @property
    def n_frames(self):
        return self.base.n_frames

    @property
    def total_data_bytes(self):
        return self.base.total_data_bytes

    @property
    def total_retrans_bytes(self):
        return self.base.total_retrans_bytes

    # -- control --------------------------------------------------------
    def _service_control(self, me: int) -> None:
        """Drain ACK frames addressed to `me`: prune resend buffers up
        to the peer's committed watermark, honor resend requests."""
        for peer in range(self.n_parties):
            if peer == me:
                continue
            while True:
                raw = self.base.try_recv(me, peer, kind=ACK)
                if raw is None:
                    break
                k, cum, resend_from, want = _ACK_BODY.unpack(raw)
                buf = self._tx_buf[(me, peer, k)]
                for s in [s for s in buf if s < cum]:
                    del buf[s]
                if want:
                    with self._slock:
                        self.resends_honored += 1
                    for s in sorted(s for s in buf if s >= resend_from):
                        try:
                            self.base.send(me, peer, buf[s], k, seq=s)
                        except WireError:
                            break      # link down: recv path owns recovery

    def _service_sleep(self, me: int):
        """An ft.retry sleep that keeps servicing control traffic — a
        peer's resend request must never starve behind our backoff."""
        def sleep(dt: float) -> None:
            end = self._clock() + dt
            while True:
                self._service_control(me)
                left = end - self._clock()
                if left <= 0:
                    return
                self._sleep(min(left, 0.02))
        return sleep

    def ack(self, me: int, *, commit: bool = True) -> None:
        """Cumulative-ACK every incoming link. With `commit` (the party
        loop calls this AFTER durably writing its flight cursor) the
        committed watermark advances to everything received — peers may
        then prune those frames from their resend buffers."""
        for peer in range(self.n_parties):
            if peer == me:
                continue
            for k in RELIABLE_KINDS:
                link = (peer, me, k)
                if commit:
                    self._rx_committed[link] = self._rx_next[link]
                if self._rx_next[link] == 0:
                    continue           # no traffic of this kind yet
                body = _ACK_BODY.pack(k, self._rx_committed[link],
                                      self._rx_next[link], 0)
                try:
                    self.base.send(me, peer, body, kind=ACK)
                except WireError:
                    pass               # dead link: ACK again post-recovery

    def _request_resend(self, me: int, src: int, kind: int) -> None:
        link = (src, me, kind)
        body = _ACK_BODY.pack(kind, self._rx_committed[link],
                              self._rx_next[link], 1)
        try:
            self.base.send(me, src, body, kind=ACK)
        except WireError:
            pass

    def _recover_link(self, me: int, peer: int) -> None:
        """Reconnect a dead link, then go-back-N retransmit our unACKed
        window to the peer (its receiver dedups what already arrived)."""
        t0 = self._clock()
        self.base.reconnect(peer, timeout=self.reconnect_timeout_s)
        with self._slock:
            self.reconnects += 1
            self.recovery_s += self._clock() - t0
        for k in RELIABLE_KINDS:
            buf = self._tx_buf[(me, peer, k)]
            for s in sorted(buf):
                try:
                    self.base.send(me, peer, buf[s], k, seq=s)
                except WireError:
                    return

    # -- interface ------------------------------------------------------
    def send(self, src: int, dst: int, data: bytes, kind: int = DATA,
             seq: int | None = None) -> None:
        if kind not in RELIABLE_KINDS:
            return self.base.send(src, dst, data, kind)
        self._service_control(src)
        link = (src, dst, kind)
        s = self._tx_next[link]
        self._tx_next[link] = s + 1
        buf = self._tx_buf[link]
        buf[s] = data = bytes(data)
        deadline = self._clock() + self.reconnect_timeout_s * 4
        while len(buf) > self.window:
            # bounded resend buffer: wait for the peer's cumulative ACK
            self._service_control(src)
            if len(buf) <= self.window:
                break
            if self._clock() > deadline:
                raise WireError(
                    f"resend buffer full on link {src}->{dst} "
                    f"({len(buf)} unACKed frames) and no ACK arriving")
            self._sleep(self.rto_s)
        try:
            self.base.send(src, dst, data, kind, seq=s)
        except WireDown:
            # dead link: reconnect (retrying — the peer may be mid-
            # respawn) and flush the buffered window
            def recover():
                self._recover_link(src, dst)
            ft.retry(recover, attempts=self.max_attempts,
                     backoff_s=self.rto_s, max_backoff_s=1.0,
                     retriable=(WireError, OSError),
                     sleep=self._service_sleep(src), clock=self._clock)

    def recv(self, dst: int, src: int, kind: int = DATA,
             timeout: float | None = None):
        if kind not in RELIABLE_KINDS:
            # block in slices, servicing control between them — a party
            # parked waiting on advisory traffic must still answer
            # peers' resend requests or it starves their recovery
            deadline = None if timeout is None else self._clock() + timeout
            while True:
                self._service_control(dst)
                slice_t = 0.05
                if deadline is not None:
                    left = deadline - self._clock()
                    if left <= 0:
                        return self.base.recv(dst, src, kind, 0.0)
                    slice_t = min(slice_t, left)
                try:
                    return self.base.recv(dst, src, kind, slice_t)
                except WireDown:
                    raise
                except WireError:
                    continue
        link = (src, dst, kind)
        out = []

        def attempt():
            self._service_control(dst)
            deadline = self._clock() + self.rto_s
            while True:
                left = max(0.001, deadline - self._clock())
                try:
                    seq, data = self.base.recv_seq(dst, src, kind,
                                                   timeout=left)
                except WireDown:
                    try:
                        self._recover_link(dst, src)
                    except (WireError, OSError):
                        pass           # still down: back off, re-attempt
                    raise _FrameLost(f"link {src}->{dst} down")
                except WireError:
                    raise _FrameLost(f"no frame from {src} within rto")
                want = self._rx_next[link]
                if seq is None or seq == want:
                    if seq is not None:
                        self._rx_next[link] = seq + 1
                    out.append(data)
                    return
                if seq < want:
                    with self._slock:
                        self.dup_frames += 1
                    continue           # retransmission we already have
                # gap: discard the out-of-order tail (go-back-N resends
                # it in order) and ask for retransmission
                with self._slock:
                    self.gap_frames += 1
                raise _FrameLost(
                    f"gap on {src}->{dst}: got seq {seq}, want {want}")

        def lossy_attempt():
            try:
                attempt()
            except _FrameLost:
                with self._slock:
                    self.retries += 1
                self._request_resend(dst, src, kind)
                raise

        try:
            lossy_attempt()            # fast path: no retry machinery
            return out[0]
        except _FrameLost:
            pass
        ft.retry(lossy_attempt, attempts=self.max_attempts,
                 backoff_s=self.rto_s, max_backoff_s=1.0,
                 retriable=(_FrameLost,), sleep=self._service_sleep(dst),
                 clock=self._clock, deadline_s=timeout)
        return out[0]

    def try_recv(self, dst: int, src: int, kind: int = DATA):
        if kind not in RELIABLE_KINDS:
            return self.base.try_recv(dst, src, kind)
        try:
            return self.recv(dst, src, kind, timeout=0.0)
        except WireError:
            return None

    # -- crash-recovery state (the durable cursor's wire half) ----------
    def state_for(self, party: int) -> dict:
        """JSON-plain snapshot of this party's link state at a flight
        boundary: tx seqs (and goodput counters) for outgoing links, rx
        watermarks for incoming ones, per reliable kind. Restoring it on
        a respawned incarnation makes re-sent flights count once and
        re-received flights dedup exactly."""
        return {
            "tx_next": {f"{d}:{k}": n
                        for (s, d, k), n in self._tx_next.items()
                        if s == party},
            "rx_next": {f"{s}:{k}": n
                        for (s, d, k), n in self._rx_next.items()
                        if d == party},
            "data_bytes": {str(d): n
                           for (s, d), n in self.base.data_bytes.items()
                           if s == party},
        }

    def restore_for(self, party: int, st: dict) -> None:
        data_bytes, tx_counted = {}, {}
        for key, n in st.get("tx_next", {}).items():
            d, k = (int(x) for x in key.split(":"))
            self._tx_next[(party, d, k)] = n
            if k == DATA:
                tx_counted[(party, d)] = n
        for key, n in st.get("rx_next", {}).items():
            s, k = (int(x) for x in key.split(":"))
            self._rx_next[(s, party, k)] = n
            self._rx_committed[(s, party, k)] = n
        for d, n in st.get("data_bytes", {}).items():
            data_bytes[(party, int(d))] = n
        self.base.restore_accounting(data_bytes, tx_counted)

    def rebuffer(self, src: int, dst: int, seq: int, data: bytes,
                 kind: int = DATA) -> None:
        """Re-stock the resend buffer on crash recovery. The cursor
        persists tx seqs but not payloads — a respawned party rebuilds
        its unACKed window from the tape (sends are deterministic plan
        payloads), else a peer still missing a pre-crash frame could
        never be served. Peers' cumulative ACKs prune what they already
        committed."""
        self._tx_buf[(src, dst, kind)][seq] = bytes(data)

    def link_down(self, peer: int) -> str | None:
        return self.base.link_down(peer)

    def reconnect(self, peer: int, timeout: float = 10.0) -> None:
        return self.base.reconnect(peer, timeout)

    def close(self) -> None:
        self.base.close()
