"""Party-to-party transports — the real wire under the flight ledger.

Two backends behind one blocking point-to-point interface:

  LocalTransport   in-process queues. Deterministic, unpaced, test-grade:
                   what the fault-tolerance tests and the `--wire local`
                   smoke path drive.
  SocketTransport  localhost TCP, one full-duplex connection per party
                   pair, length-prefixed framed messages. Every directed
                   link has a token-bucket pacer (bandwidth) and the
                   receiver injects one-way latency from a
                   `comm.NetProfile`, so any modeled network can be
                   EMULATED on a real wire — the measured makespan of a
                   flight plan is then an experiment, not a formula.

Framing (SocketTransport): every message is one frame

    !B  kind        DATA (payload, counted) | BEAT (heartbeat) | SYNC
    !d  depart_ts   sender monotonic clock AFTER pacing (Linux
                    CLOCK_MONOTONIC is boot-anchored, so it is
                    comparable across processes on one host)
    !I  length      payload bytes

followed by `length` payload bytes. The receiver thread delays delivery
until `depart_ts + one_way_latency`, which serializes subsequent frames
on the link exactly like propagation delay does.

Byte accounting: `data_bytes` counts DATA payloads only — frame headers
and control frames (BEAT/SYNC) are excluded, because the reconciliation
target is the ledger's `nbytes`, which prices share bytes, not framing.
Framing overhead is reported separately (`frame_overhead_bytes`).
"""
from __future__ import annotations

import queue
import socket
import struct
import threading
import time

# frame kinds
DATA, BEAT, SYNC = 0, 1, 2

_HEADER = struct.Struct("!BdI")

# a paced sender never sleeps longer than this per chunk, so huge frames
# on a slow profile still make progress and ctrl-C stays responsive
_MAX_SLEEP_S = 0.25


class WireError(RuntimeError):
    """Transport-level failure (timeout, short read, protocol abuse)."""


class TokenBucket:
    """Per-link bandwidth pacer: `throttle(n)` blocks until n bytes of
    budget have accrued at `rate_Bps`. Burst capacity defaults to 64 KiB
    or 50 ms of line rate, whichever is larger."""

    def __init__(self, rate_Bps: float, burst: float | None = None, *,
                 clock=time.monotonic, sleep=time.sleep):
        self.rate = float(rate_Bps)
        self.burst = float(burst if burst is not None
                           else max(65536.0, self.rate * 0.05))
        self._tokens = self.burst
        self._t = clock()
        self._clock, self._sleep = clock, sleep

    def throttle(self, nbytes: int) -> float:
        """Consume nbytes of budget, sleeping until the deficit is paid
        off; returns seconds slept. Deficit-based so a frame LARGER than
        the burst capacity still paces correctly (it waits out its own
        line time) instead of waiting for a token level the cap can
        never reach."""
        now = self._clock()
        self._tokens = min(self.burst,
                           self._tokens + (now - self._t) * self.rate)
        self._t = now
        self._tokens -= nbytes
        slept = 0.0
        while self._tokens < 0:
            wait = min(-self._tokens / self.rate, _MAX_SLEEP_S)
            self._sleep(wait)
            slept += wait
            now = self._clock()
            self._tokens += (now - self._t) * self.rate
            self._t = now
        return slept


class Transport:
    """Blocking point-to-point byte transport between n parties.

    send() is non-blocking (enqueue); recv() blocks until the next frame
    of the requested kind on the (src -> dst) link arrives. Per-link
    FIFO order is guaranteed within a kind; DATA payload bytes are
    counted in `data_bytes`.
    """

    n_parties: int

    def __init__(self, n_parties: int):
        self.n_parties = n_parties
        self.data_bytes: dict[tuple[int, int], int] = {}
        self.n_frames = 0
        self._lock = threading.Lock()

    def _count(self, src: int, dst: int, n: int, kind: int) -> None:
        with self._lock:
            self.n_frames += 1
            if kind == DATA:
                self.data_bytes[src, dst] = \
                    self.data_bytes.get((src, dst), 0) + n

    @property
    def total_data_bytes(self) -> int:
        with self._lock:
            return sum(self.data_bytes.values())

    # -- interface ------------------------------------------------------
    def send(self, src: int, dst: int, data: bytes, kind: int = DATA) -> None:
        raise NotImplementedError

    def recv(self, dst: int, src: int, kind: int = DATA,
             timeout: float | None = None) -> bytes:
        raise NotImplementedError

    def try_recv(self, dst: int, src: int, kind: int = DATA) -> bytes | None:
        """Non-blocking recv: None when no frame is waiting."""
        try:
            return self.recv(dst, src, kind, timeout=0.0)
        except WireError:
            return None

    def close(self) -> None:
        pass


class LocalTransport(Transport):
    """In-process queue transport: deterministic and instantaneous.
    The test-grade backend — heartbeat/straggler tests and `--wire
    local` runs exchange the same frames as the socket backend, minus
    pacing."""

    def __init__(self, n_parties: int):
        super().__init__(n_parties)
        self._q: dict[tuple[int, int, int], queue.Queue] = {}
        self._qlock = threading.Lock()

    def _queue(self, src: int, dst: int, kind: int) -> queue.Queue:
        k = (src, dst, kind)
        with self._qlock:
            q = self._q.get(k)
            if q is None:
                q = self._q[k] = queue.Queue()
            return q

    def send(self, src: int, dst: int, data: bytes, kind: int = DATA) -> None:
        self._count(src, dst, len(data), kind)
        self._queue(src, dst, kind).put(bytes(data))

    def recv(self, dst: int, src: int, kind: int = DATA,
             timeout: float | None = None) -> bytes:
        try:
            if timeout == 0.0:
                return self._queue(src, dst, kind).get_nowait()
            return self._queue(src, dst, kind).get(timeout=timeout)
        except queue.Empty:
            raise WireError(
                f"recv timeout: party {dst} waiting on {src} (kind {kind})")


def free_ports(n: int) -> list[int]:
    """n distinct free loopback TCP ports (bound simultaneously so they
    cannot collide with each other, then released for the parties)."""
    socks, ports = [], []
    try:
        for _ in range(n):
            s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            s.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            s.bind(("127.0.0.1", 0))
            socks.append(s)
            ports.append(s.getsockname()[1])
        return ports
    finally:
        for s in socks:
            s.close()


def _recvall(sock: socket.socket, n: int) -> bytes:
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(min(1 << 20, n - len(buf)))
        if not chunk:
            raise WireError("peer closed connection mid-frame")
        buf += chunk
    return bytes(buf)


class SocketTransport(Transport):
    """Localhost TCP transport for ONE party of a full mesh.

    Connection setup: party p listens on ports[p]; it accepts one
    connection from every higher-numbered party and dials every
    lower-numbered one (a 1-byte hello identifies the dialer), yielding
    one full-duplex socket per pair. Each directed outgoing link gets a
    sender thread (so protocol-level simultaneous exchanges can never
    head-of-line deadlock on TCP buffers) that applies token-bucket
    pacing per `profile.bandwidth_Bps`; each incoming socket gets a
    receiver thread that demultiplexes frames by kind and delays
    delivery to `depart_ts + profile.latency_s / 2` (one-way latency —
    the profile's `latency_s` is a round trip).
    """

    def __init__(self, n_parties: int, party: int, ports: list[int],
                 profile=None, *, connect_timeout: float = 20.0):
        super().__init__(n_parties)
        self.party = party
        self.profile = profile
        self.one_way_s = (profile.latency_s / 2.0) if profile else 0.0
        self._socks: dict[int, socket.socket] = {}
        self._inbox: dict[tuple[int, int], queue.Queue] = {
            (peer, kind): queue.Queue()
            for peer in range(n_parties) if peer != party
            for kind in (DATA, BEAT, SYNC)}
        self._outbox: dict[int, queue.Queue] = {}
        self._senders: list[threading.Thread] = []
        self._receivers: list[threading.Thread] = []
        self._closed = threading.Event()
        self._connect(ports, connect_timeout)
        for peer, sock in self._socks.items():
            ob: queue.Queue = queue.Queue()
            self._outbox[peer] = ob
            ts = threading.Thread(target=self._sender, args=(peer, sock, ob),
                                  daemon=True)
            tr = threading.Thread(target=self._receiver, args=(peer, sock),
                                  daemon=True)
            ts.start()
            tr.start()
            self._senders.append(ts)
            self._receivers.append(tr)

    # -- mesh setup -----------------------------------------------------
    def _connect(self, ports: list[int], timeout: float) -> None:
        p = self.party
        listener = None
        if p < self.n_parties - 1:      # someone will dial us
            listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            listener.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            listener.bind(("127.0.0.1", ports[p]))
            listener.listen(self.n_parties)
            listener.settimeout(timeout)
        # dial every lower-numbered party (retry while it boots)
        for peer in range(p):
            deadline = time.monotonic() + timeout
            while True:
                s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
                try:
                    s.connect(("127.0.0.1", ports[peer]))
                    break
                except OSError:
                    s.close()
                    if time.monotonic() > deadline:
                        raise WireError(
                            f"party {p} could not reach party {peer} on "
                            f"port {ports[peer]}")
                    time.sleep(0.02)
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            s.sendall(struct.pack("!B", p))          # hello: who dials
            self._socks[peer] = s
        # accept every higher-numbered party
        for _ in range(p + 1, self.n_parties):
            try:
                s, _addr = listener.accept()
            except socket.timeout:
                raise WireError(f"party {p}: accept timed out")
            s.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            (peer,) = struct.unpack("!B", _recvall(s, 1))
            self._socks[peer] = s
        if listener is not None:
            listener.close()

    # -- link threads ---------------------------------------------------
    def _sender(self, peer: int, sock: socket.socket, ob: queue.Queue):
        bucket = TokenBucket(self.profile.bandwidth_Bps) if self.profile \
            else None
        while not self._closed.is_set():
            try:
                item = ob.get(timeout=0.2)
            except queue.Empty:
                continue
            if item is None:
                return
            kind, data = item
            if bucket is not None and kind == DATA and data:
                bucket.throttle(len(data))
            frame = _HEADER.pack(kind, time.monotonic(), len(data)) + data
            try:
                sock.sendall(frame)
            except OSError:
                return

    def _receiver(self, peer: int, sock: socket.socket):
        while not self._closed.is_set():
            try:
                hdr = _recvall(sock, _HEADER.size)
            except (WireError, OSError):
                return
            kind, depart, length = _HEADER.unpack(hdr)
            try:
                data = _recvall(sock, length) if length else b""
            except (WireError, OSError):
                return
            if self.one_way_s:
                # propagation delay: deliver no earlier than
                # departure + one-way latency (delays this link's later
                # frames too, exactly like a real pipe)
                dt = depart + self.one_way_s - time.monotonic()
                if dt > 0:
                    time.sleep(dt)
            self._inbox[peer, kind].put(data)

    # -- interface ------------------------------------------------------
    def send(self, src: int, dst: int, data: bytes, kind: int = DATA) -> None:
        if src != self.party:
            raise WireError(f"party {self.party} cannot send as {src}")
        self._count(src, dst, len(data), kind)
        self._outbox[dst].put((kind, bytes(data)))

    def recv(self, dst: int, src: int, kind: int = DATA,
             timeout: float | None = None) -> bytes:
        if dst != self.party:
            raise WireError(f"party {self.party} cannot recv as {dst}")
        try:
            if timeout == 0.0:
                return self._inbox[src, kind].get_nowait()
            return self._inbox[src, kind].get(timeout=timeout)
        except queue.Empty:
            raise WireError(
                f"recv timeout: party {dst} waiting on {src} (kind {kind})")

    def close(self) -> None:
        # drain FIRST: senders exit on the None sentinel only after every
        # already-enqueued frame is on the wire — shutting the socket
        # before that silently drops the tail of the stream
        for ob in self._outbox.values():
            ob.put(None)
        for ts in self._senders:
            ts.join(timeout=10.0)
        self._closed.set()
        for s in self._socks.values():
            try:
                s.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            s.close()
