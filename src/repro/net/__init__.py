"""Real-wire party runtime: transports + multi-process MPC execution.

`mpc/comm.py` captures each online flight's actual messages into a
WireTape; this package replays the tape as real parties — threads over
in-process queues (`LocalTransport`) or spawned processes over paced
localhost TCP (`SocketTransport`) — reconciling transport-counted bytes
against the ledger and measuring wall-clock (`wire_makespan_s`).
"""
from repro.net.transport import (          # noqa: F401
    BEAT,
    DATA,
    SYNC,
    LocalTransport,
    SocketTransport,
    TokenBucket,
    Transport,
    WireError,
    free_ports,
)
from repro.net.runtime import (            # noqa: F401
    PartyRuntime,
    WireReport,
    compile_plan,
    expected_digests,
    reconcile,
)
