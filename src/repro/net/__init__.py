"""Real-wire party runtime: transports + multi-process MPC execution.

`mpc/comm.py` captures each online flight's actual messages into a
WireTape; this package replays the tape as real parties — threads over
in-process queues (`LocalTransport`) or spawned processes over paced
localhost TCP (`SocketTransport`) — reconciling transport-counted bytes
against the ledger and measuring wall-clock (`wire_makespan_s`).

Chaos hardening: `net.faults.FaultPlan` injects seeded, deterministic
failures (drops, latency spikes, connection resets, party crashes) and
`ReliableTransport` + the supervisor in `runtime.py` recover them —
goodput still reconciles byte-for-byte and digests stay bitwise equal.
"""
from repro.net.transport import (          # noqa: F401
    ACK,
    BEAT,
    DATA,
    SYNC,
    LocalTransport,
    ReliableTransport,
    SocketTransport,
    TokenBucket,
    Transport,
    WireDown,
    WireError,
    free_ports,
)
from repro.net.runtime import (            # noqa: F401
    PartyRuntime,
    WireReport,
    compile_plan,
    expected_digests,
    filter_tape,
    reconcile,
)
from repro.net.faults import (             # noqa: F401
    ChaosTransport,
    FaultPlan,
    InjectedCrash,
)
