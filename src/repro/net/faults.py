"""Deterministic fault injection for the party wire.

A `FaultPlan` is a seeded, fully reproducible schedule of failures
against a specific tape replay:

  drop     lose DATA frame k on directed link (src, dst) — the frame is
           counted (goodput is priced at first transmission) but never
           delivered; the reliability layer must recover it.
  spike    stall the sender for `extra_s` before frame k on a link — a
           latency spike, not a loss.
  reset    hard connection reset while sending frame k on a link: the
           frame is lost AND the link goes down (socket backend: the
           TCP pair is closed so both ends see it; local backend: the
           link's undelivered queue is purged). Recovery is reconnect +
           go-back-N retransmit.
  crash    party p dies at the top of flight f (before sending any of
           it): `InjectedCrash` in a thread worker, a hard `os._exit`
           in a process worker. Recovery is supervisor respawn + cursor
           resume — or degraded 2-of-3 completion when the party died
           at a phase boundary.
  slow     party p stalls `slow_s` at every flight — a straggler, for
           heartbeat/escalation paths.

Placement is derived from the tape's own structure (flight count,
per-link frame counts) by `FaultPlan.from_tape(seed, tape)` via a
seeded PRNG — the same seed and tape always produce the identical plan
(the determinism contract CI tests), and a plan can be serialized to
JSON (`--chaos-plan`) and replayed elsewhere.

`ChaosTransport` applies a plan identically over `LocalTransport` and
`SocketTransport` (and composes under `ReliableTransport`): it sits on
the SENDER side of every link, keyed by per-link DATA frame index.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time

import numpy as np

from repro.net import transport as tp


class InjectedCrash(BaseException):
    """A chaos-scheduled party death. Derives from BaseException so no
    protocol-level `except Exception` can accidentally survive it —
    only the worker entry point is allowed to catch it."""


@dataclasses.dataclass
class FaultPlan:
    """Seeded fault schedule. All fields are pickle-plain (spawned
    party processes receive the plan through multiprocessing args)."""
    seed: int
    drops: dict = dataclasses.field(default_factory=dict)
    #   (src, dst) -> tuple of per-link DATA frame indices to lose
    spikes: dict = dataclasses.field(default_factory=dict)
    #   (src, dst) -> {frame_index: extra_seconds}
    resets: dict = dataclasses.field(default_factory=dict)
    #   (src, dst) -> tuple of frame indices that reset the connection
    crash: tuple | None = None          # (party, flight) or None
    slow: dict = dataclasses.field(default_factory=dict)
    #   party -> stall seconds per flight

    @property
    def n_faults(self) -> int:
        return (sum(len(v) for v in self.drops.values())
                + sum(len(v) for v in self.spikes.values())
                + sum(len(v) for v in self.resets.values())
                + (1 if self.crash else 0) + len(self.slow))

    def without_crash(self) -> "FaultPlan":
        """The plan a respawned incarnation runs under — every link
        fault stays armed, but the party does not die twice."""
        return dataclasses.replace(self, crash=None)

    def crash_party(self) -> int | None:
        return self.crash[0] if self.crash else None

    # -- construction ---------------------------------------------------
    @classmethod
    def from_tape(cls, seed: int, tape, *, n_drops: int = 2,
                  n_spikes: int = 1, n_resets: int = 1,
                  spike_s: float = 0.05, crash: bool = True,
                  crash_at_boundary: bool = False,
                  slow_party: int | None = None,
                  slow_s: float = 0.0) -> "FaultPlan":
        """Derive a deterministic plan from the tape's structure. The
        PRNG is seeded and every choice is over sorted, tape-derived
        populations — same (seed, tape) in, same plan out, bit for bit.

        Faults are placed on the busiest links (most frames) so short
        smokes still exercise every recovery path; the crash lands
        mid-phase (flight in [1, n_flights-1)) unless
        `crash_at_boundary` pins it to flight 0 — the degraded-mode
        trigger."""
        rng = np.random.default_rng(seed)
        frames = tape.link_frames()
        links = sorted(frames, key=lambda k: (-frames[k], k))
        if not links:
            return cls(seed=seed)

        def pick(link, n_avoid_first=1):
            # frame 0 on a link often carries a SYNC-adjacent first
            # exchange; any index is legal, this just spreads placement
            hi = frames[link]
            return int(rng.integers(0, hi)) if hi else 0

        drops: dict = {}
        for i in range(min(n_drops, len(links))):
            link = links[i % len(links)]
            drops.setdefault(link, set()).add(pick(link))
        spikes: dict = {}
        for i in range(min(n_spikes, len(links))):
            link = links[(i + 1) % len(links)]
            spikes.setdefault(link, {})[pick(link)] = float(spike_s)
        resets: dict = {}
        for i in range(min(n_resets, len(links))):
            link = links[(i + 2) % len(links)]
            k = pick(link)
            # a reset and a drop on the same frame would double-fire
            if k in drops.get(link, ()):
                k = (k + 1) % max(1, frames[link])
            resets.setdefault(link, set()).add(k)

        crash_spec = None
        if crash and tape.n_parties > 1 and len(tape.flights) > 2:
            party = int(rng.integers(1, tape.n_parties))
            if crash_at_boundary:
                flight = 0
            else:
                flight = int(rng.integers(1, len(tape.flights) - 1))
            crash_spec = (party, flight)

        slow = {}
        if slow_party is not None and slow_s > 0:
            slow[slow_party] = float(slow_s)

        return cls(seed=seed,
                   drops={k: tuple(sorted(v)) for k, v in drops.items()},
                   spikes=spikes,
                   resets={k: tuple(sorted(v)) for k, v in resets.items()},
                   crash=crash_spec, slow=slow)

    # -- (de)serialization: --chaos-plan files --------------------------
    def to_json(self) -> str:
        def k(link):
            return f"{link[0]}->{link[1]}"
        return json.dumps({
            "seed": self.seed,
            "drops": {k(link): list(v) for link, v in self.drops.items()},
            "spikes": {k(link): {str(i): s for i, s in v.items()}
                       for link, v in self.spikes.items()},
            "resets": {k(link): list(v) for link, v in self.resets.items()},
            "crash": list(self.crash) if self.crash else None,
            "slow": {str(p): s for p, s in self.slow.items()},
        }, indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        raw = json.loads(text)

        def link(s):
            a, b = s.split("->")
            return (int(a), int(b))
        return cls(
            seed=int(raw.get("seed", 0)),
            drops={link(s): tuple(v) for s, v in raw.get("drops", {}).items()},
            spikes={link(s): {int(i): float(x) for i, x in v.items()}
                    for s, v in raw.get("spikes", {}).items()},
            resets={link(s): tuple(v) for s, v in raw.get("resets", {}).items()},
            crash=tuple(raw["crash"]) if raw.get("crash") else None,
            slow={int(p): float(s) for p, s in raw.get("slow", {}).items()})


class ChaosTransport:
    """Apply a FaultPlan at the sender side of a base Transport.

    Sits UNDER `ReliableTransport` and over either backend: reliability
    sees faulted links exactly as it would see a faulty network. Frame
    indexing counts every DATA transmission on a directed link
    (retransmissions included), so placement is a pure function of the
    plan — and dropped frames are still byte-counted (goodput is priced
    at first transmission; recovery traffic lands in the RETRANS
    channel by the sequence-number watermark underneath).
    """

    def __init__(self, base, plan: FaultPlan, *, sleep=time.sleep):
        self.base = base
        self.plan = plan
        self.n_parties = base.n_parties
        self._sleep = sleep
        self._idx: dict = {}
        self._lock = threading.Lock()
        self.dropped = 0
        self.resets_fired = 0
        self.spiked = 0

    def _next_idx(self, link) -> int:
        with self._lock:
            k = self._idx.get(link, 0)
            self._idx[link] = k + 1
            return k

    def send(self, src: int, dst: int, data, kind: int = tp.DATA,
             seq=None) -> None:
        if kind != tp.DATA:
            # forward seq: SYNC frames are sequenced by the reliability
            # layer too — stripping it here would let a retransmitted
            # barrier frame bypass receiver dedup
            return self.base.send(src, dst, data, kind, seq)
        link = (src, dst)
        k = self._next_idx(link)
        extra = self.plan.spikes.get(link, {}).get(k)
        if extra:
            self.spiked += 1
            self._sleep(extra)
        if k in self.plan.resets.get(link, ()):
            # the frame is lost in the reset: count it (first-tx goodput
            # / retrans by watermark), then kill the link
            self.resets_fired += 1
            self.base._count(src, dst, len(data), kind, seq)
            if hasattr(self.base, "inject_reset"):
                self.base.inject_reset(dst)       # socket: both ends die
            else:
                self.base.purge(src, dst, tp.DATA)  # local: window lost
            return
        if k in self.plan.drops.get(link, ()):
            self.dropped += 1
            self.base._count(src, dst, len(data), kind, seq)
            return
        return self.base.send(src, dst, data, kind, seq)

    # -- passthrough ----------------------------------------------------
    def recv_seq(self, dst, src, kind=tp.DATA, timeout=None):
        return self.base.recv_seq(dst, src, kind, timeout)

    def recv(self, dst, src, kind=tp.DATA, timeout=None):
        return self.base.recv(dst, src, kind, timeout)

    def try_recv(self, dst, src, kind=tp.DATA):
        return self.base.try_recv(dst, src, kind)

    def link_down(self, peer):
        return self.base.link_down(peer)

    def reconnect(self, peer, timeout: float = 10.0):
        return self.base.reconnect(peer, timeout)

    def purge(self, src, dst, kind=tp.DATA):
        return self.base.purge(src, dst, kind)

    def restore_accounting(self, data_bytes, tx_counted):
        return self.base.restore_accounting(data_bytes, tx_counted)

    def _count(self, src, dst, n, kind, seq=None):
        return self.base._count(src, dst, n, kind, seq)

    @property
    def data_bytes(self):
        return self.base.data_bytes

    @property
    def retrans_bytes(self):
        return self.base.retrans_bytes

    @property
    def ack_bytes(self):
        return self.base.ack_bytes

    @property
    def n_frames(self):
        return self.base.n_frames

    @property
    def total_data_bytes(self):
        return self.base.total_data_bytes

    def close(self):
        self.base.close()
