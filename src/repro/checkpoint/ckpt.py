"""Distributed checkpoint: manifest-verified .npz shards, atomic rename,
async save thread, auto-resume.

Layout:  <dir>/step_<N>/shard_<host>.npz     flattened pytree leaves
         <dir>/step_<N>/manifest.json        treedef + shapes + crc32s
         <dir>/step_<N>/COMMIT               written last (atomicity mark)

Restore picks the newest COMMITted step, verifies the manifest, and
rebuilds the pytree. Corrupt/partial steps (no COMMIT or crc mismatch)
are skipped — the restart path after a mid-save node failure.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import zlib

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, str(treedef)


_UINT_VIEW = {2: np.uint16, 1: np.uint8}     # bf16/fp8: not numpy-native


def _to_storable(a: np.ndarray) -> tuple[np.ndarray, str]:
    """(storable array, logical dtype name). Exotic dtypes -> uint view."""
    name = a.dtype.name
    if name in ("float64", "float32", "float16", "int64", "int32", "int16",
                "int8", "uint64", "uint32", "uint16", "uint8", "bool"):
        return a, name
    return a.view(_UINT_VIEW[a.dtype.itemsize]), name


def _from_storable(a: np.ndarray, logical: str) -> np.ndarray:
    if a.dtype.name == logical:
        return a
    import ml_dtypes
    return a.view(np.dtype(getattr(ml_dtypes, logical, logical)))


def save_checkpoint(ckpt_dir: str, step: int, tree, *, host: int = 0,
                    keep: int = 3) -> str:
    leaves, treedef_str = _flatten(tree)
    stored = [_to_storable(np.asarray(leaf)) for leaf in leaves]
    arrays = {f"leaf_{i}": a for i, (a, _) in enumerate(stored)}
    logical = [d for _, d in stored]
    step_dir = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(step_dir, exist_ok=True)
    shard = os.path.join(step_dir, f"shard_{host}.npz")
    tmp = shard + ".tmp.npz"          # keep .npz suffix: np.savez appends it
    np.savez(tmp, **arrays)
    os.replace(tmp, shard)
    manifest = {
        "step": step,
        "treedef": treedef_str,
        "leaves": [{"name": f"leaf_{i}", "shape": list(a.shape),
                    "dtype": str(a.dtype), "logical_dtype": logical[i],
                    "crc32": zlib.crc32(np.ascontiguousarray(a).tobytes())}
                   for i, a in enumerate(arrays.values())],
    }
    mpath = os.path.join(step_dir, "manifest.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f)
    os.replace(mpath + ".tmp", mpath)
    with open(os.path.join(step_dir, "COMMIT"), "w") as f:
        f.write("ok")
    _gc(ckpt_dir, keep)
    return step_dir


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "COMMIT")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = _steps(ckpt_dir)
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, tree_like, *, step: int | None = None,
                       host: int = 0):
    """Restore into the structure of `tree_like`. Returns (tree, step) or
    (tree_like, None) if no valid checkpoint exists."""
    steps = sorted(_steps(ckpt_dir), reverse=True)
    if step is not None:
        steps = [s for s in steps if s == step]
    for s in steps:
        step_dir = os.path.join(ckpt_dir, f"step_{s:08d}")
        try:
            with open(os.path.join(step_dir, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(step_dir, f"shard_{host}.npz")) as z:
                arrays = [z[e["name"]] for e in manifest["leaves"]]
            for a, e in zip(arrays, manifest["leaves"]):
                if zlib.crc32(np.ascontiguousarray(a).tobytes()) != e["crc32"]:
                    raise IOError(f"crc mismatch in {e['name']}")
            leaves, treedef = jax.tree_util.tree_flatten(tree_like)
            if len(leaves) != len(arrays):
                raise IOError("leaf count mismatch")
            restored = [_from_storable(np.asarray(a),
                                       e.get("logical_dtype", str(a.dtype)))
                        for a, e in zip(arrays, manifest["leaves"])]
            return jax.tree_util.tree_unflatten(treedef, restored), s
        except Exception:
            continue          # corrupt step: fall through to older one
    return tree_like, None


class AsyncCheckpointer:
    """Non-blocking saves: device->host copy on the caller, disk IO on a
    background thread (one in flight; newer save waits for the previous)."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None

    def save(self, step: int, tree) -> None:
        host_tree = jax.tree.map(np.asarray, tree)      # sync copy out
        self.wait()
        self._thread = threading.Thread(
            target=save_checkpoint,
            args=(self.ckpt_dir, step, host_tree),
            kwargs={"keep": self.keep}, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
