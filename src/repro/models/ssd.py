"""Mamba-2 SSD (state-space duality) mixer, arXiv:2405.21060.

Block: in_proj -> [z | xBC | dt]; causal conv1d + silu on xBC;
SSD core (chunked scan: intra-chunk quadratic attention-like term +
inter-chunk linear state recurrence); gated RMSNorm; out_proj.

The chunked core scans over chunks so live memory is
O(B * H * Q^2 + B * H * P * N) regardless of T — this is why mamba2 runs
the long_500k shape. Single-step decode carries (conv_state, ssm_state).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def init_ssd_block(key, d_model: int, n_layers: int, d_state: int = 128,
                   expand: int = 2, head_dim: int = 64, conv_width: int = 4):
    d_in = expand * d_model
    n_heads = d_in // head_dim
    ks = jax.random.split(key, 5)
    d_xbc = d_in + 2 * d_state
    return {
        "w_in": common.dense_init(ks[0], (n_layers, d_model,
                                          2 * d_in + 2 * d_state + n_heads)),
        "conv_w": common.dense_init(ks[1], (n_layers, conv_width, d_xbc)) * 0.1,
        "conv_b": jnp.zeros((n_layers, d_xbc)),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads))[None].repeat(n_layers, 0),
        "dt_bias": jnp.zeros((n_layers, n_heads)),
        "d_skip": jnp.ones((n_layers, n_heads)),
        "norm_scale": jnp.zeros((n_layers, d_in)),
        "w_out": common.dense_init(ks[2], (n_layers, d_in, d_model), in_axis=-2),
    }


def _segsum(a):
    """a: (B, H, Q) log decays -> (B, H, Q, Q) lower-tri pairwise sums."""
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    # L[i, j] = exp(sum_{j+1..i} a) for i >= j: cum[i] - cum[j]
    seg = cum[..., :, None] - cum[..., None, :]
    tri = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(tri, seg, -jnp.inf)


def ssd_scan(x, a, b, c, chunk: int = 128, state0=None):
    """Chunked SSD.

    x: (B, T, H, P) inputs (already dt-scaled), a: (B, T, H) log decays,
    b, c: (B, T, N) in/out state projections (n_groups=1, shared by heads).
    Returns y: (B, T, H, P), final state (B, H, P, N).
    """
    bsz, t, h, p = x.shape
    n = b.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0
    nc = t // chunk
    xc = x.reshape(bsz, nc, chunk, h, p)
    ac = a.reshape(bsz, nc, chunk, h)
    bc = b.reshape(bsz, nc, chunk, n)
    cc = c.reshape(bsz, nc, chunk, n)
    s0 = (state0 if state0 is not None
          else jnp.zeros((bsz, h, p, n), jnp.float32))

    def step(state, inp):
        x_, a_, b_, c_ = inp                      # (B,Q,H,P),(B,Q,H),(B,Q,N)
        a_ = a_.astype(jnp.float32)
        cum = jnp.cumsum(a_, axis=1)              # (B,Q,H)
        L = jnp.exp(_segsum(jnp.moveaxis(a_, -1, 1)))     # (B,H,Q,Q)
        scores = jnp.einsum("bqn,bsn->bqs", c_.astype(jnp.float32),
                            b_.astype(jnp.float32))
        m = scores[:, None] * L                   # (B,H,Q,Q)
        y_diag = jnp.einsum("bhqs,bshp->bqhp", m, x_.astype(jnp.float32))
        # contribution of the carried state
        decay_in = jnp.exp(cum)                   # (B,Q,H)
        y_off = jnp.einsum("bqn,bhpn->bqhp", c_.astype(jnp.float32), state)
        y_off = y_off * decay_in[..., None]
        # state update
        chunk_sum = cum[:, -1]                    # (B,H)
        decay_out = jnp.exp(chunk_sum[:, None] - cum)     # (B,Q,H)
        new_contrib = jnp.einsum("bqn,bqh,bqhp->bhpn", b_.astype(jnp.float32),
                                 decay_out, x_.astype(jnp.float32))
        state = state * jnp.exp(chunk_sum)[..., None, None] + new_contrib
        return state, (y_diag + y_off).astype(x.dtype)

    xs = (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(ac, 1, 0),
          jnp.moveaxis(bc, 1, 0), jnp.moveaxis(cc, 1, 0))
    state_f, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, t, h, p)
    return y, state_f


def ssd_step(x, a, b, c, state):
    """One decode step. x: (B, 1, H, P); a: (B, 1, H); b/c: (B, 1, N)."""
    a_ = jnp.exp(a[:, 0].astype(jnp.float32))                  # (B,H)
    contrib = jnp.einsum("bn,bhp->bhpn", b[:, 0].astype(jnp.float32),
                         x[:, 0].astype(jnp.float32))
    state = state * a_[..., None, None] + contrib
    y = jnp.einsum("bn,bhpn->bhp", c[:, 0].astype(jnp.float32), state)
    return y[:, None].astype(x.dtype), state


def ssd_block(x, p, cfg, state=None, decode: bool = False):
    """Full Mamba-2 block. state = (conv_state, ssm_state)."""
    d_model = x.shape[-1]
    d_in = cfg.ssm_expand * d_model
    hd = cfg.ssm_head_dim
    n_heads = d_in // hd
    n_state = cfg.ssm_state

    proj = jnp.einsum("btd,dk->btk", x, p["w_in"].astype(x.dtype))
    z, xbc, dt = jnp.split(proj, [d_in, 2 * d_in + 2 * n_state], axis=-1)
    conv_state = state[0] if state is not None else None
    from repro.models.rglru import _causal_conv
    xbc, conv_state_new = _causal_conv(xbc, p["conv_w"].astype(x.dtype),
                                       p["conv_b"].astype(x.dtype), conv_state)
    xbc = jax.nn.silu(xbc)
    xi, b, c = jnp.split(xbc, [d_in, d_in + n_state], axis=-1)
    xh = xi.reshape(*xi.shape[:2], n_heads, hd)

    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))     # (B,T,H)
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32))             # (H,)
    log_decay = dt * a_neg                                        # (B,T,H)
    x_in = xh * dt[..., None].astype(xh.dtype)

    ssm_state = state[1] if state is not None else None
    if decode:
        y, ssm_new = ssd_step(x_in, log_decay, b, c, ssm_state)
    else:
        y, ssm_new = ssd_scan(x_in, log_decay, b, c, state0=ssm_state)
    y = y + xh * p["d_skip"].astype(x.dtype)[:, None]
    y = y.reshape(*x.shape[:2], d_in)
    y = common.rmsnorm(y * jax.nn.silu(z), p["norm_scale"])
    return jnp.einsum("bti,id->btd", y, p["w_out"].astype(x.dtype)), \
        (conv_state_new, ssm_new)


def init_ssd_state(batch: int, d_model: int, cfg, dtype=jnp.bfloat16):
    d_in = cfg.ssm_expand * d_model
    n_heads = d_in // cfg.ssm_head_dim
    d_xbc = d_in + 2 * cfg.ssm_state
    return (jnp.zeros((batch, cfg.conv_width - 1, d_xbc), dtype),
            jnp.zeros((batch, n_heads, cfg.ssm_head_dim, cfg.ssm_state),
                      jnp.float32))
