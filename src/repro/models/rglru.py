"""Griffin RG-LRU recurrent block (RecurrentGemma), arXiv:2402.19427.

Block:  x -> [branch1: linear -> causal conv1d(w=4) -> RG-LRU]
             [branch2: linear -> GeLU]
        out = linear(branch1 * branch2)

RG-LRU recurrence (diagonal, gated):
    r_t = sigmoid(W_a x_t + b_a)          recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          input gate
    log a_t = -c * r_t * softplus(Lambda)        (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill uses jax.lax.associative_scan over the (a, b) linear
recurrence; decode is a single fused step. State is O(lru_width) per
sequence — this is why recurrentgemma runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common

C_GATE = 8.0


def init_rglru_block(key, d_model: int, lru_width: int, n_layers: int,
                     conv_width: int = 4):
    ks = jax.random.split(key, 7)
    return {
        "w_in": common.dense_init(ks[0], (n_layers, d_model, lru_width)),
        "w_gate_br": common.dense_init(ks[1], (n_layers, d_model, lru_width)),
        "conv_w": common.dense_init(ks[2], (n_layers, conv_width, lru_width)) * 0.1,
        "conv_b": jnp.zeros((n_layers, lru_width)),
        "w_a": common.dense_init(ks[3], (n_layers, lru_width, lru_width)),
        "b_a": jnp.zeros((n_layers, lru_width)),
        "w_x": common.dense_init(ks[4], (n_layers, lru_width, lru_width)),
        "b_x": jnp.zeros((n_layers, lru_width)),
        # Lambda init so a^c in [0.9, 0.999] (Griffin's init)
        "lam": jnp.log(jnp.expm1(
            jnp.linspace(2.0, 6.0, lru_width)))[None].repeat(n_layers, 0),
        "w_out": common.dense_init(ks[5], (n_layers, lru_width, d_model),
                                   in_axis=-2),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x: (B, T, C), w: (W, C). Returns y, new_state."""
    width = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(width))
    new_state = xp[:, -(width - 1):] if width > 1 else None
    return y + b, new_state


def _rglru_gates(u, p):
    """u: (B, T, lru). Returns (a, bterm) of the recurrence h = a h- + b."""
    r = jax.nn.sigmoid(jnp.einsum("btl,lm->btm", u, p["w_a"].astype(u.dtype))
                       + p["b_a"].astype(u.dtype))
    i = jax.nn.sigmoid(jnp.einsum("btl,lm->btm", u, p["w_x"].astype(u.dtype))
                       + p["b_x"].astype(u.dtype))
    log_a = (-C_GATE * r.astype(jnp.float32)
             * jax.nn.softplus(p["lam"].astype(jnp.float32)))
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    bterm = mult * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, bterm


def rglru_scan(u, p, h0=None):
    """Associative scan over time. u: (B, T, lru) -> (y, h_last)."""
    a, bterm = _rglru_gates(u, p)
    if h0 is not None:
        # fold initial state into the first step
        bterm = bterm.at[:, 0].add(a[:, 0] * h0)

    def combine(l, r):
        (al, bl), (ar, br) = l, r
        return al * ar, ar * bl + br

    a_s, h = jax.lax.associative_scan(combine, (a, bterm), axis=1)
    return h.astype(u.dtype), h[:, -1]


def rglru_step(u, p, h):
    """Single decode step. u: (B, 1, lru), h: (B, lru)."""
    a, bterm = _rglru_gates(u, p)
    h_new = a[:, 0] * h + bterm[:, 0]
    return h_new[:, None].astype(u.dtype), h_new


def rglru_block(x, p, state=None, decode: bool = False):
    """Full Griffin recurrent block. state = (conv_state, h)."""
    u = jnp.einsum("btd,dl->btl", x, p["w_in"].astype(x.dtype))
    gate = jax.nn.gelu(jnp.einsum("btd,dl->btl", x, p["w_gate_br"].astype(x.dtype)))
    conv_state = state[0] if state is not None else None
    h0 = state[1] if state is not None else None
    u, conv_state_new = _causal_conv(u, p["conv_w"].astype(x.dtype),
                                     p["conv_b"].astype(x.dtype), conv_state)
    if decode:
        y, h_new = rglru_step(u, p, h0)
    else:
        y, h_new = rglru_scan(u, p, h0)
    out = jnp.einsum("btl,ld->btd", y * gate, p["w_out"].astype(x.dtype))
    return out, (conv_state_new, h_new)


def init_rglru_state(batch: int, lru_width: int, conv_width: int = 4,
                     dtype=jnp.bfloat16):
    return (jnp.zeros((batch, conv_width - 1, lru_width), dtype),
            jnp.zeros((batch, lru_width), jnp.float32))
