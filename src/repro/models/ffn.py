"""FFN variants: dense GLU / plain MLP, and top-k MoE.

MoE dispatch is capacity-based gather/scatter (no (T, E, C) one-hot):
each expert gathers its top-C tokens by router weight (priority-drop when
over capacity), computes its FFN on a dense (E, C, d) block via stacked-
weight einsum, and scatter-adds gated outputs. FLOPs = E*C*d*dff ~
top_k * T * d * dff * capacity_factor; the (E, C, d) blocks shard over
the "model"/expert axis (EP) or the d_ff axis (TP) per config.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


# ---------------------------------------------------------------------------
# dense
# ---------------------------------------------------------------------------

def init_dense_ffn(key, d_model: int, d_ff: int, act: str, n_layers: int):
    k1, k2 = jax.random.split(key)
    glu = act in ("swiglu", "geglu")
    wi_out = 2 * d_ff if glu else d_ff
    return {
        "wi": common.dense_init(k1, (n_layers, d_model, wi_out)),
        "wo": common.dense_init(k2, (n_layers, d_ff, d_model), in_axis=-2),
    }


def dense_ffn(x, p, act: str):
    """x: (B, T, d); p per-layer slice {wi, wo}."""
    h = jnp.einsum("btd,df->btf", x, p["wi"].astype(x.dtype))
    if act in ("swiglu", "geglu"):
        g, u = jnp.split(h, 2, axis=-1)
        fn = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = fn(g) * u
    else:
        h = common.act_fn(act)(h)
    return jnp.einsum("btf,fd->btd", h, p["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def init_moe(key, d_model: int, d_expert: int, n_experts: int, act: str,
             n_layers: int, n_shared: int = 0):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    glu = act in ("swiglu", "geglu")
    wi_out = 2 * d_expert if glu else d_expert
    p = {
        "router": common.dense_init(k1, (n_layers, d_model, n_experts)),
        "wi": common.dense_init(k2, (n_layers, n_experts, d_model, wi_out)),
        "wo": common.dense_init(k3, (n_layers, n_experts, d_expert, d_model),
                                in_axis=-2),
    }
    if n_shared:
        p["shared"] = init_dense_ffn(k4, d_model, n_shared * d_expert, act, n_layers)
    return p


def moe_ffn(x, p, act: str, top_k: int, capacity_factor: float = 1.25,
            n_groups: int = 1):
    """x: (B, T, d). Returns (out, aux) with load-balance stats.

    Scalable dispatch: tokens are partitioned into `n_groups` routing
    groups (set to the number of DATA shards by the launcher so each
    group is device-local). Routing, capacity selection, and the gather
    into (G, E, C, d) blocks are group-local — no cross-shard token
    movement; the only collective is the expert-parallel reduce of the
    scatter-add output (classic EP all-to-all/reduce-scatter pattern,
    inserted by GSPMD from the sharding constraints below).
    """
    import math

    from repro.parallel.sharding import shard

    b, t, d = x.shape
    e = p["router"].shape[-1]
    n_tok = b * t
    g_cnt = n_groups if n_tok % n_groups == 0 else 1
    tl = n_tok // g_cnt                                           # tokens/group
    xg_ = x.reshape(g_cnt, tl, d)
    xg_ = shard(xg_, "batch", None, None)

    logits = jnp.einsum("gtd,de->gte", xg_.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_k, idx_k = jax.lax.top_k(probs, top_k)                   # (G, t, k)
    gate_k = gate_k / jnp.maximum(gate_k.sum(-1, keepdims=True), 1e-9)

    onehot = jax.nn.one_hot(idx_k, e, dtype=jnp.float32)          # (G, t, k, E)
    w_te = jnp.einsum("gtk,gtke->gte", gate_k, onehot)

    # capacity floor of 8 slots avoids drops at tiny token counts (decode);
    # at scale ceil(tl*k/E*cf) dominates, matching GShard-style capacity.
    cap = int(max(8, math.ceil(tl * top_k / e * capacity_factor)))
    cap = min(cap, tl)
    # each expert takes its per-group top-C tokens by gate (priority drop)
    top_w, top_i = jax.lax.top_k(jnp.swapaxes(w_te, 1, 2), cap)   # (G, E, C)

    gather = jax.vmap(lambda xr, ir: jnp.take(xr, ir, axis=0))    # per group
    xc = gather(xg_, top_i.reshape(g_cnt, e * cap))               # (G, E*C, d)
    xc = xc.reshape(g_cnt, e, cap, d)
    xc = shard(xc, "batch", "expert", None, None)
    h = jnp.einsum("gecd,edf->gecf", xc, p["wi"].astype(x.dtype))
    if act in ("swiglu", "geglu"):
        gt, u = jnp.split(h, 2, axis=-1)
        fn = jax.nn.silu if act == "swiglu" else jax.nn.gelu
        h = fn(gt) * u
    else:
        h = common.act_fn(act)(h)
    y = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(x.dtype))
    y = y * top_w[..., None].astype(y.dtype)
    y = shard(y, "batch", "expert", None, None)

    scatter = jax.vmap(lambda yr, ir: jnp.zeros((tl, d), yr.dtype)
                       .at[ir].add(yr))
    out = scatter(y.reshape(g_cnt, e * cap, d),
                  top_i.reshape(g_cnt, e * cap))                  # (G, t, d)
    out = shard(out, "batch", None, None)

    frac_tokens = jnp.mean(onehot.sum(2), axis=(0, 1))            # f_e
    frac_prob = jnp.mean(probs, axis=(0, 1))                      # P_e
    aux = {"lb_loss": e * jnp.sum(frac_tokens * frac_prob),
           "router_z": jnp.mean(jax.scipy.special.logsumexp(logits, -1) ** 2)}
    out = out.reshape(b, t, d)
    if "shared" in p:
        out = out + dense_ffn(x, p["shared"], act)
    return out, aux
