"""GQA attention: full / XLA-chunked-flash / decode-with-cache / local.

The chunked path is the dry-run/compile path (pure XLA, scan-based online
softmax, O(q_chunk * kv_chunk) live scores). On TPU the Pallas kernels in
repro.kernels take over via ops-level dispatch; numerics match ref.py.

Layout convention: q (B, S, H, Dh), k/v (B, S, K, Dh) with H = K * G.
Grouped matmuls keep the K axis explicit so GSPMD can shard heads without
materializing repeated KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common


def _split_groups(q, n_kv: int):
    b, s, h, dh = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, dh)


def attend_full(q, k, v, *, mask_kind: str = "causal", window: int = 0,
                prefix_len: int = 0, q_offset=0, scale: float | None = None):
    """Reference attention; used for small seqs, tests, and smoke configs."""
    b, sq, h, dh = q.shape
    n_kv = k.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    qg = _split_groups(q, n_kv)                                  # b s k g d
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = q_offset + jnp.arange(sq)
    kpos = jnp.arange(k.shape[1])
    pred = common.mask_fn(mask_kind, window, prefix_len)
    m = pred(qpos[:, None], kpos[None, :])                       # (sq, skv)
    s = jnp.where(m[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, dh)


def attend_chunked(q, k, v, *, mask_kind: str = "causal", window: int = 0,
                   prefix_len: int = 0, q_chunk: int = 512,
                   kv_chunk: int = 1024, scale: float | None = None):
    """Flash-style online-softmax attention in pure XLA (scan over chunks).

    Memory: O(B * H * q_chunk * kv_chunk) live scores.
    For mask_kind=="local", each q chunk attends a statically-sized
    [qs - window, qs + q_chunk) KV slice (exact, no wasted chunks).
    For causal, all KV chunks are scanned with masking (the known 2x FLOP
    overcount vs a triangular schedule — accounted in the roofline notes;
    the Pallas kernel skips fully-masked blocks at runtime).
    """
    import math
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    n_kv = k.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    # largest chunk <= requested that divides the length (prefix-extended
    # seqs like VLM 4096+256 are not powers of two)
    q_chunk = math.gcd(sq, min(q_chunk, sq))
    kv_chunk = math.gcd(skv, min(kv_chunk, skv))
    nq, nk = sq // q_chunk, skv // kv_chunk
    pred = common.mask_fn(mask_kind, window, prefix_len)
    qg = _split_groups(q, n_kv).reshape(b, nq, q_chunk, n_kv, h // n_kv, dh)

    if mask_kind == "local" and window and skv >= window + q_chunk:
        # pad KV so every q chunk sees a static window slice
        pad = window
        kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))

        def q_step(_, qi):
            qc = qg[:, qi]                                       # b qc k g d
            qs = qi * q_chunk
            kc = jax.lax.dynamic_slice_in_dim(kp, qs, window + q_chunk, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(vp, qs, window + q_chunk, axis=1)
            s = jnp.einsum("bqkgd,bjkd->bkgqj", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            qpos = qs + jnp.arange(q_chunk)
            kpos = qs - pad + jnp.arange(window + q_chunk)
            m = pred(qpos[:, None], kpos[None, :]) & (kpos[None, :] >= 0)
            s = jnp.where(m[None, None, None], s, -1e30)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bkgqj,bjkd->bqkgd", p.astype(v.dtype), vc)
            return None, o.reshape(b, q_chunk, h, dh)

        # static slice sizes require concrete qi: unroll via scan over iota
        _, os = jax.lax.scan(
            lambda c, qi: q_step(c, qi), None, jnp.arange(nq))
        return jnp.moveaxis(os, 0, 1).reshape(b, sq, h, dh)

    kc_all = k.reshape(b, nk, kv_chunk, n_kv, dh)
    vc_all = v.reshape(b, nk, kv_chunk, n_kv, dh)

    def q_step(_, qi):
        qc = qg[:, qi]                                           # b qc k g d
        qpos = qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, kj):
            m_run, l_run, acc = carry
            kc = kc_all[:, kj]
            vc = vc_all[:, kj]
            s = jnp.einsum("bqkgd,bjkd->bkgqj", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)
            msk = pred(qpos[:, None], kpos[None, :])
            s = jnp.where(msk[None, None, None], s, -1e30)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
            alpha = jnp.exp(m_run - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l_run * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqj,bjkd->bkgqd", p, vc.astype(jnp.float32))
            acc = acc * alpha[..., None] + pv
            return (m_new, l_new, acc), None

        g = h // n_kv
        init = (jnp.full((b, n_kv, g, q_chunk), -jnp.inf, jnp.float32),
                jnp.zeros((b, n_kv, g, q_chunk), jnp.float32),
                jnp.zeros((b, n_kv, g, q_chunk, dh), jnp.float32))
        (m_f, l_f, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nk))
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]
        return None, jnp.moveaxis(o, 3, 1).reshape(b, q_chunk, h, dh).astype(q.dtype)

    _, os = jax.lax.scan(q_step, None, jnp.arange(nq))
    return jnp.moveaxis(os, 0, 1).reshape(b, sq, h, dh)


def attend_decode(q, k_cache, v_cache, cache_len, *, window: int = 0,
                  prefix_len: int = 0, scale: float | None = None):
    """One-token decode vs a (B, Smax, K, Dh) cache. cache_len masks tail."""
    b, sq, h, dh = q.shape
    n_kv = k_cache.shape[2]
    scale = scale if scale is not None else dh ** -0.5
    qg = _split_groups(q, n_kv)
    s = jnp.einsum("bqkgd,bjkd->bkgqj", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(k_cache.shape[1])
    cache_len = jnp.broadcast_to(jnp.asarray(cache_len), (b,))
    valid = kpos[None, :] < cache_len[:, None]
    if window:
        valid = valid & (kpos[None, :] >= cache_len[:, None] - window)
    s = jnp.where(valid[:, None, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bqkgd", p.astype(v_cache.dtype), v_cache)
    return o.reshape(b, sq, h, dh)
