"""Shared building blocks: norms, RoPE, activations, init, masks."""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis]
    scale = 1.0 / math.sqrt(fan_in)
    return jax.random.truncated_normal(key, -2, 2, shape, jnp.float32) * scale


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32) * 0.02


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x, gamma, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + gamma.astype(jnp.float32))).astype(dt)


def layernorm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


def apply_norm(x, p, norm_type: str):
    if norm_type == "layernorm":
        return layernorm(x, p["scale"], p["bias"])
    return rmsnorm(x, p["scale"])


def init_norm(d: int, norm_type: str):
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    return {"scale": jnp.zeros((d,), jnp.float32)}   # rmsnorm stored as delta


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float, fraction: float = 1.0):
    d_rot = int(d_head * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, d_rot, 2, dtype=jnp.float32) / d_rot))
    return inv, d_rot


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., seq, heads, d_head); positions: (..., seq)."""
    d_head = x.shape[-1]
    inv, d_rot = rope_freqs(d_head, theta, fraction)
    ang = positions[..., :, None].astype(jnp.float32) * inv          # (..., S, d_rot/2)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    sin = sin[..., :, None, :]
    cos = cos[..., :, None, :]
    xr = x[..., :d_rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    rot = jnp.stack([y1, y2], axis=-1).reshape(xr.shape).astype(x.dtype)
    if d_rot == d_head:
        return rot
    return jnp.concatenate([rot, x[..., d_rot:]], axis=-1)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {"gelu": jax.nn.gelu, "relu": jax.nn.relu, "silu": jax.nn.silu,
            "gelu_tanh": partial(jax.nn.gelu, approximate=True)}[name]


# ---------------------------------------------------------------------------
# masks (returned as additive bias-free boolean predicates on (q_pos, k_pos))
# ---------------------------------------------------------------------------

def mask_fn(kind: str, window: int = 0, prefix_len: int = 0):
    """Returns pred(q_pos, k_pos) -> bool allowed. Positions are absolute."""
    if kind == "causal":
        return lambda q, k: k <= q
    if kind == "local":
        return lambda q, k: (k <= q) & (k > q - window)
    if kind == "bidir":
        return lambda q, k: jnp.ones(
            jnp.broadcast_shapes(jnp.shape(q), jnp.shape(k)), bool)
    if kind == "prefix":
        return lambda q, k: (k <= q) | (k < prefix_len)
    raise ValueError(kind)


def cross_entropy(logits, labels, ignore_id: int = -1):
    """Mean token cross-entropy in fp32; labels==ignore_id masked out.

    The label logit is extracted with an iota-select reduction instead of
    take_along_axis: a gather along a vocab-sharded axis makes GSPMD
    all-gather the full logits (40 GB at 152k vocab); the masked
    reduction stays shard-local and fuses.
    """
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    ll = jnp.sum(jnp.where(iota == labels[..., None], logits, 0.0), axis=-1)
    nll = lse - ll
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
