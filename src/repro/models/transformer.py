"""Model assembly: decoder-only LM / hybrid / SSM / enc-dec / VLM.

Layer params are stacked on a leading L axis and consumed by lax.scan, so
HLO size is depth-independent (512-device dry-run compiles stay tractable
on one CPU core). Hybrid models scan over repeating super-blocks.

Entry points (all pure):
  init_params(key, cfg)
  forward_logits(params, cfg, batch)            train/eval forward
  train_loss(params, cfg, batch)                scalar loss + aux
  init_cache(cfg, batch, max_len)               decode cache pytree
  prefill(params, cfg, batch, max_len)          logits + filled cache
  decode_step(params, cfg, cache, batch, pos)   one-token step
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common, attention, ffn as ffn_mod, rglru, ssd
from repro.parallel.sharding import shard

FULL_ATTN_MAX = 2048          # above this, the chunked-flash path is used


def _cdtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ===========================================================================
# parameter init
# ===========================================================================

def _init_attn(key, cfg: ArchConfig, n: int):
    d, h, k, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    p = {
        "wq": common.dense_init(ks[0], (n, d, h * dh)),
        "wk": common.dense_init(ks[1], (n, d, k * dh)),
        "wv": common.dense_init(ks[2], (n, d, k * dh)),
        "wo": common.dense_init(ks[3], (n, h * dh, d), in_axis=-2),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((n, h * dh))
        p["bk"] = jnp.zeros((n, k * dh))
        p["bv"] = jnp.zeros((n, k * dh))
    return p


def _init_norms(cfg: ArchConfig, n: int, names=("ln1", "ln2")):
    out = {}
    for nm in names:
        base = common.init_norm(cfg.d_model, cfg.norm_type)
        out[nm] = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), base)
    return out


def _init_ffn(key, cfg: ArchConfig, n: int):
    if cfg.family == "moe":
        return {"moe": ffn_mod.init_moe(key, cfg.d_model, cfg.d_expert,
                                        cfg.n_experts, cfg.act, n,
                                        cfg.n_shared_experts)}
    return {"ffn": ffn_mod.init_dense_ffn(key, cfg.d_model, cfg.d_ff, cfg.act, n)}


def init_params(key, cfg: ArchConfig):
    keys = jax.random.split(key, 12)
    d, v = cfg.d_model, cfg.vocab_size
    params: dict = {"embed": common.embed_init(keys[0], (v, d))}
    if not cfg.tie_embeddings:
        params["unembed"] = common.dense_init(keys[1], (d, v))
    params["final_norm"] = common.init_norm(d, cfg.norm_type)

    if cfg.family == "ssm":
        params["layers"] = {
            "ssd": ssd.init_ssd_block(keys[2], d, cfg.n_layers, cfg.ssm_state,
                                      cfg.ssm_expand, cfg.ssm_head_dim,
                                      cfg.conv_width),
            **_init_norms(cfg, cfg.n_layers, ("ln1",)),
        }
    elif cfg.family == "hybrid":
        kinds = cfg._layer_kinds()
        n_rec = kinds.count("rec")
        n_att = kinds.count("attn")
        params["rec_layers"] = {
            "mix": rglru.init_rglru_block(keys[2], d, cfg.lru_width, n_rec,
                                          cfg.conv_width),
            **_init_ffn(keys[3], cfg, n_rec), **_init_norms(cfg, n_rec),
        }
        params["attn_layers"] = {
            "attn": _init_attn(keys[4], cfg, n_att),
            **_init_ffn(keys[5], cfg, n_att), **_init_norms(cfg, n_att),
        }
    elif cfg.family == "encdec":
        params["enc_layers"] = {
            "attn": _init_attn(keys[2], cfg, cfg.n_enc_layers),
            **_init_ffn(keys[3], cfg, cfg.n_enc_layers),
            **_init_norms(cfg, cfg.n_enc_layers),
        }
        params["layers"] = {
            "attn": _init_attn(keys[4], cfg, cfg.n_layers),
            "xattn": _init_attn(keys[5], cfg, cfg.n_layers),
            **_init_ffn(keys[6], cfg, cfg.n_layers),
            **_init_norms(cfg, cfg.n_layers, ("ln1", "ln2", "ln3")),
        }
        params["enc_norm"] = common.init_norm(d, cfg.norm_type)
        params["frontend"] = common.dense_init(keys[7], (d, d))
    else:                                   # dense / moe / vlm
        params["layers"] = {
            "attn": _init_attn(keys[2], cfg, cfg.n_layers),
            **_init_ffn(keys[3], cfg, cfg.n_layers),
            **_init_norms(cfg, cfg.n_layers),
        }
        if cfg.family == "vlm":
            params["frontend"] = common.dense_init(keys[7], (d, d))
    return params


# ===========================================================================
# blocks
# ===========================================================================

def _qkv(x, lp, cfg: ArchConfig, positions):
    b, s, _ = x.shape
    h, k, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dh->bsh", x, lp["wq"].astype(x.dtype))
    kk = jnp.einsum("bsd,dh->bsh", x, lp["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, lp["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + lp["bq"].astype(x.dtype)
        kk = kk + lp["bk"].astype(x.dtype)
        v = v + lp["bv"].astype(x.dtype)
    q = q.reshape(b, s, h, dh)
    kk = kk.reshape(b, s, k, dh)
    v = v.reshape(b, s, k, dh)
    q = common.apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
    kk = common.apply_rope(kk, positions, cfg.rope_theta, cfg.rope_fraction)
    q = shard(q, "batch", None, "model", None)
    kk = shard(kk, "batch", None, None, None)
    return q, kk, v


def _quant_kv(t):
    """Per-(batch, head) symmetric int8 quant of one token's K or V."""
    scale = jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.round(t.astype(jnp.float32) / scale[..., None])
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def _attn_mix(x, lp, cfg: ArchConfig, *, mask_kind, positions, window=0,
              prefix_len=0, cache=None, pos=None):
    """Attention mixer. cache = (k, v[, k_scale, v_scale]) buffers."""
    q, k, v = _qkv(x, lp, cfg, positions)
    b, s = x.shape[:2]
    if cache is not None:
        int8kv = len(cache) == 4
        ck, cv = cache[0], cache[1]
        max_len = ck.shape[1]
        slot = pos % max_len if window else jnp.minimum(pos, max_len - 1)
        if int8kv:
            cks, cvs = cache[2], cache[3]
            kq, ks_new = _quant_kv(k[:, 0])
            vq, vs_new = _quant_kv(v[:, 0])
            ck = jax.lax.dynamic_update_index_in_dim(ck, kq, slot, axis=1)
            cv = jax.lax.dynamic_update_index_in_dim(cv, vq, slot, axis=1)
            cks = jax.lax.dynamic_update_index_in_dim(cks, ks_new, slot, axis=1)
            cvs = jax.lax.dynamic_update_index_in_dim(cvs, vs_new, slot, axis=1)
            dk = ck.astype(jnp.bfloat16) * cks[..., None].astype(jnp.bfloat16)
            dv = cv.astype(jnp.bfloat16) * cvs[..., None].astype(jnp.bfloat16)
            new_cache = (ck, cv, cks, cvs)
        else:
            ck = jax.lax.dynamic_update_index_in_dim(
                ck, k[:, 0].astype(ck.dtype), slot, axis=1)
            cv = jax.lax.dynamic_update_index_in_dim(
                cv, v[:, 0].astype(cv.dtype), slot, axis=1)
            dk, dv = ck, cv
            new_cache = (ck, cv)
        if window:
            # ring buffer: every slot is valid once pos >= window
            o = attention.attend_decode(q, dk, dv,
                                        jnp.minimum(pos + 1, max_len))
        else:
            o = attention.attend_decode(q, dk, dv, pos + 1)
    else:
        if s <= FULL_ATTN_MAX:
            o = attention.attend_full(q, k, v, mask_kind=mask_kind,
                                      window=window, prefix_len=prefix_len)
        else:
            o = attention.attend_chunked(q, k, v, mask_kind=mask_kind,
                                         window=window, prefix_len=prefix_len)
        new_cache = (k, v)
    o = o.reshape(b, s, cfg.n_heads * cfg.d_head)
    out = jnp.einsum("bsh,hd->bsd", o, lp["wo"].astype(x.dtype))
    return out, new_cache


def _ffn_apply(x, lp, cfg: ArchConfig):
    if cfg.family == "moe":
        out, aux = ffn_mod.moe_ffn(x, lp["moe"], cfg.act, cfg.moe_top_k,
                                   cfg.capacity_factor, cfg.moe_groups)
        return out, aux
    return ffn_mod.dense_ffn(x, lp["ffn"], cfg.act), {}


def _decoder_layer(x, lp, cfg: ArchConfig, *, mask_kind, positions,
                   window=0, prefix_len=0, cache=None, pos=None,
                   xa=None):
    """One residual block: [attn or mixer] + ffn. Returns (x, cache, aux)."""
    h = common.apply_norm(x, lp["ln1"], cfg.norm_type)
    att, new_cache = _attn_mix(h, lp["attn"], cfg, mask_kind=mask_kind,
                               positions=positions, window=window,
                               prefix_len=prefix_len, cache=cache, pos=pos)
    x = x + att
    if xa is not None:                     # enc-dec cross attention
        h = common.apply_norm(x, lp["ln3"], cfg.norm_type)
        ca, _ = _cross_attn(h, lp["xattn"], cfg, xa)
        x = x + ca
    h = common.apply_norm(x, lp["ln2"], cfg.norm_type)
    f, aux = _ffn_apply(h, lp, cfg)
    x = x + f
    x = shard(x, "batch", "seq", None)
    return x, new_cache, aux


def _cross_attn(x, lp, cfg: ArchConfig, enc_out):
    """Cross-attention to (precomputed) encoder states; no RoPE on keys."""
    b, s, _ = x.shape
    h, k, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,dh->bsh", x, lp["wq"].astype(x.dtype)).reshape(b, s, h, dh)
    kk = jnp.einsum("bsd,dh->bsh", enc_out, lp["wk"].astype(x.dtype)) \
        .reshape(b, enc_out.shape[1], k, dh)
    v = jnp.einsum("bsd,dh->bsh", enc_out, lp["wv"].astype(x.dtype)) \
        .reshape(b, enc_out.shape[1], k, dh)
    if enc_out.shape[1] <= FULL_ATTN_MAX or s > 1:
        o = attention.attend_full(q, kk, v, mask_kind="bidir") \
            if enc_out.shape[1] <= FULL_ATTN_MAX else \
            attention.attend_chunked(q, kk, v, mask_kind="bidir")
    else:
        o = attention.attend_decode(q, kk, v, kk.shape[1])
    o = o.reshape(b, s, h * dh)
    return jnp.einsum("bsh,hd->bsd", o, lp["wo"].astype(x.dtype)), None


def _rec_layer(x, lp, cfg: ArchConfig, *, state=None, decode=False):
    h = common.apply_norm(x, lp["ln1"], cfg.norm_type)
    mix, new_state = rglru.rglru_block(h, lp["mix"], state, decode)
    x = x + mix
    h = common.apply_norm(x, lp["ln2"], cfg.norm_type)
    f, aux = _ffn_apply(h, lp, cfg)
    return x + f, new_state, aux


def _ssd_layer(x, lp, cfg: ArchConfig, *, state=None, decode=False):
    h = common.apply_norm(x, lp["ln1"], cfg.norm_type)
    mix, new_state = ssd.ssd_block(h, lp["ssd"], cfg, state, decode)
    return x + mix, new_state, {}


# ===========================================================================
# stacks (scan over layers)
# ===========================================================================

def _scan_uniform(x, stacked, layer_fn, remat: bool, unroll: int = 1):
    """Scan a uniform stack; layer_fn(x, lp) -> (x, aux_scalar_dict)."""
    def body(carry, lp):
        x, aux_acc = carry
        fn = jax.checkpoint(layer_fn) if remat else layer_fn
        x, aux = fn(x, lp)
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()} \
            if aux else aux_acc
        return (x, aux_acc), None

    length = jax.tree.leaves(stacked)[0].shape[0]
    (x, aux), _ = jax.lax.scan(body, (x, {k: jnp.zeros(()) for k in
                                          _aux_keys(stacked)}), stacked,
                               unroll=min(unroll, length))
    return x, aux


def _aux_keys(stacked) -> tuple[str, ...]:
    return ("lb_loss", "router_z") if "moe" in stacked else ()


def _scan_with_cache(x, stacked, cache, layer_fn, remat: bool = False,
                     unroll: int = 1):
    """Scan stack + per-layer cache; emits updated cache as scan ys."""
    def body(carry, xs):
        x, aux_acc = carry
        lp, cache_l = xs
        fn = jax.checkpoint(layer_fn) if remat else layer_fn
        x, new_cache, aux = fn(x, lp, cache_l)
        aux_acc = {k: aux_acc.get(k, 0.0) + v for k, v in aux.items()} \
            if aux else aux_acc
        return (x, aux_acc), new_cache

    init_aux = {k: jnp.zeros(()) for k in _aux_keys(stacked)}
    length = jax.tree.leaves(stacked)[0].shape[0]
    (x, aux), new_cache = jax.lax.scan(body, (x, init_aux), (stacked, cache),
                                       unroll=min(unroll, length))
    return x, new_cache, aux


# ===========================================================================
# embedding / head
# ===========================================================================

def _embed(params, cfg: ArchConfig, batch, *, decode=False, pos=None):
    """Token (+stub-modal) embedding. Returns (x, prefix_len)."""
    dt = _cdtype(cfg)
    tokens = batch["tokens"]
    x = jnp.take(params["embed"], tokens, axis=0).astype(dt)
    x = x * jnp.asarray(math.sqrt(cfg.d_model), dt)
    prefix_len = 0
    if cfg.family == "vlm" and not decode:
        patches = batch["patches"].astype(dt)                 # (B, P, d) stub
        patches = jnp.einsum("bpd,de->bpe", patches,
                             params["frontend"].astype(dt))
        x = jnp.concatenate([patches, x], axis=1)
        prefix_len = patches.shape[1]
    return shard(x, "batch", "seq", None), prefix_len


def _head(x, params, cfg: ArchConfig):
    dt = x.dtype
    x = common.apply_norm(x, params["final_norm"], cfg.norm_type)
    w = (params["embed"].T if cfg.tie_embeddings else params["unembed"])
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(dt))
    return shard(logits, "batch", "seq", "vocab")


# ===========================================================================
# forward passes
# ===========================================================================

def _run_stack(params, cfg: ArchConfig, x, positions, *, mask_kind,
               prefix_len=0, remat=False):
    """Training/eval forward through the body (no cache)."""
    if cfg.family == "ssm":
        def fn(x, lp):
            y, _, aux = _ssd_layer(x, lp, cfg)
            return y, aux
        x, aux = _scan_uniform(x, params["layers"], fn, remat, cfg.scan_unroll)
        return x, aux
    if cfg.family == "hybrid":
        return _run_hybrid(params, cfg, x, positions, remat=remat)
    if cfg.family == "encdec":
        raise ValueError("use forward_encdec")

    def fn(x, lp):
        y, _, aux = _decoder_layer(x, lp, cfg, mask_kind=mask_kind,
                                   positions=positions,
                                   window=cfg.window_size,
                                   prefix_len=prefix_len)
        return y, aux
    x, aux = _scan_uniform(x, params["layers"], fn, remat, cfg.scan_unroll)
    return x, aux


def _hybrid_split(cfg: ArchConfig):
    kinds = cfg._layer_kinds()
    pat = list(cfg.block_pattern)
    n_full = cfg.n_layers // len(pat)
    rem = kinds[n_full * len(pat):]
    return pat, n_full, rem


def _run_hybrid(params, cfg: ArchConfig, x, positions, *, remat=False):
    pat, n_full, rem = _hybrid_split(cfg)
    n_rec_pat = pat.count("rec")
    n_att_pat = pat.count("attn")
    rec, att = params["rec_layers"], params["attn_layers"]
    rec_main = jax.tree.map(
        lambda a: a[:n_full * n_rec_pat].reshape(
            (n_full, n_rec_pat) + a.shape[1:]), rec)
    att_main = jax.tree.map(
        lambda a: a[:n_full * n_att_pat].reshape(
            (n_full, n_att_pat) + a.shape[1:]), att)

    def super_block(x, xs):
        rp, ap = xs
        ri = ai = 0
        for kind in pat:
            if kind == "rec":
                lp = jax.tree.map(lambda a: a[ri], rp)
                x, _, _ = _rec_layer(x, lp, cfg)
                ri += 1
            else:
                lp = jax.tree.map(lambda a: a[ai], ap)
                x, _, _ = _decoder_layer(x, lp, cfg, mask_kind="local",
                                         positions=positions,
                                         window=cfg.window_size)
                ai += 1
        return x, {}

    x, _ = _scan_uniform(x, (rec_main, att_main),
                         lambda x, xs: super_block(x, xs), remat,
                         cfg.scan_unroll)
    # remainder layers (at most one pattern's worth) — unrolled
    ri = n_full * n_rec_pat
    ai = n_full * n_att_pat
    for kind in rem:
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[ri], rec)
            x, _, _ = _rec_layer(x, lp, cfg)
            ri += 1
        else:
            lp = jax.tree.map(lambda a: a[ai], att)
            x, _, _ = _decoder_layer(x, lp, cfg, mask_kind="local",
                                     positions=positions,
                                     window=cfg.window_size)
            ai += 1
    return x, {}


def forward_encoder(params, cfg: ArchConfig, batch):
    dt = _cdtype(cfg)
    frames = batch["frames"].astype(dt)                        # (B, S, d) stub
    x = jnp.einsum("bsd,de->bse", frames, params["frontend"].astype(dt))
    positions = jnp.arange(x.shape[1])

    def fn(x, lp):
        y, _, aux = _decoder_layer(x, lp, cfg, mask_kind="bidir",
                                   positions=positions)
        return y, aux
    x, _ = _scan_uniform(x, params["enc_layers"], fn, remat=False,
                       unroll=cfg.scan_unroll)
    return common.apply_norm(x, params["enc_norm"], cfg.norm_type)


def forward_logits(params, cfg: ArchConfig, batch, *, remat=False):
    """Teacher-forced logits over the full sequence."""
    if cfg.family == "encdec":
        enc = forward_encoder(params, cfg, batch)
        x, _ = _embed(params, cfg, batch)
        positions = jnp.arange(x.shape[1])

        def fn(x, lp):
            y, _, aux = _decoder_layer(x, lp, cfg, mask_kind="causal",
                                       positions=positions, xa=enc)
            return y, aux
        x, aux = _scan_uniform(x, params["layers"], fn, remat, cfg.scan_unroll)
        return _head(x, params, cfg), aux
    x, prefix_len = _embed(params, cfg, batch)
    positions = jnp.arange(x.shape[1])
    mask_kind = "prefix" if cfg.family == "vlm" else "causal"
    x, aux = _run_stack(params, cfg, x, positions, mask_kind=mask_kind,
                        prefix_len=prefix_len, remat=remat)
    return _head(x, params, cfg), aux


def train_loss(params, cfg: ArchConfig, batch, *, remat=True):
    logits, aux = forward_logits(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    if cfg.family == "vlm":                       # image prefix carries no loss
        pad = jnp.full(labels.shape[:1] + (logits.shape[1] - labels.shape[1],),
                       -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = common.cross_entropy(logits, labels)
    if aux:
        loss = loss + 0.01 * aux.get("lb_loss", 0.0) + 1e-4 * aux.get("router_z", 0.0)
    return loss, aux


# ===========================================================================
# serving: cache init / prefill / decode
# ===========================================================================

def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    dt = _cdtype(cfg)
    k, dh = cfg.n_kv_heads, cfg.d_head
    if cfg.family == "ssm":
        d_in = cfg.ssm_expand * cfg.d_model
        nh = d_in // cfg.ssm_head_dim
        d_xbc = d_in + 2 * cfg.ssm_state
        return {"conv": jnp.zeros((cfg.n_layers, batch, cfg.conv_width - 1, d_xbc), dt),
                "ssm": jnp.zeros((cfg.n_layers, batch, nh, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)}
    if cfg.family == "hybrid":
        kinds = cfg._layer_kinds()
        n_rec, n_att = kinds.count("rec"), kinds.count("attn")
        w = min(cfg.window_size, max_len)
        return {"conv": jnp.zeros((n_rec, batch, cfg.conv_width - 1,
                                   cfg.lru_width), dt),
                "h": jnp.zeros((n_rec, batch, cfg.lru_width), jnp.float32),
                "k": jnp.zeros((n_att, batch, w, k, dh), dt),
                "v": jnp.zeros((n_att, batch, w, k, dh), dt)}
    kv_dt = jnp.int8 if (cfg.kv_cache_dtype == "int8"
                         and cfg.family in ("dense", "vlm")) else dt
    cache = {"k": jnp.zeros((cfg.n_layers, batch, max_len, k, dh), kv_dt),
             "v": jnp.zeros((cfg.n_layers, batch, max_len, k, dh), kv_dt)}
    if kv_dt == jnp.int8:
        cache["ks"] = jnp.zeros((cfg.n_layers, batch, max_len, k), jnp.float32)
        cache["vs"] = jnp.zeros((cfg.n_layers, batch, max_len, k), jnp.float32)
    if cfg.family == "encdec":
        cache["xk"] = jnp.zeros((cfg.n_layers, batch, max_len, k, dh), dt)
        cache["xv"] = jnp.zeros((cfg.n_layers, batch, max_len, k, dh), dt)
    return cache


def decode_step(params, cfg: ArchConfig, cache, batch, pos):
    """One new token against a filled cache. batch: {"tokens": (B, 1)}."""
    x, _ = _embed(params, cfg, batch, decode=True, pos=pos)
    positions = jnp.full((1,), pos)

    if cfg.family == "ssm":
        def fn(x, lp, cache_l):
            conv, ssm_state = cache_l
            y, new_state, aux = _ssd_layer(x, lp, cfg,
                                           state=(conv, ssm_state), decode=True)
            return y, new_state, aux
        x, new_cache, _ = _scan_with_cache(
            x, params["layers"], (cache["conv"], cache["ssm"]), fn,
            unroll=cfg.scan_unroll)
        cache = {"conv": new_cache[0], "ssm": new_cache[1]}
    elif cfg.family == "hybrid":
        x, cache = _decode_hybrid(params, cfg, cache, x, positions, pos)
    elif cfg.family == "encdec":
        def fn(x, lp, cache_l):
            ck, cv, xk, xv = cache_l
            h = common.apply_norm(x, lp["ln1"], cfg.norm_type)
            att, (nk, nv) = _attn_mix(h, lp["attn"], cfg, mask_kind="causal",
                                      positions=positions, cache=(ck, cv),
                                      pos=pos)
            x = x + att
            h = common.apply_norm(x, lp["ln3"], cfg.norm_type)
            q = jnp.einsum("bsd,dh->bsh", h, lp["xattn"]["wq"].astype(h.dtype))
            b = x.shape[0]
            q = q.reshape(b, 1, cfg.n_heads, cfg.d_head)
            o = attention.attend_decode(q, xk, xv, xk.shape[1])
            o = o.reshape(b, 1, cfg.n_heads * cfg.d_head)
            x = x + jnp.einsum("bsh,hd->bsd", o,
                               lp["xattn"]["wo"].astype(h.dtype))
            h = common.apply_norm(x, lp["ln2"], cfg.norm_type)
            f, aux = _ffn_apply(h, lp, cfg)
            return x + f, (nk, nv, xk, xv), aux
        x, new_cache, _ = _scan_with_cache(
            x, params["layers"],
            (cache["k"], cache["v"], cache["xk"], cache["xv"]), fn,
            unroll=cfg.scan_unroll)
        cache = dict(zip(("k", "v", "xk", "xv"), new_cache))
    else:
        int8kv = "ks" in cache
        cache_xs = (cache["k"], cache["v"], cache["ks"], cache["vs"]) \
            if int8kv else (cache["k"], cache["v"])

        def fn(x, lp, cache_l):
            return _decoder_layer(x, lp, cfg, mask_kind="causal",
                                  positions=positions, cache=cache_l, pos=pos)
        x, new_cache, _ = _scan_with_cache(
            x, params["layers"], cache_xs, fn, unroll=cfg.scan_unroll)
        cache = {"k": new_cache[0], "v": new_cache[1]}
        if int8kv:
            cache["ks"], cache["vs"] = new_cache[2], new_cache[3]
    logits = _head(x, params, cfg)
    return logits[:, -1], cache


def _decode_hybrid(params, cfg: ArchConfig, cache, x, positions, pos):
    pat, n_full, rem = _hybrid_split(cfg)
    kinds = cfg._layer_kinds()
    rec, att = params["rec_layers"], params["attn_layers"]
    new_conv, new_h, new_k, new_v = [], [], [], []
    ri = ai = 0
    for kind in kinds:                    # decode is cheap: unrolled is fine
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[ri], rec)
            st = (cache["conv"][ri], cache["h"][ri])
            x, (c2, h2), _ = _rec_layer(x, lp, cfg, state=st, decode=True)
            new_conv.append(c2)
            new_h.append(h2)
            ri += 1
        else:
            lp = jax.tree.map(lambda a: a[ai], att)
            x, (k2, v2), _ = _decoder_layer(
                x, lp, cfg, mask_kind="causal", positions=positions,
                window=cfg.window_size, cache=(cache["k"][ai], cache["v"][ai]),
                pos=pos)
            new_k.append(k2)
            new_v.append(v2)
            ai += 1
    cache = {"conv": jnp.stack(new_conv), "h": jnp.stack(new_h),
             "k": jnp.stack(new_k), "v": jnp.stack(new_v)}
    return x, cache


def prefill(params, cfg: ArchConfig, batch, max_len: int | None = None):
    """Process the full prompt; return (last-token logits, cache)."""
    tokens = batch["tokens"]
    b, s = tokens.shape[0], tokens.shape[1]
    max_len = max_len or s
    x, prefix_len = _embed(params, cfg, batch)
    positions = jnp.arange(x.shape[1])

    if cfg.family == "ssm":
        def fn(x, lp, _c):
            y, st, aux = _ssd_layer(x, lp, cfg, state=None)
            return y, st, aux
        dummy = jnp.zeros((cfg.n_layers,))
        x, states, _ = _scan_with_cache(x, params["layers"], dummy, fn,
                                        unroll=cfg.scan_unroll)
        cache = {"conv": states[0], "ssm": states[1]}
        # head on the last position only (full (B,S,V) logits would be
        # the dominant memory traffic of prefill, e.g. 638 GB @32k/152k)
        return _head(x[:, -1:], params, cfg)[:, -1], cache

    if cfg.family == "hybrid":
        return _prefill_hybrid(params, cfg, batch, x, positions, max_len)

    if cfg.family == "encdec":
        enc = forward_encoder(params, cfg, batch)
        def fn(x, lp, _c):
            h = common.apply_norm(x, lp["ln1"], cfg.norm_type)
            att, (k2, v2) = _attn_mix(h, lp["attn"], cfg, mask_kind="causal",
                                      positions=positions)
            x = x + att
            h = common.apply_norm(x, lp["ln3"], cfg.norm_type)
            ca, _ = _cross_attn(h, lp["xattn"], cfg, enc)
            x = x + ca
            h2 = common.apply_norm(x, lp["ln2"], cfg.norm_type)
            f, aux = _ffn_apply(h2, lp, cfg)
            xk = jnp.einsum("bsd,dh->bsh", enc, lp["xattn"]["wk"].astype(x.dtype)) \
                .reshape(b, enc.shape[1], cfg.n_kv_heads, cfg.d_head)
            xv = jnp.einsum("bsd,dh->bsh", enc, lp["xattn"]["wv"].astype(x.dtype)) \
                .reshape(b, enc.shape[1], cfg.n_kv_heads, cfg.d_head)
            return x + f, (k2, v2, xk, xv), aux
        dummy = jnp.zeros((cfg.n_layers,))
        x, caches, _ = _scan_with_cache(x, params["layers"], dummy, fn,
                                        unroll=cfg.scan_unroll)
        k2, v2, xk, xv = caches
        cache = {"k": _pad_cache(k2, max_len), "v": _pad_cache(v2, max_len),
                 "xk": xk, "xv": xv}
        return _head(x[:, -1:], params, cfg)[:, -1], cache

    mask_kind = "prefix" if cfg.family == "vlm" else "causal"

    def fn(x, lp, _c):
        return _decoder_layer(x, lp, cfg, mask_kind=mask_kind,
                              positions=positions, window=cfg.window_size,
                              prefix_len=prefix_len)
    dummy = jnp.zeros((cfg.n_layers,))
    x, caches, _ = _scan_with_cache(x, params["layers"], dummy, fn,
                                    unroll=cfg.scan_unroll)
    k2, v2 = caches
    if cfg.kv_cache_dtype == "int8" and cfg.family in ("dense", "vlm"):
        kq, ks = _quant_kv(k2)
        vq, vs = _quant_kv(v2)
        cache = {"k": _pad_cache(kq, max_len), "v": _pad_cache(vq, max_len),
                 "ks": _pad_cache(ks, max_len), "vs": _pad_cache(vs, max_len)}
    else:
        cache = {"k": _pad_cache(k2, max_len), "v": _pad_cache(v2, max_len)}
    # head on the last position only: full (B,S,V) logits would be the
    # dominant memory traffic of prefill (e.g. 638 GB at 32k x 152k)
    return _head(x[:, -1:], params, cfg)[:, -1], cache


def _pad_cache(c, max_len: int):
    s = c.shape[2]
    if s >= max_len:
        return c[:, :, :max_len]
    pad = [(0, 0)] * c.ndim
    pad[2] = (0, max_len - s)
    return jnp.pad(c, pad)


def _prefill_hybrid(params, cfg: ArchConfig, batch, x, positions, max_len):
    kinds = cfg._layer_kinds()
    rec, att = params["rec_layers"], params["attn_layers"]
    b = x.shape[0]
    w = min(cfg.window_size, max_len)
    convs, hs, ks, vs = [], [], [], []
    ri = ai = 0
    for kind in kinds:
        if kind == "rec":
            lp = jax.tree.map(lambda a: a[ri], rec)
            x, (c2, h2), _ = _rec_layer(x, lp, cfg)
            convs.append(c2)
            hs.append(h2)
            ri += 1
        else:
            lp = jax.tree.map(lambda a: a[ai], att)
            x, (k2, v2), _ = _decoder_layer(x, lp, cfg, mask_kind="local",
                                            positions=positions,
                                            window=cfg.window_size)
            # ring-order the window slice: decode writes at pos % w, so the
            # token at absolute position p must sit in slot p % w
            s_full = k2.shape[1]
            p0 = max(s_full - w, 0)
            ks.append(jnp.roll(k2[:, -w:], shift=p0 % w, axis=1))
            vs.append(jnp.roll(v2[:, -w:], shift=p0 % w, axis=1))
            ai += 1
    cache = {"conv": jnp.stack(convs), "h": jnp.stack(hs),
             "k": jnp.stack(ks), "v": jnp.stack(vs)}
    # head on the last position only: full (B,S,V) logits would be the
    # dominant memory traffic of prefill (e.g. 638 GB at 32k x 152k)
    return _head(x[:, -1:], params, cfg)[:, -1], cache
