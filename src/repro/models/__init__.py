"""Composable model zoo.

All models are pure-functional pytrees + apply functions. Layer parameters
are stacked on a leading axis and consumed with jax.lax.scan so the HLO is
O(1) in depth (critical for 512-device dry-run compile times on one CPU).

Modules:
  common.py       norms, rope, activations, initializers, masks
  attention.py    GQA attention: full / chunked-flash(XLA) / decode / local
  ffn.py          dense GLU/MLP and top-k MoE (capacity gather dispatch)
  rglru.py        Griffin RG-LRU recurrent block (associative scan)
  ssd.py          Mamba-2 SSD mixer (chunked) + single-step decode
  transformer.py  decoder-only LM / enc-dec assembly, prefill/decode paths
"""
from repro.models.transformer import (
    init_params, forward_logits, train_loss, prefill, decode_step,
    init_cache,
)
