"""MLP emulators for fused nonlinear operators (paper §4.3).

Each emulator is a 2-layer MLP (Linear -> ReLU -> Linear) substituting a
*group* of nonlinear ops while reducing the dimension the nonlinearity is
evaluated at:

  MLP_sm  softmax over attention scores:   R^S -> R^h -> R^S  (h = 2..16)
  MLP_ln  rsqrt(var + eps) in LayerNorm:   R^1 -> R^h -> R^1
  MLP_se  softmax(logits) + entropy fused: R^C -> R^h -> R^1

Ex-vivo training (paper: 5.12M synthetic points): estimate Gaussian
<mu, sigma> from activations observed while finetuning M_g on the
bootstrap sample, synthesize inputs from that Gaussian, regress onto the
true operator outputs. In-vivo: the inserted MLPs are co-tuned with the
proxy end-to-end (proxy.py).

Both execution paths live in the engine layer (the substrate-dispatch
API): `engine/clear.mlp_apply` and `engine/mpc.mlp_apply_mpc` — the
share-level path is 2 secure matmuls + low-dim ReLU, which is where the
MPC savings come from.  This module owns *fitting* (ex-vivo
Gaussian-synthesis training); import the apply paths from the engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.engine import clear as _clear


def init_mlp(key, d_in: int, hidden: int, d_out: int):
    k1, k2 = jax.random.split(key)
    s1 = (2.0 / (d_in + hidden)) ** 0.5
    s2 = (2.0 / (hidden + d_out)) ** 0.5
    return {"w1": jax.random.normal(k1, (d_in, hidden)) * s1,
            "b1": jnp.zeros((hidden,)),
            "w2": jax.random.normal(k2, (hidden, d_out)) * s2,
            "b2": jnp.zeros((d_out,))}


# ---------------------------------------------------------------------------
# the three target operators
# ---------------------------------------------------------------------------

def op_softmax(x):
    return jax.nn.softmax(x, axis=-1)


def op_rsqrt(v, eps: float = 1e-5):
    return jax.lax.rsqrt(v + eps)


def op_softmax_entropy(logits):
    return _clear.softmax_entropy(logits)


# ---------------------------------------------------------------------------
# activation statistics + ex-vivo training
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GaussStats:
    mu: jax.Array      # per-feature mean (or scalar)
    sigma: jax.Array   # per-feature std

    @staticmethod
    def estimate(samples: jax.Array) -> "GaussStats":
        flat = samples.reshape(-1, samples.shape[-1]).astype(jnp.float32)
        return GaussStats(jnp.mean(flat, 0), jnp.std(flat, 0) + 1e-4)

    def sample(self, key, n: int) -> jax.Array:
        d = self.mu.shape[-1]
        return self.mu + self.sigma * jax.random.normal(key, (n, d))


def fit_mlp(key, op_fn, stats: GaussStats, d_in: int, hidden: int,
            d_out: int, *, steps: int = 400, batch: int = 2048,
            lr: float = 3e-3, positive_input: bool = False):
    """Ex-vivo regression of `op_fn` on Gaussian-synthesized inputs."""
    kinit, kdata = jax.random.split(key)
    p = init_mlp(kinit, d_in, hidden, d_out)
    m = jax.tree.map(jnp.zeros_like, p)
    v = jax.tree.map(jnp.zeros_like, p)

    def loss_fn(p, x):
        y = op_fn(x)
        return jnp.mean((_clear.mlp_apply(p, x) - y) ** 2)

    @jax.jit
    def step(p, m, v, key, i):
        x = stats.sample(key, batch)
        if positive_input:
            x = jnp.abs(x) + 1e-4
        g = jax.grad(loss_fn)(p, x)
        m = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
        v = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, v, g)
        mh = jax.tree.map(lambda m: m / (1 - 0.9 ** (i + 1.0)), m)
        vh = jax.tree.map(lambda v: v / (1 - 0.999 ** (i + 1.0)), v)
        p = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
                         p, mh, vh)
        return p, m, v

    for i in range(steps):
        kdata, k = jax.random.split(kdata)
        p, m, v = step(p, m, v, k, jnp.float32(i))
    return p


def fit_softmax_mlp(key, stats: GaussStats, seq: int, hidden: int, **kw):
    return fit_mlp(key, op_softmax, stats, seq, hidden, seq, **kw)


def fit_rsqrt_mlp(key, stats: GaussStats, hidden: int, **kw):
    return fit_mlp(key, op_rsqrt, stats, 1, hidden, 1,
                   positive_input=True, **kw)


def fit_entropy_mlp(key, stats: GaussStats, n_classes: int, hidden: int, **kw):
    return fit_mlp(key, op_softmax_entropy, stats, n_classes, hidden, 1, **kw)


def op_sigmoid(x):
    return jax.nn.sigmoid(x)


def fit_gate_mlp(key, stats: GaussStats, d: int, hidden: int, **kw):
    """Beyond-paper: emulate the sigmoid gates of RG-LRU / MoE routers —
    the same fuse-and-reduce trick applied to non-softmax nonlinearities
    (DESIGN.md §4 notes these attach where the backbone has them)."""
    return fit_mlp(key, op_sigmoid, stats, d, hidden, d, **kw)
