"""Offline grid search over selection schedules (paper §4.2).

"The model owner schedules the selection by setting {<l_i, w_i, d_i>}
for N phases... SelectFormer determines the schedule via offline grid
search." This module implements that search against the EXECUTED cost
stream: each candidate phase is priced by a TraceEngine probe of the
one engine-generic forward (`engine/forward.py`) — the identical op
stream the wave executor realizes, round-compressed by the flight
batcher when `fused` (defaulting to the executor's own `fuse` default,
so pricing tracks what deployments actually run) — then scheduled by
the IO makespan model. Searched schedules therefore
price what will actually fly, not a paper-geometry approximation
(`costs.proxy_model_cost`, which fuses QKV and ignores ring truncation,
remains for the analytic figures).

Capacity score is a cheap monotone proxy for expected selection quality:
sum over phases of log(l*w*d) weighted by the fraction of the pool the
phase actually scores — matching the paper's observation that capacity
in LATER phases (which decide the final set) matters most.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools

from repro.configs.base import ArchConfig
from repro.core import iosched
from repro.core.proxy import ProxySpec
from repro.mpc.comm import Ledger, NetProfile, WAN
from repro.mpc.ring import RING64, RingSpec


@dataclasses.dataclass(frozen=True)
class ScoredSchedule:
    phases: tuple[ProxySpec, ...]
    delay_s: float
    capacity: float


@functools.lru_cache(maxsize=512)
def _phase_probe(n_layers: int, n_heads: int, mlp_dim: int, *,
                 d_model: int, heads: int, classes: int, seq: int,
                 batch: int, ring: RingSpec, fused: bool,
                 protocol: str = "2pc") -> Ledger:
    """Per-batch ledger of one phase proxy, probed from the executed
    forward (weight-free: abstract_shares + eval_shape). Delegates to
    the engine-level `cached_probe` memo, so the search shares probe
    results with bench_fusion and the executor (same geometry key)."""
    from repro.engine import cached_probe

    dh = d_model // heads
    cfg = ArchConfig(name="sched-probe", family="dense",
                     n_layers=max(n_layers, 1), d_model=d_model,
                     n_heads=heads, n_kv_heads=heads, d_head=dh,
                     d_ff=0, vocab_size=2)
    spec = ProxySpec(n_layers, min(n_heads, heads), mlp_dim)
    return cached_probe(cfg, spec, batch=batch, seq=seq, classes=classes,
                        ring=ring, protocol=protocol, fused=fused)


def schedule_delay(phases, n_pool: int, budget: int, *, d_model: int = 768,
                   heads: int = 12, classes: int = 2, seq: int = 512,
                   batch: int = 4, net: NetProfile = WAN,
                   sched: iosched.SchedConfig | None = None,
                   ring: RingSpec = RING64,
                   protocol: str = "2pc",
                   fused: bool | None = None) -> float:
    """`fused=None` prices whatever the executor would run by default
    (ExecConfig.fuse) — the search must rank schedules by the stream the
    deployment realizes, and follows that default if it flips."""
    if fused is None:
        from repro.core.executor import ExecConfig
        fused = ExecConfig().fuse
    sched = sched or iosched.SchedConfig()
    remaining = n_pool
    total = 0.0
    for i, ph in enumerate(phases):
        led = _phase_probe(ph.n_layers, ph.n_heads, ph.mlp_dim,
                           d_model=d_model, heads=heads, classes=classes,
                           seq=seq, batch=batch, ring=ring, fused=fused,
                           protocol=protocol)
        total += iosched.makespan(led, -(-remaining // batch), net, sched)
        remaining = budget if i == len(phases) - 1 else \
            max(budget, int(remaining * ph.selectivity))
    return total


def capacity_score(phases, n_pool: int, budget: int) -> float:
    import math
    remaining = n_pool
    score = 0.0
    for i, ph in enumerate(phases):
        frac = remaining / n_pool
        # final-phase capacity decides the purchased set: weight by the
        # inverse of how much pool it sees (later = more selective)
        weight = 1.0 + (i + 1) / len(phases)
        score += weight * math.log(ph.n_layers * ph.n_heads * ph.mlp_dim) \
            * (0.5 + 0.5 * frac)
        remaining = budget if i == len(phases) - 1 else \
            max(budget, int(remaining * ph.selectivity))
    return score


def grid_search(n_pool: int, budget_frac: float = 0.2, *, heads: int = 12,
                max_phases: int = 3, net: NetProfile = WAN
                ) -> list[ScoredSchedule]:
    """Pareto frontier over (delay, capacity) for 1..max_phases."""
    budget = int(budget_frac * n_pool)
    dims = (2, 4, 8, 16)
    layer_opts = (1, 3)
    sel_opts = (0.3, 0.5)
    cands: list[tuple[ProxySpec, ...]] = []
    for d in dims:
        for nl in layer_opts:
            cands.append((ProxySpec(nl, heads if nl > 1 else 1, d, 1.0),))
    if max_phases >= 2:
        for d1, d2 in itertools.product((2, 4), dims):
            if d2 < d1:
                continue
            for s1 in sel_opts:
                cands.append((ProxySpec(1, 1, d1, s1),
                              ProxySpec(3, heads, d2, 1.0)))
    if max_phases >= 3:
        for d2 in (4, 8):
            cands.append((ProxySpec(1, 1, 2, 0.5),
                          ProxySpec(1, heads, d2, 0.5),
                          ProxySpec(3, heads, 16, 1.0)))
    scored = [ScoredSchedule(p, schedule_delay(p, n_pool, budget,
                                               heads=heads, net=net),
                             capacity_score(p, n_pool, budget))
              for p in cands]
    # Pareto: keep schedules not dominated in (lower delay, higher capacity)
    pareto = [s for s in scored
              if not any(o.delay_s <= s.delay_s and o.capacity > s.capacity
                         or o.delay_s < s.delay_s and o.capacity >= s.capacity
                         for o in scored)]
    return sorted(pareto, key=lambda s: s.delay_s)
