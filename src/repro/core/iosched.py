"""Parallel MPC execution / IO scheduling (paper §4.4).

The paper's observation: after the MLPs project nonlinearities to low
dimensions, the op stream splits into
  bandwidth-bound ops ("bw"): big Beaver matmul openings — cost ~ bytes
  latency-bound ops ("lat"): comparisons & low-dim MLP internals — cost
                             ~ rounds * RTT

Two optimizations:
  1. COALESCING: latency-bound ops from W concurrent batches are stacked
     into one message flight — rounds are paid once per wave, not per
     batch (bytes unchanged).
  2. OVERLAP: while batch i's data is on the wire, batch i+1 computes.
     Makespan -> max(total_comm, total_compute) + pipeline fill, instead
     of their sum.

`makespan` turns a per-batch Ledger into an end-to-end delay under any
NetProfile; the four Fig-7 variants are (coalesce, overlap) in
{False,True}^2. This same model, re-parameterized with the pod-DCN
profile, schedules the TPU deployment (launch/select.py), where overlap
is realized with double-buffered inter-pod collectives (kernels aside,
XLA async collectives hide the share-exchange behind the Beaver-local
matmuls).

The schedule is EXECUTABLE, not just priced: core/executor.py runs the
Stage-2 sieve through it (vmapped waves + double-buffered dispatch) and
its recorded flight ledger must reproduce this module's inputs exactly —
`stream_totals` states the contract, `ledger_agrees` checks it.
"""
from __future__ import annotations

import dataclasses

from repro.mpc.comm import Ledger, NetProfile


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    coalesce: bool = True
    overlap: bool = True
    wave: int = 8                 # batches coalesced per flight
    flops_per_s: float = 10e12    # per-party local compute throughput
    memory_batches: int = 8       # max in-flight batches (buffer limit)


def batch_times(led: Ledger, net: NetProfile, sched: SchedConfig):
    """(latency_time, wire_time, compute_time) for ONE batch's ledger."""
    lat_rounds = led.lat_rounds
    bw_rounds = led.bw_rounds
    nbytes = led.nbytes
    compute = led.flops / sched.flops_per_s
    return lat_rounds, bw_rounds, nbytes, compute


def stream_totals(per_batch: Ledger, n_batches: int,
                  sched: SchedConfig) -> dict[str, int]:
    """Integer totals of the op stream the schedule emits for n_batches —
    exactly what `makespan` prices, and exactly what the wave executor's
    phase ledger must add up to (see `ledger_agrees`).

    Coalescing stacks latency-bound flights wave-wide (rounds once per
    wave); bandwidth-bound openings stay one flight per batch; bytes and
    flops are schedule-invariant.
    """
    wave = max(1, sched.wave)            # wave<=0 degenerates to serial
    waves = max(1, -(-n_batches // wave))
    lat_pb = per_batch.lat_rounds
    lat_total = waves * lat_pb if sched.coalesce else n_batches * lat_pb
    return {
        "lat_rounds": lat_total,
        "bw_rounds": n_batches * per_batch.bw_rounds,
        "nbytes": n_batches * per_batch.nbytes,
        "flops": n_batches * per_batch.flops,
        # dealer channel: schedule-invariant like bytes, but streamed
        # ahead of the phase — never an input to makespan
        "offline_nbytes": n_batches * per_batch.offline_nbytes,
    }


def ledger_agrees(stream: Ledger, per_batch: Ledger, n_batches: int,
                  sched: SchedConfig) -> bool:
    """Exact (integer) agreement between a realized executor ledger and
    the makespan model's inputs for the same per-batch op stream."""
    want = stream_totals(per_batch, n_batches, sched)
    return (stream.lat_rounds == want["lat_rounds"]
            and stream.bw_rounds == want["bw_rounds"]
            and stream.nbytes == want["nbytes"]
            and stream.flops == want["flops"]
            and stream.offline_nbytes == want["offline_nbytes"])


def makespan(per_batch: Ledger, n_batches: int, net: NetProfile,
             sched: SchedConfig) -> float:
    """End-to-end delay of n_batches identical batch ledgers."""
    lat_rounds, bw_rounds, nbytes, compute = batch_times(per_batch, net, sched)
    t = stream_totals(per_batch, n_batches, sched)
    latency_total = (t["lat_rounds"] + t["bw_rounds"]) * net.latency_s
    wire_total = t["nbytes"] / net.bandwidth_Bps
    compute_total = n_batches * compute
    if sched.overlap:
        # two-stage pipeline: the dominant resource runs continuously, the
        # other contributes one batch of fill at the pipeline boundary
        comm_total = latency_total + wire_total
        if comm_total >= compute_total:
            return comm_total + compute                # comm-bound
        return compute_total + (lat_rounds + bw_rounds) * net.latency_s \
            + nbytes / net.bandwidth_Bps               # compute-bound
    return latency_total + wire_total + compute_total


# Fig 7's ablation points: variant name -> (coalesce, overlap). The single
# source of truth for both the analytic sweep below and the executed sweep
# (core/executor.run_variants).
FIG7_VARIANTS = {"serial": (False, False), "+coalesce": (True, False),
                 "+overlap": (False, True), "ours": (True, True)}


def fig7_variants(per_batch: Ledger, n_batches: int, net: NetProfile,
                  flops_per_s: float = 10e12) -> dict[str, float]:
    """The paper's ablation points: PMT (no IO sched) vs Ours (full)."""
    return {
        name: makespan(per_batch, n_batches, net,
                       SchedConfig(coalesce=co, overlap=ov,
                                   flops_per_s=flops_per_s))
        for name, (co, ov) in FIG7_VARIANTS.items()
    }
