"""Parallel MPC execution / IO scheduling (paper §4.4).

The paper's observation: after the MLPs project nonlinearities to low
dimensions, the op stream splits into
  bandwidth-bound ops ("bw"): big Beaver matmul openings — cost ~ bytes
  latency-bound ops ("lat"): comparisons & low-dim MLP internals — cost
                             ~ rounds * RTT

Two optimizations:
  1. COALESCING: latency-bound ops from W concurrent batches are stacked
     into one message flight — rounds are paid once per wave, not per
     batch (bytes unchanged).
  2. OVERLAP: while batch i's data is on the wire, batch i+1 computes.
     Makespan -> max(total_comm, total_compute) + pipeline fill, instead
     of their sum.

`makespan` turns a per-batch Ledger into an end-to-end delay under any
NetProfile; the four Fig-7 variants are (coalesce, overlap) in
{False,True}^2. This same model, re-parameterized with the pod-DCN
profile, schedules the TPU deployment (launch/select.py), where overlap
is realized with double-buffered inter-pod collectives (kernels aside,
XLA async collectives hide the share-exchange behind the Beaver-local
matmuls).
"""
from __future__ import annotations

import dataclasses

from repro.mpc.comm import Ledger, NetProfile


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    coalesce: bool = True
    overlap: bool = True
    wave: int = 8                 # batches coalesced per flight
    flops_per_s: float = 10e12    # per-party local compute throughput
    memory_batches: int = 8       # max in-flight batches (buffer limit)


def batch_times(led: Ledger, net: NetProfile, sched: SchedConfig):
    """(latency_time, wire_time, compute_time) for ONE batch's ledger."""
    lat_rounds = sum(r.rounds for r in led.records if r.tag == "lat")
    bw_rounds = sum(r.rounds for r in led.records if r.tag == "bw")
    nbytes = led.nbytes
    compute = led.flops / sched.flops_per_s
    return lat_rounds, bw_rounds, nbytes, compute


def makespan(per_batch: Ledger, n_batches: int, net: NetProfile,
             sched: SchedConfig) -> float:
    """End-to-end delay of n_batches identical batch ledgers."""
    lat_rounds, bw_rounds, nbytes, compute = batch_times(per_batch, net, sched)
    if sched.coalesce:
        waves = max(1, -(-n_batches // sched.wave))
        latency_total = (waves * lat_rounds + n_batches * bw_rounds) * net.latency_s
    else:
        latency_total = n_batches * (lat_rounds + bw_rounds) * net.latency_s
    wire_total = n_batches * nbytes / net.bandwidth_Bps
    compute_total = n_batches * compute
    if sched.overlap:
        # two-stage pipeline: the dominant resource runs continuously, the
        # other contributes one batch of fill at the pipeline boundary
        comm_total = latency_total + wire_total
        if comm_total >= compute_total:
            return comm_total + compute                # comm-bound
        return compute_total + (lat_rounds + bw_rounds) * net.latency_s \
            + nbytes / net.bandwidth_Bps               # compute-bound
    return latency_total + wire_total + compute_total


def fig7_variants(per_batch: Ledger, n_batches: int, net: NetProfile,
                  flops_per_s: float = 10e12) -> dict[str, float]:
    """The paper's ablation points: PMT (no IO sched) vs Ours (full)."""
    base = SchedConfig(coalesce=False, overlap=False, flops_per_s=flops_per_s)
    co = SchedConfig(coalesce=True, overlap=False, flops_per_s=flops_per_s)
    ov = SchedConfig(coalesce=False, overlap=True, flops_per_s=flops_per_s)
    full = SchedConfig(coalesce=True, overlap=True, flops_per_s=flops_per_s)
    return {
        "serial": makespan(per_batch, n_batches, net, base),
        "+coalesce": makespan(per_batch, n_batches, net, co),
        "+overlap": makespan(per_batch, n_batches, net, ov),
        "ours": makespan(per_batch, n_batches, net, full),
    }
