"""Classifier targets for the paper's experiments.

The paper finetunes BERT/DistilBERT/ViT on selected data and reports test
accuracy. We model the target as a bidirectional encoder from the zoo +
mean-pool classification head. The same apply function serves (a) Oracle
selection scoring, (b) final train-on-selected-data, (c) M_g (the proxy
backbone) finetuning on the bootstrap sample.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import common, transformer as T


def init_classifier(key, cfg: ArchConfig, n_classes: int):
    k1, k2 = jax.random.split(key)
    params = T.init_params(k1, cfg)
    params["cls_head"] = common.dense_init(k2, (cfg.d_model, n_classes))
    return params


def encode(params, cfg: ArchConfig, tokens, *, n_layers: int | None = None):
    """Bidirectional encoder features (optionally only bottom n_layers)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.float32)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    positions = jnp.arange(x.shape[1])
    layers = params["layers"]
    if n_layers is not None:
        layers = jax.tree.map(lambda a: a[:n_layers], layers)

    def fn(x, lp):
        y, _, aux = T._decoder_layer(x, lp, cfg, mask_kind="bidir",
                                     positions=positions)
        return y, aux
    x, _ = T._scan_uniform(x, layers, fn, remat=False)
    return common.apply_norm(x, params["final_norm"], cfg.norm_type)


def classifier_logits(params, cfg: ArchConfig, tokens, *,
                      n_layers: int | None = None):
    x = encode(params, cfg, tokens, n_layers=n_layers)
    pooled = jnp.mean(x, axis=1)
    return pooled @ params["cls_head"].astype(pooled.dtype)


def prediction_entropy(params, cfg: ArchConfig, tokens, **kw):
    logits = classifier_logits(params, cfg, tokens, **kw)
    p = jax.nn.softmax(logits, axis=-1)
    return -jnp.sum(p * jnp.log(p + 1e-9), axis=-1)


def finetune(key, params, cfg: ArchConfig, tokens, labels, *,
             steps: int = 200, batch: int = 32, lr: float = 1e-3,
             n_layers: int | None = None):
    """Plain Adam finetune of the classifier (clear, model-owner side)."""
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def loss_fn(p, tok, lab):
        logits = classifier_logits(p, cfg, tok, n_layers=n_layers)
        return common.cross_entropy(logits[:, None], lab[:, None])

    @jax.jit
    def step(p, m, v, tok, lab, i):
        loss, g = jax.value_and_grad(loss_fn)(p, tok, lab)
        m = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
        v = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, v, g)
        mh = jax.tree.map(lambda m: m / (1 - 0.9 ** (i + 1.0)), m)
        vh = jax.tree.map(lambda v: v / (1 - 0.999 ** (i + 1.0)), v)
        p = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
                         p, mh, vh)
        return p, m, v, loss

    n = tokens.shape[0]
    loss = jnp.inf
    for i in range(steps):
        key, k = jax.random.split(key)
        idx = jax.random.randint(k, (min(batch, n),), 0, n)
        params, m, v, loss = step(params, m, v, tokens[idx], labels[idx],
                                  jnp.float32(i))
    return params, float(loss)


def accuracy(params, cfg: ArchConfig, tokens, labels, batch: int = 256) -> float:
    hits = 0
    fn = jax.jit(lambda tok: jnp.argmax(classifier_logits(params, cfg, tok), -1))
    for i in range(0, tokens.shape[0], batch):
        pred = fn(tokens[i:i + batch])
        hits += int(jnp.sum(pred == labels[i:i + batch]))
    return hits / tokens.shape[0]
