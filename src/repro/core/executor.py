"""Wave-pipelined MPC executor — the §4.4 schedule, executable.

`core/iosched.py` prices the paper's parallel multiphase schedule; this
module RUNS it. The Stage-2 sieve's candidate batches are grouped into
waves of W:

  COALESCE   the share-level proxy forward is `vmap`ped across the wave,
             so every latency-bound flight (comparisons inside the
             low-dim MLP ReLUs) is ONE stacked message for W batches —
             rounds are paid per wave, bytes per batch. Bandwidth-bound
             Beaver openings remain one flight per batch (their wire
             time, not their RTTs, is the cost; see comm.record).
  OVERLAP    waves are double-buffered: wave i+1 is dispatched before
             blocking on wave i, so batch i's wire/collective time hides
             behind batch i+1's local compute (JAX async dispatch on one
             host; async inter-pod collectives on the TPU mesh).

Accounting is part of the execution contract: every flight lands in the
ambient Ledger through comm.wave_scope, and the phase ledger must satisfy
`iosched.ledger_agrees` — the same integers the analytic makespan prices.
The per-batch reference ledger comes from `engine.TraceEngine` — the
abstract `jax.eval_shape` probe of the identical op stream (zero FLOPs
spent) — which in turn is pinned record-for-record to
`mpc/costs.proxy_exec_cost`.  The forward itself is the unified
engine-generic one (`engine/forward.py`) interpreted by an `MPCEngine`
over this executor's ring and protocol backend; RING64 and RING32 run
the same code path, and so do the additive-2PC (dealer Beaver) and
replicated-3PC (dealer-free) sharing schemes — `ExecConfig.protocol`
picks the backend, the party axis sizes itself accordingly.

On a pod mesh the wave dimension is a logical sharding axis ("wave" ->
the data axis; parallel/sharding.py), so W concurrent batches land on
separate devices and the stacked flights become per-device collectives.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.core import iosched
from repro.core import proxy as proxy_mod
from repro.core.proxy import ProxySpec
from repro.engine import MPCEngine, cached_probe, proxy_entropy
from repro.engine.base import FULL_VARIANT
from repro.mpc import comm, fusion, protocols
from repro.mpc.comm import DeviceReport, Ledger, NetProfile, WaveTiming
from repro.mpc.ring import RING64, RingSpec, x64_scope
from repro.mpc.sharing import AShare, share
from repro.parallel import sharding


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """Runtime knobs for one executor — mirrors iosched.SchedConfig so a
    measured phase can be priced by the identical schedule."""
    wave: int = 8                 # batches coalesced per flight
    coalesce: bool = True
    overlap: bool = True
    batch: int = 64               # candidates per batch
    flops_per_s: float = 10e12
    ring: RingSpec = RING64
    # secret-sharing protocol backend (mpc/protocols/): "2pc" additive
    # with trusted-dealer triples, "3pc" replicated 2-of-3, dealer-free
    protocol: str = "2pc"
    # round compression (mpc/fusion.py): run each batch's forward under
    # a flight_scope so independent openings share flights. The
    # per-batch probe is fused identically, so ledger_agrees still holds
    # and the schedule prices the compressed stream. Default ON now that
    # fig7/table4 report both modes; pass fuse=False (launch --eager)
    # for the uncompressed stream.
    fuse: bool = True
    # real-wire execution (repro/net/): "none" keeps flights modeled;
    # "local" replays the captured flight tape as one thread per party
    # over in-process queues; "socket" spawns one PROCESS per party over
    # paced localhost TCP emulating `net`'s profile and measures
    # wall-clock (PhaseReport.wire). Wire capture needs concrete message
    # tensors, so the executor forces coalesce=False under wire modes —
    # scores are schedule-invariant (run_variants proves it bitwise).
    wire: str = "none"
    # which comm.PROFILES entry prices the model AND paces the socket
    net: str = "wan"
    # chaos (net/faults.py): with a seed, derive a deterministic
    # FaultPlan from the captured tape and replay under injected faults
    # (reliable delivery + crash recovery engage automatically); scores
    # must stay bitwise identical and goodput must still reconcile.
    chaos_seed: int | None = None
    # degraded 2-of-3: a 3PC party that dies at a phase boundary is
    # dropped and the survivors finish the opens (replicated sharing)
    degraded: bool = False
    # device mesh (parallel/sharding.py): "none" runs single-device;
    # "host" builds a party x wave mesh over the local devices (forced
    # host devices on CPU CI) and device_puts each wave's shares with
    # party -> "pod", wave -> "data" — eager ops then run under GSPMD
    # with cross-party collectives inserted at the opens; "shardmap"
    # splits the wave lanes across the data axis under jax.shard_map
    # (party replicated per device, one jit per wave so ledger records
    # still fire every wave). Scores are bitwise identical in all three.
    mesh: str = "none"
    # Beaver post-open combine implementation for fused RING32 2PC
    # matmuls (kernels/ops.secure_matmul): "auto" compiles the Pallas
    # kernel on TPU and uses the jnp ref elsewhere; "interpret" runs the
    # kernel body on CPU (CI's witness that the kernel path is live);
    # "ref" forces the reference. Bitwise-identical int32 ring
    # arithmetic in every mode.
    combine: str = "auto"

    def sched(self) -> iosched.SchedConfig:
        return iosched.SchedConfig(coalesce=self.coalesce,
                                   overlap=self.overlap,
                                   wave=max(1, self.wave),
                                   flops_per_s=self.flops_per_s)


@dataclasses.dataclass
class PhaseReport:
    """What one executed sieve phase put on the wire."""
    ledger: Ledger                # realized flights, whole phase
    per_batch: Ledger             # one batch's op stream (probe)
    n_batches: int
    n_waves: int
    wall_s: float
    sched: iosched.SchedConfig
    # how the stream was produced — what the analytic mirror must be
    # parameterized with to reproduce it (benchmarks/common.assert_mirror)
    ring: RingSpec = RING64
    protocol: str = "2pc"
    fused: bool = True
    # real-wire outcome (net.WireReport) when the phase ran with
    # ExecConfig.wire != "none": measured wire_makespan_s, reconciled
    # byte counts, payload digests
    wire: object | None = None
    # device-side outcome (comm.DeviceReport): per-wave dispatch/ready
    # timestamps from the double-buffer loop, mesh placement, and the
    # secure_matmul kernel-vs-ref dispatch counters for the phase
    device: DeviceReport | None = None

    @property
    def device_makespan_s(self) -> float:
        """Measured device-side makespan (first dispatch -> last wave
        ready) — the compute twin of the wire's wire_makespan_s."""
        return self.device.device_makespan_s if self.device else 0.0

    def agrees(self) -> bool:
        """Realized flights == the makespan model's inputs, exactly."""
        return iosched.ledger_agrees(self.ledger, self.per_batch,
                                     self.n_batches, self.sched)

    def makespan(self, net: NetProfile) -> float:
        """Modeled end-to-end delay of this phase's measured op stream."""
        return iosched.makespan(self.per_batch, self.n_batches, net,
                                self.sched)

    def as_dict(self, net: NetProfile | None = None) -> dict:
        """The per-phase report dict every driver emits — launch/select's
        SELECT_report and serve's SERVE_report share this one shape so
        downstream tooling reads both. `makespan_wan_s` stays pinned to
        the WAN profile as the trajectory key; pass `net` to price the
        same stream under another comm.PROFILES entry (adds net_*)."""
        d = {
            "n_batches": self.n_batches, "n_waves": self.n_waves,
            "protocol": self.protocol,
            "lat_rounds": self.ledger.lat_rounds,
            "bw_rounds": self.ledger.bw_rounds,
            "nbytes": self.ledger.nbytes,
            "offline_nbytes": self.ledger.offline_nbytes,
            "makespan_wan_s": self.makespan(comm.PROFILES["wan"]),
            "wall_s": self.wall_s,
            # measured device-side makespan + mesh placement
            # (comm.DeviceReport; per-wave stamps in "device")
            "device_makespan_s": self.device_makespan_s,
            "device": self.device.as_dict() if self.device is not None
                      else None,
            # real-wire measurement when ExecConfig.wire != "none"
            "wire": self.wire.as_dict() if self.wire is not None
                    else None,
        }
        if net is not None and net.name != "wan":
            d["net"] = net.name
            d["net_makespan_s"] = self.makespan(net)
        return d


class WaveExecutor:
    """Runs the Stage-2 multiphase sieve through the §4.4 schedule."""

    def __init__(self, cfg: ExecConfig):
        if cfg.wire not in ("none", "local", "socket"):
            raise ValueError(f"unknown wire mode {cfg.wire!r}")
        if cfg.mesh not in ("none", "host", "shardmap"):
            raise ValueError(f"unknown mesh mode {cfg.mesh!r}")
        if cfg.mesh == "shardmap" and cfg.wire != "none":
            # wire capture forces the eager per-lane schedule; shard_map
            # needs the coalesced wave — the host (GSPMD) mesh composes
            # with wire capture, the shard_map one cannot
            raise ValueError("mesh='shardmap' needs the coalesced "
                             "schedule; use mesh='host' with --wire")
        if cfg.chaos_seed is not None and cfg.wire == "none":
            raise ValueError("chaos_seed needs a real wire "
                             "(wire='local' or 'socket')")
        if cfg.wire != "none" and cfg.coalesce:
            # capturing real message tensors requires the eager per-lane
            # path (vmap abstracts the payloads away); the schedule is
            # score-invariant, so this changes WHEN flights happen, not
            # what they carry
            cfg = dataclasses.replace(cfg, coalesce=False)
        self.cfg = cfg
        self.reports: list[PhaseReport] = []

    # -- the schedule ----------------------------------------------------
    def score_phase(self, key, pp, arch_cfg: ArchConfig, tokens,
                    spec: ProxySpec, variant=FULL_VARIANT) -> AShare:
        """Encrypted entropy for every candidate, executed wave-by-wave.

        Identical numerics across all four (coalesce, overlap) variants:
        per-batch PRNG keys and share masks are assigned once, so the
        schedule changes only WHEN flights happen, never their contents.
        """
        run = PhaseRun(self.cfg, key, pp, arch_cfg, tokens, spec, variant)
        for wi in range(run.n_waves):
            run.dispatch(wi)
        run.drain()
        ent, rep = run.finish()
        self.reports.append(rep)
        return ent


class PhaseRun:
    """One sieve phase as a STEPWISE schedule — the §4.4 wave loop with
    the loop inverted out.

    `WaveExecutor.score_phase` drives it sequentially (dispatch every
    wave, drain, finish) and is behavior- and record-order-identical to
    the pre-refactor closed loop. The serve/ appraisal server drives
    SEVERAL PhaseRuns at once: while one session's dispatched wave is in
    flight (its `pending` not yet blocked), the server dispatches another
    session's wave — extending the PR 1 intra-phase double buffer to
    inter-session overlap without touching numerics (each run's keys,
    masks, and record order are exactly the sequential ones).

      dispatch(wi)  build + share wave wi, run the forward under the
                    per-wave ledger/tape scopes, then block the PREVIOUS
                    pending wave (double-buffer discipline)
      drain()       block the tail pending wave
      finish()      concat scores, reconcile + replay the wire tape,
                    return (AShare, PhaseReport)
    """

    def __init__(self, cfg: ExecConfig, key, pp, arch_cfg: ArchConfig,
                 tokens, spec: ProxySpec, variant=FULL_VARIANT,
                 outer: Ledger | None = None):
        self.cfg = cfg
        self.ring = ring = cfg.ring
        self.key = key
        self.pp = pp
        self.arch_cfg = arch_cfg
        self.spec = spec
        self.variant = variant
        B, W = cfg.batch, max(1, cfg.wave)
        self.B, self.W = B, W
        self.n = n = int(tokens.shape[0])
        self.seq = seq = int(tokens.shape[1])
        self.n_batches = n_batches = -(-n // B)
        self.n_waves = -(-n_batches // W)
        tok = np.asarray(tokens)
        full = n_batches * B
        if full > n:                                   # wrap-pad the tail,
            reps = -(-full // n)                       # tiling if B > n
            tok = np.concatenate([tok] * reps)[:full]
        self.tok = tok

        self.proto = proto = cfg.protocol
        self.n_parties = protocols.get(proto).n_parties

        # device mesh: "host" realizes party -> pod / wave -> data via
        # NamedSharding device_put (GSPMD inserts the cross-party
        # collectives); "shardmap" splits wave lanes over the data axis
        # with the party axis replicated per device (shard_map bodies
        # index party components explicitly, without collectives)
        self.rules = None
        if cfg.mesh == "host":
            self.rules = sharding.party_wave_rules(self.n_parties)
        elif cfg.mesh == "shardmap":
            self.rules = sharding.party_wave_rules(1, max_data=W)
        rules = self.rules
        self.dsize = sharding.data_axis_size(rules) if rules is not None else 1
        self.dev = DeviceReport(
            placement=cfg.mesh,
            n_devices=(int(rules.mesh.devices.size) if rules is not None
                       else 1),
            mesh_axes=(dict(rules.mesh.shape) if rules is not None else {}))

        # record into the ambient ledger at CONSTRUCTION time — a server
        # builds each run under its session's ledger scope (or passes
        # `outer` explicitly) and the records land per-session even when
        # dispatches interleave
        self.outer = comm.get_ledger() if outer is None else outer
        self.phase_led = Ledger()
        # --wire: capture every executed flight's actual messages; the
        # tape is sized by the WIRE party count (spdz2pc stacks 4 share
        # rows but runs 2 parties)
        self.tape = (comm.WireTape(protocols.get(proto).n_wire_parties)
                     if cfg.wire != "none" else None)
        self.scale = jnp.asarray(arch_cfg.d_model ** 0.5, jnp.float32)
        from repro.kernels import ops as kops
        self._kops = kops
        self.smm0 = kops.smm_stats()
        self.results: list[jax.Array] = []
        self.pending: jax.Array | None = None
        self.pending_wi = -1

        with self._ctx():
            self.pp_sh = proxy_mod.share_proxy(
                jax.random.fold_in(key, 1), pp, ring, proto)
            self.batch_keys = jax.random.split(
                jax.random.fold_in(key, 2), n_batches)
            # per-batch op-stream reference: the zero-FLOP eval_shape
            # probe (fused exactly like the executed forwards below),
            # memoized on the probe geometry — repeated phases of one
            # schedule reuse it
            self.per_batch = cached_probe(
                arch_cfg, spec, batch=B, seq=seq,
                classes=int(pp["cls_head"].shape[-1]), ring=ring,
                protocol=proto, fused=cfg.fuse, variant=variant)
            if cfg.mesh == "host":
                # weights resident once per phase: each party's share
                # components on its pod slice, value dims replicated
                self.pp_sh = sharding.place_party_tree(self.pp_sh)
        self.t0 = time.time()

    def _ctx(self):
        """The ambient scopes every step runs under — re-entered per
        call so interleaved runs (serve) never leak scopes into each
        other: x64 for RING64 arithmetic, sharding rules for the mesh."""
        stack = contextlib.ExitStack()
        if self.cfg.ring.bits >= 64:
            stack.enter_context(x64_scope())
        if self.rules is not None:
            stack.enter_context(sharding.rules_scope(self.rules))
        return stack

    def lanes(self, wi: int) -> int:
        b0, b1 = wi * self.W, min((wi + 1) * self.W, self.n_batches)
        return b1 - b0

    def _fwd(self, sh, k):
        cfg = self.cfg
        eng = MPCEngine(ring=self.ring, protocol=self.proto,
                        combine_impl=cfg.combine).with_key(k)
        with fusion.flight_scope(enabled=cfg.fuse):
            return proxy_entropy(eng, self.pp_sh, self.arch_cfg,
                                 AShare(sh, self.ring, self.proto),
                                 self.spec, self.variant).sh

    def dispatch(self, wi: int) -> None:
        """Run wave `wi` and leave it in flight (cfg.overlap) — blocking
        the previously pending wave only after this one is dispatched,
        so its wire time hides behind this wave's local compute."""
        cfg = self.cfg
        B, W, seq = self.B, self.W, self.seq
        rules, dsize = self.rules, self.dsize
        with self._ctx():
            b0, b1 = wi * W, min((wi + 1) * W, self.n_batches)
            lanes = b1 - b0
            wave_tok = jnp.asarray(
                self.tok[b0 * B:b1 * B]).reshape(lanes, B, seq)
            x = jnp.take(self.pp["embed"], wave_tok, axis=0) * self.scale
            x_sh = share(jax.random.fold_in(self.key, 100 + wi),
                         x.astype(jnp.float32), self.ring, self.proto)
            w_start = time.time() - self.t0
            # party axis -> pod, wave axis -> data: a real device_put
            # on a mesh; without one, the legacy no-op annotation
            if rules is not None:
                sh = sharding.place(x_sh.sh, "pod", "wave", "batch",
                                    None, None)
            else:
                sh = sharding.shard(x_sh.sh, "pod", "wave", "batch",
                                    None, None)
            keys = self.batch_keys[b0:b1]
            used = 1

            with comm.ledger_scope() as wave_led, \
                    comm.wire_tape_scope(self.tape):
                if cfg.coalesce:
                    vf = jax.vmap(self._fwd, in_axes=(1, 0), out_axes=1)
                    if cfg.mesh == "shardmap" and dsize > 1 \
                            and lanes % dsize == 0:
                        # one fresh jit per wave: the re-trace is what
                        # fires this wave's comm.record side effects
                        # (a cached trace would silently skip them)
                        in_sh = P(*([None, "data"]
                                    + [None] * (sh.ndim - 2)))
                        vf = jax.jit(shard_map(
                            vf, mesh=rules.mesh,
                            in_specs=(in_sh, P("data")),
                            out_specs=P(None, "data", None),
                            check_rep=False))
                        used = dsize
                    elif rules is not None:
                        used = len(sh.sharding.device_set)
                    with comm.wave_scope(lanes):
                        ent = vf(sh, keys)
                else:
                    if rules is not None:
                        used = len(sh.sharding.device_set)
                    ent = jnp.stack([self._fwd(sh[:, li], keys[li])
                                     for li in range(lanes)], axis=1)
            self.phase_led.records.extend(wave_led.records)
            if self.outer is not None:
                self.outer.records.extend(wave_led.records)

            ent = ent.reshape(self.n_parties, lanes * B)
            self.dev.waves.append(WaveTiming(
                wave=wi, lanes=lanes, devices_used=used,
                start_s=w_start, dispatch_s=time.time() - self.t0))
            # double buffer: block on wave i-1 only after dispatching
            # i, so its wire time overlaps this wave's local compute
            if self.pending is not None:
                jax.block_until_ready(self.pending)
                self.dev.waves[self.pending_wi].ready_s = \
                    time.time() - self.t0
                self.pending = None
            if cfg.overlap:
                self.pending, self.pending_wi = ent, wi
            else:
                jax.block_until_ready(ent)
                self.dev.waves[wi].ready_s = time.time() - self.t0
            self.results.append(ent)

    def drain(self) -> None:
        """Block the tail pending wave (the loop's final barrier)."""
        if self.pending is not None:
            with self._ctx():
                jax.block_until_ready(self.pending)
            self.dev.waves[self.pending_wi].ready_s = time.time() - self.t0
            self.pending = None

    def finish(self) -> tuple[AShare, PhaseReport]:
        """Concatenate scores, reconcile/replay the wire tape, and seal
        the PhaseReport. Call after every wave dispatched + drain()."""
        cfg = self.cfg
        with self._ctx():
            out = jnp.concatenate(self.results, axis=1)[:, :self.n]
        wall_s = time.time() - self.t0
        smm1 = self._kops.smm_stats()
        dev = self.dev
        dev.combine_kernel = smm1["kernel"] - self.smm0["kernel"]
        dev.combine_ref = smm1["ref"] - self.smm0["ref"]
        dev.combine_padded = smm1["padded"] - self.smm0["padded"]
        wire_rep = None
        if self.tape is not None:
            # replay the captured flight plan as real parties: reconcile
            # record-for-record against the phase ledger, then measure
            from repro import net
            net.reconcile(self.phase_led, self.tape)
            fault_plan = None
            if cfg.chaos_seed is not None:
                from repro.net import faults
                fault_plan = faults.FaultPlan.from_tape(
                    cfg.chaos_seed, self.tape,
                    crash_at_boundary=cfg.degraded)
            wire_rep = net.PartyRuntime(
                self.tape, mode=cfg.wire,
                profile=(comm.PROFILES[cfg.net] if cfg.wire == "socket"
                         else None),
                fault_plan=fault_plan,
                recover=fault_plan is not None and not cfg.degraded,
                degraded=cfg.degraded).execute()
        rep = PhaseReport(
            ledger=self.phase_led, per_batch=self.per_batch,
            n_batches=self.n_batches, n_waves=self.n_waves, wall_s=wall_s,
            sched=cfg.sched(), ring=self.ring, protocol=self.proto,
            fused=cfg.fuse, wire=wire_rep, device=dev)
        return AShare(out, self.ring, self.proto), rep


def run_variants(key, pp, arch_cfg: ArchConfig, tokens, spec: ProxySpec,
                 *, batch: int, wave: int,
                 flops_per_s: float = 10e12,
                 fuse: bool | None = None,
                 protocol: str = "2pc") -> dict[str, "PhaseReport"]:
    """Fig-7's four (coalesce, overlap) points, executed on one pool.

    Returns name -> PhaseReport; every variant is checked for exact
    ledger agreement with the makespan inputs, and all variants produce
    bitwise-identical scores (the schedule moves flights, not values —
    and the flight batcher — on by default, `fuse=None` follows
    ExecConfig — compresses rounds without changing a share either).
    """
    reports = {}
    ref = None
    fuse_kw = {} if fuse is None else {"fuse": fuse}
    for name, (co, ov) in iosched.FIG7_VARIANTS.items():
        ex = WaveExecutor(ExecConfig(wave=wave, coalesce=co, overlap=ov,
                                     batch=batch, flops_per_s=flops_per_s,
                                     protocol=protocol, **fuse_kw))
        ent = ex.score_phase(key, pp, arch_cfg, tokens, spec)
        rep = ex.reports[-1]
        if not rep.agrees():
            raise AssertionError(
                f"executor ledger for {name} diverges from makespan inputs")
        if ref is None:
            ref = np.asarray(ent.sh)
        elif not np.array_equal(ref, np.asarray(ent.sh)):
            raise AssertionError(f"variant {name} changed scores")
        reports[name] = rep
    return reports
