"""The paper's contribution, as composable modules.

  approx.py     MLP emulators for fused nonlinearities (MLP_sm / MLP_ln /
                MLP_se) + ex-vivo Gaussian-synthesis training + clear and
                MPC execution paths
  target.py     classifier targets (paper setting: BERT-style encoder +
                head), finetuning loop
  proxy.py      proxy generation: sub-model extraction, head/depth
                pruning, MLP substitution, in-vivo finetune
  selection.py  the 3-stage private selection workflow (bootstrap ->
                multi-phase MPC sieve -> transaction/appraisal)
  iosched.py    parallel MPC execution: latency-op coalescing + comm/
                compute overlap makespan (paper 4.4), drives Fig 6/7
"""
from repro.core import approx, proxy, selection, iosched, target
