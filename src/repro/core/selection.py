"""The 3-stage private selection workflow (paper §4.1, Figure 1/3).

Stage 1 (clear): exchange metadata, purchase bootstrap sample S_boot.
Stage 2 (MPC):   N-phase progressive sieve. Phase i scores surviving
                 candidates with proxy M̂_i (encrypted entropy) and keeps
                 the top alpha_i fraction via QuickSelect over secure
                 comparisons (only comparison bits revealed).
Stage 3 (clear): transaction; optional appraisal = mean entropy of S_N.

Two execution modes share the same control flow:
  mode="clear"  float proxies (fast; used for efficacy experiments and
                as the numerical reference)
  mode="mpc"    share-level proxies over the RING64 oracle ring with the
                ambient cost Ledger recording every wire interaction,
                scheduled by the wave executor (core/executor.py): W
                batches coalesced per latency flight, waves
                double-buffered so wire time hides behind compute

Phase boundaries checkpoint the surviving index set — a natural
fault-tolerance barrier (runtime/ft.py restores an interrupted
selection from the last completed phase).
"""
from __future__ import annotations

import dataclasses
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import proxy as proxy_mod, target as target_mod
from repro.core.executor import ExecConfig, PhaseReport, WaveExecutor
from repro.core.proxy import ProxySpec
from repro.mpc import quickselect
from repro.mpc.sharing import AShare
from repro.mpc.ring import x64_scope


@dataclasses.dataclass
class SelectionConfig:
    phases: list[ProxySpec]
    budget_frac: float = 0.20         # B / |D|
    boot_frac: float = 0.05           # bootstrap share of the pool
    score_batch: int = 64
    exvivo_steps: int = 300
    invivo_steps: int = 150
    finetune_steps: int = 200
    mode: str = "clear"               # or "mpc"
    checkpoint_dir: str | None = None
    variant: frozenset = frozenset({"sm", "ln", "se"})  # Table 2/3 ablations
    # mode="mpc" runs through the wave executor; (wave, coalesce, overlap)
    # are the §4.4 schedule — Fig 7's four variants as runtime flags
    executor: ExecConfig = dataclasses.field(default_factory=ExecConfig)


@dataclasses.dataclass
class SelectionResult:
    selected: np.ndarray              # indices into the pool
    boot_idx: np.ndarray
    phase_survivors: list[np.ndarray]
    appraisal_entropy: float
    exec_reports: list[PhaseReport] = dataclasses.field(default_factory=list)


def two_phase_default(seq_len_heads: int = 12) -> list[ProxySpec]:
    """The paper's main schedule: <1 layer, 1 head, d=2> -> <3, all, 16>."""
    return [ProxySpec(1, 1, 2, selectivity=0.5),
            ProxySpec(3, seq_len_heads, 16, selectivity=1.0)]


def _phase_keep(n_pool: int, budget: int, phases: list[ProxySpec]) -> list[int]:
    """Survivor counts per phase ending exactly at the budget."""
    keeps = []
    cur = n_pool
    for i, ph in enumerate(phases):
        if i == len(phases) - 1:
            keeps.append(budget)
        else:
            cur = max(budget, int(round(cur * ph.selectivity)))
            keeps.append(cur)
    return keeps


def _score_clear(pp, cfg, tokens, spec,
                 variant=frozenset({"sm", "ln", "se"})) -> np.ndarray:
    fn = jax.jit(lambda t: proxy_mod.proxy_entropy_clear(pp, cfg, t, spec,
                                                         variant))
    out = []
    for i in range(0, tokens.shape[0], 256):
        out.append(np.asarray(fn(tokens[i:i + 256])))
    return np.concatenate(out)


def run_selection(key, target_params, cfg: ArchConfig, pool_tokens,
                  sel: SelectionConfig, *, n_classes: int,
                  boot_labels_fn=None) -> SelectionResult:
    """Full pipeline. `boot_labels_fn(idx) -> labels` models the clear
    purchase of the bootstrap sample (labels delivered with the data)."""
    n = pool_tokens.shape[0]
    budget = int(round(sel.budget_frac * n))
    n_boot = max(8, int(round(sel.boot_frac * n)))
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))

    # ---- stage 1: bootstrap purchase (random, clear) --------------------
    boot_idx = np.sort(rng.choice(n, size=n_boot, replace=False))
    boot_tokens = pool_tokens[boot_idx]
    boot_labels = boot_labels_fn(boot_idx)

    # ---- proxy generation (model-owner side, clear) ---------------------
    max_l = max(ph.n_layers for ph in sel.phases)
    key, kg, kf = jax.random.split(key, 3)
    m_g = proxy_mod.extract_backbone(target_params, max_l)
    m_g, _ = target_mod.finetune(kf, m_g, cfg, boot_tokens, boot_labels,
                                 steps=sel.finetune_steps, n_layers=max_l)
    proxies = []
    for ph in sel.phases:
        key, ks, kb, ki = jax.random.split(key, 4)
        stats = proxy_mod.collect_stats(m_g, cfg, boot_tokens[:256], ph)
        pp = proxy_mod.build_proxy(kb, m_g, cfg, stats, ph,
                                   seq_len=pool_tokens.shape[1],
                                   n_classes=n_classes,
                                   exvivo_steps=sel.exvivo_steps)
        pp = proxy_mod.invivo_finetune(ki, pp, cfg, boot_tokens, boot_labels,
                                       ph, steps=sel.invivo_steps)
        proxies.append(pp)

    # ---- stage 2: multi-phase MPC sieve ----------------------------------
    surviving = np.setdiff1d(np.arange(n), boot_idx)
    keeps = _phase_keep(len(surviving), budget - n_boot, sel.phases)
    survivors_log = []
    exec_reports: list[PhaseReport] = []
    appraisal = 0.0
    for pi, (ph, pp, keep) in enumerate(zip(sel.phases, proxies, keeps)):
        tok = pool_tokens[surviving]
        if sel.mode == "mpc":
            key, ks, kq = jax.random.split(key, 3)
            execu = WaveExecutor(dataclasses.replace(
                sel.executor, batch=min(sel.score_batch, len(surviving))))
            ent_sh = execu.score_phase(ks, pp, cfg, tok, ph)
            exec_reports.extend(execu.reports)
            with x64_scope():      # quickselect compares int64 shares
                top_local = quickselect.top_k_indices(ent_sh, keep,
                                                      seed=1234 + pi)
                appraisal = float(jnp.mean(
                    (ent_sh[np.asarray(top_local)].sh[0]
                     + ent_sh[np.asarray(top_local)].sh[1]).astype(jnp.float64)
                    / ent_sh.ring.scale))
        else:
            ents = _score_clear(pp, cfg, tok, ph, sel.variant)
            top_local = np.argsort(ents)[-keep:]
            appraisal = float(np.mean(ents[top_local]))
        surviving = np.sort(surviving[top_local])
        survivors_log.append(surviving.copy())
        _checkpoint_phase(sel, pi, surviving)

    selected = np.sort(np.concatenate([boot_idx, surviving]))
    return SelectionResult(selected, boot_idx, survivors_log, appraisal,
                           exec_reports)


def _checkpoint_phase(sel: SelectionConfig, phase: int, surviving) -> None:
    if not sel.checkpoint_dir:
        return
    os.makedirs(sel.checkpoint_dir, exist_ok=True)
    path = os.path.join(sel.checkpoint_dir, f"phase_{phase}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"phase": phase, "surviving": surviving.tolist()}, f)
    os.replace(tmp, path)


def appraise_threshold(ent_sh: AShare, idx, threshold: float, key) -> bool:
    """Paper §4.1 appraisal: if the average entropy of the selected set is
    sensitive, jointly compare the (encrypted) average against a public
    threshold and reveal ONLY the one-bit outcome."""
    from repro.mpc import ops as mops, compare
    sel = ent_sh[np.asarray(idx)]
    avg = mops.mean(sel, axis=0, key=jax.random.fold_in(key, 1))
    thr = mops.add_public(mops.neg(avg), threshold)      # thr - avg
    bit = compare.reveal_lt(thr, AShare(jnp.zeros_like(thr.sh), thr.ring))
    return bool(np.asarray(bit))                         # avg > threshold


def resume_phase(sel: SelectionConfig) -> tuple[int, np.ndarray] | None:
    """Restart support: latest completed phase's survivor set."""
    if not sel.checkpoint_dir or not os.path.isdir(sel.checkpoint_dir):
        return None
    best = None
    for f in os.listdir(sel.checkpoint_dir):
        if f.startswith("phase_") and f.endswith(".json"):
            with open(os.path.join(sel.checkpoint_dir, f)) as fh:
                d = json.load(fh)
            if best is None or d["phase"] > best[0]:
                best = (d["phase"], np.asarray(d["surviving"]))
    return best
