"""The 3-stage private selection workflow (paper §4.1, Figure 1/3).

Stage 1 (clear): exchange metadata, purchase bootstrap sample S_boot.
Stage 2 (MPC):   N-phase progressive sieve. Phase i scores surviving
                 candidates with proxy M̂_i (encrypted entropy) and keeps
                 the top alpha_i fraction via QuickSelect over secure
                 comparisons (only comparison bits revealed).
Stage 3 (clear): transaction; optional appraisal = mean entropy of S_N.

All execution substrates share the same control flow through the
tensor-engine API (src/repro/engine/):
  ClearEngine   float proxies (fast; used for efficacy experiments and
                as the numerical reference)
  MPCEngine     share-level proxies over a RingSpec (RING64 oracle or
                RING32/dealer-trunc) with the ambient cost Ledger
                recording every wire interaction, scheduled by the wave
                executor (core/executor.py): W batches coalesced per
                latency flight, waves double-buffered so wire time
                hides behind compute
`SelectionConfig.engine` takes an engine instance; the legacy `mode`
strings "clear"/"mpc" still resolve for back-compat.

Phase boundaries checkpoint the surviving index set — a natural
fault-tolerance barrier: when `checkpoint_dir` already holds phase
checkpoints for the same run (fingerprinted by pool/bootstrap), a
re-run resumes after the last completed phase instead of re-scoring.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import proxy as proxy_mod, target as target_mod
from repro.core.executor import ExecConfig, PhaseReport, WaveExecutor
from repro.core.proxy import ProxySpec
from repro.engine import forward as engine_forward
from repro.engine.base import FULL_VARIANT, TensorEngine, resolve_engine
from repro.mpc import quickselect
from repro.mpc.sharing import AShare, reconstruct
from repro.mpc.ring import x64_scope


@dataclasses.dataclass
class SelectionConfig:
    phases: list[ProxySpec]
    budget_frac: float = 0.20         # B / |D|
    boot_frac: float = 0.05           # bootstrap share of the pool
    score_batch: int = 64
    exvivo_steps: int = 300
    invivo_steps: int = 150
    finetune_steps: int = 200
    mode: str = "clear"               # legacy: "clear" | "mpc"
    engine: TensorEngine | str | None = None   # preferred over `mode`
    checkpoint_dir: str | None = None
    resume: bool = True               # consult phase checkpoints on start
    variant: frozenset = FULL_VARIANT  # Table 2/3 ablations
    # the MPC engine runs through the wave executor; (wave, coalesce,
    # overlap) are the §4.4 schedule — Fig 7's four variants as flags
    executor: ExecConfig = dataclasses.field(default_factory=ExecConfig)

    def __post_init__(self):
        self.engine = resolve_engine(self.engine if self.engine is not None
                                     else self.mode, ring=self.executor.ring,
                                     protocol=self.executor.protocol)
        self.mode = self.engine.kind
        if self.mode == "mpc":
            # the executor must run the engine's exact substrate: sync
            # ring AND protocol backend (engine instance wins)
            if self.executor.ring is not self.engine.ring or \
                    self.executor.protocol != self.engine.protocol:
                self.executor = dataclasses.replace(
                    self.executor, ring=self.engine.ring,
                    protocol=self.engine.protocol)


@dataclasses.dataclass
class SelectionResult:
    selected: np.ndarray              # indices into the pool
    boot_idx: np.ndarray
    phase_survivors: list[np.ndarray]
    appraisal_entropy: float
    exec_reports: list[PhaseReport] = dataclasses.field(default_factory=list)
    resumed_phases: int = 0           # phases restored from checkpoints
    # raw per-phase score shares (np.asarray(ent_sh.sh), MPC mode) — the
    # bitwise-parity witness bench_serve compares across drivers
    phase_scores: list[np.ndarray] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class PhaseRequest:
    """One sieve phase's executable work order, yielded by
    `selection_plan`: score `tokens` with proxy `pp` under `spec`, send
    the entropy AShare (plus the executor's PhaseReports) back in. The
    driver owns HOW it runs — run_selection builds one WaveExecutor per
    request; serve/ feeds requests from many sessions through
    interleaved PhaseRuns and a cross-session cache keyed on
    `fingerprint` + the phase geometry."""
    phase: int
    key: jax.Array                    # the per-phase ks split
    pp: dict                          # proxy params (model-owner side)
    tokens: np.ndarray                # surviving candidates' tokens
    spec: ProxySpec
    keep: int                         # survivors after QuickSelect
    batch: int                        # executor batch for this phase
    fingerprint: str | None           # run fingerprint (cache/ckpt key)


def two_phase_default(seq_len_heads: int = 12) -> list[ProxySpec]:
    """The paper's main schedule: <1 layer, 1 head, d=2> -> <3, all, 16>."""
    return [ProxySpec(1, 1, 2, selectivity=0.5),
            ProxySpec(3, seq_len_heads, 16, selectivity=1.0)]


def _phase_keep(n_pool: int, budget: int, phases: list[ProxySpec]) -> list[int]:
    """Survivor counts per phase ending exactly at the budget."""
    keeps = []
    cur = n_pool
    for i, ph in enumerate(phases):
        if i == len(phases) - 1:
            keeps.append(budget)
        else:
            cur = max(budget, int(round(cur * ph.selectivity)))
            keeps.append(cur)
    return keeps


def _score_clear(engine, pp, cfg, tokens, spec,
                 variant=FULL_VARIANT) -> np.ndarray:
    fn = jax.jit(lambda t: engine_forward.proxy_entropy(engine, pp, cfg, t,
                                                        spec, variant))
    out = []
    for i in range(0, tokens.shape[0], 256):
        out.append(np.asarray(fn(tokens[i:i + 256])))
    return np.concatenate(out)


def selection_plan(key, target_params, cfg: ArchConfig, pool_tokens,
                   sel: SelectionConfig, *, n_classes: int,
                   boot_labels_fn=None):
    """The full pipeline as a GENERATOR: stages 1/3 and every clear-side
    step run inline; each MPC scoring phase is yielded as a
    `PhaseRequest` and the driver sends `(ent_sh, reports)` back.

    `run_selection` drives one plan sequentially (one WaveExecutor per
    request — identical to the pre-generator closed loop, same PRNG
    split order). The serve/ AppraisalServer drives many plans at once,
    interleaving their waves and substituting cached scores; because
    QuickSelect, appraisal, and checkpointing all stay INSIDE the plan,
    any driver that sends back the right scores gets bitwise-identical
    survivors and appraisals for free. Returns (via StopIteration.value)
    the SelectionResult."""
    n = pool_tokens.shape[0]
    budget = int(round(sel.budget_frac * n))
    n_boot = max(8, int(round(sel.boot_frac * n)))
    rng = np.random.default_rng(int(jax.random.randint(key, (), 0, 2 ** 31 - 1)))

    # ---- stage 1: bootstrap purchase (random, clear) --------------------
    boot_idx = np.sort(rng.choice(n, size=n_boot, replace=False))
    boot_tokens = pool_tokens[boot_idx]
    boot_labels = boot_labels_fn(boot_idx)

    # ---- restart support: resume after the last completed phase ---------
    fp = None
    resume_from = 0
    completed: dict[int, dict] = {}
    if sel.checkpoint_dir or sel.mode == "mpc":
        # fp hashes target weights + pool: the checkpoint guard, and the
        # serve cross-session cache key (MPC plans always compute it)
        fp = _run_fingerprint(sel, n, budget, boot_idx, target_params,
                              pool_tokens)
    if sel.checkpoint_dir and sel.resume:
        for d in _load_phase_checkpoints(sel.checkpoint_dir):
            if d.get("fp") == fp and d["phase"] < len(sel.phases):
                completed[d["phase"]] = d
        # only a contiguous prefix is resumable (a later-phase file
        # may survive while an earlier one was overwritten)
        while resume_from in completed:
            resume_from += 1
    resumed_appraisal = (completed[resume_from - 1].get("appraisal", 0.0)
                         if resume_from else 0.0)

    # ---- proxy generation (model-owner side, clear) ---------------------
    max_l = max(ph.n_layers for ph in sel.phases)
    key, kg, kf = jax.random.split(key, 3)
    if resume_from < len(sel.phases):
        m_g = proxy_mod.extract_backbone(target_params, max_l)
        m_g, _ = target_mod.finetune(kf, m_g, cfg, boot_tokens, boot_labels,
                                     steps=sel.finetune_steps, n_layers=max_l)
    proxies = []
    for pi, ph in enumerate(sel.phases):
        key, kb, ki = jax.random.split(key, 3)
        if pi < resume_from:          # phase already checkpointed: no proxy
            proxies.append(None)
            continue
        stats = proxy_mod.collect_stats(m_g, cfg, boot_tokens[:256], ph)
        pp = proxy_mod.build_proxy(kb, m_g, cfg, stats, ph,
                                   seq_len=pool_tokens.shape[1],
                                   n_classes=n_classes,
                                   exvivo_steps=sel.exvivo_steps)
        pp = proxy_mod.invivo_finetune(ki, pp, cfg, boot_tokens, boot_labels,
                                       ph, steps=sel.invivo_steps)
        proxies.append(pp)

    # ---- stage 2: multi-phase MPC sieve ----------------------------------
    surviving = np.setdiff1d(np.arange(n), boot_idx)
    keeps = _phase_keep(len(surviving), budget - n_boot, sel.phases)
    survivors_log = []
    exec_reports: list[PhaseReport] = []
    phase_scores: list[np.ndarray] = []
    appraisal = resumed_appraisal
    for pi, (ph, pp, keep) in enumerate(zip(sel.phases, proxies, keeps)):
        key, ks = jax.random.split(key)
        if pi < resume_from:
            surviving = np.asarray(completed[pi]["surviving"], dtype=int)
            survivors_log.append(surviving.copy())
            continue
        tok = pool_tokens[surviving]
        if sel.mode == "mpc":
            ent_sh, reports = yield PhaseRequest(
                phase=pi, key=ks, pp=pp, tokens=tok, spec=ph, keep=keep,
                batch=min(sel.score_batch, len(surviving)), fingerprint=fp)
            exec_reports.extend(reports)
            phase_scores.append(np.asarray(ent_sh.sh))
            with x64_scope():      # quickselect compares int64 shares
                # fused runs issue per-wave comparison batches and let
                # the flight batcher fuse them into one flight/partition
                qs_wave = sel.executor.wave if sel.executor.fuse else 1
                top_local = quickselect.top_k_indices(ent_sh, keep,
                                                      seed=1234 + pi,
                                                      wave=qs_wave)
                # backend-aware reconstruction: pass the Share (MAC'd
                # schemes' extra rows are not value components)
                appraisal = float(jnp.mean(
                    reconstruct(ent_sh[np.asarray(top_local)])
                    .astype(jnp.float64) / ent_sh.ring.scale))
        else:
            ents = _score_clear(sel.engine, pp, cfg, tok, ph, sel.variant)
            top_local = np.argsort(ents)[-keep:]
            appraisal = float(np.mean(ents[top_local]))
        surviving = np.sort(surviving[top_local])
        survivors_log.append(surviving.copy())
        _checkpoint_phase(sel, pi, surviving, fp, appraisal)

    selected = np.sort(np.concatenate([boot_idx, surviving]))
    return SelectionResult(selected, boot_idx, survivors_log, appraisal,
                           exec_reports, resumed_phases=resume_from,
                           phase_scores=phase_scores)


def run_selection(key, target_params, cfg: ArchConfig, pool_tokens,
                  sel: SelectionConfig, *, n_classes: int,
                  boot_labels_fn=None) -> SelectionResult:
    """Full pipeline. `boot_labels_fn(idx) -> labels` models the clear
    purchase of the bootstrap sample (labels delivered with the data).

    The sequential driver over `selection_plan`: one fresh WaveExecutor
    per yielded phase — exactly the pre-generator control flow."""
    plan = selection_plan(key, target_params, cfg, pool_tokens, sel,
                          n_classes=n_classes, boot_labels_fn=boot_labels_fn)
    sent = None
    try:
        while True:
            req = plan.send(sent)
            execu = WaveExecutor(dataclasses.replace(sel.executor,
                                                     batch=req.batch))
            ent_sh = execu.score_phase(req.key, req.pp, cfg, req.tokens,
                                       req.spec, variant=sel.variant)
            sent = (ent_sh, execu.reports)
    except StopIteration as done:
        return done.value


def _run_fingerprint(sel: SelectionConfig, n_pool: int, budget: int,
                     boot_idx, target_params, pool_tokens) -> str:
    """Identifies one logical selection run: a checkpoint resumes only a
    re-run with the same pool (contents, not just size), budget,
    bootstrap draw, target weights, AND config (engine/ring, variant,
    phase schedule, proxy-training budgets, §4.4 schedule flags) —
    never a neighbouring experiment sharing the dir. Without the config
    terms, a `--mode mpc` run would silently adopt a clear run's
    survivors and skip the very execution it was asked to measure;
    without the weights/pool digests, a retrained target or regenerated
    pool would inherit survivor indices scored against different
    data."""
    ex = sel.executor
    cfg_desc = (sel.mode,
                getattr(sel.engine, "ring", None) and sel.engine.ring.name,
                tuple(sorted(sel.variant)),
                tuple((p.n_layers, p.n_heads, p.mlp_dim, p.selectivity)
                      for p in sel.phases),
                (sel.exvivo_steps, sel.invivo_steps, sel.finetune_steps,
                 sel.boot_frac),
                (ex.wave, ex.coalesce, ex.overlap, ex.fuse, ex.batch,
                 ex.protocol, sel.score_batch)
                if sel.mode == "mpc" else None)
    h = hashlib.sha1(np.asarray(boot_idx, dtype=np.int64).tobytes())
    h.update(np.asarray([n_pool, budget], dtype=np.int64).tobytes())
    h.update(repr(cfg_desc).encode())
    for leaf in jax.tree.leaves(target_params):
        h.update(np.asarray(leaf).tobytes())
    h.update(np.ascontiguousarray(np.asarray(pool_tokens)).tobytes())
    return h.hexdigest()[:16]


def _load_phase_checkpoints(ckpt_dir: str) -> list[dict]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for f in sorted(os.listdir(ckpt_dir)):
        if f.startswith("phase_") and f.endswith(".json"):
            with open(os.path.join(ckpt_dir, f)) as fh:
                out.append(json.load(fh))
    return out


def _checkpoint_phase(sel: SelectionConfig, phase: int, surviving,
                      fp: str, appraisal: float) -> None:
    if not sel.checkpoint_dir:
        return
    os.makedirs(sel.checkpoint_dir, exist_ok=True)
    path = os.path.join(sel.checkpoint_dir, f"phase_{phase}.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"phase": phase, "surviving": surviving.tolist(),
                   "fp": fp, "appraisal": appraisal}, f)
    os.replace(tmp, path)


def appraise_threshold(ent_sh: AShare, idx, threshold: float, key) -> bool:
    """Paper §4.1 appraisal: if the average entropy of the selected set is
    sensitive, jointly compare the (encrypted) average against a public
    threshold and reveal ONLY the one-bit outcome."""
    from repro.mpc import ops as mops, compare
    sel = ent_sh[np.asarray(idx)]
    avg = mops.mean(sel, axis=0, key=jax.random.fold_in(key, 1))
    thr = mops.add_public(mops.neg(avg), threshold)      # thr - avg
    bit = compare.reveal_lt(thr, thr.with_sh(jnp.zeros_like(thr.sh)))
    return bool(np.asarray(bit))                         # avg > threshold


def resume_phase(sel: SelectionConfig) -> tuple[int, np.ndarray] | None:
    """Restart support: latest completed phase's survivor set.

    `run_selection` consults the same checkpoints itself (guarded by the
    run fingerprint) and skips completed phases — this helper is the
    introspection surface for drivers and tests.
    """
    if not sel.checkpoint_dir:
        return None
    best = None
    for d in _load_phase_checkpoints(sel.checkpoint_dir):
        if best is None or d["phase"] > best[0]:
            best = (d["phase"], np.asarray(d["surviving"]))
    return best
