"""Proxy model generation + execution (paper §4.2).

A proxy M̂ = <l, w, d>: l bottom transformer layers of M_g with w heads,
FFN removed, and nonlinearities replaced by MLP emulators of hidden dim d
(2l + 1 MLPs total: per-layer MLP_sm + MLP_ln, one MLP_se on top).

Generation pipeline (all clear, model-owner side):
  1. extract M_g (bottom max(l_i) layers, weights copied)
  2. finetune M_g on the bootstrap sample
  3. collect Gaussian stats of every nonlinear module's inputs
  4. prune depth/width -> proxy skeleton; ex-vivo-train MLPs; insert
  5. in-vivo finetune the proxy end-to-end on bootstrap (CE on logits),
     then refit MLP_se on the updated logits distribution

Execution paths:
  proxy_entropy_clear  float path (drives in-vivo training + efficacy
                       experiments at scale)
  proxy_entropy_mpc    share-level path (the real protocol; drives the
                       delay model and the Crypten-parity tests)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import approx, target
from repro.core.approx import GaussStats
from repro.models import common
from repro.mpc import ops as mops, compare
from repro.mpc.sharing import AShare, share, from_public
from repro.mpc.ring import RingSpec, RING64


@dataclasses.dataclass(frozen=True)
class ProxySpec:
    n_layers: int
    n_heads: int
    mlp_dim: int
    selectivity: float = 1.0     # fraction kept by this phase


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

def extract_backbone(params, n_layers: int):
    """M_g: bottom n_layers with all weights copied."""
    out = dict(params)
    out["layers"] = jax.tree.map(lambda a: a[:n_layers], params["layers"])
    return out


def collect_stats(params, cfg: ArchConfig, tokens, spec: ProxySpec,
                  max_rows: int = 4096):
    """Gaussian <mu, sigma> of each nonlinearity's inputs on M_g."""
    dh = cfg.d_head
    w = spec.n_heads
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.float32)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    b, s, d = x.shape
    sm_stats, ln_stats = [], []
    for li in range(spec.n_layers):
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, -1, keepdims=True)
        ln_stats.append(GaussStats.estimate(var.reshape(-1, 1)))
        h = common.apply_norm(x, lp["ln1"], cfg.norm_type)
        ap = lp["attn"]
        q = (h @ ap["wq"][:, :w * dh]).reshape(b, s, w, dh)
        k = (h @ ap["wk"][:, :min(w, cfg.n_kv_heads) * dh]
             ).reshape(b, s, min(w, cfg.n_kv_heads), dh)
        qg = q.reshape(b, s, k.shape[2], -1, dh)
        scores = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k) * dh ** -0.5
        sm_stats.append(GaussStats.estimate(scores.reshape(-1, s)[:max_rows]))
        # advance x through the *full* M_g layer (with FFN) for fidelity
        from repro.models import transformer as T
        x, _, _ = T._decoder_layer(x, lp, cfg, mask_kind="bidir",
                                   positions=jnp.arange(s))
    logits = target.classifier_logits(params, cfg, tokens,
                                      n_layers=spec.n_layers)
    se_stats = GaussStats.estimate(logits)
    return {"sm": sm_stats, "ln": ln_stats, "se": se_stats}


def prune(params, cfg: ArchConfig, spec: ProxySpec):
    """Bottom-l layers, first-w heads, FFN dropped."""
    dh = cfg.d_head
    w = spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    layers = jax.tree.map(lambda a: a[:spec.n_layers], params["layers"])
    attn = layers["attn"]
    pruned = {
        "wq": attn["wq"][:, :, :w * dh],
        "wk": attn["wk"][:, :, :wk * dh],
        "wv": attn["wv"][:, :, :wk * dh],
        "wo": attn["wo"][:, :w * dh, :],
    }
    for bname, width in (("bq", w), ("bk", wk), ("bv", wk)):
        if bname in attn:
            pruned[bname] = attn[bname][:, :width * dh]
    ln = layers["ln1"]
    out = {"embed": params["embed"], "cls_head": params["cls_head"],
           "attn": pruned,
           "ln_scale": ln["scale"],
           "ln_bias": ln.get("bias", jnp.zeros_like(ln["scale"]))}
    return out


def build_proxy(key, params_g, cfg: ArchConfig, stats, spec: ProxySpec,
                seq_len: int, n_classes: int, *, exvivo_steps: int = 300):
    """Prune + ex-vivo-train and insert the 2l+1 MLPs."""
    pp = prune(params_g, cfg, spec)
    keys = jax.random.split(key, 2 * spec.n_layers + 1)
    pp["mlp_sm"] = [approx.fit_softmax_mlp(keys[2 * i], stats["sm"][i],
                                           seq_len, spec.mlp_dim,
                                           steps=exvivo_steps)
                    for i in range(spec.n_layers)]
    pp["mlp_ln"] = [approx.fit_rsqrt_mlp(keys[2 * i + 1], stats["ln"][i],
                                         spec.mlp_dim, steps=exvivo_steps)
                    for i in range(spec.n_layers)]
    pp["mlp_se"] = approx.fit_entropy_mlp(keys[-1], stats["se"], n_classes,
                                          spec.mlp_dim, steps=exvivo_steps)
    return pp


# ---------------------------------------------------------------------------
# clear execution
# ---------------------------------------------------------------------------

FULL_VARIANT = frozenset({"sm", "ln", "se"})


def _proxy_layer_clear(x, pp, li, cfg: ArchConfig, spec: ProxySpec,
                       variant=FULL_VARIANT):
    """variant: which nonlinearities use MLP emulators. Members of
    {"sm","ln","se"}; absent -> exact op (Table 2's NoAttnSM/NoAttnLN).
    "quad_sm" replaces softmax by MPCFormer's 2Quad; "poly_sm" by Bolt's
    polynomial exp (Table 3 baselines)."""
    dh = cfg.d_head
    w = spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    b, s, d = x.shape
    # MLP-LayerNorm: numerator exact, reciprocal-sqrt emulated
    mu = jnp.mean(x, -1, keepdims=True)
    xc = x - mu
    var = jnp.mean(xc * xc, -1, keepdims=True)
    if "ln" in variant:
        inv = approx.mlp_apply(jax.tree.map(lambda a: a[li],
                                            _stk(pp["mlp_ln"])),
                               var.reshape(-1, 1)).reshape(b, s, 1)
    else:
        inv = jax.lax.rsqrt(var + 1e-5)
    h = xc * inv * pp["ln_scale"][li] + pp["ln_bias"][li]
    ap = pp["attn"]
    q = h @ ap["wq"][li] + (ap["bq"][li] if "bq" in ap else 0.0)
    k = h @ ap["wk"][li] + (ap["bk"][li] if "bk" in ap else 0.0)
    v = h @ ap["wv"][li] + (ap["bv"][li] if "bv" in ap else 0.0)
    q = q.reshape(b, s, wk, -1, dh)
    k = k.reshape(b, s, wk, dh)
    v = v.reshape(b, s, wk, dh)
    scores = jnp.einsum("bqkgd,bjkd->bkgqj", q, k) * dh ** -0.5
    if "sm" in variant:
        probs = approx.mlp_apply(jax.tree.map(lambda a: a[li],
                                              _stk(pp["mlp_sm"])),
                                 scores.reshape(-1, s)).reshape(scores.shape)
    elif "quad_sm" in variant:       # MPCFormer 2Quad
        e = (scores + 5.0) ** 2
        probs = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-6)
    elif "poly_sm" in variant:       # Bolt-style polynomial exp
        t = jnp.clip(scores - scores.max(-1, keepdims=True), -8, 0)
        e = 1 + t + t * t / 2 + t ** 3 / 6 + t ** 4 / 24
        e = jnp.maximum(e, 0.0)
        probs = e / jnp.maximum(e.sum(-1, keepdims=True), 1e-6)
    else:
        probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqj,bjkd->bqkgd", probs, v).reshape(b, s, w * dh)
    return x + o @ ap["wo"][li]


def _stk(mlps):
    return jax.tree.map(lambda *a: jnp.stack(a), *mlps) if isinstance(mlps, list) \
        else mlps


def proxy_logits_clear(pp, cfg: ArchConfig, tokens, spec: ProxySpec,
                       variant=FULL_VARIANT):
    x = jnp.take(pp["embed"], tokens, axis=0).astype(jnp.float32)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    for li in range(spec.n_layers):
        x = _proxy_layer_clear(x, pp, li, cfg, spec, variant)
    pooled = jnp.mean(x, axis=1)
    return pooled @ pp["cls_head"]


def proxy_entropy_clear(pp, cfg: ArchConfig, tokens, spec: ProxySpec,
                        variant=FULL_VARIANT):
    logits = proxy_logits_clear(pp, cfg, tokens, spec, variant)
    if "se" in variant:
        return approx.mlp_apply(pp["mlp_se"], logits)[:, 0]
    return approx.op_softmax_entropy(logits)[:, 0]


def invivo_finetune(key, pp, cfg: ArchConfig, tokens, labels,
                    spec: ProxySpec, *, steps: int = 150, lr: float = 5e-4,
                    batch: int = 32):
    """Co-tune MLPs + exact weights on bootstrap CE; refit MLP_se after."""
    mlp_se = pp.pop("mlp_se")
    m = jax.tree.map(jnp.zeros_like, pp)
    v = jax.tree.map(jnp.zeros_like, pp)

    def loss_fn(pp, tok, lab):
        logits = proxy_logits_clear(pp, cfg, tok, spec)
        return common.cross_entropy(logits[:, None], lab[:, None])

    @jax.jit
    def step(pp, m, v, tok, lab, i):
        loss, g = jax.value_and_grad(loss_fn)(pp, tok, lab)
        m = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
        v = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, v, g)
        mh = jax.tree.map(lambda m: m / (1 - 0.9 ** (i + 1.0)), m)
        vh = jax.tree.map(lambda v: v / (1 - 0.999 ** (i + 1.0)), v)
        pp = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
                          pp, mh, vh)
        return pp, m, v, loss

    n = tokens.shape[0]
    for i in range(steps):
        key, k = jax.random.split(key)
        idx = jax.random.randint(k, (min(batch, n),), 0, n)
        pp, m, v, _ = step(pp, m, v, tokens[idx], labels[idx], jnp.float32(i))
    # refit the entropy head on the tuned proxy's logit distribution
    logits = proxy_logits_clear(pp, cfg, tokens, spec)
    stats = GaussStats.estimate(logits)
    key, k = jax.random.split(key)
    pp["mlp_se"] = approx.fit_entropy_mlp(k, stats, logits.shape[-1],
                                          mlp_se["w1"].shape[1], steps=300)
    return pp


def random_proxy(key, cfg: ArchConfig, spec: ProxySpec, seq_len: int,
                 n_classes: int):
    """Random-weight proxy, structurally identical to build_proxy output.

    Skips stats collection and ex-vivo training — for harnesses that
    exercise the *protocol* (wave executor, cost-ledger tests, fig7)
    where the MLPs' fidelity is irrelevant but the op stream must be the
    real one. Weights are scaled small so fixed-point entropies stay in
    the ring's comfortable range.
    """
    dh, w = cfg.d_head, spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    L = spec.n_layers
    ks = jax.random.split(key, 6 + 2 * L + 1)
    nrm = lambda k, shape, s: jax.random.normal(k, shape) * s  # noqa: E731
    return {
        "embed": nrm(ks[0], (cfg.vocab_size, cfg.d_model), 0.02),
        "cls_head": nrm(ks[1], (cfg.d_model, n_classes), 0.2),
        "attn": {
            "wq": nrm(ks[2], (L, cfg.d_model, w * dh), 0.08),
            "wk": nrm(ks[3], (L, cfg.d_model, wk * dh), 0.08),
            "wv": nrm(ks[4], (L, cfg.d_model, wk * dh), 0.08),
            "wo": nrm(ks[5], (L, w * dh, cfg.d_model), 0.08),
        },
        "ln_scale": jnp.ones((L, cfg.d_model)),
        "ln_bias": jnp.zeros((L, cfg.d_model)),
        "mlp_sm": [approx.init_mlp(ks[6 + 2 * i], seq_len, spec.mlp_dim,
                                   seq_len) for i in range(L)],
        "mlp_ln": [approx.init_mlp(ks[7 + 2 * i], 1, spec.mlp_dim, 1)
                   for i in range(L)],
        "mlp_se": approx.init_mlp(ks[-1], n_classes, spec.mlp_dim, 1),
    }


# ---------------------------------------------------------------------------
# MPC execution
# ---------------------------------------------------------------------------

def share_proxy(key, pp, ring: RingSpec = RING64):
    """Model owner secret-shares all proxy parameters."""
    leaves, treedef = jax.tree.flatten(pp)
    keys = jax.random.split(key, len(leaves))
    shared = [share(k, l, ring) for k, l in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, shared)


def proxy_entropy_mpc(pp_sh, cfg: ArchConfig, x_emb: AShare,
                      spec: ProxySpec, key) -> AShare:
    """Share-level proxy forward -> encrypted entropy per example.

    x_emb: shared embedded inputs (B, S, d) — the data owner shares
    one-hot rows, the embedding matmul is folded into share generation
    (equivalently a Beaver matmul; its cost is accounted by costs.py).
    """
    dh = cfg.d_head
    w = spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    bsz, s, d = x_emb.shape
    x = x_emb
    for li in range(spec.n_layers):
        key, k0, k1, k2, k3, k4, k5, k6, k7, k8 = jax.random.split(key, 10)
        # LayerNorm numerator exact on MPC
        mu = mops.mean(x, axis=-1, key=k0)
        xc = mops.sub(x, AShare(jnp.broadcast_to(mu.sh[..., None], x.sh.shape),
                                x.ring))
        var = mops.mean(mops.mul(xc, xc, k1), axis=-1, key=k2)
        mlp_ln = jax.tree.map(lambda a: a[li], _stk(pp_sh["mlp_ln"]))
        inv = approx.mlp_apply_mpc(mlp_ln, var.reshape(bsz * s, 1), k3)
        inv_b = AShare(jnp.broadcast_to(
            inv.sh.reshape(2, bsz, s, 1), xc.sh.shape), x.ring)
        h = mops.mul(xc, inv_b, k4)
        gamma = AShare(jnp.broadcast_to(
            pp_sh["ln_scale"].sh[:, li][:, None, None], h.sh.shape), h.ring)
        h = mops.mul(h, gamma, k5)
        beta = AShare(jnp.broadcast_to(
            pp_sh["ln_bias"].sh[:, li][:, None, None], h.sh.shape), h.ring)
        h = mops.add(h, beta)
        # pruned attention
        ap = pp_sh["attn"]
        h2 = h.reshape(bsz * s, d)
        q = mops.matmul(h2, _sl(ap["wq"], li), k6)
        kk = mops.matmul(h2, _sl(ap["wk"], li), jax.random.fold_in(k6, 1))
        vv = mops.matmul(h2, _sl(ap["wv"], li), jax.random.fold_in(k6, 2))
        if "bq" in ap:
            q = mops.add(q, _bcast(_sl(ap["bq"], li), q.shape))
            kk = mops.add(kk, _bcast(_sl(ap["bk"], li), kk.shape))
            vv = mops.add(vv, _bcast(_sl(ap["bv"], li), vv.shape))
        # scores per (batch, kv-head, group): fold heads into batch dims
        q4 = AShare(q.sh.reshape(2, bsz, s, w, dh), q.ring)
        k4_ = AShare(kk.sh.reshape(2, bsz, s, wk, dh), q.ring)
        v4 = AShare(vv.sh.reshape(2, bsz, s, wk, dh), q.ring)
        g = w // wk
        qT = AShare(jnp.moveaxis(q4.sh.reshape(2, bsz, s, wk, g, dh), 2, 4),
                    q.ring)                                        # b wk g s dh
        kT = AShare(jnp.swapaxes(jnp.moveaxis(k4_.sh, 3, 2), -1, -2), q.ring)
        kT_b = AShare(jnp.broadcast_to(kT.sh[:, :, :, None],
                                       (2, bsz, wk, g, dh, s)), q.ring)
        scores = mops.matmul(qT, kT_b, k7)
        scores = mops.mul_public(scores, dh ** -0.5,
                                 key=jax.random.fold_in(k7, 3))
        mlp_sm = jax.tree.map(lambda a: a[li], _stk(pp_sh["mlp_sm"]))
        probs = approx.mlp_apply_mpc(mlp_sm, scores.reshape(bsz * wk * g * s, s),
                                     k8)
        probs = probs.reshape(bsz, wk, g, s, s)
        vT = AShare(jnp.moveaxis(v4.sh, 3, 2), q.ring)             # b wk s dh
        vT_b = AShare(jnp.broadcast_to(vT.sh[:, :, :, None],
                                       (2, bsz, wk, g, s, dh)), q.ring)
        o = mops.matmul(probs, vT_b, jax.random.fold_in(k8, 5))
        o_sh = jnp.moveaxis(o.sh, 4, 2).reshape(2, bsz, s, w * dh)
        o2 = AShare(o_sh.reshape(2, bsz * s, w * dh), q.ring)
        out = mops.matmul(o2, _sl(ap["wo"], li), jax.random.fold_in(k8, 6))
        x = mops.add(x, out.reshape(bsz, s, d))
    key, k9, k10, k11 = jax.random.split(key, 4)
    pooled = mops.mean(x, axis=1, key=k9)
    logits = mops.matmul(pooled, pp_sh["cls_head"], k10)
    ent = approx.mlp_apply_mpc(pp_sh["mlp_se"], logits, k11)
    return ent.reshape(bsz)


def _sl(x: AShare, i: int) -> AShare:
    return AShare(x.sh[:, i], x.ring)


def _bcast(x: AShare, shape) -> AShare:
    return AShare(jnp.broadcast_to(x.sh, (2,) + tuple(shape)), x.ring)
