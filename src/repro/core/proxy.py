"""Proxy model generation + execution (paper §4.2).

A proxy M̂ = <l, w, d>: l bottom transformer layers of M_g with w heads,
FFN removed, and nonlinearities replaced by MLP emulators of hidden dim d
(2l + 1 MLPs total: per-layer MLP_sm + MLP_ln, one MLP_se on top).

Generation pipeline (all clear, model-owner side):
  1. extract M_g (bottom max(l_i) layers, weights copied)
  2. finetune M_g on the bootstrap sample
  3. collect Gaussian stats of every nonlinear module's inputs
  4. prune depth/width -> proxy skeleton; ex-vivo-train MLPs; insert
  5. in-vivo finetune the proxy end-to-end on bootstrap (CE on logits),
     then refit MLP_se on the updated logits distribution

Execution: the proxy forward exists ONCE, engine-generic, in
`engine/forward.py` — `proxy_entropy(engine, pp, cfg, x, spec, variant)`
runs it over clear floats (ClearEngine), secret shares of either
protocol backend (MPCEngine), or the eval_shape cost probe
(TraceEngine).  Construct an engine; the historic
`proxy_entropy_clear`/`proxy_entropy_mpc` shims are gone.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core import approx, target
from repro.core.approx import GaussStats
from repro.engine import forward as engine_forward
from repro.engine.clear import ClearEngine
from repro.models import common
from repro.mpc.sharing import share
from repro.mpc.ring import RingSpec, RING64


@dataclasses.dataclass(frozen=True)
class ProxySpec:
    n_layers: int
    n_heads: int
    mlp_dim: int
    selectivity: float = 1.0     # fraction kept by this phase


# ---------------------------------------------------------------------------
# generation
# ---------------------------------------------------------------------------

def extract_backbone(params, n_layers: int):
    """M_g: bottom n_layers with all weights copied."""
    out = dict(params)
    out["layers"] = jax.tree.map(lambda a: a[:n_layers], params["layers"])
    return out


def collect_stats(params, cfg: ArchConfig, tokens, spec: ProxySpec,
                  max_rows: int = 4096):
    """Gaussian <mu, sigma> of each nonlinearity's inputs on M_g."""
    dh = cfg.d_head
    w = spec.n_heads
    x = jnp.take(params["embed"], tokens, axis=0).astype(jnp.float32)
    x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    b, s, d = x.shape
    sm_stats, ln_stats = [], []
    for li in range(spec.n_layers):
        lp = jax.tree.map(lambda a: a[li], params["layers"])
        mu = jnp.mean(x, -1, keepdims=True)
        var = jnp.mean((x - mu) ** 2, -1, keepdims=True)
        ln_stats.append(GaussStats.estimate(var.reshape(-1, 1)))
        h = common.apply_norm(x, lp["ln1"], cfg.norm_type)
        ap = lp["attn"]
        q = (h @ ap["wq"][:, :w * dh]).reshape(b, s, w, dh)
        k = (h @ ap["wk"][:, :min(w, cfg.n_kv_heads) * dh]
             ).reshape(b, s, min(w, cfg.n_kv_heads), dh)
        qg = q.reshape(b, s, k.shape[2], -1, dh)
        # NOT a proxy forward (that lives solely in engine/forward.py):
        # this probes M_g's attention-score distribution to fit MLP_sm
        scores = jnp.einsum("bqkgd,bjkd->bkgqj", qg, k) * dh ** -0.5
        sm_stats.append(GaussStats.estimate(scores.reshape(-1, s)[:max_rows]))
        # advance x through the *full* M_g layer (with FFN) for fidelity
        from repro.models import transformer as T
        x, _, _ = T._decoder_layer(x, lp, cfg, mask_kind="bidir",
                                   positions=jnp.arange(s))
    logits = target.classifier_logits(params, cfg, tokens,
                                      n_layers=spec.n_layers)
    se_stats = GaussStats.estimate(logits)
    return {"sm": sm_stats, "ln": ln_stats, "se": se_stats}


def prune(params, cfg: ArchConfig, spec: ProxySpec):
    """Bottom-l layers, first-w heads, FFN dropped."""
    dh = cfg.d_head
    w = spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    layers = jax.tree.map(lambda a: a[:spec.n_layers], params["layers"])
    attn = layers["attn"]
    pruned = {
        "wq": attn["wq"][:, :, :w * dh],
        "wk": attn["wk"][:, :, :wk * dh],
        "wv": attn["wv"][:, :, :wk * dh],
        "wo": attn["wo"][:, :w * dh, :],
    }
    for bname, width in (("bq", w), ("bk", wk), ("bv", wk)):
        if bname in attn:
            pruned[bname] = attn[bname][:, :width * dh]
    ln = layers["ln1"]
    out = {"embed": params["embed"], "cls_head": params["cls_head"],
           "attn": pruned,
           "ln_scale": ln["scale"],
           "ln_bias": ln.get("bias", jnp.zeros_like(ln["scale"]))}
    return out


def build_proxy(key, params_g, cfg: ArchConfig, stats, spec: ProxySpec,
                seq_len: int, n_classes: int, *, exvivo_steps: int = 300):
    """Prune + ex-vivo-train and insert the 2l+1 MLPs."""
    pp = prune(params_g, cfg, spec)
    keys = jax.random.split(key, 2 * spec.n_layers + 1)
    pp["mlp_sm"] = [approx.fit_softmax_mlp(keys[2 * i], stats["sm"][i],
                                           seq_len, spec.mlp_dim,
                                           steps=exvivo_steps)
                    for i in range(spec.n_layers)]
    pp["mlp_ln"] = [approx.fit_rsqrt_mlp(keys[2 * i + 1], stats["ln"][i],
                                         spec.mlp_dim, steps=exvivo_steps)
                    for i in range(spec.n_layers)]
    pp["mlp_se"] = approx.fit_entropy_mlp(keys[-1], stats["se"], n_classes,
                                          spec.mlp_dim, steps=exvivo_steps)
    return pp


def invivo_finetune(key, pp, cfg: ArchConfig, tokens, labels,
                    spec: ProxySpec, *, steps: int = 150, lr: float = 5e-4,
                    batch: int = 32):
    """Co-tune MLPs + exact weights on bootstrap CE; refit MLP_se after."""
    mlp_se = pp.pop("mlp_se")
    m = jax.tree.map(jnp.zeros_like, pp)
    v = jax.tree.map(jnp.zeros_like, pp)
    eng = ClearEngine()

    def loss_fn(pp, tok, lab):
        logits = engine_forward.proxy_logits(eng, pp, cfg, tok, spec)
        return common.cross_entropy(logits[:, None], lab[:, None])

    @jax.jit
    def step(pp, m, v, tok, lab, i):
        loss, g = jax.value_and_grad(loss_fn)(pp, tok, lab)
        m = jax.tree.map(lambda m, g: 0.9 * m + 0.1 * g, m, g)
        v = jax.tree.map(lambda v, g: 0.999 * v + 0.001 * g * g, v, g)
        mh = jax.tree.map(lambda m: m / (1 - 0.9 ** (i + 1.0)), m)
        vh = jax.tree.map(lambda v: v / (1 - 0.999 ** (i + 1.0)), v)
        pp = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + 1e-8),
                          pp, mh, vh)
        return pp, m, v, loss

    n = tokens.shape[0]
    for i in range(steps):
        key, k = jax.random.split(key)
        idx = jax.random.randint(k, (min(batch, n),), 0, n)
        pp, m, v, _ = step(pp, m, v, tokens[idx], labels[idx], jnp.float32(i))
    # refit the entropy head on the tuned proxy's logit distribution
    logits = engine_forward.proxy_logits(eng, pp, cfg, tokens, spec)
    stats = GaussStats.estimate(logits)
    key, k = jax.random.split(key)
    pp["mlp_se"] = approx.fit_entropy_mlp(k, stats, logits.shape[-1],
                                          mlp_se["w1"].shape[1], steps=300)
    return pp


def random_proxy(key, cfg: ArchConfig, spec: ProxySpec, seq_len: int,
                 n_classes: int):
    """Random-weight proxy, structurally identical to build_proxy output.

    Skips stats collection and ex-vivo training — for harnesses that
    exercise the *protocol* (wave executor, cost-ledger tests, fig7)
    where the MLPs' fidelity is irrelevant but the op stream must be the
    real one. Weights are scaled small so fixed-point entropies stay in
    the ring's comfortable range.
    """
    dh, w = cfg.d_head, spec.n_heads
    wk = min(w, cfg.n_kv_heads)
    L = spec.n_layers
    ks = jax.random.split(key, 6 + 2 * L + 1)
    nrm = lambda k, shape, s: jax.random.normal(k, shape) * s  # noqa: E731
    return {
        "embed": nrm(ks[0], (cfg.vocab_size, cfg.d_model), 0.02),
        "cls_head": nrm(ks[1], (cfg.d_model, n_classes), 0.2),
        "attn": {
            "wq": nrm(ks[2], (L, cfg.d_model, w * dh), 0.08),
            "wk": nrm(ks[3], (L, cfg.d_model, wk * dh), 0.08),
            "wv": nrm(ks[4], (L, cfg.d_model, wk * dh), 0.08),
            "wo": nrm(ks[5], (L, w * dh, cfg.d_model), 0.08),
        },
        "ln_scale": jnp.ones((L, cfg.d_model)),
        "ln_bias": jnp.zeros((L, cfg.d_model)),
        "mlp_sm": [approx.init_mlp(ks[6 + 2 * i], seq_len, spec.mlp_dim,
                                   seq_len) for i in range(L)],
        "mlp_ln": [approx.init_mlp(ks[7 + 2 * i], 1, spec.mlp_dim, 1)
                   for i in range(L)],
        "mlp_se": approx.init_mlp(ks[-1], n_classes, spec.mlp_dim, 1),
    }


# ---------------------------------------------------------------------------
# MPC execution
# ---------------------------------------------------------------------------

def share_proxy(key, pp, ring: RingSpec = RING64, proto: str = "2pc"):
    """Model owner secret-shares all proxy parameters (any protocol
    backend: the leading party-axis size follows `proto`)."""
    leaves, treedef = jax.tree.flatten(pp)
    keys = jax.random.split(key, len(leaves))
    shared = [share(k, l, ring, proto) for k, l in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, shared)
