"""Fault-tolerance runtime: heartbeats, stragglers, retries, elasticity.

Design intent at 1000+ nodes:
  * every host runs a HeartbeatMonitor; a missed deadline marks the host
    suspect and triggers the launcher's restart-from-checkpoint path
    (checkpoint/ckpt.py provides the atomic resume point; selection
    phases additionally checkpoint survivor sets at phase boundaries).
  * data-loading and MPC batch execution run under StragglerMitigator:
    if a task exceeds p95 * slack, a backup task is dispatched and the
    first finisher wins (classic backup-requests).
  * ElasticPlan computes the host-level transfer spec when the mesh is
    re-factorized (shrink on failure / grow on recovery) so re-sharding
    moves only the diff, not a full re-init.

Everything is process-local and deterministic here (single-host CPU
container); the interfaces match what the multi-host launcher drives.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable

import numpy as np


class HeartbeatMonitor:
    def __init__(self, n_hosts: int, timeout_s: float = 10.0,
                 clock: Callable[[], float] = time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last = {h: clock() for h in range(n_hosts)}
        self._lock = threading.Lock()

    def beat(self, host: int) -> None:
        with self._lock:
            self._last[host] = self._clock()

    def suspects(self) -> list[int]:
        now = self._clock()
        with self._lock:
            return [h for h, t in self._last.items()
                    if now - t > self.timeout_s]

    def healthy(self) -> bool:
        return not self.suspects()


class StragglerMitigator:
    """Deadline-based backup dispatch; tracks a running p95 of task
    times. `clock` is injectable so tests can drive deterministic task
    durations without sleeping."""

    def __init__(self, slack: float = 2.0, window: int = 64,
                 clock: Callable[[], float] = time.monotonic):
        self.slack = slack
        self._times: list[float] = []
        self._window = window
        self._clock = clock
        self.backups_fired = 0

    def deadline(self) -> float:
        if len(self._times) < 8:
            return float("inf")
        return float(np.percentile(self._times[-self._window:], 95)) * self.slack

    def run(self, task: Callable[[], object],
            backup: Callable[[], object] | None = None):
        t0 = self._clock()
        deadline = self.deadline()
        result = task()
        dt = self._clock() - t0
        if dt > deadline and backup is not None:
            self.backups_fired += 1
            result = backup()          # first-finisher-wins (serial sim)
        self._times.append(dt)
        return result


class TransportHeartbeat:
    """Heartbeats riding a party transport as control frames.

    Duck-typed over `net.transport.Transport` (anything with
    `send(src, dst, data, kind)` / `try_recv(dst, src, kind)`) so this
    module never imports the net package. `kind` defaults to the BEAT
    frame kind (net.transport.BEAT == 1).

    Non-zero parties `emit()` a zero-byte BEAT to party 0 between
    flights; party 0 `drain()`s its beat queues non-blockingly into a
    HeartbeatMonitor — a silent party ages out of the monitor exactly
    like a dead host would, while healthy parties cost one control frame
    per beat interval on the already-open links.
    """

    def __init__(self, transport, party: int, n_parties: int,
                 monitor: HeartbeatMonitor | None = None, kind: int = 1):
        self.transport = transport
        self.party = party
        self.n_parties = n_parties
        self.monitor = monitor              # party 0 owns one; others None
        self.kind = kind
        self.beats_seen = 0

    def emit(self) -> None:
        if self.party != 0:
            self.transport.send(self.party, 0, b"", kind=self.kind)

    def drain(self) -> int:
        """Party 0: absorb all waiting beats; returns how many."""
        if self.monitor is None:
            return 0
        self.monitor.beat(0)                # party 0 vouches for itself
        got = 0
        for src in range(1, self.n_parties):
            while self.transport.try_recv(0, src, kind=self.kind) is not None:
                self.monitor.beat(src)
                got += 1
        self.beats_seen += got
        return got


def retry(fn: Callable[[], object], *, attempts: int = 3,
          backoff_s: float = 0.1, retriable=(IOError, OSError),
          sleep: Callable[[float], None] = time.sleep,
          clock: Callable[[], float] = time.monotonic,
          deadline_s: float | None = None,
          max_backoff_s: float = 5.0):
    """Call `fn` until it returns, retrying `retriable` failures with
    exponential backoff. `sleep`/`clock` are injectable so transports can
    service control traffic during the wait and tests can run without
    wall-clock time; `deadline_s` bounds the TOTAL elapsed time (checked
    before each backoff sleep) — the socket dial loop and the reliable
    recv resend loop both run on this one primitive."""
    last = None
    t0 = clock()
    for i in range(attempts):
        try:
            return fn()
        except retriable as e:           # noqa: PERF203
            last = e
            if i == attempts - 1:        # no pointless sleep after the end
                break
            wait = min(backoff_s * (2 ** i), max_backoff_s)
            if deadline_s is not None and clock() - t0 + wait > deadline_s:
                break
            sleep(wait)
    raise last


# ---------------------------------------------------------------------------
# elastic re-mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ElasticPlan:
    old_shape: tuple[int, ...]
    new_shape: tuple[int, ...]
    moves: list[tuple[int, int]]         # (src_host, dst_host) transfers
    reshard_fraction: float              # fraction of bytes that move
    bytes_moved: int = 0                 # reshard_fraction * total bytes


def plan_remesh(old_shape: tuple[int, ...], new_shape: tuple[int, ...],
                bytes_per_host: int = 1) -> ElasticPlan:
    """Host-level transfer plan for a mesh re-factorization.

    Model: parameters are range-sharded over the flattened mesh; host h of
    N owns slice [h/N, (h+1)/N). On re-factorization to M hosts, dst d
    needs bytes overlapping [d/M, (d+1)/M) — moves are the off-diagonal
    overlaps (contiguous-range reshard, the standard scalable scheme).
    `bytes_per_host` sizes the old shards, so `bytes_moved` is the wire
    cost of the transfer in bytes (the launcher budgets recovery time
    against it).
    """
    n = int(np.prod(old_shape))
    m = int(np.prod(new_shape))
    moves: list[tuple[int, int]] = []
    moved = 0.0
    for d in range(m):
        lo, hi = d / m, (d + 1) / m
        for s in range(n):
            slo, shi = s / n, (s + 1) / n
            ov = max(0.0, min(hi, shi) - max(lo, slo))
            if ov > 1e-12 and s != d:
                moves.append((s, d))
                moved += ov
    return ElasticPlan(old_shape, new_shape, moves, moved,
                       int(round(moved * n * bytes_per_host)))
