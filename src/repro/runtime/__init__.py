from repro.runtime.ft import (
    HeartbeatMonitor, StragglerMitigator, retry, ElasticPlan, plan_remesh,
)
