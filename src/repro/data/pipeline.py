"""Deterministic sharded data pipeline for LM training.

Synthetic-corpus based (offline container), but with the structure of a
production loader: per-host deterministic sharding by (step, host_id),
stateless batch addressing (resume = replay from step), background
prefetch, and pack-to-seq_len. `DataPipeline.state()` round-trips through
the checkpointer so restarts are exactly-once.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


def synth_lm_batch(seed: int, step: int, host: int, n_hosts: int,
                   batch: int, seq: int, vocab: int):
    """Deterministic (step, host)-addressed LM batch. Markov-ish synthetic
    token stream so the loss actually decreases during examples."""
    rng = np.random.default_rng((seed * 1_000_003 + step) * 64 + host)
    b_local = batch // n_hosts
    base = rng.integers(0, vocab, size=(b_local, 1), dtype=np.int32)
    steps = rng.integers(1, 7, size=(b_local, seq), dtype=np.int32)
    toks = (base + np.cumsum(steps, axis=1)) % vocab
    tokens = toks.astype(np.int32)
    labels = np.roll(tokens, -1, axis=1).astype(np.int32)
    labels[:, -1] = -1
    return {"tokens": tokens, "labels": labels}


@dataclasses.dataclass
class PipelineState:
    seed: int
    step: int


class DataPipeline:
    """Background-prefetching deterministic loader."""

    def __init__(self, seed: int, batch: int, seq: int, vocab: int,
                 host: int = 0, n_hosts: int = 1, prefetch: int = 2,
                 start_step: int = 0):
        self.seed, self.batch, self.seq, self.vocab = seed, batch, seq, vocab
        self.host, self.n_hosts = host, n_hosts
        self._step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            b = synth_lm_batch(self.seed, step, self.host, self.n_hosts,
                               self.batch, self.seq, self.vocab)
            while not self._stop.is_set():
                try:
                    self._q.put((step, b), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __next__(self):
        step, b = self._q.get()
        self._step = step + 1
        return b

    def state(self) -> PipelineState:
        return PipelineState(self.seed, self._step)

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
