"""Synthetic classification tasks for the selection experiments.

Mirrors the paper's data premise (§2.1): the candidate pool is UNLABELED
and class-IMBALANCED. Each class c has a token unigram distribution
(peaked on a class-specific subset of the vocabulary) plus class-neutral
noise tokens; sequences are sampled per-class. Imbalance removes most
minority-class examples from the pool — exactly the regime where
entropy-based selection beats random (the model is least confident on
under-represented classes, so selection re-balances the training set).

The pool also contains a REDUNDANT slab: near-duplicate easy examples of
the majority class (paper §1: "datasets are often redundant and noisy").
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class ClassTask:
    pool_tokens: np.ndarray      # (N, S) int32 — unlabeled candidates
    pool_labels: np.ndarray      # (N,) hidden labels (owner-side only)
    test_tokens: np.ndarray
    test_labels: np.ndarray
    n_classes: int
    vocab: int


def make_classification_task(seed: int, *, n_pool: int = 2000,
                             n_test: int = 500, seq: int = 16,
                             vocab: int = 512, n_classes: int = 4,
                             imbalance: float = 8.0,
                             signal: float = 0.75,
                             redundancy: float = 0.3) -> ClassTask:
    """imbalance: majority/minority prior ratio; signal: fraction of
    class-informative tokens per sequence; redundancy: fraction of the
    pool replaced by near-duplicate majority examples."""
    rng = np.random.default_rng(seed)
    toks_per_class = vocab // (n_classes + 1)
    class_tokens = [np.arange(c * toks_per_class, (c + 1) * toks_per_class)
                    for c in range(n_classes)]
    noise_tokens = np.arange(n_classes * toks_per_class, vocab)

    def sample(label: int, n: int) -> np.ndarray:
        informative = rng.choice(class_tokens[label], size=(n, seq))
        noise = rng.choice(noise_tokens, size=(n, seq))
        take = rng.random((n, seq)) < signal
        return np.where(take, informative, noise).astype(np.int32)

    # geometric class priors: p(c) ~ imbalance^{-c/(C-1)}
    w = imbalance ** (-np.arange(n_classes) / max(n_classes - 1, 1))
    priors = w / w.sum()

    pool_labels = rng.choice(n_classes, size=n_pool, p=priors)
    pool_tokens = np.concatenate([sample(int(y), 1) for y in pool_labels])
    # redundant slab: near-duplicates of one majority example
    n_red = int(redundancy * n_pool)
    if n_red:
        proto = sample(0, 1)[0]
        dup = np.tile(proto, (n_red, 1))
        flip = rng.random(dup.shape) < 0.05
        dup = np.where(flip, rng.integers(0, vocab, dup.shape), dup)
        idx = rng.choice(n_pool, size=n_red, replace=False)
        pool_tokens[idx] = dup
        pool_labels[idx] = 0

    test_labels = rng.integers(0, n_classes, size=n_test)   # balanced test
    test_tokens = np.concatenate([sample(int(y), 1) for y in test_labels])
    return ClassTask(pool_tokens, pool_labels.astype(np.int32),
                     test_tokens, test_labels.astype(np.int32),
                     n_classes, vocab)
