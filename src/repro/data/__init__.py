from repro.data.tasks import make_classification_task, ClassTask
from repro.data.pipeline import DataPipeline, synth_lm_batch
