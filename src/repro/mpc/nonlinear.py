"""CrypTen-style nonlinear baselines over shares.

These are the *expensive* ops the paper replaces with MLPs. They are real
share-level protocols built from Beaver multiplications (exp, reciprocal,
rsqrt, log) plus the comparison functionality (max, relu). Their cost is
what makes Figure 2 / Figure 6's "Oracle" so slow; our benchmarks measure
them via the ambient Ledger.

Scale discipline: every public entry point FORCES its input to the
canonical exponent first (`ops.force`) — the iterative approximations
are tuned for canonical fixed-point precision, and forcing at the
boundary keeps each protocol's internal op/cost stream identical no
matter what carried exponent the caller accumulated (mpc/scale.py).
Inside, products ride at 2f and the next iteration's multiply forces
them back — the same one-trunc-per-consumption contract as everywhere
else. Outputs are returned at their natural (usually 2f) exponent; the
caller's consumer forces once more if it cares.

Approximation choices follow CrypTen (Knott et al. 2021):
  exp(x)        limit approximation (1 + x/2**t)**(2**t), t=8 squarings
  reciprocal(x) Newton-Raphson, init 3*exp(0.5-x)+0.003, 10 iterations
  rsqrt/sqrt    Newton-Raphson on y -> y(3 - x y^2)/2, 10 iterations
  log(x)        2nd-order Householder iterations (CrypTen uses 8)
  softmax       x - max(x); exp; sum; reciprocal; mul
  gelu          0.5x(1+tanh-poly) via polynomial (MPC-friendly)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc.sharing import Share, from_public
from repro.mpc import ops, compare

EXP_ITERS = 8
RECIP_ITERS = 10
RSQRT_ITERS = 10
LOG_ITERS = 8


def exp(x: Share, key: jax.Array) -> Share:
    """(1 + x/2**t)**(2**t): t sequential squarings = t rounds."""
    x = ops.force(x, jax.random.fold_in(key, 89))
    # x/2**t is a pure exponent fold; the first squaring forces it back
    y = ops.add_public(ops.mul_public(x, 1.0 / (1 << EXP_ITERS),
                                      key=jax.random.fold_in(key, 99)), 1.0)
    for i in range(EXP_ITERS):
        y = ops.square(y, jax.random.fold_in(key, i))
    return y


def reciprocal(x: Share, key: jax.Array) -> Share:
    """NR iterations y <- y(2 - x y); init 3 exp(0.5 - x) + 0.003."""
    x = ops.force(x, jax.random.fold_in(key, 89))
    k0, key = jax.random.split(key)
    init = ops.add_public(
        ops.mul_public(exp(ops.add_public(ops.neg(x), 0.5), k0), 3.0,
                       key=jax.random.fold_in(key, 98)),
        0.003)
    y = init
    for i in range(RECIP_ITERS):
        ki = jax.random.fold_in(key, i)
        xy = ops.mul(x, y, ki)
        y = ops.mul(y, ops.add_public(ops.neg(xy), 2.0),
                    jax.random.fold_in(ki, 1))
    return y


def rsqrt(x: Share, key: jax.Array) -> Share:
    """NR for 1/sqrt(x): y <- y(3 - x y^2)/2, init 3*exp(-(x/2+0.2))+0.2."""
    x = ops.force(x, jax.random.fold_in(key, 89))
    k0, key = jax.random.split(key)
    init = ops.add_public(
        ops.mul_public(
            exp(ops.add_public(ops.mul_public(ops.neg(x), 0.5,
                                              key=jax.random.fold_in(key, 97)),
                               -0.2), k0),
            3.0, key=jax.random.fold_in(key, 96)),
        0.2)
    y = init
    for i in range(RSQRT_ITERS):
        ki = jax.random.fold_in(key, i)
        y2 = ops.square(y, ki)
        xy2 = ops.mul(x, y2, jax.random.fold_in(ki, 1))
        y = ops.mul_public(
            ops.mul(y, ops.add_public(ops.neg(xy2), 3.0), jax.random.fold_in(ki, 2)),
            0.5, key=jax.random.fold_in(ki, 3))
    return y


def log(x: Share, key: jax.Array) -> Share:
    """Householder iterations: y <- y - 1 + x*exp(-y) (order-1 form)."""
    x = ops.force(x, jax.random.fold_in(key, 89))
    y = ops.add_public(ops.mul_public(x, 1.0 / 120.0,
                                      key=jax.random.fold_in(key, 95)), 2.0)
    # crude affine init y0 ~ x/120 + 2 (CrypTen uses x/120 - 20exp(-2x-1)+3)
    for i in range(LOG_ITERS):
        ki = jax.random.fold_in(key, i)
        e = exp(ops.neg(y), ki)
        xe = ops.mul(x, e, jax.random.fold_in(ki, 1))
        y = ops.add_public(ops.add(y, xe), -1.0)
    return y


def softmax(x: Share, key: jax.Array, axis: int = -1,
            stabilize: bool = True) -> Share:
    """CrypTen softmax: subtract max (comparison tree), exp, normalize."""
    kmax, kexp, krec, kmul, key = jax.random.split(key, 5)
    x = ops.force(x, jax.random.fold_in(key, 89))
    if stabilize:
        mx = compare.max_(x, axis=axis, key=kmax)
        x = ops.sub(x, mx.with_sh(jnp.broadcast_to(mx.sh, x.sh.shape)))
    e = exp(x, kexp)
    s = ops.sum_(e, axis=axis, keepdims=True)
    r = reciprocal(s, krec)
    return ops.mul(e, r.with_sh(jnp.broadcast_to(r.sh, e.sh.shape)), kmul)


def layernorm(x: Share, gamma, beta, key: jax.Array, eps: float = 1e-5) -> Share:
    """LayerNorm with NR-rsqrt for the variance reciprocal sqrt."""
    kvar, krs, kmul, kaff = jax.random.split(key, 4)
    mu = ops.mean(x, axis=-1, key=jax.random.fold_in(key, 94))
    xc = ops.sub(x, mu.with_sh(jnp.broadcast_to(mu.sh[..., None],
                                                x.sh.shape)))
    var = ops.mean(ops.square(xc, kvar), axis=-1,
                   key=jax.random.fold_in(key, 93))
    inv = rsqrt(ops.add_public(var, eps), krs)
    xn = ops.mul(xc, inv.with_sh(jnp.broadcast_to(inv.sh[..., None],
                                                  xc.sh.shape)), kmul)
    out = ops.mul_public(xn, gamma, key=kaff)
    return ops.add(out, from_public(jnp.broadcast_to(jnp.asarray(beta),
                                                     out.shape),
                                    out.ring, out.proto))


def entropy_from_logits(logits: Share, key: jax.Array) -> Share:
    """H = -sum p log p over the class axis — the Oracle's scoring op."""
    ksm, klog, kmul, key = jax.random.split(key, 4)
    p = softmax(logits, ksm, axis=-1)
    lp = log(ops.add_public(p, 1e-6), klog)
    plp = ops.mul(p, lp, kmul)
    return ops.neg(ops.sum_(plp, axis=-1))


def gelu(x: Share, key: jax.Array) -> Share:
    """Quad approximation (MPCFormer uses this for the *baseline* models)."""
    k1, k2 = jax.random.split(key)
    x = ops.force(x, jax.random.fold_in(key, 89))
    x2 = ops.square(x, k1)
    # 0.125 x^2 + 0.25 x + 0.5  (times x) — MPCFormer's "2Quad" GeLU
    inner = ops.add_public(
        ops.add(ops.mul_public(x2, 0.125, key=jax.random.fold_in(key, 92)),
                ops.mul_public(x, 0.25, key=jax.random.fold_in(key, 91))),
        0.5)
    return ops.mul(x, inner, k2)
