"""Additive 2PC secret shares.

AShare stacks both parties' shares on a leading axis of size 2:
  sh[0] = party-0 share, sh[1] = party-1 share,  value = sh[0] + sh[1] (ring)

This layout is deliberate: on the multi-pod mesh the party axis is sharded
over the "pod" mesh axis, so party-0's share physically lives on pod 0 and
every `open` is an inter-pod collective (psum over "pod"). On a single pod
the two shares are co-located ("simulation mode"). Either way the
arithmetic is identical.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec, RING64
from repro.mpc import comm


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class AShare:
    sh: jax.Array                 # (2, *shape) ring ints
    ring: RingSpec                # static

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return (self.sh,), self.ring

    @classmethod
    def tree_unflatten(cls, ring, children):
        return cls(children[0], ring)

    # -- convenience ----------------------------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.sh.shape[1:])

    @property
    def ndim(self) -> int:
        return self.sh.ndim - 1

    def __getitem__(self, idx) -> "AShare":
        idx = idx if isinstance(idx, tuple) else (idx,)
        return AShare(self.sh[(slice(None),) + idx], self.ring)

    def reshape(self, *shape) -> "AShare":
        return AShare(self.sh.reshape((2,) + tuple(shape)), self.ring)

    def astuple(self) -> tuple[jax.Array, jax.Array]:
        return self.sh[0], self.sh[1]


def share(key: jax.Array, x: jax.Array, ring: RingSpec = RING64) -> AShare:
    """Encode x in the ring and split into two uniform additive shares."""
    enc = ring.encode(x)
    r = ring.rand(key, enc.shape)
    return AShare(jnp.stack([r, enc - r]), ring)


def share_encoded(key: jax.Array, enc: jax.Array, ring: RingSpec = RING64) -> AShare:
    r = ring.rand(key, enc.shape)
    return AShare(jnp.stack([r, enc - r]), ring)


def open_(x: AShare, op: str = "open") -> jax.Array:
    """Reconstruct the ring element (each party sends its share: 1 round)."""
    comm.record(op, rounds=1, nbytes=2 * x.ring.elem_bytes * _numel(x),
                numel=_numel(x), tag="bw")
    return x.sh[0] + x.sh[1]


def reveal(x: AShare) -> jax.Array:
    """Open and decode to float."""
    return x.ring.decode(open_(x))


def zeros_like(x: AShare) -> AShare:
    return AShare(jnp.zeros_like(x.sh), x.ring)


def from_public(v: jax.Array, ring: RingSpec = RING64) -> AShare:
    """A public constant as a (trivial) share: party 0 holds it all."""
    enc = ring.encode(v)
    return AShare(jnp.stack([enc, jnp.zeros_like(enc)]), ring)


def _numel(x: AShare) -> int:
    n = 1
    for d in x.shape:
        n *= int(d)
    return n
