"""Protocol-generic secret shares.

A `Share` stacks every party's share component on a leading axis whose
size the protocol backend decides (`mpc/protocols/`):

  2pc  additive 2-party:      sh[0] + sh[1] = value          (axis 2)
  3pc  replicated 2-of-3:     sh[0] + sh[1] + sh[2] = value  (axis 3),
       party i holds the pair (sh[i], sh[i+1 mod 3])

This layout is deliberate: on the multi-pod mesh the party axis is
sharded over the "pod" mesh axis, so each party's component physically
lives on its own pod and every `open` is an inter-pod collective. On a
single pod the components are co-located ("simulation mode"). Either
way the arithmetic is identical.

The share container itself is protocol-agnostic: it carries the ring,
the protocol name, AND the fixed-point scale it is currently encoded at
(`fb`, the carried frac-bits exponent of mpc/scale.py) — all three are
static pytree aux data. Every op that depends on the sharing scheme —
`share`, `open_`, multiplication, truncation — routes through the
backend registered under `proto`; every op that changes the scale
adjusts `fb`, so "this tensor still owes a truncation" is a tracked
property of the value instead of an implicit calling convention.
`open_` no longer hard-codes the 2-party wire model: bytes-on-wire come
from `backend.open_bytes`.

Opening is the one scale boundary that resolves for free: a revealed
ring element is public, so the receiver applies the exact division by
2**fb during decode — truncation protocols exist only because SECRET
values cannot be shifted exactly, and `reveal` therefore never forces
one (see ops.force for the consumers that must).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec, RING64
from repro.mpc import comm


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Share:
    sh: jax.Array                 # (n_parties, *shape) ring ints
    ring: RingSpec                # static
    proto: str = "2pc"            # static: protocol backend name
    fb: int | None = None         # static: carried frac-bits exponent
                                  # (None normalizes to ring.frac_bits)

    def __post_init__(self):
        if self.fb is None:
            self.fb = self.ring.frac_bits

    # -- pytree protocol ------------------------------------------------
    def tree_flatten(self):
        return (self.sh,), (self.ring, self.proto, self.fb)

    @classmethod
    def tree_unflatten(cls, aux, children):
        ring, proto, fb = aux
        return cls(children[0], ring, proto, fb)

    # -- convenience ----------------------------------------------------
    @property
    def shape(self) -> tuple[int, ...]:
        return tuple(self.sh.shape[1:])

    @property
    def ndim(self) -> int:
        return self.sh.ndim - 1

    @property
    def n_parties(self) -> int:
        return self.sh.shape[0]

    @property
    def excess(self) -> int:
        """Frac bits above canonical — the truncation this value owes."""
        return self.fb - self.ring.frac_bits

    @property
    def backend(self):
        from repro.mpc import protocols
        return protocols.get(self.proto)

    def with_sh(self, sh: jax.Array) -> "Share":
        """Same ring/protocol/SCALE, new share components — THE way to
        rebuild a share from a scale-preserving transform (a bare
        Share(sh, ring) would silently re-label 3PC shares as 2PC and
        re-stamp a 2f-scale tensor as canonical)."""
        return Share(sh, self.ring, self.proto, self.fb)

    def with_scale(self, sh: jax.Array, fb: int) -> "Share":
        """Rebuild at a different carried exponent (product emission,
        truncation, lifts)."""
        return Share(sh, self.ring, self.proto, fb)

    def derive(self, fn) -> "Share":
        """Scale-preserving LAYOUT transform (reshape/moveaxis/broadcast
        ...) that remembers its source: `ops.force` walks this lineage
        so a forced truncation fires once on the pre-layout tensor (at
        its smaller element count, for broadcasts) and the cheap layout
        replays on the truncated components."""
        out = self.with_sh(fn(self.sh))
        out._lineage = (self, fn)
        return out

    def __getitem__(self, idx) -> "Share":
        idx = idx if isinstance(idx, tuple) else (idx,)
        return self.with_sh(self.sh[(slice(None),) + idx])

    def reshape(self, *shape) -> "Share":
        return self.derive(
            lambda sh: sh.reshape((sh.shape[0],) + tuple(shape)))

    def astuple(self) -> tuple:
        return tuple(self.sh[i] for i in range(self.sh.shape[0]))


# Historic name — the additive-2PC container before protocols became
# pluggable. Every call site that builds one positionally still works
# (proto defaults to "2pc").
AShare = Share


def reconstruct(sh) -> jax.Array:
    """Functionality-boundary reconstruction.

    Pass a `Share` to dispatch to its backend — REQUIRED for schemes
    whose extra leading-axis rows are not value components (spdz2pc's
    MAC rows: summing all four rows would yield value + alpha*value),
    and what lets MAC'd backends enqueue the check obligation for every
    opened value. A raw stacked array still sums its rows (the legacy
    additive path, correct for 2pc/3pc component arrays)."""
    if isinstance(sh, Share):
        return sh.backend.reconstruct(sh.sh)
    out = sh[0]
    for i in range(1, sh.shape[0]):
        out = out + sh[i]
    return out


def share(key: jax.Array, x: jax.Array, ring: RingSpec = RING64,
          proto: str = "2pc") -> Share:
    """Encode x in the ring and split into uniform shares (backend
    layout: 2 additive components for 2pc, 3 replicated for 3pc)."""
    return share_encoded(key, ring.encode(x), ring, proto)


def share_encoded(key: jax.Array, enc: jax.Array, ring: RingSpec = RING64,
                  proto: str = "2pc", fb: int | None = None) -> Share:
    """Split an already-encoded ring tensor; `fb` tags the scale the
    encoding carries (comparison bits are shared at fb=0, making the
    b*(x-y) selection multiply exact and truncation-free)."""
    from repro.mpc import protocols
    return Share(protocols.get(proto).share_encoded(key, enc, ring), ring,
                 proto, fb)


def open_(x: Share, op: str = "open") -> jax.Array:
    """Reconstruct the ring element (each party sends the component(s)
    the others lack: 1 round, backend-defined bytes). The element is
    returned AT THE CARRIED SCALE (x.fb) — decode with
    `ring.decode_at(v, x.fb)`; once public, the scale resolves exactly
    for free. The record's payload is the backend's actual message set
    (`open_msgs`), so `--wire` runs serialize the real components."""
    comm.record(op, rounds=1, nbytes=x.backend.open_bytes(x.ring, _numel(x)),
                numel=_numel(x), tag="bw", payload=x.backend.open_msgs(x.sh))
    return reconstruct(x)


def reveal(x: Share) -> jax.Array:
    """Open and decode to float at the carried scale (exact — deferred
    truncation costs a revealed value nothing)."""
    return x.ring.decode_at(open_(x), x.fb)


def zeros_like(x: Share) -> Share:
    return x.with_sh(jnp.zeros_like(x.sh))


def from_public(v: jax.Array, ring: RingSpec = RING64,
                proto: str = "2pc", fb: int | None = None) -> Share:
    """A public constant as a (trivial) share: component 0 holds it all."""
    from repro.mpc import protocols
    fb = ring.frac_bits if fb is None else fb
    return Share(protocols.get(proto).from_public(ring.encode_at(v, fb)),
                 ring, proto, fb)


def _numel(x: Share) -> int:
    n = 1
    for d in x.shape:
        n *= int(d)
    return n
