"""Fixed-point ring specifications for the MPC substrate.

Values x in R are encoded as round(x * 2**frac_bits) in Z_{2**bits}, stored
in two's-complement signed integers (XLA integer arithmetic is modular, so
jnp +/-/* implement ring arithmetic directly).

Two presets:
  RING64  int64, 16 fractional bits — CrypTen's ring; used as the CPU
          correctness oracle (requires jax.enable_x64 scope).
  RING32  int32, 12 fractional bits — the TPU-native ring (MXU has no
          int64 path). Products of values |x·y| < 2**6 truncate locally
          with wrap probability < 2**-2 per element, so RING32 uses
          dealer-assisted truncation (SecureML-style) which is exact up
          to ±1 LSB. See ops.trunc.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RingSpec:
    name: str
    dtype: jnp.dtype
    bits: int
    frac_bits: int

    @property
    def scale(self) -> int:
        return 1 << self.frac_bits

    @property
    def elem_bytes(self) -> int:
        return self.bits // 8

    def encode(self, x: jax.Array) -> jax.Array:
        """float -> ring element at the canonical scale."""
        return self.encode_at(x, self.frac_bits)

    def encode_at(self, x: jax.Array, fb: int) -> jax.Array:
        """float -> ring element carrying `fb` fractional bits (the
        scale-carrying shares of mpc/scale.py; fb may exceed frac_bits
        or be negative)."""
        ftype = jnp.float64 if self.bits == 64 else jnp.float32
        return jnp.round(jnp.asarray(x, ftype)
                         * ftype(2.0) ** fb).astype(self.dtype)

    def decode(self, r: jax.Array) -> jax.Array:
        """ring element -> float (canonical scale)."""
        return self.decode_at(r, self.frac_bits)

    def decode_at(self, r: jax.Array, fb: int) -> jax.Array:
        """ring element carrying `fb` fractional bits -> float."""
        ftype = jnp.float64 if self.bits == 64 else jnp.float32
        return r.astype(ftype) / ftype(2.0) ** fb

    def rand(self, key: jax.Array, shape) -> jax.Array:
        """Uniform random ring element (a fresh additive mask)."""
        if self.bits == 64:
            lo = jax.random.randint(key, shape, 0, 1 << 32, dtype=jnp.uint32)
            k2 = jax.random.fold_in(key, 1)
            hi = jax.random.randint(k2, shape, 0, 1 << 32, dtype=jnp.uint32)
            full = hi.astype(jnp.uint64) << 32 | lo.astype(jnp.uint64)
            return full.astype(self.dtype)
        bits = jax.random.bits(key, shape, dtype=jnp.uint32)
        return bits.astype(self.dtype)


RING64 = RingSpec("ring64", jnp.int64, 64, 16)
RING32 = RingSpec("ring32", jnp.int32, 32, 12)


def x64_scope():
    """Context manager enabling 64-bit jnp types — RING64 arithmetic (and
    any op on its int64 shares, e.g. comparisons in QuickSelect) must run
    inside this scope or XLA demotes results to 32 bits. Wraps
    jax.experimental.enable_x64 (the jax.enable_x64 alias was removed)."""
    from jax.experimental import enable_x64
    return enable_x64()
