"""MPC substrate with pluggable secret-sharing protocol backends.

Layout of this package:

  ring.py        fixed-point ring specs (int64/f16 CPU oracle, int32/f12 TPU)
  sharing.py     protocol-generic Share container (stacked party axis),
                 share/open routed through the backend
  protocols/     the backends: additive2pc (CrypTen-style dealer Beaver)
                 and replicated3pc (2-of-3 replicated, dealer-free)
  beaver.py      back-compat re-export of the 2pc dealer
  ops.py         linear algebra over shares: add/sub/mul/matmul/trunc
  compare.py     secure comparison (ideal-functionality semantics,
                 protocol-accurate cost: 8 rounds / 432 B per scalar)
  nonlinear.py   CrypTen-style baselines: exp, reciprocal, rsqrt, softmax,
                 log, gelu/relu, layernorm — built from secure muls
  quickselect.py top-k index selection over encrypted scores
  comm.py        cost ledger (online + offline dealer channels) +
                 network profiles + delay model
  costs.py       analytic per-op cost formulas (drive fig2/fig6/fig7),
                 ring- and protocol-parameterized
  fusion.py      flight batcher: round compression of opening/resharing
                 flights

Security models: semi-honest 2PC with a trusted dealer (crypto
provider), identical to CrypTen — or honest-majority semi-honest 3PC
over replicated shares with no dealer at all. Comparison is modeled as
an ideal functionality with the real protocol's communication cost (see
DESIGN.md §8) — the selection pipeline only ever reveals comparison
*bits*, matching the paper.
"""
from repro.mpc.ring import RingSpec, RING64, RING32
from repro.mpc.sharing import AShare, Share, share, open_, reveal
from repro.mpc.comm import Ledger, NetProfile, WAN, POD_DCN, get_ledger, ledger_scope
from repro.mpc import ops, nonlinear, compare, beaver, protocols, quickselect, costs
