"""2-party MPC substrate (CrypTen-style additive secret sharing).

Layout of this package:

  ring.py        fixed-point ring specs (int64/f16 CPU oracle, int32/f12 TPU)
  sharing.py     AShare container (stacked party axis), share/open
  beaver.py      trusted-dealer Beaver triples (elementwise + matmul)
  ops.py         linear algebra over shares: add/sub/mul/matmul/trunc
  compare.py     secure comparison (ideal-functionality semantics,
                 protocol-accurate cost: 8 rounds / 432 B per scalar)
  nonlinear.py   CrypTen-style baselines: exp, reciprocal, rsqrt, softmax,
                 log, gelu/relu, layernorm — built from Beaver muls
  quickselect.py top-k index selection over encrypted scores
  comm.py        cost ledger + network profiles + delay model
  costs.py       analytic per-op cost formulas (drive fig2/fig6/fig7)

Security model: semi-honest 2PC with a trusted dealer (crypto provider),
identical to CrypTen. Comparison is modeled as an ideal functionality with
the real protocol's communication cost (see DESIGN.md §8) — the selection
pipeline only ever reveals comparison *bits*, matching the paper.
"""
from repro.mpc.ring import RingSpec, RING64, RING32
from repro.mpc.sharing import AShare, share, open_, reveal
from repro.mpc.comm import Ledger, NetProfile, WAN, POD_DCN, get_ledger, ledger_scope
from repro.mpc import ops, nonlinear, compare, beaver, quickselect, costs
