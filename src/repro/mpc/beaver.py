"""Trusted-dealer (crypto provider) Beaver triple generation.

Same trust model as CrypTen: an offline dealer samples correlated
randomness and additively shares it to the two parties. Online cost of a
multiplication is then a single simultaneous opening of (eps, delta).

The dealer is a PRNG-keyed pure function so triples are reproducible and
jit-friendly; in deployment the dealer seed lives on the crypto-provider
host and shares are streamed ahead of the online phase (their bytes are
accounted as offline cost, reported separately by the benchmarks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec
from repro.mpc.sharing import AShare


def _share_raw(key: jax.Array, enc: jax.Array, ring: RingSpec) -> jax.Array:
    r = ring.rand(key, enc.shape)
    return jnp.stack([r, enc - r])


def mul_triple(key: jax.Array, shape, ring: RingSpec) -> tuple[AShare, AShare, AShare]:
    """Elementwise triple: a*b = c (c at 2*frac scale — consumed pre-trunc)."""
    ka, kb, k1, k2, k3 = jax.random.split(key, 5)
    a = ring.rand(ka, shape)
    b = ring.rand(kb, shape)
    c = a * b   # ring product, wraps mod 2**bits
    return (AShare(_share_raw(k1, a, ring), ring),
            AShare(_share_raw(k2, b, ring), ring),
            AShare(_share_raw(k3, c, ring), ring))


def matmul_triple(key: jax.Array, a_shape, b_shape, ring: RingSpec,
                  dimension_numbers=None) -> tuple[AShare, AShare, AShare]:
    """Matrix triple A@B = C for arbitrary batched matmul shapes."""
    ka, kb, k1, k2, k3 = jax.random.split(key, 5)
    a = ring.rand(ka, a_shape)
    b = ring.rand(kb, b_shape)
    c = jnp.matmul(a, b, preferred_element_type=ring.dtype)
    return (AShare(_share_raw(k1, a, ring), ring),
            AShare(_share_raw(k2, b, ring), ring),
            AShare(_share_raw(k3, c, ring), ring))


def trunc_pair(key: jax.Array, shape, ring: RingSpec) -> tuple[AShare, AShare]:
    """Dealer-assisted truncation pair (r, r >> f) — SecureML-style.

    Exact (±1 LSB) truncation for the int32 TPU ring where local
    truncation's wrap probability is too high.
    """
    kr, k1, k2 = jax.random.split(key, 3)
    # r drawn from the "safe" range [0, 2**(bits-2)) to avoid sign wrap
    r = (ring.rand(kr, shape).astype(jnp.uint32 if ring.bits == 32 else jnp.uint64)
         >> 2).astype(ring.dtype)
    r_t = r >> ring.frac_bits    # arithmetic shift of non-negative r
    return (AShare(_share_raw(k1, r, ring), ring),
            AShare(_share_raw(k2, r_t, ring), ring))


def triple_bytes(a_shape, b_shape, c_shape, ring: RingSpec) -> int:
    """Offline bytes the dealer ships for one triple (both parties)."""
    n = 1
    for s in (a_shape, b_shape, c_shape):
        m = 1
        for d in s:
            m *= int(d)
        n += m
    return 2 * ring.elem_bytes * n
