"""Trusted-dealer correlated randomness — back-compat re-export.

The dealer moved into the additive-2PC protocol backend
(`mpc/protocols/additive2pc.py`), where it belongs: Beaver triples and
truncation pairs are an artifact of THAT trust model, not of the MPC
substrate. The replicated-3PC backend has no dealer at all. This module
keeps the historic import path (`from repro.mpc import beaver`) alive.

Dealer-shipped bytes are recorded into the ambient ledger's offline
channel (`tag="offline"`) at generation time — see `Ledger.offline_nbytes`.
"""
from repro.mpc.protocols.additive2pc import (  # noqa: F401
    matmul_triple, mul_triple, triple_bytes, trunc_pair)
