"""Linear algebra over secret shares — protocol-generic, scale-carrying.

Everything here works on any `sharing.Share` regardless of backend:
local linear ops transform the stacked components directly (party-axis
size is whatever the protocol dictates), while every scheme-dependent
op (multiplication, matmul, truncation) dispatches to the share's
`ProtocolBackend` (mpc/protocols/).

Fixed-point scale is a tracked property of the value (`Share.fb`, the
mpc/scale.py lattice), not an op-boundary invariant:

  add/sub/concat/stack ......... align exponents by exact local lifts
  mul_public by ±2**k .......... pure exponent fold — zero arithmetic
  mul_public general ........... encode at f, emit at fb+f, NO trunc
  mul / matmul ................. emit at the summed exponent (<= 2f),
                                 forcing inputs down only when the 2f
                                 headroom cap demands it
  force (this module) .......... THE truncation point: one
                                 backend.trunc(shift=excess) per value,
                                 memoized on the Share and pushed
                                 through layout lineage

so a product's truncation is paid once, where a scale-sensitive
consumer (comparison, nonlinear entry point, another multiply) actually
needs it — not once per op. The PR 3 `fusion.PendingShare` /`lazy=`
regime is retired: scale carrying subsumes it across op boundaries.

Cost accounting notes (all recorded into the ambient Ledger):
  add/sub/neg/sum/lifts/pow2 folds ......... local, 0 rounds
  mul / matmul, 2pc (Beaver) ............... 1 round: open(eps)+open(delta)
                                             + offline dealer bytes
  mul / matmul, 3pc (replicated) ........... 1 round: resharing flight,
                                             no dealer, no offline bytes
  force, 2pc RING64 ........................ 0 rounds (local shift)
  force, 2pc RING32 ........................ 1 round + offline trunc pair
  force, 3pc both rings .................... local shift + re-replication
                                             bytes on the next resharing
                                             flight (0 rounds)

Under an ambient `fusion.flight_scope` every 1-round opening/resharing
is deferred into the current fused flight instead of paying its own
round (mpc/fusion.py); the arithmetic below never changes.

All integer arithmetic relies on XLA's modular two's-complement
semantics, which *is* ring arithmetic mod 2**bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc.sharing import Share
from repro.mpc import scale


# ---------------------------------------------------------------------------
# scale plumbing: lifts, forced truncation, alignment
# ---------------------------------------------------------------------------

def lift(x: Share, k: int) -> Share:
    """Raise the carried exponent by k: int * 2**k — exact, local, free.
    Spends headroom instead of precision (the scale.align_target cap
    guarantees the result stays within the 2f contract)."""
    if k == 0:
        return x
    return x.with_scale(x.sh * jnp.asarray(1 << k, x.ring.dtype), x.fb + k)


def force(x: Share, key: jax.Array | None = None, *,
          to: int | None = None) -> Share:
    """Resolve a scale-carrying share to exponent `to` (canonical f by
    default) — THE deferred-truncation consumer.

    Sub-target exponents lift (free); excess truncates ONCE via the
    backend's `trunc(shift=)`. The result is memoized on the Share (a
    value consumed by several scale-sensitive ops pays one truncation,
    not one per consumer) and pushed through layout lineage
    (`Share.derive`): forcing a broadcast/reshaped view truncates the
    pre-layout tensor at its element count and replays the free layout.
    """
    t = x.ring.frac_bits if to is None else to
    if x.fb == t:
        return x
    if x.fb < t:
        return lift(x, t - x.fb)
    cache = getattr(x, "_forced", None)
    if cache is None:
        cache = x._forced = {}
    if t in cache:
        return cache[t]
    lineage = getattr(x, "_lineage", None)
    if lineage is not None:
        base, fn = lineage
        fbase = force(base, key, to=t)
        out = fbase.with_sh(fn(fbase.sh))
    else:
        out = x.backend.trunc(x, key, shift=x.fb - t)
    cache[t] = out
    return out


def _headroom_bits(x: Share) -> int | None:
    """Ring bit width handed to the scale lattice's headroom cap — only
    when the backend's truncation is EXACT at any shift
    (`backend.exact_trunc`). Probabilistic local truncation
    (additive2pc's RING64 shift, replicated3pc's regrouping) wraps a
    share w.p. ~ encoded/2**bits per element; at a 3f exponent that is
    2**f times the validated 2f regime, so those backends keep the 2f
    cap (`scale.cap(f, None)`)."""
    return x.ring.bits if x.backend.exact_trunc else None


def _aligned(xs: list[Share], key: jax.Array | None = None) -> list[Share]:
    """Bring operands to a common exponent for add/sub/concat: lift the
    lower ones (exact, free); trunc down only in the above-cap case
    scale.align_target clamps (a pow2-folded mean meeting a 2f residual).

    That down-force is a REAL truncation and takes the caller's key —
    keyless it degrades to the local-shift path, whose share-wrap
    probability is unacceptable for fb > 2f on the 32-bit ring (the
    MPCEngine threads a key through every add/sub for exactly this
    case; key-free library callers only ever align same-exponent or
    lift-direction operands)."""
    f = xs[0].ring.frac_bits
    t = xs[0].fb
    for x in xs[1:]:
        t = scale.align_target(t, x.fb, f, _headroom_bits(xs[0]))
    out = []
    for i, x in enumerate(xs):
        if x.fb != t:
            kx = None if key is None else jax.random.fold_in(key, 50 + i)
            x = force(x, kx, to=t)
        out.append(x)
    return out


# ---------------------------------------------------------------------------
# local (round-free) ops — party-axis generic
# ---------------------------------------------------------------------------

def add(x: Share, y: Share, *, key: jax.Array | None = None) -> Share:
    x, y = _aligned([x, y], key)
    return x.with_sh(x.sh + y.sh)


def sub(x: Share, y: Share, *, key: jax.Array | None = None) -> Share:
    x, y = _aligned([x, y], key)
    return x.with_sh(x.sh - y.sh)


def neg(x: Share) -> Share:
    return x.with_sh(-x.sh)


def add_public(x: Share, v) -> Share:
    """Add a public constant, encoded at the carried exponent. Affine,
    not linear in the components — dispatches to the backend: component
    0 absorbs it (the `from_public` convention), and MAC'd schemes also
    update their MAC rows by alpha_i * c to keep the authenticated
    invariant."""
    enc = x.ring.encode_at(jnp.asarray(v), x.fb)
    return x.with_sh(x.backend.add_public_encoded(x.sh, enc))


def mul_public(x: Share, v, *, key: jax.Array | None = None) -> Share:
    """Multiply by a public float tensor.

    Scalar powers of two fold into the carried exponent — zero
    arithmetic, zero rounding, zero wire (the attention `dh**-0.5`
    rescale and pow2 means cost literally nothing). General constants
    encode at f and emit at fb+f; no truncation here — the downstream
    scale-sensitive consumer forces once at the accumulated excess.
    """
    k = scale.pow2_exponent(v)
    if k is not None:
        sh = -x.sh if float(v) < 0 else x.sh
        return x.with_scale(sh, x.fb - k)
    _, shift, out_fb = scale.mul_public_plan(x.fb, v, x.ring.frac_bits,
                                             _headroom_bits(x))
    if shift:
        x = force(x, key, to=x.fb - shift)
    enc = x.ring.encode(jnp.asarray(v))
    return x.with_scale(x.sh * enc, out_fb)


def mul_public_int(x: Share, v: int) -> Share:
    """Multiply by a public *integer* — exact, scale-preserving."""
    return x.with_sh(x.sh * jnp.asarray(v, x.ring.dtype))


def matmul_public(x: Share, w, *, key: jax.Array | None = None,
                  w_encoded: jax.Array | None = None) -> Share:
    """x @ w with public (already known to all parties) w; emits at
    fb+f like `mul_public` — consumers force."""
    px, _, _ = scale.mul_plan(x.fb, x.ring.frac_bits, x.ring.frac_bits,
                              _headroom_bits(x))
    if px:
        x = force(x, key, to=x.fb - px)
    enc = w_encoded if w_encoded is not None else x.ring.encode(jnp.asarray(w))
    z = jnp.matmul(x.sh, enc, preferred_element_type=x.ring.dtype)
    return x.with_scale(z, x.fb + x.ring.frac_bits)


def sum_(x: Share, axis=None, keepdims=False) -> Share:
    ax = axis
    if ax is not None:
        ax = tuple(a + 1 if a >= 0 else a for a in
                   ((axis,) if isinstance(axis, int) else tuple(axis)))
    else:
        ax = tuple(range(1, x.sh.ndim))
    return x.with_sh(jnp.sum(x.sh, axis=ax, keepdims=keepdims))


def mean(x: Share, axis: int, *, key: jax.Array | None = None) -> Share:
    """Sum then multiply by 1/n — the 1/n lands on the (smaller) summed
    tensor, and for power-of-two n it is a free exponent fold."""
    n = x.shape[axis]
    s = sum_(x, axis=axis)
    return mul_public(s, 1.0 / n, key=key)


def stack(xs: list[Share], axis: int = 0, *,
          key: jax.Array | None = None) -> Share:
    xs = _aligned(xs, key)
    return xs[0].with_sh(jnp.stack([x.sh for x in xs], axis=axis + 1))


def concat(xs: list[Share], axis: int = 0, *,
           key: jax.Array | None = None) -> Share:
    xs = _aligned(xs, key)
    ax = axis + 1 if axis >= 0 else axis
    return xs[0].with_sh(jnp.concatenate([x.sh for x in xs], axis=ax))


# ---------------------------------------------------------------------------
# scheme-dependent ops: dispatch to the share's protocol backend
# ---------------------------------------------------------------------------

def trunc(x: Share, *, key: jax.Array | None = None,
          shift: int | None = None) -> Share:
    """Divide by 2**shift (default: one canonical scale, frac_bits).

    2pc RING64: local arithmetic shifts (CrypTen's choice).
    2pc RING32: dealer-assisted pair — exact, one opening round.
    3pc:        probabilistic local shift, both rings — no dealer; the
                re-replication message is priced on the resharing flight.
    """
    return x.backend.trunc(x, key, shift=shift)


def _forced_operands(x: Share, y: Share, key: jax.Array):
    """Apply scale.mul_plan: trunc inputs only as far as the ring's
    headroom cap requires (2f; 3f on RING64 exact-trunc backends). A
    squared operand
    (x is y) forces once and reuses."""
    px, py, out_fb = scale.mul_plan(x.fb, y.fb, x.ring.frac_bits,
                                    _headroom_bits(x))
    if x is y:
        if px:
            x = y = force(x, jax.random.fold_in(key, 3), to=x.fb - px)
        return x, y, out_fb
    if px:
        x = force(x, jax.random.fold_in(key, 3), to=x.fb - px)
    if py:
        y = force(y, jax.random.fold_in(key, 4), to=y.fb - py)
    return x, y, out_fb


def mul(x: Share, y: Share, key: jax.Array) -> Share:
    """Elementwise secure multiply — one wire flight (Beaver opening for
    2pc, resharing for 3pc), emitted at the summed exponent x.fb + y.fb
    (post headroom plan). No inline truncation: the consumer forces."""
    x, y, out_fb = _forced_operands(x, y, key)
    z = x.backend.mul(x, y, key)
    return z.with_scale(z.sh, out_fb)


def square(x: Share, key: jax.Array) -> Share:
    return mul(x, x, key)


def matmul(x: Share, y: Share, key: jax.Array, *,
           combine_impl: str | None = None) -> Share:
    """Secure batched matmul — one wire flight, emitted at the summed
    exponent. 2pc bytes scale with the INPUTS (Beaver triple reuse),
    3pc bytes with the OUTPUT (resharing); `combine_impl` routes the
    2pc RING32 post-open combine through the Pallas secure_matmul
    kernel and is ignored by 3pc."""
    x, y, out_fb = _forced_operands(x, y, key)
    z = x.backend.matmul(x, y, key, combine_impl=combine_impl)
    return z.with_scale(z.sh, out_fb)
