"""Linear algebra over additive shares.

Cost accounting notes (all recorded into the ambient Ledger):
  add/sub/neg/sum/mean-by-constant ......... local, 0 rounds
  mul_public/matmul_public ................. local + trunc
  mul (Beaver) ............................. 1 round: open(eps)+open(delta)
  matmul (Beaver matrix triple) ............ 1 round
  trunc local .............................. 0 rounds (RING64 path)
  trunc dealer-assisted .................... 1 round (RING32/TPU path)

Under an ambient `fusion.flight_scope` every one of these openings is
deferred into the current fused flight instead of paying its own round
(mpc/fusion.py); the arithmetic below never changes. `mul`/`matmul`/
`mul_public` additionally take `lazy=True` to return the untruncated
product as a `fusion.PendingShare` tagged with its truncation key —
`force()` applies the identical truncation later, letting a caller hold
the pending-trunc state across a fused group.

All integer arithmetic relies on XLA's modular two's-complement semantics,
which *is* ring arithmetic mod 2**bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec
from repro.mpc.sharing import AShare
from repro.mpc import beaver, comm, fusion


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def _open_flight(op: str, tensors, ring: RingSpec, *, numel: int,
                 flops: int = 0, tag: str = "bw"):
    """Open masked share tensors in ONE simultaneous message flight.

    All tensors of a flight ride the same round trip (each party sends
    its shares of every tensor at once), so the flight costs 1 round and
    2 * elem_bytes * total-elements on the wire. This is the unit the
    wave executor schedules: under comm.wave_scope the flight's bytes
    scale with the wave while latency-bound flights keep their rounds.
    """
    wire_elems = sum(_numel(t.shape[1:]) for t in tensors)
    comm.record(op, rounds=1, nbytes=2 * ring.elem_bytes * wire_elems,
                numel=numel, flops=flops, tag=tag)
    return tuple(t[0] + t[1] for t in tensors)


# ---------------------------------------------------------------------------
# local (round-free) ops
# ---------------------------------------------------------------------------

def add(x: AShare, y: AShare) -> AShare:
    return AShare(x.sh + y.sh, x.ring)


def sub(x: AShare, y: AShare) -> AShare:
    return AShare(x.sh - y.sh, x.ring)


def neg(x: AShare) -> AShare:
    return AShare(-x.sh, x.ring)


def add_public(x: AShare, v) -> AShare:
    enc = x.ring.encode(jnp.asarray(v))
    pub = jnp.stack([jnp.broadcast_to(enc, x.shape),
                     jnp.zeros(x.shape, x.ring.dtype)])
    return AShare(x.sh + pub, x.ring)


def mul_public(x: AShare, v, *, key: jax.Array | None = None,
               lazy: bool = False):
    """Multiply by a public float tensor; needs one truncation."""
    enc = x.ring.encode(jnp.asarray(v))
    z = AShare(x.sh * enc, x.ring)
    if lazy:
        return fusion.PendingShare(z, key)
    return trunc(z, key=key)


def mul_public_int(x: AShare, v: int) -> AShare:
    """Multiply by a public *integer* — exact, no truncation."""
    return AShare(x.sh * jnp.asarray(v, x.ring.dtype), x.ring)


def matmul_public(x: AShare, w, *, key: jax.Array | None = None,
                  w_encoded: jax.Array | None = None) -> AShare:
    """x @ w with public (already known to both parties) w."""
    enc = w_encoded if w_encoded is not None else x.ring.encode(jnp.asarray(w))
    z = jnp.matmul(x.sh, enc, preferred_element_type=x.ring.dtype)
    return trunc(AShare(z, x.ring), key=key)


def sum_(x: AShare, axis=None, keepdims=False) -> AShare:
    ax = axis
    if ax is not None:
        ax = tuple(a + 1 if a >= 0 else a for a in
                   ((axis,) if isinstance(axis, int) else tuple(axis)))
    else:
        ax = tuple(range(1, x.sh.ndim))
    return AShare(jnp.sum(x.sh, axis=ax, keepdims=keepdims), x.ring)


def mean(x: AShare, axis: int, *, key: jax.Array | None = None) -> AShare:
    n = x.shape[axis]
    s = sum_(x, axis=axis)
    return mul_public(s, 1.0 / n, key=key)


def stack(xs: list[AShare], axis: int = 0) -> AShare:
    return AShare(jnp.stack([x.sh for x in xs], axis=axis + 1), xs[0].ring)


def concat(xs: list[AShare], axis: int = 0) -> AShare:
    ax = axis + 1 if axis >= 0 else axis
    return AShare(jnp.concatenate([x.sh for x in xs], axis=ax), xs[0].ring)


# ---------------------------------------------------------------------------
# truncation
# ---------------------------------------------------------------------------

def trunc(x: AShare, *, key: jax.Array | None = None) -> AShare:
    """Divide by 2**frac_bits after a fixed-point product.

    RING64: local arithmetic shift of both shares — correct up to ±1 LSB
    w.p. 1 - |v|/2**(bits-1) per element (CrypTen's choice).
    RING32: dealer-assisted pair (exact): open (x+r), shift publicly,
    subtract the dealer's share of r>>f. Costs one opening round.
    """
    ring = x.ring
    if ring.bits >= 64 or key is None:
        s0 = x.sh[0] >> ring.frac_bits
        s1 = -((-x.sh[1]) >> ring.frac_bits)
        return AShare(jnp.stack([s0, s1]), ring)
    # dealer-assisted exact truncation (TPU ring)
    r, r_t = beaver.trunc_pair(key, x.shape, ring)
    masked = AShare(x.sh + r.sh, ring)
    m = masked.sh[0] + masked.sh[1]          # open
    comm.record("trunc_open", rounds=1, nbytes=2 * ring.elem_bytes * _numel(x.shape),
                numel=_numel(x.shape), tag="bw")
    m_t = m >> ring.frac_bits
    pub = jnp.stack([m_t, jnp.zeros_like(m_t)])
    return AShare(pub - r_t.sh, ring)


# ---------------------------------------------------------------------------
# Beaver multiplication / matmul
# ---------------------------------------------------------------------------

def mul(x: AShare, y: AShare, key: jax.Array, *, do_trunc: bool = True,
        lazy: bool = False):
    """Elementwise secure multiply. One opening round for (eps, delta)."""
    ring = x.ring
    shape = jnp.broadcast_shapes(x.shape, y.shape)
    xb = AShare(jnp.broadcast_to(x.sh, (2,) + shape), ring)
    yb = AShare(jnp.broadcast_to(y.sh, (2,) + shape), ring)
    a, b, c = beaver.mul_triple(key, shape, ring)
    eps = xb.sh - a.sh
    dlt = yb.sh - b.sh
    n = _numel(shape)
    eps_o, dlt_o = _open_flight("beaver_mul", (eps, dlt), ring,
                                numel=n, flops=4 * n)
    z = c.sh + eps_o * b.sh + dlt_o * a.sh
    z = z.at[0].add(eps_o * dlt_o)
    out = AShare(z, ring)
    if not do_trunc:
        return out
    tkey = jax.random.fold_in(key, 7)
    if lazy:
        return fusion.PendingShare(out, tkey)
    return trunc(out, key=tkey)


def square(x: AShare, key: jax.Array) -> AShare:
    return mul(x, x, key)


def matmul(x: AShare, y: AShare, key: jax.Array, *, do_trunc: bool = True,
           lazy: bool = False, combine_impl: str | None = None):
    """Secure batched matmul via a Beaver matrix triple. One opening round.

    Bytes on the wire: |eps| + |delta| per party = (numel(x)+numel(y)) elems
    — crucially *not* numel(x)*cols bytes: the triple reuse is what makes
    matmul bandwidth-, not latency-, dominated.

    `combine_impl` routes the post-open combine of 2-D RING32 matmuls
    through the fused Pallas kernel (`kernels/ops.secure_matmul`): both
    parties' `z_p = c_p + eps@b_p + a_p@dlt (+ p0: eps@dlt)` in one tiled
    launch. Exact wrapping int32 arithmetic — bitwise-identical to the
    inline combine ("auto" compiles on TPU, falls back to the jnp
    reference elsewhere).
    """
    ring = x.ring
    a, b, c = beaver.matmul_triple(key, x.shape, y.shape, ring)
    eps = x.sh - a.sh
    dlt = y.sh - b.sh
    n = _numel(x.shape) + _numel(y.shape)
    m, k = x.shape[-2], x.shape[-1]
    n_out = y.shape[-1]
    batch = _numel(x.shape[:-2])
    eps_o, dlt_o = _open_flight("beaver_matmul", (eps, dlt), ring, numel=n,
                                flops=2 * batch * m * k * n_out)
    # party-local: z_p = c_p + eps@b_p + a_p@dlt ; party0 adds eps@dlt
    if combine_impl is not None and ring.bits == 32 \
            and x.sh.ndim == 3 and y.sh.ndim == 3:
        from repro.kernels import ops as kops
        z = kops.secure_matmul(eps_o, dlt_o, a.sh, b.sh, c.sh,
                               impl=combine_impl)
        out = AShare(z, ring)
    else:
        eb = jnp.matmul(jnp.stack([eps_o, eps_o]), b.sh,
                        preferred_element_type=ring.dtype)
        ad = jnp.matmul(a.sh, jnp.stack([dlt_o, dlt_o]),
                        preferred_element_type=ring.dtype)
        z = c.sh + eb + ad
        ed = jnp.matmul(eps_o, dlt_o, preferred_element_type=ring.dtype)
        z = z.at[0].add(ed)
        out = AShare(z, ring)
    if not do_trunc:
        return out
    tkey = jax.random.fold_in(key, 11)
    if lazy:
        return fusion.PendingShare(out, tkey)
    return trunc(out, key=tkey)


def dot_last(x: AShare, y: AShare, key: jax.Array) -> AShare:
    """Inner product along the last axis (entropy dot products etc.)."""
    z = mul(x, y, key, do_trunc=False)
    s = sum_(z, axis=-1)
    return trunc(s, key=jax.random.fold_in(key, 13))
