"""Linear algebra over secret shares — protocol-generic.

Everything here works on any `sharing.Share` regardless of backend:
local linear ops transform the stacked components directly (party-axis
size is whatever the protocol dictates), while every scheme-dependent
op (multiplication, matmul, truncation) dispatches to the share's
`ProtocolBackend` (mpc/protocols/).

Cost accounting notes (all recorded into the ambient Ledger):
  add/sub/neg/sum/mean-by-constant ......... local, 0 rounds
  mul_public/matmul_public ................. local + trunc
  mul / matmul, 2pc (Beaver) ............... 1 round: open(eps)+open(delta)
                                             + offline dealer bytes
  mul / matmul, 3pc (replicated) ........... 1 round: resharing flight,
                                             no dealer, no offline bytes
  trunc, 2pc RING64 / 3pc both rings ....... 0 rounds (local)
  trunc, 2pc RING32 (dealer-assisted) ...... 1 round + offline pair

Under an ambient `fusion.flight_scope` every 1-round opening/resharing
is deferred into the current fused flight instead of paying its own
round (mpc/fusion.py); the arithmetic below never changes. `mul`/
`matmul`/`mul_public` additionally take `lazy=True` to return the
untruncated product as a `fusion.PendingShare` tagged with its
truncation key — `force()` applies the identical truncation later,
letting a caller hold the pending-trunc state across a fused group.

All integer arithmetic relies on XLA's modular two's-complement
semantics, which *is* ring arithmetic mod 2**bits.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc.sharing import Share
from repro.mpc import fusion


# ---------------------------------------------------------------------------
# local (round-free) ops — party-axis generic
# ---------------------------------------------------------------------------

def add(x: Share, y: Share) -> Share:
    return x.with_sh(x.sh + y.sh)


def sub(x: Share, y: Share) -> Share:
    return x.with_sh(x.sh - y.sh)


def neg(x: Share) -> Share:
    return x.with_sh(-x.sh)


def add_public(x: Share, v) -> Share:
    """Add a public constant: component 0 absorbs it (every backend's
    `from_public` convention)."""
    enc = x.ring.encode(jnp.asarray(v))
    return x.with_sh(x.sh.at[0].add(jnp.broadcast_to(enc, x.shape)))


def mul_public(x: Share, v, *, key: jax.Array | None = None,
               lazy: bool = False):
    """Multiply by a public float tensor; needs one truncation."""
    enc = x.ring.encode(jnp.asarray(v))
    z = x.with_sh(x.sh * enc)
    if lazy:
        return fusion.PendingShare(z, key)
    return trunc(z, key=key)


def mul_public_int(x: Share, v: int) -> Share:
    """Multiply by a public *integer* — exact, no truncation."""
    return x.with_sh(x.sh * jnp.asarray(v, x.ring.dtype))


def matmul_public(x: Share, w, *, key: jax.Array | None = None,
                  w_encoded: jax.Array | None = None) -> Share:
    """x @ w with public (already known to all parties) w."""
    enc = w_encoded if w_encoded is not None else x.ring.encode(jnp.asarray(w))
    z = jnp.matmul(x.sh, enc, preferred_element_type=x.ring.dtype)
    return trunc(x.with_sh(z), key=key)


def sum_(x: Share, axis=None, keepdims=False) -> Share:
    ax = axis
    if ax is not None:
        ax = tuple(a + 1 if a >= 0 else a for a in
                   ((axis,) if isinstance(axis, int) else tuple(axis)))
    else:
        ax = tuple(range(1, x.sh.ndim))
    return x.with_sh(jnp.sum(x.sh, axis=ax, keepdims=keepdims))


def mean(x: Share, axis: int, *, key: jax.Array | None = None) -> Share:
    n = x.shape[axis]
    s = sum_(x, axis=axis)
    return mul_public(s, 1.0 / n, key=key)


def stack(xs: list[Share], axis: int = 0) -> Share:
    return xs[0].with_sh(jnp.stack([x.sh for x in xs], axis=axis + 1))


def concat(xs: list[Share], axis: int = 0) -> Share:
    ax = axis + 1 if axis >= 0 else axis
    return xs[0].with_sh(jnp.concatenate([x.sh for x in xs], axis=ax))


# ---------------------------------------------------------------------------
# scheme-dependent ops: dispatch to the share's protocol backend
# ---------------------------------------------------------------------------

def trunc(x: Share, *, key: jax.Array | None = None) -> Share:
    """Divide by 2**frac_bits after a fixed-point product.

    2pc RING64: local arithmetic shifts (CrypTen's choice).
    2pc RING32: dealer-assisted pair — exact, one opening round.
    3pc:        probabilistic local truncation, both rings — no dealer.
    """
    return x.backend.trunc(x, key)


def mul(x: Share, y: Share, key: jax.Array, *, do_trunc: bool = True,
        lazy: bool = False):
    """Elementwise secure multiply. One wire flight (Beaver opening for
    2pc, resharing for 3pc)."""
    return x.backend.mul(x, y, key, do_trunc=do_trunc, lazy=lazy)


def square(x: Share, key: jax.Array) -> Share:
    return mul(x, x, key)


def matmul(x: Share, y: Share, key: jax.Array, *, do_trunc: bool = True,
           lazy: bool = False, combine_impl: str | None = None):
    """Secure batched matmul — one wire flight. 2pc bytes scale with the
    INPUTS (Beaver triple reuse), 3pc bytes with the OUTPUT (resharing);
    `combine_impl` routes the 2pc RING32 post-open combine through the
    Pallas secure_matmul kernel and is ignored by 3pc."""
    return x.backend.matmul(x, y, key, do_trunc=do_trunc, lazy=lazy,
                            combine_impl=combine_impl)


def dot_last(x: Share, y: Share, key: jax.Array) -> Share:
    """Inner product along the last axis (entropy dot products etc.)."""
    z = mul(x, y, key, do_trunc=False)
    s = sum_(z, axis=-1)
    return trunc(s, key=jax.random.fold_in(key, 13))
