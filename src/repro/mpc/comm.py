"""Communication cost accounting + network delay model.

Every MPC op that talks to the wire records a CostRecord into the ambient
Ledger (a context-scoped accumulator). Records are *structural* — rounds
and bytes are functions of static shapes — so accounting is exact whether
ops run eagerly or under trace.

Delay model (matches the paper's experiment setup, §5.1):
  serial_time   = rounds * rtt_latency + bytes_on_wire / bandwidth + compute
  overlapped    = the IO scheduler (core/iosched.py) computes a makespan
                  where comm of batch i overlaps compute of batch i+1 and
                  latency-bound ops are coalesced across batches.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterator


@dataclasses.dataclass(frozen=True)
class NetProfile:
    name: str
    bandwidth_Bps: float     # per-direction point-to-point
    latency_s: float         # one round-trip

    def time(self, rounds: float, nbytes: float, compute_s: float = 0.0) -> float:
        return rounds * self.latency_s + nbytes / self.bandwidth_Bps + compute_s


# Paper's WAN emulation: 100 MB/s, 100 ms (Section 5.1).
WAN = NetProfile("wan", 100e6, 100e-3)
# TPU v5e inter-pod data-center network (deployment projection).
POD_DCN = NetProfile("pod_dcn", 25e9, 50e-6)
# Intra-pod ICI (per-link), used by roofline collective term.
ICI = NetProfile("ici", 50e9, 1e-6)

# name -> profile, for CLI flags (--net) and ExecConfig.net: one registry
# so the delay model and the socket pacer are always parameterized by
# the same profile object.
PROFILES = {"wan": WAN, "pod_dcn": POD_DCN, "ici": ICI}


@dataclasses.dataclass
class CostRecord:
    op: str
    rounds: int
    nbytes: int          # total bytes on the wire (both directions)
    numel: int = 0
    flops: int = 0       # local per-party compute, for the overlap model
    tag: str = ""        # scheduler class: "bw" (bandwidth-bound) | "lat"
                         # | "offline" (dealer bytes, streamed pre-phase)
    wave: int = 1        # batches serviced by this flight (executor waves)


class Ledger:
    """Accumulates CostRecords; queried by benchmarks and the scheduler."""

    def __init__(self) -> None:
        self.records: list[CostRecord] = []

    def add(self, rec: CostRecord) -> None:
        self.records.append(rec)

    # ---- aggregates -------------------------------------------------
    @property
    def rounds(self) -> int:
        return sum(r.rounds for r in self.records)

    @property
    def nbytes(self) -> int:
        """Online bytes-on-wire. Offline (dealer) bytes are a separate
        channel: streamed ahead of the phase, priced by
        `offline_nbytes`, never by the delay model."""
        return sum(r.nbytes for r in self.records if r.tag != "offline")

    @property
    def offline_nbytes(self) -> int:
        """Dealer-shipped correlated-randomness bytes (Beaver triples,
        truncation pairs). Zero for dealer-free backends (3pc)."""
        return sum(r.nbytes for r in self.records if r.tag == "offline")

    @property
    def flops(self) -> int:
        return sum(r.flops for r in self.records)

    # ---- scheduler views (tagged flight classes, paper §4.4) --------
    def rounds_tagged(self, tag: str) -> int:
        return sum(r.rounds for r in self.records if r.tag == tag)

    @property
    def lat_rounds(self) -> int:
        return self.rounds_tagged("lat")

    @property
    def bw_rounds(self) -> int:
        return self.rounds_tagged("bw")

    def serial_time(self, net: NetProfile, flops_per_s: float = 10e12) -> float:
        return net.time(self.rounds, self.nbytes, self.flops / flops_per_s)

    def offline_by_op(self) -> dict[str, tuple[int, int]]:
        """op -> (numel, nbytes) totals over the offline (dealer)
        records — the per-op demand one phase batch puts on the dealer
        channel. The serve/ dealer pool multiplies these by wave lanes
        to size its pre-generation orders from a TraceEngine probe."""
        out: dict[str, tuple[int, int]] = {}
        for r in self.records:
            if r.tag == "offline":
                n, b = out.get(r.op, (0, 0))
                out[r.op] = (n + r.numel, b + r.nbytes)
        return out

    def by_op(self) -> dict[str, CostRecord]:
        out: dict[str, CostRecord] = {}
        for r in self.records:
            if r.op not in out:
                out[r.op] = CostRecord(r.op, 0, 0, 0, 0, r.tag)
            agg = out[r.op]
            agg.rounds += r.rounds
            agg.nbytes += r.nbytes
            agg.numel += r.numel
            agg.flops += r.flops
        return out

    def scaled(self, k: float) -> "Ledger":
        """Ledger for k identical repetitions of this workload."""
        led = Ledger()
        for r in self.records:
            led.add(CostRecord(r.op, int(r.rounds * k), int(r.nbytes * k),
                               int(r.numel * k), int(r.flops * k), r.tag))
        return led


# ---------------------------------------------------------------------------
# wire capture — the record execution hook (repro/net)
# ---------------------------------------------------------------------------
#
# When a WireTape is ambient (the executor's --wire mode), every ONLINE
# record ALSO captures the flight's actual message payloads: which party
# sends how many bytes to whom, in which sub-round. The PartyRuntime
# (net/runtime.py) then executes the captured flights over a real
# Transport — one framed exchange per flight — and the transport-counted
# bytes must equal the ledger's `nbytes` record-for-record
# (net.reconcile). Flights whose protocol hands concrete share tensors
# to `record(payload=...)` ship those exact bytes; modeled
# functionalities (the §4.1 comparison, the SPDZ sacrifice open) ship
# deterministic filler of exactly the modeled size — the wire carries
# real frames either way, only the *content* is synthetic.

@dataclasses.dataclass
class WaveTiming:
    """Device-side timestamps of one executed wave (seconds relative to
    the phase's t0). `start_s` is when dispatch of the wave's forward
    began, `dispatch_s` when the (async) dispatch returned, `ready_s`
    when `block_until_ready` on the wave's result returned — under the
    double-buffered schedule that is one wave later than its dispatch,
    so ready - start includes the overlap the schedule is buying."""
    wave: int
    lanes: int
    devices_used: int
    start_s: float
    dispatch_s: float
    ready_s: float = 0.0


@dataclasses.dataclass
class DeviceReport:
    """What one executed phase did on the DEVICE mesh — the compute-side
    twin of net.WireReport. `placement` records how the wave/party axes
    were realized: "none" (single device), "host" (NamedSharding
    device_put: party -> pod, wave -> data, GSPMD collectives), or
    "shardmap" (wave lanes split across the data axis under
    jax.shard_map, party replicated per device). The combine_* counters
    are the kernels/ops.secure_matmul dispatch deltas over the phase —
    the witness that fused RING32 combines ran through the kernel
    rather than the jnp ref fallback."""
    placement: str
    n_devices: int
    mesh_axes: dict
    waves: list = dataclasses.field(default_factory=list)
    combine_kernel: int = 0
    combine_ref: int = 0
    combine_padded: int = 0

    @property
    def device_makespan_s(self) -> float:
        """Measured device-side makespan: first dispatch start to last
        wave ready, from the double-buffer loop's own timestamps."""
        if not self.waves:
            return 0.0
        return (max(w.ready_s for w in self.waves)
                - min(w.start_s for w in self.waves))

    def as_dict(self) -> dict:
        return {
            "placement": self.placement,
            "n_devices": self.n_devices,
            "mesh_axes": dict(self.mesh_axes),
            "device_makespan_s": self.device_makespan_s,
            "combine_kernel": self.combine_kernel,
            "combine_ref": self.combine_ref,
            "combine_padded": self.combine_padded,
            "waves": [dataclasses.asdict(w) for w in self.waves],
        }


@dataclasses.dataclass(frozen=True)
class WireMsg:
    """One point-to-point message of a flight: src -> dst, in sub-round
    `rnd` (multi-round flights — comparisons, ABY3 trunc2 — serialize
    their sub-rounds on the wire)."""
    src: int
    dst: int
    data: bytes
    rnd: int = 0


@dataclasses.dataclass(frozen=True)
class WireFlight:
    """One captured flight: the ledger record it mirrors plus the
    per-party messages that realize it on a transport."""
    op: str
    rounds: int
    nbytes: int
    tag: str
    msgs: tuple[WireMsg, ...]


def _data_bytes(x) -> bytes | None:
    """Serialize one payload entry; None when the value is abstract
    (a tracer under vmap/eval_shape) — the caller falls back to
    synthesized filler of the recorded size."""
    if isinstance(x, (bytes, bytearray, memoryview)):
        return bytes(x)
    try:
        import numpy as np
        return np.asarray(x).tobytes()
    except Exception:
        return None


def synth_msgs(nbytes: int, rounds: int, n_parties: int) -> tuple[WireMsg, ...]:
    """Deterministic filler messages summing to EXACTLY nbytes, spread
    over the flight's sub-rounds and the canonical directed-link pattern
    (duplex pair for 2 parties, the ring for 3+). Used for modeled
    functionalities that record wire cost without materializing message
    tensors."""
    if n_parties >= 3:
        links = [(i, (i + 1) % n_parties) for i in range(n_parties)]
    else:
        links = [(0, 1), (1, 0)]
    rounds = max(1, rounds)
    msgs: list[WireMsg] = []
    left = nbytes
    cells = rounds * len(links)
    per = nbytes // cells
    for r in range(rounds):
        for li, (s, d) in enumerate(links):
            size = per
            if r == rounds - 1 and li == len(links) - 1:
                size = left                    # remainder on the last cell
            msgs.append(WireMsg(s, d, b"\x00" * size, r))
            left -= size
    return tuple(msgs)


def normalize_payload(payload, nbytes: int, rounds: int,
                      n_parties: int) -> tuple[WireMsg, ...]:
    """Payload entries ((src, dst, data[, rnd]) tuples or WireMsg) ->
    serialized WireMsg tuple whose sizes MUST sum to the recorded nbytes
    — the capture-time half of the byte reconciliation contract. Falls
    back to `synth_msgs` when any entry is abstract."""
    if payload is None:
        return synth_msgs(nbytes, rounds, n_parties)
    msgs: list[WireMsg] = []
    for e in payload:
        if isinstance(e, WireMsg):
            msgs.append(e)
            continue
        src, dst, data = e[0], e[1], e[2]
        rnd = e[3] if len(e) > 3 else 0
        raw = _data_bytes(data)
        if raw is None:                        # abstract value: synthesize
            return synth_msgs(nbytes, rounds, n_parties)
        msgs.append(WireMsg(int(src), int(dst), raw, int(rnd)))
    total = sum(len(m.data) for m in msgs)
    if total != nbytes:
        raise ValueError(
            f"wire payload carries {total} bytes but the ledger record "
            f"prices {nbytes}: the protocol's payload and its cost model "
            f"have diverged")
    return tuple(msgs)


class WireTape:
    """Ordered capture of every online flight of an execution — the
    flight plan `net.PartyRuntime` replays over a real transport.
    `n_parties` is the WIRE party count (backend.n_wire_parties — spdz2pc
    stacks 4 share rows but runs 2 parties)."""

    def __init__(self, n_parties: int):
        self.n_parties = n_parties
        self.flights: list[WireFlight] = []

    def add(self, op: str, rounds: int, nbytes: int, tag: str,
            payload=None) -> None:
        msgs = normalize_payload(payload, nbytes, rounds, self.n_parties)
        self.flights.append(WireFlight(op, rounds, nbytes, tag, msgs))

    @property
    def nbytes(self) -> int:
        return sum(f.nbytes for f in self.flights)

    def link_frames(self) -> dict:
        """DATA frames per directed link, in party-loop send order —
        the population `net.faults.FaultPlan` places faults over (a
        deterministic function of the tape alone)."""
        counts: dict = {}
        for f in self.flights:
            for r in sorted({m.rnd for m in f.msgs} or {0}):
                for m in f.msgs:
                    if m.rnd == r:
                        counts[(m.src, m.dst)] = \
                            counts.get((m.src, m.dst), 0) + 1
        return counts

    def link_nbytes(self) -> dict:
        """Payload bytes per directed link — what each transport
        link's goodput counter must equal after any replay, faulted or
        not."""
        out: dict = {}
        for f in self.flights:
            for m in f.msgs:
                out[(m.src, m.dst)] = out.get((m.src, m.dst), 0) + len(m.data)
        return out


_state = threading.local()


def get_ledger() -> Ledger | None:
    return getattr(_state, "ledger", None)


def get_wire_tape() -> WireTape | None:
    return getattr(_state, "wire_tape", None)


@contextlib.contextmanager
def wire_tape_scope(tape: WireTape | None) -> Iterator[WireTape | None]:
    """Capture every online flight recorded inside into `tape` (pass
    None to explicitly suppress an outer capture, e.g. hermetic analytic
    replays)."""
    prev = get_wire_tape()
    _state.wire_tape = tape
    try:
        yield tape
    finally:
        _state.wire_tape = prev


def get_wave() -> int:
    return getattr(_state, "wave", 1)


def get_batcher():
    """Ambient flight batcher (mpc/fusion.py), or None when eager."""
    return getattr(_state, "batcher", None)


def set_batcher(batcher):
    """Install a flight batcher; returns the previous one (restore it)."""
    prev = get_batcher()
    _state.batcher = batcher
    return prev


def record(op: str, rounds: int, nbytes: int, numel: int = 0,
           flops: int = 0, tag: str = "bw", payload=None) -> None:
    """Record one wire interaction into the ambient Ledger.

    Inside a wave_scope(W) the op services W coalesced batches in a
    single trace (the executor vmaps the wave), so the structural shapes
    seen here are per-batch: bytes/numel/flops scale by W. Rounds follow
    the paper's §4.4 split — latency-bound flights ("lat") are stacked
    into ONE message per wave (rounds paid once), bandwidth-bound Beaver
    openings ("bw") stay one flight per batch: their wire time is what
    the overlap stage hides, and serializing them costs no extra RTTs
    on a saturated link.

    `payload` is the record's EXECUTION hook: the flight's actual
    messages as (src, dst, tensor_or_bytes[, rnd]) entries. It is only
    consulted when a WireTape is ambient (`--wire` runs, which execute
    eagerly at wave 1 so tensors are concrete); modeled records pass
    None and capture as synthesized filler of the exact recorded size.
    """
    led = get_ledger()
    if led is None:
        return
    tape = get_wire_tape()
    fb = get_batcher()
    if fb is not None and fb.absorb(op, rounds, nbytes, numel, flops, tag,
                                    payload=payload):
        return                        # deferred: rides a fused flight
    w = get_wave()
    if w > 1 and tag != "lat":
        rounds = rounds * w
    led.add(CostRecord(op, rounds, nbytes * w, numel * w, flops * w, tag,
                       wave=w))
    if tape is not None and tag != "offline":
        # offline (dealer) bytes never ride the online wire — the tape
        # mirrors exactly the records Ledger.nbytes counts
        tape.add(op, rounds, nbytes * w, tag,
                 payload if w == 1 else None)


@contextlib.contextmanager
def wave_scope(wave: int) -> Iterator[None]:
    """Mark that every op recorded inside services `wave` coalesced
    batches in one flight (the executor's vmapped wave trace)."""
    prev = get_wave()
    _state.wave = wave
    try:
        yield
    finally:
        _state.wave = prev


@contextlib.contextmanager
def ledger_scope() -> Iterator[Ledger]:
    prev = get_ledger()
    led = Ledger()
    _state.ledger = led
    try:
        yield led
    finally:
        _state.ledger = prev
