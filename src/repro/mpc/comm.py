"""Communication cost accounting + network delay model.

Every MPC op that talks to the wire records a CostRecord into the ambient
Ledger (a context-scoped accumulator). Records are *structural* — rounds
and bytes are functions of static shapes — so accounting is exact whether
ops run eagerly or under trace.

Delay model (matches the paper's experiment setup, §5.1):
  serial_time   = rounds * rtt_latency + bytes_on_wire / bandwidth + compute
  overlapped    = the IO scheduler (core/iosched.py) computes a makespan
                  where comm of batch i overlaps compute of batch i+1 and
                  latency-bound ops are coalesced across batches.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Iterator


@dataclasses.dataclass(frozen=True)
class NetProfile:
    name: str
    bandwidth_Bps: float     # per-direction point-to-point
    latency_s: float         # one round-trip

    def time(self, rounds: float, nbytes: float, compute_s: float = 0.0) -> float:
        return rounds * self.latency_s + nbytes / self.bandwidth_Bps + compute_s


# Paper's WAN emulation: 100 MB/s, 100 ms (Section 5.1).
WAN = NetProfile("wan", 100e6, 100e-3)
# TPU v5e inter-pod data-center network (deployment projection).
POD_DCN = NetProfile("pod_dcn", 25e9, 50e-6)
# Intra-pod ICI (per-link), used by roofline collective term.
ICI = NetProfile("ici", 50e9, 1e-6)


@dataclasses.dataclass
class CostRecord:
    op: str
    rounds: int
    nbytes: int          # total bytes on the wire (both directions)
    numel: int = 0
    flops: int = 0       # local per-party compute, for the overlap model
    tag: str = ""        # scheduler class: "bw" (bandwidth-bound) | "lat"
                         # | "offline" (dealer bytes, streamed pre-phase)
    wave: int = 1        # batches serviced by this flight (executor waves)


class Ledger:
    """Accumulates CostRecords; queried by benchmarks and the scheduler."""

    def __init__(self) -> None:
        self.records: list[CostRecord] = []

    def add(self, rec: CostRecord) -> None:
        self.records.append(rec)

    # ---- aggregates -------------------------------------------------
    @property
    def rounds(self) -> int:
        return sum(r.rounds for r in self.records)

    @property
    def nbytes(self) -> int:
        """Online bytes-on-wire. Offline (dealer) bytes are a separate
        channel: streamed ahead of the phase, priced by
        `offline_nbytes`, never by the delay model."""
        return sum(r.nbytes for r in self.records if r.tag != "offline")

    @property
    def offline_nbytes(self) -> int:
        """Dealer-shipped correlated-randomness bytes (Beaver triples,
        truncation pairs). Zero for dealer-free backends (3pc)."""
        return sum(r.nbytes for r in self.records if r.tag == "offline")

    @property
    def flops(self) -> int:
        return sum(r.flops for r in self.records)

    # ---- scheduler views (tagged flight classes, paper §4.4) --------
    def rounds_tagged(self, tag: str) -> int:
        return sum(r.rounds for r in self.records if r.tag == tag)

    @property
    def lat_rounds(self) -> int:
        return self.rounds_tagged("lat")

    @property
    def bw_rounds(self) -> int:
        return self.rounds_tagged("bw")

    def serial_time(self, net: NetProfile, flops_per_s: float = 10e12) -> float:
        return net.time(self.rounds, self.nbytes, self.flops / flops_per_s)

    def by_op(self) -> dict[str, CostRecord]:
        out: dict[str, CostRecord] = {}
        for r in self.records:
            if r.op not in out:
                out[r.op] = CostRecord(r.op, 0, 0, 0, 0, r.tag)
            agg = out[r.op]
            agg.rounds += r.rounds
            agg.nbytes += r.nbytes
            agg.numel += r.numel
            agg.flops += r.flops
        return out

    def scaled(self, k: float) -> "Ledger":
        """Ledger for k identical repetitions of this workload."""
        led = Ledger()
        for r in self.records:
            led.add(CostRecord(r.op, int(r.rounds * k), int(r.nbytes * k),
                               int(r.numel * k), int(r.flops * k), r.tag))
        return led


_state = threading.local()


def get_ledger() -> Ledger | None:
    return getattr(_state, "ledger", None)


def get_wave() -> int:
    return getattr(_state, "wave", 1)


def get_batcher():
    """Ambient flight batcher (mpc/fusion.py), or None when eager."""
    return getattr(_state, "batcher", None)


def set_batcher(batcher):
    """Install a flight batcher; returns the previous one (restore it)."""
    prev = get_batcher()
    _state.batcher = batcher
    return prev


def record(op: str, rounds: int, nbytes: int, numel: int = 0,
           flops: int = 0, tag: str = "bw") -> None:
    """Record one wire interaction into the ambient Ledger.

    Inside a wave_scope(W) the op services W coalesced batches in a
    single trace (the executor vmaps the wave), so the structural shapes
    seen here are per-batch: bytes/numel/flops scale by W. Rounds follow
    the paper's §4.4 split — latency-bound flights ("lat") are stacked
    into ONE message per wave (rounds paid once), bandwidth-bound Beaver
    openings ("bw") stay one flight per batch: their wire time is what
    the overlap stage hides, and serializing them costs no extra RTTs
    on a saturated link.
    """
    led = get_ledger()
    if led is None:
        return
    fb = get_batcher()
    if fb is not None and fb.absorb(op, rounds, nbytes, numel, flops, tag):
        return                        # deferred: rides a fused flight
    w = get_wave()
    if w > 1 and tag != "lat":
        rounds = rounds * w
    led.add(CostRecord(op, rounds, nbytes * w, numel * w, flops * w, tag,
                       wave=w))


@contextlib.contextmanager
def wave_scope(wave: int) -> Iterator[None]:
    """Mark that every op recorded inside services `wave` coalesced
    batches in one flight (the executor's vmapped wave trace)."""
    prev = get_wave()
    _state.wave = wave
    try:
        yield
    finally:
        _state.wave = prev


@contextlib.contextmanager
def ledger_scope() -> Iterator[Ledger]:
    prev = get_ledger()
    led = Ledger()
    _state.ledger = led
    try:
        yield led
    finally:
        _state.ledger = prev
