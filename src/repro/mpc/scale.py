"""The fixed-point scale lattice — one algebra for execution AND mirror.

A scale-carrying share encodes value v as round(v * 2**fb) where `fb`
(the carried frac-bits exponent, `Share.fb`) is static pytree aux data.
Canonical scale is the ring's `frac_bits` (f); a product of f-scale
operands sits at 2f, and instead of forcing a truncation at every op
boundary (the PR 3 `PendingShare` regime) the exponent simply flows
through downstream ops:

  lift        (exact, local, free)   int * 2**k        fb += k
  pow2 fold   (exact, local, free)   reinterpretation  fb -= k  for * 2**k
  trunc       (a protocol op)        int >> shift      fb -= shift

The lattice cap is 2f: any op that GROWS integer magnitude (lifting an
operand for alignment, multiplying two shares) must keep the result's
exponent at or below 2f so |v1*v2| < 2**(bits-1-2f) — the same headroom
contract eager truncation maintained. Pure reinterpretations (pow2
folds) may push fb beyond 2f because the integers never move; the next
magnitude-growing consumer truncates by the accumulated excess in one
shot.

This module is the decision procedure only — pure functions of static
exponents, shared verbatim by the executable ops (`mpc/ops.py`) and the
analytic mirror (`mpc/costs.proxy_exec_cost`), so "where does a forced
truncation fire" exists exactly once and the record-for-record mirror
tests catch any drift.
"""
from __future__ import annotations

import math


def cap(f: int) -> int:
    """Max exponent a magnitude-growing op may produce (2f)."""
    return 2 * f


def pow2_exponent(v) -> int | None:
    """k such that v == ±2**k for a python/numpy scalar, else None.

    Multiplying by ±2**k is a pure exponent adjustment (fb -= k) plus at
    most a negation — zero arithmetic on the fraction, zero rounding,
    zero truncation. Non-scalars and non-powers return None (the general
    encode-at-f path)."""
    try:
        x = float(v)
    except (TypeError, ValueError):
        return None
    if x == 0.0 or math.isinf(x) or math.isnan(x):
        return None
    m, e = math.frexp(abs(x))       # |x| = m * 2**e, m in [0.5, 1)
    return e - 1 if m == 0.5 else None


def align_target(sa: int, sb: int, f: int) -> int:
    """Common exponent for add/sub/concat operands at exponents sa, sb.

    Equal scales pass through (even above 2f: adding two reinterpreted
    tensors moves no integers). Otherwise the lower operand LIFTS to the
    higher exponent — exact and free — capped at 2f: a lift beyond 2f
    would overflow the headroom contract, so the higher operand truncs
    down to the cap instead."""
    if sa == sb:
        return sa
    return min(max(sa, sb), cap(f))


def mul_plan(sx: int, sy: int, f: int) -> tuple[int, int, int]:
    """(shift_x, shift_y, out_exponent) for a share*share product.

    The product's exponent is sx + sy; while that exceeds the 2f cap,
    the larger operand is truncated — by exactly the excess when that
    suffices, never below canonical f. Two f-scale inputs emit at 2f
    untruncated; a 2f-scale input against an exponent-0 input (a
    comparison bit) multiplies for free; 2f x f and 2f x 2f force the
    carried truncation that eager mode paid per-product."""
    s = [sx, sy]
    shift = [0, 0]
    while s[0] + s[1] > cap(f):
        i = 0 if s[0] >= s[1] else 1
        if s[i] <= f:
            break                   # both canonical: 2f is legal by cap
        red = min(s[i] - f, s[0] + s[1] - cap(f))
        shift[i] += red
        s[i] -= red
    return shift[0], shift[1], s[0] + s[1]


def mul_public_plan(s: int, v, f: int) -> tuple[int | None, int, int]:
    """(fold_exponent, force_shift, out_exponent) for share * public v.

    Power-of-two scalars fold into the exponent (fold_exponent = k,
    force_shift = 0, out = s - k). General constants encode at f and
    multiply: if the input already sits above canonical the product
    would pass 2f, so the input forces down by `force_shift` first."""
    k = pow2_exponent(v)
    if k is not None:
        return k, 0, s - k
    shift = max(0, s - f)           # bring the share back to canonical
    return None, shift, (s - shift) + f
