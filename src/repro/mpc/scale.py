"""The fixed-point scale lattice — one algebra for execution AND mirror.

A scale-carrying share encodes value v as round(v * 2**fb) where `fb`
(the carried frac-bits exponent, `Share.fb`) is static pytree aux data.
Canonical scale is the ring's `frac_bits` (f); a product of f-scale
operands sits at 2f, and instead of forcing a truncation at every op
boundary (the PR 3 `PendingShare` regime) the exponent simply flows
through downstream ops:

  lift        (exact, local, free)   int * 2**k        fb += k
  pow2 fold   (exact, local, free)   reinterpretation  fb -= k  for * 2**k
  trunc       (a protocol op)        int >> shift      fb -= shift

The lattice cap is RING-PARAMETERIZED: any op that GROWS integer
magnitude (lifting an operand for alignment, multiplying two shares)
must keep the result's exponent at or below the cap so
|v1*v2| < 2**(bits-1-cap) — the same headroom contract eager truncation
maintained. The default cap is 2f; rings wide enough to hold a third
fraction (3f < bits-1, i.e. RING64's 48 < 63) legally defer one level
deeper — a 2f product multiplying an f operand emits at 3f with NO
force, erasing the residual RING64 truncations the uniform 2f cap
paid. RING32 (3*12 = 36 > 31) stays at 2f. Callers opt in by passing
the ring's bit width (`bits=`); the bare 3-argument form keeps the
uniform 2f cap. Pure reinterpretations (pow2 folds) may push fb beyond
the cap because the integers never move; the next magnitude-growing
consumer truncates by the accumulated excess in one shot.

This module is the decision procedure only — pure functions of static
exponents, shared verbatim by the executable ops (`mpc/ops.py`) and the
analytic mirror (`mpc/costs.proxy_exec_cost`), so "where does a forced
truncation fire" exists exactly once and the record-for-record mirror
tests catch any drift.
"""
from __future__ import annotations

import math


def cap(f: int, bits: int | None = None) -> int:
    """Max exponent a magnitude-growing op may produce.

    2f by default; 3f when the ring's bit width is given and a third
    fraction still leaves sign + headroom (3f < bits - 1): RING64
    (f=16, bits=64) caps at 48, RING32 (f=12, bits=32) stays at 24.

    Callers gate `bits` on the backend's truncation exactness
    (`ops._headroom_bits` / the costs.py mirror): probabilistic local
    truncation wraps w.p. ~ encoded/2**bits, which a 3f exponent
    amplifies 2**f-fold, so only exact-trunc backends (spdz2pc,
    aby3trunc) pass their ring width here — everyone else passes None
    and keeps the validated 2f regime.
    """
    if bits is not None and 3 * f < bits - 1:
        return 3 * f
    return 2 * f


def pow2_exponent(v) -> int | None:
    """k such that v == ±2**k for a python/numpy scalar, else None.

    Multiplying by ±2**k is a pure exponent adjustment (fb -= k) plus at
    most a negation — zero arithmetic on the fraction, zero rounding,
    zero truncation. Non-scalars and non-powers return None (the general
    encode-at-f path)."""
    try:
        x = float(v)
    except (TypeError, ValueError):
        return None
    if x == 0.0 or math.isinf(x) or math.isnan(x):
        return None
    m, e = math.frexp(abs(x))       # |x| = m * 2**e, m in [0.5, 1)
    return e - 1 if m == 0.5 else None


def align_target(sa: int, sb: int, f: int, bits: int | None = None) -> int:
    """Common exponent for add/sub/concat operands at exponents sa, sb.

    Equal scales pass through (even above the cap: adding two
    reinterpreted tensors moves no integers). Otherwise the lower
    operand LIFTS to the higher exponent — exact and free — capped by
    `cap(f, bits)`: a lift beyond the cap would overflow the headroom
    contract, so the higher operand truncs down to the cap instead."""
    if sa == sb:
        return sa
    return min(max(sa, sb), cap(f, bits))


def mul_plan(sx: int, sy: int, f: int,
             bits: int | None = None) -> tuple[int, int, int]:
    """(shift_x, shift_y, out_exponent) for a share*share product.

    The product's exponent is sx + sy; while that exceeds the headroom
    cap, the larger operand is truncated — by exactly the excess when
    that suffices, never below canonical f. Two f-scale inputs emit at
    2f untruncated; a cap-scale input against an exponent-0 input (a
    comparison bit) multiplies for free. Under the 2f cap, 2f x f and
    2f x 2f force the carried truncation that eager mode paid
    per-product; under a ring-wide 3f cap (RING64) the 2f x f case
    emits at 3f force-free."""
    c = cap(f, bits)
    s = [sx, sy]
    shift = [0, 0]
    while s[0] + s[1] > c:
        i = 0 if s[0] >= s[1] else 1
        if s[i] <= f:
            break                   # both canonical: 2f is legal by cap
        red = s[0] + s[1] - c
        if s[0] == s[1]:
            # equal operands split the excess SYMMETRICALLY (the loop
            # pass reduces each side by half) — a squared operand
            # (x is y in ops._forced_operands) forces once and reuses,
            # which is only coherent when shift_x == shift_y
            red = -(-red // 2)
        red = min(s[i] - f, red)
        shift[i] += red
        s[i] -= red
    return shift[0], shift[1], s[0] + s[1]


def mul_public_plan(s: int, v, f: int,
                    bits: int | None = None) -> tuple[int | None, int, int]:
    """(fold_exponent, force_shift, out_exponent) for share * public v.

    Power-of-two scalars fold into the exponent (fold_exponent = k,
    force_shift = 0, out = s - k). General constants encode at f and
    multiply: if the product s + f would pass the cap, the input forces
    down by `force_shift` first — exactly to the exponent where the
    product lands on the cap (canonical under 2f; up to 2f input under
    a ring-wide 3f cap)."""
    k = pow2_exponent(v)
    if k is not None:
        return k, 0, s - k
    shift = max(0, s - (cap(f, bits) - f))   # product lands on the cap
    return None, shift, (s - shift) + f
