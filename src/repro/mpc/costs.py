"""Analytic MPC cost formulas (rounds / bytes / local flops).

These mirror the executable protocols in ops.py/nonlinear.py exactly but
evaluate at *paper scale* (BERT over 42K-188K candidates) without moving
tensors, producing the Ledgers that fig2/fig6/fig7 benchmarks and the IO
scheduler consume. Element size defaults to CrypTen's int64 ring (8 B).

Tag convention ("bw" bandwidth-bound / "lat" latency-bound) feeds the
paper's §4.4 scheduler: comparisons and low-dim ops are "lat", big-tensor
Beaver openings are "bw".

Ring parameterization: the primitive helpers take an optional RingSpec.
RING64 (default) truncates locally — free, no record, CrypTen's choice.
RING32 (the TPU ring) uses dealer-assisted truncation: every fixed-point
product pays one extra opening round (`trunc_open`), mirrored here
record-for-record against the dealer path of `Additive2PC.trunc`.

Protocol parameterization: the same primitives take `protocol=`
("2pc"/"3pc") and mirror the chosen backend's records exactly:
  2pc  Beaver opening flights (bytes ~ inputs) + dealer bytes in the
       OFFLINE channel (tag="offline", 0 rounds: triples and, on
       RING32, truncation pairs) in the positions the executable dealer
       records them.
  3pc  one resharing flight per mul/matmul (bytes ~ OUTPUT), no
       truncation records at all (probabilistic local trunc), zero
       offline records — the dealer-free cost profile.
"""
from __future__ import annotations

import dataclasses
import math

from repro.mpc.comm import Ledger, CostRecord
from repro.mpc.compare import CMP_ROUNDS, CMP_BYTES
from repro.mpc.nonlinear import EXP_ITERS, RECIP_ITERS, RSQRT_ITERS, LOG_ITERS
from repro.mpc.ring import RING64, RingSpec

EB = 8  # default ring element bytes (int64)


def _led(*recs: CostRecord) -> Ledger:
    led = Ledger()
    for r in recs:
        led.add(r)
    return led


def _offline(n_elems: int, op: str, ring: RingSpec) -> CostRecord:
    """Dealer-shipped correlated randomness (mirrors
    additive2pc._record_offline): 0 rounds, both parties' components."""
    return CostRecord(op, 0, 2 * ring.elem_bytes * n_elems, n_elems, 0,
                      "offline")


def merge(*ledgers: Ledger) -> Ledger:
    out = Ledger()
    for led in ledgers:
        out.records.extend(led.records)
    return out


# ---------------------------------------------------------------------------
# primitive costs
# ---------------------------------------------------------------------------

def open_cost(n: int, op: str = "open", *, ring: RingSpec = RING64,
              protocol: str = "2pc") -> Ledger:
    parties = 3 if protocol == "3pc" else 2
    return _led(CostRecord(op, 1, parties * ring.elem_bytes * n, n, 0, "bw"))


def trunc_cost(n: int, op: str = "trunc_open", *,
               ring: RingSpec = RING64, protocol: str = "2pc") -> Ledger:
    """Fixed-point truncation after a product: free on 2pc/RING64 (local
    arithmetic shift) and on 3pc both rings (probabilistic local trunc);
    one dealer-pair opening — offline pair bytes + a trunc_open flight —
    on 2pc/RING32 (Additive2PC.trunc)."""
    if protocol == "3pc" or ring.bits >= 64:
        return Ledger()
    return _led(_offline(2 * n, op + ".pair", ring),
                CostRecord(op, 1, 2 * ring.elem_bytes * n, n, 0, "bw"))


def mul_cost(n: int, op: str = "beaver_mul", *,
             ring: RingSpec = RING64, protocol: str = "2pc") -> Ledger:
    if protocol == "3pc":
        # local cross-terms + one resharing flight; no triple, no trunc
        return _led(CostRecord(op, 1, 3 * ring.elem_bytes * n, n,
                               6 * n, "bw"))
    return merge(_led(_offline(3 * n, op + ".triple", ring),
                      CostRecord(op, 1, 4 * ring.elem_bytes * n, n,
                                 4 * n, "bw")),
                 trunc_cost(n, op + ".trunc", ring=ring))


def matmul_cost(batch: int, m: int, k: int, n: int,
                op: str = "beaver_matmul", *,
                ring: RingSpec = RING64, protocol: str = "2pc") -> Ledger:
    if protocol == "3pc":
        # resharing flight of the OUTPUT: bytes ~ batch*m*n (the inverse
        # of Beaver's input-proportional wire profile)
        out_elems = batch * m * n
        return _led(CostRecord(op, 1, 3 * ring.elem_bytes * out_elems,
                               out_elems, 6 * batch * m * k * n, "bw"))
    in_elems = batch * (m * k + k * n)
    nbytes = 2 * ring.elem_bytes * in_elems
    return merge(_led(_offline(in_elems + batch * m * n, op + ".triple",
                               ring),
                      CostRecord(op, 1, nbytes, in_elems,
                                 2 * batch * m * k * n, "bw")),
                 trunc_cost(batch * m * n, op + ".trunc", ring=ring))


def cmp_cost(n: int, op: str = "secure_cmp") -> Ledger:
    return _led(CostRecord(op, CMP_ROUNDS, CMP_BYTES * n, n, 0, "lat"))


def relu_cost(n: int, op: str = "relu", *, ring: RingSpec = RING64,
              protocol: str = "2pc") -> Ledger:
    return merge(cmp_cost(n, op + ".cmp"),
                 mul_cost(n, op + ".mul", ring=ring, protocol=protocol))


def exp_cost(n: int, op: str = "exp") -> Ledger:
    led = Ledger()
    for rec in [CostRecord(op, 1, 4 * EB * n, n, 4 * n, "bw")] * EXP_ITERS:
        led.add(rec)
    return led


def reciprocal_cost(n: int, op: str = "reciprocal") -> Ledger:
    led = exp_cost(n, op + ".exp_init")
    for _ in range(RECIP_ITERS):
        led.records.extend(mul_cost(n, op + ".nr").records * 2)
    return led


def rsqrt_cost(n: int, op: str = "rsqrt") -> Ledger:
    led = exp_cost(n, op + ".exp_init")
    for _ in range(RSQRT_ITERS):
        led.records.extend(mul_cost(n, op + ".nr").records * 3)
    return led


def log_cost(n: int, op: str = "log") -> Ledger:
    led = Ledger()
    for _ in range(LOG_ITERS):
        led.records.extend(exp_cost(n, op + ".hh_exp").records)
        led.records.extend(mul_cost(n, op + ".hh_mul").records)
    return led


def max_cost(rows: int, d: int, op: str = "max") -> Ledger:
    """Tournament max: log2(d) sequential levels of (compare + select-mul)."""
    led = Ledger()
    levels = max(1, math.ceil(math.log2(max(d, 2))))
    width = d
    for _ in range(levels):
        half = width // 2
        if half == 0:
            break
        led.records.extend(cmp_cost(rows * half, op + ".cmp").records)
        led.records.extend(mul_cost(rows * half, op + ".sel").records)
        width = width - half
    return led


def softmax_cost(rows: int, d: int, op: str = "softmax") -> Ledger:
    return merge(max_cost(rows, d, op + ".max"),
                 exp_cost(rows * d, op + ".exp"),
                 reciprocal_cost(rows, op + ".recip"),
                 mul_cost(rows * d, op + ".norm"))


def layernorm_cost(rows: int, d: int, op: str = "layernorm") -> Ledger:
    return merge(mul_cost(rows * d, op + ".var"),
                 rsqrt_cost(rows, op + ".rsqrt"),
                 mul_cost(rows * d, op + ".normmul"),
                 mul_cost(rows * d, op + ".affine"))


def gelu_cost(n: int, op: str = "gelu") -> Ledger:
    return merge(mul_cost(n, op + ".sq"), mul_cost(n, op + ".mul"))


def entropy_cost(rows: int, classes: int, op: str = "entropy") -> Ledger:
    return merge(softmax_cost(rows, classes, op + ".softmax"),
                 log_cost(rows * classes, op + ".log"),
                 mul_cost(rows * classes, op + ".plogp"))


# ---------------------------------------------------------------------------
# MLP emulator costs (the paper's technique)
# ---------------------------------------------------------------------------

def mlp_cost(rows: int, d_in: int, hidden: int, d_out: int,
             op: str = "mlp", *, ring: RingSpec = RING64,
             protocol: str = "2pc") -> Ledger:
    """Linear(d_in->h) + ReLU(h) + Linear(h->d_out), private weights."""
    return merge(matmul_cost(1, rows, d_in, hidden, op + ".fc1", ring=ring,
                             protocol=protocol),
                 relu_cost(rows * hidden, op + ".relu", ring=ring,
                           protocol=protocol),
                 matmul_cost(1, rows, hidden, d_out, op + ".fc2", ring=ring,
                             protocol=protocol))


# ---------------------------------------------------------------------------
# block / model / selection costs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockGeom:
    batch: int
    seq: int
    d_model: int
    heads: int
    d_head: int
    d_ff: int

    @property
    def tokens(self) -> int:
        return self.batch * self.seq


def exact_attention_cost(g: BlockGeom) -> Ledger:
    """One exact transformer block forward under CrypTen (the baseline)."""
    t = g.tokens
    dh = g.d_head
    return merge(
        matmul_cost(1, t, g.d_model, 3 * g.heads * dh, "attn.qkv"),
        matmul_cost(g.batch * g.heads, g.seq, dh, g.seq, "attn.scores"),
        softmax_cost(g.batch * g.heads * g.seq, g.seq, "attn.softmax"),
        matmul_cost(g.batch * g.heads, g.seq, g.seq, dh, "attn.av"),
        matmul_cost(1, t, g.heads * dh, g.d_model, "attn.out"),
        layernorm_cost(t, g.d_model, "attn.ln"),
    )


def exact_ffn_cost(g: BlockGeom) -> Ledger:
    t = g.tokens
    return merge(
        matmul_cost(1, t, g.d_model, g.d_ff, "ffn.fc1"),
        gelu_cost(t * g.d_ff, "ffn.gelu"),
        matmul_cost(1, t, g.d_ff, g.d_model, "ffn.fc2"),
        layernorm_cost(t, g.d_model, "ffn.ln"),
    )


def exact_block_cost(g: BlockGeom) -> Ledger:
    return merge(exact_attention_cost(g), exact_ffn_cost(g))


def exact_model_cost(g: BlockGeom, layers: int, classes: int) -> Ledger:
    led = Ledger()
    blk = exact_block_cost(g)
    for _ in range(layers):
        led.records.extend(blk.records)
    led.records.extend(matmul_cost(1, g.batch, g.d_model, classes, "head").records)
    led.records.extend(entropy_cost(g.batch, classes).records)
    return led


def proxy_block_cost(g: BlockGeom, mlp_hidden: int) -> Ledger:
    """SelectFormer proxy block: MLP_sm for softmax, MLP_ln for the
    LayerNorm reciprocal, no FFN, GeLU->ReLU (no GeLU at all w/o FFN)."""
    t = g.tokens
    dh = g.d_head
    rows_sm = g.batch * g.heads * g.seq
    return merge(
        matmul_cost(1, t, g.d_model, 3 * g.heads * dh, "proxy.qkv"),
        matmul_cost(g.batch * g.heads, g.seq, dh, g.seq, "proxy.scores"),
        mlp_cost(rows_sm, g.seq, mlp_hidden, g.seq, "proxy.mlp_sm"),
        matmul_cost(g.batch * g.heads, g.seq, g.seq, dh, "proxy.av"),
        matmul_cost(1, t, g.heads * dh, g.d_model, "proxy.out"),
        # LayerNorm: numerator local; reciprocal-of-std emulated by MLP
        mul_cost(t * g.d_model, "proxy.ln.var"),
        mlp_cost(t, 1, mlp_hidden, 1, "proxy.mlp_ln"),
        mul_cost(t * g.d_model, "proxy.ln.normmul"),
    )


def proxy_model_cost(g: BlockGeom, layers: int, classes: int,
                     mlp_hidden: int) -> Ledger:
    led = Ledger()
    blk = proxy_block_cost(g, mlp_hidden)
    for _ in range(layers):
        led.records.extend(blk.records)
    led.records.extend(matmul_cost(1, g.batch, g.d_model, classes,
                                   "proxy.head").records)
    # fused softmax+entropy MLP: classes -> hidden -> 1
    led.records.extend(mlp_cost(g.batch, classes, mlp_hidden, 1,
                                "proxy.mlp_se").records)
    return led


def proxy_exec_cost(bsz: int, seq: int, d_model: int, heads: int,
                    kv_heads: int, d_head: int, mlp_hidden: int,
                    classes: int, n_layers: int,
                    op: str = "exec", *, ring: RingSpec = RING64,
                    protocol: str = "2pc", fused: bool = False) -> Ledger:
    """EXACT mirror of the engine forward's share-level op stream.

    Record-for-record prediction of what one batch of the executable
    proxy forward (`engine/forward.proxy_entropy` under an MPCEngine)
    puts on the wire — the contract the wave executor's TraceEngine
    probe is tested against (tests/test_executor.py) and the per-batch
    input fig7 feeds to iosched.makespan. Unlike `proxy_model_cost`
    (paper-geometry pricing with fused QKV), this follows the executed
    path: separate q/k/v openings, two LayerNorm affine multiplies, GQA
    head grouping, and ring-dependent truncation — record-free local
    shifts on RING64, dealer-assisted `trunc_open` rounds on RING32
    (including the mean/scale `mul_public` truncations that are free on
    RING64). Biases add no wire cost, so the formulas hold with or
    without them.

    `protocol="3pc"` mirrors the replicated-sharing stream: resharing
    flights (output-proportional bytes) in place of Beaver openings,
    no truncation records on either ring, and an empty offline channel.

    `fused=True` mirrors the round-compressed stream instead: the eager
    event stream below — with GroupBegin/GroupEnd markers placed exactly
    where `engine/forward.py` opens its `eng.fused` groups — is replayed
    through `fusion.compress_events`, i.e. the very FlightBatcher the
    executed path batches with, so flush semantics cannot drift between
    model and execution.
    """
    from repro.mpc import fusion

    w, wk = heads, min(kv_heads, heads)
    t = bsz * seq
    events: list = []
    kw = dict(ring=ring, protocol=protocol)

    def ext(led: Ledger) -> None:
        events.extend(led.records)

    for _ in range(n_layers):
        # MLP-LayerNorm: mean (trunc only), numerator exact (var
        # multiply), rsqrt emulated, then normalize-and-affine
        # multiplies against shared gamma
        events.append(fusion.GroupBegin("ln_stats"))
        ext(trunc_cost(t, f"{op}.ln.mu.trunc", **kw))
        ext(mul_cost(t * d_model, f"{op}.ln.var", **kw))
        ext(trunc_cost(t, f"{op}.ln.var_mean.trunc", **kw))
        events.append(fusion.GROUP_END)
        ext(mlp_cost(t, 1, mlp_hidden, 1, f"{op}.mlp_ln", **kw))
        ext(mul_cost(t * d_model, f"{op}.ln.normmul", **kw))
        ext(mul_cost(t * d_model, f"{op}.ln.affine", **kw))
        # pruned attention: per-projection secure matmuls
        events.append(fusion.GroupBegin("qkv"))
        ext(matmul_cost(1, t, d_model, w * d_head, f"{op}.q", **kw))
        ext(matmul_cost(1, t, d_model, wk * d_head, f"{op}.k", **kw))
        ext(matmul_cost(1, t, d_model, wk * d_head, f"{op}.v", **kw))
        events.append(fusion.GROUP_END)
        ext(matmul_cost(bsz * w, seq, d_head, seq, f"{op}.scores", **kw))
        ext(trunc_cost(bsz * w * seq * seq, f"{op}.scores.scale.trunc",
                       **kw))
        ext(mlp_cost(bsz * w * seq, seq, mlp_hidden, seq, f"{op}.mlp_sm",
                     **kw))
        ext(matmul_cost(bsz * w, seq, seq, d_head, f"{op}.av", **kw))
        ext(matmul_cost(1, t, w * d_head, d_model, f"{op}.out", **kw))
    ext(trunc_cost(bsz * d_model, f"{op}.pool.trunc", **kw))
    ext(matmul_cost(1, bsz, d_model, classes, f"{op}.head", **kw))
    ext(mlp_cost(bsz, classes, mlp_hidden, 1, f"{op}.mlp_se", **kw))
    if fused:
        return fusion.compress_events(events)
    led = Ledger()
    led.records.extend(r for r in events
                       if not isinstance(r, (fusion.GroupBegin,
                                             fusion.GroupEnd)))
    return led


def mpcformer_block_cost(g: BlockGeom) -> Ledger:
    """MPCFormer baseline block: "2Quad" softmax (exp->(x+c)^2, recip stays),
    quad GeLU, keeps FFN and full dims — no dimension reduction."""
    t = g.tokens
    dh = g.d_head
    rows = g.batch * g.heads * g.seq
    quad_softmax = merge(mul_cost(rows * g.seq, "mf.sm.sq"),
                         reciprocal_cost(rows, "mf.sm.recip"),
                         mul_cost(rows * g.seq, "mf.sm.norm"))
    return merge(
        matmul_cost(1, t, g.d_model, 3 * g.heads * dh, "mf.qkv"),
        matmul_cost(g.batch * g.heads, g.seq, dh, g.seq, "mf.scores"),
        quad_softmax,
        matmul_cost(g.batch * g.heads, g.seq, g.seq, dh, "mf.av"),
        matmul_cost(1, t, g.heads * dh, g.d_model, "mf.out"),
        layernorm_cost(t, g.d_model, "mf.ln1"),
        matmul_cost(1, t, g.d_model, g.d_ff, "mf.fc1"),
        gelu_cost(t * g.d_ff, "mf.gelu"),
        matmul_cost(1, t, g.d_ff, g.d_model, "mf.fc2"),
        layernorm_cost(t, g.d_model, "mf.ln2"),
    )


def selection_phase_cost(n_candidates: int, keep: int, g: BlockGeom,
                         layers: int, classes: int, mlp_hidden: int) -> Ledger:
    """One multi-phase selection phase: score every candidate with the
    proxy, then QuickSelect the top `keep` (batched comparisons)."""
    n_batches = math.ceil(n_candidates / g.batch)
    fwd = proxy_model_cost(g, layers, classes, mlp_hidden)
    led = fwd.scaled(n_batches)
    # quickselect: ~2n comparisons in ~log(n) coalesced flights
    n_cmp = int(2.0 * n_candidates)
    flights = max(1, math.ceil(math.log2(max(n_candidates, 2)))) + 4
    led.add(CostRecord("quickselect", flights * CMP_ROUNDS,
                       n_cmp * CMP_BYTES, n_cmp, 0, "lat"))
    return led


def oracle_selection_cost(n_candidates: int, keep: int, g: BlockGeom,
                          layers: int, classes: int) -> Ledger:
    n_batches = math.ceil(n_candidates / g.batch)
    led = exact_model_cost(g, layers, classes).scaled(n_batches)
    n_cmp = int(2.0 * n_candidates)
    flights = max(1, math.ceil(math.log2(max(n_candidates, 2)))) + 4
    led.add(CostRecord("quickselect", flights * CMP_ROUNDS,
                       n_cmp * CMP_BYTES, n_cmp, 0, "lat"))
    return led
