"""Analytic MPC cost formulas (rounds / bytes / local flops).

These mirror the executable protocols in ops.py/nonlinear.py exactly but
evaluate at *paper scale* (BERT over 42K-188K candidates) without moving
tensors, producing the Ledgers that fig2/fig6/fig7 benchmarks and the IO
scheduler consume. Element size defaults to CrypTen's int64 ring (8 B).

Tag convention ("bw" bandwidth-bound / "lat" latency-bound) feeds the
paper's §4.4 scheduler: comparisons and low-dim ops are "lat", big-tensor
Beaver openings are "bw".

Ring parameterization: the primitive helpers take an optional RingSpec.
RING64 (default) truncates locally — free, no record, CrypTen's choice.
RING32 (the TPU ring) uses dealer-assisted truncation: every fixed-point
product pays one extra opening round (`trunc_open`), mirrored here
record-for-record against the dealer path of `Additive2PC.trunc`.

Protocol parameterization: the same primitives take `protocol=` and
mirror the chosen backend's records exactly:
  2pc       Beaver opening flights (bytes ~ inputs) + dealer bytes in
            the OFFLINE channel (tag="offline", 0 rounds: triples and,
            on RING32, truncation pairs) in the positions the
            executable dealer records them.
  3pc       one resharing flight per mul/matmul (bytes ~ OUTPUT) and,
            per forced truncation, a 0-round `trunc_reshare` record
            pricing the re-replication component on the resharing
            flight; zero offline records — the dealer-free cost
            profile.
  spdz2pc   the malicious tier: MAC'd dealer randomness (4 components
            per element — DOUBLE the semi-honest offline bytes), a
            sacrifice flight before every Beaver open, dealer
            truncation pairs on BOTH rings, and the constant-size
            batched MAC check + MAC-key shipment at the forward
            boundary (`proxy_exec_cost` tail).
  aby3trunc 3pc resharing costs everywhere, except each forced
            truncation is one exact `trunc2` subprotocol: rounds=2
            (a batcher barrier), 6 components of wire.
"""
from __future__ import annotations

import dataclasses
import math

from repro.mpc.comm import Ledger, CostRecord
from repro.mpc.compare import CMP_ROUNDS, CMP_BYTES
from repro.mpc.nonlinear import EXP_ITERS, RECIP_ITERS, RSQRT_ITERS, LOG_ITERS
from repro.mpc.ring import RING64, RingSpec

EB = 8  # default ring element bytes (int64)


def _led(*recs: CostRecord) -> Ledger:
    led = Ledger()
    for r in recs:
        led.add(r)
    return led


def _offline(n_elems: int, op: str, ring: RingSpec) -> CostRecord:
    """Dealer-shipped correlated randomness (mirrors
    additive2pc._record_offline): 0 rounds, both parties' components."""
    return CostRecord(op, 0, 2 * ring.elem_bytes * n_elems, n_elems, 0,
                      "offline")


def _offline_mac(n_elems: int, op: str, ring: RingSpec) -> CostRecord:
    """MAC'd dealer randomness (mirrors spdz2pc._record_offline_mac):
    4 components per element (value + MAC, both parties)."""
    return CostRecord(op, 0, 4 * ring.elem_bytes * n_elems, n_elems, 0,
                      "offline")


# protocols sharing the replicated-3pc wire profile for mul/matmul
_P3 = ("3pc", "aby3trunc")


def merge(*ledgers: Ledger) -> Ledger:
    out = Ledger()
    for led in ledgers:
        out.records.extend(led.records)
    return out


# ---------------------------------------------------------------------------
# primitive costs
# ---------------------------------------------------------------------------

def open_cost(n: int, op: str = "open", *, ring: RingSpec = RING64,
              protocol: str = "2pc") -> Ledger:
    parties = 3 if protocol in _P3 else 2
    return _led(CostRecord(op, 1, parties * ring.elem_bytes * n, n, 0, "bw"))


def trunc_cost(n: int, op: str = "trunc_open", *,
               ring: RingSpec = RING64, protocol: str = "2pc") -> Ledger:
    """One forced truncation of n elements (ops.force / backend.trunc
    with a key) — the SAME records for any shift, which is what makes
    folding a chain of deferred rescales into one trunc a pure win:
      2pc RING64   local arithmetic shift — free, no record
      2pc RING32   dealer pair (offline bytes) + one trunc_open flight
      3pc both     local regrouped shift + the re-replication message
                   riding the next resharing flight: 0 rounds, one
                   output component's bytes (the ROADMAP PR 4 follow-up
                   — previously modeled as free, now priced)
      spdz2pc both MAC'd dealer pair + one partial-open flight — local
                   shifting is not MAC-preserving, so even RING64 pays
                   (the malicious overhead curve's truncation story)
      aby3trunc    one exact trunc2 subprotocol: rounds=2 (the masked
                   open depends on the pair-generation messages — a
                   batcher barrier), 6 components of wire, both rings."""
    if protocol == "aby3trunc":
        return _led(CostRecord(op, 2, 6 * ring.elem_bytes * n, n, 0, "bw"))
    if protocol == "3pc":
        return _led(CostRecord(op + ".reshare", 0, ring.elem_bytes * n, n,
                               0, "bw"))
    if protocol == "spdz2pc":
        return _led(_offline_mac(2 * n, op + ".pair", ring),
                    CostRecord(op, 1, 2 * ring.elem_bytes * n, n, 0, "bw"))
    if ring.bits >= 64:
        return Ledger()
    return _led(_offline(2 * n, op + ".pair", ring),
                CostRecord(op, 1, 2 * ring.elem_bytes * n, n, 0, "bw"))


def mul_cost(n: int, op: str = "beaver_mul", *,
             ring: RingSpec = RING64, protocol: str = "2pc",
             inline_trunc: bool = True) -> Ledger:
    """One secure elementwise multiply. `inline_trunc=True` prices the
    classic trunc-at-op-boundary stream (the CrypTen-style baselines);
    the executable scale-carrying ops emit the RAW product
    (`inline_trunc=False`) and `proxy_exec_cost` places the forced
    truncations where `mpc/scale.py` actually fires them."""
    if protocol in _P3:
        led = _led(CostRecord(op, 1, 3 * ring.elem_bytes * n, n,
                              6 * n, "bw"))
    elif protocol == "spdz2pc":
        # MAC'd triple + sacrificed triple (offline), the 1-round
        # sacrifice correlation open, then the Beaver open — in the
        # exact order spdz2pc.mul records them
        led = _led(_offline_mac(3 * n, op + ".triple", ring),
                   _offline_mac(3 * n, op + ".sacrifice_triple", ring),
                   CostRecord(op + ".sacrifice", 1,
                              4 * ring.elem_bytes * n, n, 0, "bw"),
                   CostRecord(op, 1, 4 * ring.elem_bytes * n, n,
                              4 * n, "bw"))
    else:
        led = _led(_offline(3 * n, op + ".triple", ring),
                   CostRecord(op, 1, 4 * ring.elem_bytes * n, n,
                              4 * n, "bw"))
    if inline_trunc:
        led = merge(led, trunc_cost(n, op + ".trunc", ring=ring,
                                    protocol=protocol))
    return led


def matmul_cost(batch: int, m: int, k: int, n: int,
                op: str = "beaver_matmul", *,
                ring: RingSpec = RING64, protocol: str = "2pc",
                inline_trunc: bool = True) -> Ledger:
    if protocol in _P3:
        # resharing flight of the OUTPUT: bytes ~ batch*m*n (the inverse
        # of Beaver's input-proportional wire profile)
        out_elems = batch * m * n
        led = _led(CostRecord(op, 1, 3 * ring.elem_bytes * out_elems,
                              out_elems, 6 * batch * m * k * n, "bw"))
    elif protocol == "spdz2pc":
        in_elems = batch * (m * k + k * n)
        trip = in_elems + batch * m * n
        led = _led(_offline_mac(trip, op + ".triple", ring),
                   _offline_mac(trip, op + ".sacrifice_triple", ring),
                   CostRecord(op + ".sacrifice", 1,
                              2 * ring.elem_bytes * in_elems, in_elems,
                              0, "bw"),
                   CostRecord(op, 1, 2 * ring.elem_bytes * in_elems,
                              in_elems, 2 * batch * m * k * n, "bw"))
    else:
        in_elems = batch * (m * k + k * n)
        nbytes = 2 * ring.elem_bytes * in_elems
        led = _led(_offline(in_elems + batch * m * n, op + ".triple",
                            ring),
                   CostRecord(op, 1, nbytes, in_elems,
                              2 * batch * m * k * n, "bw"))
    if inline_trunc:
        led = merge(led, trunc_cost(batch * m * n, op + ".trunc",
                                    ring=ring, protocol=protocol))
    return led


def cmp_cost(n: int, op: str = "secure_cmp") -> Ledger:
    return _led(CostRecord(op, CMP_ROUNDS, CMP_BYTES * n, n, 0, "lat"))


def relu_cost(n: int, op: str = "relu", *, ring: RingSpec = RING64,
              protocol: str = "2pc") -> Ledger:
    return merge(cmp_cost(n, op + ".cmp"),
                 mul_cost(n, op + ".mul", ring=ring, protocol=protocol))


def exp_cost(n: int, op: str = "exp") -> Ledger:
    led = Ledger()
    for rec in [CostRecord(op, 1, 4 * EB * n, n, 4 * n, "bw")] * EXP_ITERS:
        led.add(rec)
    return led


def reciprocal_cost(n: int, op: str = "reciprocal") -> Ledger:
    led = exp_cost(n, op + ".exp_init")
    for _ in range(RECIP_ITERS):
        led.records.extend(mul_cost(n, op + ".nr").records * 2)
    return led


def rsqrt_cost(n: int, op: str = "rsqrt") -> Ledger:
    led = exp_cost(n, op + ".exp_init")
    for _ in range(RSQRT_ITERS):
        led.records.extend(mul_cost(n, op + ".nr").records * 3)
    return led


def log_cost(n: int, op: str = "log") -> Ledger:
    led = Ledger()
    for _ in range(LOG_ITERS):
        led.records.extend(exp_cost(n, op + ".hh_exp").records)
        led.records.extend(mul_cost(n, op + ".hh_mul").records)
    return led


def max_cost(rows: int, d: int, op: str = "max") -> Ledger:
    """Tournament max: log2(d) sequential levels of (compare + select-mul)."""
    led = Ledger()
    levels = max(1, math.ceil(math.log2(max(d, 2))))
    width = d
    for _ in range(levels):
        half = width // 2
        if half == 0:
            break
        led.records.extend(cmp_cost(rows * half, op + ".cmp").records)
        led.records.extend(mul_cost(rows * half, op + ".sel").records)
        width = width - half
    return led


def softmax_cost(rows: int, d: int, op: str = "softmax") -> Ledger:
    return merge(max_cost(rows, d, op + ".max"),
                 exp_cost(rows * d, op + ".exp"),
                 reciprocal_cost(rows, op + ".recip"),
                 mul_cost(rows * d, op + ".norm"))


def layernorm_cost(rows: int, d: int, op: str = "layernorm") -> Ledger:
    return merge(mul_cost(rows * d, op + ".var"),
                 rsqrt_cost(rows, op + ".rsqrt"),
                 mul_cost(rows * d, op + ".normmul"),
                 mul_cost(rows * d, op + ".affine"))


def gelu_cost(n: int, op: str = "gelu") -> Ledger:
    return merge(mul_cost(n, op + ".sq"), mul_cost(n, op + ".mul"))


def entropy_cost(rows: int, classes: int, op: str = "entropy") -> Ledger:
    return merge(softmax_cost(rows, classes, op + ".softmax"),
                 log_cost(rows * classes, op + ".log"),
                 mul_cost(rows * classes, op + ".plogp"))


# ---------------------------------------------------------------------------
# MLP emulator costs (the paper's technique)
# ---------------------------------------------------------------------------

def mlp_cost(rows: int, d_in: int, hidden: int, d_out: int,
             op: str = "mlp", *, ring: RingSpec = RING64,
             protocol: str = "2pc") -> Ledger:
    """Linear(d_in->h) + ReLU(h) + Linear(h->d_out), private weights."""
    return merge(matmul_cost(1, rows, d_in, hidden, op + ".fc1", ring=ring,
                             protocol=protocol),
                 relu_cost(rows * hidden, op + ".relu", ring=ring,
                           protocol=protocol),
                 matmul_cost(1, rows, hidden, d_out, op + ".fc2", ring=ring,
                             protocol=protocol))


# ---------------------------------------------------------------------------
# block / model / selection costs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockGeom:
    batch: int
    seq: int
    d_model: int
    heads: int
    d_head: int
    d_ff: int

    @property
    def tokens(self) -> int:
        return self.batch * self.seq


def exact_attention_cost(g: BlockGeom) -> Ledger:
    """One exact transformer block forward under CrypTen (the baseline)."""
    t = g.tokens
    dh = g.d_head
    return merge(
        matmul_cost(1, t, g.d_model, 3 * g.heads * dh, "attn.qkv"),
        matmul_cost(g.batch * g.heads, g.seq, dh, g.seq, "attn.scores"),
        softmax_cost(g.batch * g.heads * g.seq, g.seq, "attn.softmax"),
        matmul_cost(g.batch * g.heads, g.seq, g.seq, dh, "attn.av"),
        matmul_cost(1, t, g.heads * dh, g.d_model, "attn.out"),
        layernorm_cost(t, g.d_model, "attn.ln"),
    )


def exact_ffn_cost(g: BlockGeom) -> Ledger:
    t = g.tokens
    return merge(
        matmul_cost(1, t, g.d_model, g.d_ff, "ffn.fc1"),
        gelu_cost(t * g.d_ff, "ffn.gelu"),
        matmul_cost(1, t, g.d_ff, g.d_model, "ffn.fc2"),
        layernorm_cost(t, g.d_model, "ffn.ln"),
    )


def exact_block_cost(g: BlockGeom) -> Ledger:
    return merge(exact_attention_cost(g), exact_ffn_cost(g))


def exact_model_cost(g: BlockGeom, layers: int, classes: int) -> Ledger:
    led = Ledger()
    blk = exact_block_cost(g)
    for _ in range(layers):
        led.records.extend(blk.records)
    led.records.extend(matmul_cost(1, g.batch, g.d_model, classes, "head").records)
    led.records.extend(entropy_cost(g.batch, classes).records)
    return led


def proxy_block_cost(g: BlockGeom, mlp_hidden: int) -> Ledger:
    """SelectFormer proxy block: MLP_sm for softmax, MLP_ln for the
    LayerNorm reciprocal, no FFN, GeLU->ReLU (no GeLU at all w/o FFN)."""
    t = g.tokens
    dh = g.d_head
    rows_sm = g.batch * g.heads * g.seq
    return merge(
        matmul_cost(1, t, g.d_model, 3 * g.heads * dh, "proxy.qkv"),
        matmul_cost(g.batch * g.heads, g.seq, dh, g.seq, "proxy.scores"),
        mlp_cost(rows_sm, g.seq, mlp_hidden, g.seq, "proxy.mlp_sm"),
        matmul_cost(g.batch * g.heads, g.seq, g.seq, dh, "proxy.av"),
        matmul_cost(1, t, g.heads * dh, g.d_model, "proxy.out"),
        # LayerNorm: numerator local; reciprocal-of-std emulated by MLP
        mul_cost(t * g.d_model, "proxy.ln.var"),
        mlp_cost(t, 1, mlp_hidden, 1, "proxy.mlp_ln"),
        mul_cost(t * g.d_model, "proxy.ln.normmul"),
    )


def proxy_model_cost(g: BlockGeom, layers: int, classes: int,
                     mlp_hidden: int) -> Ledger:
    led = Ledger()
    blk = proxy_block_cost(g, mlp_hidden)
    for _ in range(layers):
        led.records.extend(blk.records)
    led.records.extend(matmul_cost(1, g.batch, g.d_model, classes,
                                   "proxy.head").records)
    # fused softmax+entropy MLP: classes -> hidden -> 1
    led.records.extend(mlp_cost(g.batch, classes, mlp_hidden, 1,
                                "proxy.mlp_se").records)
    return led


def proxy_exec_cost(bsz: int, seq: int, d_model: int, heads: int,
                    kv_heads: int, d_head: int, mlp_hidden: int,
                    classes: int, n_layers: int,
                    op: str = "exec", *, ring: RingSpec = RING64,
                    protocol: str = "2pc", fused: bool = False) -> Ledger:
    """EXACT mirror of the engine forward's share-level op stream.

    Record-for-record prediction of what one batch of the executable
    proxy forward (`engine/forward.proxy_entropy` under an MPCEngine)
    puts on the wire — the contract the wave executor's TraceEngine
    probe is tested against (tests/test_executor.py) and the per-batch
    input fig7 feeds to iosched.makespan. Unlike `proxy_model_cost`
    (paper-geometry pricing with fused QKV), this follows the executed
    path: separate q/k/v openings, two LayerNorm affine multiplies, GQA
    head grouping, and ring-dependent truncation — record-free local
    shifts on RING64, dealer-assisted `trunc_open` rounds on RING32
    (including the mean/scale `mul_public` truncations that are free on
    RING64). Biases add no wire cost, so the formulas hold with or
    without them.

    `protocol="3pc"` mirrors the replicated-sharing stream: resharing
    flights (output-proportional bytes) in place of Beaver openings,
    0-round `trunc_reshare` bytes wherever a truncation is forced (the
    re-replication component riding the resharing flight), and an
    empty offline channel on both rings. `protocol="spdz2pc"` mirrors
    the malicious tier (doubled MAC'd offline bytes, a sacrifice flight
    per multiply, dealer truncation on both rings, and the boundary
    mac_key/mac_check tail); `protocol="aby3trunc"` swaps every forced
    truncation for the 2-round exact `trunc2` record.

    `fused=True` mirrors the round-compressed stream instead: the eager
    event stream below — with GroupBegin/GroupEnd markers placed exactly
    where `engine/forward.py` opens its `eng.fused` groups — is replayed
    through `fusion.compress_events`, i.e. the very FlightBatcher the
    executed path batches with, so flush semantics cannot drift between
    model and execution.

    The stream is SCALE-SIMULATED: multiplies emit raw products at the
    summed exponent (`inline_trunc=False`) and forced truncations land
    exactly where the `mpc/scale.py` lattice — the SAME decision
    procedure the executable ops consult — fires them: power-of-two
    rescales (pow2 means, `d_head**-0.5`) fold for free, comparison
    bits multiply at exponent 0 (ReLU is truncation-free), a tensor
    consumed by several scale-sensitive ops truncates once (the
    ops.force memo), and forcing a broadcast bills the pre-broadcast
    element count (layout lineage). That is the cross-op deferred-
    truncation contract this mirror certifies record-for-record.
    """
    from repro.mpc import fusion, protocols, scale as lattice

    f = ring.frac_bits
    # headroom-cap bits handed to the scale lattice: mirrors
    # ops._headroom_bits — only exact-trunc backends (spdz2pc,
    # aby3trunc) may defer to the ring-wide 3f cap; probabilistic
    # local-trunc backends keep 2f (bits=None)
    hbits = ring.bits if protocols.get(protocol).exact_trunc else None
    w, wk = heads, min(kv_heads, heads)
    t = bsz * seq
    events: list = []
    kw = dict(ring=ring, protocol=protocol)

    def ext(led: Ledger) -> None:
        events.extend(led.records)

    class V:
        """Symbolic scale-carrying tensor: carried exponent, the element
        count a forced truncation bills (its lineage ROOT's numel), and
        the per-target force memo mirroring ops.force's cache."""

        def __init__(self, fb: int, n: int):
            self.fb, self.n, self.forced = fb, n, set()

    W = V(f, 0)                       # shared weights: always canonical

    def forced(v: V, name: str, to: int) -> None:
        if v.fb <= to or to in v.forced:
            return
        ext(trunc_cost(v.n, f"{op}.{name}", **kw))
        v.forced.add(to)

    def mul_pub(v: V, c: float, name: str, n_out: int) -> V:
        k = lattice.pow2_exponent(c)
        if k is not None:             # free exponent fold
            return V(v.fb - k, n_out)
        _, shift, out_fb = lattice.mul_public_plan(v.fb, c, f, hbits)
        if shift:
            forced(v, name, v.fb - shift)
        return V(out_fb, n_out)

    def mul2(x: V, y: V, name: str, n: int) -> V:
        px, py, out_fb = lattice.mul_plan(x.fb, y.fb, f, hbits)
        if px:
            forced(x, f"{name}.x", x.fb - px)
        if py and y is not x:
            forced(y, f"{name}.y", y.fb - py)
        ext(mul_cost(n, f"{op}.{name}", inline_trunc=False, **kw))
        return V(out_fb, n)

    def mm(x: V, y: V, name: str, batch: int, m: int, kk: int,
           n: int) -> V:
        px, py, out_fb = lattice.mul_plan(x.fb, y.fb, f, hbits)
        if px:
            forced(x, f"{name}.x", x.fb - px)
        if py and y is not x:
            forced(y, f"{name}.y", y.fb - py)
        ext(matmul_cost(batch, m, kk, n, f"{op}.{name}",
                        inline_trunc=False, **kw))
        return V(out_fb, batch * m * n)

    def mlp(x: V, rows: int, d_in: int, hid: int, d_out: int,
            name: str) -> V:
        h = mm(x, W, f"{name}.fc1", 1, rows, d_in, hid)
        # ReLU: comparison (scale-invariant) + bit multiply at exponent
        # 0 — truncation-free, output keeps h's exponent
        ext(cmp_cost(rows * hid, f"{op}.{name}.relu.cmp"))
        r = mul2(h, V(0, rows * hid), f"{name}.relu.mul", rows * hid)
        return mm(r, W, f"{name}.fc2", 1, rows, hid, d_out)

    x_fb = f                          # shared activations enter canonical
    for _ in range(n_layers):
        # MLP-LayerNorm: pow2 d folds the mean for free; the centered
        # activation truncates ONCE (memo) though both the variance
        # square and the normalize multiply consume it
        events.append(fusion.GroupBegin("ln_stats"))
        mu = mul_pub(V(x_fb, t), 1.0 / d_model, "ln.mu.force", t)
        # centering sub: exact lift unless mu's pow2 fold topped the 2f
        # cap (layer >= 2, pow2 d) — then mu down-truncs KEYED, billed
        # at its pre-broadcast rows (lineage)
        align_fb = lattice.align_target(x_fb, mu.fb, f, hbits)
        if mu.fb > align_fb:
            forced(mu, "ln.mu.align", align_fb)
        xc = V(align_fb, t * d_model)
        var_p = mul2(xc, xc, "ln.var", t * d_model)
        var = mul_pub(V(var_p.fb, t), 1.0 / d_model, "ln.var_mean.force", t)
        events.append(fusion.GROUP_END)
        inv = mlp(var, t, 1, mlp_hidden, 1, "mlp_ln")
        # normalize: inv's force bills its pre-broadcast rows (lineage)
        h = mul2(xc, inv, "ln.normmul", t * d_model)
        h = mul2(h, W, "ln.affine", t * d_model)
        ha = V(h.fb, t * d_model)     # + beta (lift, free): new object
        # pruned attention: per-projection secure matmuls; one forced
        # trunc of the shared input serves all three projections
        events.append(fusion.GroupBegin("qkv"))
        q = mm(ha, W, "q", 1, t, d_model, w * d_head)
        k_ = mm(ha, W, "k", 1, t, d_model, wk * d_head)
        v_ = mm(ha, W, "v", 1, t, d_model, wk * d_head)
        events.append(fusion.GROUP_END)
        scores = mm(q, k_, "scores", bsz * w, seq, d_head, seq)
        scores = mul_pub(scores, d_head ** -0.5, "scores.scale.force",
                         bsz * w * seq * seq)
        probs = mlp(scores, bsz * w * seq, seq, mlp_hidden, seq, "mlp_sm")
        o = mm(probs, v_, "av", bsz * w, seq, seq, d_head)
        out = mm(o, W, "out", 1, t, w * d_head, d_model)
        x_fb = lattice.align_target(x_fb, out.fb, f, hbits)  # residual
    pooled = mul_pub(V(x_fb, bsz * d_model), 1.0 / seq, "pool.force",
                     bsz * d_model)
    logits = mm(pooled, W, "head", 1, bsz, d_model, classes)
    ent = mlp(logits, bsz, classes, mlp_hidden, 1, "mlp_se")
    # the engine's entropy head forces its output canonical — the
    # forward's public boundary (QuickSelect consumes fb == frac_bits)
    forced(ent, "entropy.force", f)
    if protocol == "spdz2pc":
        # the malicious boundary: dealer MAC-key shipment + ONE batched
        # MAC check for every partial open of the forward (constant
        # size), in the order spdz2pc.mac_check_flight records them
        events.append(CostRecord(f"{op}.mac_key", 0, 2 * ring.elem_bytes,
                                 1, 0, "offline"))
        events.append(CostRecord(f"{op}.mac_check", 1,
                                 4 * ring.elem_bytes, 1, 0, "bw"))
    if fused:
        return fusion.compress_events(events)
    led = Ledger()
    led.records.extend(r for r in events
                       if not isinstance(r, (fusion.GroupBegin,
                                             fusion.GroupEnd)))
    return led


def pr4_trunc_baseline(bsz: int, seq: int, d_model: int, heads: int,
                       kv_heads: int, d_head: int, mlp_hidden: int,
                       classes: int, n_layers: int, *,
                       ring: RingSpec = RING64) -> tuple[int, int]:
    """FROZEN PR 4 baseline: (truncation events, dealer trunc-pair
    bytes) of the pre-scale-carrying RING32 2PC proxy stream, where
    every mul/matmul/mul_public/mean forced its own truncation at the
    op boundary (18 events per layer + 5 tail). This is the regression
    reference `bench_fusion --smoke` gates the >=25% event reduction
    against — do NOT update it to track the live stream."""
    w, wk = heads, min(kv_heads, heads)
    t = bsz * seq
    rows = bsz * w * seq
    per_layer = [
        t,                      # mean trunc
        t * d_model,            # var mul
        t,                      # var mean
        t * mlp_hidden,         # mlp_ln fc1
        t * mlp_hidden,         # mlp_ln relu mul
        t,                      # mlp_ln fc2
        t * d_model,            # normmul
        t * d_model,            # affine
        t * w * d_head,         # q
        t * wk * d_head,        # k
        t * wk * d_head,        # v
        bsz * w * seq * seq,    # scores matmul
        bsz * w * seq * seq,    # scores scale
        rows * mlp_hidden,      # mlp_sm fc1
        rows * mlp_hidden,      # mlp_sm relu mul
        rows * seq,             # mlp_sm fc2
        bsz * w * seq * d_head,  # av
        t * d_model,            # out
    ]
    tail = [bsz * d_model, bsz * classes, bsz * mlp_hidden,
            bsz * mlp_hidden, bsz]
    numels = per_layer * n_layers + tail
    # one dealer pair per event: (r, r>>f) = 2 tensors, both parties
    return len(numels), sum(4 * ring.elem_bytes * n for n in numels)


def mpcformer_block_cost(g: BlockGeom) -> Ledger:
    """MPCFormer baseline block: "2Quad" softmax (exp->(x+c)^2, recip stays),
    quad GeLU, keeps FFN and full dims — no dimension reduction."""
    t = g.tokens
    dh = g.d_head
    rows = g.batch * g.heads * g.seq
    quad_softmax = merge(mul_cost(rows * g.seq, "mf.sm.sq"),
                         reciprocal_cost(rows, "mf.sm.recip"),
                         mul_cost(rows * g.seq, "mf.sm.norm"))
    return merge(
        matmul_cost(1, t, g.d_model, 3 * g.heads * dh, "mf.qkv"),
        matmul_cost(g.batch * g.heads, g.seq, dh, g.seq, "mf.scores"),
        quad_softmax,
        matmul_cost(g.batch * g.heads, g.seq, g.seq, dh, "mf.av"),
        matmul_cost(1, t, g.heads * dh, g.d_model, "mf.out"),
        layernorm_cost(t, g.d_model, "mf.ln1"),
        matmul_cost(1, t, g.d_model, g.d_ff, "mf.fc1"),
        gelu_cost(t * g.d_ff, "mf.gelu"),
        matmul_cost(1, t, g.d_ff, g.d_model, "mf.fc2"),
        layernorm_cost(t, g.d_model, "mf.ln2"),
    )


def selection_phase_cost(n_candidates: int, keep: int, g: BlockGeom,
                         layers: int, classes: int, mlp_hidden: int) -> Ledger:
    """One multi-phase selection phase: score every candidate with the
    proxy, then QuickSelect the top `keep` (batched comparisons)."""
    n_batches = math.ceil(n_candidates / g.batch)
    fwd = proxy_model_cost(g, layers, classes, mlp_hidden)
    led = fwd.scaled(n_batches)
    # quickselect: ~2n comparisons in ~log(n) coalesced flights
    n_cmp = int(2.0 * n_candidates)
    flights = max(1, math.ceil(math.log2(max(n_candidates, 2)))) + 4
    led.add(CostRecord("quickselect", flights * CMP_ROUNDS,
                       n_cmp * CMP_BYTES, n_cmp, 0, "lat"))
    return led


def oracle_selection_cost(n_candidates: int, keep: int, g: BlockGeom,
                          layers: int, classes: int) -> Ledger:
    n_batches = math.ceil(n_candidates / g.batch)
    led = exact_model_cost(g, layers, classes).scaled(n_batches)
    n_cmp = int(2.0 * n_candidates)
    flights = max(1, math.ceil(math.log2(max(n_candidates, 2)))) + 4
    led.add(CostRecord("quickselect", flights * CMP_ROUNDS,
                       n_cmp * CMP_BYTES, n_cmp, 0, "lat"))
    return led
