"""Secure comparison.

Protocol: modeled as an ideal functionality with the *real* protocol's
communication cost, exactly as stated by the paper (§4.1): one pairwise
comparison of two secret values costs 8 communication rounds and 432
bytes, and reveals only the binary outcome. The same functionality cost
is charged on every protocol backend (2pc binary-share conversion and
3pc bit-decomposition land in the same ballpark; the ledger mirror in
mpc/costs.py charges the identical record either way).

Implementation note (DESIGN.md §8): real comparison needs binary share
conversion (B2A/edaBits). Semantics here are computed from the summed
components *inside the functionality boundary* — the returned object is
either a Share of the bit (private outcome, used by ReLU/max) or a
revealed bool (public outcome, used by QuickSelect ranking, which the
paper explicitly reveals). Outputs inherit the input's protocol
backend.
"""
from __future__ import annotations

import jax

from repro.mpc.sharing import Share, reconstruct, share_encoded
from repro.mpc import comm, ops

CMP_ROUNDS = 8          # paper §4.1
CMP_BYTES = 432         # per scalar comparison


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def lt_zero(x: Share, key: jax.Array) -> Share:
    """Shares of the bit [x < 0], shared at EXPONENT 0 (integer 0/1).

    The sign of a two's-complement encoding is the sign of the value at
    any carried exponent, so scale-carrying inputs compare without
    forcing a truncation first; and a bit at exponent 0 multiplies into
    any share exactly (fb + 0 = fb) — ReLU and tournament-max selection
    become truncation-free."""
    n = _numel(x.shape)
    comm.record("secure_cmp", rounds=CMP_ROUNDS, nbytes=CMP_BYTES * n,
                numel=n, tag="lat")
    v = reconstruct(x)                         # functionality boundary
    bit = (v < 0).astype(x.ring.dtype)
    return share_encoded(key, bit, x.ring, x.proto, fb=0)


def le(x: Share, y: Share, key: jax.Array) -> Share:
    return lt_zero(ops.sub(x, y), key)


def reveal_lt(x: Share, y: Share) -> jax.Array:
    """Public bit x<y — what QuickSelect consumes (outcome revealed)."""
    d = ops.sub(x, y)
    n = _numel(d.shape)
    comm.record("secure_cmp_reveal", rounds=CMP_ROUNDS, nbytes=CMP_BYTES * n,
                numel=n, tag="lat")
    return reconstruct(d) < 0


def relu(x: Share, key: jax.Array) -> Share:
    """ReLU(x) = x * [x >= 0]: one comparison + one secure multiply.
    The bit sits at exponent 0, so the multiply is exact and the output
    keeps x's carried exponent — no truncation anywhere in ReLU."""
    kb, km = jax.random.split(key)
    neg_bit = lt_zero(x, kb)
    pos_bit = ops.add_public(ops.neg(neg_bit), 1.0)
    return ops.mul(x, pos_bit, km)


def max_(x: Share, axis: int, key: jax.Array) -> Share:
    """Tournament max along an axis: log2(n) comparison rounds."""
    cur = x
    i = 0
    while cur.shape[axis] > 1:
        m = cur.shape[axis]
        half = m // 2
        ax = axis + 1 if axis >= 0 else axis
        lo = cur.with_sh(jax.lax.slice_in_dim(cur.sh, 0, half, axis=ax))
        hi = cur.with_sh(jax.lax.slice_in_dim(cur.sh, half, 2 * half,
                                              axis=ax))
        kb, km, ka, key = jax.random.split(jax.random.fold_in(key, i), 4)
        b = le(lo, hi, kb)                      # [lo < hi]
        diff = ops.sub(hi, lo)
        # keyed: the align clamp may FORCE lo down a real truncation
        # (keyless would be the local-shift path — wrap-prone on RING32
        # and nonexistent for MAC'd shares)
        mx = ops.add(lo, ops.mul(b, diff, km), key=ka)  # lo + b*(hi-lo)
        if m % 2:
            tail = cur.with_sh(jax.lax.slice_in_dim(cur.sh, 2 * half, m,
                                                    axis=ax))
            mx = ops.concat([mx, tail], axis=axis)
        cur = mx
        i += 1
    return cur
