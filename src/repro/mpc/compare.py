"""Secure comparison.

Protocol: modeled as an ideal functionality with the *real* protocol's
communication cost, exactly as stated by the paper (§4.1): one pairwise
comparison of two secret values costs 8 communication rounds and 432
bytes, and reveals only the binary outcome.

Implementation note (DESIGN.md §8): real 2PC comparison needs binary
share conversion (B2A/edaBits). Semantics here are computed from the
summed shares *inside the functionality boundary* — the returned object
is either an AShare of the bit (private outcome, used by ReLU/max) or a
revealed bool (public outcome, used by QuickSelect ranking, which the
paper explicitly reveals).
"""
from __future__ import annotations

import jax

from repro.mpc.sharing import AShare, share_encoded
from repro.mpc import comm, ops

CMP_ROUNDS = 8          # paper §4.1
CMP_BYTES = 432         # per scalar comparison


def _numel(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


def lt_zero(x: AShare, key: jax.Array) -> AShare:
    """Shares of the bit [x < 0] (bit encoded at fixed-point scale 1.0)."""
    n = _numel(x.shape)
    comm.record("secure_cmp", rounds=CMP_ROUNDS, nbytes=CMP_BYTES * n,
                numel=n, tag="lat")
    v = x.sh[0] + x.sh[1]                      # functionality boundary
    bit = (v < 0).astype(x.ring.dtype) * x.ring.scale
    return share_encoded(key, bit, x.ring)


def le(x: AShare, y: AShare, key: jax.Array) -> AShare:
    return lt_zero(ops.sub(x, y), key)


def reveal_lt(x: AShare, y: AShare) -> jax.Array:
    """Public bit x<y — what QuickSelect consumes (outcome revealed)."""
    d = ops.sub(x, y)
    n = _numel(d.shape)
    comm.record("secure_cmp_reveal", rounds=CMP_ROUNDS, nbytes=CMP_BYTES * n,
                numel=n, tag="lat")
    return (d.sh[0] + d.sh[1]) < 0


def relu(x: AShare, key: jax.Array) -> AShare:
    """ReLU(x) = x * [x >= 0]: one comparison + one Beaver multiply."""
    kb, km = jax.random.split(key)
    neg_bit = lt_zero(x, kb)
    pos_bit = ops.add_public(ops.neg(neg_bit), 1.0)
    return ops.mul(x, pos_bit, km)


def max_(x: AShare, axis: int, key: jax.Array) -> AShare:
    """Tournament max along an axis: log2(n) comparison rounds."""
    n = x.shape[axis]
    cur = x
    i = 0
    while cur.shape[axis] > 1:
        m = cur.shape[axis]
        half = m // 2
        ax = axis + 1 if axis >= 0 else axis
        lo = AShare(jax.lax.slice_in_dim(cur.sh, 0, half, axis=ax), x.ring)
        hi = AShare(jax.lax.slice_in_dim(cur.sh, half, 2 * half, axis=ax), x.ring)
        kb, km, key = jax.random.split(jax.random.fold_in(key, i), 3)
        b = le(lo, hi, kb)                      # [lo < hi]
        diff = ops.sub(hi, lo)
        mx = ops.add(lo, ops.mul(b, diff, km))  # lo + b*(hi-lo)
        if m % 2:
            tail = AShare(jax.lax.slice_in_dim(cur.sh, 2 * half, m, axis=ax), x.ring)
            mx = ops.concat([mx, tail], axis=axis)
        cur = mx
        i += 1
    return cur
