"""Flight fusion — round compression of the executed MPC stream (§4.4).

The eager op stream pays one wire flight per opening: every Beaver
`(eps, delta)` open is a round, and on the RING32/TPU ring every
fixed-point truncation adds a dealer round on top. But most of those
flights carry messages that are *locally computable before the flight
departs*: Beaver mask differences (`x - a`) and dealer-masked values
(`z + r`) are functions of dealer randomness plus values the party
already holds, with any dependence on previously-opened values entering
only through PUBLIC reconstructions both parties can apply after the
fact. Every opening in such a group can therefore ride ONE simultaneous
message flight — rounds are paid once per group, bytes are unchanged.

This module is the batcher that realizes that compression in the
accounting layer while leaving the share arithmetic bit-for-bit
untouched:

  flight_scope()     installs a FlightBatcher: every bandwidth-bound
                     1-round opening recorded through `comm.record`
                     (Beaver opens, dealer `trunc_open`s, reveals) is
                     DEFERRED instead of landing in the Ledger.
  fused_group(lbl)   an explicit independence annotation: flushes the
                     ambient segment, then flushes the group's own
                     openings as one named flight (`fused.<lbl>`).
  barriers           latency-bound flights (secure comparisons) need
                     real interaction, so a "lat" record flushes the
                     pending segment before it lands — fusion never
                     reorders a comparison past the opens it consumes.
  lat_scope(lbl)     coalesces *independent* comparison batches (the
                     per-wave QuickSelect partitions) into one "lat"
                     flight: rounds paid once, bytes summed.

Legality: a group may share a flight iff no message in it depends on
another message of the same flight being received first. The argument
is per protocol backend (mpc/protocols/): additive-2PC chains of
mul/mul_public/trunc qualify under the deferred-reconstruction
convention above (parties exchange only mask components and apply the
public adjustments locally); replicated-3PC resharing messages are
locally computable before their flight departs, so independent groups
(qkv, ln_stats) batch identically — the batcher itself is
scheme-agnostic because every backend marks its deferrable flights
tag="bw". Comparisons never qualify — hence the barrier. Dealer
(tag="offline") records are not flights at all: they pass through to
the ledger's offline channel without flushing anything.

Everything here is accounting: the batcher intercepts `comm.record`
calls, so the PRNG key stream, the dealer triples, and every share an
op produces are identical to the eager path (asserted bitwise across
all variant sets in tests/test_fusion.py). `compress_events` replays an
analytic record stream through the same batcher, which is how
`costs.proxy_exec_cost(fused=True)` mirrors the fused stream
record-for-record without a second implementation of flush semantics.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Iterator

from repro.mpc import comm


# ---------------------------------------------------------------------------
# pending state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PendingOpen:
    """One deferred opening: the record it would have landed eagerly.

    `msgs` are the opening's captured wire messages (comm.WireMsg) when
    a WireTape is ambient — serialized at ABSORB time, while the share
    tensors are alive, and re-emitted on the fused flight so a fused
    group becomes ONE framed message per link carrying every deferred
    opening's bytes back to back."""
    op: str
    nbytes: int
    numel: int
    flops: int
    rounds: int = 1
    tag: str = "bw"
    msgs: tuple = ()


# NOTE: PR 3's `PendingShare` (the op-boundary pending-trunc container
# behind `lazy=True`) is retired: fixed-point scale is now a tracked
# property of `Share` itself (`Share.fb`, mpc/scale.py), so untruncated
# products flow through downstream ops as ordinary shares and
# `mpc/ops.force` is the one truncation point. This module is purely
# the flight batcher again.


# ---------------------------------------------------------------------------
# the batcher
# ---------------------------------------------------------------------------

class FlightBatcher:
    """Collects deferrable openings and flushes them as fused flights.

    Installed into the ambient comm state by `flight_scope`;
    `comm.record` offers every record via `absorb()` before it lands in
    the Ledger.
    """

    def __init__(self) -> None:
        self.pending: list[PendingOpen] = []
        self.pending_lat: list[PendingOpen] = []
        self._label: str | None = None
        self._lat_label: str | None = None
        self._in_lat_group = False
        self._suspended = False
        self.n_flights = 0            # fused bw flights emitted
        self.n_lat_flights = 0
        self.n_deferred = 0           # openings absorbed

    # -- interception ----------------------------------------------------
    def absorb(self, op: str, rounds: int, nbytes: int, numel: int,
               flops: int, tag: str, payload=None) -> bool:
        """Offer one record. True -> deferred (caller must not ledger it);
        False -> caller records eagerly (after any barrier flush).

        When a WireTape is ambient the deferred opening's payload is
        serialized HERE (the tensors are only guaranteed alive at absorb
        time) and carried on the PendingOpen until the flush emits it."""
        if self._suspended:
            return False
        if tag == "offline":
            # dealer bytes never ride the online wire: not a flight, not
            # a barrier — land in the ledger's offline channel as-is
            return False
        tape = comm.get_wire_tape()
        msgs = comm.normalize_payload(payload, nbytes, rounds,
                                      tape.n_parties) if tape is not None \
            else ()
        if tag == "lat":
            if self._in_lat_group:
                self.pending_lat.append(
                    PendingOpen(op, nbytes, numel, flops, rounds, tag,
                                msgs))
                self.n_deferred += 1
                return True
            # comparisons are real interaction: barrier, then pass through
            self.flush()
            return False
        if tag == "bw" and rounds <= 1:
            # rounds == 0: a piggyback message (3pc trunc re-replication)
            # that rides whatever flight the segment flushes as
            self.pending.append(PendingOpen(op, nbytes, numel, flops,
                                            rounds, "bw", msgs))
            self.n_deferred += 1
            return True
        self.flush()                  # unknown multi-round op: be safe
        return False

    # -- flushing --------------------------------------------------------
    def _emit(self, op: str, rounds: int, batch: list[PendingOpen],
              tag: str) -> None:
        nbytes = sum(p.nbytes for p in batch)
        numel = sum(p.numel for p in batch)
        flops = sum(p.flops for p in batch)
        # fused flight payload: every deferred opening's messages, in
        # deferral order — the PartyRuntime frames them as ONE message
        # per link (only meaningful when a WireTape was ambient at
        # absorb time; empty tuples merge to an empty payload -> None)
        msgs = [m for p in batch for m in p.msgs]
        self._suspended = True        # don't re-absorb our own flush
        try:
            comm.record(op, rounds=rounds, nbytes=nbytes, numel=numel,
                        flops=flops, tag=tag, payload=msgs or None)
        finally:
            self._suspended = False

    def flush(self, label: str | None = None) -> None:
        """Emit the pending segment as ONE flight (no-op when empty).
        A segment of only piggyback records (rounds 0) flushes at 0
        rounds — fusing must never create a round eager mode didn't pay."""
        if self.pending:
            batch, self.pending = self.pending, []
            self._emit(f"fused.{label or self._label or 'flight'}",
                       max(p.rounds for p in batch), batch, "bw")
            self.n_flights += 1

    def flush_lat(self, label: str | None = None) -> None:
        """Emit coalesced comparison batches as ONE latency flight —
        rounds are the protocol's (paid once), bytes are summed."""
        if self.pending_lat:
            batch, self.pending_lat = self.pending_lat, []
            rounds = max(p.rounds for p in batch)
            self._emit(f"fused.{label or self._lat_label or 'cmp'}",
                       rounds, batch, "lat")
            self.n_lat_flights += 1

    # -- group scopes ----------------------------------------------------
    @contextlib.contextmanager
    def fused_group(self, label: str) -> Iterator[None]:
        """One independent op group = one flight: close the ambient
        segment on entry, flush the group's own openings on exit."""
        self.flush()
        prev = self._label
        self._label = label
        try:
            yield
        finally:
            self.flush(label)
            self._label = prev

    @contextlib.contextmanager
    def lat_group(self, label: str) -> Iterator[None]:
        prev, prev_lbl = self._in_lat_group, self._lat_label
        self._in_lat_group, self._lat_label = True, label
        try:
            yield
        finally:
            self.flush_lat(label)
            self._in_lat_group, self._lat_label = prev, prev_lbl


# ---------------------------------------------------------------------------
# ambient scopes
# ---------------------------------------------------------------------------

def get_batcher() -> FlightBatcher | None:
    return comm.get_batcher()


@contextlib.contextmanager
def flight_scope(enabled: bool = True) -> Iterator[FlightBatcher | None]:
    """Round-compress every opening recorded inside. Nesting installs a
    fresh batcher (the inner scope flushes at its own boundary)."""
    if not enabled:
        yield None
        return
    fb = FlightBatcher()
    prev = comm.set_batcher(fb)
    try:
        yield fb
    finally:
        fb.flush()
        fb.flush_lat()
        comm.set_batcher(prev)


def fused_group(label: str):
    """Annotate a group of independent ops: one flight when a batcher is
    ambient, a no-op otherwise (the eager path stays eager)."""
    fb = get_batcher()
    return fb.fused_group(label) if fb is not None else \
        contextlib.nullcontext()


@contextlib.contextmanager
def lat_scope(label: str) -> Iterator[None]:
    """Coalesce independent comparison batches into one lat flight.

    Self-sufficient: installs a scoped batcher when none is ambient, so
    QuickSelect's per-wave partitions compress without requiring the
    caller to open a full flight_scope.
    """
    fb = get_batcher()
    if fb is not None:
        with fb.lat_group(label):
            yield
        return
    with flight_scope() as fb:
        with fb.lat_group(label):
            yield


def barrier() -> None:
    """Force the pending segment onto the wire (dependency boundary)."""
    fb = get_batcher()
    if fb is not None:
        fb.flush()


# ---------------------------------------------------------------------------
# analytic replay (the costs.py mirror)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupBegin:
    label: str


class GroupEnd:
    pass


GROUP_END = GroupEnd()


def compress_events(events) -> comm.Ledger:
    """Replay an eager record stream (CostRecords interleaved with
    GroupBegin/GROUP_END markers) through a FlightBatcher.

    This IS the analytic mirror's fusion step: flush semantics exist
    once, here, so `costs.proxy_exec_cost(fused=True)` and the executed
    stream can only diverge if the event stream itself is wrong — which
    the record-for-record tests catch.

    The replay is hermetic: it opens its own ledger and pins the wave
    multiplier to 1, so calling the analytic mirror from inside a
    `comm.wave_scope` (e.g. executor instrumentation) cannot inflate
    the per-batch records it predicts.
    """
    with comm.ledger_scope() as led:
        # hermetic also against wire capture: the replay is an analytic
        # mirror, not an execution — it must never append to an ambient
        # WireTape
        with comm.wave_scope(1), comm.wire_tape_scope(None), \
                flight_scope() as fb:
            for e in events:
                if isinstance(e, GroupBegin):
                    fb.flush()
                    fb._label = e.label
                elif isinstance(e, GroupEnd):
                    fb.flush(fb._label)
                    fb._label = None
                else:
                    comm.record(e.op, rounds=e.rounds, nbytes=e.nbytes,
                                numel=e.numel, flops=e.flops, tag=e.tag)
    return led
