"""Pluggable secret-sharing protocol backends.

The MPC substrate used to assume 2-party additive sharing with a
trusted dealer everywhere — share layout, Beaver triples, truncation
pairs, the `2 * elem_bytes` opening wire model were baked into every
file. This package makes the scheme a backend:

  additive2pc   semi-honest 2PC, CrypTen trust model: a trusted dealer
                (crypto provider) ships Beaver triples and truncation
                pairs ahead of time — their bytes land in the ledger's
                OFFLINE channel (tag="offline", priced separately from
                the online wire).
  replicated3pc honest-majority 3PC, 2-out-of-3 replicated sharing
                (ABY3-style): multiplication is local cross-terms plus
                a correlated-PRNG zero-share resharing flight, and
                truncation is probabilistic and local — NO dealer, zero
                offline bytes.
  spdz2pc       MALICIOUS-security 2PC: SPDZ-style MAC'd additive
                shares (4 leading-axis rows: value + MAC components),
                sacrifice-authenticated Beaver triples, partial opens
                with a batched boundary MAC check, dealer truncation on
                BOTH rings — tampering aborts (MacCheckError).
  aby3trunc     replicated3pc with ABY3's EXACT two-phase `trunc2` in
                place of the probabilistic regrouped shift: <= 1 ulp
                always, zero wraps, 2 rounds per forced truncation.

A backend owns exactly the operations where the schemes differ:

  n_parties      leading component-axis size of every `Share` (4 for
                 spdz2pc: 2 value + 2 MAC rows)
  share_encoded  layout of a fresh sharing (uniform components)
  from_public    trivial sharing of a public ring element
  open_bytes     wire bytes to open n elements
  reconstruct    value from stacked components (MAC'd schemes also
                 enqueue a check obligation)
  add_public_encoded  affine constant injection (MAC rows update too)
  mul / matmul   ring multiplication incl. its wire flights
  trunc          fixed-point truncation after a product

Flight legality is per-backend: additive-2PC openings fuse under the
deferred-reconstruction convention (messages are mask components,
public corrections applied after the flight; see mpc/fusion.py), and
replicated-3PC resharing messages are locally computable before their
flight departs, so independent groups batch the same way. Both mark
their flights tag="bw"; the batcher needs no scheme-specific code.

Everything above this layer (`ops`, `compare`, `nonlinear`, the
engines, the executor, the analytic mirror) is protocol-generic and
routes through `get(name)`.
"""
from __future__ import annotations

from repro.mpc.protocols.base import BackendDefaults, ProtocolBackend
from repro.mpc.protocols.additive2pc import Additive2PC
from repro.mpc.protocols.replicated3pc import Replicated3PC
from repro.mpc.protocols.spdz2pc import SPDZ2PC
from repro.mpc.protocols.aby3trunc import ABY3Trunc

PROTOCOLS: dict[str, ProtocolBackend] = {
    "2pc": Additive2PC(),
    "3pc": Replicated3PC(),
    "spdz2pc": SPDZ2PC(),
    "aby3trunc": ABY3Trunc(),
}


def get(name: str) -> ProtocolBackend:
    try:
        return PROTOCOLS[name]
    except KeyError:
        raise ValueError(
            f"unknown protocol {name!r} (expected one of "
            f"{sorted(PROTOCOLS)})") from None


__all__ = ["ProtocolBackend", "BackendDefaults", "Additive2PC",
           "Replicated3PC", "SPDZ2PC", "ABY3Trunc", "PROTOCOLS", "get"]
