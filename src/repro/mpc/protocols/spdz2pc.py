"""SPDZ-style MAC'd additive 2PC — the malicious-security tier.

Share layout: FOUR rows on the leading axis — two value components and
two MAC components under the dealer's global key alpha:

    sh[0] + sh[1] = value          (mod 2**bits)
    sh[2] + sh[3] = alpha * value  (mod 2**bits)

Every linear op in `mpc/ops` is automatically MAC-transparent (the MAC
relation is linear in the value), the two affine exceptions
(`reconstruct`, `add_public_encoded`) dispatch here, and the scheme's
own ops below maintain the invariant through Beaver multiplication and
dealer-assisted truncation.

Trust model (SPDZ with a trusted dealer for preprocessing): the dealer
ships MAC'd correlated randomness on the PR 4 offline channel — each
tensor now costs 4 components (value + MAC, both parties), so offline
bytes double versus semi-honest 2PC. Online, the parties can deviate
arbitrarily: correctness is enforced by information-theoretic MACs.

Openings are PARTIAL — parties exchange only value components (the same
`2 * elem_bytes` wire profile as semi-honest 2PC; MAC components never
ride the wire). Each partial open enqueues a deferred check obligation

    sigma = (sh[2] + sh[3]) - alpha * opened_value

into the ambient `mac_scope` state; all obligations are verified by ONE
batched random-linear-combination check at the forward's public
boundary (`mac_check_flight`, invoked by `MPCEngine.entropy_head`) —
constant-size regardless of how many values were opened, recorded as a
1-round tag="bw" flight so the batcher fuses it like any Beaver open,
plus the dealer's one-time MAC-key shipment on the offline channel.

Triples are authenticated by SACRIFICE: each multiply consumes a second
dealer triple and burns it in a 1-round correlation check (t*a - a'
style), so a cheating dealer-channel or a tampered triple is caught
before its product is used. The sacrifice opening is a mask-component
flight — fusible under the deferred-reconstruction convention exactly
like the Beaver open it precedes.

Truncation: local shifting is NOT MAC-preserving (and a malicious party
could shift dishonestly), so BOTH rings pay the dealer trunc pair + one
opening round — the semi-honest RING64 free local shift is one of the
costs malicious security visibly buys back (`bench_fusion`'s overhead
curve).

Tamper injection (tests only): `tamper_scope(fn)` installs a fire-once
hook applied to the next stacked share tensor entering a partial open —
the adversary's one bit flip. The subsequent MAC check aborts with
`MacCheckError`; the semi-honest backends accept the same tamper
silently (pinned by tests/test_conformance.py).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec, RING32, RING64
from repro.mpc import comm
from repro.mpc.protocols.base import BackendDefaults, numel

_MAC_SEED = 0xA1C


class MacCheckError(AssertionError):
    """A batched SPDZ MAC check failed: an opened value was tampered."""


_state = threading.local()


def _ring_of(sh: jax.Array) -> RingSpec:
    """Recover the RingSpec from a stacked share's dtype (the affine
    hooks receive raw arrays; only two rings exist)."""
    return RING64 if jnp.dtype(sh.dtype).itemsize == 8 else RING32


def mac_key(ring: RingSpec):
    """The dealer's global MAC key alpha and its additive split
    (alpha0 + alpha1 = alpha). Deterministic per ring — the simulation
    stands in for the dealer's one-time key generation; its shipment to
    the parties is priced by `mac_check_flight` (offline.mac_key)."""
    k = jax.random.key(_MAC_SEED + ring.bits)
    alpha = ring.rand(k, ())
    a0 = ring.rand(jax.random.fold_in(k, 1), ())
    return alpha, a0, alpha - a0


# ---------------------------------------------------------------------------
# deferred MAC-check state + the test-only tamper hook
# ---------------------------------------------------------------------------

class MacState:
    """Deferred MAC-check obligations of one verification scope.

    Each partial open appends sigma = gamma_sum - alpha * opened; honest
    executions keep every sigma identically zero. Obligations produced
    under a trace (vmap/eval_shape tracers) cannot be checked eagerly
    and are counted in `n_traced` instead — the executed tamper tests
    run the forward eagerly, where every sigma is concrete."""

    def __init__(self) -> None:
        self.sigmas: list[tuple[str, jax.Array]] = []
        self.n_opened = 0
        self.n_traced = 0

    def verify(self) -> None:
        """The batched check: abort on any nonzero sigma."""
        import numpy as np
        for op, sg in self.sigmas:
            if bool(np.any(np.asarray(sg) != 0)):
                raise MacCheckError(
                    f"spdz2pc MAC check failed on {op!r}: an opened value "
                    f"or its MAC was tampered with — aborting")
        self.sigmas.clear()


@contextlib.contextmanager
def mac_scope() -> Iterator[MacState]:
    """Collect MAC obligations for every partial open inside; verify via
    `MacState.verify()` (the engine boundary calls it automatically
    through `mac_check_flight`)."""
    prev = getattr(_state, "mac", None)
    st = MacState()
    _state.mac = st
    try:
        yield st
    finally:
        _state.mac = prev


def get_mac_state() -> MacState | None:
    return getattr(_state, "mac", None)


@contextlib.contextmanager
def tamper_scope(fn) -> Iterator[None]:
    """TEST-ONLY adversary: `fn(stacked) -> stacked` is applied ONCE to
    the next share tensor entering a partial open (rows 0/1 = value
    components, rows 2/3 = MAC components — flip a bit in either)."""
    prev = getattr(_state, "tamper", None)
    _state.tamper = {"fn": fn, "fired": False}
    try:
        yield
    finally:
        _state.tamper = prev


def _maybe_tamper(sh: jax.Array) -> jax.Array:
    t = getattr(_state, "tamper", None)
    if t is None or t["fired"]:
        return sh
    t["fired"] = True
    return t["fn"](sh)


def _note_open(op: str, opened: jax.Array, gamma: jax.Array,
               ring: RingSpec) -> None:
    st = get_mac_state()
    if st is None:
        return
    st.n_opened += 1
    if isinstance(opened, jax.core.Tracer) or isinstance(gamma,
                                                         jax.core.Tracer):
        st.n_traced += 1
        return
    alpha, _, _ = mac_key(ring)
    st.sigmas.append((op, gamma - alpha * opened))


# ---------------------------------------------------------------------------
# the MAC'd dealer
# ---------------------------------------------------------------------------

def _share_mac(key: jax.Array, enc: jax.Array, ring: RingSpec) -> jax.Array:
    """(4, *shape): additive split of enc stacked on an additive split
    of alpha * enc."""
    alpha, _, _ = mac_key(ring)
    kx, km = jax.random.split(key)
    rx = ring.rand(kx, enc.shape)
    rm = ring.rand(km, enc.shape)
    gm = alpha * enc
    return jnp.stack([rx, enc - rx, rm, gm - rm])


def _record_offline_mac(op: str, ring: RingSpec, n_elems: int) -> None:
    """Dealer-shipped MAC'd correlated randomness: each of n_elems ring
    elements costs 4 components (value + MAC, both parties) — double
    the semi-honest dealer's bytes, the offline price of authentication."""
    comm.record(op, rounds=0, nbytes=4 * ring.elem_bytes * n_elems,
                numel=n_elems, tag="offline")


def _mac_mul_triple(key: jax.Array, shape, ring: RingSpec):
    ka, kb, k1, k2, k3 = jax.random.split(key, 5)
    a = ring.rand(ka, shape)
    b = ring.rand(kb, shape)
    c = a * b
    _record_offline_mac("offline.mul_triple", ring, 3 * numel(shape))
    return (_share_mac(k1, a, ring), _share_mac(k2, b, ring),
            _share_mac(k3, c, ring))


def _mac_matmul_triple(key: jax.Array, a_shape, b_shape, ring: RingSpec):
    ka, kb, k1, k2, k3 = jax.random.split(key, 5)
    a = ring.rand(ka, a_shape)
    b = ring.rand(kb, b_shape)
    c = jnp.matmul(a, b, preferred_element_type=ring.dtype)
    _record_offline_mac("offline.matmul_triple", ring,
                        numel(a_shape) + numel(b_shape) + numel(c.shape))
    return (_share_mac(k1, a, ring), _share_mac(k2, b, ring),
            _share_mac(k3, c, ring))


# ---------------------------------------------------------------------------
# the backend
# ---------------------------------------------------------------------------

class SPDZ2PC(BackendDefaults):
    name = "spdz2pc"
    # leading-axis size of Share.sh: 2 value + 2 MAC rows. Everything
    # generic (abstract_shares, the executor's reshape, vmap) treats it
    # as an opaque component count.
    n_parties = 4
    # ... but the WIRE has exactly 2 physical parties: rows are p0/p1
    # value + p0/p1 MAC, and partial opens exchange value rows only
    # (BackendDefaults.open_msgs already routes rows 0<->1)
    n_wire_parties = 2
    # dealer MAC'd trunc pairs are exact at any shift/exponent, so the
    # scale lattice may defer up to the ring-wide 3f headroom cap
    exact_trunc = True

    # -- sharing --------------------------------------------------------
    def share_encoded(self, key: jax.Array, enc: jax.Array,
                      ring: RingSpec) -> jax.Array:
        return _share_mac(key, enc, ring)

    def from_public(self, enc: jax.Array) -> jax.Array:
        ring = _ring_of(enc)
        _, a0, a1 = mac_key(ring)
        z = jnp.zeros_like(enc)
        return jnp.stack([enc, z, a0 * enc, a1 * enc])

    def open_bytes(self, ring: RingSpec, n: int) -> int:
        # PARTIAL open: value components only — MACs stay secret
        return 2 * ring.elem_bytes * n

    # -- affine hooks (MAC rows are not value components) ---------------
    def reconstruct(self, sh: jax.Array) -> jax.Array:
        ring = _ring_of(sh)
        sh = _maybe_tamper(sh)
        v = sh[0] + sh[1]
        _note_open("open", v, sh[2] + sh[3], ring)
        return v

    def add_public_encoded(self, sh: jax.Array, enc: jax.Array) -> jax.Array:
        ring = _ring_of(sh)
        _, a0, a1 = mac_key(ring)
        b = jnp.broadcast_to(enc, sh.shape[1:])
        return jnp.stack([sh[0] + b, sh[1], sh[2] + a0 * b, sh[3] + a1 * b])

    # -- openings -------------------------------------------------------
    def _open_flight(self, op: str, tensors, ring: RingSpec, *, n: int,
                     flops: int = 0):
        """Partially open masked tensors in ONE flight (value components
        only — same wire bytes as semi-honest 2PC) and enqueue each
        tensor's MAC obligation for the batched boundary check."""
        wire_elems = sum(numel(t.shape[1:]) for t in tensors)
        comm.record(op, rounds=1, nbytes=2 * ring.elem_bytes * wire_elems,
                    numel=n, flops=flops, tag="bw",
                    payload=[(p, 1 - p, t[p])
                             for t in tensors for p in (0, 1)])
        out = []
        for t in tensors:
            t = _maybe_tamper(t)
            v = t[0] + t[1]
            _note_open(op, v, t[2] + t[3], ring)
            out.append(v)
        return tuple(out)

    # -- truncation -----------------------------------------------------
    def trunc(self, x, key: jax.Array | None, *, shift: int | None = None):
        """Dealer-assisted MAC'd truncation — BOTH rings.

        Local shifting is not MAC-preserving (alpha*(x >> s) has no
        local relation to (alpha*x) >> s) and would let a malicious
        party shift dishonestly, so the semi-honest RING64 free local
        path does not exist here: every forced truncation opens x + r
        (partially) and rebuilds from the dealer's MAC'd (r, r >> shift)
        pair. One opening round + 2 MAC'd tensors of offline bytes per
        force, any shift — the malicious overhead curve's RING64 story.
        """
        ring = x.ring
        if key is None:
            raise ValueError(
                "spdz2pc truncation requires a PRNG key: there is no "
                "MAC-preserving local-shift path (the engine threads a "
                "key through every force site)")
        shift = ring.frac_bits if shift is None else shift
        out_fb = x.fb - shift
        n = numel(x.shape)
        kr, k1, k2 = jax.random.split(key, 3)
        utype = jnp.uint32 if ring.bits == 32 else jnp.uint64
        # r from the "safe" range [0, 2**(bits-2)) to avoid sign wrap
        r = (ring.rand(kr, x.shape).astype(utype) >> 2).astype(ring.dtype)
        r_t = r >> shift
        rsh = _share_mac(k1, r, ring)
        rtsh = _share_mac(k2, r_t, ring)
        _record_offline_mac("offline.trunc_pair", ring, 2 * n)
        (m,) = self._open_flight("trunc_open", (x.sh + rsh,), ring, n=n)
        m_t = m >> shift
        _, a0, a1 = mac_key(ring)
        out = jnp.stack([m_t - rtsh[0], -rtsh[1],
                         a0 * m_t - rtsh[2], a1 * m_t - rtsh[3]])
        return x.with_scale(out, out_fb)

    # -- multiplication -------------------------------------------------
    def _sacrifice(self, op: str, ring: RingSpec, n_triple: int,
                   n_open: int, wire_elems: int) -> None:
        """Burn a second dealer triple to authenticate the first: the
        parties open t*a - a' (and the matching c-correlation) masked
        components — 1 fusible round, and the sacrificed triple's MAC'd
        bytes on the offline channel."""
        _record_offline_mac(f"offline.sacrifice_{op}", ring, n_triple)
        comm.record("sacrifice", rounds=1,
                    nbytes=2 * ring.elem_bytes * wire_elems,
                    numel=n_open, tag="bw")

    def mul(self, x, y, key: jax.Array):
        """Authenticated Beaver multiply: sacrifice flight + (eps, delta)
        partial open; MAC rows recombine with the split of alpha on the
        public eps*delta term. Raw product — `mpc/ops.py` owns scale."""
        ring = x.ring
        shape = jnp.broadcast_shapes(x.shape, y.shape)
        xb = jnp.broadcast_to(x.sh, (4,) + shape)
        yb = jnp.broadcast_to(y.sh, (4,) + shape)
        n = numel(shape)
        a4, b4, c4 = _mac_mul_triple(key, shape, ring)
        self._sacrifice("triple", ring, 3 * n, n, 2 * n)
        eps, dlt = self._open_flight("beaver_mul", (xb - a4, yb - b4), ring,
                                     n=n, flops=4 * n)
        _, a0, a1 = mac_key(ring)
        ed = eps * dlt
        z = c4 + eps * b4 + dlt * a4
        z = z.at[0].add(ed)
        z = z.at[2].add(a0 * ed)
        z = z.at[3].add(a1 * ed)
        return x.with_sh(z)

    def matmul(self, x, y, key: jax.Array, *,
               combine_impl: str | None = None):
        """Authenticated Beaver matmul (same input-proportional wire
        profile as semi-honest 2PC, plus the sacrifice flight and the
        doubled MAC'd triple bytes offline). `combine_impl` is the
        semi-honest 2-row kernel knob and is ignored."""
        ring = x.ring
        a4, b4, c4 = _mac_matmul_triple(key, x.shape, y.shape, ring)
        na, nb = numel(x.shape), numel(y.shape)
        nc = numel(c4.shape[1:])
        self._sacrifice("matmul_triple", ring, na + nb + nc, na + nb,
                        na + nb)
        m, k = x.shape[-2], x.shape[-1]
        n_out = y.shape[-1]
        batch = numel(x.shape[:-2])
        eps, dlt = self._open_flight("beaver_matmul",
                                     (x.sh - a4, y.sh - b4), ring,
                                     n=na + nb,
                                     flops=2 * batch * m * k * n_out)
        eb = jnp.matmul(jnp.broadcast_to(eps, (4,) + eps.shape), b4,
                        preferred_element_type=ring.dtype)
        ad = jnp.matmul(a4, jnp.broadcast_to(dlt, (4,) + dlt.shape),
                        preferred_element_type=ring.dtype)
        z = c4 + eb + ad
        ed = jnp.matmul(eps, dlt, preferred_element_type=ring.dtype)
        _, a0, a1 = mac_key(ring)
        z = z.at[0].add(ed)
        z = z.at[2].add(a0 * ed)
        z = z.at[3].add(a1 * ed)
        return x.with_sh(z)

    # -- the boundary check ---------------------------------------------
    def mac_check_flight(self, ring: RingSpec) -> None:
        """Batched MAC check at the forward's public boundary (invoked
        by `MPCEngine.entropy_head`). Constant-size regardless of how
        many values were opened — the parties commit-and-open ONE random
        linear combination of their sigma shares: 1 fusible bw round,
        plus the dealer's one-time MAC-key shipment (offline). When a
        `mac_scope` is ambient, the deferred obligations are verified
        here — a tampered execution aborts at its output."""
        comm.record("offline.mac_key", rounds=0,
                    nbytes=2 * ring.elem_bytes, numel=1, tag="offline")
        comm.record("mac_check", rounds=1, nbytes=4 * ring.elem_bytes,
                    numel=1, tag="bw")
        st = get_mac_state()
        if st is not None:
            st.verify()
