"""The protocol-backend interface every sharing scheme implements.

`Share` values carry their backend's name (`Share.proto`); the generic
layers (`mpc/ops`, `mpc/compare`, `mpc/nonlinear`, the engines) look the
backend up per value and delegate every scheme-dependent operation here.
Backends are stateless singletons — randomness always arrives as an
explicit PRNG key so executions stay reproducible across schedule
variants.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax

from repro.mpc.ring import RingSpec


def numel(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


@runtime_checkable
class ProtocolBackend(Protocol):
    """Scheme-dependent share operations.

    `mul`/`matmul` consume and produce `sharing.Share` values and record
    their own wire flights (and, for dealer-based schemes, their offline
    bytes) into the ambient ledger; `trunc` implements the scheme's
    fixed-point truncation. Everything linear is protocol-generic and
    lives in `mpc/ops`.
    """

    name: str                     # registry key, also Share.proto
    n_parties: int                # leading party-axis size of Share.sh

    def share_encoded(self, key: jax.Array, enc: jax.Array,
                      ring: RingSpec) -> jax.Array:
        """(n_parties, *enc.shape) uniform components summing to enc."""
        ...

    def from_public(self, enc: jax.Array) -> jax.Array:
        """Trivial sharing of a public ring element."""
        ...

    def open_bytes(self, ring: RingSpec, n: int) -> int:
        """Wire bytes for opening n ring elements (1 round)."""
        ...

    def mul(self, x, y, key: jax.Array):
        """Elementwise secure multiply (broadcasting). Returns the RAW
        product — scale bookkeeping (the summed exponent, any forced
        input truncation) lives in `mpc/ops.py`."""
        ...

    def matmul(self, x, y, key: jax.Array, *,
               combine_impl: str | None = None):
        """Batched secure matmul (raw product; see `mul`)."""
        ...

    def trunc(self, x, key: jax.Array | None, *, shift: int | None = None):
        """Divide by 2**shift (default: frac_bits, one canonical scale)
        and lower the carried exponent accordingly — the generalized
        `trunc(shift=)` that resolves any accumulated excess in one op."""
        ...
