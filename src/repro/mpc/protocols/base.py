"""The protocol-backend interface every sharing scheme implements.

`Share` values carry their backend's name (`Share.proto`); the generic
layers (`mpc/ops`, `mpc/compare`, `mpc/nonlinear`, the engines) look the
backend up per value and delegate every scheme-dependent operation here.
Backends are stateless singletons — randomness always arrives as an
explicit PRNG key so executions stay reproducible across schedule
variants.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec


def numel(shape) -> int:
    n = 1
    for d in shape:
        n *= int(d)
    return n


class BackendDefaults:
    """Default implementations of the affine share transforms that most
    schemes share: reconstruction is the plain sum over the leading
    axis, and a public constant lands on component 0 (`from_public`'s
    convention). Schemes whose leading axis carries NON-value rows — the
    MAC components of spdz2pc — override both: summing all rows there
    would yield value + alpha*value, and a constant must also update the
    MAC rows to keep the authenticated invariant.

    `n_wire_parties` is the number of PHYSICAL protocol parties on the
    wire — the process count `net.PartyRuntime` spawns. It equals
    `n_parties` for plain schemes but NOT for MAC'd ones (spdz2pc stacks
    4 share rows across 2 parties), which is why the wire layer must
    never size itself off the component axis.
    """

    n_wire_parties = 2

    # True when `trunc(shift=)` is EXACT at any shift and any carried
    # exponent (dealer pair / trunc2 subprotocol). Probabilistic local
    # truncation (additive2pc's RING64 shift, replicated3pc's
    # regrouping) wraps a share with probability ~ encoded/2**bits per
    # element — tolerable in the validated 2f regime, 2**f times worse
    # at a 3f exponent — so only exact-trunc backends may defer under
    # the ring-wide 3f headroom cap (ops._headroom_bits).
    exact_trunc = False

    def reconstruct(self, sh: jax.Array) -> jax.Array:
        out = sh[0]
        for i in range(1, sh.shape[0]):
            out = out + sh[i]
        return out

    def add_public_encoded(self, sh: jax.Array, enc: jax.Array) -> jax.Array:
        return sh.at[0].add(jnp.broadcast_to(enc, sh.shape[1:]))

    def open_msgs(self, sh: jax.Array):
        """The messages an opening of `sh` puts on the wire, as
        (src, dst, tensor) entries for `comm.record(payload=...)` —
        MUST serialize to exactly `open_bytes` bytes. Default: the
        2-party duplex exchange of value components (rows 0 and 1 —
        also correct for spdz2pc, whose partial opens send value rows
        only)."""
        return [(0, 1, sh[0]), (1, 0, sh[1])]

    def dealer_material(self, rng, op: str, ring: RingSpec, elems: int):
        """Synthesize `elems` ring elements of dealer (offline-channel)
        material for offline op `op` — the bytes a crypto provider
        streams ahead of the phase. Every offline record (Beaver/
        sacrifice triples, truncation pairs, MAC keys) already counts
        its TOTAL element footprint in `numel`, so one uniform draw of
        that many ring elements is shape-correct for all of them. The
        serve/ dealer pool pre-generates these on a worker thread;
        dealer-free schemes (replicated 3pc) never place an order.

        `rng` is a numpy Generator — pool material is pre-staged bytes,
        deliberately OUTSIDE the execution's jax PRNG stream (online
        values stay key-derived, so scores are driver-invariant)."""
        if elems <= 0:
            return None
        udt = {32: "uint32", 64: "uint64"}[ring.bits]
        buf = rng.integers(0, (1 << ring.bits) - 1, size=int(elems),
                           dtype=udt, endpoint=True)
        return buf.view(f"int{ring.bits}")


@runtime_checkable
class ProtocolBackend(Protocol):
    """Scheme-dependent share operations.

    `mul`/`matmul` consume and produce `sharing.Share` values and record
    their own wire flights (and, for dealer-based schemes, their offline
    bytes) into the ambient ledger; `trunc` implements the scheme's
    fixed-point truncation. Everything linear is protocol-generic and
    lives in `mpc/ops` — with two affine exceptions (`reconstruct`,
    `add_public_encoded`) that dispatch here because MAC'd schemes
    interpret their extra leading-axis rows differently.

    Backends targeting MALICIOUS security may additionally expose
    `mac_check_flight(ring)`: the engine calls it once at the forward's
    public boundary (`MPCEngine.entropy_head`) to price — and, when a
    verification scope is ambient, run — the batched MAC check.
    """

    name: str                     # registry key, also Share.proto
    n_parties: int                # leading party-axis size of Share.sh
    n_wire_parties: int           # physical parties on the wire (net/)

    def share_encoded(self, key: jax.Array, enc: jax.Array,
                      ring: RingSpec) -> jax.Array:
        """(n_parties, *enc.shape) uniform components summing to enc."""
        ...

    def from_public(self, enc: jax.Array) -> jax.Array:
        """Trivial sharing of a public ring element."""
        ...

    def open_bytes(self, ring: RingSpec, n: int) -> int:
        """Wire bytes for opening n ring elements (1 round)."""
        ...

    def reconstruct(self, sh: jax.Array) -> jax.Array:
        """Value from the stacked components (the functionality-boundary
        reconstruction; MAC'd schemes also enqueue a check obligation)."""
        ...

    def add_public_encoded(self, sh: jax.Array, enc: jax.Array) -> jax.Array:
        """Add an already-encoded public constant to the sharing."""
        ...

    def mul(self, x, y, key: jax.Array):
        """Elementwise secure multiply (broadcasting). Returns the RAW
        product — scale bookkeeping (the summed exponent, any forced
        input truncation) lives in `mpc/ops.py`."""
        ...

    def matmul(self, x, y, key: jax.Array, *,
               combine_impl: str | None = None):
        """Batched secure matmul (raw product; see `mul`)."""
        ...

    def trunc(self, x, key: jax.Array | None, *, shift: int | None = None):
        """Divide by 2**shift (default: frac_bits, one canonical scale)
        and lower the carried exponent accordingly — the generalized
        `trunc(shift=)` that resolves any accumulated excess in one op."""
        ...
