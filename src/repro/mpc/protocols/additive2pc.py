"""Additive 2PC with a trusted dealer — the CrypTen trust model.

Share layout: two uniform additive components on the leading axis,
`sh[0] + sh[1] = value` (mod 2**bits). Multiplication consumes Beaver
triples and (on the TPU ring) truncation pairs from an offline dealer
(crypto provider): the dealer is a PRNG-keyed pure function so triples
are reproducible and jit-friendly; in deployment the dealer seed lives
on the crypto-provider host and shares are streamed ahead of the online
phase.

Every byte the dealer ships is recorded into the ambient ledger's
OFFLINE channel (`tag="offline"`, 0 rounds): it never rides the online
wire, is excluded from `Ledger.nbytes`/makespan, and is reported
separately (`Ledger.offline_nbytes`) — the cost axis on which the
dealer-free replicated3pc backend wins.

Online wire model: an opening flight carries both parties' components
of every tensor at once — 1 round, `2 * elem_bytes * elems` bytes.
These flights are fusible under the deferred-reconstruction convention
(mpc/fusion.py): messages are mask components (`x - a`, `z + r`)
computable before the flight departs, with dependence on previously
opened values entering only through PUBLIC reconstructions both parties
apply after the fact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec
from repro.mpc import comm
from repro.mpc.protocols.base import BackendDefaults, numel


def _share_raw(key: jax.Array, enc: jax.Array, ring: RingSpec) -> jax.Array:
    r = ring.rand(key, enc.shape)
    return jnp.stack([r, enc - r])


def _record_offline(op: str, ring: RingSpec, n_elems: int) -> None:
    """Dealer-shipped correlated randomness: n_elems ring elements,
    additively shared to both parties."""
    comm.record(op, rounds=0, nbytes=2 * ring.elem_bytes * n_elems,
                numel=n_elems, tag="offline")


# ---------------------------------------------------------------------------
# the dealer (re-exported by mpc/beaver.py for back-compat)
# ---------------------------------------------------------------------------

def mul_triple(key: jax.Array, shape, ring: RingSpec):
    """Elementwise triple: a*b = c (c at 2*frac scale — consumed pre-trunc)."""
    from repro.mpc.sharing import Share
    ka, kb, k1, k2, k3 = jax.random.split(key, 5)
    a = ring.rand(ka, shape)
    b = ring.rand(kb, shape)
    c = a * b   # ring product, wraps mod 2**bits
    _record_offline("offline.mul_triple", ring, 3 * numel(shape))
    return (Share(_share_raw(k1, a, ring), ring),
            Share(_share_raw(k2, b, ring), ring),
            Share(_share_raw(k3, c, ring), ring))


def matmul_triple(key: jax.Array, a_shape, b_shape, ring: RingSpec,
                  dimension_numbers=None):
    """Matrix triple A@B = C for arbitrary batched matmul shapes."""
    from repro.mpc.sharing import Share
    ka, kb, k1, k2, k3 = jax.random.split(key, 5)
    a = ring.rand(ka, a_shape)
    b = ring.rand(kb, b_shape)
    c = jnp.matmul(a, b, preferred_element_type=ring.dtype)
    _record_offline("offline.matmul_triple", ring,
                    numel(a_shape) + numel(b_shape) + numel(c.shape))
    return (Share(_share_raw(k1, a, ring), ring),
            Share(_share_raw(k2, b, ring), ring),
            Share(_share_raw(k3, c, ring), ring))


def trunc_pair(key: jax.Array, shape, ring: RingSpec,
               shift: int | None = None):
    """Dealer-assisted truncation pair (r, r >> shift) — SecureML-style.

    Exact (±1 LSB) truncation for the int32 TPU ring where local
    truncation's wrap probability is too high. `shift` defaults to one
    canonical scale (frac_bits); scale-carrying shares hand in their
    whole accumulated excess (e.g. f+5 after a folded mean) so ONE pair
    clears what eager mode paid as several.
    """
    from repro.mpc.sharing import Share
    shift = ring.frac_bits if shift is None else shift
    kr, k1, k2 = jax.random.split(key, 3)
    # r drawn from the "safe" range [0, 2**(bits-2)) to avoid sign wrap
    r = (ring.rand(kr, shape).astype(jnp.uint32 if ring.bits == 32 else jnp.uint64)
         >> 2).astype(ring.dtype)
    r_t = r >> shift             # arithmetic shift of non-negative r
    _record_offline("offline.trunc_pair", ring, 2 * numel(shape))
    return (Share(_share_raw(k1, r, ring), ring),
            Share(_share_raw(k2, r_t, ring), ring))


def triple_bytes(a_shape, b_shape, c_shape, ring: RingSpec) -> int:
    """Offline bytes the dealer ships for one triple (both parties)."""
    n = 0
    for s in (a_shape, b_shape, c_shape):
        n += numel(s)
    return 2 * ring.elem_bytes * n


# ---------------------------------------------------------------------------
# the backend
# ---------------------------------------------------------------------------

class Additive2PC(BackendDefaults):
    name = "2pc"
    n_parties = 2
    n_wire_parties = 2

    # -- sharing --------------------------------------------------------
    def share_encoded(self, key: jax.Array, enc: jax.Array,
                      ring: RingSpec) -> jax.Array:
        return _share_raw(key, enc, ring)

    def from_public(self, enc: jax.Array) -> jax.Array:
        return jnp.stack([enc, jnp.zeros_like(enc)])

    def open_bytes(self, ring: RingSpec, n: int) -> int:
        return 2 * ring.elem_bytes * n

    # -- openings -------------------------------------------------------
    def _open_flight(self, op: str, tensors, ring: RingSpec, *, n: int,
                     flops: int = 0):
        """Open masked share tensors in ONE simultaneous message flight.

        All tensors of a flight ride the same round trip (each party
        sends its shares of every tensor at once), so the flight costs
        1 round and 2 * elem_bytes * total-elements on the wire. This is
        the unit the wave executor schedules: under comm.wave_scope the
        flight's bytes scale with the wave while latency-bound flights
        keep their rounds.

        The record's payload IS the flight: party p's masked components
        of every tensor, routed to the peer — what `--wire` runs
        serialize onto the real transport (comm.WireTape).
        """
        wire_elems = sum(numel(t.shape[1:]) for t in tensors)
        comm.record(op, rounds=1, nbytes=2 * ring.elem_bytes * wire_elems,
                    numel=n, flops=flops, tag="bw",
                    payload=[(p, 1 - p, t[p])
                             for t in tensors for p in (0, 1)])
        return tuple(t[0] + t[1] for t in tensors)

    # -- truncation -----------------------------------------------------
    def trunc(self, x, key: jax.Array | None, *, shift: int | None = None):
        """Divide shares by 2**shift (default: one canonical scale).

        RING64 (or keyless boundary trunc): local arithmetic shift of
        both components — correct up to ±1 LSB w.p. 1 - |v|/2**(bits-1)
        per element (CrypTen's choice). RING32: dealer-assisted pair
        (exact): open (x+r), shift publicly, subtract the dealer's share
        of r>>shift. Costs one opening round plus the pair's offline
        bytes — the SAME cost for any shift, which is why folding a
        chain of deferred rescales into one trunc(shift=excess) is a
        straight win for the dealer channel."""
        ring = x.ring
        shift = ring.frac_bits if shift is None else shift
        out_fb = x.fb - shift
        if ring.bits >= 64 or key is None:
            s0 = x.sh[0] >> shift
            s1 = -((-x.sh[1]) >> shift)
            return x.with_scale(jnp.stack([s0, s1]), out_fb)
        # dealer-assisted exact truncation (TPU ring)
        r, r_t = trunc_pair(key, x.shape, ring, shift)
        masked = x.sh + r.sh
        m = masked[0] + masked[1]                # open
        comm.record("trunc_open", rounds=1,
                    nbytes=2 * ring.elem_bytes * numel(x.shape),
                    numel=numel(x.shape), tag="bw",
                    payload=[(0, 1, masked[0]), (1, 0, masked[1])])
        m_t = m >> shift
        pub = jnp.stack([m_t, jnp.zeros_like(m_t)])
        return x.with_scale(pub - r_t.sh, out_fb)

    # -- multiplication -------------------------------------------------
    def mul(self, x, y, key: jax.Array):
        """Beaver multiply. One opening round for (eps, delta); returns
        the raw product — `mpc/ops.py` owns the scale bookkeeping."""
        ring = x.ring
        shape = jnp.broadcast_shapes(x.shape, y.shape)
        xb = jnp.broadcast_to(x.sh, (2,) + shape)
        yb = jnp.broadcast_to(y.sh, (2,) + shape)
        a, b, c = mul_triple(key, shape, ring)
        eps = xb - a.sh
        dlt = yb - b.sh
        n = numel(shape)
        eps_o, dlt_o = self._open_flight("beaver_mul", (eps, dlt), ring,
                                         n=n, flops=4 * n)
        z = c.sh + eps_o * b.sh + dlt_o * a.sh
        z = z.at[0].add(eps_o * dlt_o)
        return x.with_sh(z)

    def matmul(self, x, y, key: jax.Array, *,
               combine_impl: str | None = None):
        """Beaver matrix-triple matmul. One opening round.

        Bytes on the wire: |eps| + |delta| per party = (numel(x)+numel(y))
        elems — crucially *not* numel(x)*cols bytes: the triple reuse is
        what makes 2PC matmul bandwidth-, not latency-, dominated.

        `combine_impl` routes the post-open combine of 2-D RING32
        matmuls through the fused Pallas kernel
        (`kernels/ops.secure_matmul`): both parties'
        `z_p = c_p + eps@b_p + a_p@dlt (+ p0: eps@dlt)` in one tiled
        launch. Exact wrapping int32 arithmetic — bitwise-identical to
        the inline combine ("auto" compiles on TPU, falls back to the
        jnp reference elsewhere).
        """
        ring = x.ring
        a, b, c = matmul_triple(key, x.shape, y.shape, ring)
        eps = x.sh - a.sh
        dlt = y.sh - b.sh
        n = numel(x.shape) + numel(y.shape)
        m, k = x.shape[-2], x.shape[-1]
        n_out = y.shape[-1]
        batch = numel(x.shape[:-2])
        eps_o, dlt_o = self._open_flight("beaver_matmul", (eps, dlt), ring,
                                         n=n, flops=2 * batch * m * k * n_out)
        # party-local: z_p = c_p + eps@b_p + a_p@dlt ; party0 adds eps@dlt
        # Kernel eligibility: 2-D weights on the right. Batched left
        # operands ((..., M, K) @ (K, N) — the forward's big projection
        # matmuls) flatten their batch dims into rows: row-wise int32
        # ring arithmetic is exact, so the flattened combine is bitwise
        # identical to the per-batch inline one.
        if combine_impl is not None and ring.bits == 32 \
                and y.sh.ndim == 3 and x.sh.ndim >= 3:
            from repro.kernels import ops as kops
            eps2 = eps_o.reshape(-1, k)
            z = kops.secure_matmul(eps2, dlt_o,
                                   a.sh.reshape(2, -1, k), b.sh,
                                   c.sh.reshape(2, -1, n_out),
                                   impl=combine_impl)
            out = x.with_sh(z.reshape(c.sh.shape))
        else:
            eb = jnp.matmul(jnp.stack([eps_o, eps_o]), b.sh,
                            preferred_element_type=ring.dtype)
            ad = jnp.matmul(a.sh, jnp.stack([dlt_o, dlt_o]),
                            preferred_element_type=ring.dtype)
            z = c.sh + eb + ad
            ed = jnp.matmul(eps_o, dlt_o, preferred_element_type=ring.dtype)
            z = z.at[0].add(ed)
            out = x.with_sh(z)
        return out
