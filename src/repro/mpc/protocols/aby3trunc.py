"""ABY3 exact truncation (trunc2) over replicated 2-of-3 sharing.

Identical to `replicated3pc` everywhere except `trunc`: the
probabilistic regrouped local shift — whose RING32 wrap risk is pinned
but real (wrap probability |v|/2**(bits-1) per element, measured by the
statistical test in tests/test_malicious.py) — is replaced by ABY3's
two-phase EXACT subprotocol:

  phase 1  the parties jointly generate replicated sharings of a random
           pair (r, r >> shift), r drawn from the safe range
           [0, 2**(bits-2)) — one resharing round of 2 tensors
           (correlated-PRNG generation + re-replication);
  phase 2  open the masked value m = x + r (3 messages), shift the now
           PUBLIC m exactly, and output <m >> shift> - <r >> shift>.

Phase 2 DEPENDS on phase 1's messages being received, so the two phases
can never share a flight: `trunc` emits ONE `trunc2` record of
rounds=2 — a multi-round record is exactly what the flight batcher
treats as a barrier (flush, then record eagerly), so fusion legality
falls out of the existing `FlightBatcher.absorb` rule with no new code.
Composes with the scale-carrying `trunc(shift=)` contract unchanged:
one subprotocol clears any accumulated excess, same cost for any shift.

Error is <= 1 ulp ALWAYS (the same dealer-pair bound as additive2pc's
RING32 path) — zero regrouping wraps, on both rings, which is the
correctness this backend buys for 2 rounds + 6 components of wire per
forced truncation where `replicated3pc` pays ~zero. Keyless boundary
truncs (no PRNG key) fall back to the parent's probabilistic regroup —
the engine threads keys through every force site, so the executed
forward never takes that path.

Still a semi-honest, honest-majority backend (exactness is a
correctness upgrade, not a malicious-security one) and still dealer
free: zero offline bytes, like its parent.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc import comm
from repro.mpc.protocols.base import numel
from repro.mpc.protocols.replicated3pc import Replicated3PC


class ABY3Trunc(Replicated3PC):
    name = "aby3trunc"
    # trunc2 is exact at any shift/exponent, so the scale lattice may
    # defer up to the ring-wide 3f headroom cap (the keyless boundary
    # fallback is never on the executed forward path)
    exact_trunc = True

    def trunc(self, x, key: jax.Array | None, *, shift: int | None = None):
        """Two-phase exact truncation (see module docstring). One
        `trunc2` record: rounds=2 (pair generation, then the dependent
        masked open — a batcher barrier), bytes = 2 phases x 3 messages
        of one tensor each."""
        ring = x.ring
        shift = ring.frac_bits if shift is None else shift
        if key is None:
            # boundary-only fallback: probabilistic regroup (documented;
            # the engine always supplies keys on the executed path)
            return super().trunc(x, key, shift=shift)
        out_fb = x.fb - shift
        n = numel(x.shape)
        kr, k1, k2 = jax.random.split(key, 3)
        utype = jnp.uint32 if ring.bits == 32 else jnp.uint64
        # r from the "safe" range [0, 2**(bits-2)) to avoid sign wrap
        r = (ring.rand(kr, x.shape).astype(utype) >> 2).astype(ring.dtype)
        r_t = r >> shift
        rsh = self.share_encoded(k1, r, ring)
        rtsh = self.share_encoded(k2, r_t, ring)
        masked = x.sh + rsh
        # wire payload, phased like the protocol: sub-round 0 is the
        # pair-generation resharing (one component per party), sub-round
        # 1 the dependent masked open (neighbour sends the component
        # party i lacks) — 2 phases x 3 messages = the priced 6 tensors
        comm.record("trunc2", rounds=2, nbytes=6 * ring.elem_bytes * n,
                    numel=n, tag="bw",
                    payload=[(i, (i - 1) % 3, rsh[i], 0)
                             for i in range(3)]
                    + [((i + 1) % 3, i, masked[(i + 2) % 3], 1)
                       for i in range(3)])
        m = masked[0] + masked[1] + masked[2]        # open x + r
        m_t = m >> shift                              # public exact shift
        out = jnp.stack([m_t - rtsh[0], -rtsh[1], -rtsh[2]])
        return x.with_scale(out, out_fb)
