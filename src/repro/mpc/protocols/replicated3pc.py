"""Replicated 2-out-of-3 sharing — honest-majority 3PC, NO dealer.

Share layout: three uniform additive components on the leading axis,
`sh[0] + sh[1] + sh[2] = value` (mod 2**bits); party i holds the PAIR
(sh[i], sh[i+1 mod 3]) — the ABY3/Araki-et-al. replicated sharing that
privacy-preserving feature selection deploys in practice. Any single
party sees two uniform components and learns nothing; any two parties
can reconstruct.

Multiplication is dealer-free: party i computes the local cross-terms
its pair covers,

    z_i = x_i*y_i + x_i*y_{i+1} + x_{i+1}*y_i + alpha_i,

where (alpha_0, alpha_1, alpha_2) is a ZERO sharing from the correlated
PRNG (party i and i+1 share seed k_{i+1}; alpha_i = F_{k_i} - F_{k_{i+1}}
sums to 0 and costs no interaction). The z_i already form a valid
additive 3-sharing of x*y; ONE resharing flight (party i sends z_i to
party i-1) restores replication: 1 round, 3 messages of the OUTPUT's
elements — note the wire cost scales with the output, not the inputs,
the opposite of Beaver-matmul's (|x|+|y|) profile.

Truncation is probabilistic and local (zero rounds, zero offline
bytes, any shift): regroup the three components as the 2-of-2 sharing
(sh[0]+sh[1], sh[2]) — party 1 holds the first sum, parties 2 and 3
both hold sh[2] — apply the SecureML local-shift trick to that pair
(correct to ±1 LSB w.p. 1 - |v|/2**(bits-1)), and re-randomize the
result back into three components with the correlated PRNG. The
re-replication message that restores the 2-of-3 pair invariant rides
the next resharing flight (ABY3 fuses truncation into
multiplication's resharing) — zero extra rounds, but its bytes ARE
priced: `trunc` emits a 0-round `trunc_reshare` bw record of one
output component, folded into the enclosing fused flight by the
batcher and mirrored by `costs.trunc_cost(protocol="3pc")`.

There are NO offline records in this backend — `Ledger.offline_nbytes`
of any pure-3PC execution is exactly 0, which is the headline advantage
over the dealer-based additive2pc backend.

Flight legality: a resharing message z_i is locally computable before
its flight departs, so all reshares of an independent group (e.g. the
q/k/v projections) legally ride one fused flight; chains inside an
`eng.fused` group follow the same accounting convention as 2PC's
deferred reconstructions (mpc/fusion.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.mpc.ring import RingSpec
from repro.mpc import comm
from repro.mpc.protocols.base import BackendDefaults, numel


class Replicated3PC(BackendDefaults):
    name = "3pc"
    n_parties = 3
    n_wire_parties = 3

    # -- sharing --------------------------------------------------------
    def share_encoded(self, key: jax.Array, enc: jax.Array,
                      ring: RingSpec) -> jax.Array:
        r0 = ring.rand(key, enc.shape)
        r1 = ring.rand(jax.random.fold_in(key, 101), enc.shape)
        return jnp.stack([r0, r1, enc - r0 - r1])

    def from_public(self, enc: jax.Array) -> jax.Array:
        z = jnp.zeros_like(enc)
        return jnp.stack([enc, z, z])

    def open_bytes(self, ring: RingSpec, n: int) -> int:
        # party i lacks component i+2; one neighbour sends it: 3 messages
        return 3 * ring.elem_bytes * n

    def open_msgs(self, sh: jax.Array):
        # party i holds pair (i, i+1) and lacks component i+2, which its
        # neighbour i+1 (holder of (i+1, i+2)) sends — the 3 messages
        # open_bytes prices
        return [((i + 1) % 3, i, sh[(i + 2) % 3]) for i in range(3)]

    # -- correlated-PRNG zero sharing -----------------------------------
    def _zero_share(self, key: jax.Array, shape, ring: RingSpec) -> jax.Array:
        """(3, *shape) components summing to 0: alpha_i = r_i - r_{i+1}
        where r_i comes from the seed parties i and i-1 share."""
        r = jnp.stack([ring.rand(jax.random.fold_in(key, 300 + i), shape)
                       for i in range(3)])
        return r - jnp.roll(r, -1, axis=0)

    # -- truncation -----------------------------------------------------
    def trunc(self, x, key: jax.Array | None, *, shift: int | None = None):
        """Probabilistic local truncation via the 2-of-2 regrouping —
        both rings, zero rounds, zero dealer bytes, any shift. On the
        TPU ring this trades additive2pc's exact dealer pair for a
        |v|/2**(bits-1) per-element wrap probability; RING64 keeps the
        same guarantee as 2PC local truncation.

        The regrouped result is re-randomized back into three components
        and RE-REPLICATED: party 1's fresh component must reach party 0
        to restore the 2-of-3 pair invariant. ABY3 folds that message
        into the next multiplication's resharing flight, so it costs no
        extra round — but it is NOT free bytes: one component of the
        output rides the wire, recorded here as a 0-round bw record the
        flight batcher folds into the enclosing fused flight (the
        ROADMAP PR 4 follow-up; previously modeled as free). Keyless
        boundary truncs skip re-randomization and the message."""
        ring = x.ring
        shift = ring.frac_bits if shift is None else shift
        out_fb = x.fb - shift
        hi = (x.sh[0] + x.sh[1]) >> shift
        lo = -((-x.sh[2]) >> shift)
        if key is None:
            return x.with_scale(jnp.stack([hi, jnp.zeros_like(hi), lo]),
                                out_fb)
        r = ring.rand(key, hi.shape)
        n = numel(x.shape)
        # the re-replication message: party 1's fresh component r reaches
        # party 0 to restore the 2-of-3 pair invariant
        comm.record("trunc_reshare", rounds=0, nbytes=ring.elem_bytes * n,
                    numel=n, tag="bw", payload=[(1, 0, r)])
        return x.with_scale(jnp.stack([hi - r, r, lo]), out_fb)

    # -- multiplication -------------------------------------------------
    def _cross_terms(self, xs: jax.Array, ys: jax.Array, key: jax.Array,
                     ring: RingSpec, mm: bool) -> jax.Array:
        x_n = jnp.roll(xs, -1, axis=0)
        y_n = jnp.roll(ys, -1, axis=0)
        if mm:
            z = (jnp.matmul(xs, ys, preferred_element_type=ring.dtype)
                 + jnp.matmul(xs, y_n, preferred_element_type=ring.dtype)
                 + jnp.matmul(x_n, ys, preferred_element_type=ring.dtype))
        else:
            z = xs * ys + xs * y_n + x_n * ys
        return z + self._zero_share(key, z.shape[1:], ring)

    def mul(self, x, y, key: jax.Array):
        """Elementwise multiply: local cross-terms + one resharing
        flight (no triple, no opening). Raw product — scale bookkeeping
        lives in `mpc/ops.py`."""
        ring = x.ring
        shape = jnp.broadcast_shapes(x.shape, y.shape)
        xb = jnp.broadcast_to(x.sh, (3,) + shape)
        yb = jnp.broadcast_to(y.sh, (3,) + shape)
        z = self._cross_terms(xb, yb, jax.random.fold_in(key, 1), ring,
                              mm=False)
        n = numel(shape)
        comm.record("reshare_mul", rounds=1, nbytes=3 * ring.elem_bytes * n,
                    numel=n, flops=6 * n, tag="bw",
                    payload=[(i, (i - 1) % 3, z[i]) for i in range(3)])
        return x.with_sh(z)

    def matmul(self, x, y, key: jax.Array, *,
               combine_impl: str | None = None):
        """Batched matmul: three local matmuls per party + one resharing
        flight of the OUTPUT (bytes ~ batch*m*n, vs 2PC's |x|+|y|).
        `combine_impl` is a 2PC Beaver-combine knob and is ignored."""
        ring = x.ring
        z = self._cross_terms(x.sh, y.sh, jax.random.fold_in(key, 1), ring,
                              mm=True)
        m, k = x.shape[-2], x.shape[-1]
        n_out = y.shape[-1]
        batch = numel(z.shape[1:-2])
        n = batch * m * n_out
        comm.record("reshare_matmul", rounds=1,
                    nbytes=3 * ring.elem_bytes * n, numel=n,
                    flops=6 * batch * m * k * n_out, tag="bw",
                    payload=[(i, (i - 1) % 3, z[i]) for i in range(3)])
        return x.with_sh(z)
