"""QuickSelect top-k over encrypted scores (paper §4.1).

Finds the *indices* of the k highest entropy values with O(n) expected
pairwise secure comparisons. Each comparison reveals only its binary
outcome (the paper's stated leakage: the rank order information needed
for selection). The data-dependent recursion runs on the host — this is
the selection coordinator, which in deployment drives MPC ops over the
wire; values never leave share form.

Wave coalescing: when the scores were produced by the wave executor the
pool lives in W per-wave device shards, so each partition's comparisons
are issued as per-wave `reveal_lt` batches. Those batches compare
against the SAME pivot and are mutually independent, so under
`fusion.lat_scope` they ride ONE comparison flight — the rounds of a
partition are paid once, not once per wave (the ROADMAP follow-up to
the §4.4 coalescing).
"""
from __future__ import annotations

import numpy as np

from repro.mpc.sharing import AShare
from repro.mpc import compare, fusion, ops as mops


def _cmp_batch(scores: AShare, idx_a: np.ndarray, pivot: int,
               wave: int = 1) -> np.ndarray:
    """Reveal bits [score[i] < score[pivot]] for a batch of indices.

    Batched into ONE message flight: the IO scheduler coalesces
    latency-bound comparisons (paper §4.4), so rounds are per *batch*,
    not per element. Bytes remain per-element. With wave > 1 the batch
    is issued as per-wave chunks (the executor's data layout) that the
    flight batcher fuses back into a single flight.
    """
    idx_a = np.asarray(idx_a)
    if wave <= 1 or len(idx_a) <= 1:
        a = scores[idx_a]
        b = scores[np.asarray([pivot] * len(idx_a))]
        return np.asarray(compare.reveal_lt(a, b))
    chunks = np.array_split(idx_a, min(wave, len(idx_a)))
    out = []
    with fusion.lat_scope("quickselect"):
        for ch in chunks:
            a = scores[ch]
            b = scores[np.asarray([pivot] * len(ch))]
            out.append(np.asarray(compare.reveal_lt(a, b)))
    return np.concatenate(out)


def top_k_indices(scores: AShare, k: int, seed: int = 0,
                  wave: int = 1) -> np.ndarray:
    """Indices of the k largest encrypted scores.

    `wave` is the executor's wave width: comparisons are issued as
    per-wave batches and coalesced into one flight per partition (see
    `_cmp_batch`). The selected set is invariant to `wave` — chunking
    moves messages, never outcomes.

    Scale-carrying inputs are FORCED to canonical scale up front — one
    truncation for the whole pool, before any partition slices — so
    every `reveal_lt` compares canonical encodings and the per-wave
    comparison ledger is byte-identical no matter what exponent the
    producer left on the scores (the engine's entropy head already
    emits canonical; this guards externally supplied pools).
    """
    if scores.excess != 0:
        import jax
        scores = mops.force(scores, jax.random.key(seed ^ 0x5e1ec7))
    n = scores.shape[0]
    if k >= n:
        return np.arange(n)
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    target = k
    out: list[np.ndarray] = []
    # iterative quickselect partitioning on "greater-than-pivot"
    while True:
        if len(idx) == 0:
            break
        if target <= 0:
            break
        if len(idx) <= target:
            out.append(idx)
            break
        pivot_pos = int(rng.integers(len(idx)))
        pivot = int(idx[pivot_pos])
        rest = np.delete(idx, pivot_pos)
        less = _cmp_batch(scores, rest, pivot, wave)  # rest[i] < pivot
        greater = rest[~less]
        smaller = rest[less]
        n_hi = len(greater) + 1                      # pivot included
        if n_hi == target:
            out.append(np.concatenate([greater, [pivot]]))
            break
        if n_hi < target:
            out.append(np.concatenate([greater, [pivot]]))
            target -= n_hi
            idx = smaller
        else:
            idx = greater
    return np.sort(np.concatenate(out)) if out else np.array([], dtype=int)


def expected_comparisons(n: int, k: int) -> float:
    """Analytic expected #comparisons (~2n for k<<n; <=4n worst typical)."""
    return 2.0 * n


def quickselect_cost(n: int, wave: int = 1,
                     coalesce: bool = True) -> tuple[int, int]:
    """(rounds, bytes) for a top-k over n candidates.

    Coalesced (the default, matching `top_k_indices` under the flight
    batcher): O(log n) partition flights, rounds independent of the
    wave chunking. Uncoalesced, every per-wave chunk pays its own
    comparison flight — the eager cost the batcher removes.
    """
    flights = int(np.ceil(np.log2(max(n, 2)))) + 4
    per_partition = 1 if coalesce else max(1, wave)
    return (flights * per_partition * compare.CMP_ROUNDS,
            int(expected_comparisons(n, 0)) * compare.CMP_BYTES)
