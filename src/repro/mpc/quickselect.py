"""QuickSelect top-k over encrypted scores (paper §4.1).

Finds the *indices* of the k highest entropy values with O(n) expected
pairwise secure comparisons. Each comparison reveals only its binary
outcome (the paper's stated leakage: the rank order information needed
for selection). The data-dependent recursion runs on the host — this is
the selection coordinator, which in deployment drives MPC ops over the
wire; values never leave share form.
"""
from __future__ import annotations

import numpy as np

from repro.mpc.sharing import AShare
from repro.mpc import compare


def _cmp_batch(scores: AShare, idx_a: np.ndarray, pivot: int) -> np.ndarray:
    """Reveal bits [score[i] < score[pivot]] for a batch of indices.

    Batched into ONE message flight: the IO scheduler coalesces
    latency-bound comparisons (paper §4.4), so rounds are per *batch*,
    not per element. Bytes remain per-element.
    """
    a = scores[np.asarray(idx_a)]
    b = scores[np.asarray([pivot] * len(idx_a))]
    return np.asarray(compare.reveal_lt(a, b))


def top_k_indices(scores: AShare, k: int, seed: int = 0) -> np.ndarray:
    """Indices of the k largest encrypted scores."""
    n = scores.shape[0]
    if k >= n:
        return np.arange(n)
    rng = np.random.default_rng(seed)
    idx = np.arange(n)
    lo_rank = 0                     # we select the k LARGEST
    target = k
    out: list[np.ndarray] = []
    # iterative quickselect partitioning on "greater-than-pivot"
    while True:
        if len(idx) == 0:
            break
        if target <= 0:
            break
        if len(idx) <= target:
            out.append(idx)
            break
        pivot_pos = int(rng.integers(len(idx)))
        pivot = int(idx[pivot_pos])
        rest = np.delete(idx, pivot_pos)
        less = _cmp_batch(scores, rest, pivot)      # rest[i] < pivot
        greater = rest[~less]
        smaller = rest[less]
        n_hi = len(greater) + 1                      # pivot included
        if n_hi == target:
            out.append(np.concatenate([greater, [pivot]]))
            break
        if n_hi < target:
            out.append(np.concatenate([greater, [pivot]]))
            target -= n_hi
            idx = smaller
        else:
            idx = greater
    return np.sort(np.concatenate(out)) if out else np.array([], dtype=int)


def expected_comparisons(n: int, k: int) -> float:
    """Analytic expected #comparisons (~2n for k<<n; <=4n worst typical)."""
    return 2.0 * n


def quickselect_cost(n: int) -> tuple[int, int]:
    """(rounds, bytes) under coalescing: O(log n) batched flights."""
    flights = int(np.ceil(np.log2(max(n, 2)))) + 4
    return (flights * compare.CMP_ROUNDS,
            int(expected_comparisons(n, 0)) * compare.CMP_BYTES)
