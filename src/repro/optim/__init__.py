from repro.optim.adamw import (
    AdamWConfig, init_opt_state, adamw_update, cosine_schedule,
)
from repro.optim.compress import topk_compress_update, int8_allreduce_sim
