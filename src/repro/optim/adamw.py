"""AdamW with global-norm clipping + cosine schedule (pure pytree fns).

State is a pytree mirroring params (m, v in fp32) so param_specs sharding
applies to it verbatim — the optimizer shards exactly like the model.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def cosine_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(prog, 0, 1)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


def init_opt_state(params):
    return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gn, 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                     state["m"], grads)
    v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                     state["v"], grads)
    t = step.astype(jnp.float32)
    lr = cosine_schedule(cfg, t)

    def upd(p, m_, v_):
        mh = m_ / (1 - cfg.b1 ** t)
        vh = v_ / (1 - cfg.b2 ** t)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    params = jax.tree.map(upd, params, m, v)
    return params, {"m": m, "v": v, "step": step}, {"grad_norm": gn, "lr": lr}
