"""Gradient compression for the DP all-reduce (distributed-training trick).

Two schemes, both with exact-shape pytree mechanics so they drop into the
train step ahead of psum:

  top-k + error feedback (Lin et al., Deep Gradient Compression): keep
  the k largest-|g| entries per tensor, accumulate the residual locally —
  unbiased over time, ~1/ratio wire bytes.

  int8 stochastic quantization: per-tensor scale, stochastic rounding,
  dequant after the all-reduce (simulated here; the wire format is what
  the launcher's collective would carry).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_compress_update(grads, errors, ratio: float = 0.01):
    """Returns (sparse_grads, new_errors). sparse has zeros off-support."""
    def one(g, e):
        g = g.astype(jnp.float32) + e
        flat = g.reshape(-1)
        k = max(1, int(flat.shape[0] * ratio))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        mask = jnp.zeros_like(flat).at[idx].set(1.0)
        kept = flat * mask
        return kept.reshape(g.shape), (flat - kept).reshape(g.shape)

    outs = jax.tree.map(one, grads, errors)
    sparse = jax.tree.map(lambda t: t[0], outs,
                          is_leaf=lambda t: isinstance(t, tuple))
    errs = jax.tree.map(lambda t: t[1], outs,
                        is_leaf=lambda t: isinstance(t, tuple))
    return sparse, errs


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def int8_allreduce_sim(grads, key):
    """Quantize->dequantize round trip (what the int8 collective carries)."""
    def one(g, k):
        g = g.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-9) / 127.0
        noise = jax.random.uniform(k, g.shape, minval=-0.5, maxval=0.5)
        q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
        return q.astype(jnp.float32) * scale

    leaves, treedef = jax.tree.flatten(grads)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(treedef, [one(g, k) for g, k in zip(leaves, keys)])


def wire_bytes(grads, scheme: str, ratio: float = 0.01) -> int:
    n = sum(int(x.size) for x in jax.tree.leaves(grads))
    if scheme == "topk":
        return int(n * ratio) * 8            # value + index
    if scheme == "int8":
        return n
    return n * 4
