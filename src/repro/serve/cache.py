"""Cross-session phase cache — fingerprinted score reuse.

`selection_plan` stamps every PhaseRequest with the run fingerprint
(`selection._run_fingerprint`: pool contents, bootstrap draw, target
weights, full config). Two queued sessions appraising the same model on
the same pool therefore present IDENTICAL (fingerprint, phase) keys —
the cache returns the first session's score shares and the second skips
execution entirely. Because QuickSelect/appraisal run inside the plan
on whatever scores come back, a cache hit is bitwise-indistinguishable
from a re-execution.

The key extends the fingerprint with the phase geometry, ring, and
protocol (already folded into the fingerprint, but explicit here so a
cache entry is self-describing and the hit condition is auditable).

Entries optionally persist to disk through the repro.checkpoint
subsystem (manifest-verified npz + atomic COMMIT): a restarted server
warm-starts from the previous lifetime's scores. Disk-restored entries
carry scores only — the original PhaseReport (ledger, device stamps)
lives and dies with the process that executed it.
"""
from __future__ import annotations

import hashlib
import os

import numpy as np

from repro.checkpoint import restore_checkpoint, save_checkpoint


def phase_key(req, ring, protocol: str) -> tuple:
    """Cache key for one PhaseRequest under an executor substrate."""
    s = req.spec
    return (req.fingerprint, req.phase,
            (s.n_layers, s.n_heads, s.mlp_dim),
            int(req.tokens.shape[0]), int(req.keep), int(req.batch),
            ring.name, protocol)


class PhaseCache:
    """(fingerprint, phase, geometry, ring, protocol) -> score shares."""

    def __init__(self, persist_dir: str | None = None):
        self._mem: dict[tuple, tuple[np.ndarray, object]] = {}
        self.persist_dir = persist_dir
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0

    def _slot(self, key: tuple) -> str:
        digest = hashlib.sha1(repr(key).encode()).hexdigest()[:16]
        return os.path.join(self.persist_dir, f"phase_cache_{digest}")

    def get(self, key: tuple):
        """(scores, report_or_None) on hit, None on miss — counters
        updated either way."""
        ent = self._mem.get(key)
        if ent is None and self.persist_dir:
            tree, step = restore_checkpoint(self._slot(key),
                                            {"ent": np.empty(0)})
            if step is not None:
                ent = (np.asarray(tree["ent"]), None)
                self._mem[key] = ent
                self.disk_hits += 1
        if ent is None:
            self.misses += 1
            return None
        self.hits += 1
        return ent

    def put(self, key: tuple, scores: np.ndarray, report=None) -> None:
        self._mem[key] = (scores, report)
        if self.persist_dir:
            save_checkpoint(self._slot(key), 0, {"ent": scores})

    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "disk_hits": self.disk_hits, "entries": len(self._mem)}
