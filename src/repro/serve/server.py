"""AppraisalServer — continuous-batching multi-tenant private selection.

The server holds a queue of (data-owner, model-owner) sessions, each a
full `selection_plan`, and interleaves their MPC waves round-robin: a
dispatched wave is left in flight (the PhaseRun double buffer) while
the scheduler moves to the next session, so one session's wire time
hides behind another's local compute — the PR 1 intra-phase double
buffer extended to inter-session continuous batching. Admission
pre-stages each session's dealer demand (sized from the same
TraceEngine probes the executor reconciles against) so the background
dealer produces offline material during the session's clear-side proxy
generation; fingerprint-identical phases are served from the
cross-session cache without executing at all.

Scheduling moves WHEN flights happen, never what they carry: every
session's keys and record order are exactly `run_selection`'s, so
scores, survivors, and appraisals are bitwise identical to standalone
runs — `bench_serve --smoke` gates on it.
"""
from __future__ import annotations

import time
from collections import deque

import numpy as np

from repro.engine import cached_probe, cached_probe_info
from repro.mpc import comm
from repro.serve import report as report_mod
from repro.serve.cache import PhaseCache, phase_key
from repro.serve.dealer import DealerPool, phase_orders
from repro.serve.session import AppraisalSession, SessionSpec


class AppraisalServer:
    """Queue + interleaving scheduler + dealer pipeline + phase cache."""

    def __init__(self, *, max_active: int = 4, dealer: bool = True,
                 dealer_capacity: int = 1 << 26, dealer_seed: int = 0,
                 cache_persist_dir: str | None = None):
        self.max_active = max_active
        self.cache = PhaseCache(cache_persist_dir)
        self.pool = (DealerPool(capacity_elems=dealer_capacity,
                                seed=dealer_seed) if dealer else None)
        self.queue: deque[AppraisalSession] = deque()
        self.completed: list[AppraisalSession] = []
        self.executed_reports = []        # phases actually run (not cached)
        self._inflight: dict[tuple, AppraisalSession] = {}
        self.coalesced_waits = 0
        self._t0 = None

    # ---- admission ------------------------------------------------------
    def submit(self, spec: SessionSpec) -> AppraisalSession:
        sess = AppraisalSession(spec)
        if self.pool is not None:
            self.pool.stage(self._session_orders(spec))
        self.queue.append(sess)
        return sess

    def _session_orders(self, spec: SessionSpec):
        """Dealer demand of every phase the session will run, from the
        same memoized TraceEngine probes the executor later reconciles
        its ledgers against (so staging is exact, not a heuristic)."""
        from repro.core import selection as sel_mod
        sel = spec.sel
        ex = sel.executor
        n = int(spec.pool_tokens.shape[0])
        seq = int(spec.pool_tokens.shape[1])
        budget = int(round(sel.budget_frac * n))
        n_boot = max(8, int(round(sel.boot_frac * n)))
        surviving = n - n_boot
        keeps = sel_mod._phase_keep(surviving, budget - n_boot, sel.phases)
        orders = []
        cur = surviving
        for ph, keep in zip(sel.phases, keeps):
            batch = min(sel.score_batch, cur)
            n_batches = -(-cur // batch)
            per_batch = cached_probe(
                spec.arch_cfg, ph, batch=batch, seq=seq,
                classes=spec.n_classes, ring=ex.ring, protocol=ex.protocol,
                fused=ex.fuse, variant=sel.variant)
            orders.extend(phase_orders(per_batch, n_batches, ex.ring,
                                       ex.protocol))
            cur = keep
        return orders

    # ---- scheduling -----------------------------------------------------
    def _step(self, sess: AppraisalSession) -> None:
        """One scheduling quantum: advance the plan, resolve the cache,
        or dispatch exactly one wave (leaving it in flight for the next
        session's quantum to overlap with)."""
        if sess.scoring:
            if sess.waves_left > 0:
                if self.pool is not None:
                    ex = sess.spec.sel.executor
                    self.pool.acquire(phase_orders(
                        sess.run.per_batch, sess.run.lanes(sess.next_wave),
                        ex.ring, ex.protocol))
                sess.dispatch_next()
            else:
                ent, rep = sess.finish_phase()
                self.executed_reports.append(rep)
                self.cache.put(sess._cache_key, np.asarray(ent.sh), rep)
                self._inflight.pop(sess._cache_key, None)
            return
        if sess.request is not None:
            ex = sess.spec.sel.executor
            key = phase_key(sess.request, ex.ring, ex.protocol)
            if self._inflight.get(key) is not None:
                # request coalescing: an identical phase is executing in
                # another session right now — wait for its scores to
                # land in the cache instead of duplicating the work
                self.coalesced_waits += 1
                return
            hit = self.cache.get(key)
            if hit is not None:
                scores, rep = hit
                sess.feed_scores(scores, rep)
            else:
                sess._cache_key = key
                self._inflight[key] = sess
                sess.begin_phase()
            return
        sess.advance_plan()               # clear-side work / completion

    def run(self) -> dict:
        """Drain the queue; returns the SERVE_report dict."""
        self._t0 = time.time()
        active: list[AppraisalSession] = []
        while self.queue or active:
            while self.queue and len(active) < self.max_active:
                active.append(self.queue.popleft())
            for sess in list(active):
                self._step(sess)
                if sess.done:
                    active.remove(sess)
                    self.completed.append(sess)
        return self.report()

    # ---- reporting ------------------------------------------------------
    def report(self, net: str = "wan") -> dict:
        wall_s = (time.time() - self._t0) if self._t0 else 0.0
        out = {
            "sessions": [s.as_dict() for s in self.completed],
            "throughput": report_mod.throughput(self.completed,
                                                self.executed_reports, net),
            "cache": {**self.cache.stats(),
                      "coalesced_waits": self.coalesced_waits},
            "probe_cache": cached_probe_info(),
            "ledger_agrees": all(s.ledger_agrees() for s in self.completed),
            "wall_s": wall_s,
        }
        out["dealer"] = (self.pool.stats() if self.pool is not None
                         else {"dealer_stall_s": 0.0, "staged_elems": 0,
                               "produced_elems": 0, "consumed_elems": 0,
                               "pooled_elems": 0, "stalls": 0,
                               "produced_nbytes": 0})
        return out

    def close(self) -> None:
        if self.pool is not None:
            self.pool.close()
