"""Pipelined offline dealer — pre-generates correlated randomness.

The additive-2pc and spdz2pc backends consume dealer material (Beaver
triples, sacrifice triples, truncation pairs, MAC keys) whose bytes land
on the ledger's OFFLINE channel: priced separately from the online wire
precisely because a crypto provider can stream them AHEAD of the phase.
Standalone runs leave that pipelining implicit; the appraisal server
makes it real. At session admission the server sizes each phase's
demand from its TraceEngine probe (`Ledger.offline_by_op` x the wave
fan-out) and `stage()`s production orders; a worker thread then
synthesizes the material (`ProtocolBackend.dealer_material`) into a
bounded per-(op, ring) pool WHILE the session's clear-side proxy
generation runs. Online waves `acquire()` their allocation just before
dispatch — if the pool already holds it (the steady state), acquisition
is instant; only an actual wait accrues `dealer_stall_s`, the report's
headline pipelining metric (0 at smoke scale).

Material is pool-plumbing, not execution input: online values stay
key-derived from the session's jax PRNG stream, so scores are bitwise
identical to standalone runs no matter how the dealer is scheduled.
The pool holds pre-staged BYTES of the right shape — the offline
channel realized — and `capacity_elems` bounds how far ahead the
dealer may run per (op, ring) key.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque

import numpy as np

from repro.mpc import protocols
from repro.mpc.ring import RingSpec


@dataclasses.dataclass(frozen=True)
class Order:
    """One production order: `elems` ring elements of offline material
    for `op` under `ring`, synthesized by `protocol`'s backend. The
    shift dimension is implicit: the only truncation pairs a dealer
    serves are the ring's canonical frac_bits shift."""
    op: str
    ring: RingSpec
    protocol: str
    elems: int

    @property
    def key(self) -> tuple[str, str]:
        return (self.op, self.ring.name)


def phase_orders(per_batch, n_batches: int, ring: RingSpec,
                 protocol: str) -> list[Order]:
    """Dealer orders for one phase: the probe's per-batch offline
    footprint (`Ledger.offline_by_op`) times the batch fan-out."""
    return [Order(op=op, ring=ring, protocol=protocol,
                  elems=numel * n_batches)
            for op, (numel, _) in sorted(per_batch.offline_by_op().items())
            if numel > 0]


class DealerPool:
    """Bounded per-(op, ring) pool of pre-generated dealer material,
    filled by a background worker thread, drained by online waves."""

    def __init__(self, capacity_elems: int = 1 << 26, seed: int = 0,
                 chunk_elems: int = 1 << 16):
        self.capacity_elems = int(capacity_elems)
        self.chunk_elems = int(chunk_elems)
        self._rng = np.random.default_rng(seed)
        self._cv = threading.Condition()
        self._orders: deque[Order] = deque()
        self._avail: dict[tuple, list[np.ndarray]] = {}
        self._avail_elems: dict[tuple, int] = {}
        self._stop = False
        self.staged_elems = 0
        self.produced_elems = 0
        self.produced_nbytes = 0
        self.consumed_elems = 0
        self.dealer_stall_s = 0.0
        self.stalls = 0
        self._thread = threading.Thread(target=self._work, daemon=True,
                                        name="dealer")
        self._thread.start()

    # ---- producer side --------------------------------------------------
    def stage(self, orders: list[Order]) -> None:
        """Enqueue production orders (admission-time pre-staging). The
        pool bound applies per key: an order beyond `capacity_elems`
        ahead of consumption is clipped and re-ordered on demand by the
        acquire path (bounded memory beats a silent unbounded queue)."""
        with self._cv:
            for o in orders:
                have = (self._avail_elems.get(o.key, 0)
                        + sum(q.elems for q in self._orders
                              if q.key == o.key))
                room = max(0, self.capacity_elems - have)
                clipped = dataclasses.replace(o, elems=min(o.elems, room))
                if clipped.elems > 0:
                    self._orders.append(clipped)
                    self.staged_elems += clipped.elems
            self._cv.notify_all()

    def _work(self) -> None:
        while True:
            with self._cv:
                while not self._orders and not self._stop:
                    self._cv.wait()
                if self._stop and not self._orders:
                    return
                order = self._orders.popleft()
            backend = protocols.get(order.protocol)
            left = order.elems
            while left > 0:
                n = min(left, self.chunk_elems)
                buf = backend.dealer_material(self._rng, order.op,
                                              order.ring, n)
                left -= n
                with self._cv:
                    self._avail.setdefault(order.key, []).append(buf)
                    self._avail_elems[order.key] = \
                        self._avail_elems.get(order.key, 0) + n
                    self.produced_elems += n
                    self.produced_nbytes += buf.nbytes
                    self._cv.notify_all()

    # ---- consumer side --------------------------------------------------
    def acquire(self, orders: list[Order], timeout_s: float = 60.0) -> None:
        """Consume one wave's offline allocation. Instant when the pool
        holds it; otherwise a top-up order covers the shortfall and the
        wait — only the wait — lands in `dealer_stall_s`."""
        for o in orders:
            if o.elems <= 0:
                continue
            with self._cv:
                if self._avail_elems.get(o.key, 0) < o.elems:
                    # demand the pool bound clipped (or a mis-sized
                    # probe missed): order the shortfall and stall
                    short = o.elems - self._avail_elems.get(o.key, 0)
                    self._orders.append(dataclasses.replace(o, elems=short))
                    self.staged_elems += short
                    self._cv.notify_all()
                    self.stalls += 1
                    t0 = time.perf_counter()
                    deadline = t0 + timeout_s
                    while self._avail_elems.get(o.key, 0) < o.elems:
                        if not self._cv.wait(timeout=deadline
                                             - time.perf_counter()):
                            raise TimeoutError(
                                f"dealer pool starved for {o.key}")
                    self.dealer_stall_s += time.perf_counter() - t0
                left = o.elems
                bufs = self._avail[o.key]
                while left > 0:
                    head = bufs[0]
                    if len(head) <= left:
                        bufs.pop(0)
                        left -= len(head)
                    else:
                        bufs[0] = head[left:]
                        left = 0
                self._avail_elems[o.key] -= o.elems
                self.consumed_elems += o.elems

    def close(self) -> None:
        with self._cv:
            self._stop = True
            self._cv.notify_all()
        self._thread.join(timeout=10.0)

    def stats(self) -> dict:
        with self._cv:
            return {
                "staged_elems": self.staged_elems,
                "produced_elems": self.produced_elems,
                "produced_nbytes": self.produced_nbytes,
                "consumed_elems": self.consumed_elems,
                "pooled_elems": sum(self._avail_elems.values()),
                "dealer_stall_s": self.dealer_stall_s,
                "stalls": self.stalls,
            }
