"""Service-level throughput model + SERVE_report assembly.

The perf thesis of the appraisal service: for a long-running server the
metric is SUSTAINED appraisals/hour at a fixed net profile, not any one
run's makespan. Three effects move it, all visible in this report:

  inter-session overlap   one session's compute hides under another's
                          comm — the two-stage pipeline of
                          iosched.makespan lifted from batches to the
                          whole queue: the dominant resource runs
                          continuously, fill is paid ONCE, not per phase
  cross-session cache     fingerprint-identical phases skip execution
  dealer pipelining       offline bytes stream during clear-side work,
                          so online waves never wait (dealer_stall_s)

`serve_makespan` prices the served timeline from the same per-phase
stream totals `iosched` prices standalone runs with — the baseline
(`sequential_makespan`, N independent `run_selection` calls) and the
served number are the same integers scheduled differently, so the
speedup is a statement about scheduling, never about workload drift.

Every per-phase dict in the report is `PhaseReport.as_dict` — the exact
shape `SELECT_report.json` uses — so downstream tooling reads both.
"""
from __future__ import annotations

from repro.core import iosched
from repro.mpc.comm import NetProfile, PROFILES


def phase_split(rep, net: NetProfile) -> tuple[float, float]:
    """(comm_s, compute_s) of one executed phase's op stream — the two
    pipeline resources the service overlaps across sessions."""
    t = iosched.stream_totals(rep.per_batch, rep.n_batches, rep.sched)
    comm = ((t["lat_rounds"] + t["bw_rounds"]) * net.latency_s
            + t["nbytes"] / net.bandwidth_Bps)
    comp = t["flops"] / rep.sched.flops_per_s
    return comm, comp


def sequential_makespan(all_reports, net: NetProfile) -> float:
    """Baseline: N standalone `run_selection` calls back to back — every
    phase pays its own makespan (within-phase overlap only), cached or
    not (standalone runs execute everything)."""
    return sum(rep.makespan(net) for rep in all_reports)


def serve_makespan(executed_reports, net: NetProfile) -> float:
    """Served timeline: only executed phases cost anything (cache hits
    are free), their comm and compute streams overlap ACROSS sessions,
    and the pipeline fill is paid once for the whole queue."""
    if not executed_reports:
        return 0.0
    comm = comp = 0.0
    fill = 0.0
    for rep in executed_reports:
        c, k = phase_split(rep, net)
        comm += c
        comp += k
        fill = max(fill, rep.makespan(net) - max(c, k))
    return max(comm, comp) + fill


def throughput(sessions, executed_reports, net_name: str = "wan") -> dict:
    """The headline block of SERVE_report.json."""
    net = PROFILES[net_name]
    all_reports = [r for s in sessions for r in s.reports]
    seq_s = sequential_makespan(all_reports, net)
    srv_s = serve_makespan(executed_reports, net)
    n = len(sessions)
    return {
        "net": net_name,
        "n_sessions": n,
        "n_phases_total": len(all_reports),
        "n_phases_executed": len(executed_reports),
        "sequential_makespan_s": seq_s,
        "serve_makespan_s": srv_s,
        "sequential_appraisals_per_hour": (n / (seq_s / 3600)
                                           if seq_s > 0 else 0.0),
        "serve_appraisals_per_hour": (n / (srv_s / 3600)
                                      if srv_s > 0 else 0.0),
        "speedup": (seq_s / srv_s) if srv_s > 0 else float("inf"),
    }
