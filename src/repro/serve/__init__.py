"""Appraisal-as-a-service: multi-tenant private selection.

The data-market endgame (paper §1): model owners appraise a continuous
stream of candidate datasets under MPC. This package turns the one-shot
`run_selection` pipeline into a long-running service —

  session.py   one appraisal as a schedulable state machine over
               `core.selection.selection_plan`
  server.py    queue + round-robin wave interleaver (continuous
               batching across sessions) + admission-time dealer staging
  dealer.py    background thread pre-generating offline material into a
               bounded per-(op, ring) pool; `dealer_stall_s` is the
               pipelining metric
  cache.py     cross-session phase cache keyed on the run fingerprint +
               phase geometry + ring + protocol
  report.py    sustained appraisals/hour vs the N-sequential baseline,
               priced from the same iosched stream totals

Invariant: scheduling moves flights, never values — every session's
scores are bitwise identical to its standalone run.
"""
from repro.serve.cache import PhaseCache, phase_key
from repro.serve.dealer import DealerPool, Order, phase_orders
from repro.serve.report import (phase_split, sequential_makespan,
                                serve_makespan, throughput)
from repro.serve.server import AppraisalServer
from repro.serve.session import AppraisalSession, SessionSpec

__all__ = [
    "AppraisalServer", "AppraisalSession", "SessionSpec", "DealerPool",
    "Order", "phase_orders", "PhaseCache", "phase_key", "phase_split",
    "sequential_makespan", "serve_makespan", "throughput",
]
