"""One appraisal session: a (data-owner, model-owner) selection run
decomposed into schedulable units.

The session wraps `core.selection.selection_plan` — the full 3-stage
pipeline as a generator — and exposes the server-facing state machine:

  advance_plan()   run the plan to its next PhaseRequest (all clear-side
                   work — bootstrap, proxy generation, QuickSelect of
                   the previous phase — happens inside this call)
  begin_phase()    open a stepwise PhaseRun for the pending request
  dispatch_next()  execute one wave (leaves it in flight, double-buffered)
  finish_phase()   drain + seal the PhaseRun, feed scores back to the plan
  feed_scores()    feed CACHED scores back instead (skip execution)

Numerics are the plan's: the session never touches keys, QuickSelect,
or appraisal, so scores/survivors are bitwise identical to a standalone
`run_selection` regardless of how the server interleaves dispatches.
Every wave's flights land in the session's OWN ledger (PhaseRun's
`outer`), keeping per-session accounting exact under interleaving.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.executor import ExecConfig, PhaseReport, PhaseRun
from repro.core.selection import SelectionConfig, selection_plan
from repro.mpc import comm
from repro.mpc.ring import x64_scope
from repro.mpc.sharing import AShare


@dataclasses.dataclass
class SessionSpec:
    """Everything one appraisal request carries at admission."""
    sid: str
    key: jax.Array                   # the run's root PRNG key
    target_params: dict
    arch_cfg: ArchConfig
    pool_tokens: np.ndarray
    sel: SelectionConfig
    n_classes: int
    boot_labels_fn: object


class AppraisalSession:
    """Server-side state of one queued appraisal."""

    def __init__(self, spec: SessionSpec):
        ex = spec.sel.executor
        if ex.wire != "none" or ex.mesh != "none":
            # the interleaver owns the schedule; wire capture and device
            # meshes assume they own the process — standalone runs keep
            # those modes
            raise ValueError("appraisal sessions run wire='none', "
                             "mesh='none' (got wire=%r, mesh=%r)"
                             % (ex.wire, ex.mesh))
        self.spec = spec
        self.sid = spec.sid
        self.ledger = comm.Ledger()          # all online flights, per session
        self.plan = selection_plan(
            spec.key, spec.target_params, spec.arch_cfg, spec.pool_tokens,
            spec.sel, n_classes=spec.n_classes,
            boot_labels_fn=spec.boot_labels_fn)
        self._send = None
        self.request = None                  # pending PhaseRequest
        self.run: PhaseRun | None = None
        self.next_wave = 0
        self.result = None                   # SelectionResult when done
        self.reports: list[PhaseReport] = []
        self.cached_phases: list[int] = []
        self._cache_key = None               # server's key for the open phase
        self.admitted_s = time.time()
        self.done_s: float | None = None

    # ---- plan driving ---------------------------------------------------
    @property
    def done(self) -> bool:
        return self.result is not None

    @property
    def scoring(self) -> bool:
        return self.run is not None

    @property
    def waves_left(self) -> int:
        return 0 if self.run is None else self.run.n_waves - self.next_wave

    def advance_plan(self) -> None:
        """Step the generator to its next PhaseRequest (or completion).
        The clear-side compute between MPC phases runs here — exactly
        the work the dealer thread pipelines its production behind."""
        try:
            self.request = self.plan.send(self._send)
        except StopIteration as done:
            self.result = done.value
            self.request = None
            self.done_s = time.time()
        self._send = None

    # ---- phase execution ------------------------------------------------
    def phase_cfg(self) -> ExecConfig:
        return dataclasses.replace(self.spec.sel.executor,
                                   batch=self.request.batch)

    def begin_phase(self) -> PhaseRun:
        req = self.request
        self.run = PhaseRun(self.phase_cfg(), req.key, req.pp,
                            self.spec.arch_cfg, req.tokens, req.spec,
                            self.spec.sel.variant, outer=self.ledger)
        self.next_wave = 0
        return self.run

    def dispatch_next(self) -> None:
        self.run.dispatch(self.next_wave)
        self.next_wave += 1

    def finish_phase(self) -> tuple[AShare, PhaseReport]:
        self.run.drain()
        ent, rep = self.run.finish()
        self.reports.append(rep)
        self.run = None
        self._send = (ent, [rep])
        self.request = None
        return ent, rep

    def feed_scores(self, scores: np.ndarray, report=None) -> None:
        """Cache hit: hand the plan previously-computed score shares.
        QuickSelect/appraisal still run inside the plan, so downstream
        results match a real execution bit for bit."""
        ring = self.spec.sel.executor.ring
        ctx = (x64_scope() if ring.bits >= 64
               else contextlib.nullcontext())
        with ctx:                       # int64 shares must not demote
            ent = AShare(jax.numpy.asarray(scores), ring,
                         self.spec.sel.executor.protocol)
        self.cached_phases.append(self.request.phase)
        if report is not None:
            self.reports.append(report)
        self._send = (ent, [report] if report is not None else [])
        self.request = None

    # ---- reporting ------------------------------------------------------
    def ledger_agrees(self) -> bool:
        return all(r.agrees() for r in self.reports)

    def as_dict(self) -> dict:
        """SERVE_report entry: the same per-phase dict shape as
        SELECT_report's `executed` block (PhaseReport.as_dict)."""
        return {
            "sid": self.sid,
            "phases": [r.as_dict() for r in self.reports],
            "ledger_agrees": (all(r.agrees() for r in self.reports)
                              if self.reports else None),
            "resumed_phases": (self.result.resumed_phases
                               if self.result else 0),
            "cached_phases": list(self.cached_phases),
            "appraisal_entropy": (self.result.appraisal_entropy
                                  if self.result else None),
            "n_selected": (int(len(self.result.selected))
                           if self.result else None),
            "wall_s": ((self.done_s or time.time()) - self.admitted_s),
        }
