"""RG-LRU linear recurrence kernel: h_t = a_t * h_{t-1} + b_t.

Grid (B, T/bt), time tiles innermost; the (1, D) state persists in VMEM
scratch across tiles. Inside a tile the recurrence is a fori_loop over
the bt steps — serial in time but D-wide on the VPU, with all operands
VMEM-resident (one HBM read of (a, b) and one write of h per tile).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, h_ref, state, *, bt: int):
    it = pl.program_id(1)

    @pl.when(it == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    a = a_ref[0].astype(jnp.float32)                       # (bt, D)
    b = b_ref[0].astype(jnp.float32)

    def step(t, h):
        h = a[t] * h + b[t]
        h_ref[0, t, :] = h.astype(h_ref.dtype)
        return h

    state[0] = jax.lax.fori_loop(0, bt, step, state[0])


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def rg_lru_scan(a, b, *, bt: int = 128, interpret: bool = False):
    """a, b: (B, T, D) -> h trace (B, T, D)."""
    bsz, t, d = a.shape
    bt = min(bt, t)
    assert t % bt == 0
    return pl.pallas_call(
        functools.partial(_kernel, bt=bt),
        grid=(bsz, t // bt),
        in_specs=[
            pl.BlockSpec((1, bt, d), lambda ib, it: (ib, it, 0)),
            pl.BlockSpec((1, bt, d), lambda ib, it: (ib, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, d), lambda ib, it: (ib, it, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, t, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
        interpret=interpret,
    )(a, b)
