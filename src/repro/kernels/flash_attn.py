"""Exact-softmax flash attention (baseline / target models).

Standard online-softmax tiling: grid (BH, Sq/bq, Skv/bk) with KV
innermost; running (m, l, acc) in VMEM scratch; causal variant skips
fully-masked KV tiles at runtime via pl.when (the compute is elided on
TPU because the MXU issue itself sits under the predicate).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_acc, l_acc, acc,
            *, nk: int, bq: int, bk: int, scale: float, causal: bool):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        l_acc[...] = jnp.zeros_like(l_acc)
        acc[...] = jnp.zeros_like(acc)

    run = (not causal) or (ik * bk <= iq * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m_new = jnp.maximum(m_acc[...], jnp.max(s, -1, keepdims=True))
        alpha = jnp.exp(m_acc[...] - m_new)
        p = jnp.exp(s - m_new)
        l_acc[...] = l_acc[...] * alpha + jnp.sum(p, -1, keepdims=True)
        v = v_ref[0].astype(jnp.float32)
        acc[...] = acc[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        m_acc[...] = m_new

    @pl.when(ik == nk - 1)
    def _epilogue():
        o_ref[0, ...] = (acc[...] / jnp.maximum(l_acc[...], 1e-30)
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attn(q, k, v, *, causal: bool = True, bq: int = 128,
               bk: int = 128, interpret: bool = False):
    """q,k,v: (BH, S, Dh) -> (BH, S, Dh)."""
    bh, sq, dh = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    nq, nk = sq // bq, skv // bk
    scale = dh ** -0.5
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bk=bk, scale=scale,
                          causal=causal),
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, iq, ik: (b, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, 1), jnp.float32),
                        pltpu.VMEM((bq, dh), jnp.float32)],
        interpret=interpret,
    )(q, k, v)
