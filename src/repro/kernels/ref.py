"""Pure-jnp oracles for every kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def mlp_softmax_attn(q, k, v, w1, b1, w2, b2, *, scale=None):
    """SelectFormer MLP-approximated attention, materialized form.

    q,k,v: (BH, S, Dh); w1: (S, hid); b1: (hid,); w2: (hid, S); b2: (S,).
    probs = relu(scores @ w1 + b1) @ w2 + b2  (the paper's MLP_sm),
    out = probs @ v.
    """
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    h = jax.nn.relu(s @ w1.astype(jnp.float32) + b1)
    probs = h @ w2.astype(jnp.float32) + b2
    return jnp.einsum("bqk,bkd->bqd", probs, v.astype(jnp.float32))


def flash_attn(q, k, v, *, causal=True, scale=None):
    """Exact softmax attention. q,k,v: (BH, S, Dh)."""
    scale = scale if scale is not None else q.shape[-1] ** -0.5
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = s.shape[-2:]
        mask = jnp.tril(jnp.ones((sq, sk), bool), sk - sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))


def entropy_head(logits):
    """H = logZ - E_p[x] per row. logits: (R, V) -> (R,)."""
    x = logits.astype(jnp.float32)
    m = jnp.max(x, -1, keepdims=True)
    e = jnp.exp(x - m)
    z = jnp.sum(e, -1)
    s = jnp.sum(x * e, -1)
    return m[:, 0] + jnp.log(z) - s / z


def ssd(x, a, b, c):
    """Sequential state-space scan oracle.

    x: (B, T, H, P) (dt-scaled inputs), a: (B, T, H) log decays,
    b, c: (B, T, N). Returns y: (B, T, H, P).
    """
    bs, t, h, p = x.shape
    n = b.shape[-1]

    def step(state, inp):
        x_t, a_t, b_t, c_t = inp
        state = state * jnp.exp(a_t)[..., None, None] \
            + jnp.einsum("bn,bhp->bhpn", b_t, x_t)
        y = jnp.einsum("bn,bhpn->bhp", c_t, state)
        return state, y

    xs = (jnp.moveaxis(x, 1, 0).astype(jnp.float32),
          jnp.moveaxis(a, 1, 0).astype(jnp.float32),
          jnp.moveaxis(b, 1, 0).astype(jnp.float32),
          jnp.moveaxis(c, 1, 0).astype(jnp.float32))
    s0 = jnp.zeros((bs, h, p, n), jnp.float32)
    _, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1)


def rg_lru(a, bterm, h0=None):
    """h_t = a_t * h_{t-1} + b_t. a, b: (B, T, D). Returns h trace."""
    bsz, t, d = a.shape
    h = jnp.zeros((bsz, d), jnp.float32) if h0 is None else h0

    def step(h, inp):
        a_t, b_t = inp
        h = a_t * h + b_t
        return h, h

    xs = (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
          jnp.moveaxis(bterm, 1, 0).astype(jnp.float32))
    _, ys = jax.lax.scan(step, h, xs)
    return jnp.moveaxis(ys, 0, 1)


def secure_matmul_combine(eps, dlt, a_sh, b_sh, c_sh, party: int):
    """One party's Beaver combine: z_p = c_p + eps@b_p + a_p@dlt (+p0: eps@dlt).

    All int32 ring arithmetic (wrapping). eps/dlt are the opened masked
    values; *_sh are this party's triple shares.
    """
    z = c_sh \
        + jnp.matmul(eps, b_sh, preferred_element_type=jnp.int32) \
        + jnp.matmul(a_sh, dlt, preferred_element_type=jnp.int32)
    if party == 0:
        z = z + jnp.matmul(eps, dlt, preferred_element_type=jnp.int32)
    return z
