"""Mamba-2 chunked SSD kernel with VMEM-resident inter-chunk state.

Layout (pre-arranged by ops.py): x (B, H, nc, Q, P), a (B, H, nc, Q),
b/c (B, nc, Q, N) shared across heads. Grid (B, H, nc), chunks innermost;
the (P, N) running state lives in VMEM scratch across the chunk loop —
one HBM round-trip per chunk tile instead of per step.

Per chunk: intra-chunk quadratic term  y_d = ((C B^T) ⊙ L) X
           state read                  y_o = C S_prev * exp(cum)
           state update                S   = S exp(sum a) + (B dX)^T-agg
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state, *, q: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    x = x_ref[0, 0, 0].astype(jnp.float32)                 # (Q, P)
    a = a_ref[0, 0, 0, 0].astype(jnp.float32)              # (Q,)
    b = b_ref[0, 0].astype(jnp.float32)                    # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)                    # (Q, N)

    cum = jnp.cumsum(a)                                    # (Q,)
    seg = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(tri, jnp.exp(seg), 0.0)              # (Q, Q)
    scores = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jnp.dot(scores * l_mat, x, preferred_element_type=jnp.float32)

    decay_in = jnp.exp(cum)[:, None]                       # (Q, 1)
    y_off = jnp.dot(c, state[...].T,
                    preferred_element_type=jnp.float32) * decay_in  # (Q, P)

    chunk_sum = cum[q - 1]
    decay_out = jnp.exp(chunk_sum - cum)[:, None]          # (Q, 1)
    new_contrib = jax.lax.dot_general(
        x * decay_out, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # (P, N)
    state[...] = state[...] * jnp.exp(chunk_sum) + new_contrib

    y_ref[0, 0, 0, ...] = (y_diag + y_off).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunked(x, a, b, c, *, interpret: bool = False):
    """x: (B, H, nc, Q, P); a: (B, H, nc, Q); b,c: (B, nc, Q, N)."""
    bs, h, nc, q, p = x.shape
    n = b.shape[-1]
    a4 = a[..., None, :]                                   # (B, H, nc, 1, Q)
    return pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(bs, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, q, p), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, q), lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
            pl.BlockSpec((1, 1, q, n), lambda ib, ih, ic: (ib, ic, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, q, p),
                               lambda ib, ih, ic: (ib, ih, ic, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bs, h, nc, q, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, a4, b, c)
