"""Beaver-combine matmul on the int32 TPU ring (both parties fused).

Computes, in one pass over K tiles (exact wrapping int32 arithmetic):
  z_p = c_p + eps @ b_p + a_p @ dlt   (+ party0 only: eps @ dlt)

This is the per-party local step of a secure matmul after (eps, dlt) are
opened; it is the bandwidth-bound hot loop of the MPC selection phase.
Grid (M/bm, N/bn, K/bk), K innermost, int32 accumulator in VMEM.

TPU note: int32 multiplies run on the VPU; an MXU path would decompose
into 4x int8 partial products (left as the documented perf follow-up —
correctness here is exact ring arithmetic, validated in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(eps_ref, dlt_ref, a0_ref, a1_ref, b0_ref, b1_ref,
            c0_ref, c1_ref, z0_ref, z1_ref, acc0, acc1, *, nk: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc0[...] = jnp.zeros_like(acc0)
        acc1[...] = jnp.zeros_like(acc1)

    eps = eps_ref[...]
    dlt = dlt_ref[...]
    for acc, a_r, b_r, p0 in ((acc0, a0_ref, b0_ref, True),
                              (acc1, a1_ref, b1_ref, False)):
        z = jnp.dot(eps, b_r[0], preferred_element_type=jnp.int32) \
            + jnp.dot(a_r[0], dlt, preferred_element_type=jnp.int32)
        if p0:
            z = z + jnp.dot(eps, dlt, preferred_element_type=jnp.int32)
        acc[...] += z

    @pl.when(ik == nk - 1)
    def _epilogue():
        z0_ref[...] = acc0[...] + c0_ref[0]
        z1_ref[...] = acc1[...] + c1_ref[0]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def secure_matmul(eps, dlt, a_sh, b_sh, c_sh, *, bm: int = 128,
                  bn: int = 128, bk: int = 128, interpret: bool = False):
    """eps: (M, K), dlt: (K, N) opened int32; a_sh/b_sh/c_sh: (2, ...) share
    stacks. Returns z_sh (2, M, N) — both parties' combine in one launch
    (single-pod simulation layout; on the 2-pod mesh each pod runs its
    party's half via the pod-sharded leading axis)."""
    m, kdim = eps.shape
    n = dlt.shape[1]
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, kdim)
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    z = pl.pallas_call(
        functools.partial(_kernel, nk=kdim // bk),
        grid=(m // bm, n // bn, kdim // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda im, in_, ik: (im, ik)),
            pl.BlockSpec((bk, bn), lambda im, in_, ik: (ik, in_)),
            pl.BlockSpec((1, bm, bk), lambda im, in_, ik: (0, im, ik)),
            pl.BlockSpec((1, bm, bk), lambda im, in_, ik: (0, im, ik)),
            pl.BlockSpec((1, bk, bn), lambda im, in_, ik: (0, ik, in_)),
            pl.BlockSpec((1, bk, bn), lambda im, in_, ik: (0, ik, in_)),
            pl.BlockSpec((1, bm, bn), lambda im, in_, ik: (0, im, in_)),
            pl.BlockSpec((1, bm, bn), lambda im, in_, ik: (0, im, in_)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda im, in_, ik: (im, in_)),
            pl.BlockSpec((bm, bn), lambda im, in_, ik: (im, in_)),
        ],
        out_shape=[jax.ShapeDtypeStruct((m, n), jnp.int32),
                   jax.ShapeDtypeStruct((m, n), jnp.int32)],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32),
                        pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(eps, dlt, a_sh[0][None], a_sh[1][None], b_sh[0][None], b_sh[1][None],
      c_sh[0][None], c_sh[1][None])
    return jnp.stack(z)
