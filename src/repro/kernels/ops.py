"""Dispatch wrappers: Pallas kernel on TPU, interpret/ref elsewhere.

`use_pallas()` — TPU backend gets compiled kernels; CPU gets either
interpret-mode kernels (tests: numerics of the kernel body itself) or
the jnp reference (fast path for examples). Callers can force either
via the `impl` argument ("pallas" | "interpret" | "ref" | "auto").
"""
from __future__ import annotations

import logging

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels.mlp_softmax_attn import mlp_softmax_attn as _msa
from repro.kernels.flash_attn import flash_attn as _fa
from repro.kernels.entropy_head import entropy_head as _eh
from repro.kernels.ssd import ssd_chunked as _ssd
from repro.kernels.rg_lru import rg_lru_scan as _lru
from repro.kernels.secure_matmul import secure_matmul as _smm


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _mode(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if on_tpu() else "ref"


def mlp_softmax_attn(q, k, v, w1, b1, w2, b2, *, impl="auto", **kw):
    m = _mode(impl)
    if m == "ref":
        return _ref.mlp_softmax_attn(q, k, v, w1, b1, w2, b2)
    return _msa(q, k, v, w1, b1, w2, b2, interpret=(m == "interpret"), **kw)


def flash_attn(q, k, v, *, causal=True, impl="auto", **kw):
    m = _mode(impl)
    if m == "ref":
        return _ref.flash_attn(q, k, v, causal=causal)
    return _fa(q, k, v, causal=causal, interpret=(m == "interpret"), **kw)


def entropy_head(logits, *, impl="auto", **kw):
    m = _mode(impl)
    if m == "ref":
        return _ref.entropy_head(logits)
    return _eh(logits, interpret=(m == "interpret"), **kw)


def ssd_chunked(x, a, b, c, *, chunk=128, impl="auto", **kw):
    """x: (B, T, H, P), a: (B, T, H), b/c: (B, T, N) — layout adapter
    around the kernel's (B, H, nc, Q, ...) arrangement."""
    m = _mode(impl)
    if m == "ref":
        return _ref.ssd(x, a, b, c)
    bs, t, h, p = x.shape
    q = min(chunk, t)
    assert t % q == 0
    nc = t // q
    xk = jnp.moveaxis(x.reshape(bs, nc, q, h, p), 3, 1)       # B H nc Q P
    ak = jnp.moveaxis(a.reshape(bs, nc, q, h), 3, 1)          # B H nc Q
    bk = b.reshape(bs, nc, q, -1)
    ck = c.reshape(bs, nc, q, -1)
    y = _ssd(xk, ak, bk, ck, interpret=(m == "interpret"), **kw)
    return jnp.moveaxis(y, 1, 3).reshape(bs, t, h, p)


def rg_lru_scan(a, b, *, impl="auto", **kw):
    m = _mode(impl)
    if m == "ref":
        return _ref.rg_lru(a, b)
    return _lru(a, b, interpret=(m == "interpret"), **kw)


def _tileable(dim: int, blk: int) -> bool:
    """The kernel shrinks each block to min(blk, dim) and requires the
    result to divide dim exactly."""
    return dim % min(blk, dim) == 0


def _pad_target(dim: int, blk: int) -> int:
    """Smallest tileable dim >= dim: a multiple of blk (dims <= blk are
    already tileable at block min(blk, dim)). Bounded < 2x per dim."""
    return dim if _tileable(dim, blk) else -(-dim // blk) * blk


_log = logging.getLogger(__name__)
_fallback_warned = False

# kernel-vs-ref dispatch counters (trace-time): the executor snapshots
# these around a phase to witness that fused RING32 combines actually
# ran through the kernel, not the silent ref fallback
_smm_stats = {"kernel": 0, "ref": 0, "padded": 0}


def smm_stats() -> dict:
    """Snapshot of the secure_matmul dispatch counters."""
    return dict(_smm_stats)


def reset_smm_stats() -> None:
    for k in _smm_stats:
        _smm_stats[k] = 0


def _warn_fallback(shape) -> None:
    global _fallback_warned
    if not _fallback_warned:
        _fallback_warned = True
        _log.warning(
            "secure_matmul: non-tileable shape %s fell back to the jnp "
            "reference combine (pad=False). Results are bitwise "
            "identical, but this shape is NOT running the kernel — "
            "pass pad=True (default) to pad-to-tile instead. "
            "(Further fallbacks are counted in smm_stats(), not logged.)",
            tuple(shape))


def secure_matmul(eps, dlt, a_sh, b_sh, c_sh, *, impl="auto", pad=True,
                  **kw):
    """Beaver post-open combine, both parties fused (MPC hot path).

    Non-tileable shapes are zero-PADDED to the next block multiple by
    default — exact in wrapping int32 ring arithmetic (zero rows/cols
    contribute zero to every product term and the padded output region
    is sliced away), so smoke geometries exercise the kernel instead of
    silently dropping to the reference. `pad=False` restores the old
    behaviour: fall back to the jnp reference, logged once per process
    and counted in `smm_stats()` so silent-cap drops stay visible.
    """
    m = _mode(impl)
    mm, kk = eps.shape
    nn = dlt.shape[1]
    blocks = (kw.get("bm", 128), kw.get("bn", 128), kw.get("bk", 128))
    dims = (mm, nn, kk)
    tiled = all(_tileable(d, blk) for d, blk in zip(dims, blocks))
    if m != "ref" and not tiled and not pad:
        _warn_fallback((mm, kk, nn))
        m = "ref"
    if m == "ref":
        _smm_stats["ref"] += 1
        return jnp.stack([
            _ref.secure_matmul_combine(eps, dlt, a_sh[0], b_sh[0], c_sh[0], 0),
            _ref.secure_matmul_combine(eps, dlt, a_sh[1], b_sh[1], c_sh[1], 1),
        ])
    if not tiled:
        pm, pn, pk = (_pad_target(d, blk) for d, blk in zip(dims, blocks))
        eps = jnp.pad(eps, ((0, pm - mm), (0, pk - kk)))
        dlt = jnp.pad(dlt, ((0, pk - kk), (0, pn - nn)))
        a_sh = jnp.pad(a_sh, ((0, 0), (0, pm - mm), (0, pk - kk)))
        b_sh = jnp.pad(b_sh, ((0, 0), (0, pk - kk), (0, pn - nn)))
        c_sh = jnp.pad(c_sh, ((0, 0), (0, pm - mm), (0, pn - nn)))
        _smm_stats["padded"] += 1
    _smm_stats["kernel"] += 1
    z = _smm(eps, dlt, a_sh, b_sh, c_sh, interpret=(m == "interpret"), **kw)
    if not tiled:
        z = z[:, :mm, :nn]
    return z
