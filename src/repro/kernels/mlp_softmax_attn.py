r"""Fused MLP-softmax attention — the paper's hot spot, TPU-native.

SelectFormer replaces softmax(scores) with a 2-layer MLP along the KV
axis: probs = relu(S @ W1 + b1) @ W2 + b2. We exploit associativity:

    out = probs @ V
        = relu(S @ W1 + b1) @ (W2 @ V)  +  b2 @ V
                 \_ H _/        \_ U _/     \_ u0 _/

so the (Sq x Skv) probs matrix NEVER materializes: the kernel streams KV
tiles, accumulating the tiny H = S @ W1 (bq x hid) in VMEM, then applies
one fused epilogue H_relu @ U. HBM traffic per q tile: Q, K tiles, and a
(bq x Dh) output — probs never leave VMEM (they never even exist).

Grid: (BH, Sq/bq, Skv/bk), KV innermost. Scratch: H (bq, hid) f32,
persisting across the KV loop (TPU sequential grid semantics).

MXU alignment: bq, bk multiples of 128; hid is zero-padded to >= 128 by
ops.py (the pad columns of W1 are zero, contributing nothing).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, w1_ref, b1_ref, u_ref, u0_ref, o_ref, h_acc,
            *, nk: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        h_acc[...] = jnp.zeros_like(h_acc)

    q = q_ref[0].astype(jnp.float32)                      # (bq, dh)
    k = k_ref[0].astype(jnp.float32)                      # (bk, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    w1 = w1_ref[...].astype(jnp.float32)                  # (bk, hid)
    h_acc[...] += jnp.dot(s, w1, preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _epilogue():
        h = jax.nn.relu(h_acc[...] + b1_ref[...].astype(jnp.float32))
        u = u_ref[0].astype(jnp.float32)                  # (hid, dh)
        out = jnp.dot(h, u, preferred_element_type=jnp.float32)
        o_ref[0, ...] = (out + u0_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def mlp_softmax_attn(q, k, v, w1, b1, w2, b2, *, bq: int = 128,
                     bk: int = 128, interpret: bool = False):
    """q,k,v: (BH, S, Dh); w1: (S, hid); w2: (hid, S); b1: (hid,); b2: (S,)."""
    bh, sq, dh = q.shape
    skv = k.shape[1]
    hid = w1.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0
    nq, nk = sq // bq, skv // bk
    scale = dh ** -0.5

    # precompute U = W2 @ V and u0 = b2 @ V (cheap: hid*S*dh, 1*S*dh)
    u = jnp.einsum("hs,bsd->bhd", w2.astype(jnp.float32),
                   v.astype(jnp.float32))
    u0 = jnp.einsum("s,bsd->bd", b2.astype(jnp.float32),
                    v.astype(jnp.float32))[:, None]

    grid = (bh, nq, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, iq, ik: (b, iq, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, iq, ik: (b, ik, 0)),
            pl.BlockSpec((bk, hid), lambda b, iq, ik: (ik, 0)),
            pl.BlockSpec((hid,), lambda b, iq, ik: (0,)),
            pl.BlockSpec((1, hid, dh), lambda b, iq, ik: (b, 0, 0)),
            pl.BlockSpec((1, 1, dh), lambda b, iq, ik: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, iq, ik: (b, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, hid), jnp.float32)],
        interpret=interpret,
    )(q, k, w1, b1, u, u0)
    return out
