"""Pallas TPU kernels for the performance-critical compute layers.

  mlp_softmax_attn.py  the paper's hot spot, algebraically fused:
                       out = relu(QK^T @ W1 + b1) @ (W2 @ V) + b2 @ V —
                       the S x S "probs" matrix never materializes.
  flash_attn.py        exact-softmax flash attention (baseline / targets)
  entropy_head.py      fused softmax+entropy over logits (what MLP_se
                       replaces — the Oracle's scoring op)
  ssd.py               Mamba-2 chunked SSD with VMEM-resident state carry
  rg_lru.py            RG-LRU linear recurrence, chunked time tiles
  secure_matmul.py     int32-ring Beaver matmul combine (TPU MPC path)

Each kernel: pl.pallas_call + explicit BlockSpec VMEM tiling. ops.py is
the jit'd dispatch wrapper (interpret=True off-TPU); ref.py holds the
pure-jnp oracles the tests sweep against.
"""
from repro.kernels import ops, ref
