"""Fused softmax+entropy over logits — the Oracle's scoring op.

H(row) = m + log(z) - s/z with online accumulators over vocab tiles:
  m = running max, z = sum exp(x - m), s = sum x * exp(x - m).
One pass over the (R, V) logits; never materializes probabilities.
(SelectFormer's MLP_se replaces exactly this computation under MPC; on
TPU in the clear this kernel is the fair baseline for benchmarks.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(x_ref, o_ref, m_acc, z_acc, s_acc, *, nv: int):
    iv = pl.program_id(1)

    @pl.when(iv == 0)
    def _init():
        m_acc[...] = jnp.full_like(m_acc, NEG_INF)
        z_acc[...] = jnp.zeros_like(z_acc)
        s_acc[...] = jnp.zeros_like(s_acc)

    x = x_ref[...].astype(jnp.float32)                     # (br, bv)
    m_new = jnp.maximum(m_acc[...], jnp.max(x, -1, keepdims=True))
    alpha = jnp.exp(m_acc[...] - m_new)
    e = jnp.exp(x - m_new)
    z_acc[...] = z_acc[...] * alpha + jnp.sum(e, -1, keepdims=True)
    s_acc[...] = s_acc[...] * alpha + jnp.sum(x * e, -1, keepdims=True)
    m_acc[...] = m_new

    @pl.when(iv == nv - 1)
    def _epilogue():
        z = jnp.maximum(z_acc[...], 1e-30)
        h = m_acc[...] + jnp.log(z) - s_acc[...] / z
        o_ref[...] = h[:, 0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "bv", "interpret"))
def entropy_head(logits, *, br: int = 256, bv: int = 512,
                 interpret: bool = False):
    """logits: (R, V) -> entropy (R,) in fp32."""
    r, v = logits.shape
    br = min(br, r)
    bv = min(bv, v)
    assert r % br == 0 and v % bv == 0
    return pl.pallas_call(
        functools.partial(_kernel, nv=v // bv),
        grid=(r // br, v // bv),
        in_specs=[pl.BlockSpec((br, bv), lambda ir, iv: (ir, iv))],
        out_specs=pl.BlockSpec((br,), lambda ir, iv: (ir,)),
        out_shape=jax.ShapeDtypeStruct((r,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, 1), jnp.float32),
                        pltpu.VMEM((br, 1), jnp.float32),
                        pltpu.VMEM((br, 1), jnp.float32)],
        interpret=interpret,
    )(logits)
