from repro.parallel.sharding import (
    ShardRules, rules_scope, current_rules, shard, param_specs, batch_spec,
)
