"""Logical-axis sharding rules.

Models annotate activations with *logical* names (batch / seq / model /
expert / vocab); a ShardRules context maps them onto mesh axes. Outside a
rules scope every annotation is a no-op, so smoke tests and the CPU path
never touch device state.

Policy (DP x TP, pod = extra DP dim or MPC party axis):
  batch   -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
  model   -> "model" (attention heads, ffn hidden, vocab, experts)
  seq     -> None by default; the SP hillclimb maps it to "model" for
             norm/ffn regions (see EXPERIMENTS.md §Perf)
  wave    -> "data": the MPC wave executor's stacked-batch dim, so W
             coalesced batches shard across a pod's devices

Uneven shards (e.g. 14 heads on 16-way model axis, vocab 49155) are legal
under GSPMD; rules prefer even dims but never fail on uneven ones.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading

import numpy as np
import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardRules:
    mesh: Mesh
    mpc_pod_axis: bool = False     # pod axis reserved for MPC parties
    seq_axis: str | None = None    # set to "model" to enable SP
    fsdp: bool = True              # ZeRO-3: shard params over "data" too
    fsdp_layer_dim: bool = False   # ZeRO over the layer-STACK dim instead
    # of a feature dim: same memory saving, but the gathered slice never
    # conflicts with a contraction dim -> no GSPMD resharding (CP/A2A)

    @property
    def batch_axes(self):
        names = self.mesh.axis_names
        if "pod" in names and not self.mpc_pod_axis:
            return ("pod", "data")
        return ("data",) if "data" in names else (names[0],)

    def resolve(self, logical: str | None):
        if logical is None:
            return None
        if logical == "batch":
            ax = self.batch_axes
            return ax if len(ax) > 1 else ax[0]
        if logical == "model" or logical == "expert" or logical == "vocab":
            return "model" if "model" in self.mesh.axis_names else None
        if logical == "seq":
            return self.seq_axis
        if logical == "wave":
            # the MPC executor's wave dim: W coalesced batches spread
            # across the data axis so a pod mesh runs them on separate
            # devices and wave flights become per-device collectives
            return "data" if "data" in self.mesh.axis_names else None
        if logical == "pod":
            return "pod" if "pod" in self.mesh.axis_names else None
        if logical == "fsdp":
            # intra-pod ZeRO-3 axis: layer-wise param all-gathers stay on
            # ICI; pods keep full replicas (DCN carries only grad reduce)
            return "data" if self.fsdp and "data" in self.mesh.axis_names \
                else None
        return None

    def spec(self, *logical) -> P:
        return P(*(self.resolve(ax) for ax in logical))

    def sharding(self, *logical) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(*logical))


_state = threading.local()


def current_rules() -> ShardRules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def rules_scope(rules: ShardRules):
    prev = current_rules()
    _state.rules = rules
    try:
        yield rules
    finally:
        _state.rules = prev


def shard(x, *logical):
    """Annotate an activation with logical axes (no-op without rules).
    Axes that don't divide the dim are dropped (never an error)."""
    r = current_rules()
    if r is None:
        return x
    spec = fit_spec(r, x.shape, logical)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def batch_spec(rules: ShardRules, ndim: int) -> NamedSharding:
    """Sharding for a (B, ...) input batch tensor."""
    return rules.sharding(*(["batch"] + [None] * (ndim - 1)))


def axis_size(rules: ShardRules, resolved) -> int:
    if resolved is None:
        return 1
    if isinstance(resolved, tuple):
        n = 1
        for a in resolved:
            n *= rules.mesh.shape[a]
        return n
    return rules.mesh.shape[resolved]


def fit_spec(rules: ShardRules, shape, logical_axes) -> P:
    """Resolve logical axes, dropping any that don't divide the dim or
    that would reuse a mesh axis already claimed by an earlier dim
    (e.g. SP maps seq->model, so vocab->model must yield)."""
    out = []
    used: set = set()
    for dim, logical in zip(shape, logical_axes):
        ax = rules.resolve(logical)
        names = (set(ax) if isinstance(ax, tuple) else {ax}) - {None}
        if ax is not None and not (names & used) and \
                dim % axis_size(rules, ax) == 0 and \
                dim >= axis_size(rules, ax):
            out.append(ax)
            used |= names
        else:
            out.append(None)
    return P(*out)


def place(x, *logical):
    """Physically place a concrete array on the ambient rules' mesh
    (`jax.device_put`, not just an annotation). Outside a rules scope
    this is a no-op, mirroring `shard`; non-dividing axes are dropped
    the same way. This is what the wave executor calls on each wave's
    input shares so the party axis lands on "pod" devices and the wave
    axis spreads over "data" devices for real."""
    r = current_rules()
    if r is None:
        return x
    spec = fit_spec(r, x.shape, logical)
    return jax.device_put(x, NamedSharding(r.mesh, spec))


def place_party_tree(tree):
    """device_put every array leaf of a share pytree with its leading
    party axis on "pod" (remaining dims replicated). Used for the
    proxy-weight shares: one placement per phase, after which every
    eager op runs under GSPMD with the party components resident on
    their pod's devices."""
    r = current_rules()
    if r is None:
        return tree

    def one(leaf):
        spec = fit_spec(r, leaf.shape,
                        ("pod",) + (None,) * (leaf.ndim - 1))
        return jax.device_put(leaf, NamedSharding(r.mesh, spec))
    return jax.tree_util.tree_map(one, tree)


def force_host_devices(n: int) -> int:
    """Ask XLA for `n` virtual host-platform devices (CPU CI's stand-in
    for a pod). Only effective BEFORE the jax backend initializes —
    set `XLA_FLAGS=--xla_force_host_platform_device_count=N` in the
    environment (the CI smoke-mesh job does) to be safe; this helper
    covers script entrypoints that run before any device query.
    Returns the realized device count."""
    flag = f"--xla_force_host_platform_device_count={n}"
    cur = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in cur:
        os.environ["XLA_FLAGS"] = f"{cur} {flag}".strip()
    return len(jax.devices())


def party_wave_rules(n_parties: int, *, devices=None,
                     max_data: int | None = None) -> ShardRules:
    """Mesh + rules for the MPC executor: party axis -> "pod", wave
    axis -> "data".

    pod = n_parties when the device count divides evenly (each party's
    share components live on its own pod slice; GSPMD inserts the
    cross-party collectives at the open/reconstruct sites), else pod
    collapses to 1 and the party axis stays replicated. The remaining
    devices form the "data" axis the wave dim shards over. `max_data`
    caps the data axis (shard_map needs it to divide the lane count).
    """
    devices = list(jax.devices()) if devices is None else list(devices)
    n = len(devices)
    pod = n_parties if n % n_parties == 0 and n >= n_parties else 1
    data = n // pod
    if max_data is not None:
        data = min(data, max_data)
        while data > 1 and max_data % data != 0:
            data -= 1
    if pod > 1:
        arr = np.array(devices[:pod * data]).reshape(pod, data)
        mesh = Mesh(arr, ("pod", "data"))
    else:
        mesh = Mesh(np.array(devices[:data]), ("data",))
    return ShardRules(mesh, mpc_pod_axis=True, fsdp=False)


def data_axis_size(rules: ShardRules) -> int:
    return axis_size(rules, rules.resolve("wave"))


# ---------------------------------------------------------------------------
# parameter sharding by pytree path
# ---------------------------------------------------------------------------

def _spec_for_path(path: str, leaf, rules: ShardRules) -> P:
    nd = leaf.ndim
    shape = leaf.shape

    def pad(logical):                   # right-pad logical axes to ndim
        return fit_spec(rules, shape, [None] * (nd - len(logical)) + logical)

    def first_fit(*candidates):
        """First candidate spec that actually shards something."""
        for cand in candidates:
            spec = pad(cand)
            if any(s is not None for s in spec):
                return spec
        return P(*([None] * nd))

    if "unembed" in path:               # (d, V): V on TP axis so the head
        return first_fit(["fsdp", "model"], [None, "model"], ["model", None])
    if "embed" in path:                 # (V, d): V on TP axis — critical for
        # tied heads: embed.T then contracts d (fsdp) x V (model) without
        # materializing full-vocab logits (no 40 GB all-gather)
        return first_fit(["model", "fsdp"], ["model", None], [None, "model"])
    if "router" in path:
        return P(*([None] * nd))
    if "moe" in path and ("wi" in path or "wo" in path):
        # (L, E, d, f): EP over experts + ZeRO over d; else TP over f/d
        ep = fit_spec(rules, shape, [None, "expert", "fsdp", None]
                      if "wi" in path else [None, "expert", None, "fsdp"])
        if ep[1] is not None:
            return ep
        return first_fit([None, None, "fsdp", "model"]) if "wi" in path \
            else first_fit([None, None, "model", "fsdp"])
    if any(k in path for k in ("wq", "wk", "wv", "w_in", "wi", "w_gate_br",
                               "w_a", "w_x")):
        # output features on TP axis, input features on ZeRO axis
        if rules.fsdp_layer_dim and nd >= 3:
            return first_fit(["fsdp"] + [None] * (nd - 3) + [None, "model"],
                             [None, "model"], ["model", None])
        return first_fit(["fsdp", "model"], [None, "model"], ["model", None])
    if any(k in path for k in ("wo", "w_out")):
        if rules.fsdp_layer_dim and nd >= 3:
            return first_fit(["fsdp"] + [None] * (nd - 3) + ["model", None],
                             ["model", None], [None, "model"])
        return first_fit(["model", "fsdp"], ["model", None], [None, "model"])
    if any(k in path for k in ("bq", "bk", "bv", "conv_", "b_a", "b_x")):
        return pad(["model"])
    return P(*([None] * nd))            # norms, scalars: replicate


def param_specs(params, rules: ShardRules):
    """Pytree of NamedSharding matching `params`."""
    def one(path_tuple, leaf):
        path = "/".join(str(getattr(p, "key", p)) for p in path_tuple)
        return NamedSharding(rules.mesh, _spec_for_path(path, leaf, rules))
    return jax.tree_util.tree_map_with_path(one, params)
