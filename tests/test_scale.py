"""Scale-carrying shares: the cross-op deferred-truncation IR.

Contracts (ISSUE 5):
  1. LATTICE — mpc/scale.py's pure decision procedure: pow2 detection,
     the 2f headroom cap, largest-first forced-trunc planning.
  2. METADATA — `Share.fb` is static pytree aux like `proto`: preserved
     by with_sh / layout ops / flatten-unflatten on BOTH protocol
     backends; `reveal` decodes exactly at any carried exponent.
  3. FOLDS — mul_public by ±2**k is free (no records, no rounding);
     negative and general public scalars stay correct.
  4. GUARD — double-mul chains that would overflow RING32 at 3f hit the
     forced-trunc guard (a real dealer trunc fires, values stay right);
     squares and repeated consumers truncate ONCE (the force memo);
     forcing a broadcast bills the pre-broadcast element count
     (lineage); ReLU is truncation-free (bits at exponent 0).
  5. QUICKSELECT — comparisons force to canonical scale before
     reveal_lt: the selected set and the per-wave comparison ledger are
     pinned bitwise against the canonical-input run.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.engine import MPCEngine
from repro.mpc import compare, ops as mops, quickselect, scale
from repro.mpc.comm import ledger_scope
from repro.mpc.ring import RING32, RING64
from repro.mpc.sharing import Share, reveal, share

K = jax.random.key(7)


def _k(i):
    return jax.random.fold_in(K, i)


# ---------------------------------------------------------------------------
# 1. the lattice algebra
# ---------------------------------------------------------------------------

class TestLattice:
    def test_pow2_exponent(self):
        assert scale.pow2_exponent(2.0) == 1
        assert scale.pow2_exponent(0.25) == -2
        assert scale.pow2_exponent(-0.5) == -1
        assert scale.pow2_exponent(1.0) == 0
        assert scale.pow2_exponent(1 / 32) == -5
        for not_pow2 in (1.5, 0.3, 0.0, 3.0, float("inf"), float("nan"),
                         np.ones(3), "x", None):
            assert scale.pow2_exponent(not_pow2) is None, not_pow2

    @pytest.mark.parametrize("f", [12, 16])
    def test_mul_plan(self, f):
        # canonical inputs ride to 2f untruncated
        assert scale.mul_plan(f, f, f) == (0, 0, 2 * f)
        # one deferred operand: exactly its excess is forced
        assert scale.mul_plan(2 * f, f, f) == (f, 0, 2 * f)
        assert scale.mul_plan(f, 2 * f, f) == (0, f, 2 * f)
        # both deferred: both force back to canonical
        assert scale.mul_plan(2 * f, 2 * f, f) == (f, f, 2 * f)
        # a comparison bit (exponent 0) multiplies for free
        assert scale.mul_plan(2 * f, 0, f) == (0, 0, 2 * f)
        # folded exponent above 2f: only the overhang is forced
        assert scale.mul_plan(2 * f + 3, 0, f) == (3, 0, 2 * f)
        # square at equal exponents plans equal shifts (one memoized
        # trunc when the operands are the same object)
        px, py, out = scale.mul_plan(f + 5, f + 5, f)
        assert px == py == 5 and out == 2 * f

    def test_align_target(self):
        f = 12
        assert scale.align_target(f, f, f) == f
        assert scale.align_target(f, f + 5, f) == f + 5        # lift
        assert scale.align_target(2 * f, f, f) == 2 * f
        # equal above-cap exponents pass through (pure reinterpretation)
        assert scale.align_target(2 * f + 5, 2 * f + 5, f) == 2 * f + 5
        # unequal above-cap clamps to the 2f headroom cap
        assert scale.align_target(2 * f, 2 * f + 5, f) == 2 * f


# ---------------------------------------------------------------------------
# 2. scale metadata through the container
# ---------------------------------------------------------------------------

class TestScaleMetadata:
    @pytest.mark.parametrize("proto", ["2pc", "3pc"])
    def test_pytree_roundtrip_preserves_scale(self, proto, x64):
        s = share(_k(0), jnp.ones((2, 3)), RING64, proto)
        z = mops.mul(s, s, _k(1))            # rides at 2f
        leaves, treedef = jax.tree.flatten(z)
        z2 = jax.tree.unflatten(treedef, leaves)
        assert (z2.fb, z2.proto) == (2 * RING64.frac_bits, proto)
        assert np.array_equal(np.asarray(z.sh), np.asarray(z2.sh))

    @pytest.mark.parametrize("proto", ["2pc", "3pc"])
    def test_with_sh_preserves_proto_and_scale(self, proto, x64):
        s = share(_k(2), jnp.ones((4,)), RING64, proto)
        z = mops.mul(s, s, _k(3))
        rebuilt = z.with_sh(-z.sh)
        assert (rebuilt.proto, rebuilt.fb, rebuilt.n_parties) == \
            (proto, 2 * RING64.frac_bits, z.n_parties)

    @pytest.mark.parametrize("proto", ["2pc", "3pc"])
    def test_layout_ops_propagate_scale(self, proto, x64):
        v = np.random.default_rng(0).normal(size=(2, 3, 4)) * 0.5
        eng = MPCEngine(protocol=proto).with_key(_k(4))
        s = share(_k(5), jnp.asarray(v, jnp.float32), RING64, proto)
        z = mops.mul(s, s, _k(6))            # 2f
        want = (v * v)
        for got, ref in (
                (eng.moveaxis(z, -1, 0), np.moveaxis(want, -1, 0)),
                (eng.swapaxes(z, -1, -2), np.swapaxes(want, -1, -2)),
                (eng.reshape(z, (6, 4)), want.reshape(6, 4)),
                (eng.broadcast(eng.reshape(z, (2, 3, 4)), (2, 2, 3, 4)),
                 np.broadcast_to(want, (2, 2, 3, 4)))):
            assert got.fb == 2 * RING64.frac_bits
            assert np.allclose(np.asarray(reveal(got)), ref, atol=1e-3)

    def test_reveal_decodes_at_carried_scale_exactly(self, x64):
        s = share(_k(7), jnp.asarray([1.5, -2.25, 0.125]), RING64)
        z = mops.mul_public(s, 0.25)         # free fold, no rounding
        assert z.fb == RING64.frac_bits + 2
        got = np.asarray(reveal(z))
        assert np.array_equal(got, np.asarray([0.375, -0.5625, 0.03125]))


# ---------------------------------------------------------------------------
# 3. public rescales
# ---------------------------------------------------------------------------

class TestPublicScalars:
    def test_pow2_fold_is_free(self, x64):
        s = share(_k(10), jnp.asarray([2.0, -3.0]), RING64)
        with ledger_scope() as led:
            z = mops.mul_public(s, 1 / 32, key=_k(11))
        assert not led.records                # no wire, no dealer
        assert z.fb == RING64.frac_bits + 5
        assert np.allclose(np.asarray(reveal(z)), [0.0625, -0.09375])

    def test_negative_pow2_folds_with_negation(self, x64):
        s = share(_k(12), jnp.asarray([2.0, -3.0]), RING64)
        z = mops.mul_public(s, -0.5, key=_k(13))
        assert z.fb == RING64.frac_bits + 1
        assert np.allclose(np.asarray(reveal(z)), [-1.0, 1.5])

    def test_negative_general_scalar(self, x64):
        s = share(_k(14), jnp.asarray([2.0, -3.0]), RING64)
        z = mops.mul_public(s, -1.5, key=_k(15))
        assert z.fb == 2 * RING64.frac_bits   # encoded at f, emitted 2f
        assert np.allclose(np.asarray(reveal(z)), [-3.0, 4.5], atol=1e-3)

    def test_general_scalar_on_deferred_input_forces_once(self):
        s = share(_k(16), jnp.asarray([1.0, 2.0]), RING32)
        z = mops.mul(s, s, _k(17))            # 2f
        with ledger_scope() as led:
            out = mops.mul_public(z, 1.5, key=_k(18))
        assert [r.op for r in led.records] == ["offline.trunc_pair",
                                               "trunc_open"]
        assert out.fb == 2 * RING32.frac_bits
        assert np.allclose(np.asarray(reveal(out)), [1.5, 6.0], atol=1e-2)


# ---------------------------------------------------------------------------
# 4. the forced-trunc guard
# ---------------------------------------------------------------------------

class TestForcedGuard:
    def test_double_mul_chain_fires_guard_on_ring32(self):
        """f -> 2f -> 3f would overflow the 32-bit ring (3f = 36 bits):
        the headroom plan forces the 2f operand back to canonical with
        a REAL dealer trunc, and the product lands correct at 2f."""
        vals = jnp.asarray([3.0, -2.5, 1.25])
        x = share(_k(20), vals, RING32)
        y = share(_k(21), vals, RING32)
        z = share(_k(22), vals, RING32)
        a = mops.mul(x, y, _k(23))
        assert a.excess == RING32.frac_bits
        with ledger_scope() as led:
            b = mops.mul(a, z, _k(24))
        trunc_ops = [r.op for r in led.records if "trunc" in r.op]
        assert trunc_ops == ["offline.trunc_pair", "trunc_open"], \
            "the forced-trunc guard must fire exactly once"
        assert b.fb == 2 * RING32.frac_bits
        want = np.asarray(vals) ** 3
        assert np.allclose(np.asarray(reveal(b)), want, atol=2e-2)

    def test_square_of_deferred_value_truncs_once(self):
        s = share(_k(25), jnp.asarray([1.5, 0.5]), RING32)
        z = mops.mul(s, s, _k(26))
        with ledger_scope() as led:
            z2 = mops.mul(z, z, _k(27))       # (2f, 2f) same object
        assert sum(1 for r in led.records if r.op == "trunc_open") == 1
        assert np.allclose(np.asarray(reveal(z2)),
                           np.asarray([1.5, 0.5]) ** 4, atol=2e-2)

    def test_cap_is_ring_parameterized(self):
        """RING64 has sign + headroom for a third fraction (3f = 48 <
        63); RING32 does not (3f = 36 > 31); no bit width means the
        conservative 2f contract."""
        assert scale.cap(RING64.frac_bits, RING64.bits) == \
            3 * RING64.frac_bits
        assert scale.cap(RING32.frac_bits, RING32.bits) == \
            2 * RING32.frac_bits
        assert scale.cap(16) == 32

    def test_double_mul_chain_defers_on_ring64(self, x64):
        """The exact chain that forces a dealer trunc on RING32 rides
        to 3f force-free under the RING64 headroom cap — but ONLY on a
        backend whose truncation is exact at any exponent (aby3trunc
        trunc2 here; spdz2pc's MAC'd pairs likewise). This is the
        ring-cap dividend bench_fusion tracks as
        ring64_trunc_event_delta."""
        vals = jnp.asarray([3.0, -2.5, 1.25])
        x = share(_k(40), vals, RING64, "aby3trunc")
        y = share(_k(41), vals, RING64, "aby3trunc")
        z = share(_k(42), vals, RING64, "aby3trunc")
        a = mops.mul(x, y, _k(43))
        assert a.excess == RING64.frac_bits
        with ledger_scope() as led:
            b = mops.mul(a, z, _k(44))
        assert not [r.op for r in led.records if "trunc" in r.op], \
            "3f fits RING64 headroom: no forced truncation"
        assert b.fb == 3 * RING64.frac_bits
        want = np.asarray(vals) ** 3
        assert np.allclose(np.asarray(reveal(b)), want, atol=2e-2)
        # the exactness guard: default 2pc's RING64 truncation is a
        # probabilistic local shift (wrap prob ~ encoded/2**63 — 2**16x
        # worse at 3f), so the lattice denies it the deferral: the same
        # chain forces back under the 2f cap and stays correct
        x2, y2, z2 = (share(_k(45 + i), vals, RING64) for i in range(3))
        b2 = mops.mul(mops.mul(x2, y2, _k(48)), z2, _k(49))
        assert b2.fb == 2 * RING64.frac_bits
        assert np.allclose(np.asarray(reveal(b2)), want, atol=2e-2)

    def test_force_memo_spans_consumers(self):
        """Two independent consumers of one deferred tensor pay ONE
        truncation (the ops.force cache) — the event reduction the
        acceptance gate counts."""
        s = share(_k(28), jnp.asarray([1.0, -1.0, 2.0]), RING32)
        z = mops.mul(s, s, _k(29))
        w = share(_k(30), jnp.asarray([0.5, 0.5, 0.5]), RING32)
        with ledger_scope() as led:
            mops.mul(z, w, _k(31))
            mops.mul(z, w, _k(32))
        assert sum(1 for r in led.records if r.op == "trunc_open") == 1

    def test_broadcast_force_bills_preblast_numel(self):
        """Lineage: forcing a broadcast truncates the SOURCE (n elems),
        not the broadcast (n * rows) — fewer dealer pair bytes for the
        same event."""
        eng = MPCEngine(RING32).with_key(_k(33))
        s = share(_k(34), jnp.asarray([1.0, 2.0]), RING32)
        z = mops.mul(s, s, _k(35))            # (2,) at 2f
        zb = eng.broadcast(eng.reshape(z, (1, 2)), (64, 2))
        with ledger_scope() as led:
            mops.force(zb, _k(36))
        (pair, opn) = led.records
        assert (pair.op, pair.numel) == ("offline.trunc_pair", 4)
        assert (opn.op, opn.numel) == ("trunc_open", 2)   # NOT 128

    def test_relu_is_truncation_free_on_deferred_input(self):
        """Comparison bits share at exponent 0: ReLU of a 2f tensor
        records a comparison and a multiply — no truncation anywhere —
        and keeps the carried exponent."""
        s = share(_k(37), jnp.asarray([1.5, -0.5, 2.0]), RING32)
        z = mops.mul(s, s, _k(38))
        with ledger_scope() as led:
            r = compare.relu(z, _k(39))
        assert not any("trunc" in rec.op for rec in led.records)
        assert r.fb == z.fb
        assert np.allclose(np.asarray(reveal(r)),
                           np.maximum(np.asarray([1.5, -0.5, 2.0]) ** 2, 0),
                           atol=2e-2)

    def test_3pc_force_prices_rereplication_bytes(self, x64):
        """The PR 4 follow-up: a keyed 3PC truncation is no longer free
        — one output component rides the resharing flight (0 rounds)."""
        s = share(_k(40), jnp.asarray([1.0, 2.0, 3.0]), RING64, "3pc")
        z = mops.mul(s, s, _k(41))
        with ledger_scope() as led:
            mops.force(z, _k(42))
        (rec,) = led.records
        assert (rec.op, rec.rounds, rec.tag) == ("trunc_reshare", 0, "bw")
        assert rec.nbytes == RING64.elem_bytes * 3
        assert led.offline_nbytes == 0        # still dealer-free


# ---------------------------------------------------------------------------
# 4b. multi-layer RING32: the above-cap align-down must be a KEYED trunc
# ---------------------------------------------------------------------------

class TestMultiLayerRing32:
    """Layer >= 2 is where the 2f residual meets a pow2-folded mean
    above the cap: the centering sub must down-trunc the mean with the
    dealer (exact), never a keyless local shift whose share-wrap
    probability at fb > 2f corrupts rows silently. Pinned by parity AND
    by the mirror (the align-down is a real, mirrored trunc event)."""

    L = 2

    def _setup(self):
        import dataclasses
        from repro.configs.paper_targets import TINY_TARGET
        from repro.core import proxy as proxy_mod
        from repro.core.proxy import ProxySpec
        cfg = dataclasses.replace(TINY_TARGET, vocab_size=64, n_layers=2,
                                  d_model=32, n_heads=2, n_kv_heads=2,
                                  d_head=16, d_ff=64)
        spec = ProxySpec(self.L, 2, 4)
        pp = proxy_mod.random_proxy(_k(60), cfg, spec, seq_len=8,
                                    n_classes=3)
        return cfg, spec, pp

    def test_two_layer_ring32_parity(self):
        from repro.core import proxy as proxy_mod
        from repro.engine import ClearEngine, proxy_entropy
        cfg, spec, pp = self._setup()
        tok = jnp.asarray(np.random.default_rng(8).integers(
            0, cfg.vocab_size, (32, 8)))
        clear = np.asarray(proxy_entropy(ClearEngine(), pp, cfg, tok, spec))
        pp_sh = proxy_mod.share_proxy(_k(61), pp, RING32)
        x = jnp.take(pp["embed"], tok, axis=0) * (cfg.d_model ** 0.5)
        x_sh = share(_k(62), x.astype(jnp.float32), RING32)
        eng = MPCEngine(RING32).with_key(_k(63))
        got = np.asarray(reveal(proxy_entropy(eng, pp_sh, cfg, x_sh, spec)))
        # every row, not just the max: wrap corruption is row-sparse
        assert np.abs(got - clear).max() < 5e-3, np.abs(got - clear).max()

    @pytest.mark.parametrize("proto", ["2pc", "3pc"])
    def test_two_layer_mirror_holds(self, proto):
        from repro.engine import TraceEngine, abstract_shares
        from repro.mpc import costs
        cfg, spec, pp = self._setup()
        pp_sh = abstract_shares(cfg, spec, 8, 3, RING32, proto)
        led = TraceEngine(RING32, protocol=proto).probe(
            pp_sh, cfg, spec, (6, 8, cfg.d_model))
        ana = costs.proxy_exec_cost(6, 8, cfg.d_model, spec.n_heads,
                                    cfg.n_kv_heads, cfg.d_head,
                                    spec.mlp_dim, 3, spec.n_layers,
                                    ring=RING32, protocol=proto)
        assert len(led.records) == len(ana.records)
        for got, want in zip(led.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag), (got, want)


# ---------------------------------------------------------------------------
# 5. quickselect under scale-carrying scores
# ---------------------------------------------------------------------------

class TestQuickselectScale:
    N, TOPK = 48, 16

    @pytest.fixture
    def canonical(self, x64):
        vals = jnp.asarray(np.random.default_rng(5).normal(size=self.N),
                           jnp.float32)
        return share(_k(50), vals)

    def test_deferred_scores_select_same_set(self, canonical, x64):
        """`lift` is value-preserving, so the top-k of the 2f-scale pool
        must equal the canonical run's — comparisons force first."""
        deferred = mops.lift(canonical, RING64.frac_bits)
        assert deferred.excess == RING64.frac_bits
        base = quickselect.top_k_indices(canonical, self.TOPK, seed=3)
        got = quickselect.top_k_indices(deferred, self.TOPK, seed=3)
        assert np.array_equal(base, got)

    @pytest.mark.parametrize("wave", [1, 4])
    def test_per_wave_comparison_ledger_pinned(self, canonical, wave, x64):
        """Regression pin: after the entry force, every per-wave
        reveal_lt batch records EXACTLY the canonical run's flights —
        bitwise ledger agreement per wave (RING64 entry force is a free
        local shift, so the streams are identical end to end)."""
        deferred = mops.lift(canonical, RING64.frac_bits)
        with ledger_scope() as led_c:
            quickselect.top_k_indices(canonical, self.TOPK, seed=3,
                                      wave=wave)
        with ledger_scope() as led_d:
            quickselect.top_k_indices(deferred, self.TOPK, seed=3,
                                      wave=wave)
        recs_c = [(r.op, r.rounds, r.nbytes, r.numel, r.tag)
                  for r in led_c.records]
        recs_d = [(r.op, r.rounds, r.nbytes, r.numel, r.tag)
                  for r in led_d.records]
        assert recs_c == recs_d

    def test_entry_force_restores_canonical_compare_encoding(self, x64):
        """reveal_lt consumes canonical encodings: the pool is forced
        once up front, not per comparison batch."""
        vals = jnp.asarray([0.5, -1.0, 2.0, 1.0])
        deferred = mops.lift(share(_k(51), vals), RING64.frac_bits)
        idx = quickselect.top_k_indices(deferred, 2, seed=0)
        assert np.array_equal(idx, np.asarray([2, 3]))
