"""Device-mesh wave execution (parallel/sharding.py + the executor).

Three layers of coverage:
  1. RULES — ShardRules.resolve / fit_spec semantics: the mpc_pod_axis
     policy (pod reserved for parties, batch stays off it), uneven dims
     dropped rather than erroring, claimed-axis reuse dropped. Runs on
     any device count (axis *presence* drives resolve; a 1x1 mesh is
     enough).
  2. KERNEL PATH — kernels/ops.secure_matmul pad-to-tile: non-tileable
     shapes are zero-padded onto the Pallas kernel (interpret mode on
     CPU) bitwise-identically to the jnp reference; pad=False falls
     back to ref, counted (and logged once) instead of silently.
     Plus the shared cached_probe memo (engine/trace.py).
  3. MESH (marked `mesh`, needs 8 forced host devices) — fit_spec on a
     real pod x data mesh, party_wave_rules geometry, and the
     end-to-end contract: `_score_phase` under mesh="host" (party ->
     pod, wave -> data NamedSharding) and mesh="shardmap" (lanes split
     across the data axis) yields entropy scores BITWISE identical to
     the single-device run, ledger_agrees holds, and the fused RING32
     combines run through the secure_matmul kernel.

CI runs the mesh layer under
XLA_FLAGS=--xla_force_host_platform_device_count=8 (smoke-mesh job).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.engine import cached_probe, cached_probe_info
from repro.kernels import ops as kops
from repro.mpc.ring import RING32, RING64
from repro.parallel import sharding

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices — run under "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8")


def _mesh_1x1():
    return Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("pod", "data"))


class TestShardRules:
    def test_pod_axis_reserved_for_parties(self):
        r = sharding.ShardRules(_mesh_1x1(), mpc_pod_axis=True)
        assert r.resolve("pod") == "pod"
        assert r.resolve("wave") == "data"
        # batch must NOT claim the pod axis when it belongs to parties
        assert r.batch_axes == ("data",)
        assert r.resolve("batch") == "data"

    def test_pod_axis_joins_batch_without_mpc(self):
        r = sharding.ShardRules(_mesh_1x1(), mpc_pod_axis=False)
        assert r.resolve("batch") == ("pod", "data")

    def test_resolve_missing_axes(self):
        m = Mesh(np.array(jax.devices()[:1]), ("data",))
        r = sharding.ShardRules(m, mpc_pod_axis=True)
        assert r.resolve("pod") is None
        assert r.resolve("wave") == "data"
        assert r.resolve(None) is None

    def test_fit_spec_drops_reused_axis(self):
        # wave and batch both resolve to "data": the second claim must
        # yield (never a double-sharded spec), even at axis size 1
        r = sharding.ShardRules(_mesh_1x1(), mpc_pod_axis=True)
        spec = sharding.fit_spec(r, (4, 4), ("wave", "batch"))
        assert spec == P("data", None)

    @needs_mesh
    def test_fit_spec_uneven_dims_dropped(self):
        # a real pod(2) x data(4) mesh: dims that don't divide the axis
        # are dropped per-dim, the others still shard
        rules = sharding.party_wave_rules(2)
        assert rules.mesh.shape == {"pod": 2, "data": 4}
        spec = sharding.fit_spec(rules, (3, 5), ("pod", "wave"))
        assert spec == P(None, None)          # 3 % 2 != 0, 5 % 4 != 0
        spec = sharding.fit_spec(rules, (2, 8), ("pod", "wave"))
        assert spec == P("pod", "data")
        spec = sharding.fit_spec(rules, (2, 5, 8), ("pod", "wave", "batch"))
        # wave can't take data (5 % 4), so batch (-> data) still can
        assert spec == P("pod", None, "data")

    @needs_mesh
    def test_party_wave_rules_geometry(self):
        r2 = sharding.party_wave_rules(2)
        assert r2.mpc_pod_axis and r2.mesh.shape == {"pod": 2, "data": 4}
        # 8 devices don't split 3 ways: pod collapses, parties replicate
        r3 = sharding.party_wave_rules(3)
        assert "pod" not in r3.mesh.axis_names
        assert r3.mesh.shape == {"data": 8}
        # max_data clamps the data axis to a divisor of the lane count
        r = sharding.party_wave_rules(1, max_data=4)
        assert sharding.data_axis_size(r) == 4
        r = sharding.party_wave_rules(1, max_data=6)
        assert sharding.data_axis_size(r) in (1, 2, 3, 6)
        assert 6 % sharding.data_axis_size(r) == 0

    def test_shard_and_place_noop_without_rules(self):
        x = jnp.ones((4, 4))
        assert sharding.shard(x, "wave", None) is x
        assert sharding.place(x, "wave", None) is x


class TestSecureMatmulPad:
    def _rand(self, rng, *shape):
        return jnp.asarray(rng.integers(-2**20, 2**20, shape,
                                        dtype=np.int32))

    def _case(self, m, k, n, seed=0):
        rng = np.random.default_rng(seed)
        eps = self._rand(rng, m, k)
        dlt = self._rand(rng, k, n)
        a = self._rand(rng, 2, m, k)
        b = self._rand(rng, 2, k, n)
        c = self._rand(rng, 2, m, n)
        return eps, dlt, a, b, c

    def test_pad_to_tile_bitwise(self):
        # M=136 is NOT tileable at block 128 (136 % 128 != 0): the pad
        # path must zero-extend to 256, run the kernel, slice back —
        # exact wrapping int32 ring arithmetic, bitwise vs the reference
        args = self._case(136, 32, 64)
        before = kops.smm_stats()
        z_k = kops.secure_matmul(*args, impl="interpret")
        after = kops.smm_stats()
        z_r = kops.secure_matmul(*args, impl="ref")
        assert z_k.shape == (2, 136, 64)
        assert np.array_equal(np.asarray(z_k), np.asarray(z_r))
        assert after["kernel"] == before["kernel"] + 1
        assert after["padded"] == before["padded"] + 1

    def test_tileable_shape_skips_padding(self):
        args = self._case(64, 32, 64)
        before = kops.smm_stats()
        z_k = kops.secure_matmul(*args, impl="interpret")
        z_r = kops.secure_matmul(*args, impl="ref")
        after = kops.smm_stats()
        assert np.array_equal(np.asarray(z_k), np.asarray(z_r))
        assert after["kernel"] == before["kernel"] + 1
        assert after["padded"] == before["padded"]

    def test_pad_false_falls_back_counted(self):
        args = self._case(136, 32, 64, seed=1)
        before = kops.smm_stats()
        z = kops.secure_matmul(*args, impl="interpret", pad=False)
        after = kops.smm_stats()
        z_r = kops.secure_matmul(*args, impl="ref")
        assert np.array_equal(np.asarray(z), np.asarray(z_r))
        # the silent-cap drop is visible: counted as ref, warned once
        # (the explicit impl="ref" call lands after the snapshot)
        assert after["ref"] == before["ref"] + 1
        assert after["kernel"] == before["kernel"]
        assert kops._fallback_warned


class TestCachedProbe:
    def _geom(self):
        from repro.configs.base import ArchConfig
        from repro.core.proxy import ProxySpec
        cfg = ArchConfig(name="probe-cache-test", family="dense",
                         n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                         d_head=16, d_ff=64, vocab_size=64)
        return cfg, ProxySpec(1, 2, 4)

    def test_repeat_probe_hits_cache(self):
        cfg, spec = self._geom()
        kw = dict(batch=4, seq=8, classes=2, ring=RING64,
                  protocol="2pc", fused=True)
        led1 = cached_probe(cfg, spec, **kw)
        h0 = cached_probe_info().hits
        led2 = cached_probe(cfg, spec, **kw)
        assert cached_probe_info().hits == h0 + 1
        assert len(led1.records) == len(led2.records)
        assert led1.rounds == led2.rounds and led1.nbytes == led2.nbytes

    def test_cache_isolated_from_caller_mutation(self):
        cfg, spec = self._geom()
        kw = dict(batch=4, seq=8, classes=2, ring=RING32,
                  protocol="2pc", fused=False)
        led1 = cached_probe(cfg, spec, **kw)
        n = len(led1.records)
        led1.records.append(led1.records[0])    # caller-side mutation
        led2 = cached_probe(cfg, spec, **kw)
        assert len(led2.records) == n

    def test_distinct_geometries_miss(self):
        cfg, spec = self._geom()
        m0 = cached_probe_info().misses
        cached_probe(cfg, spec, batch=2, seq=8, classes=2, ring=RING64,
                     protocol="2pc", fused=False)
        cached_probe(cfg, spec, batch=2, seq=8, classes=2, ring=RING64,
                     protocol="3pc", fused=False)
        assert cached_probe_info().misses == m0 + 2


@needs_mesh
@pytest.mark.mesh
class TestMeshExecution:
    """End-to-end: _score_phase on 8 forced host devices must be
    bitwise identical to the single-device run, with agreeing ledgers
    and the fused RING32 combine on the kernel path."""

    def _setup(self):
        from benchmarks.common import tiny_exec_setup
        seq, classes, pool_n = 8, 2, 32
        cfg, spec, pp = tiny_exec_setup(0, seq=seq, n_classes=classes)
        pool = np.random.default_rng(0).integers(
            0, cfg.vocab_size, (pool_n, seq))
        return cfg, spec, pp, pool

    def _run(self, cfg, spec, pp, pool, **cfg_kw):
        from repro.core.executor import ExecConfig, WaveExecutor
        ex = WaveExecutor(ExecConfig(wave=4, batch=4, ring=RING32,
                                     **cfg_kw))
        ent = ex.score_phase(jax.random.key(7), pp, cfg, pool, spec)
        return np.asarray(ent.sh), ex.reports[-1]

    def test_host_and_shardmap_bitwise_vs_single_device(self):
        cfg, spec, pp, pool = self._setup()
        ref, rep0 = self._run(cfg, spec, pp, pool)
        assert rep0.agrees()
        for mode in ("host", "shardmap"):
            got, rep = self._run(cfg, spec, pp, pool, mesh=mode,
                                 combine="interpret")
            dev = rep.device
            assert np.array_equal(ref, got), \
                f"mesh={mode} changed entropy scores"
            assert rep.agrees(), f"mesh={mode} broke ledger agreement"
            assert dev.placement == mode
            assert dev.device_makespan_s > 0.0
            assert dev.combine_kernel > 0, \
                f"mesh={mode}: combines never hit the kernel"
            assert dev.combine_ref == 0
            if mode == "host":
                assert dev.mesh_axes == {"pod": 2, "data": 4}
                assert all(w.devices_used == 8 for w in dev.waves)
            else:
                assert all(w.devices_used == 4 for w in dev.waves)

    def test_host_mesh_3pc_party_axis_collapses(self):
        # 8 devices % 3 parties != 0: pod collapses to 1, the wave axis
        # still shards, and scores stay bitwise identical
        cfg, spec, pp, pool = self._setup()
        ref, _ = self._run(cfg, spec, pp, pool, protocol="3pc")
        got, rep = self._run(cfg, spec, pp, pool, protocol="3pc",
                             mesh="host")
        assert np.array_equal(ref, got)
        assert rep.agrees()
        assert "pod" not in rep.device.mesh_axes

    def test_shardmap_rejects_wire(self):
        from repro.core.executor import ExecConfig, WaveExecutor
        with pytest.raises(ValueError, match="shardmap"):
            WaveExecutor(ExecConfig(mesh="shardmap", wire="local"))
