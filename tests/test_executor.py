"""Wave executor: the §4.4 schedule realized, not just priced.

Three contracts:
  1. LEDGER AGREEMENT — the executor's recorded flights for a phase are
     exactly (integer equality) the inputs iosched.makespan prices, and
     the measured per-batch op stream matches mpc/costs.proxy_exec_cost
     record-for-record.
  2. SCHEDULE INVARIANCE — the four (coalesce, overlap) variants move
     flights around but never change a single share: scores are bitwise
     identical, so wave execution selects the same survivors as the
     serial path.
  3. PARITY — wave-MPC scores track the clear float path (and selection
     survivors agree between mode="clear" and mode="mpc").
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_targets import TINY_TARGET
from repro.core import iosched
from repro.core import proxy as proxy_mod
from repro.core.executor import ExecConfig, WaveExecutor
from repro.core.proxy import ProxySpec
from repro.mpc import comm, costs, quickselect
from repro.mpc.comm import WAN, Ledger, ledger_scope
from repro.mpc.ring import RING32, x64_scope

CFG = dataclasses.replace(TINY_TARGET, vocab_size=64, n_layers=1,
                          d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                          d_ff=64)
SPEC = ProxySpec(1, 2, 4)
SEQ, BATCH, WAVE, CLASSES = 8, 8, 4, 3
POOL = 48                        # 6 batches -> 2 waves of (4, 2)
K = jax.random.key(0)

VARIANTS = iosched.FIG7_VARIANTS


@pytest.fixture(scope="module")
def pool():
    return np.random.default_rng(0).integers(0, CFG.vocab_size, (POOL, SEQ))


@pytest.fixture(scope="module")
def pp():
    return proxy_mod.random_proxy(K, CFG, SPEC, seq_len=SEQ,
                                  n_classes=CLASSES)


@pytest.fixture(scope="module")
def executed(pp, pool):
    """All four schedule variants run on the same pool with the same
    per-batch keys -> {name: (scores_sh, PhaseReport)}. Pinned to the
    eager (fuse=False) stream: these tests assert the anatomy of the
    uncompressed flight ledger; the fused default is covered by
    tests/test_fusion.py and the bench_fusion smoke gates."""
    out = {}
    for name, (co, ov) in VARIANTS.items():
        ex = WaveExecutor(ExecConfig(wave=WAVE, coalesce=co, overlap=ov,
                                     batch=BATCH, fuse=False))
        ent = ex.score_phase(jax.random.fold_in(K, 1), pp, CFG, pool, SPEC)
        out[name] = (ent, ex.reports[-1])
    return out


# ---------------------------------------------------------------------------
# wave flight accounting primitives
# ---------------------------------------------------------------------------

class TestWaveScope:
    def test_lat_rounds_paid_once_bw_per_batch(self):
        with ledger_scope() as led:
            with comm.wave_scope(4):
                comm.record("cmp", rounds=8, nbytes=432, numel=1, tag="lat")
                comm.record("open", rounds=1, nbytes=100, numel=10,
                            flops=5, tag="bw")
        cmp_rec, open_rec = led.records
        assert (cmp_rec.rounds, cmp_rec.nbytes, cmp_rec.wave) == (8, 4 * 432, 4)
        assert (open_rec.rounds, open_rec.nbytes, open_rec.flops) == \
            (4, 400, 20)

    def test_scope_restores(self):
        with comm.wave_scope(4):
            assert comm.get_wave() == 4
        assert comm.get_wave() == 1


# ---------------------------------------------------------------------------
# 1. ledger agreement
# ---------------------------------------------------------------------------

class TestLedgerAgreement:
    def test_per_batch_probe_matches_analytic_exactly(self, executed):
        pb = executed["ours"][1].per_batch
        ana = costs.proxy_exec_cost(BATCH, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers)
        assert len(pb.records) == len(ana.records)
        for got, want in zip(pb.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag), (got, want)

    def test_all_variants_agree_with_makespan_inputs(self, executed):
        for name, (_, rep) in executed.items():
            assert rep.agrees(), name

    def test_coalesce_strips_exactly_the_wave_lat_rounds(self, executed):
        pb = executed["ours"][1].per_batch
        n_b = executed["ours"][1].n_batches
        n_w = executed["ours"][1].n_waves
        assert executed["ours"][1].ledger.lat_rounds == n_w * pb.lat_rounds
        assert executed["serial"][1].ledger.lat_rounds == n_b * pb.lat_rounds
        # bytes and bw rounds are schedule-invariant
        for name, (_, rep) in executed.items():
            assert rep.ledger.nbytes == n_b * pb.nbytes, name
            assert rep.ledger.bw_rounds == n_b * pb.bw_rounds, name

    def test_disagreement_detected(self, executed):
        """ledger_agrees is a real check: a dropped flight must fail it."""
        rep = executed["ours"][1]
        broken = Ledger()
        broken.records = rep.ledger.records[:-1]
        assert not iosched.ledger_agrees(broken, rep.per_batch,
                                         rep.n_batches, rep.sched)

    def test_makespan_ordering_realized(self, executed):
        mk = {n: rep.makespan(WAN) for n, (_, rep) in executed.items()}
        assert mk["serial"] >= mk["+coalesce"] >= mk["ours"]
        assert mk["serial"] >= mk["+overlap"] >= mk["ours"]


# ---------------------------------------------------------------------------
# 1b. RING32 through the same engine code path (ROADMAP follow-up)
# ---------------------------------------------------------------------------


class TestRing32:
    @pytest.fixture(scope="class")
    def ring32_report(self, pp, pool):
        ex = WaveExecutor(ExecConfig(wave=WAVE, batch=BATCH, ring=RING32,
                                     fuse=False))
        ent = ex.score_phase(jax.random.fold_in(K, 9), pp, CFG, pool, SPEC)
        return ent, ex.reports[-1]

    def test_ring32_phase_ledger_agrees(self, ring32_report):
        """The dealer-trunc op stream satisfies the same executable
        accounting contract as RING64 — one engine, two rings."""
        ent, rep = ring32_report
        assert rep.agrees()
        assert ent.ring is RING32
        assert np.isfinite(np.asarray(ent.sh)).all()

    def test_ring32_probe_matches_analytic_mirror(self, ring32_report):
        """costs.proxy_exec_cost(ring=RING32) mirrors the executed
        stream record-for-record, dealer trunc_open rounds included."""
        _, rep = ring32_report
        ana = costs.proxy_exec_cost(BATCH, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers,
                                    ring=RING32)
        pb = rep.per_batch
        assert len(pb.records) == len(ana.records)
        for got, want in zip(pb.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag), (got, want)

    def test_ring32_pays_trunc_rounds_but_fewer_bytes(self, ring32_report,
                                                      executed):
        """Dealer truncation buys exactness with extra bw rounds; the
        4-byte ring halves every Beaver opening's wire bytes."""
        _, rep32 = ring32_report
        pb64 = executed["ours"][1].per_batch
        pb32 = rep32.per_batch
        assert pb32.bw_rounds > pb64.bw_rounds
        assert pb32.lat_rounds == pb64.lat_rounds
        beaver64 = sum(r.nbytes for r in pb64.records
                       if r.op.startswith("beaver"))
        beaver32 = sum(r.nbytes for r in pb32.records
                       if r.op.startswith("beaver"))
        assert beaver32 * 2 == beaver64


# ---------------------------------------------------------------------------
# 2. schedule invariance / serial equivalence
# ---------------------------------------------------------------------------

class TestEquivalence:
    def test_variants_bitwise_identical_scores(self, executed):
        ref = np.asarray(executed["serial"][0].sh)
        for name, (ent, _) in executed.items():
            assert np.array_equal(ref, np.asarray(ent.sh)), name

    def test_wave_selects_same_survivors_as_serial(self, executed):
        with x64_scope():
            picks = {name: quickselect.top_k_indices(ent, 16, seed=3)
                     for name, (ent, _) in executed.items()}
        for name, idx in picks.items():
            assert np.array_equal(idx, picks["serial"]), name

    def test_wave_matches_clear_proxy(self, executed, pp, pool):
        """Parity of the executed wave path against the float reference."""
        from repro.engine import ClearEngine, proxy_entropy
        from repro.mpc.sharing import reconstruct
        clear = np.asarray(proxy_entropy(ClearEngine(), pp, CFG,
                                         jnp.asarray(pool), SPEC))
        ent, _ = executed["ours"]
        with x64_scope():
            got = np.asarray(reconstruct(ent.sh).astype(jnp.float64)
                             / ent.ring.scale)
        assert np.abs(got - clear).max() < 1e-3
        k = 16
        top_c = set(np.argsort(clear)[-k:].tolist())
        top_m = set(np.argsort(got)[-k:].tolist())
        assert len(top_c & top_m) >= k - 1


# ---------------------------------------------------------------------------
# 3. end-to-end: selection drives the executor
# ---------------------------------------------------------------------------

class TestSelectionIntegration:
    def test_clear_vs_mpc_same_survivors(self):
        from repro.core.selection import SelectionConfig, run_selection
        from repro.core import target as tgt
        from repro.data.tasks import make_classification_task
        task = make_classification_task(5, n_pool=96, n_test=50, seq=8,
                                        vocab=64, n_classes=3)
        cfg = dataclasses.replace(CFG, vocab_size=task.vocab)
        params = tgt.init_classifier(K, cfg, task.n_classes)
        results = {}
        for mode in ("clear", "mpc"):
            sel = SelectionConfig(
                phases=[ProxySpec(1, 2, 2, 1.0)], budget_frac=0.3,
                boot_frac=0.1, mode=mode, score_batch=16,
                exvivo_steps=60, invivo_steps=20, finetune_steps=30,
                executor=ExecConfig(wave=3))
            results[mode] = run_selection(
                K, params, cfg, task.pool_tokens, sel,
                n_classes=task.n_classes,
                boot_labels_fn=lambda i: task.pool_labels[i])
        clear_sel = set(results["clear"].selected.tolist())
        mpc_sel = set(results["mpc"].selected.tolist())
        overlap = len(clear_sel & mpc_sel) / len(clear_sel)
        assert overlap >= 0.9, (overlap, clear_sel ^ mpc_sel)
        # the mpc run must carry executor evidence and it must check out
        reps = results["mpc"].exec_reports
        assert len(reps) == 1
        assert reps[0].agrees()

    def test_exec_config_sched_mirror(self):
        ec = ExecConfig(wave=5, coalesce=False, overlap=True)
        sc = ec.sched()
        assert (sc.wave, sc.coalesce, sc.overlap) == (5, False, True)


# ---------------------------------------------------------------------------
# wave sharding axis
# ---------------------------------------------------------------------------

class TestWaveSharding:
    def test_wave_resolves_to_data_axis(self):
        import jax as _jax
        from jax.sharding import Mesh
        from repro.parallel.sharding import ShardRules, fit_spec
        mesh = Mesh(np.array(_jax.devices()[:1]).reshape(1), ("data",))
        rules = ShardRules(mesh)
        assert rules.resolve("wave") == "data"
        # wave claims the data axis first; batch yields rather than reuse
        spec = fit_spec(rules, (4, 8), ["wave", "batch"])
        assert tuple(spec) == ("data", None)
