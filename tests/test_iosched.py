"""IO scheduler invariants + cost-model structure (paper §4.4, Fig 2/6/7)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import iosched
from repro.core.iosched import SchedConfig, fig7_variants, makespan
from repro.mpc import costs
from repro.mpc.comm import WAN, POD_DCN, Ledger, CostRecord


def _per_batch():
    g = costs.BlockGeom(batch=8, seq=128, d_model=768, heads=12,
                        d_head=64, d_ff=3072)
    return costs.proxy_model_cost(g, layers=1, classes=2, mlp_hidden=2)


class TestMakespan:
    def test_variants_ordering(self):
        """serial >= +coalesce/+overlap >= ours, for any net profile."""
        led = _per_batch()
        for net in (WAN, POD_DCN):
            v = fig7_variants(led, 200, net)
            assert v["serial"] >= v["+coalesce"] - 1e-9
            assert v["serial"] >= v["+overlap"] - 1e-9
            assert v["+coalesce"] >= v["ours"] - 1e-9
            assert v["+overlap"] >= v["ours"] - 1e-9

    def test_overlap_bounded_by_resources(self):
        """Overlapped makespan ~ max(comm, compute), never less."""
        led = _per_batch()
        n = 100
        sc = SchedConfig(coalesce=False, overlap=True)
        t = makespan(led, n, WAN, sc)
        lat, bw, nbytes, comp = iosched.batch_times(led, WAN, sc)
        comm_total = n * ((lat + bw) * WAN.latency_s + nbytes / WAN.bandwidth_Bps)
        assert t >= max(comm_total, n * comp)

    def test_coalesce_reduces_lat_rounds_only(self):
        led = Ledger()
        led.add(CostRecord("cmp", rounds=8, nbytes=432, tag="lat"))
        led.add(CostRecord("mm", rounds=1, nbytes=10 ** 6, tag="bw"))
        n = 64
        serial = makespan(led, n, WAN, SchedConfig(False, False))
        coal = makespan(led, n, WAN, SchedConfig(True, False, wave=8))
        # saved: (64 - 8) * 8 rounds * 0.1s
        assert serial - coal == pytest.approx((64 - 8) * 8 * WAN.latency_s)

    @given(st.integers(1, 500), st.integers(1, 32))
    @settings(max_examples=30, deadline=None)
    def test_makespan_monotone_in_batches(self, n, wave):
        led = _per_batch()
        sc = SchedConfig(wave=wave)
        assert makespan(led, n + 1, WAN, sc) >= makespan(led, n, WAN, sc)


class TestCostModel:
    def test_softmax_dominates_exact_block(self):
        """Paper Fig 2: softmax ~82% of bytes in an exact block."""
        g = costs.BlockGeom(batch=5, seq=128, d_model=768, heads=12,
                            d_head=64, d_ff=3072)
        led = costs.exact_attention_cost(g)
        by = led.by_op()
        sm_bytes = sum(r.nbytes for k, r in by.items() if "softmax" in k)
        assert sm_bytes / led.nbytes > 0.5

    def test_proxy_cheaper_than_exact(self):
        """Whole-model: bytes >4x and rounds >5x cheaper (Amdahl-capped by
        the shared QKV/scores/AV matmuls both paths pay)."""
        g = costs.BlockGeom(batch=8, seq=128, d_model=768, heads=12,
                            d_head=64, d_ff=3072)
        exact = costs.exact_model_cost(g, layers=3, classes=2)
        prox = costs.proxy_model_cost(g, layers=3, classes=2, mlp_hidden=16)
        assert exact.nbytes / prox.nbytes > 4
        assert exact.rounds / prox.rounds > 5

    def test_softmax_module_reduction_is_paper_scale(self):
        """Module-level at the paper's geometry (512-dim softmax -> 2-dim
        MLP): comm reduction ~42x (paper §5.4 reports exactly 42x)."""
        rows, seq = 8 * 12 * 512, 512
        exact = costs.softmax_cost(rows, seq).nbytes
        mlp = costs.mlp_cost(rows, seq, 2, seq).nbytes
        assert 30 < exact / mlp < 60

    def test_mpcformer_between(self):
        """MPCFormer (no dimension reduction) sits between ours and exact."""
        g = costs.BlockGeom(batch=8, seq=128, d_model=768, heads=12,
                            d_head=64, d_ff=3072)
        exact = costs.exact_block_cost(g).nbytes
        mf = costs.mpcformer_block_cost(g).nbytes
        ours = costs.proxy_block_cost(g, 16).nbytes
        assert ours < mf < exact

    def test_oracle_speedup_magnitude(self):
        """End-to-end modeled speedup at paper scale is order 100x+."""
        from repro.launch.select import paper_scale_delay
        d = paper_scale_delay(42_000, 0.2)
        assert d["wan"]["speedup"] > 50
        assert d["wan"]["oracle_hours"] > 500       # thousands of hours
        assert d["wan"]["ours_hours"] < 100         # tens of hours

    def test_beaver_matmul_bytes_not_quadratic(self):
        led = costs.matmul_cost(1, 512, 512, 512)
        # bytes ~ (mk + kn), not m*k*n
        assert led.nbytes == 2 * 8 * (512 * 512 + 512 * 512)


class TestScheduleSearch:
    """Paper §4.2: offline grid search over <l, w, d> phase schedules."""

    def test_pareto_frontier_properties(self):
        from repro.core.schedule_search import grid_search
        front = grid_search(42_000, 0.2)
        assert len(front) >= 4
        # frontier sorted by delay must be strictly increasing in capacity
        for a, b in zip(front, front[1:]):
            assert a.delay_s <= b.delay_s
            assert a.capacity < b.capacity
        # the paper's headline 2-phase schedule family must be on/near it
        assert any(len(s.phases) >= 2 for s in front)

    def test_multiphase_cheaper_than_big_single_phase(self):
        from repro.core.proxy import ProxySpec
        from repro.core.schedule_search import schedule_delay
        n, b = 42_000, 8_400
        single = schedule_delay((ProxySpec(3, 12, 16, 1.0),), n, b)
        multi = schedule_delay((ProxySpec(1, 1, 2, 0.3),
                                ProxySpec(3, 12, 16, 1.0)), n, b)
        assert multi < single       # paper: MPS cuts delay 33-61%
        assert 1 - multi / single > 0.2
