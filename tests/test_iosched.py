"""IO scheduler invariants + cost-model structure (paper §4.4, Fig 2/6/7)."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import iosched
from repro.core.iosched import SchedConfig, fig7_variants, makespan
from repro.mpc import costs
from repro.mpc.comm import WAN, POD_DCN, Ledger, CostRecord, NetProfile


def _per_batch():
    g = costs.BlockGeom(batch=8, seq=128, d_model=768, heads=12,
                        d_head=64, d_ff=3072)
    return costs.proxy_model_cost(g, layers=1, classes=2, mlp_hidden=2)


class TestMakespan:
    def test_variants_ordering(self):
        """serial >= +coalesce/+overlap >= ours, for any net profile."""
        led = _per_batch()
        for net in (WAN, POD_DCN):
            v = fig7_variants(led, 200, net)
            assert v["serial"] >= v["+coalesce"] - 1e-9
            assert v["serial"] >= v["+overlap"] - 1e-9
            assert v["+coalesce"] >= v["ours"] - 1e-9
            assert v["+overlap"] >= v["ours"] - 1e-9

    def test_overlap_bounded_by_resources(self):
        """Overlapped makespan ~ max(comm, compute), never less."""
        led = _per_batch()
        n = 100
        sc = SchedConfig(coalesce=False, overlap=True)
        t = makespan(led, n, WAN, sc)
        lat, bw, nbytes, comp = iosched.batch_times(led, WAN, sc)
        comm_total = n * ((lat + bw) * WAN.latency_s + nbytes / WAN.bandwidth_Bps)
        assert t >= max(comm_total, n * comp)

    def test_coalesce_reduces_lat_rounds_only(self):
        led = Ledger()
        led.add(CostRecord("cmp", rounds=8, nbytes=432, tag="lat"))
        led.add(CostRecord("mm", rounds=1, nbytes=10 ** 6, tag="bw"))
        n = 64
        serial = makespan(led, n, WAN, SchedConfig(False, False))
        coal = makespan(led, n, WAN, SchedConfig(True, False, wave=8))
        # saved: (64 - 8) * 8 rounds * 0.1s
        assert serial - coal == pytest.approx((64 - 8) * 8 * WAN.latency_s)

    @given(st.integers(1, 500), st.integers(1, 32))
    @settings(max_examples=30, deadline=None)
    def test_makespan_monotone_in_batches(self, n, wave):
        led = _per_batch()
        sc = SchedConfig(wave=wave)
        assert makespan(led, n + 1, WAN, sc) >= makespan(led, n, WAN, sc)


def _rand_ledger(lat_rounds: int, bw_flights: int, kbytes: int,
                 gflops: int) -> Ledger:
    led = Ledger()
    if lat_rounds:
        led.add(CostRecord("cmp", rounds=lat_rounds,
                           nbytes=432 * lat_rounds, tag="lat"))
    led.add(CostRecord("mm", rounds=max(bw_flights, 1),
                       nbytes=kbytes * 1024, flops=gflops * 10 ** 9,
                       tag="bw"))
    return led


ALL_VARIANTS = [(False, False), (True, False), (False, True), (True, True)]


class TestMakespanProperties:
    """Schedule-model invariants across ALL four (coalesce, overlap)
    variants: bounded below by each resource, above by the serial sum,
    and monotone in the network parameters.

    Monotonicity in rtt/bandwidth is exact except at the overlap model's
    comm-bound/compute-bound boundary, where the pipeline-fill term
    switches between one batch of comm and one batch of compute — the
    assertions allow exactly that one-batch slack.
    """

    def _check_bounds(self, led, n, wave):
        serial = SchedConfig(coalesce=False, overlap=False, wave=wave)
        serial_sum = makespan(led, n, WAN, serial)
        for co, ov in ALL_VARIANTS:
            sc = SchedConfig(coalesce=co, overlap=ov, wave=wave)
            t = makespan(led, n, WAN, sc)
            tot = iosched.stream_totals(led, n, sc)
            comm_total = ((tot["lat_rounds"] + tot["bw_rounds"])
                          * WAN.latency_s
                          + tot["nbytes"] / WAN.bandwidth_Bps)
            compute_total = tot["flops"] / sc.flops_per_s
            assert t <= serial_sum + 1e-9, (co, ov)
            assert t >= max(comm_total, compute_total) - 1e-9, (co, ov)

    @given(st.integers(0, 64), st.integers(1, 8), st.integers(1, 10 ** 5),
           st.integers(0, 10 ** 4), st.integers(1, 300), st.integers(1, 32))
    @settings(max_examples=50, deadline=None)
    def test_bounds(self, lat, bwf, kb, gf, n, wave):
        self._check_bounds(_rand_ledger(lat, bwf, kb, gf), n, wave)

    @pytest.mark.parametrize("lat,bwf,kb,gf,n,wave", [
        (8, 2, 1000, 0, 64, 8),       # latency-dominated
        (0, 4, 10 ** 5, 1, 100, 4),   # bandwidth-dominated
        (16, 1, 10, 10 ** 4, 32, 16),  # compute-dominated
        (64, 8, 10 ** 5, 10 ** 3, 1, 1),  # single batch
    ])
    def test_bounds_concrete(self, lat, bwf, kb, gf, n, wave):
        """Deterministic spot checks (run even without hypothesis)."""
        self._check_bounds(_rand_ledger(lat, bwf, kb, gf), n, wave)

    def _slack(self, led, net, sc):
        """One batch's serial time — the fill-term discontinuity bound."""
        return (led.rounds * net.latency_s + led.nbytes / net.bandwidth_Bps
                + led.flops / sc.flops_per_s)

    @given(st.integers(0, 64), st.integers(1, 10 ** 5), st.integers(0, 10 ** 3),
           st.integers(1, 200),
           st.floats(1e-4, 0.5), st.floats(1e-4, 0.5))
    @settings(max_examples=50, deadline=None)
    def test_monotone_in_rtt(self, lat, kb, gf, n, r1, r2):
        led = _rand_ledger(lat, 1, kb, gf)
        lo = NetProfile("lo", WAN.bandwidth_Bps, min(r1, r2))
        hi = NetProfile("hi", WAN.bandwidth_Bps, max(r1, r2))
        for co, ov in ALL_VARIANTS:
            sc = SchedConfig(coalesce=co, overlap=ov)
            slack = self._slack(led, hi, sc) if ov else 0.0
            assert makespan(led, n, hi, sc) >= \
                makespan(led, n, lo, sc) - slack - 1e-9, (co, ov)

    @given(st.integers(0, 64), st.integers(1, 10 ** 5), st.integers(0, 10 ** 3),
           st.integers(1, 200),
           st.floats(1e6, 1e11), st.floats(1e6, 1e11))
    @settings(max_examples=50, deadline=None)
    def test_monotone_in_bandwidth(self, lat, kb, gf, n, b1, b2):
        led = _rand_ledger(lat, 1, kb, gf)
        slow = NetProfile("slow", min(b1, b2), WAN.latency_s)
        fast = NetProfile("fast", max(b1, b2), WAN.latency_s)
        for co, ov in ALL_VARIANTS:
            sc = SchedConfig(coalesce=co, overlap=ov)
            slack = self._slack(led, slow, sc) if ov else 0.0
            assert makespan(led, n, fast, sc) <= \
                makespan(led, n, slow, sc) + slack + 1e-9, (co, ov)


class TestCostModel:
    def test_softmax_dominates_exact_block(self):
        """Paper Fig 2: softmax ~82% of bytes in an exact block."""
        g = costs.BlockGeom(batch=5, seq=128, d_model=768, heads=12,
                            d_head=64, d_ff=3072)
        led = costs.exact_attention_cost(g)
        by = led.by_op()
        sm_bytes = sum(r.nbytes for k, r in by.items() if "softmax" in k)
        assert sm_bytes / led.nbytes > 0.5

    def test_proxy_cheaper_than_exact(self):
        """Whole-model: bytes >4x and rounds >5x cheaper (Amdahl-capped by
        the shared QKV/scores/AV matmuls both paths pay)."""
        g = costs.BlockGeom(batch=8, seq=128, d_model=768, heads=12,
                            d_head=64, d_ff=3072)
        exact = costs.exact_model_cost(g, layers=3, classes=2)
        prox = costs.proxy_model_cost(g, layers=3, classes=2, mlp_hidden=16)
        assert exact.nbytes / prox.nbytes > 4
        assert exact.rounds / prox.rounds > 5

    def test_softmax_module_reduction_is_paper_scale(self):
        """Module-level at the paper's geometry (512-dim softmax -> 2-dim
        MLP): comm reduction ~42x (paper §5.4 reports exactly 42x)."""
        rows, seq = 8 * 12 * 512, 512
        exact = costs.softmax_cost(rows, seq).nbytes
        mlp = costs.mlp_cost(rows, seq, 2, seq).nbytes
        assert 30 < exact / mlp < 60

    def test_mpcformer_between(self):
        """MPCFormer (no dimension reduction) sits between ours and exact."""
        g = costs.BlockGeom(batch=8, seq=128, d_model=768, heads=12,
                            d_head=64, d_ff=3072)
        exact = costs.exact_block_cost(g).nbytes
        mf = costs.mpcformer_block_cost(g).nbytes
        ours = costs.proxy_block_cost(g, 16).nbytes
        assert ours < mf < exact

    def test_oracle_speedup_magnitude(self):
        """End-to-end modeled speedup at paper scale is order 100x+."""
        from repro.launch.select import paper_scale_delay
        d = paper_scale_delay(42_000, 0.2)
        assert d["wan"]["speedup"] > 50
        assert d["wan"]["oracle_hours"] > 500       # thousands of hours
        assert d["wan"]["ours_hours"] < 100         # tens of hours

    def test_beaver_matmul_bytes_not_quadratic(self):
        led = costs.matmul_cost(1, 512, 512, 512)
        # bytes ~ (mk + kn), not m*k*n
        assert led.nbytes == 2 * 8 * (512 * 512 + 512 * 512)


class TestScheduleSearch:
    """Paper §4.2: offline grid search over <l, w, d> phase schedules."""

    def test_pareto_frontier_properties(self):
        from repro.core.schedule_search import grid_search
        front = grid_search(42_000, 0.2)
        assert len(front) >= 4
        # frontier sorted by delay must be strictly increasing in capacity
        for a, b in zip(front, front[1:]):
            assert a.delay_s <= b.delay_s
            assert a.capacity < b.capacity
        # the paper's headline 2-phase schedule family must be on/near it
        assert any(len(s.phases) >= 2 for s in front)

    def test_multiphase_cheaper_than_big_single_phase(self):
        from repro.core.proxy import ProxySpec
        from repro.core.schedule_search import schedule_delay
        n, b = 42_000, 8_400
        single = schedule_delay((ProxySpec(3, 12, 16, 1.0),), n, b)
        multi = schedule_delay((ProxySpec(1, 1, 2, 0.3),
                                ProxySpec(3, 12, 16, 1.0)), n, b)
        assert multi < single       # paper: MPS cuts delay 33-61%
        assert 1 - multi / single > 0.2
