"""Launch layer: mesh builders, input specs, train smoke, serve smoke,
dry-run artifact sanity (reads the JSONs the sweep produced)."""
import glob
import json
import os

import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, SHAPES, load_arch, cell_is_applicable

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


class TestMesh:
    def test_host_mesh(self):
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh()
        assert set(mesh.axis_names) == {"data", "model"}

    def test_production_mesh_shapes(self):
        # can't build 256/512-device meshes here (1 CPU device); assert the
        # factorizations instead — dryrun.py builds them in its own process
        from repro.launch import mesh as M
        import inspect
        src = inspect.getsource(M.make_production_mesh)
        assert "(2, 16, 16)" in src and "(16, 16)" in src
        assert '("pod", "data", "model")' in src


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    @pytest.mark.parametrize("shape", list(SHAPES))
    def test_specs_complete(self, arch, shape):
        from repro.launch.dryrun import input_specs
        cfg = load_arch(arch)
        sh = SHAPES[shape]
        ok, _ = cell_is_applicable(cfg, sh)
        if not ok:
            pytest.skip("cell skipped by design")
        specs = input_specs(cfg, sh)
        assert "tokens" in specs
        b = sh.global_batch
        assert specs["tokens"].shape[0] == b
        if sh.kind == "decode":
            assert specs["tokens"].shape[1] == 1
        if cfg.family == "vlm" and sh.kind != "decode":
            assert "patches" in specs
        if cfg.family == "encdec" and sh.kind != "decode":
            assert "frames" in specs


class TestTrainSmoke:
    def test_train_and_resume(self, tmp_path):
        from repro.launch.train import TrainConfig, train
        ckpt = str(tmp_path / "ck")
        out = train(TrainConfig(arch="qwen2_0_5b", smoke=True, steps=8,
                                batch=4, seq=32, ckpt_dir=ckpt,
                                ckpt_every=4, log_every=100))
        assert out["final_loss"] is not None
        assert np.isfinite(out["final_loss"])
        out2 = train(TrainConfig(arch="qwen2_0_5b", smoke=True, steps=12,
                                 batch=4, seq=32, ckpt_dir=ckpt,
                                 ckpt_every=4, log_every=100))
        assert out2["resumed_from"] == 8

    def test_loss_decreases_over_training(self, tmp_path):
        from repro.launch.train import TrainConfig, train
        out = train(TrainConfig(arch="qwen2_0_5b", smoke=True, steps=30,
                                batch=8, seq=64,
                                ckpt_dir=str(tmp_path / "ck2"),
                                ckpt_every=1000, log_every=1000))
        assert out["final_loss"] < out["first_loss"]


class TestServeSmoke:
    def test_serve_batched(self):
        from repro.launch.serve import ServeConfig, Server, Request
        srv = Server(ServeConfig(arch="qwen2_0_5b", slots=2, max_new=4))
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(0, srv.cfg.vocab_size, size=5))
                for i in range(3)]
        out = srv.run(reqs)
        assert out["requests"] == 3
        assert all(len(v) == 4 for v in out["outputs"].values())


@pytest.mark.skipif(not glob.glob(os.path.join(DRYRUN_DIR, "*.json")),
                    reason="dry-run sweep not yet executed")
class TestDryrunArtifacts:
    def _cells(self):
        out = {}
        for p in glob.glob(os.path.join(DRYRUN_DIR, "*.json")):
            name = os.path.basename(p)[:-5]
            if name.count("__") != 2:
                continue          # hillclimb variant artifacts (__<variant>)
            with open(p) as f:
                out[name] = json.load(f)
        return out

    def test_all_80_cells_present_and_clean(self):
        cells = self._cells()
        assert len(cells) == 80
        errors = {k: v for k, v in cells.items() if "error" in v}
        assert not errors, f"failed cells: {list(errors)}"

    def test_applicable_cells_have_analysis(self):
        for name, c in self._cells().items():
            if not c.get("applicable", False):
                assert "skip_reason" in c
                continue
            assert c["cost"]["flops"] and c["cost"]["flops"] > 0, name
            assert c["memory"]["peak_bytes"] and \
                c["memory"]["peak_bytes"] > 0, name

    def test_multi_pod_cells_fit_hbm(self):
        """Every applicable cell must fit v5e HBM (16 GiB) per device."""
        hbm = 16 * 2 ** 30
        for name, c in self._cells().items():
            if not c.get("applicable", False):
                continue
            assert c["memory"]["peak_bytes"] < hbm * 1.05, \
                (name, c["memory"]["peak_bytes"])

    def test_multi_pod_uses_pod_axis(self):
        """Multi-pod programs must shard over the pod axis: per-device
        flops should drop vs single-pod for batch-sharded cells."""
        cells = self._cells()
        checked = 0
        for arch in ARCH_IDS:
            a = cells.get(f"{arch}__train_4k__pod16x16")
            b = cells.get(f"{arch}__train_4k__pod2x16x16")
            if not (a and b and a.get("applicable") and b.get("applicable")):
                continue
            assert b["cost"]["flops"] < a["cost"]["flops"] * 0.75, arch
            checked += 1
        assert checked >= 8
