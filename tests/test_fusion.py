"""Flight fusion: round-compressed MPC streams (mpc/fusion.py).

Contracts:
  1. ACCOUNTING ONLY — fusion moves records, never values: fused vs
     eager output shares are bitwise identical across every Table-2/3
     variant on both rings, at identical bytes-on-wire.
  2. COMPRESSION — the RING32 proxy forward under flight_scope records
     >= 40% fewer ledger rounds than the eager path (the dealer-trunc
     and Beaver openings fold into per-group flights).
  3. MIRROR — costs.proxy_exec_cost(fused=True) predicts the fused
     stream record-for-record, and an executed fused phase still
     satisfies iosched.ledger_agrees.
  4. HOT PATH — MPCEngine.matmul's RING32 combine routes through the
     Pallas secure_matmul kernel bitwise-identically (ref + interpret).
"""
import contextlib
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_targets import TINY_TARGET
from repro.core import proxy as proxy_mod
from repro.core.executor import ExecConfig, WaveExecutor
from repro.core.proxy import ProxySpec
from repro.engine import MPCEngine, TraceEngine, VARIANTS, abstract_shares, \
    proxy_entropy
from repro.mpc import comm, costs, fusion, ops as mops, quickselect
from repro.mpc.comm import ledger_scope
from repro.mpc.ring import RING32, RING64, x64_scope
from repro.mpc.sharing import share

CFG = dataclasses.replace(TINY_TARGET, vocab_size=64, n_layers=1,
                          d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                          d_ff=64)
SPEC = ProxySpec(1, 2, 4)
SEQ, BATCH, CLASSES = 8, 6, 3
K = jax.random.key(0)

RINGS = {"ring64": RING64, "ring32": RING32}


def _ring_ctx(ring):
    return x64_scope() if ring.bits >= 64 else contextlib.nullcontext()


@pytest.fixture(scope="module")
def pp():
    return proxy_mod.random_proxy(K, CFG, SPEC, seq_len=SEQ,
                                  n_classes=CLASSES)


@pytest.fixture(scope="module")
def tok():
    return jnp.asarray(np.random.default_rng(1).integers(
        0, CFG.vocab_size, (BATCH, SEQ)))


def _run_forward(pp, tok, ring, variant, fused):
    """One MPC forward; returns (shares ndarray, Ledger)."""
    with _ring_ctx(ring):
        pp_sh = proxy_mod.share_proxy(jax.random.fold_in(K, 2), pp, ring)
        x = jnp.take(pp["embed"], tok, axis=0) * (CFG.d_model ** 0.5)
        x_sh = share(jax.random.fold_in(K, 3), x.astype(jnp.float32), ring)
        eng = MPCEngine(ring).with_key(jax.random.fold_in(K, 4))
        with ledger_scope() as led, fusion.flight_scope(enabled=fused):
            out = proxy_entropy(eng, pp_sh, CFG, x_sh, SPEC, variant)
        return np.asarray(out.sh), led


# ---------------------------------------------------------------------------
# batcher unit semantics
# ---------------------------------------------------------------------------

class TestBatcher:
    def test_bw_openings_fuse_to_one_flight(self):
        with ledger_scope() as led, fusion.flight_scope():
            comm.record("a", rounds=1, nbytes=100, numel=10, flops=1)
            comm.record("b", rounds=1, nbytes=200, numel=20, flops=2)
        (rec,) = led.records
        assert (rec.op, rec.rounds, rec.nbytes, rec.numel, rec.flops,
                rec.tag) == ("fused.flight", 1, 300, 30, 3, "bw")

    def test_lat_record_is_a_barrier(self):
        with ledger_scope() as led, fusion.flight_scope():
            comm.record("open1", rounds=1, nbytes=100, numel=1)
            comm.record("cmp", rounds=8, nbytes=432, numel=1, tag="lat")
            comm.record("open2", rounds=1, nbytes=50, numel=1)
        ops_seen = [(r.op, r.rounds, r.nbytes) for r in led.records]
        assert ops_seen == [("fused.flight", 1, 100), ("cmp", 8, 432),
                            ("fused.flight", 1, 50)]

    def test_fused_group_bounds_and_labels(self):
        with ledger_scope() as led, fusion.flight_scope():
            comm.record("ambient", rounds=1, nbytes=10, numel=1)
            with fusion.fused_group("qkv"):
                comm.record("q", rounds=1, nbytes=1, numel=1)
                comm.record("k", rounds=1, nbytes=2, numel=1)
            comm.record("tail", rounds=1, nbytes=5, numel=1)
        assert [(r.op, r.nbytes) for r in led.records] == \
            [("fused.flight", 10), ("fused.qkv", 3), ("fused.flight", 5)]

    def test_fused_group_without_scope_is_noop(self):
        with ledger_scope() as led:
            with fusion.fused_group("qkv"):
                comm.record("q", rounds=1, nbytes=1, numel=1)
        assert [(r.op, r.rounds) for r in led.records] == [("q", 1)]

    def test_lat_scope_coalesces_comparison_batches(self):
        with ledger_scope() as led, fusion.lat_scope("qs"):
            comm.record("cmp", rounds=8, nbytes=432, numel=1, tag="lat")
            comm.record("cmp", rounds=8, nbytes=864, numel=2, tag="lat")
        (rec,) = led.records
        assert (rec.op, rec.rounds, rec.nbytes, rec.numel, rec.tag) == \
            ("fused.qs", 8, 1296, 3, "lat")

    def test_wave_scaling_applies_at_flush(self):
        """Fused flights are per-batch flights: under wave_scope(W) the
        flush scales exactly like the eager bw records it replaces."""
        with ledger_scope() as led, comm.wave_scope(4):
            with fusion.flight_scope():
                comm.record("a", rounds=1, nbytes=100, numel=10)
                comm.record("b", rounds=1, nbytes=100, numel=10)
        (rec,) = led.records
        assert (rec.rounds, rec.nbytes, rec.numel) == (4, 800, 80)

    def test_scope_exit_restores_eager(self):
        with ledger_scope() as led:
            with fusion.flight_scope():
                comm.record("in", rounds=1, nbytes=1, numel=1)
            comm.record("out", rounds=1, nbytes=1, numel=1)
        assert [r.op for r in led.records] == ["fused.flight", "out"]


# ---------------------------------------------------------------------------
# cross-op deferred truncation: scale-carrying shares retired PendingShare
# ---------------------------------------------------------------------------

class TestScaleCarriedTrunc:
    def test_pending_share_is_retired(self):
        """`lazy=True`/PendingShare is gone: the carried exponent on
        Share itself (mpc/scale.py) is the pending-trunc state now."""
        assert not hasattr(fusion, "PendingShare")
        assert not hasattr(fusion, "force")

    @pytest.mark.parametrize("ring", list(RINGS.values()),
                             ids=list(RINGS))
    def test_mul_emits_summed_scale_force_resolves(self, ring):
        with _ring_ctx(ring):
            k = jax.random.fold_in(K, 11)
            x = share(jax.random.fold_in(K, 12),
                      jnp.linspace(-2.0, 2.0, 12).reshape(3, 4), ring)
            y = share(jax.random.fold_in(K, 13),
                      jnp.linspace(0.5, 1.5, 12).reshape(3, 4), ring)
            z = mops.mul(x, y, k)
            assert z.fb == 2 * ring.frac_bits      # raw product scale
            forced = mops.force(z, jax.random.fold_in(K, 14))
            assert forced.fb == ring.frac_bits
            # decode-at-scale: both views reveal the same product
            from repro.mpc.sharing import reveal
            assert np.allclose(np.asarray(reveal(z)),
                               np.asarray(reveal(forced)),
                               atol=4.0 / ring.scale)
            # the memo: forcing twice truncates once
            assert mops.force(z, jax.random.fold_in(K, 15)) is forced


# ---------------------------------------------------------------------------
# 1+2: bitwise parity and >=40% RING32 compression, all variants
# ---------------------------------------------------------------------------

class TestFusedParity:
    @pytest.mark.parametrize("ring", list(RINGS.values()), ids=list(RINGS))
    @pytest.mark.parametrize("vname", sorted(VARIANTS))
    def test_fused_matches_eager_bitwise(self, vname, ring, pp, tok):
        variant = VARIANTS[vname]
        sh_e, led_e = _run_forward(pp, tok, ring, variant, fused=False)
        sh_f, led_f = _run_forward(pp, tok, ring, variant, fused=True)
        assert np.array_equal(sh_e, sh_f), vname
        assert led_f.nbytes == led_e.nbytes, vname
        assert led_f.flops == led_e.flops, vname
        assert led_f.lat_rounds == led_e.lat_rounds, vname
        assert led_f.rounds < led_e.rounds, vname

    def test_ring32_forward_cuts_rounds_40pct(self, pp, tok):
        """The acceptance gate: dealer-trunc + Beaver openings fold into
        per-group flights — >= 40% fewer ledger rounds, bytes unchanged."""
        _, led_e = _run_forward(pp, tok, RING32, VARIANTS["full"], False)
        _, led_f = _run_forward(pp, tok, RING32, VARIANTS["full"], True)
        assert led_f.nbytes == led_e.nbytes
        assert 1 - led_f.rounds / led_e.rounds >= 0.40


# ---------------------------------------------------------------------------
# 3: analytic mirror + executed fused phase
# ---------------------------------------------------------------------------

class TestFusedMirror:
    @pytest.mark.parametrize("ring", list(RINGS.values()), ids=list(RINGS))
    def test_fused_probe_matches_mirror(self, ring):
        pp_sh = abstract_shares(CFG, SPEC, SEQ, CLASSES, ring)
        led = TraceEngine(ring).probe(pp_sh, CFG, SPEC,
                                      (BATCH, SEQ, CFG.d_model), fused=True)
        ana = costs.proxy_exec_cost(BATCH, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers,
                                    ring=ring, fused=True)
        assert len(led.records) == len(ana.records)
        for got, want in zip(led.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag), (got, want)
            if got.tag == "bw":       # fused flight names are contract too
                assert got.op == want.op

    def test_mirror_is_hermetic_under_wave_scope(self):
        """The analytic mirror must not inherit ambient wave scaling:
        per-batch predictions are identical inside a wave_scope."""
        kw = dict(bsz=BATCH, seq=SEQ, d_model=CFG.d_model,
                  heads=SPEC.n_heads, kv_heads=CFG.n_kv_heads,
                  d_head=CFG.d_head, mlp_hidden=SPEC.mlp_dim,
                  classes=CLASSES, n_layers=SPEC.n_layers,
                  ring=RING32, fused=True)
        outside = costs.proxy_exec_cost(**kw)
        with comm.wave_scope(4):
            inside = costs.proxy_exec_cost(**kw)
        assert (inside.rounds, inside.nbytes, inside.flops) == \
            (outside.rounds, outside.nbytes, outside.flops)

    def test_fused_mirror_strictly_fewer_rounds_same_bytes(self):
        kw = dict(bsz=BATCH, seq=SEQ, d_model=CFG.d_model,
                  heads=SPEC.n_heads, kv_heads=CFG.n_kv_heads,
                  d_head=CFG.d_head, mlp_hidden=SPEC.mlp_dim,
                  classes=CLASSES, n_layers=SPEC.n_layers)
        for ring in RINGS.values():
            eager = costs.proxy_exec_cost(**kw, ring=ring)
            fused = costs.proxy_exec_cost(**kw, ring=ring, fused=True)
            assert fused.rounds < eager.rounds
            assert fused.nbytes == eager.nbytes
            assert fused.lat_rounds == eager.lat_rounds


class TestExecutedFusedPhase:
    POOL = 24

    @pytest.fixture(scope="class")
    def pool(self):
        return np.random.default_rng(0).integers(0, CFG.vocab_size,
                                                 (self.POOL, SEQ))

    @pytest.fixture(scope="class")
    def executed(self, pp, pool):
        out = {}
        for name, fuse in (("eager", False), ("fused", True)):
            ex = WaveExecutor(ExecConfig(wave=2, batch=8, ring=RING32,
                                         fuse=fuse))
            ent = ex.score_phase(jax.random.fold_in(K, 9), pp, CFG, pool,
                                 SPEC)
            out[name] = (np.asarray(ent.sh), ex.reports[-1])
        return out

    def test_fused_phase_ledger_agrees(self, executed):
        """iosched.ledger_agrees holds for the round-compressed phase:
        the fused per-batch probe is exactly what the schedule prices."""
        assert executed["fused"][1].agrees()

    def test_fusion_does_not_change_scores(self, executed):
        assert np.array_equal(executed["eager"][0], executed["fused"][0])

    def test_fused_per_batch_matches_mirror(self, executed):
        pb = executed["fused"][1].per_batch
        ana = costs.proxy_exec_cost(8, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers,
                                    ring=RING32, fused=True)
        assert len(pb.records) == len(ana.records)
        for got, want in zip(pb.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag)

    def test_fused_phase_pays_fewer_rounds(self, executed):
        led_e = executed["eager"][1].ledger
        led_f = executed["fused"][1].ledger
        assert led_f.rounds < led_e.rounds
        assert led_f.nbytes == led_e.nbytes


# ---------------------------------------------------------------------------
# 4: the Pallas combine kernel on the RING32 matmul hot path
# ---------------------------------------------------------------------------

class TestKernelCombine:
    def _operands(self):
        x = share(jax.random.fold_in(K, 21),
                  jnp.asarray(np.random.default_rng(2).normal(
                      size=(16, 8)) * 0.3, jnp.float32), RING32)
        y = share(jax.random.fold_in(K, 22),
                  jnp.asarray(np.random.default_rng(3).normal(
                      size=(8, 8)) * 0.3, jnp.float32), RING32)
        return x, y

    @pytest.mark.parametrize("impl", ["ref", "interpret"])
    def test_kernel_combine_bitwise_equals_inline(self, impl):
        x, y = self._operands()
        k = jax.random.fold_in(K, 23)
        inline = mops.matmul(x, y, k)
        kern = mops.matmul(x, y, k, combine_impl=impl)
        assert np.array_equal(np.asarray(inline.sh), np.asarray(kern.sh))

    def test_engine_routes_ring32_matmul_through_kernel(self):
        x, y = self._operands()
        eng = MPCEngine(RING32, combine_impl="interpret").with_key(
            jax.random.fold_in(K, 24))
        ref = MPCEngine(RING32).with_key(jax.random.fold_in(K, 24))
        got = eng.matmul(x, y)
        want = ref.matmul(x, y)
        assert np.array_equal(np.asarray(got.sh), np.asarray(want.sh))

    def test_ring64_keeps_inline_combine(self):
        """The kernel is int32: a RING64 engine must not route to it."""
        with x64_scope():
            x = share(jax.random.fold_in(K, 25),
                      jnp.ones((4, 4), jnp.float32), RING64)
            eng = MPCEngine(RING64).with_key(jax.random.fold_in(K, 26))
            out = eng.matmul(x, x)
            assert out.sh.dtype == RING64.dtype


# ---------------------------------------------------------------------------
# QuickSelect per-wave comparison coalescing
# ---------------------------------------------------------------------------

class TestQuickselectWaves:
    @pytest.fixture(scope="class")
    def scores(self):
        with x64_scope():
            vals = jnp.asarray(np.random.default_rng(5).normal(size=48),
                               jnp.float32)
            return share(jax.random.fold_in(K, 31), vals)

    def test_wave_chunking_preserves_selection(self, scores, x64):
        base = quickselect.top_k_indices(scores, 16, seed=3)
        for wave in (2, 4, 7):
            got = quickselect.top_k_indices(scores, 16, seed=3, wave=wave)
            assert np.array_equal(base, got), wave

    def test_wave_batches_ride_one_flight(self, scores, x64):
        """Per-wave reveal_lt batches coalesce: a wave-chunked partition
        pays the same rounds as the unchunked one, bytes unchanged."""
        with ledger_scope() as led1:
            quickselect.top_k_indices(scores, 16, seed=3)
        with ledger_scope() as led4:
            quickselect.top_k_indices(scores, 16, seed=3, wave=4)
        assert led4.lat_rounds == led1.lat_rounds
        assert led4.nbytes == led1.nbytes
        assert all(r.tag == "lat" for r in led4.records)

    def test_quickselect_cost_prices_coalescing(self):
        r1, b1 = quickselect.quickselect_cost(1000)
        rc, bc = quickselect.quickselect_cost(1000, wave=8)
        re, be = quickselect.quickselect_cost(1000, wave=8, coalesce=False)
        assert rc == r1 and bc == b1        # coalesced: wave-invariant
        assert re == 8 * r1 and be == b1    # eager: a flight per chunk


# ---------------------------------------------------------------------------
# schedule search prices the executed (fused) stream
# ---------------------------------------------------------------------------

class TestScheduleSearchProbes:
    def test_fused_pricing_is_cheaper_on_ring32(self):
        from repro.core.schedule_search import schedule_delay
        ph = (ProxySpec(1, 1, 2, 1.0),)
        fused = schedule_delay(ph, 4_000, 800, ring=RING32, fused=True)
        eager = schedule_delay(ph, 4_000, 800, ring=RING32, fused=False)
        assert fused < eager

    def test_default_pricing_tracks_executor_default(self):
        """fused=None must price the stream ExecConfig actually runs."""
        from repro.core.schedule_search import schedule_delay
        ph = (ProxySpec(1, 1, 2, 1.0),)
        default = schedule_delay(ph, 4_000, 800, ring=RING32)
        explicit = schedule_delay(ph, 4_000, 800, ring=RING32,
                                  fused=ExecConfig().fuse)
        assert default == explicit

    def test_probe_pricing_matches_trace_engine(self):
        """schedule_delay's per-phase ledger IS a TraceEngine probe of
        the executed stream (not proxy_model_cost's paper geometry)."""
        from repro.core.schedule_search import _phase_probe
        led = _phase_probe(1, 2, 4, d_model=32, heads=2, classes=2,
                           seq=8, batch=4, ring=RING64, fused=True)
        cfg = dataclasses.replace(CFG, n_heads=2, n_kv_heads=2, d_head=16)
        pp_sh = abstract_shares(cfg, ProxySpec(1, 2, 4), 8, 2, RING64)
        want = TraceEngine(RING64).probe(pp_sh, cfg, ProxySpec(1, 2, 4),
                                         (4, 8, cfg.d_model), fused=True)
        assert (led.rounds, led.nbytes, led.flops) == \
            (want.rounds, want.nbytes, want.flops)
