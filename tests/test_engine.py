"""Engine API: one forward, many substrates (src/repro/engine/).

Contracts:
  1. PARITY BY CONSTRUCTION — for every Table-2/Table-3 variant
     (full / no-sm / no-ln / no-se / quad_sm / poly_sm) the decoded MPC
     entropies match the clear engine within fixed-point tolerance.
     The exact-op and baseline variants run *real* share-level
     protocols (CrypTen softmax/rsqrt/entropy, 2Quad, Bolt polynomial)
     — their first MPC execution in this repo.
  2. REMOVED SHIMS — the deprecated proxy_entropy_clear/_mpc and
     approx.mlp_apply/_mpc back-compat wrappers are gone: the engine
     API is the only entry point.
  3. TRACE — TraceEngine's abstract probe equals the analytic mirror on
     both rings without materializing weights (abstract_shares).
  4. RESOLUTION — legacy mode strings resolve to engine instances.
"""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.paper_targets import TINY_TARGET
from repro.core import proxy as proxy_mod
from repro.core.proxy import ProxySpec
from repro.engine import (ClearEngine, MPCEngine, TraceEngine, VARIANTS,
                          abstract_shares, proxy_entropy, resolve_engine)
from repro.engine.base import FULL_VARIANT, TensorEngine
from repro.mpc import costs
from repro.mpc.ring import RING32, RING64
from repro.mpc.sharing import reveal, share

CFG = dataclasses.replace(TINY_TARGET, vocab_size=64, n_layers=1,
                          d_model=32, n_heads=2, n_kv_heads=2, d_head=16,
                          d_ff=64)
SPEC = ProxySpec(1, 2, 4)
SEQ, BATCH, CLASSES = 8, 6, 3
K = jax.random.key(0)

# decoded-MPC vs clear tolerance per variant: MLP emulators accumulate
# only truncation LSBs; exact-op variants add the CrypTen iterative
# approximations' own error (NR reciprocal/rsqrt, limit-approx exp,
# Householder log)
ATOL = {"full": 2e-3, "no-sm": 2e-2, "no-ln": 2e-2, "no-se": 6e-2,
        "quad_sm": 2e-2, "poly_sm": 2e-2}


@pytest.fixture(scope="module")
def pp():
    return proxy_mod.random_proxy(K, CFG, SPEC, seq_len=SEQ,
                                  n_classes=CLASSES)


@pytest.fixture(scope="module")
def tok():
    return jnp.asarray(np.random.default_rng(1).integers(
        0, CFG.vocab_size, (BATCH, SEQ)))


# ---------------------------------------------------------------------------
# 1. clear/MPC parity across every variant
# ---------------------------------------------------------------------------


class TestParitySweep:
    @pytest.mark.parametrize("vname", sorted(VARIANTS))
    def test_variant_parity(self, vname, pp, tok, x64):
        variant = VARIANTS[vname]
        clear = np.asarray(proxy_entropy(ClearEngine(), pp, CFG, tok,
                                         SPEC, variant))
        pp_sh = proxy_mod.share_proxy(jax.random.fold_in(K, 2), pp)
        x = jnp.take(pp["embed"], tok, axis=0) * (CFG.d_model ** 0.5)
        x_sh = share(jax.random.fold_in(K, 3), x.astype(jnp.float32))
        eng = MPCEngine().with_key(jax.random.fold_in(K, 4))
        got = np.asarray(reveal(proxy_entropy(eng, pp_sh, CFG, x_sh,
                                              SPEC, variant)))
        err = np.abs(got - clear).max()
        assert err < ATOL[vname], (vname, err)

    def test_qkv_bias_parity(self, pp, tok, x64):
        """Biased-attention archs (qkv_bias=True) run over MPC through
        the same forward: the bias share broadcast right-aligns value
        dims under the party axis (regression: both hand-written
        forwards crashed here, so biased archs had never executed or
        been priced over MPC)."""
        kb = jax.random.fold_in(K, 40)
        dh, w = CFG.d_head, SPEC.n_heads
        wk = min(w, CFG.n_kv_heads)
        pp_b = dict(pp)
        pp_b["attn"] = dict(pp["attn"])
        L = SPEC.n_layers
        pp_b["attn"]["bq"] = 0.05 * jax.random.normal(kb, (L, w * dh))
        pp_b["attn"]["bk"] = 0.05 * jax.random.normal(
            jax.random.fold_in(kb, 1), (L, wk * dh))
        pp_b["attn"]["bv"] = 0.05 * jax.random.normal(
            jax.random.fold_in(kb, 2), (L, wk * dh))
        clear = np.asarray(proxy_entropy(ClearEngine(), pp_b, CFG, tok,
                                         SPEC))
        pp_sh = proxy_mod.share_proxy(jax.random.fold_in(K, 41), pp_b)
        x = jnp.take(pp_b["embed"], tok, axis=0) * (CFG.d_model ** 0.5)
        x_sh = share(jax.random.fold_in(K, 42), x.astype(jnp.float32))
        eng = MPCEngine().with_key(jax.random.fold_in(K, 43))
        got = np.asarray(reveal(proxy_entropy(eng, pp_sh, CFG, x_sh,
                                              SPEC)))
        assert np.abs(got - clear).max() < ATOL["full"]
        # and the biased arch is priceable: probe emits the same record
        # stream (biases add no wire cost — costs.proxy_exec_cost's
        # documented contract)
        led = TraceEngine(RING64).probe(pp_sh, CFG, SPEC,
                                        (BATCH, SEQ, CFG.d_model))
        ana = costs.proxy_exec_cost(BATCH, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers)
        assert (led.rounds, led.nbytes, led.flops) == \
            (ana.rounds, ana.nbytes, ana.flops)

    def test_softmax_strategies_differ(self, pp):
        """The strategies are real: distinct softmax ops, distinct
        probabilities (exact softmax rows sum to 1; 2Quad and the MLP
        emulator don't reproduce it bitwise)."""
        eng = ClearEngine()
        scores = jnp.asarray(np.random.default_rng(2).normal(
            size=(4, SEQ)) * 0.3, jnp.float32)
        outs = {v: np.asarray(eng.attn_probs(pp, 0, scores, VARIANTS[v]))
                for v in ("full", "quad_sm", "poly_sm", "no-sm")}
        assert np.allclose(outs["no-sm"].sum(-1), 1.0, atol=1e-6)
        for v in ("full", "quad_sm", "poly_sm"):
            assert not np.allclose(outs[v], outs["no-sm"], atol=1e-4), v


# ---------------------------------------------------------------------------
# 2. the deprecated shims are gone (PR 2 left them; this PR removes them)
# ---------------------------------------------------------------------------


class TestShimsRemoved:
    def test_proxy_shims_removed(self):
        assert not hasattr(proxy_mod, "proxy_entropy_clear")
        assert not hasattr(proxy_mod, "proxy_entropy_mpc")
        assert not hasattr(proxy_mod, "proxy_logits_clear")

    def test_approx_shims_removed(self):
        from repro.core import approx
        assert not hasattr(approx, "mlp_apply")
        assert not hasattr(approx, "mlp_apply_mpc")


# ---------------------------------------------------------------------------
# 3. TraceEngine: abstract probe == analytic mirror, no weights needed
# ---------------------------------------------------------------------------


class TestTrace:
    @pytest.mark.parametrize("ring", [RING64, RING32],
                             ids=["ring64", "ring32"])
    def test_abstract_probe_matches_mirror(self, ring):
        pp_sh = abstract_shares(CFG, SPEC, SEQ, CLASSES, ring)
        led = TraceEngine(ring).probe(pp_sh, CFG, SPEC,
                                      (BATCH, SEQ, CFG.d_model))
        ana = costs.proxy_exec_cost(BATCH, SEQ, CFG.d_model, SPEC.n_heads,
                                    CFG.n_kv_heads, CFG.d_head,
                                    SPEC.mlp_dim, CLASSES, SPEC.n_layers,
                                    ring=ring)
        assert len(led.records) == len(ana.records)
        for got, want in zip(led.records, ana.records):
            assert (got.rounds, got.nbytes, got.numel, got.flops, got.tag) \
                == (want.rounds, want.nbytes, want.numel, want.flops,
                    want.tag), (got, want)

    def test_baseline_softmaxes_cost_more(self):
        """quad/poly baselines pay reciprocal/comparison protocols the
        MLP emulator avoids — visible in the probed stream."""
        pp_sh = abstract_shares(CFG, SPEC, SEQ, CLASSES, RING64)
        led = {v: TraceEngine(RING64, VARIANTS[v]).probe(
                   pp_sh, CFG, SPEC, (BATCH, SEQ, CFG.d_model))
               for v in ("full", "quad_sm", "poly_sm")}
        assert led["quad_sm"].rounds > led["full"].rounds
        assert led["poly_sm"].rounds > led["quad_sm"].rounds


# ---------------------------------------------------------------------------
# 4. engine resolution + protocol surface
# ---------------------------------------------------------------------------


class TestResolution:
    def test_mode_strings(self):
        assert isinstance(resolve_engine("clear"), ClearEngine)
        eng = resolve_engine("mpc", ring=RING32)
        assert isinstance(eng, MPCEngine) and eng.ring is RING32
        assert isinstance(resolve_engine("trace"), TraceEngine)
        with pytest.raises(ValueError):
            resolve_engine("homomorphic")

    def test_instances_pass_through(self):
        eng = MPCEngine(ring=RING32)
        assert resolve_engine(eng) is eng

    def test_engines_satisfy_protocol(self):
        assert isinstance(ClearEngine(), TensorEngine)
        assert isinstance(MPCEngine(), TensorEngine)

    def test_unseeded_mpc_engine_refuses_keyed_ops(self):
        from repro.mpc.sharing import from_public
        x = from_public(jnp.ones((2, 2)), RING32)
        with pytest.raises(ValueError, match="with_key"):
            MPCEngine(RING32).mul(x, x)

    def test_selection_config_accepts_engine_and_string(self):
        from repro.core.executor import ExecConfig
        from repro.core.selection import SelectionConfig
        sel = SelectionConfig(phases=[SPEC], mode="mpc",
                              executor=ExecConfig(ring=RING32))
        assert isinstance(sel.engine, MPCEngine)
        assert sel.engine.ring is RING32
        sel2 = SelectionConfig(phases=[SPEC], engine=MPCEngine(RING32))
        assert sel2.mode == "mpc" and sel2.executor.ring is RING32
        assert SelectionConfig(phases=[SPEC]).mode == "clear"
        assert FULL_VARIANT == frozenset({"sm", "ln", "se"})
