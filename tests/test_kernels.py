"""Per-kernel shape/dtype sweeps: pallas(interpret=True) vs ref oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

K = jax.random.key(7)


def _k(i):
    return jax.random.fold_in(K, i)


def _rand(i, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(_k(i), shape) * scale).astype(dtype)


@pytest.mark.parametrize("bh,s,dh,hid", [(2, 32, 16, 4), (3, 64, 32, 8),
                                         (1, 128, 64, 16), (2, 64, 32, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mlp_softmax_attn_sweep(bh, s, dh, hid, dtype):
    q, k, v = (_rand(i, (bh, s, dh), dtype) for i in range(3))
    w1 = _rand(3, (s, hid), scale=0.2)
    b1 = _rand(4, (hid,), scale=0.1)
    w2 = _rand(5, (hid, s), scale=0.2)
    b2 = _rand(6, (s,), scale=0.01)
    got = ops.mlp_softmax_attn(q, k, v, w1, b1, w2, b2, impl="interpret",
                               bq=16, bk=16)
    want = ref.mlp_softmax_attn(q, k, v, w1, b1, w2, b2)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-4
    err = float(jnp.abs(got.astype(jnp.float32) - want).max())
    assert err < tol * max(1.0, float(jnp.abs(want).max())), err


def test_mlp_softmax_attn_block_shape_invariance():
    """Different BlockSpec tilings must give identical results."""
    q, k, v = (_rand(i, (2, 64, 32)) for i in range(3))
    w1, b1 = _rand(3, (64, 8), scale=0.2), _rand(4, (8,), scale=0.1)
    w2, b2 = _rand(5, (8, 64), scale=0.2), _rand(6, (64,), scale=0.01)
    o1 = ops.mlp_softmax_attn(q, k, v, w1, b1, w2, b2, impl="interpret",
                              bq=16, bk=16)
    o2 = ops.mlp_softmax_attn(q, k, v, w1, b1, w2, b2, impl="interpret",
                              bq=64, bk=32)
    assert np.allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)


@pytest.mark.parametrize("bh,sq,skv,dh", [(2, 32, 32, 16), (1, 64, 64, 64),
                                          (3, 32, 64, 32)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attn_sweep(bh, sq, skv, dh, causal):
    if causal and sq != skv:
        pytest.skip("causal requires square here")
    q = _rand(0, (bh, sq, dh))
    k = _rand(1, (bh, skv, dh))
    v = _rand(2, (bh, skv, dh))
    got = ops.flash_attn(q, k, v, causal=causal, impl="interpret",
                         bq=16, bk=16)
    want = ref.flash_attn(q, k, v, causal=causal)
    assert float(jnp.abs(got - want).max()) < 2e-5


@pytest.mark.parametrize("r,v", [(16, 64), (32, 512), (64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_entropy_head_sweep(r, v, dtype):
    logits = _rand(0, (r, v), dtype, scale=3.0)
    got = ops.entropy_head(logits, impl="interpret", br=16, bv=32)
    want = ref.entropy_head(logits)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    assert float(jnp.abs(got - want).max()) < tol
    # entropy bounded by log(V)
    assert float(got.max()) <= np.log(v) + 0.1


@pytest.mark.parametrize("b,t,h,p,n,chunk", [(2, 64, 3, 8, 16, 16),
                                             (1, 128, 2, 16, 32, 32),
                                             (2, 32, 1, 8, 8, 8)])
def test_ssd_sweep(b, t, h, p, n, chunk):
    x = _rand(0, (b, t, h, p))
    a = -jnp.abs(_rand(1, (b, t, h), scale=0.2))
    bb = _rand(2, (b, t, n), scale=0.3)
    c = _rand(3, (b, t, n), scale=0.3)
    got = ops.ssd_chunked(x, a, bb, c, chunk=chunk, impl="interpret")
    want = ref.ssd(x, a, bb, c)
    scale = max(1.0, float(jnp.abs(want).max()))
    assert float(jnp.abs(got - want).max()) / scale < 1e-5


def test_ssd_kernel_matches_model_scan():
    """The Pallas kernel and the model-zoo ssd_scan share semantics."""
    from repro.models.ssd import ssd_scan
    b, t, h, p, n = 2, 64, 3, 8, 16
    x = _rand(0, (b, t, h, p))
    a = -jnp.abs(_rand(1, (b, t, h), scale=0.2))
    bb = _rand(2, (b, t, n), scale=0.3)
    c = _rand(3, (b, t, n), scale=0.3)
    y_model, _ = ssd_scan(x, a, bb, c, chunk=16)
    y_kernel = ops.ssd_chunked(x, a, bb, c, chunk=16, impl="interpret")
    assert np.allclose(np.asarray(y_model), np.asarray(y_kernel), atol=1e-4)


@pytest.mark.parametrize("b,t,d,bt", [(2, 64, 16, 16), (1, 128, 32, 32),
                                      (3, 32, 8, 8)])
def test_rg_lru_sweep(b, t, d, bt):
    a = jax.nn.sigmoid(_rand(0, (b, t, d)))
    bb = _rand(1, (b, t, d))
    got = ops.rg_lru_scan(a, bb, impl="interpret", bt=bt)
    want = ref.rg_lru(a, bb)
    assert float(jnp.abs(got - want).max()) < 1e-5


@pytest.mark.parametrize("m,k,n", [(16, 32, 8), (32, 64, 32), (8, 128, 16)])
def test_secure_matmul_exact(m, k, n):
    rng = np.random.default_rng(m + k + n)
    eps = jnp.asarray(rng.integers(-2 ** 20, 2 ** 20, (m, k)), jnp.int32)
    dlt = jnp.asarray(rng.integers(-2 ** 20, 2 ** 20, (k, n)), jnp.int32)
    a = jnp.asarray(rng.integers(-2 ** 30, 2 ** 30, (2, m, k)), jnp.int32)
    b = jnp.asarray(rng.integers(-2 ** 30, 2 ** 30, (2, k, n)), jnp.int32)
    c = jnp.asarray(rng.integers(-2 ** 30, 2 ** 30, (2, m, n)), jnp.int32)
    got = ops.secure_matmul(eps, dlt, a, b, c, impl="interpret",
                            bm=8, bn=8, bk=16)
    want = ops.secure_matmul(eps, dlt, a, b, c, impl="ref")
    assert np.array_equal(np.asarray(got), np.asarray(want))


def test_secure_matmul_implements_beaver():
    """Kernel combine + reconstruction == plain ring matmul x@y."""
    rng = np.random.default_rng(0)
    m, kdim, n = 8, 16, 8
    x = rng.integers(-2 ** 10, 2 ** 10, (m, kdim)).astype(np.int32)
    y = rng.integers(-2 ** 10, 2 ** 10, (kdim, n)).astype(np.int32)
    a = rng.integers(-2 ** 30, 2 ** 30, (m, kdim)).astype(np.int32)
    b = rng.integers(-2 ** 30, 2 ** 30, (kdim, n)).astype(np.int32)
    c = (a.astype(np.int64) @ b.astype(np.int64)).astype(np.int32)  # wraps
    # share everything
    a_sh = np.stack([rng.integers(-2 ** 31, 2 ** 31, a.shape),
                     np.zeros_like(a)]).astype(np.int32)
    a_sh[1] = a - a_sh[0]
    b_sh = np.stack([rng.integers(-2 ** 31, 2 ** 31, b.shape),
                     np.zeros_like(b)]).astype(np.int32)
    b_sh[1] = b - b_sh[0]
    c_sh = np.stack([rng.integers(-2 ** 31, 2 ** 31, c.shape),
                     np.zeros_like(c)]).astype(np.int32)
    c_sh[1] = c - c_sh[0]
    eps = (x - a).astype(np.int32)
    dlt = (y - b).astype(np.int32)
    z_sh = ops.secure_matmul(jnp.asarray(eps), jnp.asarray(dlt),
                             jnp.asarray(a_sh), jnp.asarray(b_sh),
                             jnp.asarray(c_sh), impl="interpret",
                             bm=8, bn=8, bk=16)
    z = np.asarray(z_sh[0] + z_sh[1])
    want = (x.astype(np.int64) @ y.astype(np.int64)).astype(np.int32)
    assert np.array_equal(z, want)
