import functools
import inspect
import os
import sys
import types

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the 512-device mesh belongs to dryrun.py
# only, which is its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402

# ---------------------------------------------------------------------------
# hypothesis guard: property tests SKIP (not error) when hypothesis is
# absent. The shim replaces @given-decorated tests with a skipper whose
# signature hides the strategy-bound parameters from pytest's fixture
# resolution; everything else in the module still collects and runs.
# Install dev deps (requirements-dev.txt) to run the property tests.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given(*strategies, **kw_strategies):
        def deco(fn):
            @functools.wraps(fn)
            def skipper(*args, **kwargs):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")
            sig = inspect.signature(fn)
            names = list(sig.parameters)
            bound = set(kw_strategies)
            if strategies:                 # positional strategies fill from the right
                bound |= set(names[len(names) - len(strategies):])
            skipper.__signature__ = sig.replace(
                parameters=[p for n, p in sig.parameters.items() if n not in bound])
            return skipper
        return deco

    def _settings(*args, **kwargs):
        if args and callable(args[0]):     # bare @settings
            return args[0]
        return lambda fn: fn

    class _Strategies(types.ModuleType):
        def __getattr__(self, name):       # any strategy -> inert placeholder
            return lambda *a, **k: None

    _st = _Strategies("hypothesis.strategies")
    _hyp = types.ModuleType("hypothesis")
    _hyp.given, _hyp.settings, _hyp.strategies = _given, _settings, _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None, data_too_large=None)
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


@pytest.fixture
def key():
    return jax.random.key(0)


@pytest.fixture
def x64():
    from repro.mpc.ring import x64_scope
    with x64_scope():
        yield
