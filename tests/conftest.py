import os
import sys

# NOTE: do NOT set xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device (the 512-device mesh belongs to dryrun.py
# only, which is its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def key():
    return jax.random.key(0)


@pytest.fixture
def x64():
    with jax.enable_x64(True):
        yield
