"""Cross-backend conformance suite — every registered protocol backend
satisfies the same executable contracts.

Parametrized over ALL of `protocols.PROTOCOLS` (2pc, 3pc, spdz2pc,
aby3trunc) x both rings:

  1. ROUNDTRIP    share -> open reconstructs the encoded value exactly.
  2. WIRE MODEL   `open_` records 1 round of `backend.open_bytes`,
                  matching `costs.open_cost` tuple-for-tuple.
  3. ARITHMETIC   mul / matmul match the clear product within the
                  ring's fixed-point tolerance.
  4. SCALE LATTICE add/sub/concat/stack align mixed-exponent operands
                  (canonical vs 2f products) exactly — the carried-
                  scale contract is backend-independent.
  5. TRUNCATION   trunc(shift=) holds each scheme's error bound: <= a
                  few ulp for every backend on small-range values
                  (exact schemes by construction; probabilistic ones
                  because the wrap term vanishes at small |v|).
  6. MIRROR       each sampled op's executed ledger records equal the
                  analytic `costs.*_cost` records (rounds, bytes,
                  numel, flops, tag).
  7. TAMPER       semi-honest backends accept a flipped share bit
                  SILENTLY (documented here); only spdz2pc aborts
                  (pinned in tests/test_malicious.py).

The property-based cases sample values/shapes with hypothesis; when
hypothesis is not installed they skip via the conftest shim (CI fails
if that happens in the tier-1 job — see .github/workflows/ci.yml).
"""
import contextlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.mpc import costs, ops as mops, protocols
from repro.mpc.comm import ledger_scope
from repro.mpc.ring import RING32, RING64, x64_scope
from repro.mpc.sharing import open_, reveal, share

PROTOS = sorted(protocols.PROTOCOLS)
RINGS = {"ring64": RING64, "ring32": RING32}
K = jax.random.key(11)


def _k(i):
    return jax.random.fold_in(K, i)


def ring_scope(ring):
    """RING64 arithmetic needs x64; RING32 must run WITHOUT global x64
    (jnp reductions would silently promote int32 sums to int64)."""
    return x64_scope() if ring.bits >= 64 else contextlib.nullcontext()


def tol(ring, ulp=4):
    return ulp / ring.scale


def _wrap_prone(proto, ring):
    """3pc on RING32 truncates by probabilistic share-regroup: each
    element wraps with probability |enc|/2^32, landing a 2^(32-2f)
    error when it does. Every other scheme/ring combo is exact (dealer
    pair, trunc2) or wraps with ~2^-50 probability (RING64 shifts)."""
    return proto == "3pc" and ring.bits == 32


def _assert_close(got, want, ring, proto, ulp=8):
    err = np.abs(got - want)
    if _wrap_prone(proto, ring):
        wrapped = err > 1.0
        # bounded-probability wraps are this scheme's documented error
        # mode (quantified in test_malicious's statistical wrap test)
        assert wrapped.mean() < 0.5, proto
        err = err[~wrapped]
        if err.size == 0:
            return
    assert err.max() < tol(ring, ulp), (proto, ring.bits)


def _vals(i, shape, scale=3.0):
    return np.asarray(jax.random.normal(_k(i), shape)) * scale


ring_params = pytest.mark.parametrize("ring", list(RINGS.values()),
                                      ids=list(RINGS))
proto_params = pytest.mark.parametrize("proto", PROTOS)


# ---------------------------------------------------------------------------
# 1-2. roundtrip + wire model
# ---------------------------------------------------------------------------

@proto_params
@ring_params
def test_share_open_roundtrip(proto, ring):
    v = _vals(1, (5, 3))
    with ring_scope(ring):
        x = share(_k(2), jnp.asarray(v, jnp.float32), ring, proto)
        assert x.sh.shape[0] == protocols.get(proto).n_parties
        got = np.asarray(reveal(x))
    assert np.abs(got - v).max() < tol(ring, 2), proto


@proto_params
@ring_params
def test_open_wire_model_matches_mirror(proto, ring):
    n = 12
    with ring_scope(ring):
        x = share(_k(3), jnp.ones((n,), jnp.float32), ring, proto)
        with ledger_scope() as led:
            open_(x)
    assert len(led.records) == 1
    r = led.records[0]
    assert r.rounds == 1
    assert r.nbytes == protocols.get(proto).open_bytes(ring, n)
    (w,) = costs.open_cost(n, ring=ring, protocol=proto).records
    assert (r.rounds, r.nbytes, r.numel, r.flops, r.tag) == \
        (w.rounds, w.nbytes, w.numel, w.flops, w.tag)


# ---------------------------------------------------------------------------
# 3. secure arithmetic vs clear
# ---------------------------------------------------------------------------

@proto_params
@ring_params
def test_mul_matches_clear(proto, ring):
    a, b = _vals(4, (4, 5)), _vals(5, (4, 5))
    with ring_scope(ring):
        x = share(_k(6), jnp.asarray(a, jnp.float32), ring, proto)
        y = share(_k(7), jnp.asarray(b, jnp.float32), ring, proto)
        z = mops.force(mops.mul(x, y, _k(8)), _k(9))
        got = np.asarray(reveal(z))
    _assert_close(got, a * b, ring, proto)


@proto_params
@ring_params
def test_matmul_matches_clear(proto, ring):
    a, b = _vals(10, (3, 4), 1.0), _vals(11, (4, 2), 1.0)
    with ring_scope(ring):
        x = share(_k(12), jnp.asarray(a, jnp.float32), ring, proto)
        y = share(_k(13), jnp.asarray(b, jnp.float32), ring, proto)
        z = mops.force(mops.matmul(x, y, _k(14)), _k(15))
        got = np.asarray(reveal(z))
    _assert_close(got, a @ b, ring, proto, ulp=16)


# ---------------------------------------------------------------------------
# 4. scale-lattice alignment of linear ops
# ---------------------------------------------------------------------------

@proto_params
@ring_params
def test_linear_ops_align_mixed_exponents(proto, ring):
    """A canonical-f operand meets a 2f product in add/sub/concat/stack:
    the lattice lifts the lower exponent exactly on EVERY backend."""
    a, b, c = _vals(16, (6,)), _vals(17, (6,)), _vals(18, (6,))
    with ring_scope(ring):
        x = share(_k(19), jnp.asarray(a, jnp.float32), ring, proto)
        y = share(_k(20), jnp.asarray(b, jnp.float32), ring, proto)
        w = share(_k(21), jnp.asarray(c, jnp.float32), ring, proto)
        p = mops.mul(x, y, _k(22))            # rides at 2f
        assert p.excess > 0
        add = np.asarray(reveal(mops.add(p, w)))
        sub = np.asarray(reveal(mops.sub(p, w)))
        cat = np.asarray(reveal(mops.concat([p, w], axis=0)))
        stk = np.asarray(reveal(mops.stack([w, p], axis=0)))
    t = tol(ring, 16)
    assert np.abs(add - (a * b + c)).max() < t, proto
    assert np.abs(sub - (a * b - c)).max() < t, proto
    assert np.abs(cat - np.concatenate([a * b, c])).max() < t, proto
    assert np.abs(stk - np.stack([c, a * b])).max() < t, proto


# ---------------------------------------------------------------------------
# 5. truncation error bound per scheme
# ---------------------------------------------------------------------------

@proto_params
@ring_params
def test_trunc_shift_error_bound(proto, ring):
    """force(product) truncates the 2f excess in ONE trunc(shift=).
    Exact schemes (2pc dealer pair on RING32, spdz2pc's MAC'd pair,
    aby3trunc's trunc2) and the RING64 shifts stay within a few ulp
    everywhere; 3pc on RING32 additionally wraps with probability
    |enc|/2^32 per element — its non-wrapped elements still meet the
    same ulp bound (the wrap RATE itself is gated statistically in
    test_malicious)."""
    a, b = _vals(23, (64,)), _vals(24, (64,))
    with ring_scope(ring):
        x = share(_k(25), jnp.asarray(a, jnp.float32), ring, proto)
        y = share(_k(26), jnp.asarray(b, jnp.float32), ring, proto)
        p = mops.mul(x, y, _k(27))
        f = mops.force(p, _k(28))
        assert f.fb == ring.frac_bits
        got = np.asarray(reveal(f))
    _assert_close(got, a * b, ring, proto)


# ---------------------------------------------------------------------------
# 6. executed ledger == analytic mirror, per sampled op
# ---------------------------------------------------------------------------

def _tuples(records):
    return [(r.rounds, r.nbytes, r.numel, r.flops, r.tag) for r in records]


@proto_params
@ring_params
@pytest.mark.parametrize("opname", ["mul", "matmul", "force"])
def test_op_ledger_matches_mirror(proto, ring, opname):
    with ring_scope(ring):
        if opname == "matmul":
            x = share(_k(29), jnp.ones((3, 4), jnp.float32), ring, proto)
            y = share(_k(30), jnp.ones((4, 2), jnp.float32), ring, proto)
            with ledger_scope() as led:
                mops.matmul(x, y, _k(31))
            want = costs.matmul_cost(1, 3, 4, 2, ring=ring, protocol=proto,
                                     inline_trunc=False)
        elif opname == "mul":
            x = share(_k(32), jnp.ones((7,), jnp.float32), ring, proto)
            with ledger_scope() as led:
                mops.mul(x, x, _k(33))
            want = costs.mul_cost(7, ring=ring, protocol=proto,
                                  inline_trunc=False)
        else:
            x = share(_k(34), jnp.ones((7,), jnp.float32), ring, proto)
            p = mops.mul(x, x, _k(35))
            with ledger_scope() as led:
                mops.force(p, _k(36))
            want = costs.trunc_cost(7, ring=ring, protocol=proto)
    assert _tuples(led.records) == _tuples(want.records), \
        (proto, ring.bits, opname,
         [r.op for r in led.records], [r.op for r in want.records])


# ---------------------------------------------------------------------------
# 7. semi-honest backends accept tampering SILENTLY
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("proto", [p for p in PROTOS if p != "spdz2pc"])
def test_semi_honest_backends_accept_tamper_silently(proto):
    """The documented gap malicious security closes: flip one bit of a
    share component and every semi-honest backend opens the corrupted
    value without complaint — there is no authentication to trip. The
    spdz2pc abort on the identical flip is pinned in test_malicious."""
    v = np.asarray([1.5, -2.25], np.float32)
    with x64_scope():
        x = share(_k(37), jnp.asarray(v), RING64, proto)
        honest = np.asarray(reveal(x))
        bad = x.with_sh(x.sh.at[0, 0].add(1 << 8))
        tampered = np.asarray(reveal(bad))   # no exception: accepted
    assert np.abs(honest - v).max() < tol(RING64, 2)
    assert tampered[0] != honest[0], "tamper must corrupt the opening"
    assert tampered[1] == honest[1]


# ---------------------------------------------------------------------------
# property-based cases (hypothesis; skip via conftest shim when absent)
# ---------------------------------------------------------------------------

@proto_params
@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-8, 8, allow_nan=False, allow_infinity=False,
                          width=32), min_size=1, max_size=8))
def test_prop_roundtrip_any_values(proto, vs):
    v = np.asarray(vs, np.float32)
    with x64_scope():
        got = np.asarray(reveal(share(_k(38), jnp.asarray(v), RING64,
                                      proto)))
    assert np.abs(got - v).max() < tol(RING64, 2), proto


@proto_params
@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-4, 4, allow_nan=False, allow_infinity=False,
                          width=32), min_size=1, max_size=6),
       st.floats(-4, 4, allow_nan=False, allow_infinity=False, width=32))
def test_prop_affine_public_constant(proto, vs, c):
    """add_public is exact on every backend (MAC'd schemes must update
    their MAC rows too, or the next open would be rejected)."""
    v = np.asarray(vs, np.float32)
    with x64_scope():
        x = share(_k(39), jnp.asarray(v), RING64, proto)
        got = np.asarray(reveal(mops.add_public(x, float(c))))
    assert np.abs(got - (v + np.float32(c))).max() < tol(RING64, 4), proto


@proto_params
@settings(max_examples=10, deadline=None)
@given(st.lists(st.floats(-4, 4, allow_nan=False, allow_infinity=False,
                          width=32), min_size=2, max_size=6),
       st.integers(min_value=1, max_value=6))
def test_prop_trunc_any_shift(proto, vs, shift):
    """trunc(shift=) divides by 2**shift within a few ulp of the OUTPUT
    exponent, for any sampled shift, on every backend."""
    v = np.asarray(vs, np.float32)
    with x64_scope():
        x = share(_k(40), jnp.asarray(v), RING64, proto)
        z = mops.trunc(x, key=_k(41), shift=shift)
        assert z.fb == RING64.frac_bits - shift
        got = np.asarray(reveal(z))
    assert np.abs(got - v).max() < 4 * 2.0 ** -(RING64.frac_bits - shift), \
        proto


@proto_params
@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=1, max_value=5),
       st.integers(min_value=1, max_value=5))
def test_prop_mirror_any_shape(proto, rows, cols):
    """Ledger/mirror agreement holds for SAMPLED shapes, not just the
    hand-picked ones above."""
    with x64_scope():
        x = share(_k(42), jnp.ones((rows, cols), jnp.float32), RING64,
                  proto)
        with ledger_scope() as led:
            mops.mul(x, x, _k(43))
    want = costs.mul_cost(rows * cols, ring=RING64, protocol=proto,
                          inline_trunc=False)
    assert _tuples(led.records) == _tuples(want.records), proto
