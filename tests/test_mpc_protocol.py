"""MPC substrate: protocol correctness + hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.mpc import RING64, ops, nonlinear, compare, quickselect
from repro.mpc.sharing import share, reveal
from repro.mpc.comm import ledger_scope
from repro.mpc.ring import RING32, x64_scope
from repro.mpc import beaver

pytestmark = pytest.mark.usefixtures("x64")

K = jax.random.key(42)
TOL = 2.0 / RING64.scale * 4     # a few LSBs of the fixed-point ring


def _k(i):
    return jax.random.fold_in(K, i)


# ---------------------------------------------------------------------------
# sharing
# ---------------------------------------------------------------------------

class TestSharing:
    def test_share_reconstruct_roundtrip(self):
        x = jnp.array([1.5, -2.25, 1000.0, -0.0001, 0.0])
        assert np.allclose(reveal(share(_k(0), x)), x, atol=TOL)

    def test_single_share_is_uniform(self):
        """One share alone must carry no information about the value."""
        x = jnp.full((4096,), 7.25)
        s = share(_k(1), x)
        sh0 = np.asarray(s.sh[0], dtype=np.float64)
        # uniform over the full int64 ring: huge spread, near-zero mean
        assert np.std(sh0) > 2 ** 60
        assert abs(np.mean(sh0 / 2 ** 63)) < 0.1

    def test_different_keys_different_shares(self):
        x = jnp.ones((16,))
        s1, s2 = share(_k(2), x), share(_k(3), x)
        assert not np.array_equal(np.asarray(s1.sh[0]), np.asarray(s2.sh[0]))
        assert np.allclose(reveal(s1), reveal(s2), atol=TOL)


# ---------------------------------------------------------------------------
# linear ops (hypothesis)
# ---------------------------------------------------------------------------

small_floats = st.lists(st.floats(-64, 64, allow_nan=False, width=32),
                        min_size=1, max_size=16)


class TestLinearOps:
    @given(small_floats, small_floats)
    @settings(max_examples=25, deadline=None)
    def test_add_homomorphic(self, xs, ys):
        n = min(len(xs), len(ys))
        x = jnp.array(xs[:n], jnp.float64)
        y = jnp.array(ys[:n], jnp.float64)
        with x64_scope():
            z = reveal(ops.add(share(_k(4), x), share(_k(5), y)))
        assert np.allclose(z, x + y, atol=TOL)

    @given(small_floats, small_floats)
    @settings(max_examples=25, deadline=None)
    def test_mul_beaver(self, xs, ys):
        n = min(len(xs), len(ys))
        x = jnp.array(xs[:n], jnp.float64)
        y = jnp.array(ys[:n], jnp.float64)
        with x64_scope():
            z = reveal(ops.mul(share(_k(6), x), share(_k(7), y), _k(8)))
        # mul error ~ |x| * trunc_lsb: scale tolerance with magnitude
        tol = TOL * (1 + np.abs(x * y).max())
        assert np.allclose(z, x * y, atol=tol)

    def test_matmul(self):
        a = jax.random.normal(_k(9), (5, 7))
        b = jax.random.normal(_k(10), (7, 3))
        z = reveal(ops.matmul(share(_k(11), a), share(_k(12), b), _k(13)))
        assert np.allclose(z, a @ b, atol=1e-3)

    def test_public_ops(self):
        x = jnp.array([1.0, -2.0, 3.0])
        xs = share(_k(14), x)
        assert np.allclose(reveal(ops.add_public(xs, 2.5)), x + 2.5, atol=TOL)
        assert np.allclose(reveal(ops.mul_public(xs, -1.5)), x * -1.5,
                           atol=1e-3)
        assert np.allclose(reveal(ops.mul_public_int(xs, 3)), x * 3, atol=TOL)

    def test_sum_mean(self):
        x = jax.random.normal(_k(15), (4, 8))
        xs = share(_k(16), x)
        assert np.allclose(reveal(ops.sum_(xs, axis=-1)), x.sum(-1), atol=1e-3)
        assert np.allclose(reveal(ops.mean(xs, axis=-1)), x.mean(-1), atol=1e-3)


# ---------------------------------------------------------------------------
# nonlinear baselines
# ---------------------------------------------------------------------------

class TestNonlinear:
    def test_exp(self):
        x = jnp.array([-2.0, -1.0, 0.0, 0.5, 1.0])
        z = reveal(nonlinear.exp(share(_k(20), x), _k(21)))
        assert np.allclose(z, np.exp(x), rtol=0.05, atol=0.02)

    def test_reciprocal(self):
        x = jnp.array([0.25, 0.5, 1.0, 3.0, 7.0])
        z = reveal(nonlinear.reciprocal(share(_k(22), x), _k(23)))
        assert np.allclose(z, 1 / x, rtol=0.02)

    def test_rsqrt(self):
        x = jnp.array([0.25, 1.0, 2.0, 4.0])
        z = reveal(nonlinear.rsqrt(share(_k(24), x), _k(25)))
        assert np.allclose(z, x ** -0.5, rtol=0.1)

    def test_softmax_close_and_normalized(self):
        x = jax.random.normal(_k(26), (3, 8)) * 2
        z = reveal(nonlinear.softmax(share(_k(27), x), _k(28)))
        want = jax.nn.softmax(x, -1)
        assert np.allclose(z, want, atol=0.02)
        assert np.allclose(z.sum(-1), 1.0, atol=0.05)

    def test_entropy_from_logits(self):
        x = jax.random.normal(_k(29), (4, 6)) * 2
        z = reveal(nonlinear.entropy_from_logits(share(_k(30), x), _k(31)))
        p = jax.nn.softmax(x, -1)
        want = -(p * jnp.log(p + 1e-9)).sum(-1)
        assert np.allclose(z, want, atol=0.15)

    def test_layernorm(self):
        x = jax.random.normal(_k(32), (2, 16))
        g = jnp.ones((16,))
        b = jnp.zeros((16,))
        z = reveal(nonlinear.layernorm(share(_k(33), x), g, b, _k(34)))
        mu = x.mean(-1, keepdims=True)
        want = (x - mu) / jnp.sqrt(x.var(-1, keepdims=True) + 1e-5)
        assert np.allclose(z, want, atol=0.2)


# ---------------------------------------------------------------------------
# comparisons / quickselect
# ---------------------------------------------------------------------------

class TestCompare:
    def test_relu(self):
        x = jnp.array([-3.0, -0.5, 0.0, 0.5, 3.0])
        z = reveal(compare.relu(share(_k(40), x), _k(41)))
        assert np.allclose(z, np.maximum(x, 0), atol=1e-3)

    def test_max_matches(self):
        x = jax.random.normal(_k(42), (4, 7))
        z = reveal(compare.max_(share(_k(43), x), axis=-1, key=_k(44)))
        assert np.allclose(z[..., 0], x.max(-1), atol=1e-3)

    def test_comparison_cost_accounted(self):
        x = share(_k(45), jnp.zeros((10,)))
        y = share(_k(46), jnp.ones((10,)))
        with ledger_scope() as led:
            compare.reveal_lt(x, y)
        assert led.rounds == compare.CMP_ROUNDS
        assert led.nbytes == compare.CMP_BYTES * 10

    @given(st.integers(10, 200), st.integers(1, 9))
    @settings(max_examples=10, deadline=None)
    def test_quickselect_exact_topk(self, n, kfrac):
        k = max(1, n * kfrac // 10)
        rng = np.random.default_rng(n * 10 + kfrac)
        scores = jnp.asarray(rng.normal(size=n))
        with x64_scope():
            ss = share(_k(47), scores)
            got = quickselect.top_k_indices(ss, k, seed=0)
        want = np.sort(np.argsort(np.asarray(scores))[-k:])
        assert np.array_equal(np.sort(got), want)

    def test_quickselect_reveals_only_bits(self):
        """The ledger for quickselect must contain only comparison ops."""
        scores = jnp.asarray(np.random.default_rng(0).normal(size=50))
        ss = share(_k(48), scores)
        with ledger_scope() as led:
            quickselect.top_k_indices(ss, 10)
        assert all(r.op.startswith("secure_cmp") for r in led.records)


# ---------------------------------------------------------------------------
# RING32 dealer-assisted truncation
# ---------------------------------------------------------------------------

class TestRing32:
    def test_trunc_pair_mul(self):
        x = jnp.array([1.5, -2.0, 0.25, 3.0], jnp.float32)
        y = jnp.array([2.0, 1.5, -4.0, 0.5], jnp.float32)
        xs = share(_k(50), x, RING32)
        ys = share(_k(51), y, RING32)
        z = reveal(ops.mul(xs, ys, _k(52)))
        assert np.allclose(z, x * y, atol=4.0 / RING32.scale * (1 + 8))

    def test_beaver_triple_consistency(self):
        a, b, c = beaver.mul_triple(_k(53), (32,), RING64)
        av = a.sh[0] + a.sh[1]
        bv = b.sh[0] + b.sh[1]
        cv = c.sh[0] + c.sh[1]
        assert np.array_equal(np.asarray(av * bv), np.asarray(cv))
