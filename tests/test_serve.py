"""Appraisal service: dealer pool, phase cache, scheduler parity.

Contracts (ISSUE 10):
  1. DEALER — the background pool produces exactly the staged demand
     (per-op/per-ring element accounting from the same TraceEngine
     probes the executor reconciles against); an un-staged acquire
     still completes (top-up) but bills the wait into dealer_stall_s;
     dealer-free backends stage nothing.
  2. CACHE — put/get roundtrips bitwise in memory and across a
     persist_dir handoff (disk hit); the key separates fingerprint,
     ring, and protocol.
  3. SERVER — interleaving two identical + queued sessions yields
     scores/survivors bitwise identical to standalone `run_selection`,
     with the duplicate's phases served from cache/coalescing, every
     per-session ledger reconciled, and the modeled service makespan
     strictly below the N-sequential baseline.
  4. GUARDS — sessions reject wire/mesh executor modes (the
     interleaver owns the schedule).
"""
import dataclasses
import time

import numpy as np
import jax
import pytest

from repro.configs.paper_targets import TINY_TARGET
from repro.core import target as tgt
from repro.core.executor import ExecConfig
from repro.core.proxy import ProxySpec
from repro.core.selection import PhaseRequest, SelectionConfig, run_selection
from repro.data.tasks import make_classification_task
from repro.engine import MPCEngine, cached_probe
from repro.mpc.ring import RING32, RING64
from repro.serve import (AppraisalServer, DealerPool, Order, PhaseCache,
                         SessionSpec, phase_key, phase_orders)
from repro.serve.session import AppraisalSession


# ---------------------------------------------------------------------------
# 1. dealer pool
# ---------------------------------------------------------------------------

def _probe(protocol, ring=RING32):
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=64)
    return cached_probe(cfg, ProxySpec(1, 1, 2), batch=4, seq=8, classes=2,
                        ring=ring, protocol=protocol, fused=True), cfg


class TestDealerPool:
    def test_orders_mirror_probe_offline_channel(self):
        pb, _ = _probe("2pc")
        orders = phase_orders(pb, 3, RING32, "2pc")
        assert orders and all(o.elems > 0 for o in orders)
        want = {op: numel * 3
                for op, (numel, _) in pb.offline_by_op().items()}
        got = {o.op: o.elems for o in orders}
        assert got == want, "per-batch offline numel x n_batches"

    def test_dealer_free_backend_stages_nothing(self):
        pb, _ = _probe("3pc")
        assert phase_orders(pb, 3, RING32, "3pc") == []

    def test_staged_acquire_is_stall_free(self):
        orders = [Order("offline.mul_triple", RING32, "2pc", 3000),
                  Order("offline.trunc_pair", RING32, "2pc", 1000)]
        pool = DealerPool(seed=1)
        try:
            pool.stage(orders)
            deadline = time.time() + 30
            while pool.stats()["pooled_elems"] < 4000:
                assert time.time() < deadline, pool.stats()
                time.sleep(0.01)
            pool.acquire(orders)
            st = pool.stats()
            assert st["dealer_stall_s"] == 0.0 and st["stalls"] == 0
            assert st["consumed_elems"] == 4000
            assert st["produced_elems"] >= 4000
        finally:
            pool.close()

    def test_unstaged_acquire_tops_up_and_bills_stall(self):
        orders = [Order("offline.mul_triple", RING64, "2pc", 2048)]
        pool = DealerPool(seed=2)
        try:
            pool.acquire(orders)          # nothing pre-staged
            st = pool.stats()
            assert st["consumed_elems"] == 2048
            assert st["stalls"] == 1 and st["dealer_stall_s"] > 0.0
        finally:
            pool.close()


# ---------------------------------------------------------------------------
# 2. phase cache
# ---------------------------------------------------------------------------

def _req(fingerprint="aa" * 8, phase=0, keep=8, batch=4):
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=64)
    return PhaseRequest(phase=phase, key=None, pp=None,
                        tokens=np.zeros((16, 8), np.int32),
                        spec=ProxySpec(1, 1, 2), keep=keep, batch=batch,
                        fingerprint=fingerprint)


class TestPhaseCache:
    def test_memory_roundtrip_bitwise(self):
        c = PhaseCache()
        key = phase_key(_req(), RING64, "2pc")
        assert c.get(key) is None
        scores = np.arange(16, dtype=np.int64) * (1 << 40) - 7
        c.put(key, scores, None)
        got, rep = c.get(key)
        assert np.array_equal(got, scores) and rep is None
        assert c.stats()["hits"] == 1 and c.stats()["misses"] == 1

    def test_key_separates_fingerprint_ring_protocol(self):
        base = phase_key(_req(), RING64, "2pc")
        assert phase_key(_req(fingerprint="bb" * 8), RING64, "2pc") != base
        assert phase_key(_req(), RING32, "2pc") != base
        assert phase_key(_req(), RING64, "spdz2pc") != base
        assert phase_key(_req(phase=1), RING64, "2pc") != base
        assert phase_key(_req(), RING64, "2pc") == base

    def test_persist_dir_survives_process_handoff(self, tmp_path):
        key = phase_key(_req(), RING64, "2pc")
        scores = np.arange(8, dtype=np.int64) - 3
        c1 = PhaseCache(persist_dir=str(tmp_path))
        c1.put(key, scores, None)
        c2 = PhaseCache(persist_dir=str(tmp_path))   # fresh memory
        got, _ = c2.get(key)
        assert np.array_equal(got, scores)
        assert c2.stats()["disk_hits"] == 1


# ---------------------------------------------------------------------------
# 3. server end-to-end parity (tiny: one phase, two twin sessions)
# ---------------------------------------------------------------------------

def _spec(sid, seed, n_pool=32):
    task = make_classification_task(seed, n_pool=n_pool, n_test=16, seq=8,
                                    vocab=64, n_classes=2)
    cfg = dataclasses.replace(TINY_TARGET, vocab_size=task.vocab)
    key = jax.random.key(seed)
    params0 = tgt.init_classifier(key, cfg, task.n_classes)
    sel = SelectionConfig(
        phases=[ProxySpec(1, 1, 2, 1.0)], budget_frac=0.5, boot_frac=0.25,
        engine=MPCEngine(protocol="2pc"), exvivo_steps=2, invivo_steps=1,
        finetune_steps=1, score_batch=8, checkpoint_dir=None,
        executor=ExecConfig(wave=2, protocol="2pc"))
    ctx = dict(key=key, params0=params0, cfg=cfg, task=task, sel=sel)
    return SessionSpec(sid=sid, key=key, target_params=params0,
                       arch_cfg=cfg, pool_tokens=task.pool_tokens, sel=sel,
                       n_classes=task.n_classes,
                       boot_labels_fn=lambda i: task.pool_labels[i]), ctx


@pytest.mark.slow
class TestServerParity:
    def test_twin_sessions_match_standalone_bitwise(self):
        srv = AppraisalServer(max_active=2)
        spec_a, ctx = _spec("a", 3)
        spec_b, _ = _spec("b", 3)            # twin -> cache/coalescing
        sa, sb = srv.submit(spec_a), srv.submit(spec_b)
        rep = srv.run()
        srv.close()
        std = run_selection(ctx["key"], ctx["params0"], ctx["cfg"],
                            ctx["task"].pool_tokens,
                            dataclasses.replace(ctx["sel"]),
                            n_classes=ctx["task"].n_classes,
                            boot_labels_fn=lambda i:
                            ctx["task"].pool_labels[i])
        for s in (sa, sb):
            assert all(np.array_equal(x, y) for x, y in
                       zip(s.result.phase_scores, std.phase_scores))
            assert s.result.appraisal_entropy == std.appraisal_entropy
            assert np.array_equal(s.result.selected, std.selected)
        # the twin never re-executed: one executed phase for two sessions
        t = rep["throughput"]
        assert t["n_phases_executed"] < t["n_phases_total"]
        assert rep["cache"]["hits"] + rep["cache"]["coalesced_waits"] > 0
        assert rep["ledger_agrees"] is True
        assert (t["serve_appraisals_per_hour"]
                > t["sequential_appraisals_per_hour"])
        assert rep["dealer"]["dealer_stall_s"] == 0.0


# ---------------------------------------------------------------------------
# 4. guards
# ---------------------------------------------------------------------------

class TestGuards:
    @pytest.mark.parametrize("kw", [dict(wire="local"), dict(mesh="host")])
    def test_session_rejects_wire_and_mesh(self, kw):
        spec, _ = _spec("x", 0)
        bad = dataclasses.replace(
            spec, sel=dataclasses.replace(
                spec.sel, executor=dataclasses.replace(spec.sel.executor,
                                                       **kw)))
        with pytest.raises(ValueError, match="wire='none'"):
            AppraisalSession(bad)
