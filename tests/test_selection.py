"""Selection pipeline: clear<->MPC parity, efficacy ordering, approx MLPs."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_targets import TINY_TARGET
from repro.core import approx, proxy as proxy_mod, target as tgt
from repro.core.approx import GaussStats
from repro.core.proxy import ProxySpec
from repro.core.selection import (SelectionConfig, run_selection,
                                  resume_phase, _phase_keep)
from repro.data.tasks import make_classification_task
from repro.engine import ClearEngine, MPCEngine, proxy_entropy
from repro.engine.clear import mlp_apply
from repro.engine.mpc import mlp_apply_mpc
from repro.mpc.sharing import share, reveal
from repro.mpc.comm import ledger_scope

K = jax.random.key(0)
CFG = dataclasses.replace(TINY_TARGET, vocab_size=256, n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
                          d_ff=128)


@pytest.fixture(scope="module")
def task():
    return make_classification_task(3, n_pool=300, n_test=150, seq=12,
                                    vocab=256, n_classes=4)


@pytest.fixture(scope="module")
def built_proxy(task):
    params = tgt.init_classifier(K, CFG, task.n_classes)
    spec = ProxySpec(2, 4, 8)
    mg = proxy_mod.extract_backbone(params, 2)
    boot = jnp.asarray(task.pool_tokens[:64])
    stats = proxy_mod.collect_stats(mg, CFG, boot, spec)
    pp = proxy_mod.build_proxy(K, mg, CFG, stats, spec, seq_len=12,
                               n_classes=4, exvivo_steps=120)
    return params, pp, spec


# ---------------------------------------------------------------------------
# MLP approximators
# ---------------------------------------------------------------------------

class TestApproxMLPs:
    def test_softmax_mlp_learns(self):
        stats = GaussStats(jnp.zeros(12), jnp.ones(12))
        p = approx.fit_softmax_mlp(K, stats, 12, 16, steps=400)
        x = stats.sample(jax.random.fold_in(K, 1), 256)
        err = jnp.abs(mlp_apply(p, x) - jax.nn.softmax(x, -1)).mean()
        assert float(err) < 0.05

    def test_rsqrt_mlp_learns(self):
        # variance inputs are bounded away from 0 in practice (LN of
        # d-dim activations); the MLP fits that regime
        stats = GaussStats(jnp.full((1,), 1.0), jnp.full((1,), 0.3))
        p = approx.fit_rsqrt_mlp(K, stats, 8, steps=800)
        v = jnp.abs(stats.sample(jax.random.fold_in(K, 2), 256)) + 1e-4
        rel = jnp.abs(mlp_apply(p, v) - jax.lax.rsqrt(v + 1e-5)) \
            / jax.lax.rsqrt(v + 1e-5)
        assert float(rel.mean()) < 0.12

    def test_entropy_mlp_preserves_ranking(self):
        """What selection needs: the MLP's output must RANK like entropy."""
        stats = GaussStats(jnp.zeros(4), jnp.full((4,), 2.0))
        p = approx.fit_entropy_mlp(K, stats, 4, 16, steps=4000)
        x = stats.sample(jax.random.fold_in(K, 3), 128)
        got = mlp_apply(p, x)[:, 0]
        want = approx.op_softmax_entropy(x)[:, 0]
        rho = np.corrcoef(np.argsort(np.argsort(np.asarray(got))),
                          np.argsort(np.argsort(np.asarray(want))))[0, 1]
        assert rho > 0.9, f"rank corr {rho}"

    def test_mlp_mpc_matches_clear(self, x64):
        p = approx.init_mlp(K, 6, 4, 6)
        x = jax.random.normal(jax.random.fold_in(K, 4), (8, 6))
        clear = mlp_apply(p, x)
        p_sh = proxy_mod.share_proxy(jax.random.fold_in(K, 5), p)
        x_sh = share(jax.random.fold_in(K, 6), x)
        got = reveal(mlp_apply_mpc(p_sh, x_sh, jax.random.fold_in(K, 7)))
        assert np.allclose(np.asarray(got), np.asarray(clear), atol=1e-3)


# ---------------------------------------------------------------------------
# proxy: clear vs MPC
# ---------------------------------------------------------------------------

class TestProxy:
    def test_proxy_entropy_mpc_parity(self, built_proxy, task, x64):
        params, pp, spec = built_proxy
        tok = jnp.asarray(task.pool_tokens[:12])
        clear = proxy_entropy(ClearEngine(), pp, CFG, tok, spec)
        pp_sh = proxy_mod.share_proxy(jax.random.fold_in(K, 8), pp)
        x = jnp.take(pp["embed"], tok, axis=0) * (CFG.d_model ** 0.5)
        with ledger_scope() as led:
            x_sh = share(jax.random.fold_in(K, 9), x.astype(jnp.float32))
            eng = MPCEngine().with_key(jax.random.fold_in(K, 10))
            ent = reveal(proxy_entropy(eng, pp_sh, CFG, x_sh, spec))
        assert np.abs(np.asarray(ent) - np.asarray(clear)).max() < 1e-3
        # top-half selection overlap must be near-perfect
        kk = 6
        top_c = set(np.argsort(np.asarray(clear))[-kk:].tolist())
        top_m = set(np.argsort(np.asarray(ent))[-kk:].tolist())
        assert len(top_c & top_m) >= kk - 1
        assert led.rounds > 0 and led.nbytes > 0

    def test_proxy_layer_count(self, built_proxy):
        _, pp, spec = built_proxy
        assert len(pp["mlp_sm"]) == spec.n_layers
        assert len(pp["mlp_ln"]) == spec.n_layers
        # 2l + 1 MLPs total (paper §4.3)
        assert 2 * spec.n_layers + 1 == \
            len(pp["mlp_sm"]) + len(pp["mlp_ln"]) + 1

    def test_pruned_shapes(self, built_proxy):
        params, pp, spec = built_proxy
        dh = CFG.d_head
        assert pp["attn"]["wq"].shape[-1] == spec.n_heads * dh
        assert pp["attn"]["wo"].shape[1] == spec.n_heads * dh


# ---------------------------------------------------------------------------
# end-to-end selection
# ---------------------------------------------------------------------------

class TestSelection:
    def test_phase_keep_schedule(self):
        keeps = _phase_keep(1000, 200, [ProxySpec(1, 1, 2, 0.5),
                                        ProxySpec(3, 4, 16, 1.0)])
        assert keeps == [500, 200]

    def test_selection_rebalances_and_beats_random(self, task):
        params = tgt.init_classifier(K, CFG, task.n_classes)
        # tiny proxies need the ex-vivo/in-vivo budget — undertrained
        # phase-1 MLPs invert the sieve (lesson recorded in §Perf notes)
        sel = SelectionConfig(phases=[ProxySpec(1, 2, 2, 0.6),
                                      ProxySpec(2, 4, 8, 1.0)],
                              budget_frac=0.3, boot_frac=0.08,
                              exvivo_steps=150, invivo_steps=80,
                              finetune_steps=60,
                              checkpoint_dir="/tmp/sel_test_ckpt")
        res = run_selection(K, params, CFG, task.pool_tokens, sel,
                            n_classes=task.n_classes,
                            boot_labels_fn=lambda i: task.pool_labels[i])
        assert len(res.selected) == int(0.3 * 300)
        # entropy selection must raise minority-class share vs the pool
        pool_minor = (task.pool_labels >= 2).mean()
        sel_minor = (task.pool_labels[res.selected] >= 2).mean()
        assert sel_minor > pool_minor
        # phase checkpointing: resume returns the last phase
        resumed = resume_phase(sel)
        assert resumed is not None
        assert np.array_equal(np.sort(resumed[1]),
                              np.sort(res.phase_survivors[resumed[0]]))

    def test_resume_skips_completed_phases(self, task, tmp_path,
                                           monkeypatch):
        """A re-run with the same key/config resumes from the phase
        checkpoints: no re-scoring, identical selection, restored
        appraisal. A different run sharing the dir must NOT resume
        (fingerprint guard)."""
        from repro.core import selection as sel_mod
        params = tgt.init_classifier(K, CFG, task.n_classes)
        calls = []
        orig_score = sel_mod._score_clear

        def counting_score(*a, **kw):
            calls.append(1)
            return orig_score(*a, **kw)

        monkeypatch.setattr(sel_mod, "_score_clear", counting_score)

        def make_sel():
            return SelectionConfig(phases=[ProxySpec(1, 2, 2, 0.5),
                                           ProxySpec(1, 2, 2, 1.0)],
                                   budget_frac=0.2, boot_frac=0.05,
                                   exvivo_steps=60, invivo_steps=20,
                                   finetune_steps=30,
                                   checkpoint_dir=str(tmp_path / "ck"))

        def go(k):
            return run_selection(k, params, CFG, task.pool_tokens,
                                 make_sel(), n_classes=task.n_classes,
                                 boot_labels_fn=lambda i:
                                     task.pool_labels[i])

        res1 = go(K)
        assert len(calls) == 2                      # both phases scored
        calls.clear()
        res2 = go(K)
        assert len(calls) == 0                      # fully resumed
        assert np.array_equal(res1.selected, res2.selected)
        assert res2.appraisal_entropy == pytest.approx(
            res1.appraisal_entropy)
        assert len(res2.phase_survivors) == len(res1.phase_survivors)
        # different execution config (variant ablation) sharing the dir
        # must not adopt the full run's survivors
        calls.clear()
        sel_v = make_sel()
        sel_v.variant = frozenset({"ln", "se"})
        run_selection(K, params, CFG, task.pool_tokens, sel_v,
                      n_classes=task.n_classes,
                      boot_labels_fn=lambda i: task.pool_labels[i])
        assert len(calls) == 2
        # different key -> different bootstrap draw -> fingerprints
        # mismatch -> checkpoints ignored, both phases re-scored
        calls.clear()
        go(jax.random.fold_in(K, 123))
        assert len(calls) == 2
        # resume=False opts out even for the matching run
        calls.clear()
        sel = make_sel()
        sel.resume = False
        run_selection(K, params, CFG, task.pool_tokens, sel,
                      n_classes=task.n_classes,
                      boot_labels_fn=lambda i: task.pool_labels[i])
        assert len(calls) == 2

    def test_survivors_monotone(self, task):
        params = tgt.init_classifier(K, CFG, task.n_classes)
        sel = SelectionConfig(phases=[ProxySpec(1, 2, 2, 0.5),
                                      ProxySpec(1, 2, 2, 1.0)],
                              budget_frac=0.2, boot_frac=0.05,
                              exvivo_steps=60, invivo_steps=20,
                              finetune_steps=30)
        res = run_selection(K, params, CFG, task.pool_tokens, sel,
                            n_classes=task.n_classes,
                            boot_labels_fn=lambda i: task.pool_labels[i])
        prev = None
        for surv in res.phase_survivors:
            if prev is not None:
                assert set(surv).issubset(set(prev))
            prev = surv
        assert not set(res.boot_idx) & set(res.phase_survivors[-1])


class TestAppraisalAndGates:
    def test_appraisal_threshold_one_bit(self, x64):
        """Paper §4.1: appraisal reveals only the comparison bit."""
        from repro.core.selection import appraise_threshold
        from repro.mpc.comm import ledger_scope
        ents = jnp.array([0.9, 1.1, 1.3, 0.2, 0.5])
        sh = share(jax.random.fold_in(K, 60), ents)
        idx = np.array([0, 1, 2])          # avg = 1.1
        with ledger_scope() as led:
            hi = appraise_threshold(sh, idx, 1.0, jax.random.fold_in(K, 61))
            lo = appraise_threshold(sh, idx, 1.2, jax.random.fold_in(K, 62))
        assert hi is True and lo is False
        # only comparison + the open inside mean's trunc path on the wire
        assert all(("cmp" in r.op) or ("open" in r.op) or ("trunc" in r.op)
                   for r in led.records)

    def test_gate_mlp_emulates_sigmoid(self):
        """Beyond-paper: RG-LRU/router sigmoid gates emulate like softmax.
        Elementwise sigmoid needs ~4 ReLU pieces per dim -> hidden 4x."""
        stats = GaussStats(jnp.zeros(8), jnp.ones(8) * 1.5)
        p = approx.fit_gate_mlp(K, stats, 8, 32, steps=1200)
        x = stats.sample(jax.random.fold_in(K, 63), 256)
        err = jnp.abs(mlp_apply(p, x) - jax.nn.sigmoid(x))
        assert float(err.mean()) < 0.05
