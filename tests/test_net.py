"""Real-wire runtime tests: transports, wire capture, reconciliation.

Everything except the `wire`-marked tests stays in-process
(LocalTransport threads / pure plan logic); the marked tests spawn real
party processes over localhost TCP.
"""
import hashlib

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import net
from repro.mpc import comm, ops, sharing
from repro.mpc.ring import RING64, x64_scope
from repro.net import transport as tp


# ---------------------------------------------------------------------------
# transport primitives
# ---------------------------------------------------------------------------

def test_local_transport_roundtrip_fifo():
    t = net.LocalTransport(2)
    t.send(0, 1, b"first")
    t.send(0, 1, b"second")
    t.send(1, 0, b"back")
    assert t.recv(1, 0) == b"first"
    assert t.recv(1, 0) == b"second"
    assert t.recv(0, 1) == b"back"
    assert t.total_data_bytes == len(b"first" + b"second" + b"back")
    assert t.data_bytes[0, 1] == 11


def test_local_transport_kinds_demuxed():
    t = net.LocalTransport(2)
    t.send(1, 0, b"", kind=tp.BEAT)
    t.send(1, 0, b"payload", kind=tp.DATA)
    # control frames never pollute the DATA byte count
    assert t.recv(0, 1, kind=tp.DATA) == b"payload"
    assert t.try_recv(0, 1, kind=tp.BEAT) == b""
    assert t.try_recv(0, 1, kind=tp.BEAT) is None
    assert t.total_data_bytes == 7


def test_local_transport_timeout_raises():
    t = net.LocalTransport(2)
    with pytest.raises(net.WireError):
        t.recv(0, 1, timeout=0.01)


def test_token_bucket_paces_with_fake_clock():
    now = [0.0]
    slept = []

    def clock():
        return now[0]

    def sleep(dt):
        slept.append(dt)
        now[0] += dt

    b = tp.TokenBucket(rate_Bps=1000.0, burst=100.0, clock=clock, sleep=sleep)
    assert b.throttle(100) == 0.0          # burst absorbs the first frame
    waited = b.throttle(500)               # then 500 B at 1 kB/s = 0.5 s
    assert waited == pytest.approx(0.5, rel=1e-6)
    assert sum(slept) == pytest.approx(0.5, rel=1e-6)


def test_free_ports_distinct():
    ports = tp.free_ports(3)
    assert len(set(ports)) == 3
    assert all(1024 <= p <= 65535 for p in ports)


# ---------------------------------------------------------------------------
# synthesized filler + payload normalization
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nbytes,rounds,n", [
    (432, 8, 2), (7, 3, 2), (1, 1, 3), (100, 2, 3), (0, 1, 2),
])
def test_synth_msgs_exact_bytes(nbytes, rounds, n):
    msgs = comm.synth_msgs(nbytes, rounds, n)
    assert sum(len(m.data) for m in msgs) == nbytes
    assert {m.rnd for m in msgs} == set(range(max(1, rounds)))
    for m in msgs:
        assert 0 <= m.src < n and 0 <= m.dst < n and m.src != m.dst


def test_normalize_payload_rejects_diverged_cost():
    with pytest.raises(ValueError):
        comm.normalize_payload([(0, 1, b"\x00" * 10)], nbytes=12, rounds=1,
                               n_parties=2)


def test_normalize_payload_abstract_falls_back_to_synth():
    def f(x):
        msgs = comm.normalize_payload([(0, 1, x)], nbytes=32, rounds=1,
                                      n_parties=2)
        assert sum(len(m.data) for m in msgs) == 32
        return x

    jax.eval_shape(f, jax.ShapeDtypeStruct((4,), jnp.int32))


# ---------------------------------------------------------------------------
# capture -> plan -> replay
# ---------------------------------------------------------------------------

def _capture(proto):
    with x64_scope():
        x = sharing.share(jax.random.PRNGKey(0),
                          jnp.arange(12.0).reshape(3, 4), RING64, proto)
        tape = comm.WireTape(x.backend.n_wire_parties)
        with comm.ledger_scope() as led, comm.wire_tape_scope(tape):
            y = ops.mul(x, x, jax.random.PRNGKey(1))
            y = ops.force(y, jax.random.PRNGKey(2))
            sharing.reveal(y)
    return led, tape


@pytest.mark.parametrize("proto", ["2pc", "3pc", "aby3trunc", "spdz2pc"])
def test_capture_reconciles_and_replays(proto):
    led, tape = _capture(proto)
    rec = net.reconcile(led, tape)
    assert rec["nbytes"] == led.nbytes
    rep = net.PartyRuntime(tape, mode="local", beat_every=1).execute()
    assert rep.bytes_match and rep.digests_ok
    assert rep.wire_nbytes == led.nbytes
    assert rep.n_flights == len(tape.flights)
    assert rep.suspects == []
    if tape.n_parties > 1:
        assert rep.beats_seen > 0     # liveness rode the same transport


def test_reconcile_detects_divergence():
    led, tape = _capture("2pc")
    tape.flights[0] = comm.WireFlight(
        tape.flights[0].op, tape.flights[0].rounds,
        tape.flights[0].nbytes + 8, tape.flights[0].tag,
        tape.flights[0].msgs)
    with pytest.raises(net.WireError):
        net.reconcile(led, tape)


def test_plan_covers_every_message_once():
    _, tape = _capture("3pc")
    n_msgs = sum(len(f.msgs) for f in tape.flights)
    sends = sum(len(s) for p in range(3)
                for fl in net.compile_plan(tape, p) for s, _ in fl)
    recvs = sum(len(r) for p in range(3)
                for fl in net.compile_plan(tape, p) for _, r in fl)
    assert sends == n_msgs and recvs == n_msgs


def test_expected_digests_match_manual():
    _, tape = _capture("2pc")
    want = net.expected_digests(tape, 2)
    # chained form: state = H(state || payload) — checkpointable, so a
    # crashed party can resume the digest from its flight cursor
    state = b""
    for f in tape.flights:
        for r in sorted({m.rnd for m in f.msgs} or {0}):
            for m in f.msgs:
                if m.rnd == r and m.dst == 1:
                    state = hashlib.blake2b(state + m.data,
                                            digest_size=16).digest()
    assert want[1] == state.hex()


def test_fused_flight_is_single_merged_exchange():
    """A fused group's payloads merge into ONE tape flight whose bytes
    still reconcile."""
    from repro.mpc import fusion
    with x64_scope():
        a = sharing.share(jax.random.PRNGKey(0), jnp.arange(4.0), RING64)
        b = sharing.share(jax.random.PRNGKey(1), jnp.arange(4.0) + 1, RING64)
        tape = comm.WireTape(2)
        with comm.ledger_scope() as led, comm.wire_tape_scope(tape), \
                fusion.flight_scope():
            sharing.open_(a)
            sharing.open_(b)
    online = [r for r in led.records if r.tag != "offline"]
    assert len(online) == 1 and len(tape.flights) == 1
    assert tape.flights[0].nbytes == led.nbytes
    net.reconcile(led, tape)


# ---------------------------------------------------------------------------
# executor integration
# ---------------------------------------------------------------------------

def _tiny_phase(protocol, wire, net_name="wan"):
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "benchmarks"))
    from common import tiny_exec_setup
    from repro.core.executor import ExecConfig, WaveExecutor

    cfg, spec, pp = tiny_exec_setup(0)
    tokens = np.asarray(
        jax.random.randint(jax.random.key(1), (16, 8), 0, cfg.vocab_size))
    ex = WaveExecutor(ExecConfig(wave=2, batch=8, protocol=protocol,
                                 wire=wire, net=net_name))
    ent = ex.score_phase(jax.random.key(2), pp, cfg, tokens, spec)
    return np.asarray(ent.sh), ex.reports[-1]


@pytest.mark.parametrize("protocol", ["2pc", "3pc"])
def test_executor_wire_local_bitwise_and_reconciled(protocol):
    ref, rep0 = _tiny_phase(protocol, "none")
    got, rep = _tiny_phase(protocol, "local")
    assert np.array_equal(ref, got)
    assert rep0.wire is None and rep.wire is not None
    assert rep.wire.bytes_match and rep.wire.digests_ok
    assert rep.wire.wire_nbytes == rep.ledger.nbytes
    assert rep.wire.wire_makespan_s > 0.0
    assert rep.agrees()               # wire capture never bends the ledger


def test_executor_rejects_unknown_wire_mode():
    from repro.core.executor import ExecConfig, WaveExecutor
    with pytest.raises(ValueError):
        WaveExecutor(ExecConfig(wire="carrier-pigeon"))


# ---------------------------------------------------------------------------
# socket transport — real processes, real TCP (marked)
# ---------------------------------------------------------------------------

@pytest.mark.wire
def test_socket_transport_pair_roundtrip():
    import threading
    ports = tp.free_ports(2)
    out = {}

    def party(p):
        t = tp.SocketTransport(2, p, ports)
        try:
            t.send(p, 1 - p, b"hello from %d" % p)
            out[p] = t.recv(p, 1 - p, timeout=10.0)
        finally:
            t.close()

    ths = [threading.Thread(target=party, args=(p,)) for p in range(2)]
    for th in ths:
        th.start()
    for th in ths:
        th.join(timeout=30.0)
    assert out == {0: b"hello from 1", 1: b"hello from 0"}


@pytest.mark.wire
@pytest.mark.parametrize("proto", ["2pc", "3pc"])
def test_socket_runtime_executes_tape(proto):
    led, tape = _capture(proto)
    rep = net.PartyRuntime(tape, mode="socket", beat_every=1).execute()
    assert rep.bytes_match and rep.digests_ok
    assert rep.wire_nbytes == led.nbytes
    assert rep.mode == "socket" and rep.n_parties == tape.n_parties
