"""Substrate tests: checkpoint, fault tolerance, optimizer, data, sharding."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import (AsyncCheckpointer, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data.pipeline import DataPipeline, synth_lm_batch
from repro.data.tasks import make_classification_task
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, \
    cosine_schedule
from repro.optim.compress import (init_error_state, int8_allreduce_sim,
                                  topk_compress_update, wire_bytes)
from repro.runtime.ft import (HeartbeatMonitor, StragglerMitigator,
                              plan_remesh, retry)

K = jax.random.key(0)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)},
                "step": jnp.int32(7)}
        save_checkpoint(str(tmp_path), 5, tree)
        got, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 5
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
            assert np.array_equal(np.asarray(a), np.asarray(b))

    def test_resume_latest_and_gc(self, tmp_path):
        tree = {"x": jnp.zeros((2,))}
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(str(tmp_path), s, tree, keep=3)
        assert latest_step(str(tmp_path)) == 5
        assert len(os.listdir(tmp_path)) == 3      # gc keeps 3

    def test_corrupt_step_skipped(self, tmp_path):
        tree = {"x": jnp.arange(4.0)}
        save_checkpoint(str(tmp_path), 1, tree)
        save_checkpoint(str(tmp_path), 2, tree)
        # corrupt the newest shard
        shard = tmp_path / "step_00000002" / "shard_0.npz"
        shard.write_bytes(b"garbage")
        got, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 1                            # fell back

    def test_uncommitted_step_invisible(self, tmp_path):
        tree = {"x": jnp.arange(4.0)}
        save_checkpoint(str(tmp_path), 1, tree)
        d = tmp_path / "step_00000002"
        d.mkdir()
        (d / "shard_0.npz").write_bytes(b"partial")  # no COMMIT
        assert latest_step(str(tmp_path)) == 1

    def test_async_checkpointer(self, tmp_path):
        ck = AsyncCheckpointer(str(tmp_path))
        tree = {"x": jnp.arange(8.0)}
        ck.save(3, tree)
        ck.wait()
        got, step = restore_checkpoint(str(tmp_path), tree)
        assert step == 3
        assert np.array_equal(np.asarray(got["x"]), np.arange(8.0))


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

class TestFT:
    def test_heartbeat_suspects(self):
        t = [0.0]
        hb = HeartbeatMonitor(3, timeout_s=5, clock=lambda: t[0])
        t[0] = 4.0
        hb.beat(0)
        hb.beat(1)
        t[0] = 7.0
        assert hb.suspects() == [2]
        assert not hb.healthy()

    def test_straggler_backup_fires(self):
        sm = StragglerMitigator(slack=0.5)
        for _ in range(10):
            sm.run(lambda: time.sleep(0.001))
        calls = []
        sm.run(lambda: (time.sleep(0.05), calls.append("slow"))[0],
               backup=lambda: calls.append("backup"))
        assert "backup" in calls
        assert sm.backups_fired == 1

    def test_retry_recovers(self):
        state = {"n": 0}

        def flaky():
            state["n"] += 1
            if state["n"] < 3:
                raise IOError("transient")
            return "ok"
        assert retry(flaky, attempts=4, backoff_s=0.0) == "ok"

    def test_retry_exhausts(self):
        with pytest.raises(IOError):
            retry(lambda: (_ for _ in ()).throw(IOError("x")),
                  attempts=2, backoff_s=0.0)

    @given(st.integers(2, 16), st.integers(2, 16))
    @settings(max_examples=20, deadline=None)
    def test_remesh_plan_covers(self, n, m):
        plan = plan_remesh((n,), (m,))
        # every destination host must receive its full range
        assert plan.reshard_fraction <= 1.0 + 1e-9
        if n == m:
            assert plan.reshard_fraction == 0.0


# ---------------------------------------------------------------------------
# optimizer + compression
# ---------------------------------------------------------------------------

class TestOptim:
    def test_adamw_descends_quadratic(self):
        p = {"w": jnp.array([3.0, -2.0])}
        st_ = init_opt_state(p)
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                          total_steps=1000)
        for _ in range(300):
            g = jax.tree.map(lambda w: 2 * w, p)
            p, st_, _ = adamw_update(p, g, st_, cfg)
        assert float(jnp.abs(p["w"]).max()) < 0.1

    def test_clip_norm(self):
        p = {"w": jnp.zeros((4,))}
        st_ = init_opt_state(p)
        cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0)
        _, _, stats = adamw_update(p, {"w": jnp.full((4,), 100.0)}, st_, cfg)
        assert float(stats["grad_norm"]) == pytest.approx(200.0)

    def test_schedule_shape(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(cosine_schedule(cfg, 0)) == 0.0
        assert float(cosine_schedule(cfg, 10)) == pytest.approx(1.0)
        assert float(cosine_schedule(cfg, 100)) == pytest.approx(0.1)

    def test_topk_error_feedback_unbiased(self):
        g = {"w": jax.random.normal(K, (128,))}
        e = init_error_state(g)
        acc = jnp.zeros((128,))
        for i in range(50):
            sparse, e = topk_compress_update(g, e, ratio=0.1)
            acc = acc + sparse["w"]
        # error feedback: accumulated transmitted mass approaches 50*g
        rel = float(jnp.linalg.norm(acc - 50 * g["w"]) /
                    jnp.linalg.norm(50 * g["w"]))
        assert rel < 0.15

    def test_int8_quant_bounded_error(self):
        g = {"w": jax.random.normal(K, (256,)) * 3}
        deq = int8_allreduce_sim(g, K)
        err = float(jnp.abs(deq["w"] - g["w"]).max())
        assert err < 2 * float(jnp.abs(g["w"]).max()) / 127
        assert wire_bytes(g, "int8") == 256
        assert wire_bytes(g, "topk", 0.01) < wire_bytes(g, "int8")


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

class TestData:
    def test_batch_deterministic(self):
        a = synth_lm_batch(0, 5, 0, 1, 8, 32, 1000)
        b = synth_lm_batch(0, 5, 0, 1, 8, 32, 1000)
        assert np.array_equal(a["tokens"], b["tokens"])
        c = synth_lm_batch(0, 6, 0, 1, 8, 32, 1000)
        assert not np.array_equal(a["tokens"], c["tokens"])

    def test_host_sharding_disjoint(self):
        a = synth_lm_batch(0, 5, 0, 2, 8, 32, 1000)
        b = synth_lm_batch(0, 5, 1, 2, 8, 32, 1000)
        assert a["tokens"].shape[0] == 4
        assert not np.array_equal(a["tokens"], b["tokens"])

    def test_pipeline_resume_exactly_once(self):
        p1 = DataPipeline(0, 4, 16, 100, start_step=0)
        batches = [next(p1) for _ in range(3)]
        state = p1.state()
        p1.close()
        p2 = DataPipeline(0, 4, 16, 100, start_step=state.step)
        nxt = next(p2)
        p2.close()
        ref = synth_lm_batch(0, 3, 0, 1, 4, 16, 100)
        assert np.array_equal(nxt["tokens"], ref["tokens"])

    def test_task_imbalance(self):
        t = make_classification_task(0, n_pool=1000, n_classes=4,
                                     imbalance=8.0)
        counts = np.bincount(t.pool_labels, minlength=4)
        assert counts[0] > 3 * counts[3]
        # test set stays balanced-ish
        tc = np.bincount(t.test_labels, minlength=4)
        assert tc.min() > 0.15 * len(t.test_labels)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

class TestSharding:
    def test_fit_spec_drops_uneven_and_duplicates(self):
        from repro.parallel.sharding import ShardRules, fit_spec
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = ShardRules(mesh)
        spec = fit_spec(rules, (14, 64), ["model", "model"])
        # axis size 1 divides everything, but a mesh axis may be used by
        # only one dim (SP/vocab conflicts) -> second use dropped
        assert spec == jax.sharding.PartitionSpec("model", None)

    def test_param_specs_cover_all_leaves(self):
        from repro.configs import load_arch
        from repro.models import transformer as T
        from repro.parallel.sharding import ShardRules, param_specs
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        rules = ShardRules(mesh)
        for arch in ("qwen2_0_5b", "mamba2_2_7b", "phi3_5_moe",
                     "recurrentgemma_2b", "whisper_small"):
            cfg = load_arch(arch, smoke=True)
            shapes = jax.eval_shape(lambda k: T.init_params(k, cfg), K)
            specs = param_specs(shapes, rules)
            assert jax.tree.structure(specs) == jax.tree.structure(shapes)
