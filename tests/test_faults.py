"""Chaos-hardened wire: deterministic fault injection, reliable
delivery, crash recovery, degraded 2-of-3.

Most tests run mode="local" (threads over in-process queues — fast and
deterministic); `chaos`+`wire`-marked tests spawn real party processes
over localhost TCP and exercise TCP reconnect + supervisor respawn.
"""
import hashlib
import json

import jax
import jax.numpy as jnp
import pytest

from repro import net
from repro.mpc import comm, ops, sharing
from repro.mpc.ring import RING64, x64_scope
from repro.net import faults as fx
from repro.net import transport as tp


def _capture(proto):
    with x64_scope():
        x = sharing.share(jax.random.PRNGKey(0),
                          jnp.arange(12.0).reshape(3, 4), RING64, proto)
        tape = comm.WireTape(x.backend.n_wire_parties)
        with comm.ledger_scope() as led, comm.wire_tape_scope(tape):
            y = ops.mul(x, x, jax.random.PRNGKey(1))
            y = ops.force(y, jax.random.PRNGKey(2))
            sharing.reveal(y)
    return led, tape


# ---------------------------------------------------------------------------
# FaultPlan: determinism + serialization
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic():
    _, tape = _capture("3pc")
    a = fx.FaultPlan.from_tape(123, tape)
    b = fx.FaultPlan.from_tape(123, tape)
    assert a == b                      # same (seed, tape) -> same plan
    assert a.n_faults >= 4             # drops + spike + reset + crash
    c = fx.FaultPlan.from_tape(124, tape)
    assert c != a                      # the seed is load-bearing


def test_fault_plan_json_roundtrip():
    _, tape = _capture("2pc")
    plan = fx.FaultPlan.from_tape(7, tape, slow_party=1, slow_s=0.01)
    again = fx.FaultPlan.from_json(plan.to_json())
    assert again == plan
    # serialized placement is introspectable (--chaos-plan files)
    raw = json.loads(plan.to_json())
    assert raw["seed"] == 7 and "drops" in raw and "crash" in raw


def test_fault_plan_without_crash():
    _, tape = _capture("3pc")
    plan = fx.FaultPlan.from_tape(123, tape)
    assert plan.crash is not None
    respawn_plan = plan.without_crash()
    assert respawn_plan.crash is None
    assert respawn_plan.drops == plan.drops    # link faults stay armed


def test_injected_crash_skips_except_exception():
    # protocol code wraps ops in `except Exception` — a scheduled death
    # must not be survivable there
    assert issubclass(fx.InjectedCrash, BaseException)
    assert not issubclass(fx.InjectedCrash, Exception)


def test_link_frames_population():
    _, tape = _capture("2pc")
    frames = tape.link_frames()
    assert sum(frames.values()) == sum(len(f.msgs) for f in tape.flights)
    assert all(src != dst for src, dst in frames)


# ---------------------------------------------------------------------------
# reliable delivery primitives (no processes, no sockets)
# ---------------------------------------------------------------------------

def test_reliable_dedups_duplicate_frames():
    base = tp.LocalTransport(2)
    rel = tp.ReliableTransport(base)
    rel.send(0, 1, b"first")                      # seq 0
    base.send(0, 1, b"first", tp.DATA, seq=0)     # wire-level duplicate
    rel.send(0, 1, b"second")                     # seq 1
    assert rel.recv(1, 0, timeout=1.0) == b"first"
    assert rel.recv(1, 0, timeout=1.0) == b"second"   # dup skipped
    assert rel.dup_frames == 1


def test_reliable_recovers_dropped_frame():
    base = tp.LocalTransport(2)
    chaos = fx.ChaosTransport(
        base, fx.FaultPlan(seed=0, drops={(0, 1): (0,)}))
    rel = tp.ReliableTransport(chaos, rto_s=0.01)
    rel.send(0, 1, b"eaten")                      # dropped on the wire
    rel.send(0, 1, b"later")
    # single-threaded: the recv observes the gap and posts a resend
    # request, which party 0's next transport touch services (in the
    # runtime that touch happens from party 0's own thread)
    with pytest.raises(tp.WireError):
        rel.recv(1, 0, timeout=0.05)
    rel._service_control(0)                       # sender honors request
    assert rel.recv(1, 0, timeout=1.0) == b"eaten"
    assert rel.recv(1, 0, timeout=1.0) == b"later"
    assert chaos.dropped == 1 and rel.retries > 0
    assert rel.resends_honored > 0


def test_goodput_vs_retrans_channels():
    base = tp.LocalTransport(2)
    chaos = fx.ChaosTransport(
        base, fx.FaultPlan(seed=0, drops={(0, 1): (1,)}))
    rel = tp.ReliableTransport(chaos, rto_s=0.01)
    payloads = [b"a" * 10, b"b" * 10, b"c" * 10]
    for p in payloads:
        rel.send(0, 1, p)
    assert rel.recv(1, 0, timeout=1.0) == payloads[0]
    with pytest.raises(tp.WireError):
        rel.recv(1, 0, timeout=0.05)              # gap: frame 1 dropped
    rel._service_control(0)
    assert rel.recv(1, 0, timeout=1.0) == payloads[1]
    assert rel.recv(1, 0, timeout=1.0) == payloads[2]
    # goodput counted once per frame (drop included: priced at first
    # transmission), recovery bytes on the separate RETRANS channel
    assert base.total_data_bytes == 30
    assert base.total_retrans_bytes > 0
    assert rel.total_data_bytes == 30


def test_local_purge_counts_lost_frames():
    base = tp.LocalTransport(2)
    base.send(0, 1, b"x" * 5, tp.DATA, seq=0)
    base.send(0, 1, b"y" * 5, tp.DATA, seq=1)
    assert base.purge(0, 1, tp.DATA) == 2
    with pytest.raises(tp.WireError):
        base.recv(1, 0, timeout=0.01)


# ---------------------------------------------------------------------------
# chaos replay: local mode (fast path, runs in tier-1)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("proto", ["2pc", "3pc"])
def test_chaos_local_replay_reconciles(proto):
    led, tape = _capture(proto)
    plan = fx.FaultPlan.from_tape(123, tape, crash=False)
    assert plan.n_faults > 0
    rep = net.PartyRuntime(tape, mode="local", fault_plan=plan).execute()
    assert rep.bytes_match and rep.digests_ok
    assert rep.wire_nbytes == led.nbytes       # goodput == ledger
    assert rep.retries > 0
    assert rep.retrans_bytes > 0
    assert rep.faults_injected == plan.n_faults
    assert rep.fault_plan == plan.to_json()


def test_chaos_local_crash_respawn_resumes_from_cursor():
    led, tape = _capture("3pc")
    plan = fx.FaultPlan.from_tape(123, tape)
    assert plan.crash is not None and plan.crash[1] >= 1   # mid-phase
    rep = net.PartyRuntime(tape, mode="local", fault_plan=plan,
                           recover=True).execute()
    assert rep.bytes_match and rep.digests_ok
    assert rep.respawns == 1
    assert rep.recovery_time_s > 0
    assert not rep.degraded


def test_chaos_crash_without_recovery_policy_rejected():
    _, tape = _capture("3pc")
    plan = fx.FaultPlan.from_tape(123, tape)
    with pytest.raises(ValueError):
        net.PartyRuntime(tape, mode="local", fault_plan=plan)


def test_degraded_two_of_three_completes():
    led, tape = _capture("3pc")
    plan = fx.FaultPlan.from_tape(7, tape, n_drops=0, n_spikes=0,
                                  n_resets=0, crash_at_boundary=True)
    assert plan.crash is not None and plan.crash[1] == 0
    rep = net.PartyRuntime(tape, mode="local", fault_plan=plan,
                           degraded=True).execute()
    assert rep.degraded
    assert rep.dead_parties == [plan.crash[0]]
    assert rep.bytes_match and rep.digests_ok   # vs the FILTERED tape
    assert rep.respawns == 0


def test_filter_tape_drops_dead_party_messages():
    _, tape = _capture("3pc")
    filtered = net.filter_tape(tape, dead=2)
    assert len(filtered.flights) == len(tape.flights)
    for f in filtered.flights:
        assert all(m.src != 2 and m.dst != 2 for m in f.msgs)
        assert f.nbytes == sum(len(m.data) for m in f.msgs)


def test_chaos_scores_bitwise_identical_to_fault_free():
    led, tape = _capture("2pc")
    clean = net.PartyRuntime(tape, mode="local").execute()
    plan = fx.FaultPlan.from_tape(123, tape, crash=False)
    chaotic = net.PartyRuntime(tape, mode="local", fault_plan=plan).execute()
    # the digest chain is over delivered payloads: identical delivery
    # under faults IS bitwise-identical replay
    assert clean.digests_ok and chaotic.digests_ok
    assert clean.wire_nbytes == chaotic.wire_nbytes == led.nbytes


def test_expected_digest_chain_is_checkpointable():
    _, tape = _capture("2pc")
    want = net.expected_digests(tape, 2)
    state = b""
    for f in tape.flights:
        for r in sorted({m.rnd for m in f.msgs} or {0}):
            for m in f.msgs:
                if m.rnd == r and m.dst == 0:
                    state = hashlib.blake2b(state + m.data,
                                            digest_size=16).digest()
    assert want[0] == state.hex()


# ---------------------------------------------------------------------------
# socket wire: real processes, real TCP faults (marked)
# ---------------------------------------------------------------------------

@pytest.mark.chaos
@pytest.mark.wire
def test_socket_wire_down_raises_immediately():
    """Satellite gate: a dead link is LOUD. The peer closes, and both
    send and recv raise WireDown promptly instead of blocking out their
    timeout against a wire nobody is servicing."""
    import threading
    import time
    ports = tp.free_ports(2)
    out = {}

    def party(p):
        t = tp.SocketTransport(2, p, ports)
        try:
            t.send(p, 1 - p, b"hello")
            t.recv(p, 1 - p, timeout=10.0)
            if p == 1:
                t.close()                 # dies without saying goodbye
                out[p] = "closed"
                return
            deadline = time.monotonic() + 10.0
            while t.link_down(1) is None and time.monotonic() < deadline:
                time.sleep(0.02)
            assert t.link_down(1) is not None
            with pytest.raises(tp.WireDown):
                t.send(0, 1, b"into the void")
            t0 = time.monotonic()
            with pytest.raises(tp.WireDown):
                t.recv(0, 1, timeout=30.0)
            assert time.monotonic() - t0 < 5.0    # loud, not a timeout
            out[p] = "down-raised"
        finally:
            t.close()

    ths = [threading.Thread(target=party, args=(p,)) for p in range(2)]
    for th in ths:
        th.start()
    for th in ths:
        th.join(timeout=30.0)
    assert out.get(0) == "down-raised" and out.get(1) == "closed"


@pytest.mark.chaos
@pytest.mark.wire
@pytest.mark.parametrize("proto", ["2pc", "3pc"])
def test_socket_chaos_replay_recovers(proto):
    """The headline gate: drops + a latency spike + a TCP reset + (3pc)
    a party crash mid-phase, over real processes — replay completes,
    goodput reconciles, digests match, retries observed."""
    led, tape = _capture(proto)
    plan = fx.FaultPlan.from_tape(123, tape)
    rep = net.PartyRuntime(tape, mode="socket",
                           profile=comm.PROFILES["pod_dcn"],
                           timeout_s=60.0, fault_plan=plan,
                           recover=True).execute()
    assert rep.bytes_match and rep.digests_ok
    assert rep.wire_nbytes == led.nbytes
    assert rep.retries > 0
    if plan.crash is not None:
        assert rep.respawns >= 1
        assert rep.recovery_time_s > 0
