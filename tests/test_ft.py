"""Fault tolerance over a real transport: heartbeats as BEAT frames on
`net.LocalTransport` with injected clocks, and deterministic straggler
backup-wins — the liveness path the PartyRuntime drives between flights."""
import pytest

from repro import net
from repro.net import transport as tp
from repro.runtime.ft import (HeartbeatMonitor, StragglerMitigator,
                              TransportHeartbeat)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


class TestTransportHeartbeat:
    def test_beats_ride_transport_as_beat_frames(self):
        t = net.LocalTransport(3)
        clk = FakeClock()
        mon = HeartbeatMonitor(3, timeout_s=5.0, clock=clk)
        hb0 = TransportHeartbeat(t, 0, 3, monitor=mon, kind=tp.BEAT)
        hb1 = TransportHeartbeat(t, 1, 3, kind=tp.BEAT)
        hb2 = TransportHeartbeat(t, 2, 3, kind=tp.BEAT)
        hb1.emit()
        hb2.emit()
        assert hb0.drain() == 2
        assert mon.suspects() == []
        # beats are control frames: the DATA byte count stays untouched
        assert t.total_data_bytes == 0

    def test_silent_party_marked_suspect(self):
        t = net.LocalTransport(3)
        clk = FakeClock()
        mon = HeartbeatMonitor(3, timeout_s=5.0, clock=clk)
        hb0 = TransportHeartbeat(t, 0, 3, monitor=mon, kind=tp.BEAT)
        hb1 = TransportHeartbeat(t, 1, 3, kind=tp.BEAT)
        hb2 = TransportHeartbeat(t, 2, 3, kind=tp.BEAT)
        for step in range(4):
            clk.t = step * 3.0
            hb1.emit()
            if step == 0:
                hb2.emit()       # party 2 dies after its first beat
            hb0.drain()
        # t=9: party 1 beat at 9, party 2 last beat at 0, party 0 vouched
        # for itself on every drain
        assert mon.suspects() == [2]
        assert not mon.healthy()

    def test_emitter_without_monitor_drains_nothing(self):
        t = net.LocalTransport(2)
        hb1 = TransportHeartbeat(t, 1, 2, kind=tp.BEAT)
        hb1.emit()
        assert hb1.drain() == 0        # no monitor -> a no-op, not a crash
        assert hb1.beats_seen == 0

    def test_runtime_feeds_monitor_end_to_end(self):
        """PartyRuntime wires TransportHeartbeat in: a healthy replay
        sees beats from every non-zero party and no suspects."""
        import jax.numpy as jnp
        import jax
        from repro.mpc import comm, ops, sharing
        from repro.mpc.ring import RING64, x64_scope
        with x64_scope():
            x = sharing.share(jax.random.PRNGKey(0), jnp.arange(8.0),
                              RING64, "3pc")
            tape = comm.WireTape(3)
            with comm.ledger_scope(), comm.wire_tape_scope(tape):
                y = ops.mul(x, x, jax.random.PRNGKey(1))
                y = ops.force(y, jax.random.PRNGKey(2))
                sharing.reveal(y)
        rep = net.PartyRuntime(tape, mode="local", beat_every=1).execute()
        assert rep.beats_seen >= 2     # both non-zero parties reported in
        assert rep.suspects == []


class TestStragglerInjectedClock:
    def _warm(self, sm, clk, dt=1.0, n=10):
        for _ in range(n):
            def fast():
                clk.t += dt
            sm.run(fast)

    def test_backup_wins_on_straggling_recv(self):
        """The mitigated task is a real recv over LocalTransport that
        never arrives; the backup path wins deterministically under the
        injected clock."""
        t = net.LocalTransport(2)
        clk = FakeClock()
        sm = StragglerMitigator(slack=2.0, clock=clk)
        self._warm(sm, clk)            # p95 ~= 1.0 -> deadline 2.0
        wins = []

        def straggler():
            clk.t += 10.0              # recv timed out way past deadline
            if t.try_recv(0, 1) is None:
                return None

        def backup():
            wins.append("backup")
            return "backup"

        assert sm.run(straggler, backup=backup) == "backup"
        assert wins == ["backup"] and sm.backups_fired == 1

    def test_fast_task_fires_no_backup(self):
        t = net.LocalTransport(2)
        clk = FakeClock()
        sm = StragglerMitigator(slack=2.0, clock=clk)
        self._warm(sm, clk)

        def fast():
            t.send(1, 0, b"x")
            clk.t += 0.5
            return t.recv(0, 1)

        assert sm.run(fast, backup=lambda: pytest.fail("backup fired")) \
            == b"x"
        assert sm.backups_fired == 0

    def test_deadline_needs_history(self):
        sm = StragglerMitigator(clock=FakeClock())
        assert sm.deadline() == float("inf")
